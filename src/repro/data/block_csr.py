"""Block-local sharded CSR: the feature-distributed layout of a PaddedCSR.

The masked global-CSR view of a feature shard keeps *global* padded rows
``(indices, values)`` on every worker and masks per-block membership on
every access — ``(idx >= lo) & (idx < hi)`` plus a ``where``-guarded
gather, O(nnz_max) work per worker per row regardless of q.  That defeats
the paper's whole point: worker l's compute should shrink with the number
of workers.

``BlockCSR`` re-indexes once, at load time.  For each feature block l of a
:class:`~repro.core.partition.FeaturePartition` it stores the block's
entries of every instance as padded rows with a *per-block* nnz budget:

    indices[l]: int32[N, nnz_l]   LOCAL feature ids in [0, dim_l), pad 0
    values[l]:  float[N, nnz_l]   matching values, pad 0.0

so worker l gathers against its local dense ``w`` block with zero masking
arithmetic — the hot-path cost is O(nnz_l) ≈ O(nnz_max / q).  Padding with
(local id 0, value 0.0) is safe for every operation here (dots and
scatter-adds): a zero value contributes nothing.

Entry order within a row is preserved from the source PaddedCSR, so
per-feature scatter accumulation order — and therefore floating point —
matches the global layout.

:func:`local_margins` / :func:`local_scatter` are the two block-local hot
paths; they are also the numerics contract for the fused Pallas kernels in
:mod:`repro.kernels` (``sparse_margin``, ``fused_update``).
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.sparse import PaddedCSR

if TYPE_CHECKING:  # import would cycle through repro.core.__init__ at runtime
    from repro.core.partition import FeaturePartition


@dataclasses.dataclass(frozen=True)
class BlockCSR:
    """A PaddedCSR re-indexed into q block-local shards."""

    partition: FeaturePartition
    indices: tuple[jax.Array, ...]  # per block: int32[N, nnz_l], local ids
    values: tuple[jax.Array, ...]  # per block: float[N, nnz_l]
    labels: jax.Array  # float[N], in {-1, +1}
    dim: int  # global d
    # Per-block column-nnz statistics: int32[dim_l] counting, for each
    # LOCAL feature id, the number of instances whose rows store it with a
    # nonzero value (explicit zeros were dropped by from_padded, so these
    # are structural-nonzero counts of the layout as stored).  They feed
    # the probabilistic lazy-update step corrections N/nnz_col(j) — see
    # repro.kernels.lazy_update.  None means "not computed" (direct
    # constructions); use nnz_col_block() which computes on demand.
    nnz_col: tuple[jax.Array, ...] | None = None
    # The source's global padded-row width (PaddedCSR.nnz_max).  The
    # drivers charge per-instance communication/compute cost against it,
    # so carrying it here lets a run start from slabs alone — no global
    # PaddedCSR in memory.  None on direct constructions that predate the
    # streaming path; use global_nnz_max() which falls back to the sum of
    # per-block budgets (exact when budgets are tight and rows dense).
    nnz_max: int | None = None

    @property
    def num_blocks(self) -> int:
        return self.partition.num_blocks

    @property
    def num_instances(self) -> int:
        return int(self.indices[0].shape[0])

    @property
    def block_dims(self) -> tuple[int, ...]:
        return tuple(self.partition.block_sizes())

    @property
    def nnz_budgets(self) -> tuple[int, ...]:
        return tuple(int(i.shape[1]) for i in self.indices)

    def block(self, l: int) -> tuple[jax.Array, jax.Array]:
        return self.indices[l], self.values[l]

    def global_nnz_max(self) -> int:
        """The global padded-row width the cost model charges against.

        Exact when set by the constructor (``from_padded`` /
        ``stream_block_csr``); otherwise a conservative reconstruction
        from the per-block budgets (their sum bounds the widest global
        row from above).
        """
        if self.nnz_max is not None:
            return self.nnz_max
        return int(sum(self.nnz_budgets))

    def nnz_col_block(self, l: int) -> jax.Array:
        """int32[dim_l] per-feature instance counts for block ``l``.

        Counts rows storing a *nonzero* value at each local id, so padding
        and explicit zeros (which the scatter/gather paths cannot
        distinguish — see the explicit-zero invariant on
        :meth:`from_padded`) contribute nothing.  Precomputed by
        :meth:`from_padded`; computed on demand for directly-constructed
        instances (host-side numpy, cheap relative to re-indexing).
        """
        if self.nnz_col is not None:
            return self.nnz_col[l]
        return jnp.asarray(
            _count_cols(
                np.asarray(self.indices[l]),
                np.asarray(self.values[l]),
                int(self.block_dims[l]),
            )
        )

    @classmethod
    def from_padded(
        cls,
        data: PaddedCSR,
        partition: FeaturePartition,
        *,
        lane_multiple: int = 1,
    ) -> "BlockCSR":
        """Build the block-local layout (host-side, once per data set).

        ``lane_multiple`` rounds each block's nnz budget up (TPU lane
        padding); 1 keeps the budgets tight, which the equivalence tests
        use.  The single-block partition reuses the PaddedCSR rows as-is
        (local ids == global ids when lo = 0), so the q = 1 path is
        bit-for-bit the global layout.

        **Explicit-zero invariant.**  Entries with ``value == 0.0`` are
        dropped during re-indexing (the ``val != 0.0`` filter below), so
        an explicitly stored zero becomes indistinguishable from padding —
        including the collision case where a genuine ``(global id lo,
        0.0)`` entry would land exactly on the padding pattern ``(local
        id 0, value 0.0)``.  This is safe for every operation this layout
        supports — dots (:func:`local_margins`) and scatter-adds
        (:func:`local_scatter`) — because a zero *value* contributes
        nothing regardless of its index; the property tests in
        ``tests/test_block_csr.py`` pin margins/scatter equality against
        the masked oracle on data containing explicit zeros.  Any future
        operation that keys off *structural* nonzeros (e.g. counting
        stored entries per feature) must not assume explicit zeros
        survive this constructor.
        """
        if partition.dim != data.dim:
            raise ValueError(
                f"partition covers dim={partition.dim}, data has dim={data.dim}"
            )
        if partition.num_blocks == 1:
            return cls(
                partition=partition,
                indices=(data.indices,),
                values=(data.values,),
                labels=data.labels,
                dim=data.dim,
                nnz_col=(
                    jnp.asarray(
                        _count_cols(
                            np.asarray(data.indices),
                            np.asarray(data.values),
                            data.dim,
                        )
                    ),
                ),
                nnz_max=data.nnz_max,
            )
        idx = np.asarray(data.indices)
        val = np.asarray(data.values)
        n = idx.shape[0]
        block_indices: list[jax.Array] = []
        block_values: list[jax.Array] = []
        block_nnz_col: list[jax.Array] = []
        for l in range(partition.num_blocks):
            lo, hi = partition.block(l)
            in_blk = (idx >= lo) & (idx < hi) & (val != 0.0)
            counts = in_blk.sum(axis=1)
            budget = max(1, int(counts.max()) if n else 1)
            budget += (-budget) % lane_multiple
            out_idx = np.zeros((n, budget), dtype=np.int32)
            out_val = np.zeros((n, budget), dtype=val.dtype)
            rows, cols = np.nonzero(in_blk)  # row-major: preserves row order
            # position of each entry within its (compacted) row
            pos = np.arange(rows.size) - np.searchsorted(rows, rows, side="left")
            out_idx[rows, pos] = idx[rows, cols] - lo
            out_val[rows, pos] = val[rows, cols]
            block_indices.append(jnp.asarray(out_idx))
            block_values.append(jnp.asarray(out_val))
            block_nnz_col.append(
                jnp.asarray(_count_cols(out_idx, out_val, hi - lo))
            )
        return cls(
            partition=partition,
            indices=tuple(block_indices),
            values=tuple(block_values),
            labels=data.labels,
            dim=data.dim,
            nnz_col=tuple(block_nnz_col),
            nnz_max=data.nnz_max,
        )

    def stacked(self, budget: int | None = None) -> tuple[jax.Array, jax.Array]:
        """Uniform-budget [q, N, B] index/value stacks for ``shard_map``.

        shard_map shards need identical shapes per worker, so every block
        is padded up to a common nnz budget (default: the max per-block
        budget).  Shard the leading axis over the feature mesh axes and
        each worker receives only its O(nnz_max/q)-wide local rows.
        """
        common = max(self.nnz_budgets)
        if budget is not None:
            if budget < common:
                raise ValueError(f"budget {budget} < required {common}")
            common = budget
        idx = jnp.stack(
            [
                jnp.pad(i, ((0, 0), (0, common - i.shape[1])))
                for i in self.indices
            ]
        )
        val = jnp.stack(
            [
                jnp.pad(v, ((0, 0), (0, common - v.shape[1])))
                for v in self.values
            ]
        )
        return idx, val

    def nnz_total(self) -> int:
        return int(sum(jnp.sum(v != 0.0) for v in self.values))


def _count_cols(indices: np.ndarray, values: np.ndarray, dim: int) -> np.ndarray:
    """int32[dim] count of rows storing a nonzero value per local id."""
    mask = values != 0.0
    return np.bincount(
        indices[mask].reshape(-1), minlength=dim
    ).astype(np.int32)


def aot_nnz_budget(nnz_max: int, q: int) -> int:
    """Stacked-layout nnz budget for AOT (dry-run / perf) shapes.

    The runtime budget is data-dependent (``BlockCSR.stacked``); for
    compile-only shapes we model nnz_max/q with 4x slack for skewed text
    feature popularity, never below one lane octet.  Keep in lockstep
    with what ``run_fdsvrg_sharded`` feeds the compiled step.
    """
    return max(8, -(-nnz_max // q) * 4)


def local_margins(
    indices: jax.Array, values: jax.Array, w_block: jax.Array
) -> jax.Array:
    """s^(l)_i = w^(l)T x^(l)_i from block-LOCAL padded rows.

    No membership mask, no id arithmetic: ``indices`` are already local to
    ``w_block``.  Works on [N, nnz_l] (full data) and [u, nnz_l] (sampled
    rows) alike.
    """
    return jnp.sum(w_block[indices] * values, axis=-1)


def local_scatter(
    indices: jax.Array,
    values: jax.Array,
    coeffs: jax.Array,
    block_dim: int,
) -> jax.Array:
    """sum_i coeffs_i * x^(l)_i as a dense block vector, local ids only."""
    flat_idx = indices.reshape(-1)
    flat_val = (values * coeffs[..., None]).reshape(-1)
    return jnp.zeros((block_dim,), dtype=values.dtype).at[flat_idx].add(flat_val)
