"""Padded-CSR sparse matrices for high-dimensional (d >> N) data in JAX.

The paper's data sets (news20, url, webspam, kdd2010) are extremely sparse
text/web feature matrices with d up to 29.9M.  TPUs (and XLA generally)
want static shapes, so we store each instance with a fixed nnz budget:

    indices: int32[N, nnz_max]   feature ids, padded with 0
    values:  float32[N, nnz_max] feature values, padded with 0.0

Padding with (index 0, value 0.0) is safe for every operation used here
(dots and scatter-adds), because a zero value contributes nothing.

The feature-distributed view of the same matrix lives in
:mod:`repro.data.block_csr`: per-block re-indexed padded rows with a
per-block nnz budget, so a worker's gather/scatter work is O(nnz_max/q)
against local ids with zero masking arithmetic.  (The historical
masked-global view — keep global ids everywhere and select ids in
[lo, hi) with ``(idx >= lo) & (idx < hi)`` on every access — cost every
worker the full O(nnz_max) per row and survives only as the oracle the
BlockCSR property tests compare against.)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PaddedCSR:
    """A sparse d x N design matrix stored instance-major with padded rows."""

    indices: jax.Array  # int32[N, nnz_max]
    values: jax.Array  # float32[N, nnz_max]
    labels: jax.Array  # float32[N], in {-1, +1}
    dim: int  # d

    @property
    def num_instances(self) -> int:
        return int(self.indices.shape[0])

    @property
    def nnz_max(self) -> int:
        return int(self.indices.shape[1])

    def nnz_total(self) -> int:
        return int(jnp.sum(self.values != 0.0))

    def instance(self, i: int) -> tuple[jax.Array, jax.Array]:
        return self.indices[i], self.values[i]

    def to_dense(self) -> np.ndarray:
        """Dense d x N matrix (tests / tiny data only)."""
        n, nnz = self.indices.shape
        out = np.zeros((self.dim, n), dtype=np.float32)
        idx = np.asarray(self.indices).reshape(-1)
        val = np.asarray(self.values, dtype=np.float32).reshape(-1)
        cols = np.repeat(np.arange(n), nnz)
        # np.add.at handles repeated indices (padding collides on 0).
        np.add.at(out, (idx, cols), val)
        return out


def margins_rows(
    indices: jax.Array, values: jax.Array, w: jax.Array
) -> jax.Array:
    """s_i = w^T x_i from padded rows; the one definition of the margin
    gather every global-layout path shares (objective, full gradient,
    serial inner loop)."""
    return jnp.sum(w[indices] * values, axis=-1)


def margins(data: PaddedCSR, w: jax.Array) -> jax.Array:
    """s_i = w^T x_i for all instances; w is the dense d-vector."""
    return margins_rows(data.indices, data.values, w)


def scatter_grad(
    indices: jax.Array,
    values: jax.Array,
    coeffs: jax.Array,
    dim: int,
) -> jax.Array:
    """sum_i coeffs_i * x_i as a dense d-vector (the data-dependent gradient).

    indices/values: [N, nnz]; coeffs: [N].
    """
    flat_idx = indices.reshape(-1)
    flat_val = (values * coeffs[:, None]).reshape(-1)
    return jnp.zeros((dim,), dtype=values.dtype).at[flat_idx].add(flat_val)
