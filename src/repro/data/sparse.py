"""Padded-CSR sparse matrices for high-dimensional (d >> N) data in JAX.

The paper's data sets (news20, url, webspam, kdd2010) are extremely sparse
text/web feature matrices with d up to 29.9M.  TPUs (and XLA generally)
want static shapes, so we store each instance with a fixed nnz budget:

    indices: int32[N, nnz_max]   feature ids, padded with 0
    values:  float32[N, nnz_max] feature values, padded with 0.0

Padding with (index 0, value 0.0) is safe for every operation used here
(dots and scatter-adds), because a zero value contributes nothing.

The feature-distributed view of the same matrix keeps *global* feature ids
but masks per-block membership, so a worker's shard is (indices, values,
mask) with the mask selecting ids in [lo, hi).  Gathers against a local
dense w block subtract ``lo``; masked-out lanes read w[0] and are zeroed
by the mask, which keeps everything shape-static.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PaddedCSR:
    """A sparse d x N design matrix stored instance-major with padded rows."""

    indices: jax.Array  # int32[N, nnz_max]
    values: jax.Array  # float32[N, nnz_max]
    labels: jax.Array  # float32[N], in {-1, +1}
    dim: int  # d

    @property
    def num_instances(self) -> int:
        return int(self.indices.shape[0])

    @property
    def nnz_max(self) -> int:
        return int(self.indices.shape[1])

    def nnz_total(self) -> int:
        return int(jnp.sum(self.values != 0.0))

    def instance(self, i: int) -> tuple[jax.Array, jax.Array]:
        return self.indices[i], self.values[i]

    def to_dense(self) -> np.ndarray:
        """Dense d x N matrix (tests / tiny data only)."""
        n, _ = self.indices.shape
        out = np.zeros((self.dim, n), dtype=np.float32)
        idx = np.asarray(self.indices)
        val = np.asarray(self.values)
        for i in range(n):
            # np.add.at handles repeated indices (padding collides on 0).
            np.add.at(out[:, i], idx[i], val[i])
        return out


def margins(data: PaddedCSR, w: jax.Array) -> jax.Array:
    """s_i = w^T x_i for all instances; w is the dense d-vector."""
    gathered = w[data.indices]  # [N, nnz]
    return jnp.sum(gathered * data.values, axis=1)


def margins_block(
    indices: jax.Array,
    values: jax.Array,
    w_block: jax.Array,
    lo: int,
) -> jax.Array:
    """Partial margins from one feature block [lo, lo+len(w_block)).

    ``indices``/``values`` are global padded-CSR rows; entries outside the
    block are masked out.  Returns s^(l)_i = w^(l)T x^(l)_i.
    """
    hi = lo + w_block.shape[0]
    in_block = (indices >= lo) & (indices < hi)
    local = jnp.where(in_block, indices - lo, 0)
    gathered = jnp.where(in_block, w_block[local], 0.0)
    return jnp.sum(gathered * values, axis=-1)


def scatter_grad(
    indices: jax.Array,
    values: jax.Array,
    coeffs: jax.Array,
    dim: int,
) -> jax.Array:
    """sum_i coeffs_i * x_i as a dense d-vector (the data-dependent gradient).

    indices/values: [N, nnz]; coeffs: [N].
    """
    flat_idx = indices.reshape(-1)
    flat_val = (values * coeffs[:, None]).reshape(-1)
    return jnp.zeros((dim,), dtype=values.dtype).at[flat_idx].add(flat_val)


def scatter_grad_block(
    indices: jax.Array,
    values: jax.Array,
    coeffs: jax.Array,
    lo: int,
    block_dim: int,
) -> jax.Array:
    """Feature-block view of :func:`scatter_grad` — only coords in [lo, lo+block_dim)."""
    hi = lo + block_dim
    in_block = (indices >= lo) & (indices < hi)
    local = jnp.where(in_block, indices - lo, 0)
    contrib = jnp.where(in_block, values, 0.0) * coeffs[..., None]
    flat_idx = local.reshape(-1)
    flat_val = contrib.reshape(-1)
    return jnp.zeros((block_dim,), dtype=values.dtype).at[flat_idx].add(flat_val)
