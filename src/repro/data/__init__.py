from repro.data.sparse import PaddedCSR
from repro.data import datasets, synthetic

__all__ = ["PaddedCSR", "datasets", "synthetic"]
