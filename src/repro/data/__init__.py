from repro.data.sparse import PaddedCSR
from repro.data.block_csr import BlockCSR, local_margins, local_scatter
from repro.data import datasets, synthetic
from repro.data.libsvm import load_libsvm, scan_libsvm, write_libsvm
from repro.data.pipeline import (
    ArraySource,
    DataSource,
    LibSVMSource,
    SyntheticSource,
    as_source,
    stream_block_csr,
)
from repro.data.ingest_cache import get_or_build

__all__ = [
    "PaddedCSR",
    "BlockCSR",
    "local_margins",
    "local_scatter",
    "datasets",
    "synthetic",
    "load_libsvm",
    "scan_libsvm",
    "write_libsvm",
    "ArraySource",
    "DataSource",
    "LibSVMSource",
    "SyntheticSource",
    "as_source",
    "stream_block_csr",
    "get_or_build",
]
