from repro.data.sparse import PaddedCSR
from repro.data.block_csr import BlockCSR, local_margins, local_scatter
from repro.data import datasets, synthetic

__all__ = [
    "PaddedCSR",
    "BlockCSR",
    "local_margins",
    "local_scatter",
    "datasets",
    "synthetic",
]
