"""Synthetic high-dimensional sparse linear-classification data (d >> N).

We cannot ship LibSVM's news20/url/webspam/kdd2010 in this container, so we
generate sparse data with the same *statistical shape*: very high
dimensionality, low per-instance nnz, heavy-tailed feature popularity
(text-like Zipf), and labels from a sparse ground-truth separator plus
noise.  This preserves everything the paper's claims depend on (d vs N,
sparsity, conditioning); see data/datasets.py for the paper-shaped presets.
"""

from __future__ import annotations

import numpy as np

from repro.data.sparse import PaddedCSR
import jax.numpy as jnp


def make_sparse_classification(
    *,
    dim: int,
    num_instances: int,
    nnz_per_instance: int,
    seed: int = 0,
    zipf_a: float = 1.3,
    label_noise: float = 0.02,
    teacher_nnz_frac: float = 0.05,
) -> PaddedCSR:
    """Generate a PaddedCSR data set with a planted sparse separator.

    Feature ids are drawn from a Zipf-like popularity distribution (text
    data: few very common tokens, long tail), values are tf-idf-ish
    positive weights normalized per instance (LibSVM text sets are
    L2-normalized rows).
    """
    rng = np.random.default_rng(seed)

    # Popularity ranking: probability ∝ (rank+1)^(-zipf_a), over dim features.
    # Sampling directly from a d=30M categorical is slow; use the standard
    # inverse-CDF trick on a continuous Pareto approximation.
    u = rng.random((num_instances, nnz_per_instance))
    raw = u ** (-1.0 / (zipf_a - 1.0)) - 1.0
    raw = np.minimum(raw, float(dim))  # clamp before the int cast (u ~ 0)
    ranks = np.clip(np.floor(raw).astype(np.int64), 0, dim - 1)
    # Scatter popular ranks across the id space deterministically so blocks
    # are statistically balanced (the paper balances blocks by features).
    perm_mult = 2654435761 % dim
    indices = (ranks * perm_mult + 12345) % dim

    # Deduplicate within an instance by nudging collisions (cheap, rare).
    for _ in range(2):
        sort_ix = np.argsort(indices, axis=1)
        srt = np.take_along_axis(indices, sort_ix, axis=1)
        dup = np.zeros_like(srt, dtype=bool)
        dup[:, 1:] = srt[:, 1:] == srt[:, :-1]
        bump = np.zeros_like(indices)
        np.put_along_axis(bump, sort_ix, dup.astype(np.int64), axis=1)
        indices = (indices + bump * 97) % dim

    values = rng.gamma(2.0, 1.0, size=(num_instances, nnz_per_instance)).astype(
        np.float32
    )
    norms = np.linalg.norm(values, axis=1, keepdims=True)
    values = values / np.maximum(norms, 1e-8)

    # Planted sparse teacher on the most popular feature ids so that the
    # signal is actually observable.
    teacher_nnz = max(1, int(dim * teacher_nnz_frac))
    teacher_ids = (np.arange(teacher_nnz, dtype=np.int64) * perm_mult + 12345) % dim
    teacher = np.zeros(dim, dtype=np.float32)
    teacher[teacher_ids] = rng.normal(0.0, 1.0, size=teacher_nnz).astype(np.float32)

    margins = np.einsum(
        "ij,ij->i", values, teacher[indices].astype(np.float32)
    )
    labels = np.sign(margins + 1e-12)
    flip = rng.random(num_instances) < label_noise
    labels = np.where(flip, -labels, labels).astype(np.float32)
    labels = np.where(labels == 0, 1.0, labels)

    return PaddedCSR(
        indices=jnp.asarray(indices, dtype=jnp.int32),
        values=jnp.asarray(values),
        labels=jnp.asarray(labels),
        dim=dim,
    )


def make_dense_classification(
    *, dim: int, num_instances: int, seed: int = 0, label_noise: float = 0.0
) -> tuple[np.ndarray, np.ndarray]:
    """Small dense problem (tests): returns (D [d x N], y [N])."""
    rng = np.random.default_rng(seed)
    D = rng.normal(0.0, 1.0, size=(dim, num_instances)).astype(np.float32)
    D /= np.maximum(np.linalg.norm(D, axis=0, keepdims=True), 1e-8)
    teacher = rng.normal(0.0, 1.0, size=dim).astype(np.float32)
    y = np.sign(teacher @ D)
    flip = rng.random(num_instances) < label_noise
    y = np.where(flip, -y, y).astype(np.float32)
    y = np.where(y == 0, 1.0, y)
    return D, y
