"""Streaming sparse ingestion: ``DataSource`` -> per-worker ``BlockCSR``.

The paper's whole argument is the d >> N regime (news20 d=1.35M, webspam
d=16.6M, kdd2010 d=29.9M), where *no node ever holds the full design
matrix* — yet the original loaders materialized a global
:class:`~repro.data.sparse.PaddedCSR` on one host before any worker saw
its feature slice.  This module is the fix, three layers:

* **:class:`DataSource`** — one protocol over "where rows come from":
  an in-memory array (:class:`ArraySource`), the synthetic generator
  (:class:`SyntheticSource`), or an on-disk LibSVM file
  (:class:`LibSVMSource`).  A source yields bounded
  :class:`RowChunk`\\ s (a mini padded-CSR of ``chunk_rows`` rows), knows
  its :class:`SourceStats` up front, and has a content ``digest()`` that
  keys the on-disk slab cache (:mod:`repro.data.ingest_cache`).
* **:func:`stream_block_csr`** — incremental BlockCSR construction:
  worker l's slab is built chunk-by-chunk from only the features in
  ``[lo_l, hi_l)`` (plus the ``nnz_col`` stats the lazy-proba kernels
  need), never materializing the global ``[N, nnz_max]`` arrays.  Peak
  extra memory is one chunk plus the slabs being built
  (:func:`stream_block_slab` builds a single worker's slab for the truly
  out-of-core case).
* **the bit contract** — for every chunk size, q, and padding budget the
  streamed build is **bit-identical** to the one-shot
  ``PaddedCSR -> BlockCSR.from_padded`` path (property-tested in
  ``tests/test_ingest.py``).  The construction mirrors ``from_padded``'s
  placement exactly: entries keep file/row order, explicit zeros are
  dropped for q > 1 and kept as-is for q = 1, budgets and ``nnz_col``
  are computed over the same masks.

This module used to hold the LM token synthesizer; that moved to
:mod:`repro.data.token_stream` (a deprecation shim below keeps the old
names importable) so ``pipeline.py`` is the sparse-ingestion module its
name claims.
"""

from __future__ import annotations

import abc
import dataclasses
import hashlib
import os
from typing import Iterator

import numpy as np

from repro.data import libsvm as libsvm_lib
from repro.data.block_csr import BlockCSR, _count_cols
from repro.data.sparse import PaddedCSR

#: Default rows-per-chunk budget; at news20-like widths (~500 stored
#: entries/row, 8 bytes each) this holds host memory near 256 MiB.
DEFAULT_CHUNK_ROWS = 65536


@dataclasses.dataclass(frozen=True)
class SourceStats:
    """What a source knows about itself before any slab is built."""

    num_instances: int
    dim: int
    nnz_max: int  # global padded-row width (>= 1 for parsed text sources)
    nnz_total: int


@dataclasses.dataclass(frozen=True)
class RowChunk:
    """A bounded slice of rows in the padded layout.

    Same conventions as :class:`~repro.data.sparse.PaddedCSR`: entries
    left-aligned in source order, padded with ``(0, 0.0)``; ``labels``
    are already canonical {-1, +1} in the values' float family.
    """

    indices: np.ndarray  # int32[c, w]
    values: np.ndarray  # float[c, w]
    labels: np.ndarray  # float[c]


class DataSource(abc.ABC):
    """Where rows come from.  Implementations must be deterministic: the
    same source yields the same chunks (hence the same slabs) every pass,
    and ``digest()`` changes iff the rows would."""

    @property
    @abc.abstractmethod
    def name(self) -> str: ...

    @abc.abstractmethod
    def stats(self) -> SourceStats: ...

    @abc.abstractmethod
    def digest(self) -> str:
        """Content digest keying the on-disk slab cache."""

    @abc.abstractmethod
    def chunks(
        self, chunk_rows: int = DEFAULT_CHUNK_ROWS
    ) -> Iterator[RowChunk]: ...

    def materialize(self) -> PaddedCSR:
        """The global padded layout (instance-sharded baselines need it).

        This IS the allocation streaming exists to avoid — callers on the
        d >> N sets should prefer :func:`stream_block_csr`.
        """
        import jax.numpy as jnp

        stats = self.stats()
        width = stats.nnz_max
        idx_parts, val_parts, lab_parts = [], [], []
        for chunk in self.chunks():
            pad = width - chunk.indices.shape[1]
            idx_parts.append(np.pad(chunk.indices, ((0, 0), (0, pad))))
            val_parts.append(np.pad(chunk.values, ((0, 0), (0, pad))))
            lab_parts.append(chunk.labels)
        return PaddedCSR(
            indices=jnp.asarray(np.vstack(idx_parts)),
            values=jnp.asarray(np.vstack(val_parts)),
            labels=jnp.asarray(np.concatenate(lab_parts)),
            dim=stats.dim,
        )


def is_source(obj) -> bool:
    return isinstance(obj, DataSource)


def as_source(obj) -> DataSource:
    """Coerce a PaddedCSR, a ``*.libsvm`` path, or a DataSource."""
    if isinstance(obj, DataSource):
        return obj
    if isinstance(obj, PaddedCSR):
        return ArraySource(obj)
    if isinstance(obj, (str, os.PathLike)):
        return LibSVMSource(os.fspath(obj))
    raise TypeError(
        f"cannot build a DataSource from {type(obj).__name__}; pass a "
        "PaddedCSR, a LibSVM file path, or a DataSource"
    )


# ---------------------------------------------------------------------------
# Sources
# ---------------------------------------------------------------------------


class ArraySource(DataSource):
    """An in-memory :class:`PaddedCSR`, chunked by row slices.

    Chunk width is the array's full padded width, so the q = 1 streamed
    build reproduces the arrays as-is — including the stored-explicit-zero
    / padding ambiguity ``BlockCSR.from_padded`` documents.
    """

    def __init__(self, data: PaddedCSR, *, name: str = "array") -> None:
        self._data = data
        self._name = name
        self._digest: str | None = None

    @property
    def name(self) -> str:
        return self._name

    def stats(self) -> SourceStats:
        values = np.asarray(self._data.values)
        # Exact array width, unclamped: bit-parity with from_padded
        # extends to the metadata (nnz_max) even for width-0 arrays.
        return SourceStats(
            num_instances=self._data.num_instances,
            dim=self._data.dim,
            nnz_max=self._data.nnz_max,
            nnz_total=int(np.count_nonzero(values)),
        )

    def digest(self) -> str:
        if self._digest is None:
            h = hashlib.sha256()
            h.update(f"array:v1:dim={self._data.dim}:".encode())
            for arr in (self._data.indices, self._data.values, self._data.labels):
                a = np.ascontiguousarray(np.asarray(arr))
                h.update(str((a.dtype, a.shape)).encode())
                h.update(a.tobytes())
            self._digest = h.hexdigest()
        return self._digest

    def chunks(
        self, chunk_rows: int = DEFAULT_CHUNK_ROWS
    ) -> Iterator[RowChunk]:
        if chunk_rows < 1:
            raise ValueError(f"chunk_rows >= 1 required, got {chunk_rows}")
        indices = np.asarray(self._data.indices)
        values = np.asarray(self._data.values)
        labels = np.asarray(self._data.labels)
        for lo in range(0, indices.shape[0], chunk_rows):
            hi = lo + chunk_rows
            yield RowChunk(indices[lo:hi], values[lo:hi], labels[lo:hi])

    def materialize(self) -> PaddedCSR:
        return self._data


class SyntheticSource(DataSource):
    """The synthetic generator behind a parametric digest.

    The digest is a pure function of the generation parameters (plus the
    generator's version tag), so a cache key never requires generating
    the data; the rows themselves are generated once, on first access.
    """

    def __init__(
        self,
        *,
        dim: int,
        num_instances: int,
        nnz_per_instance: int,
        seed: int = 0,
        name: str = "synthetic",
    ) -> None:
        self._dim = dim
        self._n = num_instances
        self._nnz = nnz_per_instance
        self._seed = seed
        self._name = name
        self._generated: ArraySource | None = None

    @classmethod
    def from_dataset(
        cls, dataset: str, *, scaled: bool = True, seed: int = 0
    ) -> "SyntheticSource":
        from repro.data import datasets

        spec = datasets.spec(dataset, scaled=scaled)
        return cls(
            dim=spec.dim,
            num_instances=spec.num_instances,
            nnz_per_instance=spec.nnz_per_instance,
            seed=seed,
            name=f"{dataset}{'' if scaled else '-full'}",
        )

    @property
    def name(self) -> str:
        return self._name

    def stats(self) -> SourceStats:
        # The generator emits exactly nnz_per_instance entries per row,
        # all nonzero (gamma draws), so stats need no generation.
        return SourceStats(
            num_instances=self._n,
            dim=self._dim,
            nnz_max=self._nnz,  # generated width is exactly nnz_per_instance
            nnz_total=self._n * self._nnz,
        )

    def digest(self) -> str:
        from repro.data.synthetic import GENERATOR_VERSION

        return hashlib.sha256(
            f"synthetic:v{GENERATOR_VERSION}:dim={self._dim}:n={self._n}:"
            f"nnz={self._nnz}:seed={self._seed}".encode()
        ).hexdigest()

    def _array(self) -> ArraySource:
        if self._generated is None:
            from repro.data.synthetic import make_sparse_classification

            self._generated = ArraySource(
                make_sparse_classification(
                    dim=self._dim,
                    num_instances=self._n,
                    nnz_per_instance=self._nnz,
                    seed=self._seed,
                ),
                name=self._name,
            )
        return self._generated

    def chunks(
        self, chunk_rows: int = DEFAULT_CHUNK_ROWS
    ) -> Iterator[RowChunk]:
        return self._array().chunks(chunk_rows)

    def materialize(self) -> PaddedCSR:
        return self._array().materialize()


class LibSVMSource(DataSource):
    """An on-disk LibSVM file, parsed in bounded chunks.

    The stats pass (:func:`repro.data.libsvm.scan_libsvm`) runs once per
    source object and fixes the label convention from the file's global
    label alphabet; ``dim`` defaults to ``max stored id + 1`` and may be
    overridden with the true dimensionality (files omit all-zero
    columns).  ``digest()`` is the file content's sha256 — hashing, not
    parsing, so a warm cache hit never tokenizes a line — memoized
    against ``(size, mtime_ns)``.
    """

    def __init__(self, path: str, *, dim: int | None = None) -> None:
        self.path = os.fspath(path)
        self._dim_arg = dim
        self._stats: SourceStats | None = None
        self._mapper = None
        self._digest: tuple[tuple[int, int], str] | None = None

    @property
    def name(self) -> str:
        return os.path.basename(self.path)

    def _scan(self) -> SourceStats:
        if self._stats is None:
            scanned = libsvm_lib.scan_libsvm(self.path)
            if scanned.num_instances == 0:
                raise ValueError(f"{self.path}: no data rows")
            dim = max(scanned.max_index + 1, 1)
            if self._dim_arg is not None:
                if self._dim_arg <= scanned.max_index:
                    raise ValueError(
                        f"dim={self._dim_arg} but {self.path} stores feature "
                        f"id {scanned.max_index} (0-based)"
                    )
                dim = self._dim_arg
            self._mapper = libsvm_lib.canonical_label_map(scanned.label_values)
            self._stats = SourceStats(
                num_instances=scanned.num_instances,
                dim=dim,
                nnz_max=max(1, scanned.nnz_max),
                nnz_total=scanned.nnz_total,
            )
        return self._stats

    def stats(self) -> SourceStats:
        return self._scan()

    def digest(self) -> str:
        st = os.stat(self.path)
        key = (st.st_size, st.st_mtime_ns)
        if self._digest is None or self._digest[0] != key:
            h = hashlib.sha256()
            h.update(f"libsvm:v1:dim={self._dim_arg}:".encode())
            with open(self.path, "rb") as f:
                for block in iter(lambda: f.read(1 << 20), b""):
                    h.update(block)
            self._digest = (key, h.hexdigest())
        return self._digest[1]

    def chunks(
        self, chunk_rows: int = DEFAULT_CHUNK_ROWS
    ) -> Iterator[RowChunk]:
        self._scan()  # fixes the label convention before the first chunk
        for raw_labels, indices, values in libsvm_lib.iter_libsvm_chunks(
            self.path, chunk_rows
        ):
            yield RowChunk(indices, values, self._mapper(raw_labels))

    def materialize(self) -> PaddedCSR:
        stats = self._scan()
        return libsvm_lib.load_libsvm(self.path, dim=stats.dim)


# ---------------------------------------------------------------------------
# Incremental BlockCSR construction
# ---------------------------------------------------------------------------


class _RawAccumulator:
    """q = 1: keep rows as-is (``from_padded``'s single-block fast path —
    stored explicit zeros and padding survive untouched)."""

    def __init__(self, dim: int, width: int) -> None:
        self.dim = dim
        self.width = width
        self._idx: list[np.ndarray] = []
        self._val: list[np.ndarray] = []

    def add(self, idx: np.ndarray, val: np.ndarray) -> None:
        pad = self.width - idx.shape[1]
        if pad < 0:
            raise ValueError(
                f"chunk width {idx.shape[1]} exceeds the source's declared "
                f"nnz_max {self.width}"
            )
        self._idx.append(np.pad(idx, ((0, 0), (0, pad))))
        self._val.append(np.pad(val, ((0, 0), (0, pad))))

    def finalize(self, lane_multiple: int):
        del lane_multiple  # from_padded's q=1 path keeps budgets as-is
        idx = np.vstack(self._idx) if self._idx else np.zeros((0, self.width), np.int32)
        val = np.vstack(self._val) if self._val else np.zeros((0, self.width), np.float32)
        return idx, val, _count_cols(idx, val, self.dim)


class _BlockAccumulator:
    """One feature block's compacted entries, chunk by chunk.

    Mirrors ``BlockCSR.from_padded``'s per-block pass exactly — the mask,
    the row-major compaction order, the budget rule, the ``nnz_col``
    counts — restricted to one chunk of rows at a time.  ``finalize``
    pastes the per-chunk compacted strips into the ``[N, budget]`` slab.
    """

    def __init__(self, lo: int, hi: int) -> None:
        self.lo = lo
        self.hi = hi
        self._strips: list[tuple[np.ndarray, np.ndarray]] = []
        self._rows = 0
        self._max_count = 0
        self._nnz_col = np.zeros(hi - lo, dtype=np.int64)

    def add(self, idx: np.ndarray, val: np.ndarray) -> None:
        in_blk = (idx >= self.lo) & (idx < self.hi) & (val != 0.0)
        counts = in_blk.sum(axis=1)
        c = idx.shape[0]
        w = int(counts.max()) if c else 0
        self._max_count = max(self._max_count, w)
        out_idx = np.zeros((c, w), dtype=np.int32)
        out_val = np.zeros((c, w), dtype=val.dtype)
        rows, cols = np.nonzero(in_blk)  # row-major: preserves row order
        pos = np.arange(rows.size) - np.searchsorted(rows, rows, side="left")
        out_idx[rows, pos] = idx[rows, cols] - self.lo
        out_val[rows, pos] = val[rows, cols]
        self._strips.append((out_idx, out_val))
        self._rows += c
        if rows.size:
            self._nnz_col += np.bincount(
                out_idx[rows, pos].astype(np.int64), minlength=self.hi - self.lo
            )

    def finalize(self, lane_multiple: int):
        budget = max(1, self._max_count)
        budget += (-budget) % lane_multiple
        dtype = self._strips[0][1].dtype if self._strips else np.float32
        indices = np.zeros((self._rows, budget), dtype=np.int32)
        values = np.zeros((self._rows, budget), dtype=dtype)
        row0 = 0
        for s_idx, s_val in self._strips:
            c, w = s_idx.shape
            if w:
                indices[row0 : row0 + c, :w] = s_idx
                values[row0 : row0 + c, :w] = s_val
            row0 += c
        return indices, values, self._nnz_col.astype(np.int32)


def _accumulators(partition, block_ids, width):
    out = {}
    for l in block_ids:
        if partition.num_blocks == 1:
            out[l] = _RawAccumulator(partition.dim, width)
        else:
            lo, hi = partition.block(l)
            out[l] = _BlockAccumulator(lo, hi)
    return out


def stream_block_csr(
    source: DataSource,
    partition,
    *,
    chunk_rows: int = DEFAULT_CHUNK_ROWS,
    lane_multiple: int = 1,
) -> BlockCSR:
    """Build the full per-worker :class:`BlockCSR` by streaming ``source``.

    Bit-identical to ``BlockCSR.from_padded(source.materialize(),
    partition, lane_multiple=...)`` for any ``chunk_rows`` — that is the
    ingestion contract (property-tested) — without ever allocating the
    global ``[N, nnz_max]`` padded arrays.  Peak host memory is one chunk
    plus the compacted slabs themselves.
    """
    stats = source.stats()
    if partition.dim != stats.dim:
        raise ValueError(
            f"partition covers dim={partition.dim}, source has "
            f"dim={stats.dim}"
        )
    q = partition.num_blocks
    acc = _accumulators(partition, range(q), stats.nnz_max)
    labels_parts: list[np.ndarray] = []
    for chunk in source.chunks(chunk_rows):
        labels_parts.append(chunk.labels)
        for a in acc.values():
            a.add(chunk.indices, chunk.values)
    return _assemble(
        partition, acc, labels_parts, stats, lane_multiple, source
    )


def stream_block_slab(
    source: DataSource,
    partition,
    block_id: int,
    *,
    chunk_rows: int = DEFAULT_CHUNK_ROWS,
    lane_multiple: int = 1,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """ONE worker's ``(indices, values, nnz_col)`` slab — the truly
    out-of-core shape: worker ``block_id`` parses the stream and keeps
    only its own ``[lo, hi)`` entries (O(nnz_l) memory, q parse passes
    for q workers instead of one — the :mod:`repro.data.ingest_cache`
    amortizes that to once ever)."""
    stats = source.stats()
    if partition.dim != stats.dim:
        raise ValueError(
            f"partition covers dim={partition.dim}, source has "
            f"dim={stats.dim}"
        )
    acc = _accumulators(partition, [block_id], stats.nnz_max)[block_id]
    for chunk in source.chunks(chunk_rows):
        acc.add(chunk.indices, chunk.values)
    return acc.finalize(lane_multiple)


def _assemble(partition, acc, labels_parts, stats, lane_multiple, source):
    import jax.numpy as jnp

    q = partition.num_blocks
    block_indices, block_values, block_nnz_col = [], [], []
    for l in range(q):
        idx, val, nnz_col = acc[l].finalize(lane_multiple)
        block_indices.append(jnp.asarray(idx))
        block_values.append(jnp.asarray(val))
        block_nnz_col.append(jnp.asarray(nnz_col))
    labels = (
        np.concatenate(labels_parts)
        if labels_parts
        else np.zeros((0,), np.float32)
    )
    if labels.shape[0] != stats.num_instances:
        raise ValueError(
            f"source {source.name!r} declared {stats.num_instances} "
            f"instances but yielded {labels.shape[0]} rows"
        )
    return BlockCSR(
        partition=partition,
        indices=tuple(block_indices),
        values=tuple(block_values),
        labels=jnp.asarray(labels),
        dim=stats.dim,
        nnz_col=tuple(block_nnz_col),
        nnz_max=stats.nnz_max,
    )


# ---------------------------------------------------------------------------
# Streaming inference helpers (serving without materializing)
# ---------------------------------------------------------------------------


def streamed_margins(
    source: DataSource,
    w,
    *,
    chunk_rows: int = DEFAULT_CHUNK_ROWS,
) -> np.ndarray:
    """``w^T x_i`` for every row of ``source``, one chunk at a time.

    ``w`` is ``[d]`` (returns ``[n]``) or multi-output ``[d, k]``
    (returns ``[n, k]`` in ONE pass over the source — column ``j`` is
    computed exactly like the ``k = 1`` call with ``w[:, j]``, so a
    one-vs-rest model never pays k parse passes over a file)."""
    w = np.asarray(w)
    if w.ndim not in (1, 2):
        raise ValueError(f"w must be [d] or [d, k], got shape {w.shape}")
    parts = []
    for chunk in source.chunks(chunk_rows):
        if w.ndim == 2:
            # One gather per column, NOT w[chunk.indices][:, :, j]: einsum
            # over a strided column slice reduces in a different order
            # than over the contiguous gather the k = 1 path sees, and
            # the per-column bit contract would quietly break.
            parts.append(
                np.stack(
                    [
                        np.einsum(
                            "rk,rk->r", w[:, j][chunk.indices], chunk.values
                        )
                        for j in range(w.shape[1])
                    ],
                    axis=1,
                )
            )
        else:
            parts.append(
                np.einsum("rk,rk->r", w[chunk.indices], chunk.values)
            )
    if parts:
        return np.concatenate(parts)
    shape = (0,) if w.ndim == 1 else (0, w.shape[1])
    return np.zeros(shape, dtype=w.dtype)


def source_labels(
    source: DataSource, *, chunk_rows: int = DEFAULT_CHUNK_ROWS
) -> np.ndarray:
    """The canonical {-1, +1} labels, streamed."""
    parts = [chunk.labels for chunk in source.chunks(chunk_rows)]
    return (
        np.concatenate(parts) if parts else np.zeros((0,), dtype=np.float32)
    )


# ---------------------------------------------------------------------------
# Deprecation shim: the LM token pipeline moved to repro.data.token_stream
# ---------------------------------------------------------------------------

_TOKEN_STREAM_NAMES = ("PipelineConfig", "batches", "_token_stream")


def __getattr__(name: str):
    if name in _TOKEN_STREAM_NAMES:
        import warnings

        warnings.warn(
            f"repro.data.pipeline.{name} moved to repro.data.token_stream; "
            "this alias will be removed",
            DeprecationWarning,
            stacklevel=2,
        )
        from repro.data import token_stream

        return getattr(token_stream, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
