"""Chunked LibSVM text ingestion: parse, scan, write.

The paper's data sets (news20, url, webspam, kdd2010 — and the Avazu set
of the mxnet feature-distributed exemplar) ship as LibSVM text:

    <label> <index>:<value> <index>:<value> ...   # optional comment

with **1-based** feature indices.  This module owns the three text-level
operations; everything block/worker-shaped lives in
:mod:`repro.data.pipeline`:

* :func:`iter_libsvm_rows` / :func:`iter_libsvm_chunks` — a streaming
  parser holding one chunk of rows in memory at a time.  Handles the
  format's corners: 1-based indices (converted to 0-based here, once),
  ``#`` comments (whole-line and trailing), blank lines, empty rows
  (label only), ranking ``qid:`` tokens (skipped), and duplicate feature
  ids (preserved in file order — the scatter paths apply duplicates in
  program order, so order is part of the numerics contract).
* :func:`scan_libsvm` — the cheap stats pass (N, max index, widest row,
  label alphabet) a streaming build needs before it can partition
  features or canonicalize labels.
* :func:`write_libsvm` — the inverse, used by tests/benchmarks to
  generate real files from synthetic data.  Values are written with
  ``repr`` so a float32 survives the text round trip bit-for-bit.
* :func:`load_libsvm` — one-shot file -> :class:`PaddedCSR`, built on
  the same chunk iterator (ONE parser; the streamed and one-shot paths
  cannot drift).

Label conventions: files in the wild use {-1,+1}, {0,1}, or two
arbitrary values.  :func:`canonical_label_map` fixes one deterministic
rule — +/-1 pass through, {0,1} maps 0 -> -1, any other two-value
alphabet maps (sorted) low -> -1 / high -> +1 — applied identically by
the one-shot loader and the streaming source, from the *global* label
alphabet (a per-chunk decision would be ambiguous: a chunk containing
only ``1``\\ s cannot know whether its file is 0/1- or +/-1-coded).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, TextIO

import numpy as np


class LibSVMFormatError(ValueError):
    """A malformed LibSVM line, with the 1-based line number."""


@dataclasses.dataclass(frozen=True)
class LibSVMStats:
    """What one stats pass over a file learns (see :func:`scan_libsvm`)."""

    num_instances: int
    max_index: int  # largest 0-based feature id seen; -1 if no entries
    nnz_max: int  # widest row (stored entries, explicit zeros included)
    nnz_total: int  # stored entries over the whole file
    label_values: tuple[float, ...]  # sorted unique raw labels


def _parse_line(
    line: str, lineno: int
) -> tuple[float, list[int], list[float]] | None:
    """One row -> (raw label, 0-based ids, values); None for non-data lines."""
    hash_at = line.find("#")
    if hash_at != -1:
        line = line[:hash_at]
    parts = line.split()
    if not parts:
        return None
    try:
        label = float(parts[0])
    except ValueError:
        raise LibSVMFormatError(
            f"line {lineno}: label {parts[0]!r} is not a number"
        ) from None
    ids: list[int] = []
    vals: list[float] = []
    for tok in parts[1:]:
        idx_s, sep, val_s = tok.partition(":")
        if not sep:
            raise LibSVMFormatError(
                f"line {lineno}: expected index:value, got {tok!r}"
            )
        if idx_s == "qid":  # ranking metadata, not a feature
            continue
        try:
            idx = int(idx_s)
            val = float(val_s)
        except ValueError:
            raise LibSVMFormatError(
                f"line {lineno}: expected index:value, got {tok!r}"
            ) from None
        if idx < 1:
            raise LibSVMFormatError(
                f"line {lineno}: LibSVM indices are 1-based, got {idx}"
            )
        ids.append(idx - 1)
        vals.append(val)
    return label, ids, vals


def iter_libsvm_rows(
    f: TextIO,
) -> Iterator[tuple[float, list[int], list[float]]]:
    """Data rows of an open LibSVM file, comments/blanks skipped."""
    for lineno, line in enumerate(f, start=1):
        row = _parse_line(line, lineno)
        if row is not None:
            yield row


def iter_libsvm_chunks(
    path: str, chunk_rows: int
) -> Iterator[tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Stream ``(raw_labels f64[c], indices i32[c, w], values f32[c, w])``.

    ``w`` is the widest row *within the chunk* (at least 1); shorter rows
    are left-aligned and padded with ``(0, 0.0)`` — exactly the global
    padded layout's convention, so a downstream consumer that pads chunks
    up to a common width reproduces :func:`load_libsvm` bit-for-bit.
    Peak memory is one chunk, not the file.
    """
    if chunk_rows < 1:
        raise ValueError(f"chunk_rows >= 1 required, got {chunk_rows}")
    with open(path, "r") as f:
        rows: list[tuple[float, list[int], list[float]]] = []
        for row in iter_libsvm_rows(f):
            rows.append(row)
            if len(rows) == chunk_rows:
                yield _pack_chunk(rows)
                rows = []
        if rows:
            yield _pack_chunk(rows)


def _pack_chunk(
    rows: list[tuple[float, list[int], list[float]]]
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    c = len(rows)
    w = max(1, max(len(ids) for _, ids, _ in rows))
    labels = np.empty(c, dtype=np.float64)
    indices = np.zeros((c, w), dtype=np.int32)
    values = np.zeros((c, w), dtype=np.float32)
    for i, (label, ids, vals) in enumerate(rows):
        labels[i] = label
        k = len(ids)
        if k:
            indices[i, :k] = ids
            values[i, :k] = vals
    return labels, indices, values


def scan_libsvm(path: str, chunk_rows: int = 65536) -> LibSVMStats:
    """The stats pass: parse everything, keep nothing but counters."""
    n = 0
    max_index = -1
    nnz_max = 0
    nnz_total = 0
    label_values: set[float] = set()
    with open(path, "r") as f:
        for label, ids, vals in iter_libsvm_rows(f):
            n += 1
            label_values.add(label)
            nnz_max = max(nnz_max, len(ids))
            nnz_total += len(ids)
            if ids:
                max_index = max(max_index, max(ids))
    return LibSVMStats(
        num_instances=n,
        max_index=max_index,
        nnz_max=nnz_max,
        nnz_total=nnz_total,
        label_values=tuple(sorted(label_values)),
    )


def canonical_label_map(
    label_values: tuple[float, ...]
) -> Callable[[np.ndarray], np.ndarray]:
    """The one deterministic raw-labels -> {-1, +1} float32 rule.

    Decided from the file's GLOBAL label alphabet (see module docstring);
    more than two values is an error — this repo is binary classification.
    """
    uniq = tuple(sorted(set(float(v) for v in label_values)))
    if not uniq:
        raise ValueError("no labels: cannot infer a label convention")
    if len(uniq) > 2:
        raise ValueError(
            f"binary classification requires <= 2 label values, file has "
            f"{len(uniq)}: {uniq[:5]}..."
        )
    if set(uniq) <= {-1.0, 1.0}:
        positive = 1.0
    elif set(uniq) <= {0.0, 1.0}:
        positive = 1.0
    elif len(uniq) == 2:
        positive = uniq[1]
    else:
        raise ValueError(
            f"cannot infer a binary label convention from the single label "
            f"value {uniq[0]!r}; use -1/+1 or 0/1 coding"
        )

    def map_labels(raw: np.ndarray) -> np.ndarray:
        return np.where(np.asarray(raw) == positive, 1.0, -1.0).astype(
            np.float32
        )

    return map_labels


def load_libsvm(path: str, *, dim: int | None = None, chunk_rows: int = 65536):
    """One-shot ``path`` -> :class:`~repro.data.sparse.PaddedCSR`.

    Built on :func:`iter_libsvm_chunks` — the exact arrays a streaming
    consumer sees, concatenated — so the streamed-vs-oneshot equality
    contract in :mod:`repro.data.pipeline` is against shared code, not a
    second parser.  ``dim`` defaults to ``max index + 1``; passing the
    true dimensionality matters when trailing features are absent from
    the file (LibSVM files omit all-zero columns).
    """
    import jax.numpy as jnp

    from repro.data.sparse import PaddedCSR

    raw_labels: list[np.ndarray] = []
    chunks: list[tuple[np.ndarray, np.ndarray]] = []
    width = 1
    max_index = -1
    for labels, indices, values in iter_libsvm_chunks(path, chunk_rows):
        raw_labels.append(labels)
        chunks.append((indices, values))
        width = max(width, indices.shape[1])
        if indices.size:
            # Padding ids are 0 and real ids nonnegative, so the plain max
            # is the max stored id (or 0 for an all-empty chunk) — the
            # same quantity scan_libsvm computes, clamped below at dim 1.
            max_index = max(max_index, int(indices.max()))
    if not raw_labels:
        raise ValueError(f"{path}: no data rows")
    if dim is None:
        dim = max(max_index + 1, 1)
    elif dim <= max_index:
        raise ValueError(
            f"dim={dim} but {path} stores feature id {max_index} (0-based)"
        )
    all_raw = np.concatenate(raw_labels)
    mapper = canonical_label_map(tuple(np.unique(all_raw)))
    indices = np.vstack(
        [np.pad(i, ((0, 0), (0, width - i.shape[1]))) for i, _ in chunks]
    )
    values = np.vstack(
        [np.pad(v, ((0, 0), (0, width - v.shape[1]))) for _, v in chunks]
    )
    return PaddedCSR(
        indices=jnp.asarray(indices),
        values=jnp.asarray(values),
        labels=jnp.asarray(mapper(all_raw)),
        dim=int(dim),
    )


def write_libsvm(path: str, data, *, comment: str | None = None) -> str:
    """Write a :class:`~repro.data.sparse.PaddedCSR` as LibSVM text.

    Only stored nonzeros are written (padding and explicit zeros are
    indistinguishable in the padded layout — the documented invariant),
    1-based, in each row's stored order.  Values go through ``repr`` of
    the exact Python float, so parsing back yields the same float32 bits.
    Labels that are whole numbers are written as integers (the
    convention every LibSVM distribution uses).
    """
    indices = np.asarray(data.indices)
    values = np.asarray(data.values)
    labels = np.asarray(data.labels)
    with open(path, "w") as f:
        if comment:
            f.write(f"# {comment}\n")
        for i in range(indices.shape[0]):
            row_mask = values[i] != 0.0
            lab = float(labels[i])
            parts = [str(int(lab)) if lab == int(lab) else repr(lab)]
            parts.extend(
                f"{int(idx) + 1}:{_fmt_value(val)}"
                for idx, val in zip(indices[i, row_mask], values[i, row_mask])
            )
            f.write(" ".join(parts) + "\n")
    return path


def _fmt_value(v: np.floating) -> str:
    """Shortest text that parses back to the same float32 bits."""
    return repr(float(v))
