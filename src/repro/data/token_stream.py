"""LM token pipeline: deterministic synthetic streams per architecture.

A real deployment would put SSTable/ArrayRecord readers here; in this
container the pipeline synthesizes structured token streams (Zipf unigram
mixture + copy motifs so models actually have something learnable), with
the same sharding/batching/packing interface a file-backed reader would
expose.  Yields exactly the batch dict ``input_specs`` promises.

This module used to be ``repro.data.pipeline``; it moved here so that
``pipeline.py`` can be the sparse-ingestion module its name claims (the
streaming LibSVM -> per-worker BlockCSR path).  ``repro.data.pipeline``
keeps a deprecation shim for the old names.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class PipelineConfig:
    batch_size: int
    seq_len: int
    seed: int = 0
    grad_accum: int = 1


def _token_stream(rng, n, vocab, zipf_a=1.2):
    """Zipf-ish unigram stream with injected copy motifs (learnable)."""
    u = rng.random(n)
    raw = np.minimum(u ** (-1.0 / (zipf_a - 1.0)) - 1.0, float(vocab))
    toks = np.clip(np.floor(raw).astype(np.int64), 0, vocab - 1)
    # repeat motifs: every 64 tokens, copy the previous 8
    for start in range(64, n - 8, 64):
        toks[start : start + 8] = toks[start - 8 : start]
    return toks.astype(np.int32)


def batches(cfg: ModelConfig, pcfg: PipelineConfig) -> Iterator[dict]:
    """Yields {"tokens": ..., "labels": ..., (modality extras)} forever."""
    rng = np.random.default_rng(pcfg.seed)
    v = cfg.vocab_size
    b, s = pcfg.batch_size, pcfg.seq_len

    while True:
        if cfg.modality == "audio-codec":
            k = cfg.num_codebooks
            toks = np.stack(
                [
                    _token_stream(rng, b * s, v).reshape(b, s)
                    for _ in range(k)
                ],
                axis=-1,
            )
            batch = {"tokens": toks, "labels": toks.copy()}
        elif cfg.modality == "vision":
            p = cfg.num_patches
            text = _token_stream(rng, b * (s - p), v).reshape(b, s - p)
            patches = rng.normal(0, 1, size=(b, p, cfg.frontend_dim)).astype(
                np.float32
            )
            labels = np.concatenate(
                [np.zeros((b, p), np.int32), text], axis=1
            )
            batch = {"tokens": text, "patch_embeds": patches, "labels": labels}
        else:
            toks = _token_stream(rng, b * s, v).reshape(b, s)
            batch = {"tokens": toks, "labels": toks.copy()}

        if pcfg.grad_accum > 1:
            a = pcfg.grad_accum
            batch = {
                k2: v2.reshape((a, v2.shape[0] // a) + v2.shape[1:])
                for k2, v2 in batch.items()
            }
        yield batch
