"""On-disk BlockCSR slab cache: parse once, sweep forever.

A sweep re-solves the same data set dozens of times (step sizes, q,
methods); re-parsing a multi-GB LibSVM file for each run would dominate
wall clock.  This module persists the *product* of ingestion — the
per-worker slabs — in a content-addressed layout:

    <cache_dir>/<key>/
        manifest.json     version, source digest, dim/N/nnz_max,
                          partition bounds, lane_multiple, dtypes
        labels.npy        float[N] canonical {-1, +1}
        slab_0000.npz     indices, values, nnz_col for worker 0
        ...

The key is a hash of ``(format version, source digest, partition
bounds, lane_multiple)`` — everything that changes the slab bytes.
``chunk_rows`` is deliberately NOT part of the key: the streaming build
is bit-identical for every chunk size (the ingestion contract), so slabs
built with different chunking are the same bytes.  A warm hit costs one
source digest (for a LibSVM file: hashing the bytes, never tokenizing a
line) plus ``np.load``; invalidation is automatic — edit the file, the
digest moves, the old entry is simply never looked up again.

Writes are atomic (build into a temp dir, ``os.replace`` into place), so
a crashed build never leaves a half-entry that a later run would trust.

Slabs are stored compressed (``np.savez_compressed``) with trailing
all-padding lanes trimmed on write and re-padded on load: the padded
layout rounds every worker's lane count up to its block's max (often a
``lane_multiple`` of 8/128 for the kernels), so the tail lanes of most
slabs are pure ``(index 0, value 0.0)`` padding — bytes that deflate
poorly at scale but trim for free.  The full lane count is stored per
slab, so the loaded arrays are byte-identical to what was saved.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import tempfile

import numpy as np

from repro.data.block_csr import BlockCSR
from repro.data.pipeline import (
    DEFAULT_CHUNK_ROWS,
    DataSource,
    stream_block_csr,
    stream_block_slab,
)

# v2: compressed slabs with trailing padding lanes trimmed (+ "lanes" key
# per slab).  v1 entries fail the manifest version check and are rebuilt.
CACHE_VERSION = 2


def _trim_padding_lanes(
    indices: np.ndarray, values: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Drop trailing lanes that are pure padding in EVERY row.

    A padding slot is exactly ``(index 0, value 0.0)`` — explicit zero
    values with a real index (kept by some layouts) and index-0 entries
    with a real value both count as data, so only true padding is
    trimmed.  At least one lane is always kept (the empty-matrix case)."""
    used = (indices != 0) | (values != 0)
    lane_used = used.any(axis=0) if indices.size else np.zeros(0, dtype=bool)
    if lane_used.any():
        keep = int(np.max(np.nonzero(lane_used)[0])) + 1
    else:
        keep = min(1, indices.shape[1])
    return indices[:, :keep], values[:, :keep]


@dataclasses.dataclass(frozen=True)
class CacheOutcome:
    """What :func:`get_or_build` did — benches and logs key off this."""

    data: BlockCSR
    status: str  # "warm" (loaded), "cold" (built + saved), "off" (no dir)
    path: str | None


def cache_key(digest: str, partition, lane_multiple: int) -> str:
    """Directory name for one (source, partition, padding) combination."""
    h = hashlib.sha256()
    h.update(
        f"v{CACHE_VERSION}:{digest}:dim={partition.dim}:"
        f"bounds={tuple(partition.bounds)}:lane={lane_multiple}".encode()
    )
    return h.hexdigest()[:24]


def save_block_csr(
    cache_dir: str,
    digest: str,
    block_data: BlockCSR,
    *,
    lane_multiple: int = 1,
    source_name: str = "?",
) -> str:
    """Persist slabs under ``cache_dir``; returns the entry path."""
    key = cache_key(digest, block_data.partition, lane_multiple)
    entry = os.path.join(cache_dir, key)
    os.makedirs(cache_dir, exist_ok=True)
    tmp = tempfile.mkdtemp(prefix=f".{key}.", dir=cache_dir)
    try:
        labels = np.asarray(block_data.labels)
        np.save(os.path.join(tmp, "labels.npy"), labels)
        for l in range(block_data.num_blocks):
            indices = np.asarray(block_data.indices[l])
            values = np.asarray(block_data.values[l])
            t_indices, t_values = _trim_padding_lanes(indices, values)
            np.savez_compressed(
                os.path.join(tmp, f"slab_{l:04d}.npz"),
                indices=t_indices,
                values=t_values,
                nnz_col=np.asarray(block_data.nnz_col_block(l)),
                # Full padded lane count, so the load re-pads exactly.
                lanes=np.int64(indices.shape[1]),
            )
        manifest = {
            "version": CACHE_VERSION,
            "digest": digest,
            "source_name": source_name,
            "dim": block_data.dim,
            "num_instances": block_data.num_instances,
            "nnz_max": block_data.global_nnz_max(),
            "bounds": list(block_data.partition.bounds),
            "lane_multiple": lane_multiple,
            "labels_dtype": str(labels.dtype),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.isdir(entry):  # lost a race; the other build is identical
            shutil.rmtree(tmp)
        else:
            os.replace(tmp, entry)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return entry


def load_block_csr(
    cache_dir: str, digest: str, partition, *, lane_multiple: int = 1
) -> BlockCSR | None:
    """A warm entry's BlockCSR, or None on any miss/mismatch."""
    import jax.numpy as jnp

    entry = os.path.join(cache_dir, cache_key(digest, partition, lane_multiple))
    manifest_path = os.path.join(entry, "manifest.json")
    if not os.path.isfile(manifest_path):
        return None
    with open(manifest_path) as f:
        manifest = json.load(f)
    if (
        manifest.get("version") != CACHE_VERSION
        or manifest.get("digest") != digest
        or manifest.get("bounds") != list(partition.bounds)
        or manifest.get("dim") != partition.dim
    ):
        return None  # key collision or stale format: rebuild, don't trust
    q = partition.num_blocks
    block_indices, block_values, block_nnz_col = [], [], []
    for l in range(q):
        slab_path = os.path.join(entry, f"slab_{l:04d}.npz")
        if not os.path.isfile(slab_path):
            return None
        with np.load(slab_path) as slab:
            indices = slab["indices"]
            values = slab["values"]
            lanes = int(slab["lanes"])
            if indices.shape[1] < lanes:
                # Restore the trimmed trailing padding lanes (zeros).
                pad = ((0, 0), (0, lanes - indices.shape[1]))
                indices = np.pad(indices, pad)
                values = np.pad(values, pad)
            block_indices.append(jnp.asarray(indices))
            block_values.append(jnp.asarray(values))
            block_nnz_col.append(jnp.asarray(slab["nnz_col"]))
    labels = np.load(os.path.join(entry, "labels.npy"))
    return BlockCSR(
        partition=partition,
        indices=tuple(block_indices),
        values=tuple(block_values),
        labels=jnp.asarray(labels),
        dim=partition.dim,
        nnz_col=tuple(block_nnz_col),
        nnz_max=int(manifest["nnz_max"]),
    )


def get_or_build(
    source: DataSource,
    partition,
    *,
    cache_dir: str | None,
    chunk_rows: int = DEFAULT_CHUNK_ROWS,
    lane_multiple: int = 1,
) -> CacheOutcome:
    """The one ingestion entry point: warm load or streamed build + save.

    With ``cache_dir=None`` caching is off and this is just
    :func:`~repro.data.pipeline.stream_block_csr`.  A warm hit never
    parses the source — only ``source.digest()`` runs (for LibSVM files,
    a byte hash).
    """
    if cache_dir is None:
        return CacheOutcome(
            data=stream_block_csr(
                source, partition, chunk_rows=chunk_rows, lane_multiple=lane_multiple
            ),
            status="off",
            path=None,
        )
    digest = source.digest()
    cached = load_block_csr(
        cache_dir, digest, partition, lane_multiple=lane_multiple
    )
    if cached is not None:
        entry = os.path.join(
            cache_dir, cache_key(digest, partition, lane_multiple)
        )
        return CacheOutcome(data=cached, status="warm", path=entry)
    built = stream_block_csr(
        source, partition, chunk_rows=chunk_rows, lane_multiple=lane_multiple
    )
    entry = save_block_csr(
        cache_dir,
        digest,
        built,
        lane_multiple=lane_multiple,
        source_name=source.name,
    )
    return CacheOutcome(data=built, status="cold", path=entry)


__all__ = [
    "CACHE_VERSION",
    "CacheOutcome",
    "cache_key",
    "get_or_build",
    "load_block_csr",
    "save_block_csr",
    "stream_block_slab",
]
