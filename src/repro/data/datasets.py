"""Paper-shaped data set presets (Table 1, scaled to container scale).

The paper evaluates on four LibSVM sets:

    name      d            N           d/N
    news20    1,355,191    19,954      ~68
    url       3,231,961    2,396,130   ~1.3 (d < N here — url is the outlier)
    webspam   16,609,143   350,000     ~47
    kdd2010   29,890,095   19,264,097  ~1.6

We reproduce the *ratios* and sparsity at 1/64–1/1024 scale so the
convergence/communication benchmarks run in seconds on CPU while keeping
the d-vs-N regimes intact.  ``scale=1.0`` would reproduce the full sizes
(data generation is O(N · nnz), feasible on a real cluster).
"""

from __future__ import annotations

import dataclasses
import os

from repro.data.sparse import PaddedCSR
from repro.data.synthetic import make_sparse_classification


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    dim: int
    num_instances: int
    nnz_per_instance: int
    default_workers: int  # paper: 8 for news20, 16 for the rest


# Full-size specs straight from Table 1 (nnz per instance from LibSVM docs:
# news20 ~455, url ~116, webspam(trigram) ~3730, kdd2010 ~29).  The d =
# 16.6M webspam row IS the trigram variant, so its preset carries the
# trigram density (an earlier revision said 3730 here but shipped 800 —
# which silently flattered every analytic webspam cost model).
TABLE1_FULL = {
    "news20": DatasetSpec("news20", 1_355_191, 19_954, 455, 8),
    "url": DatasetSpec("url", 3_231_961, 2_396_130, 116, 16),
    "webspam": DatasetSpec("webspam", 16_609_143, 350_000, 3730, 16),
    "kdd2010": DatasetSpec("kdd2010", 29_890_095, 19_264_097, 29, 16),
    # Avazu CTR (click-through): the d ≈ 10^6, N ≈ 40M ad-click set the
    # mxnet feature-distributed exemplar runs on.  d < N, but per-row nnz
    # is tiny (~15 one-hot fields), so the feature-partitioned layout and
    # the streaming ingestion path are exactly what it needs.
    "avazu": DatasetSpec("avazu", 1_000_000, 40_428_967, 15, 16),
}

# Container-scale versions preserving d/N and sparsity character.
TABLE1_SCALED = {
    "news20": DatasetSpec("news20", 67_760, 998, 64, 8),
    "url": DatasetSpec("url", 50_500, 37_440, 24, 16),
    "webspam": DatasetSpec("webspam", 129_760, 2_734, 100, 16),
    "kdd2010": DatasetSpec("kdd2010", 116_758, 75_250, 12, 16),
    "avazu": DatasetSpec("avazu", 31_250, 1_263_405 // 32, 15, 16),
}

# One-host materialization budget for load().  The synthetic generator's
# scratch (float64 uniform + Pareto draws) plus the padded int32/float32
# arrays cost ~24 bytes per stored entry.
_BYTES_PER_ENTRY = 24
_DEFAULT_BUDGET_BYTES = 1 << 30  # 1 GiB


def materialize_bytes(spec: DatasetSpec) -> int:
    """Estimated one-host bytes to generate + hold ``spec`` padded."""
    return spec.num_instances * spec.nnz_per_instance * _BYTES_PER_ENTRY


def load(
    name: str,
    *,
    scaled: bool = True,
    seed: int = 0,
    max_bytes: int | None = None,
) -> PaddedCSR:
    """Materialize a preset as one in-memory :class:`PaddedCSR`.

    Guarded: materializing a full Table-1 set (url: ~6.7 GB, webspam:
    ~31 GB, avazu: ~15 GB) on one host is exactly what the streaming
    path exists to avoid, so estimates above the budget (default 1 GiB;
    override with ``max_bytes`` or ``REPRO_MATERIALIZE_BUDGET_BYTES``)
    raise instead of OOM-ing.
    """
    spec = (TABLE1_SCALED if scaled else TABLE1_FULL)[name]
    budget = max_bytes
    if budget is None:
        budget = int(
            os.environ.get(
                "REPRO_MATERIALIZE_BUDGET_BYTES", _DEFAULT_BUDGET_BYTES
            )
        )
    est = materialize_bytes(spec)
    if est > budget:
        raise MemoryError(
            f"materializing {name!r} (scaled={scaled}) needs ~{est / 1e9:.1f} GB"
            f" on one host (budget {budget / 1e9:.1f} GB); use the streaming"
            " path instead — repro.data.pipeline.SyntheticSource"
            f".from_dataset({name!r}, scaled={scaled}) with"
            " stream_block_csr/solve(source=...), or raise max_bytes /"
            " REPRO_MATERIALIZE_BUDGET_BYTES if you really have the RAM."
        )
    return make_sparse_classification(
        dim=spec.dim,
        num_instances=spec.num_instances,
        nnz_per_instance=spec.nnz_per_instance,
        seed=seed,
    )


def spec(name: str, *, scaled: bool = True) -> DatasetSpec:
    return (TABLE1_SCALED if scaled else TABLE1_FULL)[name]
