"""Top-k MoE with expert-parallel, capacity-bounded sort dispatch.

Routing (per token): softmax router, top-k experts, combine weights
renormalized over the selected k (OLMoE / Mixtral convention).

Dispatch is the sort-based fixed-capacity scheme (TPU-native: all shapes
static, no ragged tensors):
  1. flatten (token, k) assignment pairs and sort by expert id,
  2. rank each pair within its expert's run; pairs ranked past the
     per-expert capacity C are dropped (standard GShard-style overflow),
  3. gather tokens into an [E, C, D] buffer -> per-expert dense GEMMs
     (the MXU path), experts sharded over the ``model`` axis,
  4. scatter-add weighted expert outputs back to [T, D]; with experts
     sharded, this combine is the activation all-reduce — the paper's
     feature-partition communication pattern, with experts as the
     feature blocks.

Aux outputs: load-balance loss (Switch-style) and router z-loss.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int  # per-expert hidden
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    act: str = "silu"


def init_moe(key, cfg: MoEConfig, dtype) -> dict:
    kr, kg, ku, kd = jax.random.split(key, 4)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    s_in, s_out = d ** -0.5, f ** -0.5
    return {
        "router": (jax.random.normal(kr, (d, e)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(kg, (e, d, f)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ku, (e, d, f)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(kd, (e, f, d)) * s_out).astype(dtype),
    }


def capacity(tokens: int, cfg: MoEConfig) -> int:
    c = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(cfg.top_k, min(c, tokens))


def _num_groups(ctx, b: int) -> int:
    """Dispatch groups = data-parallel shards (GShard-style), so routing,
    capacity and the token<->expert buffers stay shard-local.  Without the
    group axis, capacity is computed over the GLOBAL token count and the
    expert buffers (and their GEMMs) are data-shards-times too large —
    measured as the 13-16x useful-flops inflation of the MoE baselines
    (EXPERIMENTS.md §Perf pair 1)."""
    if ctx is None or getattr(ctx, "mesh", None) is None:
        return 1
    from repro.sharding.specs import axis_size

    g = axis_size(ctx.mesh, "batch")
    while g > 1 and b % g:
        g //= 2
    return max(1, g)


def moe_ffn(
    params: dict,
    x: jax.Array,  # [B, S, D]
    cfg: MoEConfig,
    ctx,
    num_groups: int | None = None,
) -> tuple[jax.Array, dict]:
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.top_k
    g = _num_groups(ctx, b) if num_groups is None else num_groups
    tg = t // g  # tokens per group
    cap = capacity(tg, cfg)
    xg = x.reshape(g, tg, d)

    def dispatch_group(xt, router):
        # ---- routing (per group) ----
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)  # [Tg, E]
        top_w, top_e = jax.lax.top_k(probs, k)  # [Tg, k]
        top_w = top_w / jnp.maximum(top_w.sum(axis=-1, keepdims=True), 1e-9)

        frac_tokens = jnp.mean(
            (jax.nn.one_hot(top_e, e).sum(axis=1) > 0).astype(jnp.float32), axis=0
        )
        frac_probs = jnp.mean(probs, axis=0)
        lb_loss = e * jnp.sum(frac_tokens * frac_probs)
        z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

        # ---- sort-based dispatch ----
        flat_e = top_e.reshape(-1)  # [Tg*k]
        flat_t = jnp.repeat(jnp.arange(tg), k)
        flat_w = top_w.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        se = flat_e[order]
        st = flat_t[order]
        sw = flat_w[order]
        first = jnp.searchsorted(se, se, side="left")
        rank = jnp.arange(tg * k) - first
        keep = rank < cap
        # dropped pairs get an out-of-range slot; mode="drop" discards them
        slot = jnp.where(keep, se * cap + rank, e * cap)

        pad_row = tg
        buf_tok = jnp.full((e * cap,), pad_row, jnp.int32)
        buf_tok = buf_tok.at[slot].set(st.astype(jnp.int32), mode="drop")
        buf_w = jnp.zeros((e * cap,), jnp.float32).at[slot].set(sw, mode="drop")

        xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
        dispatched = xt_pad[buf_tok].reshape(e, cap, d)
        aux = (lb_loss, z_loss, jnp.mean(keep.astype(jnp.float32)))
        return dispatched, buf_tok, buf_w, aux

    dispatched, buf_tok, buf_w, (lb, zl, kept) = jax.vmap(
        dispatch_group, in_axes=(0, None)
    )(xg, params["router"])
    # [G, E, C, D]: groups ride the data axes, experts the model axis
    dispatched = ctx.constrain(dispatched, "batch", "experts", None, "embed")

    # ---- expert GEMMs (experts on the model axis, groups on data) ----
    h = jnp.einsum("gecd,edf->gecf", dispatched, params["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", dispatched, params["w_up"])
    h = ctx.constrain(h, "batch", "experts", None, "expert_mlp")
    h = jax.nn.silu(h) if cfg.act == "silu" else jax.nn.gelu(h)
    h = h * u
    out_buf = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    out_buf = ctx.constrain(out_buf, "batch", "experts", None, "embed")

    # ---- combine: per-group weighted scatter-add back to tokens ----
    def combine_group(out_b, tok, w):
        contrib = out_b.reshape(e * cap, d) * w[:, None].astype(out_b.dtype)
        return jnp.zeros((tg + 1, d), out_b.dtype).at[tok].add(contrib)[:tg]

    y = jax.vmap(combine_group)(out_buf, buf_tok, buf_w)  # [G, Tg, D]
    y = y.reshape(b, s, d)
    y = ctx.constrain(y, "batch", "seq", "embed")

    aux = {
        "lb_loss": jnp.mean(lb),
        "z_loss": jnp.mean(zl),
        "overflow_frac": 1.0 - jnp.mean(kept),
    }
    return y.astype(x.dtype), aux


def moe_ffn_dense_ref(params: dict, x: jax.Array, cfg: MoEConfig) -> jax.Array:
    """Oracle: compute every expert densely, combine by router weights.
    O(E x) compute — tests only.  Matches moe_ffn exactly when no token
    overflows capacity."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, cfg.top_k)
    top_w = top_w / jnp.maximum(top_w.sum(axis=-1, keepdims=True), 1e-9)

    h = jnp.einsum("td,edf->etf", xt, params["w_gate"])
    u = jnp.einsum("td,edf->etf", xt, params["w_up"])
    h = jax.nn.silu(h) if cfg.act == "silu" else jax.nn.gelu(h)
    all_out = jnp.einsum("etf,efd->etd", h * u, params["w_down"])  # [E, T, D]

    combine = jnp.zeros((t, cfg.num_experts), jnp.float32)
    combine = jax.vmap(
        lambda c, e_i, w_i: c.at[e_i].add(w_i), in_axes=(0, 0, 0)
    )(combine, top_e, top_w)
    y = jnp.einsum("te,etd->td", combine.astype(all_out.dtype), all_out)
    return y.reshape(b, s, d).astype(x.dtype)
