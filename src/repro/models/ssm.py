"""Mamba2 / SSD (state-space duality) mixer [arXiv:2405.21060].

Train path: the chunked SSD algorithm — intra-chunk "attention-like"
quadratic term plus an inter-chunk recurrence over compressed states —
a faithful port of the paper's minimal SSD reference, with the chunk
recurrence expressed as a lax.scan (TPU-friendly: every term is a dense
einsum on MXU-aligned tiles; the sequential dimension is S/chunk, not S).

Decode path: the equivalent linear recurrence,
    h' = exp(dt·A) h + dt · B ⊗ x,   y = C·h' + D_skip·x,
carrying (conv_state, ssm_state) per layer.

Feature distribution (DESIGN.md §5): the SSD head axis is the partitioned
feature dimension (``ssm_heads`` -> model axis); B/C are grouped (one group
here, like mamba2's default n_groups=1 per-device groups) and replicated,
so inter-chip traffic is only the output projection's reduction — the
inner recurrence is chip-local, exactly the property the paper's feature
partition gives the linear model.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import init_rms_scale, rms_norm
from repro.models.unroll import scan_unroll


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64  # SSD "P"
    conv_width: int = 4
    chunk: int = 256
    norm_eps: float = 1e-6
    # §Perf lever: SSD einsum operand dtype ("float32" faithful default;
    # "bfloat16" streams operands at half the HBM bytes with f32
    # accumulation via preferred_element_type)
    compute_dtype: str = "float32"

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def num_heads(self) -> int:
        assert self.d_inner % self.head_dim == 0
        return self.d_inner // self.head_dim


def init_ssm(key, cfg: SSMConfig, dtype) -> dict:
    kin, kout, kconv, kdt = jax.random.split(key, 4)
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.num_heads
    # in_proj emits [z (gate, di), x (di), B (n), C (n), dt (h)]
    proj_out = 2 * di + 2 * n + h
    s_in = d ** -0.5
    conv_dim = di + 2 * n  # x, B, C go through the depthwise conv
    return {
        "in_proj": (jax.random.normal(kin, (d, proj_out)) * s_in).astype(dtype),
        "conv_w": (jax.random.normal(kconv, (cfg.conv_width, conv_dim)) * 0.3).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_norm": init_rms_scale(di),
        "out_proj": (jax.random.normal(kout, (di, d)) * di ** -0.5).astype(dtype),
    }


def _split_proj(proj, cfg: SSMConfig):
    di, n, h = cfg.d_inner, cfg.d_state, cfg.num_heads
    z = proj[..., :di]
    xbc = proj[..., di : di + di + 2 * n]
    dt = proj[..., di + di + 2 * n :]
    return z, xbc, dt


def _segsum(x: jax.Array) -> jax.Array:
    """x: [..., L] -> [..., L, L] lower-triangular pairwise segment sums."""
    l = x.shape[-1]
    csum = jnp.cumsum(x, axis=-1)
    seg = csum[..., :, None] - csum[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # [B, S, H, P]
    dt: jax.Array,  # [B, S, H] (post-softplus)
    a: jax.Array,  # [H] (negative)
    bmat: jax.Array,  # [B, S, N]
    cmat: jax.Array,  # [B, S, N]
    chunk: int,
    ctx=None,
    compute_dtype: str = "float32",
) -> jax.Array:
    """Chunked SSD scan; returns y [B, S, H, P].

    Sequences that don't divide the chunk size are zero-padded at the end
    (dt=0 => decay 1, zero input: padding is inert) and sliced back."""
    b, s0, h, p = x.shape
    n = bmat.shape[-1]
    pad = (-s0) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    s = s0 + pad
    c = s // chunk

    # discretized decay per step: alpha = dt * a  (log-space), [B, S, H]
    la = dt * a[None, None, :]
    xd = x * dt[..., None]  # input discretization

    # chunked views
    la_c = la.reshape(b, c, chunk, h).transpose(0, 3, 1, 2)  # [B, H, C, L]
    x_c = xd.reshape(b, c, chunk, h, p)  # [B, C, L, H, P]
    b_c = bmat.reshape(b, c, chunk, n)  # [B, C, L, N]
    c_c = cmat.reshape(b, c, chunk, n)

    la_cum = jnp.cumsum(la_c, axis=-1)  # [B, H, C, L]

    cdt = jnp.dtype(compute_dtype)
    f32 = jnp.float32

    # 1) intra-chunk (quadratic-in-chunk attention-like term)
    lmat = jnp.exp(_segsum(la_c)).astype(cdt)  # [B, H, C, L, L]
    y_diag = jnp.einsum(
        "bcln,bcsn,bhcls,bcshp->bclhp",
        c_c.astype(cdt), b_c.astype(cdt), lmat, x_c.astype(cdt),
        preferred_element_type=f32,
    )

    # 2) per-chunk compressed states
    decay_states = jnp.exp(la_cum[..., -1:] - la_cum)  # [B, H, C, L]
    states = jnp.einsum(
        "bcln,bhcl,bclhp->bchpn",
        b_c.astype(cdt), decay_states.astype(cdt), x_c.astype(cdt),
        preferred_element_type=f32,
    )

    # 3) inter-chunk recurrence over compressed states (sequential in C only)
    chunk_decay = jnp.exp(la_cum[..., -1])  # [B, H, C]

    def scan_fn(h_prev, inp):
        st, dec = inp  # [B, H, P, N], [B, H]
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev

    states_t = states.transpose(1, 0, 2, 3, 4)  # [C, B, H, P, N]
    decay_t = chunk_decay.transpose(2, 0, 1)  # [C, B, H]
    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    _, h_prevs = jax.lax.scan(
        scan_fn, h0, (states_t.astype(jnp.float32), decay_t), unroll=scan_unroll(c)
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)  # [B, C, H, P, N] (state entering chunk)

    # 4) inter-chunk output contribution
    state_decay_out = jnp.exp(la_cum)  # [B, H, C, L]
    y_off = jnp.einsum(
        "bcln,bchpn,bhcl->bclhp",
        c_c.astype(cdt), h_prevs.astype(cdt), state_decay_out.astype(cdt),
        preferred_element_type=f32,
    )

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y[:, :s0] if pad else y


def ssm_train(params: dict, x: jax.Array, cfg: SSMConfig, ctx) -> jax.Array:
    """x: [B, S, D] -> [B, S, D]."""
    b, s, d = x.shape
    di, n, h, p = cfg.d_inner, cfg.d_state, cfg.num_heads, cfg.head_dim

    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xbc, dt_raw = _split_proj(proj, cfg)

    # depthwise causal conv over (x, B, C)
    w = params["conv_w"]  # [W, conv_dim]
    pad = jnp.pad(xbc, ((0, 0), (cfg.conv_width - 1, 0), (0, 0)))
    conv = sum(
        pad[:, i : i + s, :] * w[i][None, None, :] for i in range(cfg.conv_width)
    )
    conv = jax.nn.silu(conv + params["conv_b"][None, None, :])

    xs = conv[..., :di].reshape(b, s, h, p)
    xs = ctx.constrain(xs, "batch", None, "ssm_heads", None)
    bmat = conv[..., di : di + n]
    cmat = conv[..., di + n :]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # [B,S,H]
    a = -jnp.exp(params["a_log"])  # [H]

    y = ssd_chunked(
        xs.astype(jnp.float32), dt, a,
        bmat.astype(jnp.float32), cmat.astype(jnp.float32), cfg.chunk, ctx,
        compute_dtype=cfg.compute_dtype,
    )
    y = y + xs.astype(jnp.float32) * params["d_skip"][None, None, :, None]
    y = y.reshape(b, s, di).astype(x.dtype)

    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    return ctx.constrain(out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Decode (recurrent form)
# ---------------------------------------------------------------------------


def init_ssm_cache(batch: int, cfg: SSMConfig, dtype, ctx) -> dict:
    conv_dim = cfg.d_inner + 2 * cfg.d_state
    return {
        "conv": ctx.constrain(
            jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
            "batch", None, None,
        ),
        "state": ctx.constrain(
            jnp.zeros((batch, cfg.num_heads, cfg.head_dim, cfg.d_state), jnp.float32),
            "batch", "ssm_heads", None, None,
        ),
    }


def ssm_decode(
    params: dict,
    x: jax.Array,  # [B, 1, D]
    cache: dict,
    cfg: SSMConfig,
    ctx,
) -> tuple[jax.Array, dict]:
    b, one, d = x.shape
    di, n, h, p = cfg.d_inner, cfg.d_state, cfg.num_heads, cfg.head_dim

    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"])[:, 0]  # [B, E]
    z, xbc, dt_raw = _split_proj(proj, cfg)

    # conv state update: window = [cache, current]
    win = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # [B, W, C]
    conv = jnp.einsum("bwc,wc->bc", win, params["conv_w"]) + params["conv_b"]
    conv = jax.nn.silu(conv)
    new_conv = win[:, 1:, :]

    xs = conv[:, :di].reshape(b, h, p)
    bvec = conv[:, di : di + n].astype(jnp.float32)  # [B, N]
    cvec = conv[:, di + n :].astype(jnp.float32)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # [B, H]
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt * a[None, :])  # [B, H]

    xd = xs.astype(jnp.float32) * dt[..., None]  # [B, H, P]
    state = cache["state"] * decay[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", xd, bvec
    )
    y = jnp.einsum("bhpn,bn->bhp", state, cvec)
    y = y + xs.astype(jnp.float32) * params["d_skip"][None, :, None]
    y = y.reshape(b, 1, di)

    y = y.astype(x.dtype) * jax.nn.silu(z)[:, None, :]
    y = rms_norm(y, params["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    out = ctx.constrain(out, "batch", None, "embed")
    return out, {"conv": new_conv, "state": state}
