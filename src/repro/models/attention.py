"""GQA attention: flash-style chunked train/prefill path + cached decode.

Feature-distributed mapping (DESIGN.md §4): head projections are the
partitioned feature axes; between-chip traffic is activation reductions.
Two cache layouts:

* train/prefill — q laid out [B, H, S, Dh] with H carried by the ``model``
  axis (GSPMD pads when H doesn't divide the axis; recorded per-arch in
  DESIGN.md).  Keys/values stream through a lax.scan over key chunks with
  an online-softmax accumulator, so the [S, S] score matrix never
  materializes (required for prefill_32k).
* decode — the KV cache is sequence-sharded over ``model``
  (flash-decoding split-K, but across chips): each chip scores its cache
  shard and the softmax max/sum and weighted-value reductions cross chips
  as *scalar-per-head* collectives — the paper's communicate-inner-
  products-not-vectors principle applied to serving.

Supports: GQA/MQA, RoPE, qk-norm (qwen3), sliding window (gemma2 local
layers), attention logit softcap (gemma2).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, init_rms_scale, rms_norm, softcap
from repro.models.unroll import scan_unroll

_MASK_VALUE = -1e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    qk_norm: bool = False
    window: int | None = None  # sliding window (None = global)
    attn_softcap: float | None = None
    norm_eps: float = 1e-6
    kv_chunk: int = 1024
    # §Perf lever: when set, queries are processed in blocks of q_chunk and
    # each block only visits the key chunks its causal/window mask can
    # reach — skipping ~half the score matmuls (more for sliding windows).
    q_chunk: int | None = None

    @property
    def group(self) -> int:
        return self.num_heads // self.num_kv_heads


def init_attention(key, d_model: int, cfg: AttnConfig, dtype) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    s_in = d_model ** -0.5
    s_out = (cfg.num_heads * cfg.head_dim) ** -0.5
    params = {
        "wq": (jax.random.normal(kq, (d_model, cfg.num_heads, cfg.head_dim)) * s_in).astype(dtype),
        "wk": (jax.random.normal(kk, (d_model, cfg.num_kv_heads, cfg.head_dim)) * s_in).astype(dtype),
        "wv": (jax.random.normal(kv, (d_model, cfg.num_kv_heads, cfg.head_dim)) * s_in).astype(dtype),
        "wo": (jax.random.normal(ko, (cfg.num_heads, cfg.head_dim, d_model)) * s_out).astype(dtype),
    }
    if cfg.qk_norm:
        params["q_norm"] = init_rms_scale(cfg.head_dim)
        params["k_norm"] = init_rms_scale(cfg.head_dim)
    return params


def _project_qkv(params, x, positions, cfg: AttnConfig, ctx):
    """x: [B, S, D] -> q [B, H, S, Dh], k/v [B, S, Hkv, Dh] (rope applied)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = jnp.swapaxes(q, 1, 2)  # [B, H, S, Dh]
    q = ctx.constrain(q, "batch", "heads", None, None)
    k = ctx.constrain(k, "batch", None, "kv_heads", None)
    v = ctx.constrain(v, "batch", None, "kv_heads", None)
    return q, k, v


def attention_train(
    params: dict,
    x: jax.Array,  # [B, S, D]
    positions: jax.Array,  # [B, S]
    cfg: AttnConfig,
    ctx,
    *,
    kv_chunk: int | None = None,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Causal (optionally windowed) attention; returns output and (k, v)
    in cache layout so prefill shares this path."""
    b, s, d = x.shape
    h, dh = cfg.num_heads, cfg.head_dim
    scale = dh ** -0.5
    kv_chunk = cfg.kv_chunk if kv_chunk is None else kv_chunk
    q, k, v = _project_qkv(params, x, positions, cfg, ctx)

    if cfg.q_chunk is not None and s > cfg.q_chunk:
        y = _attention_blockwise(q, k, v, positions, cfg, ctx, scale)
        return y_project(params, y, ctx, x.dtype), (k, v)

    kv_chunk = min(kv_chunk, s)
    assert s % kv_chunk == 0, f"seq {s} % kv_chunk {kv_chunk} != 0"
    n_chunks = s // kv_chunk
    # chunk layout: [n, B, kc, Hkv, Dh]
    kc = k.reshape(b, n_chunks, kv_chunk, cfg.num_kv_heads, dh).swapaxes(0, 1)
    vc = v.reshape(b, n_chunks, kv_chunk, cfg.num_kv_heads, dh).swapaxes(0, 1)
    kpos = positions.reshape(b, n_chunks, kv_chunk).swapaxes(0, 1)

    acc0 = jnp.zeros((b, h, s, dh), jnp.float32)
    m0 = jnp.full((b, h, s, 1), _MASK_VALUE, jnp.float32)
    l0 = jnp.zeros((b, h, s, 1), jnp.float32)

    def step(carry, inp):
        acc, m, l = carry
        k_c, v_c, kp = inp  # [B, kc, Hkv, Dh], ..., [B, kc]
        # expand kv groups to full heads (local gather; kv replicated on model)
        k_r = jnp.repeat(k_c, cfg.group, axis=2)  # [B, kc, H, Dh]
        v_r = jnp.repeat(v_c, cfg.group, axis=2)
        scores = jnp.einsum(
            "bhsd,bchd->bhsc", q.astype(jnp.float32), k_r.astype(jnp.float32)
        ) * scale
        scores = softcap(scores, cfg.attn_softcap)
        causal = kp[:, None, None, :] <= positions[:, None, :, None]
        if cfg.window is not None:
            causal &= (positions[:, None, :, None] - kp[:, None, None, :]) < cfg.window
        scores = jnp.where(causal, scores, _MASK_VALUE)
        scores = ctx.constrain(scores, "batch", "heads", None, None)

        m_new = jnp.maximum(m, scores.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new)
        l_new = l * alpha + p.sum(axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum(
            "bhsc,bchd->bhsd", p, v_r.astype(jnp.float32)
        )
        return (acc_new, m_new, l_new), None

    (acc, m, l), _ = jax.lax.scan(
        step, (acc0, m0, l0), (kc, vc, kpos), unroll=scan_unroll(n_chunks)
    )
    out = acc / jnp.maximum(l, 1e-30)  # [B, H, S, Dh]
    return y_project(params, out, ctx, x.dtype), (k, v)


def y_project(params, out_f32, ctx, dtype):
    y = jnp.einsum("bhsd,hdo->bso", out_f32.astype(dtype), params["wo"])
    return ctx.constrain(y, "batch", "seq", "embed")


def _attention_blockwise(q, k, v, positions, cfg: AttnConfig, ctx, scale):
    """Causal block-skipping flash attention (§Perf lever, exact numerics).

    Queries are processed q_chunk at a time; block (i) only scans the key
    chunks its mask can reach: [lo_i, (i+1)*qc) with lo_i = 0 for global
    attention or aligned(start of window) for sliding-window layers.
    Relative to the single-scan path this skips the fully-masked upper
    triangle (~2x fewer score FLOPs at long S; much more for local layers).
    Assumes canonical positions (arange), which train/prefill use.
    """
    b, h, s, dh = q.shape
    qc = cfg.q_chunk
    kc = min(cfg.kv_chunk, qc)
    assert s % qc == 0 and qc % kc == 0, (s, qc, kc)
    outs = []
    for i in range(s // qc):
        q_i = q[:, :, i * qc : (i + 1) * qc, :].astype(jnp.float32)
        qpos = positions[:, i * qc : (i + 1) * qc]
        hi = (i + 1) * qc
        lo = 0
        if cfg.window is not None:
            lo = max(0, (i * qc - cfg.window) // kc * kc)
        n_kc = (hi - lo) // kc
        k_i = k[:, lo:hi].reshape(b, n_kc, kc, cfg.num_kv_heads, dh).swapaxes(0, 1)
        v_i = v[:, lo:hi].reshape(b, n_kc, kc, cfg.num_kv_heads, dh).swapaxes(0, 1)
        kpos = positions[:, lo:hi].reshape(b, n_kc, kc).swapaxes(0, 1)

        def step(carry, inp):
            acc, m, l = carry
            k_c, v_c, kp = inp
            k_r = jnp.repeat(k_c, cfg.group, axis=2)
            v_r = jnp.repeat(v_c, cfg.group, axis=2)
            scores = jnp.einsum(
                "bhsd,bchd->bhsc", q_i, k_r.astype(jnp.float32)
            ) * scale
            scores = softcap(scores, cfg.attn_softcap)
            causal = kp[:, None, None, :] <= qpos[:, None, :, None]
            if cfg.window is not None:
                causal &= (qpos[:, None, :, None] - kp[:, None, None, :]) < cfg.window
            scores = jnp.where(causal, scores, _MASK_VALUE)
            scores = ctx.constrain(scores, "batch", "heads", None, None)
            m_new = jnp.maximum(m, scores.max(axis=-1, keepdims=True))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(scores - m_new)
            l_new = l * alpha + p.sum(axis=-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum(
                "bhsc,bchd->bhsd", p, v_r.astype(jnp.float32)
            )
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, h, qc, dh), jnp.float32)
        m0 = jnp.full((b, h, qc, 1), _MASK_VALUE, jnp.float32)
        l0 = jnp.zeros((b, h, qc, 1), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            step, (acc0, m0, l0), (k_i, v_i, kpos), unroll=scan_unroll(n_kc)
        )
        outs.append(acc / jnp.maximum(l, 1e-30))
    return jnp.concatenate(outs, axis=2)  # [B, H, S, Dh] f32


def init_kv_cache(
    batch: int, max_len: int, cfg: AttnConfig, dtype, ctx
) -> dict:
    k = jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype)
    v = jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype)
    return {
        "k": ctx.constrain(k, "batch", "seq_kv", None, None),
        "v": ctx.constrain(v, "batch", "seq_kv", None, None),
    }


def attention_decode(
    params: dict,
    x: jax.Array,  # [B, 1, D] current token's activations
    cache: dict,  # {"k": [B, S, Hkv, Dh], "v": ...} sequence-sharded
    pos: jax.Array,  # [] int32 — current position (same for the whole batch)
    cfg: AttnConfig,
    ctx,
) -> tuple[jax.Array, dict]:
    b, one, d = x.shape
    hkv, dh, g = cfg.num_kv_heads, cfg.head_dim, cfg.group
    s_max = cache["k"].shape[1]
    scale = dh ** -0.5
    positions = jnp.broadcast_to(pos, (b, 1))

    q, k_new, v_new = _project_qkv(params, x, positions, cfg, ctx)
    # q: [B, H, 1, Dh] -> grouped [B, Hkv, G, Dh]
    qg = q[:, :, 0, :].reshape(b, hkv, g, dh)

    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, pos, 0, 0))
    k = ctx.constrain(k, "batch", "seq_kv", None, None)
    v = ctx.constrain(v, "batch", "seq_kv", None, None)

    # scores over the (sequence-sharded) cache: [B, Hkv, G, S]
    scores = jnp.einsum(
        "bkgd,bskd->bkgs", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    scores = softcap(scores, cfg.attn_softcap)
    kpos = jnp.arange(s_max)
    valid = kpos[None, None, None, :] <= pos
    if cfg.window is not None:
        valid &= (pos - kpos[None, None, None, :]) < cfg.window
    scores = jnp.where(valid, scores, _MASK_VALUE)

    # max/sum reductions over the sharded S axis -> scalar-per-head traffic
    m = scores.max(axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    l = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32)) / jnp.maximum(
        l, 1e-30
    )
    out = out.reshape(b, 1, cfg.num_heads, dh).swapaxes(1, 2)  # [B, H, 1, Dh]
    y = jnp.einsum("bhsd,hdo->bso", out.astype(x.dtype), params["wo"])
    y = ctx.constrain(y, "batch", None, "embed")
    return y, {"k": k, "v": v}


def attention_ref(
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: AttnConfig,
    ctx,
) -> jax.Array:
    """Materialized-logits oracle (small shapes / tests only)."""
    b, s, d = x.shape
    q, k, v = _project_qkv(params, x, positions, cfg, ctx)
    k_r = jnp.repeat(k, cfg.group, axis=2)  # [B, S, H, Dh]
    v_r = jnp.repeat(v, cfg.group, axis=2)
    scores = jnp.einsum(
        "bhsd,bthd->bhst", q.astype(jnp.float32), k_r.astype(jnp.float32)
    ) * (cfg.head_dim ** -0.5)
    scores = softcap(scores, cfg.attn_softcap)
    causal = positions[:, None, None, :] <= positions[:, None, :, None]
    if cfg.window is not None:
        causal &= (
            positions[:, None, :, None] - positions[:, None, None, :]
        ) < cfg.window
    scores = jnp.where(causal, scores, _MASK_VALUE)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bhsd", p, v_r.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bhsd,hdo->bso", out, params["wo"])
