"""Shared building blocks: norms, rotary embeddings, MLPs, embeddings."""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    # gemma-style (1 + scale) so zero-init means identity
    return (normed * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def init_rms_scale(dim: int) -> jax.Array:
    return jnp.zeros((dim,), dtype=jnp.float32)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jax.Array,  # [..., S, H, Dh]
    positions: jax.Array,  # [..., S]
    theta: float,
) -> jax.Array:
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)  # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, Dh/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations / MLP
# ---------------------------------------------------------------------------


ACTS: dict[str, Callable[[jax.Array], jax.Array]] = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),  # nemotron squared-ReLU
}


def init_mlp(key, d_model: int, d_ff: int, dtype, gated: bool = True) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = d_model ** -0.5
    scale_out = d_ff ** -0.5
    p = {
        "w_up": (jax.random.normal(k2, (d_model, d_ff)) * scale_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d_model)) * scale_out).astype(dtype),
    }
    if gated:
        p["w_gate"] = (jax.random.normal(k1, (d_model, d_ff)) * scale_in).astype(dtype)
    return p


def mlp(params: dict, x: jax.Array, act: str, ctx) -> jax.Array:
    """MLP, gated (SwiGLU/GeGLU) when w_gate is present, plain otherwise
    (nemotron-style squared-ReLU).  x: [B, S, D] -> [B, S, D].

    The hidden dim is the feature-partitioned axis: only the final
    projection's output needs a reduction — activations cross chips,
    parameters never do (the paper's communication pattern).
    """
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    u = ctx.constrain(u, "batch", "seq", "mlp")
    if "w_gate" in params:
        h = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
        h = ctx.constrain(h, "batch", "seq", "mlp")
        h = ACTS[act](h) * u
    else:
        h = ACTS[act](u)
    out = jnp.einsum("bsf,fd->bsd", h, params["w_down"])
    return ctx.constrain(out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d_model: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d_model)) * (d_model ** -0.5)).astype(dtype)


def embed_tokens(table: jax.Array, tokens: jax.Array, ctx, scale: bool) -> jax.Array:
    x = table[tokens]  # [B, S, D] gather over the vocab-sharded table
    if scale:
        x = x * jnp.asarray(table.shape[-1] ** 0.5, x.dtype)
    return ctx.constrain(x, "batch", "seq", "embed")


def lm_logits(
    x: jax.Array,  # [B, S, D]
    table: jax.Array,  # [V, D] (tied) or head [D, V]
    *,
    tied: bool,
    cap: float | None,
    ctx,
) -> jax.Array:
    if tied:
        logits = jnp.einsum("bsd,vd->bsv", x, table)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, table)
    logits = ctx.constrain(logits, "batch", "seq", "vocab")
    return softcap(logits.astype(jnp.float32), cap)
