"""Roofline unroll mode.

XLA's ``cost_analysis`` counts a ``while`` body ONCE, regardless of trip
count, so scan-based models under-report FLOPs/bytes/collectives by the
trip count.  For roofline extraction the dry-run compiles a reduced-depth
variant with every scan fully unrolled (trip-count-1 loops carry the whole
body, so the costs are exact) and extrapolates linearly in the repeat
count; the production scan compile is still what proves memory fit.

``unrolled()`` flips every lax.scan in the model stack (layer stack,
attention kv chunks, SSD chunk recurrence, grad-accum microbatches) to
full unroll.
"""

from __future__ import annotations

import contextlib

_FULL_UNROLL = False


def scan_unroll(length: int) -> int:
    return length if _FULL_UNROLL else 1


@contextlib.contextmanager
def unrolled():
    global _FULL_UNROLL
    prev = _FULL_UNROLL
    _FULL_UNROLL = True
    try:
        yield
    finally:
        _FULL_UNROLL = prev
