"""Composable decoder stack covering all six architecture families.

A model is a repeating *pattern* of layer templates (configs/base.py):
dense LMs repeat (global attention, dense FFN); gemma2 repeats
(local, dense), (global, dense); jamba repeats an 8-layer super-block of
mamba/attention mixers with alternating dense/MoE FFNs; mamba2 repeats a
pure SSD block.  Parameters for each pattern position are stacked along a
leading repeat axis and the stack is driven by ``lax.scan`` (small HLO,
fast compiles at 64 layers) with full per-superblock rematerialization.

Three execution modes share the layer code:
  * ``forward``     — training/scoring forward pass, logits over all positions
  * ``prefill``     — forward + returns the serving cache (KV / SSM states)
  * ``decode_step`` — one token in, one logits row out, cache updated in place

Sharding: all tensors are annotated with logical axes (sharding/specs.py);
``make_ctx`` degrades any rule whose dimension doesn't divide the mesh axis
to replication, so every (arch x mesh) combination lowers; the degradations
are the recorded baseline the §Perf loop then attacks.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import LayerTemplate, ModelConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    embed_tokens,
    init_embedding,
    init_mlp,
    init_rms_scale,
    lm_logits,
    mlp,
    rms_norm,
)
from repro.models.unroll import scan_unroll
from repro.sharding.specs import RULES, ShardingCtx


# ---------------------------------------------------------------------------
# Config plumbing
# ---------------------------------------------------------------------------


def make_ctx(mesh, cfg: ModelConfig, overrides: dict | None = None) -> ShardingCtx:
    """Sharding context for one (model, mesh) pair.

    Head/kv-head counts that don't divide the ``model`` axis stay sharded —
    GSPMD pads (e.g. qwen3's 40 q-heads become 48 lanes, a 20% attention
    overcount recorded in EXPERIMENTS.md) which beats the 16x redundant
    compute of replication.  MQA (kv=1) k/v stay replicated.  Axes that are
    genuinely degenerate (dim < tp with heavy padding cost) degrade to
    replication.
    """
    rules = dict(RULES)
    if mesh is not None:
        tp = mesh.shape.get("model", 1)

        def degrade(rule_name: str, dim: int):
            if dim and dim % tp != 0:
                rules[rule_name] = None

        if not cfg.shard_heads or (cfg.num_heads and cfg.num_heads < tp // 2):
            rules["heads"] = None
        if cfg.num_kv_heads and cfg.num_kv_heads < tp // 2:
            rules["kv_heads"] = None  # MQA/few-kv: replicate k/v activations
        degrade("experts", cfg.num_experts)
        degrade("mlp", cfg.d_ff)
        degrade("vocab", padded_vocab(cfg, tp))
        if cfg.has_ssm:
            degrade("ssm_heads", (cfg.ssm_expand * cfg.d_model) // cfg.ssm_head_dim)
    if overrides:
        rules.update(overrides)
    return ShardingCtx(mesh=mesh, rules=rules)


def padded_vocab(cfg: ModelConfig, tp: int = 16) -> int:
    v = cfg.vocab_size
    if v % tp == 0:
        return v
    mult = 256
    return ((v + mult - 1) // mult) * mult


def attn_config(cfg: ModelConfig, tmpl: LayerTemplate) -> attn_lib.AttnConfig:
    return attn_lib.AttnConfig(
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim,
        rope_theta=cfg.rope_theta,
        qk_norm=cfg.qk_norm,
        window=cfg.sliding_window if tmpl.mixer == "local" else None,
        attn_softcap=cfg.attn_softcap,
        norm_eps=cfg.norm_eps,
        kv_chunk=cfg.attn_kv_chunk,
        q_chunk=cfg.attn_q_chunk,
    )


def ssm_config(cfg: ModelConfig) -> ssm_lib.SSMConfig:
    return ssm_lib.SSMConfig(
        d_model=cfg.d_model,
        d_state=cfg.ssm_state,
        expand=cfg.ssm_expand,
        head_dim=cfg.ssm_head_dim,
        conv_width=cfg.ssm_conv,
        chunk=cfg.ssm_chunk,
        norm_eps=cfg.norm_eps,
        compute_dtype=cfg.ssm_compute_dtype,
    )


def moe_config(cfg: ModelConfig) -> moe_lib.MoEConfig:
    return moe_lib.MoEConfig(
        d_model=cfg.d_model,
        d_ff=cfg.moe_d_ff,
        num_experts=cfg.num_experts,
        top_k=cfg.top_k,
        capacity_factor=cfg.capacity_factor,
        act=cfg.act,
    )


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ModelConfig, tmpl: LayerTemplate) -> dict:
    dtype = _dtype(cfg)
    keys = jax.random.split(key, 4)
    p: dict[str, Any] = {"norm1": init_rms_scale(cfg.d_model)}
    if tmpl.mixer in ("global", "local"):
        p["attn"] = attn_lib.init_attention(keys[0], cfg.d_model, attn_config(cfg, tmpl), dtype)
    elif tmpl.mixer == "ssm":
        p["ssm"] = ssm_lib.init_ssm(keys[0], ssm_config(cfg), dtype)
    else:
        raise ValueError(tmpl.mixer)
    if cfg.post_norm:
        p["norm1_post"] = init_rms_scale(cfg.d_model)
    if tmpl.ffn == "dense":
        p["norm2"] = init_rms_scale(cfg.d_model)
        p["ffn"] = init_mlp(keys[1], cfg.d_model, cfg.d_ff, dtype, gated=cfg.mlp_gated)
    elif tmpl.ffn == "moe":
        p["norm2"] = init_rms_scale(cfg.d_model)
        p["moe"] = moe_lib.init_moe(keys[1], moe_config(cfg), dtype)
    elif tmpl.ffn != "none":
        raise ValueError(tmpl.ffn)
    if cfg.post_norm and tmpl.ffn != "none":
        p["norm2_post"] = init_rms_scale(cfg.d_model)
    return p


def init_params(cfg: ModelConfig, key: jax.Array, tp: int = 16) -> dict:
    dtype = _dtype(cfg)
    kemb, kblocks, khead, kfront = jax.random.split(key, 4)
    vpad = padded_vocab(cfg, tp)

    params: dict[str, Any] = {}
    if cfg.modality == "audio-codec":
        ks = jax.random.split(kemb, cfg.num_codebooks)
        params["embed"] = jnp.stack(
            [init_embedding(k, vpad, cfg.d_model, dtype) for k in ks]
        )  # [K, V, D]
        params["lm_head"] = jnp.stack(
            [
                (jax.random.normal(k, (cfg.d_model, vpad)) * cfg.d_model ** -0.5).astype(dtype)
                for k in jax.random.split(khead, cfg.num_codebooks)
            ]
        )  # [K, D, V]
    else:
        params["embed"] = init_embedding(kemb, vpad, cfg.d_model, dtype)
        if not cfg.tie_embeddings:
            params["lm_head"] = (
                jax.random.normal(khead, (cfg.d_model, vpad)) * cfg.d_model ** -0.5
            ).astype(dtype)
    if cfg.modality == "vision":
        params["vision_proj"] = (
            jax.random.normal(kfront, (cfg.frontend_dim, cfg.d_model))
            * cfg.frontend_dim ** -0.5
        ).astype(dtype)

    # blocks: one stacked pytree per pattern position, leaves [R, ...]
    r = cfg.num_repeats
    blocks = []
    for pi, tmpl in enumerate(cfg.pattern):
        kp = jax.random.fold_in(kblocks, pi)
        stacked = jax.vmap(
            lambda k: _init_block(k, cfg, tmpl)
        )(jax.random.split(kp, r))
        blocks.append(stacked)
    params["blocks"] = tuple(blocks)
    params["final_norm"] = init_rms_scale(cfg.d_model)
    return params


def param_specs(params, cfg: ModelConfig, ctx: ShardingCtx, zero1: bool = True):
    """PartitionSpec pytree for the parameter pytree.

    Feature axes ride the ``model`` axis (the paper's partition); when
    ``zero1`` a remaining large axis is additionally sharded over the data
    axes, which is where master params / optimizer state live (ZeRO-1).
    Block leaves carry a leading stacked repeat axis (always replicated).
    """
    z = "zero1" if zero1 else None

    def spec_of(kp, x) -> Any:
        path = jax.tree_util.keystr(kp)
        nd = x.ndim
        in_blocks = "blocks" in path

        def s(*names):  # block leaf: leading repeat axis
            assert len(names) + 1 == nd, (path, nd, names)
            return ctx.spec_div(tuple(x.shape), None, *names)

        if "vision_proj" in path:
            return ctx.spec_div(tuple(x.shape), z, None)
        if "embed" in path:
            if cfg.modality == "audio-codec":
                return ctx.spec_div(tuple(x.shape), None, "vocab", z)
            return ctx.spec_div(tuple(x.shape), "vocab", z)
        if "lm_head" in path:
            if cfg.modality == "audio-codec":
                return ctx.spec_div(tuple(x.shape), None, z, "vocab")
            return ctx.spec_div(tuple(x.shape), z, "vocab")
        if not in_blocks:  # final_norm etc.
            return ctx.spec(*([None] * nd))
        if path.endswith("wq']"):
            return s(z, "heads", None)
        if path.endswith("wk']") or path.endswith("wv']"):
            return s(z, "kv_heads", None)
        if path.endswith("wo']"):
            return s("heads", None, z)
        if "w_gate" in path or "w_up" in path:
            if nd == 4:  # stacked expert weights [R, E, D, F]
                return s("experts", z, "expert_mlp")
            return s(z, "mlp")
        if "w_down" in path:
            if nd == 4:
                return s("experts", "expert_mlp", z)
            return s("mlp", z)
        if "router" in path or "in_proj" in path or "out_proj" in path:
            return s(z, None)
        # norms, conv weights, scalars: replicated beyond the repeat axis
        return ctx.spec(*([None] * nd))

    return jax.tree_util.tree_map_with_path(spec_of, params)


def cache_specs(cfg: ModelConfig, ctx: ShardingCtx):
    """PartitionSpec pytree matching init_cache's structure."""
    out = []
    for tmpl in cfg.pattern:
        if tmpl.mixer in ("global", "local"):
            out.append({
                "k": ctx.spec(None, "batch", "seq_kv", None, None),
                "v": ctx.spec(None, "batch", "seq_kv", None, None),
            })
        else:
            out.append({
                "conv": ctx.spec(None, "batch", None, None),
                "state": ctx.spec(None, "batch", "ssm_heads", None, None),
            })
    return tuple(out)


# ---------------------------------------------------------------------------
# Embedding of model inputs
# ---------------------------------------------------------------------------


def embed_inputs(params, cfg: ModelConfig, batch: dict, ctx: ShardingCtx):
    """-> (x [B, S, D], positions [B, S], loss_mask [B, S])."""
    if cfg.modality == "vision":
        tokens = batch["tokens"]  # [B, S_text]
        patches = batch["patch_embeds"]  # [B, P, frontend_dim]
        tx = embed_tokens(params["embed"], tokens, ctx, cfg.embed_scale)
        px = jnp.einsum("bpf,fd->bpd", patches.astype(tx.dtype), params["vision_proj"])
        px = ctx.constrain(px, "batch", None, "embed")
        x = jnp.concatenate([px, tx], axis=1)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        loss_mask = jnp.concatenate(
            [jnp.zeros((b, patches.shape[1])), jnp.ones((b, tokens.shape[1]))], axis=1
        )
        return x, positions, loss_mask
    if cfg.modality == "audio-codec":
        tokens = batch["tokens"]  # [B, S, K]
        b, s, k = tokens.shape
        x = jnp.zeros((b, s, cfg.d_model), _dtype(cfg))
        for i in range(cfg.num_codebooks):
            x = x + params["embed"][i][tokens[:, :, i]]
        x = ctx.constrain(x, "batch", "seq", "embed")
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        return x, positions, jnp.ones((b, s))
    tokens = batch["tokens"]  # [B, S]
    x = embed_tokens(params["embed"], tokens, ctx, cfg.embed_scale)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    return x, positions, jnp.ones((b, s))


def output_logits(params, cfg: ModelConfig, x: jax.Array, ctx: ShardingCtx):
    if cfg.modality == "audio-codec":
        outs = [
            lm_logits(x, params["lm_head"][i], tied=False, cap=cfg.logit_softcap, ctx=ctx)
            for i in range(cfg.num_codebooks)
        ]
        return jnp.stack(outs, axis=2)  # [B, S, K, V]
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return lm_logits(x, table, tied=cfg.tie_embeddings, cap=cfg.logit_softcap, ctx=ctx)


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------


_ZERO_AUX = {"lb_loss": 0.0, "z_loss": 0.0, "overflow_frac": 0.0}


def _apply_block_train(
    tmpl: LayerTemplate, p, x, positions, cfg: ModelConfig, ctx, collect_cache: bool
):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    cache_out = None
    if tmpl.mixer in ("global", "local"):
        y, (k, v) = attn_lib.attention_train(p["attn"], h, positions, attn_config(cfg, tmpl), ctx)
        if collect_cache:
            cache_out = {
                "k": ctx.constrain(k, "batch", "seq_kv", None, None),
                "v": ctx.constrain(v, "batch", "seq_kv", None, None),
            }
    else:
        y = ssm_lib.ssm_train(p["ssm"], h, ssm_config(cfg), ctx)
        if collect_cache:
            cache_out = ssm_prefill_cache(p["ssm"], h, cfg, ctx)
    if cfg.post_norm:
        y = rms_norm(y, p["norm1_post"], cfg.norm_eps)
    x = x + y
    aux = dict(_ZERO_AUX)
    if tmpl.ffn != "none":
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if tmpl.ffn == "dense":
            y = mlp(p["ffn"], h, cfg.act, ctx)
        else:
            y, aux = moe_lib.moe_ffn(p["moe"], h, moe_config(cfg), ctx)
        if cfg.post_norm:
            y = rms_norm(y, p["norm2_post"], cfg.norm_eps)
        x = x + y
    return x, aux, cache_out


def ssm_prefill_cache(p, h, cfg: ModelConfig, ctx):
    """Recompute the final SSM state for serving after a prefill pass.

    Cheap relative to the main pass (one extra projection + recurrence on
    the compressed states); keeps ssm_train itself cache-free for training.
    """
    scfg = ssm_config(cfg)
    b, s, _ = h.shape
    di, n = scfg.d_inner, scfg.d_state
    proj = jnp.einsum("bsd,de->bse", h, p["in_proj"])
    z, xbc, dt_raw = ssm_lib._split_proj(proj, scfg)
    pad = jnp.pad(xbc, ((0, 0), (scfg.conv_width - 1, 0), (0, 0)))
    conv = sum(
        pad[:, i : i + s, :] * p["conv_w"][i][None, None, :]
        for i in range(scfg.conv_width)
    )
    conv = jax.nn.silu(conv + p["conv_b"][None, None, :])
    xs = conv[..., :di].reshape(b, s, scfg.num_heads, scfg.head_dim)
    bmat = conv[..., di : di + n].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    la = dt * a[None, None, :]  # [B, S, H]
    # state = sum_s exp(sum_{s'>s} la) * dt_s * B_s (x) x_s
    rev_cum = jnp.cumsum(la[:, ::-1, :], axis=1)[:, ::-1, :] - la
    decay = jnp.exp(rev_cum)  # [B, S, H]
    xd = xs.astype(jnp.float32) * dt[..., None]
    state = jnp.einsum("bsh,bshp,bsn->bhpn", decay, xd, bmat)
    if s >= scfg.conv_width - 1:
        conv_tail = xbc[:, -(scfg.conv_width - 1):, :]
    else:
        conv_tail = jnp.pad(xbc, ((0, 0), (scfg.conv_width - 1 - s, 0), (0, 0)))
    return {
        "conv": conv_tail,
        "state": ctx.constrain(state, "batch", "ssm_heads", None, None),
    }


def _apply_block_decode(tmpl: LayerTemplate, p, x, cache, pos, cfg: ModelConfig, ctx):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if tmpl.mixer in ("global", "local"):
        y, new_cache = attn_lib.attention_decode(
            p["attn"], h, cache, pos, attn_config(cfg, tmpl), ctx
        )
    else:
        y, new_cache = ssm_lib.ssm_decode(p["ssm"], h, cache, ssm_config(cfg), ctx)
    if cfg.post_norm:
        y = rms_norm(y, p["norm1_post"], cfg.norm_eps)
    x = x + y
    if tmpl.ffn != "none":
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if tmpl.ffn == "dense":
            y = mlp(p["ffn"], h, cfg.act, ctx)
        else:
            y, _ = moe_lib.moe_ffn(p["moe"], h, moe_config(cfg), ctx)
        if cfg.post_norm:
            y = rms_norm(y, p["norm2_post"], cfg.norm_eps)
        x = x + y
    return x, new_cache


# ---------------------------------------------------------------------------
# Full model: forward / prefill / decode
# ---------------------------------------------------------------------------


def forward(params, cfg: ModelConfig, batch: dict, ctx: ShardingCtx):
    """-> (logits, aux).  aux carries MoE losses and the loss mask."""
    x, positions, loss_mask = embed_inputs(params, cfg, batch, ctx)

    def body(carry, block_params):
        x, aux_acc = carry
        x = ctx.constrain(x, "batch", "seq", "embed")
        for tmpl, p in zip(cfg.pattern, block_params):
            x, aux, _ = _apply_block_train(tmpl, p, x, positions, cfg, ctx, False)
            aux_acc = {k: aux_acc[k] + aux[k] for k in aux_acc}
        return (x, aux_acc), None

    body = jax.checkpoint(body)
    aux0 = {k: jnp.zeros((), jnp.float32) for k in _ZERO_AUX}
    (x, aux), _ = jax.lax.scan(
        body, (x, aux0), params["blocks"], unroll=scan_unroll(cfg.num_repeats)
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = output_logits(params, cfg, x, ctx)
    aux = dict(aux)
    aux["loss_mask"] = loss_mask
    return logits, aux


def init_cache(cfg: ModelConfig, batch: int, max_len: int, ctx: ShardingCtx, tp: int = 16):
    """Stacked cache pytree: tuple over pattern positions, leaves [R, ...]."""
    dtype = _dtype(cfg)

    def one(tmpl: LayerTemplate):
        if tmpl.mixer in ("global", "local"):
            return attn_lib.init_kv_cache(batch, max_len, attn_config(cfg, tmpl), dtype, ctx)
        return ssm_lib.init_ssm_cache(batch, ssm_config(cfg), dtype, ctx)

    r = cfg.num_repeats
    caches = []
    for tmpl in cfg.pattern:
        c = one(tmpl)
        caches.append(jax.tree.map(lambda a: jnp.broadcast_to(a, (r,) + a.shape), c))
    return tuple(caches)


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, ctx: ShardingCtx, extra: dict | None = None):
    """tokens: [B, 1] (or [B, 1, K] audio); pos: scalar int32 position.
    -> (logits [B, 1, (K,) V], new_cache)."""
    if cfg.modality == "vision":
        # decode path: text token only; patches were consumed at prefill
        x = embed_tokens(params["embed"], tokens, ctx, cfg.embed_scale)
    elif cfg.modality == "audio-codec":
        b, one, k = tokens.shape
        x = jnp.zeros((b, 1, cfg.d_model), _dtype(cfg))
        for i in range(cfg.num_codebooks):
            x = x + params["embed"][i][tokens[:, :, i]]
    else:
        x = embed_tokens(params["embed"], tokens, ctx, cfg.embed_scale)

    def body(x, xs):
        block_params, block_cache = xs
        new_caches = []
        for tmpl, p, c in zip(cfg.pattern, block_params, block_cache):
            x, c_new = _apply_block_decode(tmpl, p, x, c, pos, cfg, ctx)
            new_caches.append(c_new)
        return x, tuple(new_caches)

    x, new_cache = jax.lax.scan(
        body, x, (params["blocks"], cache), unroll=scan_unroll(cfg.num_repeats)
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = output_logits(params, cfg, x, ctx)
    return logits, new_cache


def prefill(params, cfg: ModelConfig, batch: dict, max_len: int, ctx: ShardingCtx):
    """Forward pass that also builds the serving cache.

    Returns (last_logits [B, 1, ...], cache with the prefix written and
    room up to max_len)."""
    x, positions, _ = embed_inputs(params, cfg, batch, ctx)
    b, s, _ = x.shape

    def body(x, block_params):
        x = ctx.constrain(x, "batch", "seq", "embed")
        caches = []
        for tmpl, p in zip(cfg.pattern, block_params):
            x, _, c = _apply_block_train(tmpl, p, x, positions, cfg, ctx, True)
            caches.append(c)
        return x, tuple(caches)

    body = jax.checkpoint(body)
    x, cache = jax.lax.scan(
        body, x, params["blocks"], unroll=scan_unroll(cfg.num_repeats)
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = output_logits(params, cfg, x[:, -1:, :], ctx)

    # pad KV caches out to max_len so decode can continue
    if max_len > s:
        def pad_cache(c):
            def pad_leaf(a, name):
                if name in ("k", "v"):
                    widths = [(0, 0)] * a.ndim
                    widths[2] = (0, max_len - s)  # [R, B, S, Hkv, Dh]
                    return ctx.constrain(
                        jnp.pad(a, widths), None, "batch", "seq_kv", None, None
                    )
                return a
            return {k: pad_leaf(v, k) for k, v in c.items()}
        cache = tuple(pad_cache(c) for c in cache)
    return logits, cache
