"""train_step / eval_step factories: loss, grad-accum, mixed precision,
ZeRO-1 parameter layout.

Layout contract (see DESIGN.md §6):
  * master params live f32, sharded feature-dim over ``model`` AND over the
    data axes (``zero1``);
  * each step casts to the compute dtype and re-constrains to the
    feature-only sharding (GSPMD emits the ZeRO all-gathers);
  * gradients come back feature-sharded, the optimizer update runs on the
    fully-sharded layout (reduce-scatter over data is implicit in the
    output sharding).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.optim.optimizers import Optimizer, apply_updates
from repro.sharding.specs import ShardingCtx


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    optimizer: str = "adamw"
    lr: float = 3e-4
    weight_decay: float = 0.0
    grad_accum: int = 1
    lb_coef: float = 0.01  # MoE load-balance aux
    z_coef: float = 1e-3  # router z-loss
    max_grad_norm: float | None = 1.0


def cross_entropy(
    logits: jax.Array,  # [B, S, V] or [B, S, K, V] (f32)
    labels: jax.Array,  # [B, S] or [B, S, K] int32
    mask: jax.Array,  # [B, S]
    vocab_size: int,
) -> jax.Array:
    """Mean CE over unmasked positions; ignores padded vocab tail."""
    if logits.ndim == 4 and labels.ndim == 3:
        mask = mask[..., None]  # broadcast over codebooks
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(params, cfg: ModelConfig, batch: dict, ctx: ShardingCtx, settings: TrainSettings):
    logits, aux = transformer.forward(params, cfg, batch, ctx)
    # next-token prediction: shift within the provided labels
    labels = batch["labels"]
    mask = aux["loss_mask"]
    # drop the final position (no next token)
    if logits.ndim == 4:
        lo, la, ma = logits[:, :-1], labels[:, 1:], mask[:, 1:]
    else:
        lo, la, ma = logits[:, :-1], labels[:, 1:], mask[:, 1:]
    ce = cross_entropy(lo, la, ma, cfg.vocab_size)
    total = ce
    if cfg.has_moe:
        total = total + settings.lb_coef * aux["lb_loss"] + settings.z_coef * aux["z_loss"]
    metrics = {
        "loss": total,
        "ce": ce,
        "lb_loss": aux["lb_loss"],
        "z_loss": aux["z_loss"],
        "overflow_frac": aux["overflow_frac"],
    }
    return total, metrics


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(
        jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    )
    return jnp.sqrt(sum(leaves))


def make_train_step(
    cfg: ModelConfig,
    ctx: ShardingCtx,
    opt: Optimizer,
    settings: TrainSettings,
):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params": f32 master tree, "opt": opt state, "step": i32}.
    Grad accumulation scans over the microbatch axis of ``batch`` leaves
    shaped [A, mb, ...] when settings.grad_accum > 1.
    """
    compute_dtype = jnp.dtype(cfg.dtype)

    def cast_params(params):
        # re-constrain to feature-only sharding (drops the zero1 axes);
        # GSPMD emits the ZeRO all-gathers here.
        casted = jax.tree.map(
            lambda p: p.astype(compute_dtype) if p.ndim > 1 else p, params
        )
        if ctx.mesh is not None:
            specs = transformer.param_specs(casted, cfg, ctx, zero1=False)
            casted = jax.tree.map(
                lambda x, s: jax.lax.with_sharding_constraint(
                    x, jax.sharding.NamedSharding(ctx.mesh, s)
                ),
                casted, specs,
            )
        return casted

    grad_of = jax.grad(
        lambda p, b: loss_fn(p, cfg, b, ctx, settings), has_aux=True
    )

    def constrain_grads(g, params_like):
        """Gradients live in the ZeRO-1 (fully sharded) layout: each
        microbatch's contribution is reduce-scattered over the data axes
        instead of all-reduced, and the optimizer update is chip-local."""
        if ctx.mesh is None:
            return g
        specs = transformer.param_specs(params_like, cfg, ctx, zero1=True)
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(
                x, jax.sharding.NamedSharding(ctx.mesh, s)
            ),
            g, specs,
        )

    def train_step(state, batch):
        params = state["params"]
        cparams = cast_params(params)

        if settings.grad_accum > 1:
            def micro(carry, mb):
                g_acc, m_acc = carry
                g, m = grad_of(cparams, mb)
                g = constrain_grads(g, cparams)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), g_acc, g
                )
                g_acc = constrain_grads(g_acc, cparams)
                m_acc = jax.tree.map(lambda a, b_: a + b_, m_acc, m)
                return (g_acc, m_acc), None

            g0 = constrain_grads(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), cparams),
                cparams,
            )
            m0 = {
                "loss": 0.0, "ce": 0.0, "lb_loss": 0.0, "z_loss": 0.0,
                "overflow_frac": 0.0,
            }
            m0 = jax.tree.map(lambda v: jnp.asarray(v, jnp.float32), m0)
            from repro.models.unroll import scan_unroll
            (grads, metrics), _ = jax.lax.scan(
                micro, (g0, m0), batch, unroll=scan_unroll(settings.grad_accum)
            )
            denom = settings.grad_accum
            grads = jax.tree.map(lambda g: g / denom, grads)
            metrics = jax.tree.map(lambda m: m / denom, metrics)
        else:
            grads, metrics = grad_of(cparams, batch)
            grads = constrain_grads(grads, cparams)

        if settings.max_grad_norm is not None:
            gnorm = _global_norm(grads)
            scale = jnp.minimum(1.0, settings.max_grad_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
            metrics["grad_norm"] = gnorm

        updates, opt_state = opt.update(grads, state["opt"], params)
        new_params = apply_updates(params, updates)
        if ctx.mesh is not None:
            specs = transformer.param_specs(new_params, cfg, ctx, zero1=True)
            new_params = jax.tree.map(
                lambda x, s: jax.lax.with_sharding_constraint(
                    x, jax.sharding.NamedSharding(ctx.mesh, s)
                ),
                new_params, specs,
            )
        return (
            {"params": new_params, "opt": opt_state, "step": state["step"] + 1},
            metrics,
        )

    return train_step


def init_state(cfg: ModelConfig, key, opt: Optimizer, tp: int = 16):
    params = transformer.init_params(cfg, key, tp)
    # master copy in f32 (compute casts down per step)
    params = jax.tree.map(
        lambda p: p.astype(jnp.float32) if p.dtype == jnp.bfloat16 else p, params
    )
    return {"params": params, "opt": opt.init(params), "step": jnp.zeros((), jnp.int32)}


def state_specs(state, cfg: ModelConfig, ctx: ShardingCtx):
    """PartitionSpecs for the full train state (ZeRO-1 layout)."""
    from jax.sharding import PartitionSpec as P

    pspec = transformer.param_specs(state["params"], cfg, ctx, zero1=True)

    opt_state = state["opt"]
    if isinstance(opt_state, dict) and "m" in opt_state:
        ospec = {k: (pspec if k in ("m", "v") else P()) for k in opt_state}
    elif isinstance(opt_state, dict):
        ospec = {k: P() for k in opt_state}
    else:
        ospec = jax.tree.map(lambda _: P(), opt_state)
    return {"params": pspec, "opt": ospec, "step": P()}
