"""Serving: prefill + batched single-token decode."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.sharding.specs import ShardingCtx


def make_serve_step(cfg: ModelConfig, ctx: ShardingCtx):
    """serve_step(params, cache, tokens, pos) -> (next_tokens, logits, cache).

    One decode step for a batch of requests at a shared position (the
    dry-run decode shapes: KV cache of seq_len, ONE new token).  Greedy
    sampling; a sampler module can replace argmax without touching the
    model code.
    """

    def serve_step(params, cache, tokens, pos):
        logits, cache = transformer.decode_step(params, cfg, cache, tokens, pos, ctx)
        # mask padded vocab tail before sampling
        v = cfg.vocab_size
        neg = jnp.asarray(-1e30, logits.dtype)
        vpad = logits.shape[-1]
        if vpad > v:
            mask = jnp.arange(vpad) < v
            logits = jnp.where(mask, logits, neg)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, logits, cache

    return serve_step


def make_prefill(cfg: ModelConfig, ctx: ShardingCtx, max_len: int):
    def prefill_step(params, batch):
        return transformer.prefill(params, cfg, batch, max_len, ctx)

    return prefill_step


def greedy_generate(
    params,
    cfg: ModelConfig,
    ctx: ShardingCtx,
    prompt: jax.Array,  # [B, S0] (or [B, S0, K] audio)
    steps: int,
    max_len: int,
    extra: dict | None = None,
):
    """Prefill the prompt then decode ``steps`` greedy tokens (examples/tests)."""
    batch = {"tokens": prompt, **(extra or {})}
    _, cache = transformer.prefill(params, cfg, batch, max_len, ctx)
    serve_step = make_serve_step(cfg, ctx)

    pos0 = prompt.shape[1] + (cfg.num_patches if cfg.modality == "vision" else 0)
    if cfg.modality == "audio-codec":
        last = prompt[:, -1:, :]
    else:
        last = prompt[:, -1:]
    tokens = []
    tok = last
    for i in range(steps):
        pos = jnp.asarray(pos0 + i - 1, jnp.int32)
        nxt, _, cache = serve_step(params, cache, tok, pos)
        tok = nxt if cfg.modality == "audio-codec" else nxt[:, :]
        tokens.append(tok)
    return jnp.concatenate(tokens, axis=1)
