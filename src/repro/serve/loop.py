"""The serve loop: inference traffic interleaved with online updates.

One host, two streams, one weight store.  Requests stream in (here: the
rows of a :class:`~repro.data.pipeline.DataSource`, each trimmed to its
stored entries so nnz varies per request), get micro-batched, and are
scored by the :class:`~repro.serve.engine.PredictionEngine`; meanwhile
the same traffic feeds ``FDSVRGClassifier.partial_fit`` in chunks, and
each update epoch publishes a new :class:`~repro.serve.engine.
WeightSnapshot` under the monotone version counter.

**The staleness contract.**  A batch pins the engine's snapshot at
*flush* time (the moment it leaves the batcher), and is scored with that
pinned snapshot even if a publish lands before its compute runs — that
is what an async serving tier does: inference grabs a consistent
parameter version, training swaps the store underneath it.  Per-request
``staleness`` is the number of versions published between pin and serve
(``latest_at_serve - pinned``); 0 means the request was answered with
the freshest model that existed when its batch formed.  The loop is
single-threaded and deterministic — the interleaving is explicit
(chunk t's flushed batches are scored *after* chunk t's update
publishes), so staleness is exercised and testable, not a race.

The per-chunk training order mirrors the online distributed
linear-classification shape (dist kvstore + streaming LibSVM) of the
MXNet sparse example the ROADMAP names: pull the current weights (warm
start from ``coef_``), run an epoch on the chunk, push the new version.
"""

from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataSource, as_source
from repro.data.sparse import PaddedCSR
from repro.serve.batching import Batch, MicroBatcher
from repro.serve.engine import PredictionEngine, WeightSnapshot


@dataclasses.dataclass(frozen=True)
class ServedRequest:
    """One request's serving record (the margin plus the bookkeeping the
    latency/staleness metrics are computed from)."""

    req_id: int
    margin: np.ndarray  # scalar () for binary, [k] for multi-output
    latency_s: float  # enqueue -> served (includes batching delay)
    version_used: int  # the batch's pinned snapshot version
    staleness: int  # versions published between pin and serve


@dataclasses.dataclass
class ServeReport:
    """What one serve-loop run measured."""

    served: list[ServedRequest]
    num_batches: int
    serve_wall_s: float  # engine compute time only
    total_wall_s: float  # whole loop, training included
    versions_published: int
    updates_skipped: int  # single-class chunks the trainer skipped
    bucket_counts: dict[tuple[int, int], int]
    flush_causes: dict[str, int]
    compiled_shapes: int

    @property
    def num_requests(self) -> int:
        return len(self.served)

    @property
    def predictions_per_s(self) -> float:
        if self.serve_wall_s <= 0:
            return 0.0
        return self.num_requests / self.serve_wall_s

    def latency_percentiles(self, qs=(50, 99)) -> dict[str, float]:
        lats = np.asarray([r.latency_s for r in self.served])
        if lats.size == 0:
            return {f"p{q}_ms": 0.0 for q in qs}
        return {
            f"p{q}_ms": float(np.percentile(lats, q) * 1e3) for q in qs
        }

    def staleness_histogram(self) -> dict[int, int]:
        hist: dict[int, int] = {}
        for r in self.served:
            hist[r.staleness] = hist.get(r.staleness, 0) + 1
        return hist

    def margins(self) -> np.ndarray:
        """All served margins in request-id order, ``[n]`` or ``[n, k]``."""
        order = sorted(self.served, key=lambda r: r.req_id)
        return np.stack([np.asarray(r.margin) for r in order])


def _chunk_requests(chunk):
    """Split one RowChunk into per-row (indices, values) with trailing
    padding and explicit zeros trimmed — requests carry only stored
    entries, so row nnz varies and the width buckets get exercised."""
    mask = np.asarray(chunk.values) != 0.0
    idx = np.asarray(chunk.indices)
    val = np.asarray(chunk.values)
    for r in range(idx.shape[0]):
        m = mask[r]
        yield idx[r, m], val[r, m]


def _chunk_padded(chunk, dim: int) -> PaddedCSR:
    return PaddedCSR(
        indices=jnp.asarray(chunk.indices),
        values=jnp.asarray(chunk.values),
        labels=jnp.asarray(chunk.labels),
        dim=dim,
    )


def run_serve_loop(
    source,
    engine: PredictionEngine,
    batcher: MicroBatcher,
    *,
    classifier=None,
    update_every_chunks: int = 1,
    train_outer_iters: int = 1,
    chunk_rows: int = 64,
    limit_rows: int | None = None,
    clock=time.perf_counter,
) -> ServeReport:
    """Drive ``source``'s rows through batcher + engine, interleaving
    ``classifier.partial_fit`` every ``update_every_chunks`` chunks.

    ``classifier=None`` serves a frozen model (pure inference).  With a
    classifier (must already be fitted — its ``coef_`` seeds version 0),
    each update trains on the chunk's rows *with their stream labels*
    and publishes ``engine.version + 1``; chunks whose labels are all
    one class are skipped (counted in ``updates_skipped``) since a
    one-class chunk is not a classification epoch.
    """
    source = as_source(source)
    if classifier is not None and not classifier.is_fitted:
        raise ValueError(
            "run_serve_loop needs a fitted classifier (its coef_ is the "
            "version the engine starts serving)"
        )
    dim = source.stats().dim
    if engine.snapshot.dim != dim:
        raise ValueError(
            f"engine serves dim={engine.snapshot.dim}, source rows have "
            f"dim={dim}"
        )

    served: list[ServedRequest] = []
    serve_wall = 0.0
    num_batches = 0
    versions_published = 0
    updates_skipped = 0
    rows_seen = 0

    def score(batches: list[Batch]) -> None:
        nonlocal serve_wall, num_batches
        for batch in batches:
            snap = batch.snapshot
            t0 = clock()
            out = engine.margins(batch.indices, batch.values, snapshot=snap)
            t1 = clock()
            serve_wall += t1 - t0
            num_batches += 1
            latest = engine.version
            for r, req in enumerate(batch.requests):
                served.append(
                    ServedRequest(
                        req_id=req.req_id,
                        margin=out[r],
                        latency_s=t1 - req.t_enqueue,
                        version_used=snap.version,
                        staleness=latest - snap.version,
                    )
                )

    def pin(batches: list[Batch]) -> list[Batch]:
        for b in batches:
            b.snapshot = engine.snapshot
        return batches

    t_start = clock()
    for ci, chunk in enumerate(source.chunks(chunk_rows)):
        if limit_rows is not None and rows_seen >= limit_rows:
            break
        rows_seen += chunk.indices.shape[0]
        # 1) this chunk's rows become requests
        for idx, val in _chunk_requests(chunk):
            batcher.submit(idx, val)
        # 2) flush what's ready, pinning the snapshot they see
        pending = pin(batcher.ready())
        # 3) the online update: train on this chunk, publish atomically.
        #    Scoring the pinned batches AFTER the publish is the
        #    deterministic stand-in for "training swapped the store
        #    while these batches were in flight" — their staleness is 1.
        if (
            classifier is not None
            and (ci + 1) % update_every_chunks == 0
        ):
            if np.unique(np.asarray(chunk.labels)).size < 2:
                updates_skipped += 1
            else:
                classifier.partial_fit(
                    _chunk_padded(chunk, dim), outer_iters=train_outer_iters
                )
                engine.publish(
                    WeightSnapshot.from_estimator(
                        classifier, engine.version + 1
                    )
                )
                versions_published += 1
        # 4) serve the in-flight batches
        score(pending)
    # end of stream: deadline-flush whatever is left, then drain
    score(pin(batcher.ready()))
    score(pin(batcher.drain()))
    total_wall = clock() - t_start

    return ServeReport(
        served=served,
        num_batches=num_batches,
        serve_wall_s=serve_wall,
        total_wall_s=total_wall,
        versions_published=versions_published,
        updates_skipped=updates_skipped,
        bucket_counts=dict(batcher.bucket_counts),
        flush_causes=dict(batcher.flush_causes),
        compiled_shapes=len(engine.compiled_shapes),
    )


def synthetic_request_source(
    *,
    dim: int,
    num_requests: int,
    nnz_lo: int = 4,
    nnz_hi: int = 64,
    seed: int = 0,
    name: str = "requests",
) -> DataSource:
    """A planted-separator request stream with per-row varying nnz.

    Rows store ``nnz_i ~ U[nnz_lo, nnz_hi]`` entries (random ids, unit-
    scale values) padded to ``nnz_hi``; labels are the sign of the
    margin against a hidden ``w*`` so the interleaved ``partial_fit``
    has something real to learn.  Deterministic in ``seed``.
    """
    if not 1 <= nnz_lo <= nnz_hi <= dim:
        raise ValueError(
            f"need 1 <= nnz_lo <= nnz_hi <= dim, got "
            f"({nnz_lo}, {nnz_hi}, {dim})"
        )
    rng = np.random.default_rng(seed)
    w_star = rng.normal(size=dim).astype(np.float32)
    indices = np.zeros((num_requests, nnz_hi), dtype=np.int32)
    values = np.zeros((num_requests, nnz_hi), dtype=np.float32)
    nnz = rng.integers(nnz_lo, nnz_hi + 1, size=num_requests)
    for r in range(num_requests):
        k = int(nnz[r])
        indices[r, :k] = rng.choice(dim, size=k, replace=False)
        values[r, :k] = rng.normal(size=k).astype(np.float32)
    margins = np.einsum("rk,rk->r", w_star[indices], values)
    labels = np.where(margins > 0, 1.0, -1.0).astype(np.float32)
    from repro.data.pipeline import ArraySource

    return ArraySource(
        PaddedCSR(
            indices=jnp.asarray(indices),
            values=jnp.asarray(values),
            labels=jnp.asarray(labels),
            dim=dim,
        ),
        name=name,
    )
