"""Versioned weight snapshots + the batched sparse margin hot path.

Serving splits the estimator's ``decision_function`` into its two real
halves: a *frozen, versioned* parameter snapshot that swaps atomically
under online updates (:class:`WeightSnapshot`), and a *compiled* margin
computation over padded request batches (:class:`PredictionEngine`).

The numerics contract is the repo-wide one: the engine computes

    s_i = sum_k w[idx[i, k]] * val[i, k]        (per output column)

through :func:`repro.kernels.ops.sparse_margins` (the Pallas gather
kernel, interpret-mode off-TPU) when ``use_kernels=True`` and through
the jnp reference otherwise, and both are **bit-identical** to
``FDSVRGClassifier.decision_function`` evaluated on the same padded
rows (pinned in ``tests/test_serve_engine.py``).  Multi-output ``w ∈
R^{d×k}`` runs one kernel pass per column — exactly the per-column loop
``decision_function`` does for one-vs-rest models, so ``k > 1`` stays
bitwise too.

Two padding facts the batcher design leans on (both verified by test):

* padding extra **rows** (zero indices/values) never changes the
  surviving rows' bits — each row's reduction is independent;
* padding extra nnz **lanes** appends exact-zero addends, which XLA may
  still *reassociate* at large widths — so the bit contract with a
  reference computed at a different padded width holds only for the
  narrow widths typical of text/CTR rows (empirically ≲ 64 lanes on
  CPU); at matched width it holds always.
"""

from __future__ import annotations

import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.sparse import margins_rows
from repro.kernels import ops

# The jnp reference, jit'd once.  Jit is load-bearing for the bit
# contract: XLA contracts gather·multiply·reduce the same way it does
# inside the training epochs, so this path is bit-identical to the
# Pallas kernel (pinned in tests/test_fused_kernels.py: kernel ==
# jax.jit(ref)) — the un-jitted eager call is NOT (it skips the fused
# multiply-add).  `FDSVRGClassifier.decision_function` routes through
# :func:`batched_margins` below for exactly this reason.
_ref_margins = jax.jit(margins_rows)


def batched_margins(indices, values, w, *, use_kernels: bool = False) -> np.ndarray:
    """THE serving margin computation — one definition shared by the
    engine and ``FDSVRGClassifier.decision_function``.

    ``w`` is ``[d]`` (returns ``[n]``) or ``[d, k]`` (returns ``[n, k]``,
    one kernel pass per column — bitwise equal to k binary scorings).
    ``use_kernels=True`` runs the Pallas gather kernel (interpret-mode
    off-TPU); both paths are bit-identical to each other.
    """
    idx = jnp.asarray(indices, dtype=jnp.int32)
    val = jnp.asarray(values)
    if idx.ndim != 2 or idx.shape != val.shape:
        raise ValueError(
            f"need matching [n, width] arrays, got {idx.shape} / {val.shape}"
        )
    w = jnp.asarray(w)
    if w.ndim not in (1, 2):
        raise ValueError(f"w must be [d] or [d, k], got shape {w.shape}")
    if idx.shape[0] == 0:
        shape = (0,) if w.ndim == 1 else (0, int(w.shape[1]))
        return np.zeros(shape, dtype=np.asarray(val).dtype)
    column = ops.sparse_margins if use_kernels else _ref_margins
    if w.ndim == 1:
        return np.asarray(column(idx, val, w))
    return np.column_stack(
        [np.asarray(column(idx, val, w[:, j])) for j in range(w.shape[1])]
    )


@dataclasses.dataclass(frozen=True)
class WeightSnapshot:
    """A frozen model version: ``w`` is ``[d]`` (binary) or ``[d, k]``
    (one-vs-rest multi-output), ``version`` is the monotone counter the
    engine orders publishes by."""

    w: jax.Array
    version: int

    def __post_init__(self):
        if self.w.ndim not in (1, 2):
            raise ValueError(
                f"w must be [d] or [d, k], got shape {self.w.shape}"
            )

    @property
    def dim(self) -> int:
        return int(self.w.shape[0])

    @property
    def num_outputs(self) -> int:
        return 1 if self.w.ndim == 1 else int(self.w.shape[1])

    @classmethod
    def from_dense(cls, w, version: int) -> "WeightSnapshot":
        return cls(w=jnp.asarray(w), version=version)

    @classmethod
    def from_blocks(cls, blocks, version: int) -> "WeightSnapshot":
        """Assemble from per-worker feature blocks (``[d_l]`` or
        ``[d_l, k]`` in partition order, the shape each FD worker owns
        at the end of an epoch).  Concatenation along the feature axis
        is lossless, so a block-published snapshot serves bit-identically
        to the dense one."""
        blocks = [jnp.asarray(b) for b in blocks]
        if not blocks:
            raise ValueError("from_blocks needs at least one block")
        ndims = {b.ndim for b in blocks}
        if ndims - {1, 2} or len(ndims) != 1:
            raise ValueError(
                f"blocks must all be [d_l] or all [d_l, k], got ndims {ndims}"
            )
        return cls(w=jnp.concatenate(blocks, axis=0), version=version)

    @classmethod
    def from_estimator(cls, clf, version: int) -> "WeightSnapshot":
        """From a fitted ``FDSVRGClassifier``: sklearn's ``coef_`` is
        ``[k, d]`` for one-vs-rest, the engine runs ``[d, k]``."""
        coef = np.asarray(clf.coef_)
        return cls(
            w=jnp.asarray(coef.T if coef.ndim == 2 else coef),
            version=version,
        )


class PredictionEngine:
    """Batched sparse margins against an atomically swappable snapshot.

    The engine is deliberately *dumb about requests* — it scores padded
    ``(indices, values)`` batches (the :class:`~repro.serve.batching.
    MicroBatcher`'s output) and leaves queueing, deadlines, and snapshot
    pinning to the caller.  What it owns:

    * the **current snapshot** (``publish`` swaps it; versions must be
      strictly increasing — a stale publish is a hard error, not a
      silent overwrite);
    * the **compiled-shape meter**: every distinct ``(rows, width, k,
      dtype)`` it has scored.  Each entry is one XLA compilation on both
      the kernel and jnp paths, so ``len(compiled_shapes)`` is the
      recompile count BENCH_serve gates on.
    """

    def __init__(self, snapshot: WeightSnapshot | None = None, *,
                 use_kernels: bool = False) -> None:
        self.use_kernels = use_kernels
        self._lock = threading.Lock()
        self._snapshot = snapshot
        self.compiled_shapes: set[tuple] = set()
        self.batches_served = 0
        self.rows_served = 0

    @classmethod
    def from_estimator(cls, clf, *, use_kernels: bool = False,
                       version: int = 0) -> "PredictionEngine":
        return cls(
            WeightSnapshot.from_estimator(clf, version),
            use_kernels=use_kernels,
        )

    @property
    def snapshot(self) -> WeightSnapshot:
        snap = self._snapshot
        if snap is None:
            raise ValueError("no snapshot published yet")
        return snap

    @property
    def version(self) -> int:
        return self.snapshot.version

    def publish(self, snapshot: WeightSnapshot) -> WeightSnapshot:
        """Atomically install ``snapshot``; returns the one it replaced
        (or None).  Versions are monotone: serving must never silently
        step a model backwards."""
        with self._lock:
            prev = self._snapshot
            if prev is not None:
                if snapshot.version <= prev.version:
                    raise ValueError(
                        f"publish version {snapshot.version} is not newer "
                        f"than the current {prev.version}"
                    )
                if snapshot.dim != prev.dim:
                    raise ValueError(
                        f"snapshot dim {snapshot.dim} != engine dim "
                        f"{prev.dim}"
                    )
            self._snapshot = snapshot
            return prev

    def margins(self, indices, values, *,
                snapshot: WeightSnapshot | None = None) -> np.ndarray:
        """Margins for one padded batch: ``[n]`` for binary snapshots,
        ``[n, k]`` for multi-output.  ``snapshot`` overrides the current
        one (the serve loop passes the version a batch was pinned to at
        flush time — see :mod:`repro.serve.loop`)."""
        snap = self.snapshot if snapshot is None else snapshot
        values = np.asarray(values)
        n, width = values.shape if values.ndim == 2 else (0, 0)
        if n:
            self.compiled_shapes.add(
                (n, width, snap.num_outputs, str(values.dtype),
                 self.use_kernels)
            )
        out = batched_margins(
            indices, values, snap.w, use_kernels=self.use_kernels
        )
        self.batches_served += 1
        self.rows_served += n
        return out
