"""Request micro-batching onto a bounded set of compiled shapes.

Serving traffic is ragged — every request is a sparse row with its own
nnz — but XLA wants static shapes, and every distinct padded shape is a
compilation.  The batcher quantizes both axes to powers of two:

* **width buckets**: a request with ``nnz`` stored entries lands in the
  bucket of width ``bucket_width(nnz)`` (next power of two, floored at
  ``min_width``).  Requests only ever share a batch with same-bucket
  peers, so batch width is the bucket width, never a data-dependent max.
* **row buckets**: a flushed batch pads its row count up to the next
  power of two (≤ ``max_batch``).

The compiled-shape universe is therefore at most
``log2(max_batch) · log2(max_width)`` shapes — bounded by construction,
independent of traffic, and metered (``PredictionEngine.compiled_shapes``
counts what actually compiled; ``MicroBatcher.bucket_counts`` counts
what actually flushed).

Flush policy: a bucket flushes when it holds ``max_batch`` requests
(throughput) or when its **oldest** request has waited ``max_delay_s``
(tail latency) — the deadline is per-request age, checked at every
:meth:`MicroBatcher.ready` poll, so a lone request in a cold bucket is
served within one deadline, not held hostage for a full batch.

Padding is exact for the margins the engine computes: padded rows are
independent (sliced off after the kernel), and padded lanes are
``(index 0, value 0.0)`` entries contributing exact zeros — see the
width-reassociation caveat in :mod:`repro.serve.engine`.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np


def bucket_width(nnz: int, *, min_width: int = 8) -> int:
    """The padded nnz width a request with ``nnz`` entries buckets to:
    the next power of two, floored at ``min_width``."""
    if nnz < 0:
        raise ValueError(f"nnz must be >= 0, got {nnz}")
    width = min_width
    while width < nnz:
        width <<= 1
    return width


def _pow2_rows(n: int) -> int:
    rows = 1
    while rows < n:
        rows <<= 1
    return rows


@dataclasses.dataclass(frozen=True)
class Request:
    """One sparse prediction request: global feature ids + values."""

    req_id: int
    indices: np.ndarray  # int32[nnz]
    values: np.ndarray  # float[nnz]
    t_enqueue: float

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])


@dataclasses.dataclass
class Batch:
    """A flushed, padded micro-batch.  ``indices``/``values`` are the
    bucket-shaped ``[rows, width]`` arrays (rows ``n_valid:`` are
    padding); ``snapshot`` is pinned by the serve loop at flush time —
    the model version this batch will be scored with, regardless of
    publishes that land before the compute runs."""

    requests: tuple[Request, ...]
    indices: np.ndarray  # int32[rows, width]
    values: np.ndarray  # float[rows, width]
    t_flush: float
    cause: str  # "full" | "deadline" | "drain"
    snapshot: object | None = None

    @property
    def n_valid(self) -> int:
        return len(self.requests)

    @property
    def shape(self) -> tuple[int, int]:
        return tuple(self.indices.shape)


class MicroBatcher:
    """Accumulates requests into power-of-two buckets; flushes on size
    or deadline.  Single-owner object (the serve loop) — no locking."""

    def __init__(
        self,
        *,
        max_batch: int = 256,
        max_delay_s: float = 0.002,
        min_width: int = 8,
        clock: Callable[[], float] = time.monotonic,
    ) -> None:
        if max_batch < 1 or (max_batch & (max_batch - 1)) != 0:
            raise ValueError(
                f"max_batch must be a power of two >= 1, got {max_batch}"
            )
        if min_width < 1 or (min_width & (min_width - 1)) != 0:
            raise ValueError(
                f"min_width must be a power of two >= 1, got {min_width}"
            )
        self.max_batch = max_batch
        self.max_delay_s = max_delay_s
        self.min_width = min_width
        self.clock = clock
        self._buckets: dict[int, list[Request]] = {}
        self._next_id = 0
        # flushed-shape histogram {(rows, width): count} and flush causes
        self.bucket_counts: dict[tuple[int, int], int] = {}
        self.flush_causes: dict[str, int] = {}

    @property
    def pending(self) -> int:
        return sum(len(reqs) for reqs in self._buckets.values())

    def submit(self, indices, values, *, now: float | None = None) -> Request:
        """Enqueue one sparse request; returns its :class:`Request`
        record (the id is the submission counter)."""
        idx = np.asarray(indices, dtype=np.int32).reshape(-1)
        val = np.asarray(values).reshape(-1)
        if idx.shape != val.shape:
            raise ValueError(
                f"indices/values length mismatch: {idx.shape} vs {val.shape}"
            )
        req = Request(
            req_id=self._next_id,
            indices=idx,
            values=val,
            t_enqueue=self.clock() if now is None else now,
        )
        self._next_id += 1
        self._buckets.setdefault(
            bucket_width(req.nnz, min_width=self.min_width), []
        ).append(req)
        return req

    def ready(self, now: float | None = None) -> list[Batch]:
        """Flush and return every bucket that is full or past deadline."""
        now = self.clock() if now is None else now
        out = []
        for width in sorted(self._buckets):
            reqs = self._buckets[width]
            while len(reqs) >= self.max_batch:
                out.append(
                    self._flush(width, reqs[: self.max_batch], "full", now)
                )
                del reqs[: self.max_batch]
            if reqs and now - reqs[0].t_enqueue >= self.max_delay_s:
                out.append(self._flush(width, reqs, "deadline", now))
                self._buckets[width] = []
        return out

    def drain(self, now: float | None = None) -> list[Batch]:
        """Flush everything (end of stream / shutdown)."""
        now = self.clock() if now is None else now
        out = []
        for width, reqs in sorted(self._buckets.items()):
            for lo in range(0, len(reqs), self.max_batch):
                out.append(
                    self._flush(
                        width, reqs[lo : lo + self.max_batch], "drain", now
                    )
                )
        self._buckets.clear()
        return out

    def _flush(self, width: int, reqs: list[Request], cause: str,
               now: float) -> Batch:
        rows = min(_pow2_rows(len(reqs)), self.max_batch)
        dtype = reqs[0].values.dtype
        indices = np.zeros((rows, width), dtype=np.int32)
        values = np.zeros((rows, width), dtype=dtype)
        for r, req in enumerate(reqs):
            indices[r, : req.nnz] = req.indices
            values[r, : req.nnz] = req.values
        shape = (rows, width)
        self.bucket_counts[shape] = self.bucket_counts.get(shape, 0) + 1
        self.flush_causes[cause] = self.flush_causes.get(cause, 0) + 1
        return Batch(
            requests=tuple(reqs),
            indices=indices,
            values=values,
            t_flush=now,
            cause=cause,
        )
