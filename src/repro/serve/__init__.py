"""repro.serve — the inference half of the system.

Training (``repro.api.solve`` / ``FDSVRGClassifier``) produces a linear
model ``w ∈ R^d`` (or ``R^{d×k}`` one-vs-rest).  This package serves it
at traffic scale and keeps it learning while it serves:

* :class:`~repro.serve.engine.PredictionEngine` — holds a *versioned,
  frozen* :class:`~repro.serve.engine.WeightSnapshot` (dense ``w`` or
  per-worker feature blocks) and computes request-batch margins through
  the same Pallas ``sparse_margin`` gather kernel the training hot path
  uses (jnp reference off-kernel) — bit-identical to
  ``FDSVRGClassifier.decision_function`` on the same rows.
* :class:`~repro.serve.batching.MicroBatcher` — maps arbitrary sparse
  requests onto a *bounded* set of compiled shapes (power-of-two nnz and
  row buckets) with a deadline-based flush, so tail latency is capped
  and recompiles are a metered quantity.
* :func:`~repro.serve.loop.run_serve_loop` — interleaves inference
  traffic with streaming ``partial_fit`` updates: snapshots swap
  atomically under a monotone version counter, batches pin the snapshot
  they were flushed against, and per-request staleness (latest published
  version minus the pinned version at serve time) is recorded.

``benchmarks/serve_bench.py`` → ``BENCH_serve.json`` measures the whole
path; ``examples/serve_linear.py`` is the quickstart.  (The seed's LM
prefill/decode demo lives on in :mod:`repro.launch.serve`.)
"""

from repro.serve.batching import Batch, MicroBatcher, Request, bucket_width
from repro.serve.engine import PredictionEngine, WeightSnapshot
from repro.serve.loop import (
    ServedRequest,
    ServeReport,
    run_serve_loop,
    synthetic_request_source,
)

__all__ = [
    "Batch",
    "MicroBatcher",
    "PredictionEngine",
    "Request",
    "ServeReport",
    "ServedRequest",
    "WeightSnapshot",
    "bucket_width",
    "run_serve_loop",
    "synthetic_request_source",
]
