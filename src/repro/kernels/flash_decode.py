"""Pallas TPU kernel: single-token GQA attention over a long KV cache.

The serving hot-spot for the decode_32k / long_500k shapes: one query
token attends to up to 524k cached keys.  Decode attention is
bandwidth-bound (every K/V byte is read once per token), so the kernel's
job is to stream K/V through VMEM in blocks with an online-softmax
accumulator and never materialize the [H, S] logits in HBM.

Layout choices (TPU-native, not a CUDA port):
  * grid = (kv_heads, S/block_s), S innermost so the per-head accumulator
    lives in VMEM scratch across the sweep (the "split-K" dimension of GPU
    flash-decoding becomes a sequential VMEM-resident sweep; cross-chip S
    partitioning is handled one level up by GSPMD, not inside the kernel).
  * all q-heads of one kv group are processed together -> the score matmul
    is [group, Dh] x [Dh, block_s] on the MXU.
  * cache-validity masking arrives as an additive bias row (0 / -1e30)
    computed by the wrapper; this keeps the kernel free of scalar-prefetch
    plumbing while the bias stream costs S*4 bytes vs the cache's
    S*2*Hkv*Dh*2 — negligible.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_decode_kernel(
    scale: float,
    q_ref,  # [1, group, dh]
    k_ref,  # [block_s, 1, dh]
    v_ref,  # [block_s, 1, dh]
    bias_ref,  # [1, block_s]
    out_ref,  # [1, group, dh]
    acc_ref,  # VMEM [group, dh] f32
    m_ref,  # VMEM [group, 1] f32
    l_ref,  # VMEM [group, 1] f32
):
    sb = pl.program_id(1)

    @pl.when(sb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)  # [group, dh]
    k = k_ref[:, 0, :].astype(jnp.float32)  # [block_s, dh]
    v = v_ref[:, 0, :].astype(jnp.float32)
    logits = (
        jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        * scale
        + bias_ref[...]  # [1, block_s] broadcasts over the group dim
    )

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(sb == pl.num_programs(1) - 1)
    def _finish():
        out_ref[0] = (acc_ref[...] / l_ref[...]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "block_s", "interpret"))
def flash_decode(
    q: jax.Array,  # [hkv, group, dh]
    k: jax.Array,  # [S, hkv, dh]
    v: jax.Array,  # [S, hkv, dh]
    bias: jax.Array,  # [1, S]  (0 for valid positions, -1e30 for invalid)
    *,
    scale: float,
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:  # [hkv, group, dh] float32
    hkv, group, dh = q.shape
    s = k.shape[0]
    assert k.shape == v.shape == (s, hkv, dh)
    assert bias.shape == (1, s)
    assert s % block_s == 0, "caller pads the cache to tile multiples"

    grid = (hkv, s // block_s)
    return pl.pallas_call(
        functools.partial(_flash_decode_kernel, scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, group, dh), lambda j, sb: (j, 0, 0)),
            pl.BlockSpec((block_s, 1, dh), lambda j, sb: (sb, j, 0)),
            pl.BlockSpec((block_s, 1, dh), lambda j, sb: (sb, j, 0)),
            pl.BlockSpec((1, block_s), lambda j, sb: (0, sb)),
        ],
        out_specs=pl.BlockSpec((1, group, dh), lambda j, sb: (j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((hkv, group, dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((group, dh), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, bias)
