"""Public jit'd wrappers around the Pallas kernels.

Handles padding to tile multiples, layout munging ([d] vectors to the 2-D
layouts the TPU tiles want), backend selection (interpret=True off-TPU so
the same code validates on CPU), and exposes shapes the rest of the
framework uses.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.fd_matvec import fd_matvec
from repro.kernels.flash_decode import flash_decode
from repro.kernels.fused_update import fused_update
from repro.kernels.lazy_update import (
    lazy_catchup,
    lazy_flush,
    lazy_proba_update,
    lazy_touch_update,
    step_corrections,
)
from repro.kernels.logistic_grad import logistic_grad
from repro.kernels.prox_update import prox_update
from repro.kernels.sparse_margin import sparse_margin
from repro.kernels.svrg_update import svrg_update


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, multiple: int, value=0.0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def sparse_margins(
    indices: jax.Array,  # int32[N, nnz_l], block-LOCAL ids (BlockCSR rows)
    values: jax.Array,  # [N, nnz_l]
    w_block: jax.Array,  # [d_block]
    *,
    block_rows: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:  # [N] float32
    """Fused gather-margin over one block's local CSR rows (the FD-SVRG
    margin hot path).  ``block_rows=None`` keeps all rows in one tile,
    which is also the shape the bit-identity contract is stated for."""
    interpret = _interpret_default() if interpret is None else interpret
    n = indices.shape[0]
    if block_rows is None:
        block_rows = max(n, 1)
    idx2 = _pad_to(indices, 0, block_rows)
    val2 = _pad_to(values, 0, block_rows)
    out = sparse_margin(
        w_block[None, :], idx2, val2, block_rows=block_rows, interpret=interpret
    )
    return out[0, :n]


def fused_block_update(
    w_block: jax.Array,  # [d_block]
    indices: jax.Array,  # int32[u, nnz_l], block-LOCAL ids
    values: jax.Array,  # [u, nnz_l]
    coef: jax.Array,  # [u]
    z_block: jax.Array,  # [d_block]
    eta: jax.Array | float,  # runtime scalar (eta * option mask)
    *,
    lam: float,
    interpret: bool | None = None,
) -> jax.Array:  # [d_block]
    """Fused scatter-grad + variance-reduced parameter update on one
    block: w - eta * (scatter(coef * x) + z + lam * w) in a single pass
    (L2 family; lam = 0 covers the unregularized path)."""
    interpret = _interpret_default() if interpret is None else interpret
    d = w_block.shape[0]
    out = fused_update(
        w_block[None, :],
        indices,
        values,
        coef[None, :],
        z_block[None, :],
        jnp.asarray(eta, dtype=w_block.dtype)[None, None],
        lam=lam,
        interpret=interpret,
    )
    return out[0, :d]


def fused_block_prox_update(
    w_block: jax.Array,  # [d_block]
    indices: jax.Array,  # int32[u, nnz_l], block-LOCAL ids
    values: jax.Array,  # [u, nnz_l]
    coef: jax.Array,  # [u]
    z_block: jax.Array,  # [d_block]
    eta: jax.Array | float,  # runtime scalar (eta * option mask)
    *,
    lam: float,  # smooth L2 coefficient (the classic 'l2' path)
    lam1: float = 0.0,  # L1 strength handled by the fused prox
    lam2: float = 0.0,  # elastic-net L2 strength handled by the fused prox
    interpret: bool | None = None,
) -> jax.Array:  # [d_block]
    """Fused scatter-grad + proximal variance-reduced update on one block:
    prox_{eta*g}(w - eta * (scatter(coef * x) + z + lam * w)) in a single
    pass.  Covers the whole regularizer family — lam1 = lam2 = 0 elides
    the prox stages, reproducing :func:`fused_block_update` bit-exactly;
    the prox is elementwise (paper eq. 3), so it stays block-local."""
    interpret = _interpret_default() if interpret is None else interpret
    d = w_block.shape[0]
    out = prox_update(
        w_block[None, :],
        indices,
        values,
        coef[None, :],
        z_block[None, :],
        jnp.asarray(eta, dtype=w_block.dtype)[None, None],
        lam=lam,
        lam1=lam1,
        lam2=lam2,
        interpret=interpret,
    )
    return out[0, :d]


def _i32_scalar(x) -> jax.Array:
    return jnp.asarray(x, dtype=jnp.int32)[None, None]


def lazy_block_catchup(
    w_block: jax.Array,  # [d_block]
    last_block: jax.Array,  # int32[d_block]
    z_block: jax.Array,  # [d_block]
    indices: jax.Array,  # int32[u, nnz_l], block-LOCAL ids
    eta: jax.Array | float,  # UNMASKED step size
    m: jax.Array | int,  # current inner-step index
    stop: jax.Array | int,  # number of active (unmasked) steps this epoch
    *,
    lam: jax.Array | float,  # smooth strength — RUNTIME operand (see kernel)
    lam1: float = 0.0,
    lam2: float = 0.0,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:  # ([d_block], int32[d_block])
    """Exact-lazy catch-up: replay the deferred decay of every feature
    touched at inner step ``m`` (see :mod:`repro.kernels.lazy_update`),
    returning the caught-up block and the updated ``last`` counters."""
    interpret = _interpret_default() if interpret is None else interpret
    d = w_block.shape[0]
    w_out, last_out = lazy_catchup(
        w_block[None, :],
        last_block[None, :],
        z_block[None, :],
        indices,
        jnp.asarray(lam, dtype=w_block.dtype)[None, None],
        jnp.asarray(eta, dtype=w_block.dtype)[None, None],
        _i32_scalar(m),
        _i32_scalar(stop),
        lam1=lam1,
        lam2=lam2,
        interpret=interpret,
    )
    return w_out[0, :d], last_out[0, :d]


def lazy_block_touch_update(
    w_block: jax.Array,  # [d_block], caught up at the touched ids
    indices: jax.Array,  # int32[u, nnz_l], block-LOCAL ids
    values: jax.Array,  # [u, nnz_l]
    coef: jax.Array,  # [u]
    z_block: jax.Array,  # [d_block]
    eta: jax.Array | float,  # masked step size (eta * option mask)
    *,
    lam: float,
    lam1: float = 0.0,
    lam2: float = 0.0,
    interpret: bool | None = None,
) -> jax.Array:  # [d_block]
    """Exact-lazy eager half-step: the dense prox update evaluated only at
    the touched lanes — O(u * nnz_l) instead of O(d_block)."""
    interpret = _interpret_default() if interpret is None else interpret
    d = w_block.shape[0]
    out = lazy_touch_update(
        w_block[None, :],
        indices,
        values,
        coef[None, :],
        z_block[None, :],
        jnp.asarray(eta, dtype=w_block.dtype)[None, None],
        lam=lam,
        lam1=lam1,
        lam2=lam2,
        interpret=interpret,
    )
    return out[0, :d]


def lazy_block_flush(
    w_block: jax.Array,  # [d_block]
    last_block: jax.Array,  # int32[d_block]
    z_block: jax.Array,  # [d_block]
    eta: jax.Array | float,  # UNMASKED step size
    total: jax.Array | int,  # total inner steps M this epoch
    stop: jax.Array | int,  # number of active steps
    *,
    lam: jax.Array | float,  # smooth strength — RUNTIME operand (see kernel)
    lam1: float = 0.0,
    lam2: float = 0.0,
    interpret: bool | None = None,
) -> jax.Array:  # [d_block]
    """Epoch-end reconciliation: replay every feature's deferred steps so
    the block equals the dense iterate after all M inner steps."""
    interpret = _interpret_default() if interpret is None else interpret
    d = w_block.shape[0]
    out = lazy_flush(
        w_block[None, :],
        last_block[None, :],
        z_block[None, :],
        jnp.asarray(lam, dtype=w_block.dtype)[None, None],
        jnp.asarray(eta, dtype=w_block.dtype)[None, None],
        _i32_scalar(total),
        _i32_scalar(stop),
        lam1=lam1,
        lam2=lam2,
        interpret=interpret,
    )
    return out[0, :d]


def lazy_block_proba_update(
    w_block: jax.Array,  # [d_block]
    indices: jax.Array,  # int32[u, nnz_l], block-LOCAL ids
    values: jax.Array,  # [u, nnz_l]
    coef: jax.Array,  # [u]
    z_block: jax.Array,  # [d_block]
    corr_block: jax.Array,  # [d_block] step corrections (step_corrections)
    eta: jax.Array | float,  # masked step size (eta * option mask)
    *,
    lam: float,
    lam1: float = 0.0,
    lam2: float = 0.0,
    interpret: bool | None = None,
) -> jax.Array:  # [d_block]
    """Probabilistic lazy step: touched features only, decay scaled by the
    per-feature corrections so the expected update is unbiased."""
    interpret = _interpret_default() if interpret is None else interpret
    d = w_block.shape[0]
    out = lazy_proba_update(
        w_block[None, :],
        indices,
        values,
        coef[None, :],
        z_block[None, :],
        corr_block[None, :],
        jnp.asarray(eta, dtype=w_block.dtype)[None, None],
        lam=lam,
        lam1=lam1,
        lam2=lam2,
        interpret=interpret,
    )
    return out[0, :d]


def margins_dense(
    w: jax.Array,  # [d]
    data: jax.Array,  # [d, N]
    *,
    block_k: int = 512,
    block_n: int = 256,
    interpret: bool | None = None,
) -> jax.Array:  # [N]
    """S = wᵀD, the full-gradient-phase margins for one feature block."""
    interpret = _interpret_default() if interpret is None else interpret
    d, n = data.shape
    w2 = _pad_to(w[:, None], 0, block_k)
    d2 = _pad_to(_pad_to(data, 0, block_k), 1, block_n)
    out = fd_matvec(w2, d2, block_k=block_k, block_n=block_n, interpret=interpret)
    return out[0, :n]


def loss_and_grad(
    s: jax.Array,  # [N]
    y: jax.Array,  # [N]
    *,
    block: int = 1024,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused logistic loss values + margin derivatives."""
    interpret = _interpret_default() if interpret is None else interpret
    n = s.shape[0]
    s2 = _pad_to(s[None, :], 1, block)
    y2 = _pad_to(y[None, :], 1, block, value=1.0)
    loss, dloss = logistic_grad(s2, y2, block=block, interpret=interpret)
    return loss[0, :n], dloss[0, :n]


def svrg_dense_update(
    w: jax.Array,  # [d]
    g_sparse: jax.Array,  # [d]
    z: jax.Array,  # [d]
    *,
    eta: float,
    lam: float,
    block: int = 2048,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused w' = (1-eta*lam) w - eta (g_sparse + z)   (L2 path)."""
    interpret = _interpret_default() if interpret is None else interpret
    d = w.shape[0]
    w2 = _pad_to(w[None, :], 1, block)
    g2 = _pad_to(g_sparse[None, :], 1, block)
    z2 = _pad_to(z[None, :], 1, block)
    out = svrg_update(w2, g2, z2, eta=eta, lam=lam, block=block, interpret=interpret)
    return out[0, :d]


def decode_attention(
    q: jax.Array,  # [H, Dh] one token's query heads
    k: jax.Array,  # [S, Hkv, Dh] cache
    v: jax.Array,  # [S, Hkv, Dh]
    *,
    length: jax.Array | int,  # valid cache prefix
    scale: float | None = None,
    block_s: int = 512,
    interpret: bool | None = None,
) -> jax.Array:  # [H, Dh]
    """Flash-decoding over the KV cache (one token, GQA)."""
    interpret = _interpret_default() if interpret is None else interpret
    h, dh = q.shape
    s, hkv, _ = k.shape
    assert h % hkv == 0
    group = h // hkv
    scale = dh ** -0.5 if scale is None else scale

    s_pad = s + ((-s) % block_s)
    kp = _pad_to(k, 0, block_s)
    vp = _pad_to(v, 0, block_s)
    bias = jnp.where(jnp.arange(s_pad)[None, :] < length, 0.0, -1e30).astype(
        jnp.float32
    )
    qg = q.reshape(hkv, group, dh)
    out = flash_decode(
        qg, kp, vp, bias, scale=scale, block_s=block_s, interpret=interpret
    )
    return out.reshape(h, dh)


__all__ = [
    "sparse_margins",
    "fused_block_update",
    "fused_block_prox_update",
    "lazy_block_catchup",
    "lazy_block_touch_update",
    "lazy_block_flush",
    "lazy_block_proba_update",
    "step_corrections",
    "margins_dense",
    "loss_and_grad",
    "svrg_dense_update",
    "decode_attention",
    "ref",
]
