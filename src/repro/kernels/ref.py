"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fd_matvec_ref(w: jax.Array, data: jax.Array) -> jax.Array:
    """w: [d, 1], data: [d, N] -> [1, N] float32."""
    return jnp.dot(
        w.astype(jnp.float32).T, data.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def logistic_grad_ref(s: jax.Array, y: jax.Array) -> tuple[jax.Array, jax.Array]:
    s = s.astype(jnp.float32)
    y = y.astype(jnp.float32)
    z = -y * s
    loss = jnp.logaddexp(0.0, z)
    dloss = -y * jax.nn.sigmoid(z)
    return loss, dloss


def sparse_margin_ref(
    w: jax.Array,  # [d_block]
    indices: jax.Array,  # int32[N, nnz_l], block-LOCAL ids
    values: jax.Array,  # [N, nnz_l]
) -> jax.Array:  # [N]
    """Block-local gather-margin: s_i = sum_k w[idx[i,k]] * val[i,k]."""
    return jnp.sum(w[indices] * values, axis=-1)


def fused_update_ref(
    w: jax.Array,  # [d_block]
    indices: jax.Array,  # int32[u, nnz_l], block-LOCAL ids
    values: jax.Array,  # [u, nnz_l]
    coef: jax.Array,  # [u]
    z: jax.Array,  # [d_block]
    eta: jax.Array | float,
    *,
    lam: float,
) -> jax.Array:  # [d_block]
    """Fused scatter-grad + variance-reduced update (L2 family):
    w - eta * (scatter(coef * x) + z + lam * w), in exactly the reference
    association order of the FD-SVRG inner loop."""
    contrib = values * coef[..., None]
    g = (
        jnp.zeros_like(w)
        .at[indices.reshape(-1)]
        .add(contrib.reshape(-1))
    )
    return w - eta * (g + z + lam * w)


def prox_update_ref(
    w: jax.Array,  # [d_block]
    indices: jax.Array,  # int32[u, nnz_l], block-LOCAL ids
    values: jax.Array,  # [u, nnz_l]
    coef: jax.Array,  # [u]
    z: jax.Array,  # [d_block]
    eta: jax.Array | float,
    *,
    lam: float,
    lam1: float,
    lam2: float,
) -> jax.Array:  # [d_block]
    """Fused scatter-grad + proximal VR update, whole regularizer family:
    v = w - eta * (scatter(coef * x) + z + lam * w), then the closed-form
    prox — soft-threshold by eta*lam1, shrink by 1/(1+eta*lam2) — in
    exactly the reference association order of the FD-Prox-SVRG inner
    loop.  lam1 == lam2 == 0 elides the prox stages at trace time,
    reproducing :func:`fused_update_ref` verbatim."""
    contrib = values * coef[..., None]
    g = (
        jnp.zeros_like(w)
        .at[indices.reshape(-1)]
        .add(contrib.reshape(-1))
    )
    v = w - eta * (g + z + lam * w)
    if lam1 != 0.0 or lam2 != 0.0:
        v = jnp.sign(v) * jnp.maximum(jnp.abs(v) - eta * lam1, 0.0)
        if lam2 != 0.0:
            v = v / (1.0 + eta * lam2)
    return v


def svrg_update_ref(
    w: jax.Array, g_sparse: jax.Array, z: jax.Array, *, eta: float, lam: float
) -> jax.Array:
    w = w.astype(jnp.float32)
    return w - eta * (
        g_sparse.astype(jnp.float32) + z.astype(jnp.float32) + lam * w
    )


def flash_decode_ref(
    q: jax.Array,  # [H, Dh]
    k: jax.Array,  # [S, Hkv, Dh]
    v: jax.Array,  # [S, Hkv, Dh]
    *,
    length: int | jax.Array,  # valid prefix of the cache
    scale: float | None = None,
) -> jax.Array:  # [H, Dh]
    """One-token GQA attention over a KV cache (serving hot loop)."""
    h, dh = q.shape
    s, hkv, _ = k.shape
    group = h // hkv
    scale = scale if scale is not None else dh ** -0.5
    qg = q.reshape(hkv, group, dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("kgd,skd->kgs", qg, kf) * scale
    mask = jnp.arange(s)[None, None, :] < length
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("kgs,skd->kgd", p, vf)
    return out.reshape(h, dh)
