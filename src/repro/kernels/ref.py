"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fd_matvec_ref(w: jax.Array, data: jax.Array) -> jax.Array:
    """w: [d, 1], data: [d, N] -> [1, N] float32."""
    return jnp.dot(
        w.astype(jnp.float32).T, data.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def logistic_grad_ref(s: jax.Array, y: jax.Array) -> tuple[jax.Array, jax.Array]:
    s = s.astype(jnp.float32)
    y = y.astype(jnp.float32)
    z = -y * s
    loss = jnp.logaddexp(0.0, z)
    dloss = -y * jax.nn.sigmoid(z)
    return loss, dloss


def sparse_margin_ref(
    w: jax.Array,  # [d_block]
    indices: jax.Array,  # int32[N, nnz_l], block-LOCAL ids
    values: jax.Array,  # [N, nnz_l]
) -> jax.Array:  # [N]
    """Block-local gather-margin: s_i = sum_k w[idx[i,k]] * val[i,k]."""
    return jnp.sum(w[indices] * values, axis=-1)


def fused_update_ref(
    w: jax.Array,  # [d_block]
    indices: jax.Array,  # int32[u, nnz_l], block-LOCAL ids
    values: jax.Array,  # [u, nnz_l]
    coef: jax.Array,  # [u]
    z: jax.Array,  # [d_block]
    eta: jax.Array | float,
    *,
    lam: float,
) -> jax.Array:  # [d_block]
    """Fused scatter-grad + variance-reduced update (L2 family):
    w - eta * (scatter(coef * x) + z + lam * w), in exactly the reference
    association order of the FD-SVRG inner loop."""
    contrib = values * coef[..., None]
    g = (
        jnp.zeros_like(w)
        .at[indices.reshape(-1)]
        .add(contrib.reshape(-1))
    )
    return w - eta * (g + z + lam * w)


def prox_update_ref(
    w: jax.Array,  # [d_block]
    indices: jax.Array,  # int32[u, nnz_l], block-LOCAL ids
    values: jax.Array,  # [u, nnz_l]
    coef: jax.Array,  # [u]
    z: jax.Array,  # [d_block]
    eta: jax.Array | float,
    *,
    lam: float,
    lam1: float,
    lam2: float,
) -> jax.Array:  # [d_block]
    """Fused scatter-grad + proximal VR update, whole regularizer family:
    v = w - eta * (scatter(coef * x) + z + lam * w), then the closed-form
    prox — soft-threshold by eta*lam1, shrink by 1/(1+eta*lam2) — in
    exactly the reference association order of the FD-Prox-SVRG inner
    loop.  lam1 == lam2 == 0 elides the prox stages at trace time,
    reproducing :func:`fused_update_ref` verbatim."""
    contrib = values * coef[..., None]
    g = (
        jnp.zeros_like(w)
        .at[indices.reshape(-1)]
        .add(contrib.reshape(-1))
    )
    v = w - eta * (g + z + lam * w)
    if lam1 != 0.0 or lam2 != 0.0:
        v = jnp.sign(v) * jnp.maximum(jnp.abs(v) - eta * lam1, 0.0)
        if lam2 != 0.0:
            v = v / (1.0 + eta * lam2)
    return v


# ---------------------------------------------------------------------------
# Lazy (delayed-decay) inner steps — see repro.kernels.lazy_update
# ---------------------------------------------------------------------------
#
# The exact variant must be BIT-identical to iterating the dense oracle
# (:func:`prox_update_ref` step after step), so the catch-up below *replays*
# the per-step expression tree instead of using closed forms (a geometric
# decay ``(1 - eta*lam)**k * w`` rounds differently from k explicit steps).
# For a feature untouched at step i the dense scatter contributes exactly
# the +0.0 base, so the replayed step is the dense step with g = 0.0.
#
# Masked steps (Option II tail, eta_m = +0.0) are idempotent after one
# application — the only state they can change is flipping a -0.0 weight to
# +0.0 (w - (-0.0) = +0.0 under round-to-nearest) and normalizing through
# the prox, and a second application is then the identity.  The option mask
# is monotone (a prefix of ones), so a gap of untouched steps decomposes as
# ``k_active`` active replays followed by at most one masked replay.
#
# ``lam`` must reach the replay loop as a RUNTIME scalar, never a baked
# constant.  With a constant 0.0 (the l1 / elastic-net / unregularized
# cases) XLA folds ``lam * w`` away, sees ``eta * g`` as loop-invariant,
# and hoists the pre-rounded product out of the loop — two roundings per
# step.  The dense scan's body keeps the multiply inside the loop (its g
# changes every step) and LLVM contracts ``w - eta*g`` into a single-
# rounding FMA, so the hoisted replay drifts by an ulp on rare inputs.  A
# runtime ``lam`` keeps ``g`` loop-varying and the contraction identical.


def _lazy_step_ref(
    w: jax.Array, z: jax.Array, eta, *, lam, lam1: float, lam2: float
) -> jax.Array:
    """One dense inner step restricted to untouched features (g = +0.0),
    in exactly the dense oracle's association order."""
    g = 0.0 + z  # scatter base + z; never -0.0, so `+ lam*w` below is
    g = g + lam * w  # bitwise `+ zeros_like(w)` when lam == 0.0
    v = w - eta * g
    if lam1 != 0.0 or lam2 != 0.0:
        v = jnp.sign(v) * jnp.maximum(jnp.abs(v) - eta * lam1, 0.0)
        if lam2 != 0.0:
            v = v / (1.0 + eta * lam2)
    return v


def lazy_replay_ref(
    w: jax.Array,  # [L] gathered (or whole-block) weights
    z: jax.Array,  # [L] matching z entries
    eta: jax.Array | float,  # UNMASKED step size
    k_active: jax.Array,  # int32[L] number of active steps to replay
    has_masked: jax.Array,  # bool[L] replay one masked (eta_m = 0) step too
    *,
    lam,  # RUNTIME scalar (see module comment: hoisting vs FMA)
    lam1: float,
    lam2: float,
) -> jax.Array:
    """Replay ``k_active`` untouched active steps, then at most one masked
    step — the exact catch-up primitive shared by kernels and references."""

    def body(i, cur):
        stepped = _lazy_step_ref(cur, z, eta, lam=lam, lam1=lam1, lam2=lam2)
        return jnp.where(i < k_active, stepped, cur)

    w = jax.lax.fori_loop(0, jnp.max(k_active, initial=0), body, w)
    masked = _lazy_step_ref(w, z, eta * 0.0, lam=lam, lam1=lam1, lam2=lam2)
    return jnp.where(has_masked, masked, w)


def _first_occurrence(flat: jax.Array) -> jax.Array:
    """first[e] = smallest lane index holding the same feature id as lane e.

    Accumulating per-feature gradient contributions at first-occurrence
    lanes **in flat order** reproduces the dense scatter-add's per-slot
    accumulation order, hence its floating point, without materializing
    the dense block.  O(L^2) compare, L = u * nnz_l (tiny on the sparse
    hot path this family exists for)."""
    return jnp.argmax(flat[:, None] == flat[None, :], axis=1)


def lazy_catchup_ref(
    w: jax.Array,  # [d_block]
    last: jax.Array,  # int32[d_block] steps already applied per feature
    z: jax.Array,  # [d_block]
    indices: jax.Array,  # int32[u, nnz_l] ids touched at step ``step``
    eta: jax.Array | float,  # UNMASKED step size
    step: jax.Array,  # int32 current inner-step index m
    stop: jax.Array,  # int32 number of active (unmasked) steps this epoch
    *,
    lam,  # RUNTIME scalar (see module comment: hoisting vs FMA)
    lam1: float,
    lam2: float,
) -> tuple[jax.Array, jax.Array]:
    """Bring every feature touched at inner step ``step`` up to date by
    replaying its deferred steps ``last[j] .. step-1``; marks them as
    updated through ``step`` (the eager touch update follows)."""
    flat = indices.reshape(-1)
    ll = last[flat]
    k_active = jnp.maximum(jnp.minimum(stop, step) - ll, 0)
    has_masked = (step - ll) > k_active
    wl = lazy_replay_ref(
        w[flat], z[flat], eta, k_active, has_masked, lam=lam, lam1=lam1,
        lam2=lam2,
    )
    return w.at[flat].set(wl), last.at[flat].set(step + 1)


def lazy_touch_update_ref(
    w: jax.Array,  # [d_block], caught up at the touched ids
    indices: jax.Array,  # int32[u, nnz_l]
    values: jax.Array,  # [u, nnz_l]
    coef: jax.Array,  # [u]
    z: jax.Array,  # [d_block]
    eta: jax.Array | float,  # masked step size eta * mask[m]
    *,
    lam: float,
    lam1: float,
    lam2: float,
) -> jax.Array:
    """The dense prox update evaluated only at the touched ids: O(u * nnz_l)
    work, bit-identical at those ids to :func:`prox_update_ref`."""
    flat = indices.reshape(-1)
    contrib = (values * coef[..., None]).reshape(-1)
    first = _first_occurrence(flat)
    g = jnp.zeros_like(contrib).at[first].add(contrib)
    wl = w[flat]
    g = g + z[flat]
    g = g + lam * wl
    v = wl - eta * g
    if lam1 != 0.0 or lam2 != 0.0:
        v = jnp.sign(v) * jnp.maximum(jnp.abs(v) - eta * lam1, 0.0)
        if lam2 != 0.0:
            v = v / (1.0 + eta * lam2)
    return w.at[flat].set(v[first])


def lazy_flush_ref(
    w: jax.Array,  # [d_block]
    last: jax.Array,  # int32[d_block]
    z: jax.Array,  # [d_block]
    eta: jax.Array | float,  # UNMASKED step size
    total: jax.Array,  # int32 total inner steps M this epoch
    stop: jax.Array,  # int32 number of active steps
    *,
    lam,  # RUNTIME scalar (see module comment: hoisting vs FMA)
    lam1: float,
    lam2: float,
) -> jax.Array:
    """Epoch-end reconciliation: replay every feature's deferred steps so
    the returned block equals the dense iterate after all M steps."""
    k_active = jnp.maximum(jnp.minimum(stop, total) - last, 0)
    has_masked = (total - last) > k_active
    return lazy_replay_ref(
        w, z, eta, k_active, has_masked, lam=lam, lam1=lam1, lam2=lam2
    )


def lazy_proba_update_ref(
    w: jax.Array,  # [d_block]
    indices: jax.Array,  # int32[u, nnz_l]
    values: jax.Array,  # [u, nnz_l]
    coef: jax.Array,  # [u]
    z: jax.Array,  # [d_block]
    corr: jax.Array,  # [d_block] per-feature step corrections (>= 1)
    eta: jax.Array | float,  # masked step size eta * mask[m]
    *,
    lam: float,
    lam1: float,
    lam2: float,
) -> jax.Array:
    """Probabilistic (unbiased) lazy step: only touched features move, but
    their deterministic decay — the ``z + lam*w`` drift and the prox
    strengths — is scaled by ``corr[j] = 1 / P(j touched per step)`` so the
    per-step expected update matches the dense oracle's deterministic part.
    No flush needed: ``w`` is always this algorithm's materialized iterate."""
    flat = indices.reshape(-1)
    contrib = (values * coef[..., None]).reshape(-1)
    first = _first_occurrence(flat)
    g = jnp.zeros_like(contrib).at[first].add(contrib)
    wl = w[flat]
    cl = corr[flat]
    v = wl - eta * (g + cl * (z[flat] + lam * wl))
    if lam1 != 0.0 or lam2 != 0.0:
        v = jnp.sign(v) * jnp.maximum(jnp.abs(v) - eta * lam1 * cl, 0.0)
        if lam2 != 0.0:
            v = v / (1.0 + eta * lam2 * cl)
    return w.at[flat].set(v[first])


def svrg_update_ref(
    w: jax.Array, g_sparse: jax.Array, z: jax.Array, *, eta: float, lam: float
) -> jax.Array:
    w = w.astype(jnp.float32)
    return w - eta * (
        g_sparse.astype(jnp.float32) + z.astype(jnp.float32) + lam * w
    )


def flash_decode_ref(
    q: jax.Array,  # [H, Dh]
    k: jax.Array,  # [S, Hkv, Dh]
    v: jax.Array,  # [S, Hkv, Dh]
    *,
    length: int | jax.Array,  # valid prefix of the cache
    scale: float | None = None,
) -> jax.Array:  # [H, Dh]
    """One-token GQA attention over a KV cache (serving hot loop)."""
    h, dh = q.shape
    s, hkv, _ = k.shape
    group = h // hkv
    scale = scale if scale is not None else dh ** -0.5
    qg = q.reshape(hkv, group, dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("kgd,skd->kgs", qg, kf) * scale
    mask = jnp.arange(s)[None, None, :] < length
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("kgs,skd->kgd", p, vf)
    return out.reshape(h, dh)
