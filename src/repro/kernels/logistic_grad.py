"""Pallas TPU kernel: fused logistic loss + margin-derivative.

FD-SVRG evaluates, for every sampled instance, both the loss value (for
monitoring) and the derivative w.r.t. the margin (for the update).  Doing
the two in one VMEM pass halves the HBM traffic of the elementwise stage;
on the (N up to 19M)-sized margin vectors of the full-gradient phase this
stage is bandwidth-bound, so the fusion is a straight 2x on paper.

Elementwise over a [1, N] layout with (1, block) tiles (the TPU vector
unit wants the trailing dim on lanes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _logistic_kernel(s_ref, y_ref, loss_ref, dloss_ref):
    s = s_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    z = -y * s
    # log(1+e^z) stably, and its derivative -y*sigmoid(z), sharing the exp.
    zpos = jnp.maximum(z, 0.0)
    ez = jnp.exp(z - zpos)  # e^{z-max(z,0)} in (0, 1]
    e0 = jnp.exp(-zpos)  # e^{-max(z,0)}
    loss_ref[...] = zpos + jnp.log(e0 + ez)
    dloss_ref[...] = -y * (ez / (e0 + ez))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def logistic_grad(
    s: jax.Array,  # [1, N]
    y: jax.Array,  # [1, N]
    *,
    block: int = 1024,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    one, n = s.shape
    assert one == 1 and s.shape == y.shape
    assert n % block == 0, "caller pads to tile multiples"
    grid = (n // block,)
    return pl.pallas_call(
        _logistic_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n), jnp.float32),
            jax.ShapeDtypeStruct((1, n), jnp.float32),
        ],
        interpret=interpret,
    )(s, y)
