"""Pallas TPU kernel: fused scatter-grad + variance-reduced SVRG update.

Algorithm 1 line 11, per worker and per inner step:

    g^(l)  = sum_i coef_i * x^(l)_i          (scatter over local ids)
    w^(l)' = w^(l) - eta * (g^(l) + z^(l) + lam * w^(l))

Unfused this is three sweeps over the d/q-sized block per step — densify
the sparse gradient, add the cached full gradient, axpy the regularized
update — each reading and writing HBM.  The kernel keeps the block
resident in VMEM (see sparse_margin.py for the d/q sizing argument) and
does one read of each operand and one write: scatter-accumulate the u
sampled rows into a fresh accumulator, then the fused elementwise update.

``eta`` arrives as a runtime (1, 1) scalar because Option II masks the
step size per inner step (eta * mask_m) and the kernel must not retrace
per step; ``lam`` is a compile-time constant of the run.  This kernel
covers the smooth L2 family (lam = 0 covers "none"); the drivers route
through :mod:`repro.kernels.prox_update`, which extends the same pass
with the block-local prox for L1 / elastic-net and reproduces this
kernel's expression tree bit-exactly when both prox strengths are 0.

``interpret=True`` (CPU) is the numerics contract: the scatter and the
update are computed with exactly the reference's jnp expression tree —
``w - eta * ((g + z) + lam * w)`` in that association order — so the
``use_kernels`` path is bit-identical to the reference path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_update_kernel(lam: float, w_ref, idx_ref, val_ref, coef_ref,
                         z_ref, eta_ref, out_ref):
    w = w_ref[0, :]  # [d_block]
    contrib = val_ref[...] * coef_ref[0, :][:, None]  # [u, nnz_l]
    g = (
        jnp.zeros_like(w)
        .at[idx_ref[...].reshape(-1)]
        .add(contrib.reshape(-1))
    )
    eta = eta_ref[0, 0]
    out_ref[0, :] = w - eta * (g + z_ref[0, :] + lam * w)


@functools.partial(jax.jit, static_argnames=("lam", "interpret"))
def fused_update(
    w: jax.Array,  # [1, d_block]
    indices: jax.Array,  # int32[u, nnz_l], local ids
    values: jax.Array,  # [u, nnz_l]
    coef: jax.Array,  # [1, u]
    z: jax.Array,  # [1, d_block]
    eta: jax.Array,  # [1, 1] runtime step size (eta * option mask)
    *,
    lam: float,
    interpret: bool = False,
) -> jax.Array:  # [1, d_block] float32
    one, d_block = w.shape
    assert one == 1 and z.shape == w.shape
    u, nnz = indices.shape
    assert values.shape == (u, nnz) and coef.shape == (1, u)
    assert eta.shape == (1, 1)

    # Single grid step: the whole block stays VMEM-resident, which is the
    # point — scatter targets cannot be tiled without cross-tile traffic.
    spec_vec = pl.BlockSpec((1, d_block), lambda: (0, 0))
    spec_rows = pl.BlockSpec((u, nnz), lambda: (0, 0))
    return pl.pallas_call(
        functools.partial(_fused_update_kernel, lam),
        grid=(),
        in_specs=[
            spec_vec,
            spec_rows,
            spec_rows,
            pl.BlockSpec((1, u), lambda: (0, 0)),
            spec_vec,
            pl.BlockSpec((1, 1), lambda: (0, 0)),
        ],
        out_specs=spec_vec,
        out_shape=jax.ShapeDtypeStruct((1, d_block), jnp.float32),
        interpret=interpret,
    )(w, indices, values, coef, z, eta)
