"""Pallas TPU kernel: fused SVRG parameter update (Algorithm 1 line 11).

    w' = w - eta * (g_sparse + z + lam * w)
       = (1 - eta*lam) * w - eta * (g_sparse + z)

where ``g_sparse`` is the densified data-dependent part
(phi'(w̃ᵀx)-phi'(w̃₀ᵀx))·x of the variance-reduced gradient, ``z`` the
cached full gradient and ``lam*w`` the L2 regularizer gradient.  Unfused,
XLA emits three passes over the d-sized vectors (two adds, one axpy); the
kernel does one read of each operand and one write — the inner loop is
bandwidth-bound at d up to 29.9M, so this is the dominant-term fusion.

eta/lam are compile-time constants of the run (the paper uses a fixed
step size), closed over at trace time.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _svrg_update_kernel(eta: float, lam: float, w_ref, g_ref, z_ref, out_ref):
    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    z = z_ref[...].astype(jnp.float32)
    out_ref[...] = (1.0 - eta * lam) * w - eta * (g + z)


@functools.partial(jax.jit, static_argnames=("eta", "lam", "block", "interpret"))
def svrg_update(
    w: jax.Array,  # [1, d]
    g_sparse: jax.Array,  # [1, d]
    z: jax.Array,  # [1, d]
    *,
    eta: float,
    lam: float,
    block: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    one, d = w.shape
    assert one == 1 and w.shape == g_sparse.shape == z.shape
    assert d % block == 0, "caller pads to tile multiples"
    grid = (d // block,)
    spec = pl.BlockSpec((1, block), lambda i: (0, i))
    return pl.pallas_call(
        functools.partial(_svrg_update_kernel, eta, lam),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(w, g_sparse, z)
