"""Pallas TPU kernel: blocked feature-shard margin GEMM  S = wᵀ·D.

This is the compute hot-spot of FD-SVRG's full-gradient phase (Algorithm 1
lines 3-5): every outer iteration each worker computes its partial margins
``w^(l)T D^(l)`` over *all* N instances.  On TPU the per-block data is laid
out as a dense [d_block, N] matrix (text sparsity is exploited at the
partition level — see DESIGN.md) so this phase is a skinny GEMM that
should run on the MXU from VMEM tiles.

Tiling: grid = (N / block_n, d / block_k), the k-dimension innermost so a
given output tile stays resident in VMEM while partial products accumulate
into it.  Block shapes default to (512, 256) — k a multiple of the 128-wide
MXU systolic dimension, n a multiple of the lane width — giving a working
set of 512*256*4B (D tile) + 512*4B (w tile) + 256*4B (out tile) ≈ 527 KB,
comfortably inside the ~16 MB v5e VMEM even with double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fd_matvec_kernel(w_ref, d_ref, out_ref):
    """One (n, k) grid step: out[0, n-tile] += w[k-tile,0]ᵀ · D[k-tile, n-tile]."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jax.lax.dot_general(
        w_ref[...],
        d_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),  # contract the d axis
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit, static_argnames=("block_k", "block_n", "interpret")
)
def fd_matvec(
    w: jax.Array,  # [d, 1]
    data: jax.Array,  # [d, N]
    *,
    block_k: int = 512,
    block_n: int = 256,
    interpret: bool = False,
) -> jax.Array:  # [1, N] float32
    d, one = w.shape
    assert one == 1, "w must be [d, 1]"
    d2, n = data.shape
    assert d == d2, f"shape mismatch {w.shape} vs {data.shape}"
    assert d % block_k == 0 and n % block_n == 0, "caller pads to tile multiples"

    grid = (n // block_n, d // block_k)
    return pl.pallas_call(
        _fd_matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_k, 1), lambda i, k: (k, 0)),
            pl.BlockSpec((block_k, block_n), lambda i, k: (k, i)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i, k: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(w, data)
