"""Pallas TPU kernels: lazy (delayed-decay) FD-SVRG inner steps.

The dense inner step (:mod:`repro.kernels.prox_update`) densifies the full
per-worker block every sample — O(d_block) work per inner step even when a
news20 row touches ~0.02% of features.  This family defers the regularizer
/ z-drift decay of untouched features and restores O(u * nnz_l) inner-step
work, in two flavors:

* **exact** — three kernels cooperating with a per-feature ``last`` counter
  (number of inner steps already applied):

    - ``lazy_catchup``: before the margins of step m are read, replay each
      *touched* feature's deferred steps ``last[j] .. m-1`` (g = +0.0 for
      an untouched feature, so each replayed step is the dense step with a
      zero data gradient) and stamp ``last[j] = m+1``;
    - ``lazy_touch_update``: the dense prox update evaluated only at the
      touched lanes (first-occurrence scatter keeps the dense per-feature
      accumulation order);
    - ``lazy_flush``: epoch-end reconciliation replaying every feature's
      remaining deferred steps, so snapshots / objectives / meters are
      computed on the fully-materialized iterate.

  Replay — not closed forms — because the contract is BIT-identity to the
  iterated dense oracle: ``(1 - eta*lam)**k * w`` rounds differently from
  k explicit steps.  The Option II mask is a monotone prefix of ones, so a
  gap decomposes as ``k_active`` active replays plus at most one masked
  (eta_m = +0.0) replay, which is idempotent.

* **probabilistic** — one kernel, ``lazy_proba_update``: only touched
  features move, with the deterministic decay scaled by the per-feature
  correction ``corr[j] = 1 / P(j touched per step)`` (``step_corrections``
  below, fed by ``BlockCSR.nnz_col``) so the expected per-step update
  matches the dense oracle's deterministic part.  No counter, no flush.

Both variants are block-local — they read only ``w^(l)``/``z^(l)`` and the
block's own rows, so they add **zero communication** to Algorithm 1.

``lam1``/``lam2`` are compile-time constants (as in prox_update);
``eta``/``m``/``stop`` arrive as runtime (1, 1) scalars.  The smooth
strength ``lam`` is ALSO a runtime (1, 1) scalar in the two replaying
kernels (``lazy_catchup``/``lazy_flush``) — baking it in would let XLA
hoist the loop-invariant ``eta * g`` out of the replay loop, pre-rounding
the product the dense scan computes as an in-loop FMA (see the comment in
:mod:`repro.kernels.ref`); the single-application kernels keep it static
like the dense fused kernels.  The kernel bodies execute the reference
expression functions from :mod:`repro.kernels.ref` verbatim — that
sharing *is* the numerics contract, and ``interpret=True`` (CPU) asserts
it bit-for-bit in the tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref


def step_corrections(
    nnz_col: jax.Array,  # int32[d_block] rows storing a nonzero per feature
    n: int,  # total instances
    u: int = 1,  # mini-batch size
    dtype=jnp.float32,
) -> jax.Array:  # [d_block]
    """Per-feature probabilistic step corrections 1 / P(touched per step).

    A feature stored by ``nnz_col[j]`` of the n rows is touched by a
    uniform u-row mini-batch with probability ``p = 1 - (1 - nnz_col/n)^u``
    (``= nnz_col/n`` for u = 1, the classic ``N/nnz_col(j)`` correction).
    Features stored by no row (nnz_col = 0) are never touched, so their
    correction is irrelevant; it is pinned to 1.0 to keep the vector
    finite."""
    p1 = nnz_col.astype(dtype) / dtype(n)
    p = 1.0 - (1.0 - p1) ** u
    safe = jnp.where(nnz_col > 0, p, dtype(1.0))
    return (1.0 / safe).astype(dtype)


def _catchup_kernel(lam1, lam2, w_ref, last_ref, z_ref, idx_ref,
                    lam_ref, eta_ref, m_ref, stop_ref, w_out, last_out):
    w = w_ref[0, :]
    last = last_ref[0, :]
    flat = idx_ref[...].reshape(-1)
    lam = lam_ref[0, 0]
    eta = eta_ref[0, 0]
    m = m_ref[0, 0]
    stop = stop_ref[0, 0]
    ll = last[flat]
    k_active = jnp.maximum(jnp.minimum(stop, m) - ll, 0)
    has_masked = (m - ll) > k_active
    wl = ref.lazy_replay_ref(
        w[flat], z_ref[0, :][flat], eta, k_active, has_masked,
        lam=lam, lam1=lam1, lam2=lam2,
    )
    w_out[0, :] = w.at[flat].set(wl)
    last_out[0, :] = last.at[flat].set(m + 1)


@functools.partial(jax.jit, static_argnames=("lam1", "lam2", "interpret"))
def lazy_catchup(
    w: jax.Array,  # [1, d_block]
    last: jax.Array,  # int32[1, d_block]
    z: jax.Array,  # [1, d_block]
    indices: jax.Array,  # int32[u, nnz_l], local ids
    lam: jax.Array,  # [1, 1] smooth strength (runtime: see module docstring)
    eta: jax.Array,  # [1, 1] UNMASKED step size
    m: jax.Array,  # int32[1, 1] current inner-step index
    stop: jax.Array,  # int32[1, 1] number of active steps this epoch
    *,
    lam1: float,
    lam2: float,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    one, d_block = w.shape
    assert one == 1 and z.shape == w.shape and last.shape == w.shape
    u, nnz = indices.shape
    assert lam.shape == (1, 1) and eta.shape == (1, 1)
    assert m.shape == (1, 1) and stop.shape == (1, 1)

    spec_vec = pl.BlockSpec((1, d_block), lambda: (0, 0))
    spec_scalar = pl.BlockSpec((1, 1), lambda: (0, 0))
    return pl.pallas_call(
        functools.partial(_catchup_kernel, lam1, lam2),
        grid=(),
        in_specs=[
            spec_vec,
            spec_vec,
            spec_vec,
            pl.BlockSpec((u, nnz), lambda: (0, 0)),
            spec_scalar,
            spec_scalar,
            spec_scalar,
            spec_scalar,
        ],
        out_specs=[spec_vec, spec_vec],
        out_shape=[
            jax.ShapeDtypeStruct((1, d_block), jnp.float32),
            jax.ShapeDtypeStruct((1, d_block), jnp.int32),
        ],
        interpret=interpret,
    )(w, last, z, indices, lam, eta, m, stop)


def _touch_update_kernel(lam, lam1, lam2, w_ref, idx_ref, val_ref, coef_ref,
                         z_ref, eta_ref, out_ref):
    out_ref[0, :] = ref.lazy_touch_update_ref(
        w_ref[0, :], idx_ref[...], val_ref[...], coef_ref[0, :], z_ref[0, :],
        eta_ref[0, 0], lam=lam, lam1=lam1, lam2=lam2,
    )


@functools.partial(jax.jit, static_argnames=("lam", "lam1", "lam2", "interpret"))
def lazy_touch_update(
    w: jax.Array,  # [1, d_block], caught up at the touched ids
    indices: jax.Array,  # int32[u, nnz_l]
    values: jax.Array,  # [u, nnz_l]
    coef: jax.Array,  # [1, u]
    z: jax.Array,  # [1, d_block]
    eta: jax.Array,  # [1, 1] masked step size (eta * option mask)
    *,
    lam: float,
    lam1: float,
    lam2: float,
    interpret: bool = False,
) -> jax.Array:  # [1, d_block] float32
    one, d_block = w.shape
    assert one == 1 and z.shape == w.shape
    u, nnz = indices.shape
    assert values.shape == (u, nnz) and coef.shape == (1, u)
    assert eta.shape == (1, 1)

    spec_vec = pl.BlockSpec((1, d_block), lambda: (0, 0))
    spec_rows = pl.BlockSpec((u, nnz), lambda: (0, 0))
    return pl.pallas_call(
        functools.partial(_touch_update_kernel, lam, lam1, lam2),
        grid=(),
        in_specs=[
            spec_vec,
            spec_rows,
            spec_rows,
            pl.BlockSpec((1, u), lambda: (0, 0)),
            spec_vec,
            pl.BlockSpec((1, 1), lambda: (0, 0)),
        ],
        out_specs=spec_vec,
        out_shape=jax.ShapeDtypeStruct((1, d_block), jnp.float32),
        interpret=interpret,
    )(w, indices, values, coef, z, eta)


def _flush_kernel(lam1, lam2, w_ref, last_ref, z_ref, lam_ref, eta_ref,
                  total_ref, stop_ref, out_ref):
    out_ref[0, :] = ref.lazy_flush_ref(
        w_ref[0, :], last_ref[0, :], z_ref[0, :], eta_ref[0, 0],
        total_ref[0, 0], stop_ref[0, 0], lam=lam_ref[0, 0], lam1=lam1,
        lam2=lam2,
    )


@functools.partial(jax.jit, static_argnames=("lam1", "lam2", "interpret"))
def lazy_flush(
    w: jax.Array,  # [1, d_block]
    last: jax.Array,  # int32[1, d_block]
    z: jax.Array,  # [1, d_block]
    lam: jax.Array,  # [1, 1] smooth strength (runtime: see module docstring)
    eta: jax.Array,  # [1, 1] UNMASKED step size
    total: jax.Array,  # int32[1, 1] total inner steps M
    stop: jax.Array,  # int32[1, 1] number of active steps
    *,
    lam1: float,
    lam2: float,
    interpret: bool = False,
) -> jax.Array:  # [1, d_block] float32
    one, d_block = w.shape
    assert one == 1 and z.shape == w.shape and last.shape == w.shape
    assert lam.shape == (1, 1) and eta.shape == (1, 1)
    assert total.shape == (1, 1) and stop.shape == (1, 1)

    spec_vec = pl.BlockSpec((1, d_block), lambda: (0, 0))
    spec_scalar = pl.BlockSpec((1, 1), lambda: (0, 0))
    return pl.pallas_call(
        functools.partial(_flush_kernel, lam1, lam2),
        grid=(),
        in_specs=[spec_vec, spec_vec, spec_vec, spec_scalar, spec_scalar,
                  spec_scalar, spec_scalar],
        out_specs=spec_vec,
        out_shape=jax.ShapeDtypeStruct((1, d_block), jnp.float32),
        interpret=interpret,
    )(w, last, z, lam, eta, total, stop)


def _proba_update_kernel(lam, lam1, lam2, w_ref, idx_ref, val_ref, coef_ref,
                         z_ref, corr_ref, eta_ref, out_ref):
    out_ref[0, :] = ref.lazy_proba_update_ref(
        w_ref[0, :], idx_ref[...], val_ref[...], coef_ref[0, :], z_ref[0, :],
        corr_ref[0, :], eta_ref[0, 0], lam=lam, lam1=lam1, lam2=lam2,
    )


@functools.partial(jax.jit, static_argnames=("lam", "lam1", "lam2", "interpret"))
def lazy_proba_update(
    w: jax.Array,  # [1, d_block]
    indices: jax.Array,  # int32[u, nnz_l]
    values: jax.Array,  # [u, nnz_l]
    coef: jax.Array,  # [1, u]
    z: jax.Array,  # [1, d_block]
    corr: jax.Array,  # [1, d_block] step corrections (step_corrections)
    eta: jax.Array,  # [1, 1] masked step size (eta * option mask)
    *,
    lam: float,
    lam1: float,
    lam2: float,
    interpret: bool = False,
) -> jax.Array:  # [1, d_block] float32
    one, d_block = w.shape
    assert one == 1 and z.shape == w.shape and corr.shape == w.shape
    u, nnz = indices.shape
    assert values.shape == (u, nnz) and coef.shape == (1, u)
    assert eta.shape == (1, 1)

    spec_vec = pl.BlockSpec((1, d_block), lambda: (0, 0))
    spec_rows = pl.BlockSpec((u, nnz), lambda: (0, 0))
    return pl.pallas_call(
        functools.partial(_proba_update_kernel, lam, lam1, lam2),
        grid=(),
        in_specs=[
            spec_vec,
            spec_rows,
            spec_rows,
            pl.BlockSpec((1, u), lambda: (0, 0)),
            spec_vec,
            spec_vec,
            pl.BlockSpec((1, 1), lambda: (0, 0)),
        ],
        out_specs=spec_vec,
        out_shape=jax.ShapeDtypeStruct((1, d_block), jnp.float32),
        interpret=interpret,
    )(w, indices, values, coef, z, corr, eta)
