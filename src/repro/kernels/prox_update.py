"""Pallas TPU kernel: fused scatter-grad + proximal variance-reduced update.

FD-Prox-SVRG inner step (Algorithm 1 line 11 + the block-local prox),
per worker and per inner step:

    g^(l)  = sum_i coef_i * x^(l)_i                       (local scatter)
    v^(l)  = w^(l) - eta * (g^(l) + z^(l) + lam * w^(l))  (smooth part)
    w^(l)' = prox_{eta*g_ns}(v^(l))                       (block-local prox)

with ``lam`` the smooth L2 coefficient (the classic path), ``lam1`` the
L1 strength and ``lam2`` the elastic-net L2 strength handled in closed
form: soft-threshold by ``eta*lam1`` then shrink by ``1/(1+eta*lam2)``.
Because g decomposes over feature blocks (paper eq. 3) the prox is
elementwise — it fuses into the same single VMEM-resident pass as the
scatter and the update, and costs zero extra communication.

``lam``/``lam1``/``lam2`` are compile-time constants of the run; ``eta``
arrives as a runtime (1, 1) scalar because Option II masks the step size
per inner step.  When ``lam1 == lam2 == 0`` the prox stages are elided at
trace time, leaving exactly the expression tree of
:mod:`repro.kernels.fused_update` — so the L2 family keeps its historical
bit-identity, and one kernel covers the whole regularizer family.

``interpret=True`` (CPU) is the numerics contract: scatter, update, and
prox are computed with exactly the reference's jnp expression tree
(``sign(v) * max(|v| - eta*lam1, 0)``, then the division only when
``lam2 != 0``), so the ``use_kernels`` path is bit-identical to the
reference path for every regularizer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _prox_update_kernel(lam: float, lam1: float, lam2: float, w_ref, idx_ref,
                        val_ref, coef_ref, z_ref, eta_ref, out_ref):
    w = w_ref[0, :]  # [d_block]
    contrib = val_ref[...] * coef_ref[0, :][:, None]  # [u, nnz_l]
    g = (
        jnp.zeros_like(w)
        .at[idx_ref[...].reshape(-1)]
        .add(contrib.reshape(-1))
    )
    eta = eta_ref[0, 0]
    v = w - eta * (g + z_ref[0, :] + lam * w)
    if lam1 != 0.0 or lam2 != 0.0:
        # losses.soft_threshold, verbatim — the shared numerics contract.
        v = jnp.sign(v) * jnp.maximum(jnp.abs(v) - eta * lam1, 0.0)
        if lam2 != 0.0:
            v = v / (1.0 + eta * lam2)
    out_ref[0, :] = v


@functools.partial(jax.jit, static_argnames=("lam", "lam1", "lam2", "interpret"))
def prox_update(
    w: jax.Array,  # [1, d_block]
    indices: jax.Array,  # int32[u, nnz_l], local ids
    values: jax.Array,  # [u, nnz_l]
    coef: jax.Array,  # [1, u]
    z: jax.Array,  # [1, d_block]
    eta: jax.Array,  # [1, 1] runtime step size (eta * option mask)
    *,
    lam: float,
    lam1: float,
    lam2: float,
    interpret: bool = False,
) -> jax.Array:  # [1, d_block] float32
    one, d_block = w.shape
    assert one == 1 and z.shape == w.shape
    u, nnz = indices.shape
    assert values.shape == (u, nnz) and coef.shape == (1, u)
    assert eta.shape == (1, 1)

    # Single grid step: the whole block stays VMEM-resident (see
    # fused_update.py) — the prox adds two elementwise VPU stages to the
    # same pass, not another sweep over HBM.
    spec_vec = pl.BlockSpec((1, d_block), lambda: (0, 0))
    spec_rows = pl.BlockSpec((u, nnz), lambda: (0, 0))
    return pl.pallas_call(
        functools.partial(_prox_update_kernel, lam, lam1, lam2),
        grid=(),
        in_specs=[
            spec_vec,
            spec_rows,
            spec_rows,
            pl.BlockSpec((1, u), lambda: (0, 0)),
            spec_vec,
            pl.BlockSpec((1, 1), lambda: (0, 0)),
        ],
        out_specs=spec_vec,
        out_shape=jax.ShapeDtypeStruct((1, d_block), jnp.float32),
        interpret=interpret,
    )(w, indices, values, coef, z, eta)
