"""Pallas TPU kernel: fused sparse gather-margin over block-local CSR rows.

The FD-SVRG hot path (Algorithm 1 lines 4 and 9) is, per worker,

    s^(l)_i = sum_k w^(l)[idx[i, k]] * val[i, k]

over block-LOCAL padded rows (:class:`repro.data.block_csr.BlockCSR`) —
no membership mask, no id rebasing.  The masked global-CSR formulation
this replaces did an O(nnz_max) compare/where/gather chain per worker per
row; here the rows are already O(nnz_max/q) wide and the kernel fuses
gather, multiply, and the lane reduction into one VMEM-resident pass.

Layout: the whole w block stays resident in VMEM across the row grid —
the payoff of the block-local layout is that d/q * 4 B fits VMEM even at
the paper's d = 29.9M once q is a pod-slice worth of chips (e.g.
d/q ≈ 470k floats ≈ 1.9 MB at q = 64).  Rows are tiled by ``block_rows``;
the gather lowers through Mosaic's dynamic-gather path (one-hot MXU
matmul on older toolchains).  ``interpret=True`` executes the same
arithmetic with jnp on CPU — that mode is the numerics contract: each
row's product+sum is computed exactly like the jnp reference, so iterates
are bit-identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sparse_margin_kernel(w_ref, idx_ref, val_ref, out_ref):
    """One row tile: out[0, rows] = sum_k w[idx[rows, k]] * val[rows, k]."""
    w = w_ref[0, :]  # [d_block], VMEM-resident across the grid
    gathered = w[idx_ref[...]]  # [block_rows, nnz_l]
    out_ref[...] = jnp.sum(gathered * val_ref[...], axis=-1)[None, :]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def sparse_margin(
    w: jax.Array,  # [1, d_block]
    indices: jax.Array,  # int32[N, nnz_l], local ids
    values: jax.Array,  # [N, nnz_l]
    *,
    block_rows: int,
    interpret: bool = False,
) -> jax.Array:  # [1, N] float32
    one, d_block = w.shape
    assert one == 1, "w must be [1, d_block]"
    n, nnz = indices.shape
    assert values.shape == (n, nnz), f"{values.shape} vs {indices.shape}"
    assert n % block_rows == 0, "caller pads rows to tile multiples"

    grid = (n // block_rows,)
    return pl.pallas_call(
        _sparse_margin_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d_block), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, nnz), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, nnz), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_rows), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(w, indices, values)
