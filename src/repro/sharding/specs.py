"""Logical-axis sharding rules (the feature-distributed principle, applied).

The paper's insight — partition parameters along *feature* dimensions so
that cross-worker communication is activation reductions (inner products)
rather than parameter/gradient vectors — generalizes to every architecture
in the pool as Megatron-style tensor parallelism over the ``model`` mesh
axis.  This module is the single source of truth for which logical axis of
which tensor carries that partition.

Rules are expressed MaxText-style: tensors are annotated with logical axis
names; ``spec()`` resolves them against the current mesh (axes absent from
the mesh resolve to replication, so one model definition serves the
single-pod (data, model), the multi-pod (pod, data, model), and the
single-device test meshes unchanged).

Parameter master/optimizer state is additionally sharded over the data
axes (ZeRO-1): see ``param_spec(zero1=True)``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axes (tuples mean "sharded over both, major first")
RULES: dict[str, tuple[str, ...] | str | None] = {
    "batch": ("pod", "data"),
    "seq": None,               # sequence stays unsharded between layers (baseline);
    "seq_kv": "model",         # decode KV cache: sequence split-K over model
                               # (long_500k overrides to ("data","model"))
    "embed": None,             # d_model replicated (Megatron TP pattern)
    "heads": "model",          # q heads  — the feature partition in attention
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",            # FFN hidden — the feature partition in MLPs
    "experts": "model",        # expert parallelism
    "expert_mlp": None,
    "vocab": "model",          # LM head / embedding feature partition
    "ssm_inner": "model",      # SSD inner channels — feature partition for SSMs
    "ssm_heads": "model",      # SSD head axis
    "ssm_state": None,
    "conv_width": None,
    "codebooks": None,
    "patches": None,
    "zero1": ("pod", "data"),  # extra partition for master params/opt state
}


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    """Resolves logical axis names against a mesh; no-ops when mesh is None."""

    mesh: Mesh | None
    rules: dict = dataclasses.field(default_factory=lambda: dict(RULES))
    # when False, constraints become identity (single-device smoke tests)
    enable: bool = True

    def _resolve_one(self, name: str | None):
        if name is None:
            return None
        mapped = self.rules.get(name, None)
        if mapped is None:
            return None
        axes = (mapped,) if isinstance(mapped, str) else tuple(mapped)
        present = tuple(a for a in axes if a in self.mesh.shape)
        if not present:
            return None
        return present if len(present) > 1 else present[0]

    def spec(self, *names: str | None) -> P:
        if self.mesh is None:
            return P()
        return P(*(self._resolve_one(n) for n in names))

    def spec_div(self, shape: tuple[int, ...], *names: str | None) -> P:
        """Like spec(), but drops axes whose dimension doesn't divide the
        mesh-axis product.  jit *argument* shardings require divisibility
        (activations under with_sharding_constraint may be padded; arrays
        crossing the jit boundary may not)."""
        if self.mesh is None:
            return P()
        assert len(shape) == len(names), (shape, names)
        out = []
        for dim, n in zip(shape, names):
            axes = self._resolve_one(n)
            if axes is None:
                out.append(None)
                continue
            ax = (axes,) if isinstance(axes, str) else axes
            size = 1
            for a in ax:
                size *= self.mesh.shape[a]
            out.append(axes if dim % size == 0 else None)
        return P(*out)

    def sharding(self, *names: str | None) -> NamedSharding | None:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*names))

    def constrain(self, x: jax.Array, *names: str | None) -> jax.Array:
        """with_sharding_constraint by logical names (no-op without a mesh)."""
        if self.mesh is None or not self.enable:
            return x
        assert len(names) == x.ndim, (names, x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(*names))
        )


def unsharded_ctx() -> ShardingCtx:
    return ShardingCtx(mesh=None)


def axis_size(mesh: Mesh | None, logical: str) -> int:
    """Product of mesh-axis sizes behind a logical axis (1 without a mesh)."""
    if mesh is None:
        return 1
    mapped = RULES.get(logical)
    if mapped is None:
        return 1
    axes = (mapped,) if isinstance(mapped, str) else tuple(mapped)
    size = 1
    for a in axes:
        size *= mesh.shape.get(a, 1)
    return size
