"""Checkpointing: flat-npz with pytree structure + sharding metadata.

Orbax would be the production choice; this container implements the same
contract directly: save/restore round-trips the full train state
(params, optimizer, step) and records the PartitionSpec of every leaf so a
restore onto a different mesh can re-shard deterministically.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(state):
    leaves, treedef = jax.tree_util.tree_flatten(state)
    return leaves, treedef


def save(path: str, state, specs=None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    arrays = {}
    meta = {"keys": [], "specs": {}}
    for kp, leaf in flat:
        key = jax.tree_util.keystr(kp)
        meta["keys"].append(key)
        arrays[f"arr_{len(arrays)}"] = np.asarray(leaf)
    if specs is not None:
        spec_flat = jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        )[0]
        meta["specs"] = {
            jax.tree_util.keystr(kp): str(s) for kp, s in spec_flat
        }
    np.savez(path + ".npz", **arrays)
    with open(path + ".json", "w") as f:
        json.dump(meta, f)


def restore(path: str, like):
    """Restore into the structure of ``like`` (a template pytree)."""
    with np.load(path + ".npz") as data:
        arrays = [data[f"arr_{i}"] for i in range(len(data.files))]
    leaves, treedef = jax.tree_util.tree_flatten(like)
    if len(arrays) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(arrays)} leaves, template has {len(leaves)}"
        )
    restored = [
        jnp.asarray(a, dtype=l.dtype) if hasattr(l, "dtype") else a
        for a, l in zip(arrays, leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, restored)


def load_meta(path: str) -> dict:
    with open(path + ".json") as f:
        return json.load(f)
