"""Checkpointing: flat-npz with pytree structure + sharding metadata.

Orbax would be the production choice; this container implements the same
contract directly: save/restore round-trips the full train state
(params, optimizer, step) and records the PartitionSpec of every leaf so a
restore onto a different mesh can re-shard deterministically.

The meta sidecar (``<path>.json``) records every leaf's key path, shape,
and dtype; :func:`restore` validates all three against the template
pytree and fails with a one-line error on any mismatch — a checkpoint is
either bit-exactly the state it claims to be, or it is rejected.  The
sidecar also carries an optional free-form ``extra`` dict for state that
is not an array (rng generator state, meter counters, loop indices);
Python's json handles the arbitrary-precision ints a PCG64 state
contains, and float round-trips are exact (repr-based), so resume from
``extra`` is bit-identical too.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(state):
    leaves, treedef = jax.tree_util.tree_flatten(state)
    return leaves, treedef


def save(path: str, state, specs=None, extra: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    arrays = {}
    meta = {"keys": [], "shapes": [], "dtypes": [], "specs": {}}
    for kp, leaf in flat:
        key = jax.tree_util.keystr(kp)
        arr = np.asarray(leaf)
        meta["keys"].append(key)
        meta["shapes"].append(list(arr.shape))
        meta["dtypes"].append(str(arr.dtype))
        arrays[f"arr_{len(arrays)}"] = arr
    if specs is not None:
        spec_flat = jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        )[0]
        meta["specs"] = {
            jax.tree_util.keystr(kp): str(s) for kp, s in spec_flat
        }
    if extra is not None:
        meta["extra"] = extra
    np.savez(path + ".npz", **arrays)
    with open(path + ".json", "w") as f:
        json.dump(meta, f)


def restore(path: str, like):
    """Restore into the structure of ``like`` (a template pytree).

    Every leaf is validated against the template — key path (when the
    sidecar is present), shape, and dtype must all match exactly; any
    mismatch raises ``ValueError`` with a one-line diagnosis instead of
    silently casting or misassigning.
    """
    with np.load(path + ".npz") as data:
        n = len(data.files)
        missing = [f"arr_{i}" for i in range(n) if f"arr_{i}" not in data]
        if missing:
            raise ValueError(
                f"checkpoint {path}.npz is malformed: missing {missing[0]} "
                f"(expected arr_0..arr_{n - 1})"
            )
        arrays = [data[f"arr_{i}"] for i in range(n)]
    leaves, treedef = jax.tree_util.tree_flatten(like)
    if len(arrays) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(arrays)} leaves, template has {len(leaves)}"
        )
    flat_keys = [
        jax.tree_util.keystr(kp)
        for kp, _ in jax.tree_util.tree_flatten_with_path(like)[0]
    ]
    meta = load_meta(path) if os.path.exists(path + ".json") else None
    if meta is not None and meta.get("keys") and meta["keys"] != flat_keys:
        bad = next(
            (s, t) for s, t in zip(meta["keys"], flat_keys) if s != t
        ) if len(meta["keys"]) == len(flat_keys) else (meta["keys"], flat_keys)
        raise ValueError(
            f"checkpoint tree structure mismatch: saved key {bad[0]!r} vs "
            f"template key {bad[1]!r}"
        )
    restored = []
    for key, a, l in zip(flat_keys, arrays, leaves):
        want_shape = tuple(np.shape(l))
        if tuple(a.shape) != want_shape:
            raise ValueError(
                f"checkpoint leaf {key!r} has shape {tuple(a.shape)}, "
                f"template wants {want_shape}"
            )
        if hasattr(l, "dtype"):
            if a.dtype != np.dtype(l.dtype):
                raise ValueError(
                    f"checkpoint leaf {key!r} has dtype {a.dtype}, template "
                    f"wants {np.dtype(l.dtype)}"
                )
            restored.append(jnp.asarray(a))
        else:
            restored.append(a)
    return jax.tree_util.tree_unflatten(treedef, restored)


def load_meta(path: str) -> dict:
    with open(path + ".json") as f:
        return json.load(f)
