"""`FDSVRGClassifier` — a scikit-learn-style fit/predict estimator over
the solver registry.

This is the first user-facing *serving* scenario for the repo's trained
linear models: fit on a :class:`~repro.data.sparse.PaddedCSR`, a dense
``(X, y)`` pair (converted internally), or — the out-of-core path — a
:class:`~repro.data.pipeline.DataSource` / LibSVM file path (labels come
from the source; the global matrix is never materialized), then
``decision_function`` / ``predict`` / ``score`` like any sklearn linear
classifier.  Any registered method is a constructor argument away —
``FDSVRGClassifier(method="dsvrg")`` trains with the DSVRG driver
through the same :func:`repro.api.solve` front door the benchmarks use.

``partial_fit`` warm-starts from the current coefficients via the
harness's snapshot rotation: the outer-loop engine computes the full
gradient at ``init_w`` before the first epoch, so continuing a run is
exactly "one more rotation" of the same machinery — no cold restart, no
re-deriving state.  Each call advances the seed so the sample stream
does not replay.
"""

from __future__ import annotations

import os

import numpy as np
import jax.numpy as jnp

from repro.api.registry import solve
from repro.api.spec import PAPER, ExperimentSpec
from repro.core import losses as losses_lib
from repro.core.driver import OuterRecord
from repro.data.pipeline import (
    as_source,
    is_source,
    source_labels,
    streamed_margins,
)
from repro.data.sparse import PaddedCSR
from repro.serve.engine import batched_margins


def _coerce_input(X):
    """A path becomes a streaming LibSVM source; everything else passes."""
    if isinstance(X, (str, os.PathLike)):
        return as_source(X)
    return X


def as_padded_csr(X, y=None) -> PaddedCSR:
    """Coerce estimator input to a PaddedCSR.

    * ``X`` already a PaddedCSR: returned as-is (``y``, if given, must
      match its stored labels' length).
    * ``X`` a dense ``[n, d]`` array with labels ``y``: converted to a
      padded sparse layout with the per-row maximum nnz as the budget.
    """
    if isinstance(X, PaddedCSR):
        if y is not None and len(y) != X.num_instances:
            raise ValueError(
                f"y has {len(y)} labels but the PaddedCSR holds "
                f"{X.num_instances} instances"
            )
        return X
    X = np.asarray(X)
    if X.ndim != 2:
        raise ValueError(f"X must be [n_samples, n_features], got {X.shape}")
    if y is None:
        raise ValueError("dense X requires y")
    n, d = X.shape
    if len(np.asarray(y)) != n:
        raise ValueError(
            f"y has {len(np.asarray(y))} labels but X holds {n} instances"
        )
    # Floating inputs keep their dtype (a float64 study stays float64 when
    # jax x64 is enabled — no silent demotion); anything else runs float32.
    dtype = X.dtype if np.issubdtype(X.dtype, np.floating) else np.float32
    nnz_rows = np.count_nonzero(X, axis=1)
    budget = max(1, int(nnz_rows.max())) if n else 1
    indices = np.zeros((n, budget), dtype=np.int32)
    values = np.zeros((n, budget), dtype=dtype)
    # One vectorized pack (mirrors PaddedCSR.to_dense's single np.add.at):
    # np.nonzero is row-major, so positions within each row are the running
    # index minus the row's starting offset.
    rows, cols = np.nonzero(X)
    pos = np.arange(rows.size) - np.repeat(
        np.cumsum(nnz_rows) - nnz_rows, nnz_rows
    )
    indices[rows, pos] = cols
    values[rows, pos] = X[rows, cols]
    return PaddedCSR(
        indices=jnp.asarray(indices),
        values=jnp.asarray(values),
        labels=jnp.asarray(np.asarray(y, dtype=dtype)),
        dim=d,
    )


class FDSVRGClassifier:
    """Linear classifier trained by any registered solver.

    Parameters mirror :class:`~repro.api.spec.ExperimentSpec`; the
    defaults are the registry's per-method ``"paper"`` operating point.
    Labels may be any values: two classes are mapped onto {-1, +1}
    internally (sorted order, bit-identical to the historical binary
    path); three or more train one-vs-rest as a single multi-output run
    ``w ∈ R^{d×k}`` (``coef_`` becomes sklearn's ``[k, d]`` and
    :meth:`predict` takes the argmax margin) — which requires a method
    with multi-output support (``serial``/``fdsvrg``).
    """

    def __init__(
        self,
        *,
        method: str = "fdsvrg",
        workers: int | None = None,
        eta: float | str = PAPER,
        reg: str = "l2",
        lam: float = 1e-4,
        lam2: float = 0.0,
        loss: str = "logistic",
        batch_size: int | str = PAPER,
        inner_steps: int | str = PAPER,
        outer_iters: int = 10,
        option: str = "I",
        seed: int = 0,
        use_kernels: bool = False,
        lazy_updates: str | None = None,
        cluster=None,
        checkpoint_dir: str | None = None,
        checkpoint_every: int = 1,
        resume: bool = False,
        data_cache_dir: str | None = None,
        ingest_chunk_rows: int = 65536,
    ) -> None:
        self.method = method
        self.workers = workers
        self.eta = eta
        self.reg = reg
        self.lam = lam
        self.lam2 = lam2
        self.loss = loss
        self.batch_size = batch_size
        self.inner_steps = inner_steps
        self.outer_iters = outer_iters
        self.option = option
        self.seed = seed
        self.use_kernels = use_kernels
        self.lazy_updates = lazy_updates
        self.cluster = cluster
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        self.resume = resume
        self.data_cache_dir = data_cache_dir
        self.ingest_chunk_rows = ingest_chunk_rows
        self._fits = 0

    # -- sklearn-style attributes set by fit: coef_, classes_, history_ --

    @property
    def is_fitted(self) -> bool:
        return getattr(self, "coef_", None) is not None

    def _spec(self, data, outer_iters: int, init_w) -> ExperimentSpec:
        if is_source(data):
            data_kw = dict(
                source=data,
                data_cache_dir=self.data_cache_dir,
                ingest_chunk_rows=self.ingest_chunk_rows,
            )
        else:
            data_kw = dict(data=data)
        return ExperimentSpec(
            method=self.method,
            **data_kw,
            loss=self.loss,
            reg=losses_lib.Regularizer(self.reg, self.lam, self.lam2),
            q=self.workers,
            eta=self.eta,
            batch_size=self.batch_size,
            inner_steps=self.inner_steps,
            outer_iters=outer_iters,
            option=self.option,
            # advance the stream per call so partial_fit never replays
            # the previous call's samples
            seed=self.seed + self._fits,
            use_kernels=self.use_kernels,
            lazy_updates=self.lazy_updates,
            cluster=self.cluster,
            init_w=init_w,
            checkpoint_dir=self.checkpoint_dir,
            checkpoint_every=self.checkpoint_every,
            # only the first solve of this estimator resumes; warm-start
            # continuations already carry their state in init_w
            resume=self.resume and self._fits == 0,
        )

    def _encode_labels(self, raw) -> np.ndarray:
        """Map arbitrary labels (any dtype, including strings) onto what
        the losses expect, recording ``classes_``: two classes become the
        historical 1-D {-1,+1} coding (bit-identical to the binary path);
        three or more become a one-vs-rest ``[N, k]`` sign matrix (column
        j is +1 where the label is ``classes_[j]``), trained as one
        multi-output run ``w ∈ R^{d×k}``."""
        raw = np.asarray(raw)
        classes = np.unique(raw)
        if classes.size < 2:
            raise ValueError(
                f"classification requires at least 2 classes, got "
                f"{classes.size}"
            )
        if self.is_fitted and not np.array_equal(classes, self.classes_):
            raise ValueError(
                f"classes {classes} differ from the fitted {self.classes_}"
            )
        self.classes_ = classes
        if classes.size == 2:
            return np.where(raw == classes[1], 1.0, -1.0).astype(np.float32)
        return np.where(
            raw[:, None] == classes[None, :], 1.0, -1.0
        ).astype(np.float32)

    def _encoded_data(self, X, y) -> PaddedCSR:
        """The training PaddedCSR with ±1 labels.  Labels are encoded
        BEFORE any dense->sparse conversion (so non-numeric label values
        work for dense input too), and the result is memoized per input
        object: repeated partial_fit on the same (X, y) reuses ONE data
        object, which is what keeps the id()-keyed BlockCSR cache hitting
        across warm-start calls instead of re-indexing every time."""
        cached = getattr(self, "_encoded", None)
        if cached is not None and cached[0] is X and cached[1] is y:
            return cached[2]
        if is_source(X):
            # Streamed sources carry their own canonical {-1, +1} labels
            # (fixed from the file's global label alphabet at scan time).
            if y is not None:
                raise ValueError(
                    "a DataSource carries its own labels; pass y=None"
                )
            classes = np.array([-1.0, 1.0], dtype=np.float32)
            if self.is_fitted and not np.array_equal(classes, self.classes_):
                raise ValueError(
                    f"classes {classes} differ from the fitted {self.classes_}"
                )
            self.classes_ = classes
            self._encoded = (X, y, X)
            return X
        if isinstance(X, PaddedCSR):
            as_padded_csr(X, y)  # one home for the y-length validation
            signed = self._encode_labels(X.labels if y is None else y)
            if np.array_equal(signed, np.asarray(X.labels)):
                data = X
            else:
                # labels follow the data's values dtype — a re-encoded
                # float64 run must not silently go mixed-precision
                data = PaddedCSR(
                    indices=X.indices, values=X.values,
                    labels=jnp.asarray(signed, X.values.dtype), dim=X.dim,
                )
        else:
            if y is None:
                raise ValueError("dense X requires y")
            data = as_padded_csr(X, self._encode_labels(y))
        # Strong refs to the inputs: identity keys stay valid (no id()
        # recycling), and repeated partial_fit on the same objects reuses
        # one encoded data set.
        self._encoded = (X, y, data)
        return data

    def fit(self, X, y=None) -> "FDSVRGClassifier":
        """Train from scratch for ``outer_iters`` outer iterations."""
        self.coef_ = None
        self.history_: list[OuterRecord] = []
        self._fits = 0
        self._encoded = None
        return self.partial_fit(X, y, outer_iters=self.outer_iters)

    def partial_fit(self, X, y=None, *, outer_iters: int = 1) -> "FDSVRGClassifier":
        """Continue training from the current coefficients (warm start via
        the harness's snapshot rotation); trains from zeros if unfitted."""
        data = self._encoded_data(_coerce_input(X), y)
        if not hasattr(self, "history_"):
            self.history_ = []
        if self.is_fitted:
            # Multiclass stores sklearn's [k, d]; the drivers run [d, k].
            init_w = jnp.asarray(
                self.coef_.T if self.coef_.ndim == 2 else self.coef_
            )
        else:
            init_w = None
        result = solve(self._spec(data, outer_iters, init_w))
        self._fits += 1
        w = np.asarray(result.w)
        self.coef_ = w.T if w.ndim == 2 else w
        self.n_features_in_ = (
            data.stats().dim if is_source(data) else data.dim
        )
        # Each solve() starts a fresh meter/clock, so rebase ALL the
        # cumulative fields — not just the outer index — onto the previous
        # history's totals: history_ then reads as one continuous run
        # (comm/time never step backwards at a warm-start boundary).
        if self.history_:
            last = self.history_[-1]
            base, scal0, rnd0, mod0, wall0 = (
                last.outer + 1, last.comm_scalars, last.comm_rounds,
                last.modeled_time_s, last.wall_time_s,
            )
        else:
            base, scal0, rnd0, mod0, wall0 = 0, 0, 0, 0.0, 0.0
        self.history_.extend(
            OuterRecord(base + h.outer, h.objective, h.grad_norm,
                        scal0 + h.comm_scalars, rnd0 + h.comm_rounds,
                        mod0 + h.modeled_time_s, wall0 + h.wall_time_s)
            for h in result.history
        )
        self.result_ = result
        return self

    def free_training_cache(self) -> "FDSVRGClassifier":
        """Release the memoized training data and the inference-input
        memo (serving: a fitted estimator keeps only
        ``coef_``/``classes_``/``history_``).  The next ``partial_fit``
        (or dense-input ``predict``) re-encodes from its inputs."""
        self._encoded = None
        self._infer_encoded = None
        self.result_ = None
        return self

    def _check_fitted(self) -> None:
        if not self.is_fitted:
            raise ValueError("this FDSVRGClassifier is not fitted yet")

    def decision_function(self, X) -> np.ndarray:
        """Margins ``w^T x_i`` (``[n, k]`` for one-vs-rest models);
        positive means ``classes_[1]``.

        Streamed input (a DataSource or LibSVM path) is scored one chunk
        at a time — serving never materializes the matrix; a one-vs-rest
        model streams the file ONCE for all k columns.  In-memory input
        runs :func:`repro.serve.engine.batched_margins` — the serving
        hot path (the Pallas gather kernel when ``use_kernels``), pinned
        bit-identical to what a :class:`~repro.serve.engine.
        PredictionEngine` holding ``coef_`` serves for the same rows.
        Dense ``X`` converts to the padded sparse layout once per input
        object (identity-memoized like the fit-time data), so
        ``predict`` → ``score`` on the same matrix converts once.
        """
        self._check_fitted()
        X = self._inference_data(_coerce_input(X))
        # The engine's [d(, k)] orientation; sklearn's coef_ is [k, d].
        w = self.coef_.T if self.coef_.ndim == 2 else self.coef_
        if is_source(X):
            return streamed_margins(X, w, chunk_rows=self.ingest_chunk_rows)
        return batched_margins(
            X.indices, X.values, jnp.asarray(w), use_kernels=self.use_kernels
        )

    def _inference_data(self, X):
        """Sources and PaddedCSRs pass through; a dense matrix converts
        to PaddedCSR ONCE per input object (the inference twin of the
        ``_encoded_data`` memo — repeated ``predict``/``score`` calls on
        the same matrix must not redo the O(n·d) pack)."""
        if is_source(X) or isinstance(X, PaddedCSR):
            return X
        cached = getattr(self, "_infer_encoded", None)
        if cached is not None and cached[0] is X:
            return cached[1]
        arr = np.asarray(X)
        if arr.ndim != 2:
            raise ValueError(
                f"X must be [n_samples, n_features], got {arr.shape}"
            )
        data = as_padded_csr(arr, np.zeros(arr.shape[0], dtype=np.float32))
        self._infer_encoded = (X, data)
        return data

    def predict(self, X) -> np.ndarray:
        self._check_fitted()
        df = self.decision_function(X)
        if df.ndim == 2:
            return self.classes_[np.argmax(df, axis=1)]
        return self.classes_[(df > 0).astype(int)]

    def score(self, X, y=None) -> float:
        """Mean accuracy on ``(X, y)``.  ``y=None`` uses a PaddedCSR's (or
        a streamed source's) own stored labels; if the model was fitted on
        classes other than the stored ±1 coding, the ±1 labels are decoded
        through ``classes_`` (same convention as the fit-time encoding: +1
        is ``classes_[1]``) so the comparison happens in one label space."""
        X = _coerce_input(X)
        if y is None:
            if is_source(X):
                y = source_labels(X, chunk_rows=self.ingest_chunk_rows)
            elif isinstance(X, PaddedCSR):
                y = np.asarray(X.labels)
            else:
                raise ValueError(
                    "score() needs y unless X is a PaddedCSR or a source"
                )
            if self.is_fitted and not np.isin(y, self.classes_).all():
                if set(np.unique(y)) <= {-1.0, 1.0}:
                    y = self.classes_[(y > 0).astype(int)]
                else:
                    raise ValueError(
                        f"the PaddedCSR's labels are neither the fitted "
                        f"classes {self.classes_} nor ±1-coded; pass y "
                        "explicitly"
                    )
        return float(np.mean(self.predict(X) == np.asarray(y)))

    def final_objective(self) -> float:
        self._check_fitted()
        return self.history_[-1].objective
