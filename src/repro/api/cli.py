"""Run any registered method on any LinearConfig from the command line.

    PYTHONPATH=src python -m repro.api.cli --config fdsvrg-news20 --method fdsvrg
    PYTHONPATH=src python -m repro.api.cli --list
    PYTHONPATH=src python -m repro.api.cli --config fdsvrg-news20 \\
        --method dsvrg --quick
    PYTHONPATH=src python -m repro.api.cli --data path/to/train.libsvm \\
        --data-cache .ingest_cache --workers 8

One flag per spec knob; everything unset resolves through the registry's
``"paper"`` defaults.  ``--quick`` is the CI smoke shape: 2 outer
iterations with the inner loop capped at 300 steps.

``--data`` streams a LibSVM file through the out-of-core ingestion path
(worker slabs built incrementally, the global matrix never materialized);
combined with ``--config`` it keeps the preset's loss/reg/eta but swaps
the data in.  ``--data-cache`` persists the built slabs so re-runs skip
parsing.
"""

from __future__ import annotations

import argparse
import sys

from repro.api.registry import (
    METHODS,
    PAPER_MAX_INNER,
    capability_matrix,
    method_info,
    solve,
)
from repro.configs.fdsvrg_linear import CONFIGS


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.api.cli",
        description="One front door: solve an ExperimentSpec with any "
        "registered method.",
    )
    p.add_argument("--config", choices=sorted(CONFIGS),
                   help="LinearConfig preset (repro.configs.fdsvrg_linear)")
    p.add_argument("--data", default=None, metavar="PATH",
                   help="stream a LibSVM file instead of a preset's "
                   "synthetic data (out-of-core ingestion; streaming "
                   "methods only)")
    p.add_argument("--data-cache", default=None, metavar="DIR",
                   help="on-disk slab cache for --data (warm re-runs "
                   "skip parsing)")
    p.add_argument("--chunk-rows", type=int, default=None,
                   help="rows per parsed chunk for --data (bounds host "
                   "memory; default 65536)")
    p.add_argument("--method", default="fdsvrg",
                   help=f"registered method ({', '.join(sorted(METHODS))})")
    p.add_argument("--outer-iters", type=int, default=None)
    p.add_argument("--eta", type=float, default=None)
    p.add_argument("--batch-size", type=int, default=None)
    p.add_argument("--inner-steps", type=int, default=None)
    p.add_argument("--workers", type=int, default=None)
    p.add_argument("--seed", type=int, default=None)
    p.add_argument("--use-kernels", action="store_true")
    p.add_argument("--lazy-updates", choices=("exact", "proba"), default=None,
                   help="O(nnz) delayed-decay inner steps (lazy-capable "
                   "methods only)")
    p.add_argument("--checkpoint-dir", default=None,
                   help="persist a rolling outer-loop checkpoint here "
                   "(checkpoint-capable methods only)")
    p.add_argument("--checkpoint-every", type=int, default=None,
                   help="outers between checkpoint writes (default 1)")
    p.add_argument("--resume", action="store_true",
                   help="resume from --checkpoint-dir when a checkpoint "
                   "exists (bit-identical to the uninterrupted run)")
    p.add_argument("--quick", action="store_true",
                   help="CI smoke shape: 2 outers, inner loop capped at 300")
    p.add_argument("--list", action="store_true",
                   help="print the method registry (capability matrix) and exit")
    return p


def _print_registry() -> None:
    """Render repro.api.capability_matrix() — ONE source for this table
    and the docs: a new MethodInfo capability shows up here for free."""
    rows = sorted(capability_matrix(), key=lambda r: r["method"])
    cols = list(rows[0])
    widths = {
        c: max(len(c), *(len(str(r[c])) for r in rows)) for c in cols[:-1]
    }
    print(" ".join([f"{c:<{widths[c]}}" for c in cols[:-1]] + [cols[-1]]))
    for r in rows:
        print(" ".join([f"{str(r[c]):<{widths[c]}}" for c in cols[:-1]]
                       + [str(r[cols[-1]])]))


def main(argv: list[str] | None = None) -> int:
    args = _parser().parse_args(argv)
    if args.list:
        _print_registry()
        return 0
    if args.config is None and args.data is None:
        print("error: --config or --data is required (or use --list)",
              file=sys.stderr)
        return 2
    try:
        info = method_info(args.method)  # fail fast on unknown methods
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    lc = CONFIGS[args.config] if args.config is not None else None

    overrides: dict = {}
    if args.data is not None:
        overrides["dataset"] = None  # the source replaces any preset data
        overrides["source"] = args.data
        if args.data_cache is not None:
            overrides["data_cache_dir"] = args.data_cache
        if args.chunk_rows is not None:
            overrides["ingest_chunk_rows"] = args.chunk_rows
    elif args.data_cache is not None or args.chunk_rows is not None:
        print("error: --data-cache/--chunk-rows only apply with --data",
              file=sys.stderr)
        return 2
    if args.outer_iters is not None:
        overrides["outer_iters"] = args.outer_iters
    if args.eta is not None:
        overrides["eta"] = args.eta
    if args.batch_size is not None:
        overrides["batch_size"] = args.batch_size
    if args.inner_steps is not None:
        overrides["inner_steps"] = args.inner_steps
    if args.workers is not None:
        overrides["q"] = args.workers
    elif info.needs_mesh and lc is not None:
        # shard_map: the worker count IS the mesh size; drop the config's
        # paper worker count so the default 1-device mesh decides — and
        # say so, because a q=1 run meters zero communication and is NOT
        # comparable to the preset's multi-worker runs.
        overrides["q"] = None
        import jax

        n_dev = len(jax.devices())
        print(f"note: {args.method} runs at the mesh size (q={n_dev} "
              f"device(s) here), not the preset's workers={lc.workers}; "
              "comm meters reflect that q")
    if args.seed is not None:
        overrides["seed"] = args.seed
    if args.use_kernels:
        overrides["use_kernels"] = True
    if args.lazy_updates is not None:
        overrides["lazy_updates"] = args.lazy_updates
    if args.checkpoint_dir is not None:
        overrides["checkpoint_dir"] = args.checkpoint_dir
    if args.checkpoint_every is not None:
        overrides["checkpoint_every"] = args.checkpoint_every
    if args.resume:
        overrides["resume"] = True
    if args.quick:
        overrides.setdefault("outer_iters", 2)
        overrides.setdefault("inner_steps", min(300, PAPER_MAX_INNER))

    if lc is not None:
        data_desc = (
            f"data={args.data}" if args.data else f"dataset={lc.dataset}"
        )
        print(f"config {lc.name}: {data_desc} method={args.method} "
              f"({info.summary})")
        make_spec = lambda: lc.to_spec(method=args.method, **overrides)
    else:
        from repro.api.spec import ExperimentSpec

        overrides.pop("dataset", None)
        print(f"data {args.data}: method={args.method} ({info.summary})")
        make_spec = lambda: ExperimentSpec(method=args.method, **overrides)
    try:
        result = solve(make_spec())
    except (TypeError, ValueError) as e:
        # spec/capability validation errors follow the CLI's one-line
        # error convention, same as a missing --config
        print(f"error: {e}", file=sys.stderr)
        return 2

    print(f"\n{'outer':>5} {'objective':>12} {'optimality':>12} "
          f"{'comm scalars':>13} {'modeled s':>10}")
    for h in result.history:
        print(f"{h.outer:>5} {h.objective:>12.6f} {h.grad_norm:>12.4e} "
              f"{h.comm_scalars:>13,} {h.modeled_time_s:>10.4f}")
    print(f"\nfinal objective {result.final_objective():.6f}; "
          f"{result.meter.total_scalars:,} scalars in "
          f"{result.meter.total_rounds:,} rounds")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
