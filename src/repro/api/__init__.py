"""repro.api — the public front door.

One declarative spec, one solver registry, one estimator:

* :class:`~repro.api.spec.ExperimentSpec` — a frozen description of one
  run (data, loss, ONE regularizer, method, schedule with ``"paper"``
  auto-defaults, backend knobs).
* :func:`~repro.api.registry.solve` — runs a spec through its registered
  driver and returns the shared :class:`~repro.core.driver.RunResult`;
  :func:`~repro.api.registry.register_method` +
  :class:`~repro.api.registry.MethodInfo` are the extension point.
* :class:`~repro.api.estimator.FDSVRGClassifier` — scikit-learn-style
  ``fit`` / ``partial_fit`` (warm start) / ``predict`` / ``score``.
* :data:`~repro.api.cache.BLOCK_CACHE` — the shared bounded BlockCSR
  cache ``solve`` builds partitions through.
* ``python -m repro.api.cli`` — any registered method on any
  ``LinearConfig`` preset.

Benchmarks, examples, launch, and serving all drive the same surface;
``benchmarks.common.run_method`` survives only as a deprecated shim over
:func:`solve`.
"""

from repro.api.cache import BLOCK_CACHE, BlockCache, block_data
from repro.api.estimator import FDSVRGClassifier, as_padded_csr
from repro.api.registry import (
    METHODS,
    PAPER_FD_BATCH,
    PAPER_MAX_INNER,
    MethodInfo,
    ResolvedRun,
    capability_matrix,
    method_info,
    register_method,
    solve,
)
from repro.api.spec import PAPER, ExperimentSpec

__all__ = [
    "BLOCK_CACHE",
    "BlockCache",
    "ExperimentSpec",
    "FDSVRGClassifier",
    "METHODS",
    "MethodInfo",
    "PAPER",
    "PAPER_FD_BATCH",
    "PAPER_MAX_INNER",
    "ResolvedRun",
    "as_padded_csr",
    "block_data",
    "capability_matrix",
    "method_info",
    "register_method",
    "solve",
]
