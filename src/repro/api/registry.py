"""The solver registry: one ``solve(spec) -> RunResult`` over all seven
optimizer drivers.

Every driver in :mod:`repro.core` registers here under a method name with
a :class:`MethodInfo` capability record; :func:`solve` is the single
front door that

* loads the data set (or takes the spec's in-memory one),
* resolves the ``"paper"`` auto-defaults per method — the per-method step
  sizes, the trajectory mini-batch, and the inner-step rules
  (FD: ``m = N/u``; DSVRG/Syn: ``m = N/q``; serial/PS: ``m = N``),
  capped at :data:`PAPER_MAX_INNER` — conventions that used to live as
  module constants inside ``benchmarks/common.py``,
* validates the spec against the method's capabilities and fails loudly
  on mismatches (``use_kernels`` on a driver without a kernel path, a
  mesh on a non-shard_map method, Option II on a driver that ignores it),
* owns partition building and BlockCSR caching (the shared bounded
  :data:`repro.api.cache.BLOCK_CACHE`),
* dispatches to the registered driver and returns its
  :class:`~repro.core.driver.RunResult` — the same history schema for
  every method, so callers compare like-for-like.

Method names (the seven drivers; the async pair shares one driver):

====================  ====================================================
``serial``            Algorithm 2 (Johnson & Zhang), the proof reference
``fdsvrg``            Algorithm 1, jitted metered simulation
``fdsvrg_sim``        Algorithm 1, explicit q-worker object simulation
``fdsvrg_sharded``    Algorithm 1, deployable shard_map over a mesh
``dsvrg``             DSVRG (Lee et al.), instance-sharded ring
``synsvrg``           SynSVRG on a parameter server (App. B)
``asysvrg``           AsySVRG on a parameter server (App. B)
``pslite_sgd``        PS-Lite asynchronous SGD (no variance reduction)
``fd_saga``           FD-SAGA update rule (replicated n-float table)
``fd_bcd``            Distributed block coordinate descent (L1 baseline)
====================  ====================================================

New methods register with :func:`register_method`; nothing else in the
repo needs to change for them to be reachable from the CLI, the
estimator, and the benchmarks.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

from repro.api.cache import BLOCK_CACHE
from repro.api.spec import PAPER, ExperimentSpec
from repro.core import baselines
from repro.core import losses as losses_lib
from repro.core.driver import CheckpointPolicy, RunResult
from repro.core.fdsvrg import (
    SVRGConfig,
    fdsvrg_worker_simulation,
    run_fdsvrg,
    run_serial_svrg,
)
from repro.core.fdsvrg_shardmap import FDSVRGShardedConfig, run_fdsvrg_sharded
from repro.core.partition import balanced
from repro.data import datasets
from repro.data.pipeline import as_source, is_source
from repro.dist import SimBackend, make_mesh
from repro.optim.update_rules import BCDRule, SAGARule, make_context, run_with_rule

#: Cap on inner steps per outer for the scaled trajectories of the largest
#: sets (url/kdd) — subsampled epochs, noted in EXPERIMENTS.md.
PAPER_MAX_INNER = 12_000

#: Scaled-trajectory mini-batch for the FD family (keeps big-set scans
#: tractable; the paper's §4.4.1 mini-batch trick).
PAPER_FD_BATCH = 8


@dataclasses.dataclass(frozen=True)
class MethodInfo:
    """Capability record + paper operating point of one registered method."""

    name: str
    run: Callable  # (spec, data, resolved, mesh) -> RunResult
    backend: str  # backend family: "none" | "sim" | "shardmap"
    supports_kernels: bool
    supports_prox: bool = True
    supports_lazy: bool = False  # lazy O(nnz) delayed-decay inner steps
    supports_option_ii: bool = True
    needs_mesh: bool = False
    supports_checkpoint: bool = False  # outer-loop checkpoint/resume
    # Can run from streamed per-worker slabs alone (spec.source=...),
    # never touching a global PaddedCSR.
    supports_streaming: bool = False
    # Accepts a [N, k] label matrix (w ∈ R^{d×k}, one-vs-rest multiclass).
    supports_multi_output: bool = False
    # "paper" auto-default operating point (tuned on the scaled sets,
    # fixed like the paper; lifted from benchmarks/common.py):
    paper_eta: float = 1.0
    paper_batch: int = 1
    inner_rule: str = "n"  # "n" | "n_over_u" | "n_over_q" | "q"
    summary: str = ""


@dataclasses.dataclass(frozen=True)
class ResolvedRun:
    """Concrete numbers after ``"paper"`` resolution, handed to adapters."""

    eta: float
    batch_size: int
    inner_steps: int
    q: int


METHODS: dict[str, MethodInfo] = {}


def register_method(
    name: str,
    *,
    backend: str,
    supports_kernels: bool,
    supports_prox: bool = True,
    supports_lazy: bool = False,
    supports_option_ii: bool = True,
    needs_mesh: bool = False,
    supports_checkpoint: bool = False,
    supports_streaming: bool = False,
    supports_multi_output: bool = False,
    paper_eta: float,
    paper_batch: int = 1,
    inner_rule: str,
    summary: str = "",
) -> Callable:
    """Decorator registering a driver adapter under ``name``.

    The adapter receives ``(spec, data, resolved, mesh)`` — the validated
    spec, the loaded data set, the resolved numeric parameters, and (for
    ``needs_mesh`` methods) the mesh — and returns a ``RunResult``.
    """
    if inner_rule not in ("n", "n_over_u", "n_over_q", "q"):
        raise ValueError(f"unknown inner_rule {inner_rule!r}")

    def deco(fn: Callable) -> Callable:
        if name in METHODS:
            raise ValueError(f"method {name!r} is already registered")
        METHODS[name] = MethodInfo(
            name=name,
            run=fn,
            backend=backend,
            supports_kernels=supports_kernels,
            supports_prox=supports_prox,
            supports_lazy=supports_lazy,
            supports_option_ii=supports_option_ii,
            needs_mesh=needs_mesh,
            supports_checkpoint=supports_checkpoint,
            supports_streaming=supports_streaming,
            supports_multi_output=supports_multi_output,
            paper_eta=paper_eta,
            paper_batch=paper_batch,
            inner_rule=inner_rule,
            summary=summary
            or ((fn.__doc__ or "").strip().splitlines() or [""])[0],
        )
        return fn

    return deco


def method_info(name: str) -> MethodInfo:
    try:
        return METHODS[name]
    except KeyError:
        raise ValueError(
            f"unknown method {name!r}; registered methods: "
            f"{', '.join(sorted(METHODS))}"
        ) from None


def _validate(spec: ExperimentSpec, info: MethodInfo) -> None:
    """Capability checks — every mismatch is a loud error, never a
    silently ignored flag."""
    if spec.use_kernels and not info.supports_kernels:
        raise ValueError(
            f"method {info.name!r} does not support use_kernels=True "
            f"(kernel-path methods: "
            f"{', '.join(sorted(m for m, i in METHODS.items() if i.supports_kernels))}). "
            "The flag would previously have been silently ignored; it now "
            "fails here so a benchmark that believes it measured the Pallas "
            "path actually did."
        )
    if spec.lazy_updates is not None and not info.supports_lazy:
        raise ValueError(
            f"method {info.name!r} does not support lazy_updates="
            f"{spec.lazy_updates!r} (lazy-capable methods: "
            f"{', '.join(sorted(m for m, i in METHODS.items() if i.supports_lazy))}). "
            "The delayed-decay replay only exists for the BlockCSR inner "
            "scans; on any other driver the flag would be silently ignored."
        )
    if not spec.reg.is_smooth and not info.supports_prox:
        raise ValueError(
            f"method {info.name!r} does not support the proximal "
            f"regularizer family (got reg={spec.reg.name!r})"
        )
    if spec.option == "II" and not info.supports_option_ii:
        raise ValueError(
            f"method {info.name!r} ignores the Option I/II step mask; "
            "option='II' would not be honored — run Option I or use a "
            "driver that supports it"
        )
    if spec.mesh is not None and not info.needs_mesh:
        raise ValueError(
            f"method {info.name!r} does not run on a mesh; mesh= is only "
            "meaningful for shard_map methods (fdsvrg_sharded)"
        )
    if spec.tree_mode != "psum" and not info.needs_mesh:
        raise ValueError(
            f"method {info.name!r} does not consume tree_mode="
            f"{spec.tree_mode!r}; the collective topology is a shard_map "
            "knob (fdsvrg_sharded) — it would not be honored here"
        )
    if spec.source is not None and not info.supports_streaming:
        raise ValueError(
            f"method {info.name!r} cannot run from a streamed source "
            f"(streaming methods: "
            f"{', '.join(sorted(m for m, i in METHODS.items() if i.supports_streaming))}). "
            "This driver needs the global matrix; materializing it behind "
            "your back would defeat the out-of-core path — load the data "
            "yourself (data=repro.data.load_libsvm(...)) if that is what "
            "you want."
        )
    if spec.checkpoint_dir is not None and not info.supports_checkpoint:
        raise ValueError(
            f"method {info.name!r} does not support checkpoint/resume "
            f"(checkpointing methods: "
            f"{', '.join(sorted(m for m, i in METHODS.items() if i.supports_checkpoint))}). "
            "checkpoint_dir would be silently ignored; it fails here so a "
            "run that believes it is durable actually is."
        )
    labels = getattr(spec.data, "labels", None)
    if (
        labels is not None
        and getattr(labels, "ndim", 1) == 2
        and labels.shape[1] > 1
        and not info.supports_multi_output
    ):
        raise ValueError(
            f"method {info.name!r} does not support multi-output labels "
            f"(got a [N, {labels.shape[1]}] label matrix; multi-output "
            f"methods: "
            f"{', '.join(sorted(m for m, i in METHODS.items() if i.supports_multi_output))})"
        )


def _resolve(
    spec: ExperimentSpec, info: MethodInfo, n: int, q: int
) -> ResolvedRun:
    """Turn ``"paper"`` sentinels into numbers with the per-method rules."""
    eta = info.paper_eta if spec.eta == PAPER else float(spec.eta)
    u = info.paper_batch if spec.batch_size == PAPER else int(spec.batch_size)
    if spec.inner_steps == PAPER:
        if info.inner_rule == "n_over_u":
            m = min(max(1, n // u), PAPER_MAX_INNER)
        elif info.inner_rule == "n_over_q":
            m = min(max(1, n // q), PAPER_MAX_INNER)
        elif info.inner_rule == "q":
            # One cycle over the feature blocks per outer (BCD).
            m = min(max(1, q), PAPER_MAX_INNER)
        else:  # "n"
            m = min(n, PAPER_MAX_INNER)
    else:
        m = int(spec.inner_steps)
    return ResolvedRun(eta=eta, batch_size=u, inner_steps=m, q=q)


@functools.lru_cache(maxsize=4)
def _load_dataset(name: str):
    """Memoized :func:`repro.data.datasets.load`: dataset-name specs get
    the SAME data object across solve() calls, so the id()-keyed
    BlockCSR cache actually hits for sweeps built on ``spec.replace`` —
    a fresh load per call would both regenerate the data and evict the
    cache every time."""
    return datasets.load(name)


def solve(spec: ExperimentSpec) -> RunResult:
    """Run ``spec`` through its registered driver; the ONE front door.

    Returns the driver's :class:`~repro.core.driver.RunResult` — final
    iterate, per-outer history (objective, optimality residual, metered
    communication, modeled and wall-clock time), and the run's meter.
    """
    info = method_info(spec.method)
    _validate(spec, info)
    if spec.source is not None:
        # The streaming path: `data` is a DataSource handle the adapter
        # turns into per-worker slabs (through the block/slab caches) —
        # the global PaddedCSR is never materialized.
        data = as_source(spec.source)
        n = data.stats().num_instances
    else:
        data = (
            spec.data if spec.data is not None else _load_dataset(spec.dataset)
        )
        n = data.num_instances
    mesh = None
    if info.needs_mesh:
        mesh = spec.mesh if spec.mesh is not None else make_mesh((1,), ("model",))
        q = int(mesh.devices.size)
        if spec.q is not None and spec.q != q:
            raise ValueError(
                f"q={spec.q} disagrees with the mesh's {q} device(s); for "
                f"{info.name!r} the worker count IS the mesh size — pass a "
                "bigger mesh, not a bigger q"
            )
    elif spec.q is not None:
        q = spec.q
    elif spec.dataset is not None:
        q = datasets.spec(spec.dataset).default_workers
    else:
        q = 1
    resolved = _resolve(spec, info, n, q)
    return info.run(spec, data, resolved, mesh)


def capability_matrix() -> list[dict]:
    """Rows for the docs/CLI capability table, in registration order."""
    return [
        {
            "method": i.name,
            "backend": i.backend,
            "kernels": i.supports_kernels,
            "prox": i.supports_prox,
            "lazy": i.supports_lazy,
            "option_II": i.supports_option_ii,
            "mesh": i.needs_mesh,
            "checkpoint": i.supports_checkpoint,
            "streaming": i.supports_streaming,
            "multi_output": i.supports_multi_output,
            "paper_eta": i.paper_eta,
            "paper_batch": i.paper_batch,
            "inner_rule": i.inner_rule,
            "summary": i.summary,
        }
        for i in METHODS.values()
    ]


# ---------------------------------------------------------------------------
# Adapters: the seven drivers, registered
# ---------------------------------------------------------------------------


def _svrg_config(spec: ExperimentSpec, p: ResolvedRun) -> SVRGConfig:
    return SVRGConfig(
        eta=p.eta,
        inner_steps=p.inner_steps,
        outer_iters=spec.outer_iters,
        batch_size=p.batch_size,
        option=spec.option,
        seed=spec.seed,
    )


def _checkpoint_policy(spec: ExperimentSpec) -> CheckpointPolicy | None:
    if spec.checkpoint_dir is None:
        return None
    return CheckpointPolicy(
        directory=spec.checkpoint_dir,
        every=spec.checkpoint_every,
        resume=spec.resume,
    )


def _source_slabs(spec: ExperimentSpec, source, q: int):
    """Streamed per-worker slabs for a source= run, through both cache
    layers (in-process identity cache; on-disk when the spec names one)."""
    return BLOCK_CACHE.get_source(
        source,
        q,
        cache_dir=spec.data_cache_dir,
        chunk_rows=spec.ingest_chunk_rows,
    )


@register_method(
    "serial", backend="none", supports_kernels=True, supports_lazy=True,
    supports_checkpoint=True, supports_streaming=True,
    supports_multi_output=True,
    paper_eta=2.0, inner_rule="n",
    summary="Algorithm 2 (serial SVRG), the proof reference",
)
def _solve_serial(spec, data, p, mesh) -> RunResult:
    block = None
    if is_source(data):
        # Serial runs on the q=1 layout whatever spec.q says (q only
        # shapes the FD partitions).
        block, data = _source_slabs(spec, data, 1), None
    return run_serial_svrg(
        data, losses_lib.LOSSES[spec.loss], spec.reg, _svrg_config(spec, p),
        use_kernels=spec.use_kernels, lazy_updates=spec.lazy_updates,
        block_data=block,
        init_w=spec.init_w, checkpoint=_checkpoint_policy(spec),
    )


@register_method(
    "fdsvrg", backend="sim", supports_kernels=True, supports_lazy=True,
    supports_checkpoint=True, supports_streaming=True,
    supports_multi_output=True,
    paper_eta=2.0, paper_batch=PAPER_FD_BATCH, inner_rule="n_over_u",
    summary="Algorithm 1 (FD-SVRG), jitted metered simulation",
)
def _solve_fdsvrg(spec, data, p, mesh) -> RunResult:
    if is_source(data):
        block, data = _source_slabs(spec, data, p.q), None
    else:
        block = BLOCK_CACHE.get(data, p.q)
    return run_fdsvrg(
        data, block.partition, losses_lib.LOSSES[spec.loss], spec.reg,
        _svrg_config(spec, p), spec.cluster,
        use_kernels=spec.use_kernels, lazy_updates=spec.lazy_updates,
        block_data=block,
        init_w=spec.init_w, checkpoint=_checkpoint_policy(spec),
    )


@register_method(
    "fdsvrg_sim", backend="sim", supports_kernels=True, supports_lazy=True,
    supports_checkpoint=True, supports_streaming=True,
    paper_eta=2.0, paper_batch=PAPER_FD_BATCH, inner_rule="n_over_u",
    summary="Algorithm 1, explicit q-worker object-level simulation",
)
def _solve_fdsvrg_sim(spec, data, p, mesh) -> RunResult:
    if is_source(data):
        block, data = _source_slabs(spec, data, p.q), None
    else:
        block = BLOCK_CACHE.get(data, p.q)
    return fdsvrg_worker_simulation(
        data, block.partition, losses_lib.LOSSES[spec.loss], spec.reg,
        _svrg_config(spec, p), SimBackend(p.q, spec.cluster),
        use_kernels=spec.use_kernels, lazy_updates=spec.lazy_updates,
        block_data=block,
        init_w=spec.init_w, checkpoint=_checkpoint_policy(spec),
    )


@register_method(
    "fdsvrg_sharded", backend="shardmap",
    # The shard_map worker has a kernel path, but solve() does not expose
    # it yet: Pallas-inside-shard_map is only exercised by the dedicated
    # perf harness (launch/perf), not certified through this front door —
    # so the honest capability today is False, and asking for it errors
    # instead of silently running the jnp path.
    supports_kernels=False,
    supports_option_ii=False,  # the sharded inner scan has no step mask
    needs_mesh=True,
    paper_eta=2.0, paper_batch=PAPER_FD_BATCH, inner_rule="n_over_u",
    summary="Algorithm 1, deployable shard_map over the mesh's feature axes",
)
def _solve_fdsvrg_sharded(spec, data, p, mesh) -> RunResult:
    cfg = FDSVRGShardedConfig(
        dim=data.dim,
        num_instances=data.num_instances,
        nnz_max=data.nnz_max,
        eta=p.eta,
        inner_steps=p.inner_steps,
        batch_size=p.batch_size,
        loss_name=spec.loss,
        reg_name=spec.reg.name,
        lam=spec.reg.lam,
        lam2=spec.reg.lam2,
        tree_mode=spec.tree_mode,
    )
    return run_fdsvrg_sharded(
        data, mesh, cfg, feature_axes=tuple(mesh.axis_names),
        outer_iters=spec.outer_iters, seed=spec.seed, cluster=spec.cluster,
        init_w=spec.init_w,
    )


def _register_baseline(name, runner, *, paper_eta, inner_rule, supports_option_ii=True, summary):
    @register_method(
        name, backend="sim", supports_kernels=False,
        supports_option_ii=supports_option_ii,
        paper_eta=paper_eta, inner_rule=inner_rule, summary=summary,
    )
    def _solve_baseline(spec, data, p, mesh) -> RunResult:
        return runner(
            data, p.q, losses_lib.LOSSES[spec.loss], spec.reg,
            _svrg_config(spec, p), spec.cluster, init_w=spec.init_w,
        )

    return _solve_baseline


_register_baseline(
    "dsvrg", baselines.run_dsvrg, paper_eta=1.0, inner_rule="n_over_q",
    summary="DSVRG (Lee et al.), instance-sharded ring",
)
_register_baseline(
    "synsvrg", baselines.run_syn_svrg, paper_eta=2.0, inner_rule="n_over_q",
    summary="SynSVRG on a parameter server (App. B, Alg 3/4)",
)
_register_baseline(
    "asysvrg", baselines.run_asy_svrg, paper_eta=0.5, inner_rule="n",
    supports_option_ii=False,  # the async scan draws no step mask
    summary="AsySVRG on a parameter server (App. B, Alg 5/6)",
)
_register_baseline(
    "pslite_sgd", baselines.run_pslite_sgd, paper_eta=0.3, inner_rule="n",
    supports_option_ii=False,
    summary="PS-Lite asynchronous SGD, no variance reduction",
)


# -- update-rule methods: a registration, not a new driver -------------------


@register_method(
    "fd_saga", backend="sim", supports_kernels=False,
    supports_option_ii=False,  # SAGA has no Option I/II step mask
    paper_eta=1.0, paper_batch=PAPER_FD_BATCH, inner_rule="n_over_u",
    summary="FD-SAGA: feature-distributed SAGA, replicated n-float table",
)
def _solve_fd_saga(spec, data, p, mesh) -> RunResult:
    block = BLOCK_CACHE.get(data, p.q)
    ctx = make_context(
        block, losses_lib.LOSSES[spec.loss], spec.reg,
        _svrg_config(spec, p), backend=SimBackend(p.q, spec.cluster),
    )
    return run_with_rule(SAGARule(), ctx, init_w=spec.init_w)


@register_method(
    "fd_bcd", backend="sim", supports_kernels=False,
    supports_option_ii=False,  # deterministic block cycling, no step mask
    paper_eta=1.0, inner_rule="q",
    summary="Distributed block coordinate descent (Mahajan et al.), L1 baseline",
)
def _solve_fd_bcd(spec, data, p, mesh) -> RunResult:
    block = BLOCK_CACHE.get(data, p.q)
    ctx = make_context(
        block, losses_lib.LOSSES[spec.loss], spec.reg,
        _svrg_config(spec, p), backend=SimBackend(p.q, spec.cluster),
    )
    return run_with_rule(BCDRule(), ctx, init_w=spec.init_w)
