"""The shared, bounded BlockCSR cache service.

Re-indexing a data set into the block-local
:class:`~repro.data.block_csr.BlockCSR` layout is host-side numpy work
that every FD caller repeats for the same ``(data, q)`` pair: sweeps call
:func:`repro.api.solve` many times per data set, the estimator refits,
the CLI re-runs.  This cache amortizes it once for all of them (it used
to be a private dict inside ``benchmarks/common.py``, invisible to every
non-benchmark caller).

Scoping rules (unchanged from the benchmarks-era cache, now tested where
the cache lives):

* **per-sweep scope** — a new data object evicts every entry built for
  other data sets, so a sweep over data sets never pins the previous
  set's blocks alive (the original unbounded ``id()``-keyed dict did);
  the identity check also guards against ``id()`` recycling.
* **LRU bound** — at most :attr:`BlockCache.max_entries` distinct ``q``
  values are kept for the current data set.
"""

from __future__ import annotations

from collections import OrderedDict

from repro.core.partition import balanced
from repro.data.block_csr import BlockCSR
from repro.data.sparse import PaddedCSR


class BlockCache:
    """A bounded ``(data, q) -> BlockCSR`` cache with per-sweep scope."""

    def __init__(self, max_entries: int = 4) -> None:
        self.max_entries = int(max_entries)
        self._entries: OrderedDict[
            tuple[int, int], tuple[object, BlockCSR]
        ] = OrderedDict()

    def get(self, data: PaddedCSR, q: int) -> BlockCSR:
        """The BlockCSR of ``data`` at ``q`` blocks, built at most once."""
        key = (id(data), q)
        hit = self._entries.get(key)
        if hit is not None and hit[0] is data:
            self._entries.move_to_end(key)
            return hit[1]
        # New data object: the sweep moved on — drop other data sets'
        # entries (and any stale entry whose id() was recycled).
        for k in [k for k, v in self._entries.items() if v[0] is not data]:
            del self._entries[k]
        block = BlockCSR.from_padded(data, balanced(data.dim, q))
        self._entries[key] = (data, block)
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
        return block

    def clear(self) -> None:
        self._entries.clear()

    def __len__(self) -> int:
        return len(self._entries)

    def values(self):
        """(data, BlockCSR) pairs, LRU order (oldest first) — tests."""
        return self._entries.values()


#: The process-wide cache :func:`repro.api.solve` uses.
BLOCK_CACHE = BlockCache()


def block_data(data: PaddedCSR, q: int) -> BlockCSR:
    """Module-level convenience over :data:`BLOCK_CACHE`."""
    return BLOCK_CACHE.get(data, q)
