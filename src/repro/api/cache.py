"""The shared, bounded BlockCSR cache service.

Re-indexing a data set into the block-local
:class:`~repro.data.block_csr.BlockCSR` layout is host-side numpy work
that every FD caller repeats for the same ``(data, q)`` pair: sweeps call
:func:`repro.api.solve` many times per data set, the estimator refits,
the CLI re-runs.  This cache amortizes it once for all of them (it used
to be a private dict inside ``benchmarks/common.py``, invisible to every
non-benchmark caller).

Scoping rules (unchanged from the benchmarks-era cache, now tested where
the cache lives):

* **per-sweep scope** — a new data object evicts every entry built for
  other data sets, so a sweep over data sets never pins the previous
  set's blocks alive (the original unbounded ``id()``-keyed dict did);
  the identity check also guards against ``id()`` recycling.
* **LRU bound** — at most :attr:`BlockCache.max_entries` distinct ``q``
  values are kept for the current data set.

Streamed sources (:class:`~repro.data.pipeline.DataSource`) share the
same in-process cache through :meth:`BlockCache.get_source` — identity
keyed like arrays, so a sweep holding one source object re-ingests
nothing — layered over the on-disk slab cache
(:mod:`repro.data.ingest_cache`) when the caller passes ``cache_dir``.
"""

from __future__ import annotations

from collections import OrderedDict

from repro.core.partition import balanced
from repro.data.block_csr import BlockCSR
from repro.data.sparse import PaddedCSR


class BlockCache:
    """A bounded ``(data, q) -> BlockCSR`` cache with per-sweep scope."""

    def __init__(self, max_entries: int = 4) -> None:
        self.max_entries = int(max_entries)
        self._entries: OrderedDict[
            tuple[int, int], tuple[object, BlockCSR]
        ] = OrderedDict()

    def get(self, data: PaddedCSR, q: int) -> BlockCSR:
        """The BlockCSR of ``data`` at ``q`` blocks, built at most once."""
        hit = self._lookup(data, q)
        if hit is not None:
            return hit
        block = BlockCSR.from_padded(data, balanced(data.dim, q))
        self._insert(data, q, block)
        return block

    def get_source(
        self,
        source,
        q: int,
        *,
        cache_dir: str | None = None,
        chunk_rows: int = 65536,
    ) -> BlockCSR:
        """The streamed BlockCSR of a DataSource at ``q`` blocks.

        Memory layer: identity-keyed like :meth:`get` (one ingest per
        (source object, q) while the sweep holds it).  Disk layer: with
        ``cache_dir``, a miss here goes through
        :func:`repro.data.ingest_cache.get_or_build`, so even a fresh
        process warm-loads slabs instead of parsing.
        """
        from repro.data.ingest_cache import get_or_build

        hit = self._lookup(source, q)
        if hit is not None:
            return hit
        partition = balanced(source.stats().dim, q)
        outcome = get_or_build(
            source, partition, cache_dir=cache_dir, chunk_rows=chunk_rows
        )
        self._insert(source, q, outcome.data)
        return outcome.data

    def _lookup(self, owner, q: int) -> BlockCSR | None:
        key = (id(owner), q)
        hit = self._entries.get(key)
        if hit is not None and hit[0] is owner:
            self._entries.move_to_end(key)
            return hit[1]
        return None

    def _insert(self, owner, q: int, block: BlockCSR) -> None:
        # New owner object: the sweep moved on — drop other data sets'
        # entries (and any stale entry whose id() was recycled).
        for k in [k for k, v in self._entries.items() if v[0] is not owner]:
            del self._entries[k]
        self._entries[(id(owner), q)] = (owner, block)
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)

    def clear(self) -> None:
        self._entries.clear()

    def __len__(self) -> int:
        return len(self._entries)

    def values(self):
        """(data, BlockCSR) pairs, LRU order (oldest first) — tests."""
        return self._entries.values()


#: The process-wide cache :func:`repro.api.solve` uses.
BLOCK_CACHE = BlockCache()


def block_data(data: PaddedCSR, q: int) -> BlockCSR:
    """Module-level convenience over :data:`BLOCK_CACHE`."""
    return BLOCK_CACHE.get(data, q)
