"""`ExperimentSpec` — the one declarative problem description every caller
hands to :func:`repro.api.solve`.

Before this module, each of the seven optimizer drivers had its own
positional signature, and the only method-dispatching facade
(``benchmarks.common.run_method``) was a private benchmark helper that
hoarded the paper's per-method conventions and took the regularizer
*twice* (a ``lam`` float and a ``Regularizer`` whose ``lam`` had to
match).  ``ExperimentSpec`` is the fix:

* **one regularizer** — a single :class:`repro.core.losses.Regularizer`;
  the headline strength is ``spec.reg.lam``, there is no second argument
  to disagree with it;
* **"paper" auto-defaults** — ``eta``, ``batch_size``, and
  ``inner_steps`` default to the sentinel string ``"paper"``, resolved
  per method by the registry (the ``m = N/u`` rule, the per-method step
  sizes, the inner-step cap) so a spec that names only a dataset and a
  method runs at the repo's Table-1-scaled operating point;
* **loud validation** — structural errors (no data, both ``dataset`` and
  ``data``, bad option) fail here; capability mismatches (``use_kernels``
  on a driver that doesn't support it, a mesh on a non-shard_map method)
  fail inside :func:`repro.api.solve` against the registry's
  :class:`~repro.api.registry.MethodInfo` record.

The spec is frozen: a sweep can hold thousands of them, derive variants
with :func:`dataclasses.replace`, and trust that none mutated under it.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax

from repro.core import losses as losses_lib
from repro.data.sparse import PaddedCSR
from repro.dist import ClusterModel

#: Sentinel for "resolve this per method from the registry's paper defaults".
PAPER = "paper"


@dataclasses.dataclass(frozen=True, eq=False)
class ExperimentSpec:
    """A complete, declarative description of one optimization run.

    Exactly one of ``dataset`` (a :mod:`repro.data.datasets` key),
    ``data`` (an in-memory :class:`~repro.data.sparse.PaddedCSR`), or
    ``source`` (a :class:`~repro.data.pipeline.DataSource` or a LibSVM
    file path — the streaming out-of-core path) must be set.
    ``eq=False``: specs carry device arrays (``data``, ``init_w``), so
    identity — not elementwise comparison — is the right equality.
    """

    method: str
    dataset: str | None = None
    data: PaddedCSR | None = None
    # Streaming ingestion (repro.data.pipeline): a DataSource instance or
    # a path to a LibSVM file.  Worker slabs are built incrementally —
    # bit-identical to the in-memory path — and never materialize the
    # global matrix; methods must advertise supports_streaming.
    source: Any | None = None
    # On-disk slab cache for source= runs (repro.data.ingest_cache); None
    # disables caching.  Warm hits skip parsing entirely.
    data_cache_dir: str | None = None
    # Host-memory bound for streamed parsing, in rows per chunk.
    ingest_chunk_rows: int = 65536
    loss: str = "logistic"
    reg: losses_lib.Regularizer = losses_lib.l2(1e-4)  # paper §5.3 default
    q: int | None = None  # workers; None -> dataset default (or 1 for raw data)
    eta: float | str = PAPER
    batch_size: int | str = PAPER
    inner_steps: int | str = PAPER
    outer_iters: int = 6
    option: str = "I"  # Algorithm 2 Option I/II
    seed: int = 0
    use_kernels: bool = False
    # Lazy O(nnz) inner steps (delayed-decay replay over BlockCSR):
    # None -> the paper-faithful dense inner step; "exact" -> bitwise-
    # equivalent catch-up replay; "proba" -> unbiased probabilistic decay.
    lazy_updates: str | None = None
    cluster: ClusterModel | None = None  # None -> the backend's default
    init_w: jax.Array | None = None  # warm start (None -> zeros)
    # Outer-loop checkpoint/resume (methods with supports_checkpoint):
    # a rolling checkpoint under checkpoint_dir every checkpoint_every
    # outers; resume=True restores it when present (resume is proven
    # bit-identical to the uninterrupted run).
    checkpoint_dir: str | None = None
    checkpoint_every: int = 1
    resume: bool = False
    # shard_map-only knobs (validated against MethodInfo.needs_mesh):
    mesh: Any | None = None  # jax Mesh; None -> a 1-device ("model",) mesh
    tree_mode: str = "psum"  # "psum" | "butterfly"

    def __post_init__(self) -> None:
        given = sum(
            x is not None for x in (self.dataset, self.data, self.source)
        )
        if given != 1:
            raise ValueError(
                "exactly one of dataset= (a repro.data.datasets key), "
                "data= (a PaddedCSR), or source= (a DataSource / LibSVM "
                "path) must be set"
            )
        if self.ingest_chunk_rows < 1:
            raise ValueError(
                f"ingest_chunk_rows >= 1 required, got "
                f"{self.ingest_chunk_rows!r}"
            )
        if self.data_cache_dir is not None and self.source is None:
            raise ValueError(
                "data_cache_dir= only applies to source= runs (the "
                "in-memory paths have nothing to cache on disk)"
            )
        if self.option not in ("I", "II"):
            raise ValueError(f"option must be 'I' or 'II', got {self.option!r}")
        if not isinstance(self.reg, losses_lib.Regularizer):
            raise TypeError(
                f"reg must be a repro.core.losses.Regularizer (got "
                f"{type(self.reg).__name__}); the spec takes ONE regularizer "
                "— there is no separate lam argument to mismatch it with"
            )
        if self.loss not in losses_lib.LOSSES:
            raise ValueError(
                f"unknown loss {self.loss!r}; known: "
                f"{sorted(losses_lib.LOSSES)}"
            )
        for field, value in (
            ("eta", self.eta), ("batch_size", self.batch_size),
            ("inner_steps", self.inner_steps),
        ):
            if isinstance(value, str):
                if value != PAPER:
                    raise ValueError(
                        f"{field} must be a number or the sentinel "
                        f"{PAPER!r}, got {value!r}"
                    )
            elif field == "eta":
                if value <= 0:
                    raise ValueError(f"eta > 0 required, got {value!r}")
            elif value < 1:
                raise ValueError(f"{field} >= 1 required, got {value!r}")
        if self.outer_iters < 1:
            raise ValueError(
                f"outer_iters >= 1 required, got {self.outer_iters!r}"
            )
        if self.q is not None and self.q < 1:
            raise ValueError("q >= 1 required")
        if self.lazy_updates not in (None, "exact", "proba"):
            raise ValueError(
                f"lazy_updates must be None, 'exact', or 'proba', got "
                f"{self.lazy_updates!r}"
            )
        if self.checkpoint_every < 1:
            raise ValueError(
                f"checkpoint_every >= 1 required, got {self.checkpoint_every!r}"
            )
        if self.checkpoint_dir is None and self.resume:
            raise ValueError(
                "resume=True needs checkpoint_dir= (there is nothing to "
                "resume from without one)"
            )

    def replace(self, **changes) -> "ExperimentSpec":
        """Derive a variant spec (sweeps: ``spec.replace(reg=...)``)."""
        return dataclasses.replace(self, **changes)
