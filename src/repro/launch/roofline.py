"""Roofline extraction from compiled artifacts (see ROOFLINE ANALYSIS).

Three terms, per (arch x shape x mesh), all in seconds:

    compute    = HLO_FLOPs / (chips * 197e12 bf16 FLOP/s)
    memory     = HLO_bytes / (chips * 819e9 B/s HBM)
    collective = collective_bytes / (chips * 50e9 B/s ICI link)

``cost_analysis()`` reports per-device FLOPs/bytes for the SPMD-partitioned
module, so we multiply back by ``chips`` where needed — conventions are
normalized here so the table always reads "total work / total capability".

collective_bytes comes from parsing the post-SPMD HLO
(``compiled.as_text()``): we sum the *output shape* bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op (a standard, slightly conservative proxy for per-chip link traffic).
"""

from __future__ import annotations

import dataclasses
import math
import re

PEAK_FLOPS = 197e12  # bf16 / chip (TPU v5e)
HBM_BW = 819e9  # B / s / chip
ICI_BW = 50e9  # B / s / link

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.MULTILINE,
)

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|u32|s16|u16|s8|u8|pred)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(shape_str: str) -> int:
    """Sum bytes over every tensor literal in a shape string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype = m.group(1)
        dims = m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        b = _DTYPE_BYTES.get(dtype[:4], None) or _DTYPE_BYTES.get(dtype[:3], 4)
        if dtype.startswith("f8"):
            b = 1
        total += n * b
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-kind output bytes of collective ops in a post-SPMD HLO module.

    ``-start``/``-done`` pairs are counted once (the -start carries the op).
    """
    by_kind: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        # -done ops repeat the shape of their -start; skip them
        tail = hlo_text[m.end() - 1 : m.end() + 8]
        line = hlo_text[m.start():hlo_text.index("\n", m.start())]
        if f"{kind}-done" in line:
            continue
        by_kind[kind] = by_kind.get(kind, 0) + _shape_bytes(shape_str)
    return by_kind


@dataclasses.dataclass
class Roofline:
    flops_total: float
    hbm_bytes_total: float
    collective_bytes_per_chip: float
    chips: int

    @property
    def compute_s(self) -> float:
        return self.flops_total / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_total / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_chip / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        return {
            "flops_total": self.flops_total,
            "hbm_bytes_total": self.hbm_bytes_total,
            "collective_bytes_per_chip": self.collective_bytes_per_chip,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def from_compiled(compiled, chips: int) -> Roofline:
    """Build the roofline terms from a jax Compiled object.

    jax cost_analysis on the CPU backend reports metrics for the
    *per-device* partitioned module; totals are per-device x chips.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    coll_total = float(sum(coll.values()))
    return Roofline(
        flops_total=flops_dev * chips,
        hbm_bytes_total=bytes_dev * chips,
        collective_bytes_per_chip=coll_total,
        chips=chips,
    )


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D for training, 2·N·D per generated/scored token for
    inference (N = active params)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
