"""Production meshes (TPU v5e): 16x16 single pod, 2x16x16 multi-pod.

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state; callers (dryrun.py) set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import.  Mesh construction goes through :func:`repro.dist.compat.make_mesh`
so it works across jax releases (the ``axis_types`` kwarg is newer than
the 0.4.x series).
"""

from __future__ import annotations

from repro.dist.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1):
    """Small mesh for CPU tests (device count permitting)."""
    return make_mesh((data, model), ("data", "model"))


def chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
