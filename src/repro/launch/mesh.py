"""Production meshes (TPU v5e): 16x16 single pod, 2x16x16 multi-pod.

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state; callers (dryrun.py) set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import.
"""

from __future__ import annotations

import jax


def _mesh(shape, axes):
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1):
    """Small mesh for CPU tests (device count permitting)."""
    return _mesh((data, model), ("data", "model"))


def chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
