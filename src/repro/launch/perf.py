import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: named hypothesis experiments over the dry-run.

    PYTHONPATH=src python -m repro.launch.perf --pair jamba_train
    PYTHONPATH=src python -m repro.launch.perf --pair qwen3_prefill
    PYTHONPATH=src python -m repro.launch.perf --pair fdsvrg

Each experiment = a config delta applied to the baseline architecture,
re-lowered and re-analysed exactly like the dry-run; results append to
results/perf/<pair>.json with before/after roofline terms.
"""

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

import repro.configs as configs_pkg
from repro.configs import get_config
import repro.launch.dryrun as dryrun

RESULTS = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "perf")
)


def _run_variant(base_arch: str, shape: str, label: str, **overrides) -> dict:
    cfg = get_config(base_arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    tmp = f"__perf_{label}"
    cfg = dataclasses.replace(cfg, name=tmp)
    configs_pkg.ARCHS[tmp] = cfg
    dryrun.GRAD_ACCUM[tmp] = dryrun.GRAD_ACCUM[base_arch]
    try:
        res = dryrun.dryrun_one(tmp, shape, False)
    finally:
        configs_pkg.ARCHS.pop(tmp, None)
        dryrun.GRAD_ACCUM.pop(tmp, None)
    res["label"] = label
    res["overrides"] = {k: str(v) for k, v in overrides.items()}
    return res


def _print_row(res: dict):
    rl = res["roofline"]
    mem = res.get("memory_analysis", {}).get("temp_size_in_bytes", 0) / 2**30
    print(
        f"  {res['label']:<28} compute={rl['compute_s']:.4f}s "
        f"memory={rl['memory_s']:.4f}s collective={rl['collective_s']:.4f}s "
        f"dominant={rl['dominant']:<10} useful={res.get('useful_flops_ratio') or 0:.3f} "
        f"temp={mem:.1f}GiB",
        flush=True,
    )


def pair_jamba_train() -> list[dict]:
    """jamba-v0.1-52b x train_4k.  Baseline dominant: memory; useful-flops
    ratio 0.096 — the SSD intra-chunk quadratic term (chunk=256 vs
    d_state=16) wastes ~L/(2N) of the mixer FLOPs and its L^2 decay
    matrices carry the memory term."""
    out = [_run_variant("jamba-v0.1-52b", "train_4k", "baseline")]
    _print_row(out[-1])
    # H1a: chunk ~ 4*d_state balances intra (L) vs inter (N) work
    for chunk in (64, 32):
        out.append(_run_variant("jamba-v0.1-52b", "train_4k",
                                f"ssm_chunk={chunk}", ssm_chunk=chunk))
        _print_row(out[-1])
    # H1b: bf16 SSD operands halve the streamed bytes (f32 accumulation)
    out.append(_run_variant("jamba-v0.1-52b", "train_4k",
                            "chunk=32+bf16-ssd",
                            ssm_chunk=32, ssm_compute_dtype="bfloat16"))
    _print_row(out[-1])
    return out


def pair_qwen3_prefill() -> list[dict]:
    """qwen3-14b x prefill_32k.  The single-scan flash path scores every
    (q, k) chunk pair; causal block-skipping halves score FLOPs."""
    out = [_run_variant("qwen3-14b", "prefill_32k", "baseline")]
    _print_row(out[-1])
    for qc in (4096, 2048):
        out.append(_run_variant("qwen3-14b", "prefill_32k",
                                f"q_chunk={qc}", attn_q_chunk=qc))
        _print_row(out[-1])
    return out


def pair_gemma2_long() -> list[dict]:
    """gemma2-9b x long_500k (extra): block-skipping on local layers should
    collapse their work to O(window)."""
    out = [_run_variant("gemma2-9b", "long_500k", "baseline")]
    _print_row(out[-1])
    return out


def pair_fdsvrg() -> list[dict]:
    """The paper's own workload: collective-term iteration."""
    from repro.core.fdsvrg_shardmap import FDSVRGShardedConfig, make_outer_iteration
    from repro.launch.mesh import chips, make_production_mesh
    from repro.launch import roofline as rl

    mesh = make_production_mesh(multi_pod=False)
    q = chips(mesh)
    d = ((29_890_095 + q - 1) // q) * q
    n, nnz, m = 65_536, 32, 256
    out = []
    for label, tree_mode, u in (
        ("baseline-psum-u64", "psum", 64),
        ("butterfly-u64", "butterfly", 64),
        ("psum-u512", "psum", 512),
        ("psum-u8", "psum", 8),
    ):
        cfg = FDSVRGShardedConfig(dim=d, num_instances=n, nnz_max=nnz, eta=0.1,
                                  inner_steps=m, batch_size=u, tree_mode=tree_mode)
        step = make_outer_iteration(mesh, cfg, feature_axes=("data", "model"))
        from repro.data.block_csr import aot_nnz_budget

        bnnz = aot_nnz_budget(nnz, q)  # block-local stacked rows, nnz/q + slack
        args = (
            jax.ShapeDtypeStruct((d,), jnp.float32),
            jax.ShapeDtypeStruct((q, n, bnnz), jnp.int32),
            jax.ShapeDtypeStruct((q, n, bnnz), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((m, u), jnp.int32),
        )
        compiled = step.lower(*args).compile()
        coll = rl.collective_bytes(compiled.as_text())
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        # the inner scan body is counted once; scale collective/flops by M
        # analytically for the inner-loop share (1 tree per step)
        res = {
            "label": label, "arch": "fdsvrg-kdd2010", "shape": "outer",
            "mesh": "16x16", "chips": q,
            "collectives": coll,
            "flops_dev": float(cost.get("flops", 0.0)),
            "bytes_dev": float(cost.get("bytes accessed", 0.0)),
            "roofline": {
                "compute_s": float(cost.get("flops", 0.0)) / 197e12,
                "memory_s": float(cost.get("bytes accessed", 0.0)) / 819e9,
                "collective_s": sum(coll.values()) / 50e9,
                "dominant": "n/a",
            },
            "inner_steps": m, "batch": u, "tree_mode": tree_mode,
            "ok": True,
        }
        out.append(res)
        print(f"  {label:<28} coll_bytes={sum(coll.values()):>12,} "
              f"kinds={ {k: v for k, v in sorted(coll.items())} }", flush=True)
    return out


PAIRS = {
    "jamba_train": pair_jamba_train,
    "qwen3_prefill": pair_qwen3_prefill,
    "gemma2_long": pair_gemma2_long,
    "fdsvrg": pair_fdsvrg,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", required=True, choices=sorted(PAIRS))
    args = ap.parse_args()
    os.makedirs(RESULTS, exist_ok=True)
    t0 = time.time()
    print(f"== perf pair: {args.pair} ==", flush=True)
    results = PAIRS[args.pair]()
    with open(os.path.join(RESULTS, f"{args.pair}.json"), "w") as f:
        json.dump(results, f, indent=2, default=str)
    print(f"done in {time.time()-t0:.0f}s -> results/perf/{args.pair}.json")


if __name__ == "__main__":
    main()
