import os
import sys

# The flag must land before jax initializes, hence before any jax import —
# callers (benchmarks.roofline auto-populate, the tier-1 smoke test) run
# this module in a SUBPROCESS for the same reason.  --smoke lowers one
# reduced combo on an 8-device mesh; forcing 512 host devices for that
# would slow the compile for nothing.
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8"
    if "--smoke" in sys.argv
    else "--xla_force_host_platform_device_count=512"
)

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against the production meshes, with ShapeDtypeStruct inputs
(no allocation), and record memory / cost / collective analysis for the
roofline tables (EXPERIMENTS.md §Dry-run, §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                      # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
      --shape train_4k [--multi-pod] [--fdsvrg]
Results land in results/dryrun/<arch>__<shape>__<mesh>.json.
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, INPUT_SHAPES, get_config
from repro.configs.base import InputShape, ModelConfig
from repro.launch import roofline as roofline_lib
from repro.launch.inputs import (
    decode_token_specs,
    prefill_batch_specs,
    train_batch_specs,
)
from repro.launch.mesh import chips, make_production_mesh
from repro.models import transformer
from repro.optim.optimizers import adamw
from repro.sharding.specs import ShardingCtx
from repro.train.loop import TrainSettings, init_state, make_train_step, state_specs
from repro.train.serve import make_serve_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")

# per-arch gradient-accumulation (microbatching) for train_4k: keeps the
# per-device activation footprint inside v5e HBM at global batch 256
GRAD_ACCUM = {
    "qwen3-14b": 8, "jamba-v0.1-52b": 8, "gemma2-9b": 8,
    "minitron-4b": 4, "paligemma-3b": 4, "musicgen-large": 4,
    "mamba2-2.7b": 4, "olmoe-1b-7b": 4,
    "smollm-360m": 2, "granite-moe-1b-a400m": 2,
}

# pure full-attention archs skip long_500k (DESIGN.md §5 "Shape skips")
LONG_CONTEXT_ARCHS = {a for a, c in ARCHS.items() if c.supports_long_context}


def _sh(mesh, ctx: ShardingCtx, *names):
    return NamedSharding(mesh, ctx.spec(*names))


def _batch_shardings(cfg, mesh, ctx, batch_specs: dict, grad_accum: int):
    lead = (None,) if grad_accum > 1 else ()

    def names_for(key: str, rank: int):
        body = {
            "tokens": ("batch", None, None),
            "labels": ("batch", None, None),
            "patch_embeds": ("batch", None, None),
        }[key]
        return lead + body[: rank - len(lead)]

    return {
        k: NamedSharding(mesh, ctx.spec(*names_for(k, v.ndim)))
        for k, v in batch_specs.items()
    }


def _memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # backend may not support it
        return {"error": str(e)}
    if ma is None:
        return {}
    out = {}
    for attr in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        if hasattr(ma, attr):
            out[attr] = int(getattr(ma, attr))
    if not out:
        out["repr"] = str(ma)
    return out


def _rules_overrides(shape: InputShape) -> dict:
    if shape.name == "long_500k":
        # batch=1: retire the batch axes, spread the KV cache over data+model
        return {"batch": None, "seq_kv": ("data", "model")}
    return {}


def _lower_combo(cfg: ModelConfig, shape: InputShape, mesh, ctx, grad_accum: int):
    """Build + lower the right step function for one combo (no compile)."""
    tp = mesh.shape["model"]
    if shape.kind == "train":
        ga = grad_accum
        opt = adamw(3e-4)
        settings = TrainSettings(grad_accum=ga)
        state_sds = jax.eval_shape(
            lambda: init_state(cfg, jax.random.key(0), opt, tp)
        )
        sspecs = state_specs(state_sds, cfg, ctx)
        state_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, s), sspecs,
            is_leaf=lambda x: isinstance(x, P),
        )
        batch_sds = train_batch_specs(cfg, shape, ga)
        batch_sh = _batch_shardings(cfg, mesh, ctx, batch_sds, ga)
        step = make_train_step(cfg, ctx, opt, settings)
        jitted = jax.jit(
            step, in_shardings=(state_sh, batch_sh), out_shardings=(state_sh, None)
        )
        lowered = jitted.lower(state_sds, batch_sds)
    elif shape.kind == "prefill":
        params_sds = jax.eval_shape(
            lambda: transformer.init_params(cfg, jax.random.key(0), tp)
        )
        pspecs = transformer.param_specs(params_sds, cfg, ctx, zero1=False)
        params_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, s), pspecs,
            is_leaf=lambda x: isinstance(x, P),
        )
        batch_sds = prefill_batch_specs(cfg, shape)
        batch_sh = _batch_shardings(cfg, mesh, ctx, batch_sds, 1)

        def prefill_fn(params, batch):
            return transformer.prefill(params, cfg, batch, shape.seq_len, ctx)

        jitted = jax.jit(prefill_fn, in_shardings=(params_sh, batch_sh))
        lowered = jitted.lower(params_sds, batch_sds)
    else:  # decode
        params_sds = jax.eval_shape(
            lambda: transformer.init_params(cfg, jax.random.key(0), tp)
        )
        pspecs = transformer.param_specs(params_sds, cfg, ctx, zero1=False)
        params_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, s), pspecs,
            is_leaf=lambda x: isinstance(x, P),
        )
        cache_sds = jax.eval_shape(
            lambda: transformer.init_cache(
                cfg, shape.global_batch, shape.seq_len, ctx, tp
            )
        )
        cspecs = transformer.cache_specs(cfg, ctx)
        cache_sh = tuple(
            {k: NamedSharding(mesh, v) for k, v in c.items()} for c in cspecs
        )
        tok_sds = decode_token_specs(cfg, shape)
        tok_sh = NamedSharding(
            mesh, ctx.spec(*(("batch",) + (None,) * (tok_sds.ndim - 1)))
        )
        pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
        serve_step = make_serve_step(cfg, ctx)
        jitted = jax.jit(
            serve_step,
            in_shardings=(params_sh, cache_sh, tok_sh, NamedSharding(mesh, P())),
            out_shardings=(None, None, cache_sh),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(params_sds, cache_sds, tok_sds, pos_sds)

    return lowered


def _cost_tuple(compiled) -> tuple[float, float, float]:
    """(flops_per_dev, bytes_per_dev, collective_bytes_per_dev)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = roofline_lib.collective_bytes(compiled.as_text())
    return (
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        float(sum(coll.values())),
    )


# depth pair used for the unrolled roofline extrapolation (costs are exactly
# linear in depth under full unroll, so the smallest pair suffices)
_ROOFLINE_DEPTHS = (1, 2)


def dryrun_one(arch: str, shape_name: str, multi_pod: bool) -> dict:
    """One (arch x shape x mesh) combination.

    Two kinds of compile:
      1. PRODUCTION compile — full depth, scans as scans, real grad-accum:
         proves lowering/SPMD coherence and yields memory_analysis().
      2. ROOFLINE compiles — depth R=2 and R=4 variants with every scan
         fully unrolled (cost_analysis counts while bodies once; unrolled
         trip-1 loops are exact), ga=1; FLOPs/bytes/collective-bytes are
         exactly linear in depth, so extrapolate to the full depth.
    """
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = transformer.make_ctx(mesh, cfg, overrides=_rules_overrides(shape))
    ga = GRAD_ACCUM[arch] if shape.kind == "train" else 1

    # --- production compile ---
    t0 = time.time()
    lowered = _lower_combo(cfg, shape, mesh, ctx, ga)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = _memory_analysis_dict(compiled)
    coll_prod = roofline_lib.collective_bytes(compiled.as_text())

    if multi_pod:
        # multi-pod pass proves the "pod" axis shards (lower+compile);
        # the roofline table is single-pod only (see brief) — skip the
        # unrolled roofline compiles here.
        return {
            "arch": arch, "shape": shape_name, "mesh": "2x16x16",
            "chips": chips(mesh),
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
            "memory_analysis": mem,
            "collectives_production_hlo": coll_prod,
            "roofline": None,
            "grad_accum": ga if shape.kind == "train" else None,
            "ok": True,
        }

    # --- roofline compiles (reduced depth, fully unrolled, ga=1) ---
    import dataclasses as _dc

    from repro.models.unroll import unrolled

    plen = len(cfg.pattern)
    costs = {}
    with unrolled():
        for rr in _ROOFLINE_DEPTHS:
            cfg_r = _dc.replace(cfg, name=f"{cfg.name}@r{rr}", num_layers=rr * plen)
            # ga=1 keeps the unrolled roofline compile tractable; the one
            # thing it misses vs production is (ga-1) extra parameter
            # re-reads per step, corrected analytically below.
            lr = _lower_combo(cfg_r, shape, mesh, ctx, 1)
            costs[rr] = _cost_tuple(lr.compile())
    r_full = cfg.num_repeats
    r1, r2 = _ROOFLINE_DEPTHS
    per_layer = tuple((b - a) / (r2 - r1) for a, b in zip(costs[r1], costs[r2]))
    full = tuple(a + (r_full - r1) * d for a, d in zip(costs[r1], per_layer))
    flops_dev, bytes_dev, coll_dev = full
    if shape.kind == "train" and ga > 1:
        tp = mesh.shape["model"]
        bytes_dev += (ga - 1) * cfg.param_count() * 2 / tp  # bf16 re-reads

    nchips = chips(mesh)
    rf = roofline_lib.Roofline(
        flops_total=flops_dev * nchips,
        hbm_bytes_total=bytes_dev * nchips,
        collective_bytes_per_chip=coll_dev,
        chips=nchips,
    )
    mf = roofline_lib.model_flops(cfg, shape)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": nchips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "collectives_production_hlo": coll_prod,
        "roofline": rf.as_dict(),
        "roofline_depths": {str(r): costs[r] for r in costs},
        "model_flops": mf,
        "useful_flops_ratio": mf / rf.flops_total if rf.flops_total else None,
        "grad_accum": ga if shape.kind == "train" else None,
        "ok": True,
    }
    return result


def dryrun_fdsvrg(multi_pod: bool) -> dict:
    """The paper's own workload at kdd2010 scale: FD-SVRG outer iteration
    with w feature-sharded over all chips."""
    from repro.core.fdsvrg_shardmap import (
        FDSVRGShardedConfig, input_shardings, make_outer_iteration,
    )

    mesh = make_production_mesh(multi_pod=multi_pod)
    q = chips(mesh)
    d = 29_890_095  # kdd2010 dimensionality
    d_pad = ((d + q - 1) // q) * q
    n, nnz, m, u = 65_536, 32, 256, 64  # instance window per outer iteration
    cfg = FDSVRGShardedConfig(
        dim=d_pad, num_instances=n, nnz_max=nnz, eta=0.1,
        inner_steps=m, batch_size=u,
    )
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    step = make_outer_iteration(mesh, cfg, feature_axes=axes)
    from repro.data.block_csr import aot_nnz_budget

    bnnz = aot_nnz_budget(nnz, q)  # block-local stacked rows, nnz/q + skew slack
    w = jax.ShapeDtypeStruct((d_pad,), jnp.float32)
    idx = jax.ShapeDtypeStruct((q, n, bnnz), jnp.int32)
    val = jax.ShapeDtypeStruct((q, n, bnnz), jnp.float32)
    lab = jax.ShapeDtypeStruct((n,), jnp.float32)
    samples = jax.ShapeDtypeStruct((m, u), jnp.int32)
    t0 = time.time()
    lowered = step.lower(w, idx, val, lab, samples)
    compiled = lowered.compile()
    rf = roofline_lib.from_compiled(compiled, q)
    return {
        "arch": "fdsvrg-kdd2010",
        "shape": f"outer(N={n},M={m},u={u})",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": q,
        "compile_s": round(time.time() - t0, 2),
        "memory_analysis": _memory_analysis_dict(compiled),
        "collectives": roofline_lib.collective_bytes(compiled.as_text()),
        "roofline": rf.as_dict(),
        "ok": True,
    }


def dryrun_smoke() -> dict:
    """ONE reduced arch x mesh combo, fast enough for CI: smollm-360m at
    CPU-smoke scale on a 2x4 host mesh (the tests/test_dryrun_small.py
    shape).  Gives benchmarks.roofline at least one real compiled row to
    render when results/dryrun/ is empty."""
    import dataclasses

    from repro.configs import reduced_config
    from repro.dist.compat import make_mesh

    arch = "smollm-360m"
    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = dataclasses.replace(reduced_config(get_config(arch)), ssm_chunk=16)
    shape = InputShape("train_64", 64, 8, "train")
    ctx = transformer.make_ctx(mesh, cfg, overrides=_rules_overrides(shape))
    t0 = time.time()
    lowered = _lower_combo(cfg, shape, mesh, ctx, 1)
    compiled = lowered.compile()
    rf = roofline_lib.from_compiled(compiled, chips=8)
    return {
        "arch": f"{arch}-reduced",
        "shape": "train(seq=64,batch=8)",
        "mesh": "2x4",
        "chips": 8,
        "compile_s": round(time.time() - t0, 2),
        "memory_analysis": _memory_analysis_dict(compiled),
        "collectives": roofline_lib.collective_bytes(compiled.as_text()),
        "roofline": rf.as_dict(),
        "ok": True,
    }


def combos():
    for arch in sorted(ARCHS):
        for shape_name in INPUT_SHAPES:
            if shape_name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                continue
            yield arch, shape_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--fdsvrg", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="one reduced arch x mesh combo on 8 host devices")
    ap.add_argument("--out-dir", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    out_dir = args.out_dir or os.path.abspath(RESULTS_DIR)
    os.makedirs(out_dir, exist_ok=True)

    if args.smoke:
        path = os.path.join(out_dir, "smoke__train_64__2x4.json")
        try:
            res = dryrun_smoke()
            rl = res["roofline"]
            print(f"[OK] smoke: compile={res['compile_s']}s "
                  f"dominant={rl['dominant']}", flush=True)
            failures = 0
        except Exception as e:
            res = {
                "arch": "smollm-360m-reduced", "shape": "train(seq=64,batch=8)",
                "mesh": "2x4", "ok": False, "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:],
            }
            print(f"[FAIL] smoke: {type(e).__name__}: {str(e)[:300]}",
                  flush=True)
            failures = 1
        with open(path, "w") as f:
            json.dump(res, f, indent=2, default=str)
        print(f"done; {failures} failures", flush=True)
        return failures

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    jobs = []
    if args.fdsvrg:
        jobs = [("fdsvrg", None)]
    elif args.arch and args.shape:
        jobs = [(args.arch, args.shape)]
    elif args.arch:
        jobs = [(a, s) for a, s in combos() if a == args.arch]
    else:
        jobs = list(combos())

    failures = 0
    for arch, shape_name in jobs:
        for mp in meshes:
            mesh_tag = "2x16x16" if mp else "16x16"
            tag = f"{arch}__{shape_name or 'paper'}__{mesh_tag}"
            path = os.path.join(out_dir, tag + ".json")
            if args.skip_existing and os.path.exists(path):
                try:
                    with open(path) as f:
                        prev = json.load(f)
                    if prev.get("ok"):
                        print(f"[SKIP] {tag}: already done", flush=True)
                        continue
                except Exception:
                    pass
            try:
                if arch == "fdsvrg":
                    res = dryrun_fdsvrg(mp)
                else:
                    res = dryrun_one(arch, shape_name, mp)
                rl = res.get("roofline")
                if rl:
                    print(
                        f"[OK] {tag}: compile={res['compile_s']}s "
                        f"compute={rl['compute_s']:.4f}s memory={rl['memory_s']:.4f}s "
                        f"collective={rl['collective_s']:.4f}s dominant={rl['dominant']}",
                        flush=True,
                    )
                else:
                    print(f"[OK] {tag}: compile={res['compile_s']}s "
                          f"(multi-pod proof; roofline is single-pod)", flush=True)
            except Exception as e:
                failures += 1
                res = {
                    "arch": arch, "shape": shape_name, "mesh": mesh_tag,
                    "ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:],
                }
                print(f"[FAIL] {tag}: {type(e).__name__}: {str(e)[:300]}", flush=True)
            with open(path, "w") as f:
                json.dump(res, f, indent=2, default=str)
    print(f"done; {failures} failures", flush=True)
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
