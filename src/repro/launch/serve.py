"""Serving driver: prefill a batch of prompts, decode greedily.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b \
        --reduced --batch 4 --prompt-len 32 --gen 16

This is the LM-side serving scaffold (token decode against the
transformer/SSM stacks).  Serving for the paper's *linear classifiers*
— batched sparse margins with online ``partial_fit`` interleaving —
lives in :mod:`repro.serve` (see ``examples/serve_linear.py``).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import transformer
from repro.sharding.specs import unsharded_ctx
from repro.train.serve import make_serve_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    ctx = unsharded_ctx()
    params = transformer.init_params(cfg, jax.random.key(0), tp=1)
    rng = np.random.default_rng(0)
    max_len = args.prompt_len + args.gen + (cfg.num_patches or 0)

    if cfg.modality == "audio-codec":
        prompt = rng.integers(0, cfg.vocab_size,
                              size=(args.batch, args.prompt_len, cfg.num_codebooks))
        batch = {"tokens": jnp.asarray(prompt, jnp.int32)}
    elif cfg.modality == "vision":
        prompt = rng.integers(0, cfg.vocab_size, size=(args.batch, args.prompt_len))
        batch = {
            "tokens": jnp.asarray(prompt, jnp.int32),
            "patch_embeds": jnp.asarray(
                rng.normal(0, 1, size=(args.batch, cfg.num_patches, cfg.frontend_dim)),
                jnp.float32,
            ),
        }
    else:
        prompt = rng.integers(0, cfg.vocab_size, size=(args.batch, args.prompt_len))
        batch = {"tokens": jnp.asarray(prompt, jnp.int32)}

    t0 = time.perf_counter()
    last_logits, cache = transformer.prefill(params, cfg, batch, max_len, ctx)
    print(f"prefill: {args.batch}x{args.prompt_len} in {time.perf_counter()-t0:.2f}s")

    serve_step = jax.jit(make_serve_step(cfg, ctx))
    pos0 = args.prompt_len + (cfg.num_patches or 0)
    tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
    if cfg.modality == "audio-codec":
        tok = tok.reshape(args.batch, 1, cfg.num_codebooks)
    outs = []
    t0 = time.perf_counter()
    for i in range(args.gen):
        pos = jnp.asarray(pos0 + i, jnp.int32)
        tok, logits, cache = serve_step(params, cache, tok, pos)
        outs.append(np.asarray(tok))
    dt = time.perf_counter() - t0
    print(f"decode: {args.gen} steps x batch {args.batch} in {dt:.2f}s "
          f"({dt/args.gen*1000:.1f} ms/token)")
    gen = np.concatenate(outs, axis=1)
    print("generated ids (first request):", gen[0].flatten()[:24].tolist())
    return gen


if __name__ == "__main__":
    main()
