"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 100 --batch 8 --seq 256 [--reduced] [--optimizer adamw]
        [--svrg-anchor-every 50] [--ckpt /tmp/ck]

Runs on whatever devices exist (1 CPU here; the production mesh path is
exercised by dryrun.py).  ``--reduced`` selects the smoke-scale variant of
the architecture so a full run fits a laptop.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.data.token_stream import PipelineConfig, batches
from repro.models import transformer
from repro.optim import optimizers
from repro.sharding.specs import unsharded_ctx
from repro.train.loop import TrainSettings, init_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "sgd", "momentum"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    ctx = unsharded_ctx()
    opt = optimizers.OPTIMIZERS[args.optimizer](args.lr)
    settings = TrainSettings(grad_accum=args.grad_accum)
    state = init_state(cfg, jax.random.key(0), opt, tp=1)
    step = jax.jit(make_train_step(cfg, ctx, opt, settings))

    pcfg = PipelineConfig(args.batch, args.seq, grad_accum=args.grad_accum)
    it = batches(cfg, pcfg)
    n_params = sum(p.size for p in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M steps={args.steps}")

    t0 = time.perf_counter()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, metrics = step(state, batch)
        if (i + 1) % args.log_every == 0 or i == 0:
            dt = time.perf_counter() - t0
            print(
                f"step {i+1:5d} loss={float(metrics['loss']):.4f} "
                f"ce={float(metrics['ce']):.4f} "
                f"gnorm={float(metrics.get('grad_norm', 0.0)):.3f} "
                f"({dt/(i+1):.2f}s/step)",
                flush=True,
            )
    if args.ckpt:
        from repro.checkpoint import ckpt

        ckpt.save(args.ckpt, state)
        print(f"saved checkpoint to {args.ckpt}.npz")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
