"""ShapeDtypeStruct stand-ins for every model input (dry-run contract).

``input_specs(cfg, shape)`` returns exactly the pytrees the jitted step
functions consume — weak-type-correct, shardable, zero allocation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig

S = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ModelConfig, shape: InputShape, grad_accum: int = 1) -> dict:
    b, s = shape.global_batch, shape.seq_len

    def shaped(*dims, dtype=jnp.int32):
        if grad_accum > 1:
            assert b % grad_accum == 0, (cfg.name, b, grad_accum)
            dims = (grad_accum, b // grad_accum) + dims[1:]
        return S(dims, dtype)

    if cfg.modality == "audio-codec":
        return {
            "tokens": shaped(b, s, cfg.num_codebooks),
            "labels": shaped(b, s, cfg.num_codebooks),
        }
    if cfg.modality == "vision":
        return {
            "tokens": shaped(b, s - cfg.num_patches),
            "patch_embeds": shaped(b, cfg.num_patches, cfg.frontend_dim, dtype=jnp.float32),
            "labels": shaped(b, s),
        }
    return {"tokens": shaped(b, s), "labels": shaped(b, s)}


def decode_token_specs(cfg: ModelConfig, shape: InputShape) -> jax.ShapeDtypeStruct:
    b = shape.global_batch
    if cfg.modality == "audio-codec":
        return S((b, 1, cfg.num_codebooks), jnp.int32)
    return S((b, 1), jnp.int32)


def prefill_batch_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.modality == "audio-codec":
        return {"tokens": S((b, s, cfg.num_codebooks), jnp.int32)}
    if cfg.modality == "vision":
        return {
            "tokens": S((b, s - cfg.num_patches), jnp.int32),
            "patch_embeds": S((b, cfg.num_patches, cfg.frontend_dim), jnp.float32),
        }
    return {"tokens": S((b, s), jnp.int32)}
