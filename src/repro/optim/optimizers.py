"""Hand-rolled optimizers (no optax in this container).

Optax-like API: ``init(params) -> state``, ``update(grads, state, params)
-> (updates, state)``; apply with ``apply_updates``.  All states are f32
pytrees mirroring the parameter tree, so the ZeRO-1 parameter sharding
specs apply verbatim to optimizer state.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def _zeros_like_f32(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params):
        return jax.tree.map(lambda g: -lr * g.astype(jnp.float32), grads), state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return {"m": _zeros_like_f32(params)}

    def update(grads, state, params):
        m = jax.tree.map(
            lambda mi, g: beta * mi + g.astype(jnp.float32), state["m"], grads
        )
        return jax.tree.map(lambda mi: -lr * mi, m), {"m": m}

    return Optimizer(init, update)


def adamw(
    lr: float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params):
        return {
            "m": _zeros_like_f32(params),
            "v": _zeros_like_f32(params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        t = state["t"] + 1
        m = jax.tree.map(
            lambda mi, g: b1 * mi + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads,
        )
        v = jax.tree.map(
            lambda vi, g: b2 * vi + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads,
        )
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(mi, vi, p):
            step = (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return -lr * step

        return jax.tree.map(upd, m, v, params), {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# SVRG for deep models — the paper's optimizer generalized
# ---------------------------------------------------------------------------


class SVRGState(NamedTuple):
    anchor_params: Any  # w̃_0
    anchor_grad: Any  # z = full (large-batch) gradient at the anchor
    inner: Any  # wrapped optimizer state


def svrg(base: Optimizer) -> Optimizer:
    """Variance-reduced wrapper: callers must compute, per step, BOTH the
    minibatch gradient at the current params and at the anchor params, and
    pass ``grads = (g_current, g_anchor)``.  The update applied is

        g_vr = g_current - g_anchor + z      (Algorithm 2 line 7)

    Refresh the anchor with :func:`svrg_refresh` every epoch (outer loop).
    """

    def init(params):
        return SVRGState(
            anchor_params=jax.tree.map(lambda p: p, params),
            anchor_grad=_zeros_like_f32(params),
            inner=base.init(params),
        )

    def update(grads, state: SVRGState, params):
        g_cur, g_anc = grads
        g_vr = jax.tree.map(
            lambda gc, ga, z: gc.astype(jnp.float32)
            - ga.astype(jnp.float32)
            + z,
            g_cur, g_anc, state.anchor_grad,
        )
        updates, inner = base.update(g_vr, state.inner, params)
        return updates, SVRGState(state.anchor_params, state.anchor_grad, inner)

    return Optimizer(init, update)


def svrg_refresh(state: SVRGState, params, full_grad) -> SVRGState:
    return SVRGState(
        anchor_params=jax.tree.map(lambda p: p, params),
        anchor_grad=jax.tree.map(lambda g: g.astype(jnp.float32), full_grad),
        inner=state.inner,
    )


OPTIMIZERS = {"sgd": sgd, "momentum": momentum, "adamw": adamw}
