"""The pluggable update-rule layer: one outer-loop harness, many inner steps.

FD-SVRG is one point in a family of feature-distributed variance-reduced
methods.  What they share is the *shape* the harness
(:func:`repro.core.driver.run_outer_loop`) expects — a ``snapshot`` hook,
an ``epoch`` hook, an ``evaluate`` hook — and the BlockCSR block-local
layout.  What differs is everything an :class:`UpdateRule` owns:

* **per-step state init/carry** — SVRG carries nothing beyond the
  harness's replicated snapshot pair ``(z, s0)``; SAGA carries the
  per-sample scalar gradient table ``α ∈ R^n`` and its running mean
  ``z = (1/n) Σ α_i x_i``; BCD carries the active-block cursor and the
  maintained margins;
* **the variance-reduced direction** — SVRG's
  ``(φ'(s_m) − φ'(s̃_m)) x + z``, SAGA's ``(α_new − α_old) x + z``,
  BCD's full block gradient;
* **the communication it implies** — metered/charged inside the rule's
  ``epoch`` against the §4.5-style closed forms in
  :data:`repro.dist.COSTS`, so the drift guard pins every rule's meter
  to its analytic schedule the same way.

:class:`SVRGRule` is the extraction of the exact code the drivers
``run_serial_svrg`` / ``run_fdsvrg`` used to inline — same jitted scans
(:func:`repro.core.fdsvrg._inner_epoch` and friends stay where the
worker simulation shares them), same metering order, bit-identical by
construction and pinned in ``tests/test_update_rules.py``.

Multi-output ``w ∈ R^{d×k}`` rides the SVRG rule: a ``[N, k]`` label
matrix (e.g. the estimator's one-vs-rest coding, or multivariate squared
loss) vmaps the same jitted epoch over the trailing output axis — one
data matrix, one margin tree per batch carrying ``u·k`` scalars.  ``k=1``
keeps the historical 1-D path untouched (a ``[N, 1]`` label matrix is
squeezed before any compute), so binary runs are bitwise identical.

Import direction: this module imports the jitted building blocks *from*
:mod:`repro.core.fdsvrg`; the drivers there import this module lazily
inside their function bodies.  That keeps the graph acyclic whichever
module is imported first (``repro.core.__init__`` eagerly imports
``fdsvrg``, so a module-level import back into ``repro.optim`` from
there would deadlock the partially-initialized module).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import losses as losses_lib
from repro.core.driver import (
    CheckpointPolicy,
    RecoveryPolicy,
    RunResult,
    draw_samples,
    make_same_iterate_eval,
    optimality_norm,
    option_mask,
    resolve_init_w,
    run_outer_loop,
)
from repro.core.fdsvrg import (
    SVRGConfig,
    _bounds,
    _check_lazy,
    _default_fd_abort,
    _full_grad_blocks,
    _inner_epoch,
    _kernel_lams,
    _lazy_corrections,
    _lazy_inner_epoch,
)
from repro.data.block_csr import BlockCSR, local_margins, local_scatter
from repro.dist import COSTS, Collectives, tree_order_sum


# ---------------------------------------------------------------------------
# Context: everything a rule needs to build its hooks
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RuleContext:
    """One run's immutable inputs, handed to :meth:`UpdateRule.build_*`.

    ``backend=None`` is the serial (unmetered) path — rules must meter
    and charge only when a backend is present, exactly like the
    pre-refactor drivers.  ``num_outputs`` is the trailing output width
    k; 1 is the scalar path (labels are 1-D)."""

    block_data: BlockCSR
    loss: losses_lib.MarginLoss
    reg: losses_lib.Regularizer
    cfg: SVRGConfig
    backend: Collectives | None = None
    num_outputs: int = 1

    @property
    def labels(self) -> jax.Array:
        return self.block_data.labels

    @property
    def n(self) -> int:
        return self.block_data.num_instances

    @property
    def q(self) -> int:
        return self.block_data.num_blocks

    @property
    def u(self) -> int:
        return self.cfg.batch_size

    @property
    def nnz(self) -> int:
        return self.block_data.global_nnz_max()

    @property
    def dtype(self):
        return self.block_data.values[0].dtype


def make_context(
    block_data: BlockCSR,
    loss: losses_lib.MarginLoss,
    reg: losses_lib.Regularizer,
    cfg: SVRGConfig,
    *,
    backend: Collectives | None = None,
) -> RuleContext:
    """Build a :class:`RuleContext`, deriving the output width from the
    labels: a ``[N, k]`` label matrix means ``w ∈ R^{d×k}``; ``[N, 1]``
    is squeezed onto the scalar path so k=1 stays bitwise identical to a
    1-D label run."""
    labels = block_data.labels
    num_outputs = 1
    if getattr(labels, "ndim", 1) == 2:
        num_outputs = int(labels.shape[1])
        if num_outputs == 1:
            block_data = dataclasses.replace(block_data, labels=labels[:, 0])
            num_outputs = 1
    if backend is not None and backend.q != block_data.num_blocks:
        raise ValueError(
            f"backend has q={backend.q} workers but block_data has "
            f"{block_data.num_blocks} blocks"
        )
    return RuleContext(
        block_data=block_data,
        loss=loss,
        reg=reg,
        cfg=cfg,
        backend=backend,
        num_outputs=num_outputs,
    )


# ---------------------------------------------------------------------------
# The protocol
# ---------------------------------------------------------------------------


class UpdateRule:
    """Base class: a rule owns its state carry, direction, and comm.

    ``build_snapshot`` / ``build_epoch`` / ``build_evaluate`` are called
    once per run and return the harness hooks; state that must carry
    *across* epochs but is not part of the harness's replicated snapshot
    (SAGA's table, BCD's cursor) lives in the epoch closure.  The
    capability flags mirror the registry's :class:`MethodInfo` record —
    :func:`run_with_rule` enforces them for direct (non-registry)
    callers too.
    """

    name: str = "update_rule"
    supports_recovery: bool = False  # epoch-abort-to-snapshot retries
    supports_checkpoint: bool = False
    supports_multi_output: bool = False
    supports_option_ii: bool = False

    def validate(self, ctx: RuleContext) -> None:
        if ctx.num_outputs > 1 and not self.supports_multi_output:
            raise ValueError(
                f"rule {self.name!r} does not support multi-output labels "
                f"(got a [N, {ctx.num_outputs}] label matrix)"
            )
        if ctx.cfg.option == "II" and not self.supports_option_ii:
            raise ValueError(
                f"rule {self.name!r} runs Option I only; option='II' "
                "would not be honored"
            )

    def build_snapshot(self, ctx: RuleContext) -> Callable:
        raise NotImplementedError

    def build_epoch(self, ctx: RuleContext) -> Callable:
        raise NotImplementedError

    def build_evaluate(self, ctx: RuleContext) -> Callable:
        return make_same_iterate_eval(ctx.labels, ctx.loss, ctx.reg, ctx.cfg.eta)

    def build_init_w(self, ctx: RuleContext, init_w) -> jax.Array:
        return resolve_init_w(
            init_w, ctx.block_data.dim, ctx.dtype, ctx.num_outputs
        )

    def default_abort(self, ctx: RuleContext) -> Callable | None:
        return None


def run_with_rule(
    rule: UpdateRule,
    ctx: RuleContext,
    *,
    init_w=None,
    recovery: RecoveryPolicy | None = None,
    checkpoint: CheckpointPolicy | None = None,
) -> RunResult:
    """Wire one rule into the ONE outer-loop harness and run it."""
    rule.validate(ctx)
    if recovery is not None and not rule.supports_recovery:
        raise ValueError(
            f"rule {rule.name!r} does not support epoch-abort recovery: "
            "its carried state (gradient table / block cursor) advances "
            "inside the epoch, so a snapshot retry would replay against "
            "mutated state"
        )
    if checkpoint is not None and not rule.supports_checkpoint:
        raise ValueError(
            f"rule {rule.name!r} does not support checkpoint/resume: the "
            "harness checkpoint only persists (w, z, s0), not the rule's "
            "carried state"
        )
    if recovery is not None and recovery.on_abort is None \
            and ctx.backend is not None:
        on_abort = rule.default_abort(ctx)
        if on_abort is not None:
            recovery = dataclasses.replace(recovery, on_abort=on_abort)
    return run_outer_loop(
        outer_iters=ctx.cfg.outer_iters,
        seed=ctx.cfg.seed,
        init_w=rule.build_init_w(ctx, init_w),
        snapshot=rule.build_snapshot(ctx),
        epoch=rule.build_epoch(ctx),
        evaluate=rule.build_evaluate(ctx),
        backend=ctx.backend,
        recovery=recovery,
        checkpoint=checkpoint,
    )


# ---------------------------------------------------------------------------
# SVRG (the extracted rule — bit-identical to the pre-refactor drivers)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SVRGRule(UpdateRule):
    """Prox-SVRG: snapshot pair (z, s0) is the whole state; the harness's
    rotation carries it.  ``use_kernels`` / ``lazy_updates`` select the
    fused-Pallas and delayed-decay inner scans exactly as the drivers'
    keyword arguments always did (scalar path only — the kernels have no
    trailing output axis)."""

    use_kernels: bool = False
    lazy_updates: str | None = None

    name = "svrg"
    supports_recovery = True
    supports_checkpoint = True
    supports_multi_output = True
    supports_option_ii = True

    def validate(self, ctx: RuleContext) -> None:
        super().validate(ctx)
        _check_lazy(self.lazy_updates)
        if ctx.num_outputs > 1 and (self.use_kernels or self.lazy_updates):
            raise ValueError(
                "multi-output labels run the jnp inner step only: "
                "use_kernels/lazy_updates have no trailing-k kernels "
                f"(got k={ctx.num_outputs})"
            )

    def default_abort(self, ctx: RuleContext) -> Callable | None:
        return _default_fd_abort(
            ctx.n * ctx.num_outputs, ctx.nnz, ctx.q
        )

    def build_snapshot(self, ctx: RuleContext) -> Callable:
        bd, loss_name = ctx.block_data, ctx.loss.name
        use_kernels = self.use_kernels

        def snapshot(w):
            return _full_grad_blocks(
                bd.indices, bd.values, bd.labels, w,
                loss_name, bd.block_dims, use_kernels,
            )

        if ctx.num_outputs == 1:
            return snapshot

        def one(labels_j, w_j):
            return _full_grad_blocks(
                bd.indices, bd.values, labels_j, w_j,
                loss_name, bd.block_dims, False,
            )

        multi = jax.vmap(one, in_axes=(1, 1), out_axes=(1, 1))

        def snapshot_multi(w):
            return multi(bd.labels, w)

        return snapshot_multi

    def build_epoch(self, ctx: RuleContext) -> Callable:
        bd, cfg, backend, loss, reg = (
            ctx.block_data, ctx.cfg, ctx.backend, ctx.loss, ctx.reg,
        )
        use_kernels, lazy_updates = self.use_kernels, self.lazy_updates
        kernel_lams = _kernel_lams(reg, use_kernels)
        corrections = _lazy_corrections(bd, ctx.n, ctx.u, lazy_updates)
        n, u, nnz, q, k = ctx.n, ctx.u, ctx.nnz, ctx.q, ctx.num_outputs
        labels, block_dims = bd.labels, bd.block_dims

        multi_epoch = _bind_multi_epoch(ctx) if k > 1 else None

        def epoch(t, rng, w, z_data, s0, eta_scale=1.0):
            # --- full-gradient phase (Alg 1 lines 3-5): account the
            # snapshot gradient this outer iteration consumes ---
            if backend is not None:
                backend.meter_tree(payload=n * k)
                backend.charge_cost(COSTS.fd_fullgrad(n=n, nnz=nnz, q=q, k=k))
            # eta stays a traced operand, so divergence backoff
            # (eta_scale < 1) reuses the compiled scan; eta * 1.0 is
            # bit-exact on the default path.
            eta = cfg.eta * eta_scale
            samples = draw_samples(rng, n, cfg.inner_steps, u)
            mask = option_mask(rng, cfg.inner_steps, cfg.option)
            if multi_epoch is not None:
                w = multi_epoch(
                    labels, w, z_data, s0,
                    jnp.asarray(samples), eta, jnp.asarray(mask),
                )
            elif lazy_updates is not None:
                w = _lazy_inner_epoch(
                    bd.indices, bd.values, labels,
                    w, z_data, s0,
                    jnp.asarray(samples), eta, jnp.asarray(mask),
                    corrections, loss.name, reg.name, reg.lam, block_dims,
                    use_kernels, lazy_updates, lam2=reg.lam2,
                    kernel_lams=kernel_lams,
                )
            else:
                w = _inner_epoch(
                    bd.indices, bd.values, labels,
                    w, z_data, s0,
                    jnp.asarray(samples), eta, jnp.asarray(mask),
                    loss.name, reg.name, reg.lam, block_dims, use_kernels,
                    lam2=reg.lam2, kernel_lams=kernel_lams,
                )
            # --- inner-loop communication (Alg 1 lines 9-11): one tree
            # round per mini-batch of u·k margins; M steps, in aggregate.
            if backend is not None:
                backend.meter_tree(payload=u * k, steps=cfg.inner_steps)
                backend.charge_cost(
                    COSTS.fd_inner_step(nnz=nnz, q=q, u=u, k=k),
                    steps=cfg.inner_steps,
                )
            return w

        return epoch

    def build_evaluate(self, ctx: RuleContext) -> Callable:
        if ctx.num_outputs == 1:
            return super().build_evaluate(ctx)
        labels, loss, reg, eta, k = (
            ctx.labels, ctx.loss, ctx.reg, ctx.cfg.eta, ctx.num_outputs,
        )

        def evaluate(w, z_data, s0):
            # Mean-per-output objective: the data term averages over all
            # N·k margins, so g(w) is divided by k to match — for k=1
            # this is exactly the scalar objective, and for independent
            # columns it is the average of the k per-column objectives.
            obj = float(
                jnp.mean(loss.value(s0, labels)) + reg.value(w) / k
            )
            return obj, optimality_norm(z_data, w, reg, eta)

        return evaluate


def _bind_multi_epoch(ctx: RuleContext) -> Callable:
    """vmap the scalar jnp inner epoch over the trailing output axis:
    labels/w/z/s0 batch on axis 1, the sample stream and step mask are
    shared (one margin tree per batch carries u·k scalars)."""
    bd, loss, reg = ctx.block_data, ctx.loss, ctx.reg
    block_dims = bd.block_dims

    def one(labels_j, w_j, z_j, s0_j, samples, eta, mask):
        return _inner_epoch(
            bd.indices, bd.values, labels_j, w_j, z_j, s0_j,
            samples, eta, mask,
            loss.name, reg.name, reg.lam, block_dims, False,
            lam2=reg.lam2, kernel_lams=None,
        )

    return jax.vmap(
        one, in_axes=(1, 1, 1, 1, None, None, None), out_axes=1
    )


# ---------------------------------------------------------------------------
# FD-SAGA: replicated scalar gradient table (n floats, never d)
# ---------------------------------------------------------------------------


# lam traced / lam2 static, mirroring _inner_epoch (lambda sweeps reuse
# one compiled scan).
@functools.partial(
    jax.jit, static_argnames=("loss_name", "reg_name", "block_dims", "lam2")
)
def _saga_inner_epoch(
    block_indices,  # per-block int32[N, nnz_l], LOCAL ids
    block_values,  # per-block float[N, nnz_l]
    labels,
    w0,
    z0,  # running table mean (1/n) sum_i alpha_i x_i, concatenated blocks
    alpha0,  # float[n] per-sample margin-derivative table
    samples,  # int32[M, u]
    eta,
    loss_name: str,
    reg_name: str,
    lam,
    block_dims: tuple[int, ...],
    lam2: float = 0.0,
):
    """M FD-SAGA steps on the block-local layout.

    Per step: the sampled margins are computed the feature-distributed
    way (per-block partial dots summed in tree order — u scalars on the
    wire, same schedule as the SVRG step), the direction is
    ``mean_i (α_new_i − α_old_i) x_i + z + ∇g_smooth`` followed by the
    prox, and the table/mean are updated in place.  The table is *per
    sample* scalars, so every worker holds all n floats (replicating it
    costs one N-payload tree at init); the mean z is feature-partitioned
    like w.  Duplicate draws inside one mini-batch count toward the
    direction (iid sampling keeps it unbiased) but only their first
    occurrence updates the table and its mean, so the invariant
    ``z == (1/n) Σ α_i x_i`` holds exactly at every step.
    """
    loss = losses_lib.LOSSES[loss_name]
    reg = losses_lib.Regularizer(reg_name, lam, lam2)
    u = samples.shape[1]
    n = labels.shape[0]
    q = len(block_dims)
    bounds = _bounds(block_dims)

    def step(carry, ids):
        w, z, alpha = carry
        y = labels[ids]
        rows = [(block_indices[l][ids], block_values[l][ids]) for l in range(q)]
        parts = [
            local_margins(
                rows[l][0], rows[l][1],
                jax.lax.slice_in_dim(w, bounds[l], bounds[l + 1]),
            )
            for l in range(q)
        ]
        s_m = tree_order_sum(parts)
        a_new = loss.dvalue(s_m, y)
        delta = a_new - alpha[ids]
        # First-occurrence mask over the u drawn ids (u is small; the
        # u×u comparison is trivial) — duplicates must not double-count
        # in the table mean.
        eq = ids[:, None] == ids[None, :]
        is_first = jnp.argmax(eq, axis=1) == jnp.arange(u)
        coef_dir = delta / u
        coef_tab = jnp.where(is_first, delta, 0.0) / n
        new_w, new_z = [], []
        for l in range(q):
            idx, val = rows[l]
            w_blk = jax.lax.slice_in_dim(w, bounds[l], bounds[l + 1])
            z_blk = jax.lax.slice_in_dim(z, bounds[l], bounds[l + 1])
            g = local_scatter(idx, val, coef_dir, block_dims[l])
            g = g + z_blk + reg.smooth_grad(w_blk)
            new_w.append(reg.prox(w_blk - eta * g, eta))
            new_z.append(
                z_blk + local_scatter(idx, val, coef_tab, block_dims[l])
            )
        w_next = jnp.concatenate(new_w) if q > 1 else new_w[0]
        z_next = jnp.concatenate(new_z) if q > 1 else new_z[0]
        alpha_next = alpha.at[ids].set(a_new)
        return (w_next, z_next, alpha_next), None

    (w_final, z_final, alpha_final), _ = jax.lax.scan(
        step, (w0, z0, alpha0), samples
    )
    return w_final, z_final, alpha_final


class SAGARule(UpdateRule):
    """Feature-distributed SAGA (Distributed SAGA, arXiv 1705.10405).

    State carry: the n-float margin-derivative table α and its running
    mean z, initialized from the outer-0 harness snapshot — ``α =
    φ'(s0, y)`` and ``z = z_data`` are *exactly* the snapshot pair's
    content, so initialization is one full-gradient-shaped phase
    (:meth:`CostModel.fd_saga_init`), charged once.  After that no
    full-gradient phase ever recurs: the harness's per-outer snapshots
    are reporting-only (compute, never metered), and each of the M
    steps meters one u-payload tree + 3 sparse passes
    (:meth:`CostModel.fd_saga_step`).
    """

    name = "fd_saga"
    supports_recovery = False  # the table advances inside the epoch
    supports_checkpoint = False
    supports_multi_output = False
    supports_option_ii = False

    def build_snapshot(self, ctx: RuleContext) -> Callable:
        bd, loss_name = ctx.block_data, ctx.loss.name

        def snapshot(w):
            return _full_grad_blocks(
                bd.indices, bd.values, bd.labels, w,
                loss_name, bd.block_dims, False,
            )

        return snapshot

    def build_epoch(self, ctx: RuleContext) -> Callable:
        bd, cfg, backend, loss, reg = (
            ctx.block_data, ctx.cfg, ctx.backend, ctx.loss, ctx.reg,
        )
        n, u, nnz, q = ctx.n, ctx.u, ctx.nnz, ctx.q
        labels, block_dims = bd.labels, bd.block_dims
        state: dict = {}

        def epoch(t, rng, w, z_data, s0, eta_scale=1.0):
            if "alpha" not in state:
                # Outer 0: adopt the harness snapshot as the table —
                # z_data IS (1/n) Σ φ'(s0_i, y_i) x_i, bit-for-bit.
                state["alpha"] = loss.dvalue(s0, labels)
                state["z"] = z_data
                if backend is not None:
                    backend.meter_tree(payload=n)
                    backend.charge_cost(COSTS.fd_saga_init(n=n, nnz=nnz, q=q))
            eta = cfg.eta * eta_scale
            samples = draw_samples(rng, n, cfg.inner_steps, u)
            w, z, alpha = _saga_inner_epoch(
                bd.indices, bd.values, labels,
                w, state["z"], state["alpha"],
                jnp.asarray(samples), eta,
                loss.name, reg.name, reg.lam, block_dims, lam2=reg.lam2,
            )
            state["z"], state["alpha"] = z, alpha
            if backend is not None:
                backend.meter_tree(payload=u, steps=cfg.inner_steps)
                backend.charge_cost(
                    COSTS.fd_saga_step(nnz=nnz, q=q, u=u),
                    steps=cfg.inner_steps,
                )
            return w

        return epoch


# ---------------------------------------------------------------------------
# FD-BCD: distributed block coordinate descent (Mahajan et al., 1405.4544)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("loss_name", "reg_name", "lo", "block_dim", "lam2"),
)
def _bcd_block_step(
    idx,  # int32[N, nnz_l] LOCAL ids of the active block
    val,  # float[N, nnz_l]
    labels,
    w,
    s,  # float[N] maintained margins (replicated)
    eta,
    loss_name: str,
    reg_name: str,
    lam,
    lo: int,
    block_dim: int,
    lam2: float = 0.0,
):
    """One BCD step: the active worker takes a prox-gradient step on its
    whole block against the full data gradient restricted to it, then
    the margin delta of the block update is tree-replicated so every
    worker's maintained margins stay exact."""
    loss = losses_lib.LOSSES[loss_name]
    reg = losses_lib.Regularizer(reg_name, lam, lam2)
    n = labels.shape[0]
    coeffs = loss.dvalue(s, labels) / n
    w_blk = jax.lax.slice_in_dim(w, lo, lo + block_dim)
    g = local_scatter(idx, val, coeffs, block_dim) + reg.smooth_grad(w_blk)
    w_new_blk = reg.prox(w_blk - eta * g, eta)
    s_next = s + local_margins(idx, val, w_new_blk - w_blk)
    w_next = jax.lax.dynamic_update_slice_in_dim(w, w_new_blk, lo, axis=0)
    return w_next, s_next


class BCDRule(UpdateRule):
    """Distributed block coordinate descent — the paper's natural L1
    competitor (Mahajan et al., arXiv 1405.4544), on the same BlockCSR
    column partition as FD-SVRG.

    State carry: the active-block cursor (cycling; it survives across
    outers so M need not be a multiple of q) plus the maintained margins
    — re-seeded each epoch from the harness snapshot's ``s0``, which is
    exactly the margins at the epoch-entry iterate.  Each step meters
    one N-payload tree (the block's margin delta must reach every
    worker); the sample stream is untouched (BCD is deterministic)."""

    name = "fd_bcd"
    supports_recovery = False  # the cursor advances inside the epoch
    supports_checkpoint = False
    supports_multi_output = False
    supports_option_ii = False

    def build_snapshot(self, ctx: RuleContext) -> Callable:
        bd, loss_name = ctx.block_data, ctx.loss.name

        def snapshot(w):
            return _full_grad_blocks(
                bd.indices, bd.values, bd.labels, w,
                loss_name, bd.block_dims, False,
            )

        return snapshot

    def build_epoch(self, ctx: RuleContext) -> Callable:
        bd, cfg, backend, loss, reg = (
            ctx.block_data, ctx.cfg, ctx.backend, ctx.loss, ctx.reg,
        )
        n, nnz, q = ctx.n, ctx.nnz, ctx.q
        labels, block_dims = bd.labels, bd.block_dims
        bounds = _bounds(block_dims)
        state = {"cursor": 0}

        def epoch(t, rng, w, z_data, s0, eta_scale=1.0):
            eta = cfg.eta * eta_scale
            s = s0
            for m in range(cfg.inner_steps):
                l = (state["cursor"] + m) % q
                idx, val = bd.block(l)
                w, s = _bcd_block_step(
                    idx, val, labels, w, s, eta,
                    loss.name, reg.name, reg.lam,
                    bounds[l], block_dims[l], lam2=reg.lam2,
                )
            state["cursor"] = (state["cursor"] + cfg.inner_steps) % q
            if backend is not None:
                backend.meter_tree(payload=n, steps=cfg.inner_steps)
                backend.charge_cost(
                    COSTS.fd_bcd_step(n=n, nnz=nnz, q=q),
                    steps=cfg.inner_steps,
                )
            return w

        return epoch


RULES = {
    "svrg": SVRGRule,
    "fd_saga": SAGARule,
    "fd_bcd": BCDRule,
}

__all__ = [
    "BCDRule",
    "RULES",
    "RuleContext",
    "SAGARule",
    "SVRGRule",
    "UpdateRule",
    "make_context",
    "run_with_rule",
]
