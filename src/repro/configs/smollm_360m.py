"""smollm-360m [dense] — llama-architecture small model
[hf:HuggingFaceTB/SmolLM-135M family, 360M geometry]."""

from repro.configs.base import LayerTemplate, ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    arch_type="dense",
    source="hf:HuggingFaceTB/SmolLM-135M",
    num_layers=32,
    d_model=960,
    d_ff=2560,
    vocab_size=49_152,
    num_heads=15,
    num_kv_heads=5,  # GQA 3:1
    head_dim=64,
    pattern=(LayerTemplate("global", "dense"),),
    act="silu",
    tie_embeddings=True,
    rope_theta=10_000.0,
)
