"""musicgen-large [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284].

Transformer backbone only: the EnCodec codec is a stub frontend per the
carve-out; the model consumes 4 parallel codebook token streams (summed
embeddings, delay-pattern handling lives in the data pipeline) and emits
4 codebook logit heads.
"""

from repro.configs.base import LayerTemplate, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    arch_type="audio",
    source="arXiv:2306.05284",
    num_layers=48,
    d_model=2048,
    d_ff=8192,
    vocab_size=2048,
    num_heads=32,
    num_kv_heads=32,  # MHA
    head_dim=64,
    pattern=(LayerTemplate("global", "dense"),),
    act="gelu",
    tie_embeddings=False,
    modality="audio-codec",
    num_codebooks=4,
    rope_theta=10_000.0,
)
