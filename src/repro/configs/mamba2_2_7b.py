"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060]."""

from repro.configs.base import LayerTemplate, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    source="arXiv:2405.21060",
    num_layers=64,
    d_model=2560,
    d_ff=0,  # the mamba block subsumes the FFN
    vocab_size=50_280,
    num_heads=0,  # attention-free
    num_kv_heads=0,
    pattern=(LayerTemplate("ssm", "none"),),
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    act="silu",
    tie_embeddings=True,
    supports_long_context=True,  # O(1) state; 500k decode is native
)
