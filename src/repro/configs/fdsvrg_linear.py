"""The paper's own model configs: L2-regularized logistic regression on the
four Table-1 data sets, solved with FD-SVRG (eq. 5)."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LinearConfig:
    name: str
    dataset: str  # repro.data.datasets key
    loss: str = "logistic"
    reg: str = "l2"
    lam: float = 1e-4  # paper §5.3 default
    eta: float = 0.25
    batch_size: int = 1  # paper default; §4.4.1 mini-batch is a flag
    workers: int = 16  # paper: 8 for news20, 16 otherwise
    outer_iters: int = 10


CONFIGS = {
    "fdsvrg-news20": LinearConfig("fdsvrg-news20", "news20", workers=8),
    "fdsvrg-url": LinearConfig("fdsvrg-url", "url"),
    "fdsvrg-webspam": LinearConfig("fdsvrg-webspam", "webspam"),
    "fdsvrg-kdd2010": LinearConfig("fdsvrg-kdd2010", "kdd2010"),
}
