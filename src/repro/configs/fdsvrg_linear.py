"""The paper's own model configs: regularized logistic regression on the
four Table-1 data sets, solved with FD-SVRG (eq. 5).

The objective (paper §2, eq. 3) covers the L1 family too — the classic
sparse-text workload and the regime of Mahajan et al.'s distributed block
coordinate descent for l1-regularized linear classifiers — so alongside
the L2 presets there are L1 / elastic-net variants solved with
FD-Prox-SVRG (same communication, block-local prox).
"""

from __future__ import annotations

import dataclasses

from repro.core import losses


@dataclasses.dataclass(frozen=True)
class LinearConfig:
    name: str
    dataset: str  # repro.data.datasets key
    loss: str = "logistic"
    reg: str = "l2"  # "l2" | "l1" | "elastic_net" | "none"
    lam: float = 1e-4  # paper §5.3 default (L1 strength for l1/elastic_net)
    lam2: float = 0.0  # elastic-net L2 strength
    eta: float = 0.25
    batch_size: int = 1  # paper default; §4.4.1 mini-batch is a flag
    workers: int = 16  # paper: 8 for news20, 16 otherwise
    outer_iters: int = 10

    def regularizer(self) -> losses.Regularizer:
        return losses.Regularizer(self.reg, self.lam, self.lam2)

    def to_spec(self, method: str = "fdsvrg", **overrides):
        """This config as an :class:`repro.api.ExperimentSpec` for any
        registered method — the bridge from the paper's presets to the
        one front door (``solve(cfg.to_spec(method="dsvrg"))``).

        Keyword ``overrides`` replace any spec field (e.g.
        ``outer_iters=2, inner_steps=300`` for a smoke run); the
        config's own eta/batch/workers are the paper's operating point,
        not the registry's scaled-trajectory ``"paper"`` defaults.
        """
        from repro.api.spec import ExperimentSpec  # deferred: configs load early

        kw = dict(
            method=method,
            dataset=self.dataset,
            loss=self.loss,
            reg=self.regularizer(),
            q=self.workers,
            eta=self.eta,
            batch_size=self.batch_size,
            outer_iters=self.outer_iters,
        )
        kw.update(overrides)
        return ExperimentSpec(**kw)


def get_config(name: str) -> LinearConfig:
    """CONFIGS lookup with the registry's one-line error convention —
    a misspelled preset fails with the valid names, not a raw KeyError."""
    try:
        return CONFIGS[name]
    except KeyError:
        raise ValueError(
            f"unknown config {name!r}; available configs: "
            f"{', '.join(sorted(CONFIGS))}"
        ) from None


CONFIGS = {
    "fdsvrg-news20": LinearConfig("fdsvrg-news20", "news20", workers=8),
    "fdsvrg-url": LinearConfig("fdsvrg-url", "url"),
    "fdsvrg-webspam": LinearConfig("fdsvrg-webspam", "webspam"),
    "fdsvrg-kdd2010": LinearConfig("fdsvrg-kdd2010", "kdd2010"),
    # Avazu CTR (d ≈ 10^6 one-hot features, tiny per-row nnz): the
    # ad-click workload of the mxnet feature-distributed exemplar, and
    # the first preset sized for the streaming ingestion path.
    "fdsvrg-avazu": LinearConfig("fdsvrg-avazu", "avazu"),
    # Proximal variants (FD-Prox-SVRG): sparse-text L1 on the two d >> N
    # sets, plus an elastic-net middle ground on webspam.
    "fdsvrg-news20-l1": LinearConfig(
        "fdsvrg-news20-l1", "news20", reg="l1", lam=1e-5, workers=8
    ),
    "fdsvrg-webspam-l1": LinearConfig(
        "fdsvrg-webspam-l1", "webspam", reg="l1", lam=1e-5
    ),
    "fdsvrg-webspam-elastic": LinearConfig(
        "fdsvrg-webspam-elastic", "webspam", reg="elastic_net",
        lam=1e-5, lam2=1e-4,
    ),
}
