"""gemma2-9b [dense] — local+global alternating attention, logit softcaps,
sandwich norms [arXiv:2408.00118]."""

from repro.configs.base import LayerTemplate, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    arch_type="dense",
    source="arXiv:2408.00118",
    num_layers=42,
    d_model=3584,
    d_ff=14336,
    vocab_size=256_000,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    sliding_window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    pattern=(
        LayerTemplate("local", "dense"),
        LayerTemplate("global", "dense"),
    ),
    post_norm=True,
    embed_scale=True,
    act="gelu",
    tie_embeddings=True,
    rope_theta=10_000.0,
    # local layers have a native 4096 window; global layers decode a full
    # (sequence-sharded) cache linearly per token -> long_500k runs.
    supports_long_context=True,
)
