"""minitron-4b [dense] — pruned nemotron [arXiv:2407.14679]."""

from repro.configs.base import LayerTemplate, ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    arch_type="dense",
    source="arXiv:2407.14679",
    num_layers=32,
    d_model=3072,
    d_ff=9216,
    vocab_size=256_000,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    pattern=(LayerTemplate("global", "dense"),),
    act="relu2",  # nemotron squared-ReLU
    mlp_gated=False,  # nemotron plain 2-matrix MLP
    tie_embeddings=False,
    rope_theta=10_000.0,
)
