"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE
[arXiv:2403.19887].

Jamba block structure (period 8): attention at in-block offset 4, Mamba
elsewhere; MoE replaces the dense FFN every other layer (offsets 1,3,5,7).
The paper's mixer is Mamba-1; we implement it with the SSD (Mamba-2)
formulation — same state-space recurrence class, TPU-native chunked scan —
with Jamba's d_state=16 (recorded as a hardware adaptation in DESIGN.md).
"""

from repro.configs.base import LayerTemplate, ModelConfig


def _template(i: int) -> LayerTemplate:
    mixer = "global" if i == 4 else "ssm"
    ffn = "moe" if i % 2 == 1 else "dense"
    return LayerTemplate(mixer, ffn)


CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    source="arXiv:2403.19887",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=65_536,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    pattern=tuple(_template(i) for i in range(8)),
    num_experts=16,
    top_k=2,
    moe_d_ff=14336,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    act="silu",
    tie_embeddings=False,
    rope_theta=10_000.0,  # jamba uses no rope on its single attn layer; kept for uniformity
    supports_long_context=True,  # 4 attention layers carry the KV; mamba is O(1)
)
