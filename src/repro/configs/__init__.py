"""Config registry: ``get_config(arch_id)`` / ``--arch <id>``."""

from __future__ import annotations

import dataclasses

from repro.configs.base import INPUT_SHAPES, InputShape, LayerTemplate, ModelConfig
from repro.configs import (
    fdsvrg_linear,
    gemma2_9b,
    granite_moe_1b_a400m,
    jamba_v0_1_52b,
    mamba2_2_7b,
    minitron_4b,
    musicgen_large,
    olmoe_1b_7b,
    paligemma_3b,
    qwen3_14b,
    smollm_360m,
)

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        paligemma_3b.CONFIG,
        smollm_360m.CONFIG,
        qwen3_14b.CONFIG,
        olmoe_1b_7b.CONFIG,
        musicgen_large.CONFIG,
        jamba_v0_1_52b.CONFIG,
        minitron_4b.CONFIG,
        mamba2_2_7b.CONFIG,
        gemma2_9b.CONFIG,
        granite_moe_1b_a400m.CONFIG,
    ]
}

LINEAR = dict(fdsvrg_linear.CONFIGS)


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def reduced_config(cfg: ModelConfig, tp: int = 1) -> ModelConfig:
    """CPU-smoke-test variant: 1 pattern repeat (>=2 layers), d_model<=512,
    <=4 experts, tiny vocab — same family, same code paths."""
    d_model = min(cfg.d_model, 256)
    num_layers = len(cfg.pattern) if len(cfg.pattern) >= 2 else 2
    heads = 0
    kv = 0
    head_dim = 0
    if cfg.num_heads:
        heads = min(cfg.num_heads, 4)
        kv = max(1, min(cfg.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        head_dim = 32
    experts = min(cfg.num_experts, 4) if cfg.num_experts else 0
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=num_layers,
        d_model=d_model,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=head_dim,
        num_experts=experts,
        # full capacity: keeps reduced-model numerics drop-free so the
        # decode-vs-forward consistency tests are exact
        capacity_factor=float(experts) if experts else cfg.capacity_factor,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        moe_d_ff=min(cfg.moe_d_ff, 128) if cfg.moe_d_ff else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=32 if cfg.ssm_state else 64,
        ssm_chunk=8,
        sliding_window=8 if cfg.sliding_window else None,
        frontend_dim=64 if cfg.frontend_dim else 0,
        num_patches=4 if cfg.num_patches else 0,
        dtype="float32",
    )


__all__ = [
    "ARCHS",
    "LINEAR",
    "INPUT_SHAPES",
    "InputShape",
    "LayerTemplate",
    "ModelConfig",
    "get_config",
    "reduced_config",
]
