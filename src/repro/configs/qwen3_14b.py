"""qwen3-14b [dense] — qk-norm, GQA [hf:Qwen/Qwen3-8B family, 14B geometry]."""

from repro.configs.base import LayerTemplate, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    arch_type="dense",
    source="hf:Qwen/Qwen3-8B",
    num_layers=40,
    d_model=5120,
    d_ff=17408,
    vocab_size=151_936,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    qk_norm=True,
    pattern=(LayerTemplate("global", "dense"),),
    act="silu",
    tie_embeddings=False,
    rope_theta=1_000_000.0,
)
