"""olmoe-1b-7b [moe] — 64 experts, top-8 [arXiv:2409.02060]."""

from repro.configs.base import LayerTemplate, ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    arch_type="moe",
    source="arXiv:2409.02060",
    num_layers=16,
    d_model=2048,
    d_ff=1024,  # (expert hidden; no dense FFN layers in this arch)
    vocab_size=50_304,
    num_heads=16,
    num_kv_heads=16,  # MHA
    head_dim=128,
    pattern=(LayerTemplate("global", "moe"),),
    num_experts=64,
    top_k=8,
    moe_d_ff=1024,
    act="silu",
    tie_embeddings=False,
    rope_theta=10_000.0,
)
