"""paligemma-3b [vlm] — SigLIP + gemma decoder [arXiv:2407.07726].

Language backbone only (gemma-2B geometry); the SigLIP vision tower is a
stub frontend per the assignment carve-out: ``input_specs`` supplies
precomputed patch embeddings [B, 256, 1152] and the model owns the
projector into d_model.
"""

from repro.configs.base import LayerTemplate, ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    arch_type="vlm",
    source="arXiv:2407.07726",
    num_layers=18,
    d_model=2048,
    d_ff=16384,
    vocab_size=257_216,
    num_heads=8,
    num_kv_heads=1,  # MQA
    head_dim=256,
    pattern=(LayerTemplate("global", "dense"),),
    act="gelu",
    embed_scale=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
    modality="vision",
    frontend_dim=1152,  # SigLIP-So400m width
    num_patches=256,
)
