"""ModelConfig: one schema covering all six architecture families."""

from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class LayerTemplate:
    """One position in the repeating layer pattern."""

    mixer: str  # "global" | "local" | "ssm"
    ffn: str  # "dense" | "moe" | "none"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    source: str  # citation bracket from the assignment
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    # attention
    num_heads: int = 0  # 0 => attention-free
    num_kv_heads: int = 0
    head_dim: int = 0  # 0 => d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: int | None = None
    attn_softcap: float | None = None
    attn_kv_chunk: int = 1024
    attn_q_chunk: int | None = None  # §Perf lever: causal block-skipping
    # pattern: template list repeated num_layers/len(pattern) times
    pattern: tuple[LayerTemplate, ...] = (LayerTemplate("global", "dense"),)
    # output
    logit_softcap: float | None = None
    tie_embeddings: bool = True
    embed_scale: bool = False  # gemma-style sqrt(d) embedding scale
    post_norm: bool = False  # gemma2 sandwich norms
    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden (olmoe/granite: the listed d_ff)
    capacity_factor: float = 1.25
    # SSM
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    ssm_compute_dtype: str = "float32"  # §Perf lever: "bfloat16" halves SSD HBM traffic
    # multimodal frontends (stub embeddings per the carve-out)
    modality: str | None = None  # "vision" | "audio-codec"
    frontend_dim: int = 0  # SigLIP width for paligemma
    num_patches: int = 0
    num_codebooks: int = 0
    mlp_gated: bool = True  # False: plain 2-matrix MLP (nemotron)
    # numerics / misc
    act: str = "silu"
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    shard_heads: bool = True  # False when q-heads don't divide the TP axis
    # capability flags used by the dry-run matrix
    supports_long_context: bool = False  # sub-quadratic decode at 500k

    def __post_init__(self):
        if self.num_layers % len(self.pattern) != 0:
            raise ValueError(
                f"{self.name}: num_layers {self.num_layers} not a multiple of "
                f"pattern length {len(self.pattern)}"
            )
        if self.num_heads:
            if self.num_heads % max(self.num_kv_heads, 1) != 0:
                raise ValueError(f"{self.name}: heads % kv_heads != 0")

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def num_repeats(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def has_attention(self) -> bool:
        return any(t.mixer in ("global", "local") for t in self.pattern)

    @property
    def has_moe(self) -> bool:
        return any(t.ffn == "moe" for t in self.pattern)

    @property
    def has_ssm(self) -> bool:
        return any(t.mixer == "ssm" for t in self.pattern)

    def param_count(self) -> int:
        """Approximate parameter count (used for 6ND roofline math)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        for tmpl in self.pattern:
            n_rep = self.num_repeats
            if tmpl.mixer in ("global", "local"):
                attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + (
                    self.num_heads * hd * d
                )
                total += attn * n_rep
            elif tmpl.mixer == "ssm":
                di = self.ssm_expand * d
                n = self.ssm_state
                h = di // self.ssm_head_dim
                total += (d * (2 * di + 2 * n + h) + di * d) * n_rep
            if tmpl.ffn == "dense":
                n_mats = 3 if self.mlp_gated else 2
                total += n_mats * d * ff * n_rep
            elif tmpl.ffn == "moe":
                total += (3 * d * self.moe_d_ff * self.num_experts + d * self.num_experts) * n_rep
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of num_experts)."""
        if not self.has_moe:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        for tmpl in self.pattern:
            if tmpl.ffn == "moe":
                inactive = (
                    3 * d * self.moe_d_ff * (self.num_experts - self.top_k)
                ) * self.num_repeats
                total -= inactive
        return total


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
