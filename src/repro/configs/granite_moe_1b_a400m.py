"""granite-moe-1b-a400m [moe] — 32 experts, top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""

from repro.configs.base import LayerTemplate, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    arch_type="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    num_layers=24,
    d_model=1024,
    d_ff=512,  # expert hidden
    vocab_size=49_155,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    pattern=(LayerTemplate("global", "moe"),),
    num_experts=32,
    top_k=8,
    moe_d_ff=512,
    act="silu",
    tie_embeddings=True,
    rope_theta=10_000.0,
)
