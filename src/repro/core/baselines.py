"""Instance-distributed baselines the paper compares against (§3, §5, App. B).

* :func:`run_dsvrg`     — DSVRG (Lee et al., 2017): decentralized ring;
  full gradient computed in parallel over instance shards, inner loop runs
  on ONE machine at a time sampling its local shard.  Comm per outer:
  2qd (full-grad round) + 2d (parameter handoff).
* :func:`run_syn_svrg`  — SynSVRG on a Parameter Server (App. B, Alg 3/4):
  synchronous mini-batch SVRG with one sample per worker per step; every
  step pulls the dense w and pushes gradients.
* :func:`run_asy_svrg`  — AsySVRG on a Parameter Server (App. B, Alg 5/6):
  same traffic per step but asynchronous — gradients are computed at
  stale parameters (bounded delay ≤ q-1), latency overlaps.
* :func:`run_pslite_sgd` — PS-Lite (SGD): asynchronous SGD, no variance
  reduction (the paper's Table 3 baseline).

All baselines share the exact loss/regularizer code with FD-SVRG, run on
the same :class:`repro.dist.Collectives` substrate, drive the same
outer-loop engine (:func:`repro.core.driver.run_outer_loop` — snapshot
rotation, sampling, same-iterate reporting), and charge the same §4.5
closed forms (:data:`repro.dist.COSTS`), so Figures 6/7 and Tables 2/3
compare like-for-like.  Sparse pushes are metered as 2·u·nnz scalars
(key+value pairs — the PS-Lite <key,value> optimization the paper grants
the baselines); dense pulls as d scalars.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses as losses_lib
from repro.core.driver import (
    make_same_iterate_eval,
    option_mask,
    resolve_init_w,
    run_outer_loop,
)
from repro.core.fdsvrg import (
    RunResult,
    SVRGConfig,
    _inner_epoch,
    draw_samples,
    full_gradient,
)
from repro.data.sparse import PaddedCSR
from repro.dist import COSTS, ClusterModel, Collectives, SimBackend


def instance_shards(n: int, q: int) -> list[tuple[int, int]]:
    base, rem = divmod(n, q)
    out, lo = [], 0
    for k in range(q):
        hi = lo + base + (1 if k < rem else 0)
        out.append((lo, hi))
        lo = hi
    return out


# ---------------------------------------------------------------------------
# DSVRG
# ---------------------------------------------------------------------------


def run_dsvrg(
    data: PaddedCSR,
    q: int,
    loss: losses_lib.MarginLoss,
    reg: losses_lib.Regularizer,
    cfg: SVRGConfig,
    cluster: ClusterModel | None = None,
    backend: Collectives | None = None,
    *,
    init_w: jax.Array | None = None,
) -> RunResult:
    backend = backend or SimBackend(q, cluster)
    n, d, nnz = data.num_instances, data.dim, data.nnz_max
    shards = instance_shards(n, q)
    m_local = cfg.inner_steps  # paper: M = local instance count = N/q

    def snapshot(w):
        return full_gradient(data, w, loss)

    def epoch(t, rng, w, z_data, s0):
        # center -> q machines: w (d each); machines -> center: grad (d each)
        fg = COSTS.dsvrg_fullgrad(n=n, d=d, nnz=nnz, q=q)
        backend.p2p(fg.scalars, "dsvrg_fullgrad", rounds=fg.rounds)
        backend.charge_cost(fg)

        # inner loop runs on machine J = t mod q over its local shard
        lo, hi = shards[t % q]
        samples = (
            rng.integers(lo, hi, size=(m_local, cfg.batch_size)).astype(np.int32)
        )
        mask = option_mask(rng, m_local, cfg.option)
        w = _inner_epoch(
            (data.indices,), (data.values,), data.labels,
            w, z_data, s0,
            jnp.asarray(samples), cfg.eta, jnp.asarray(mask),
            loss.name, reg.name, reg.lam, (data.dim,), False,
            lam2=reg.lam2,
        )
        # M serial steps + center -> J: full gradient (d); J -> center:
        # parameter (d)
        ep = COSTS.dsvrg_epoch(m=m_local, nnz=nnz, d=d, u=cfg.batch_size)
        backend.p2p(ep.scalars, "dsvrg_handoff", rounds=ep.rounds)
        backend.charge_cost(ep)
        return w

    return run_outer_loop(
        outer_iters=cfg.outer_iters,
        seed=cfg.seed,
        init_w=resolve_init_w(init_w, d, data.values.dtype),
        snapshot=snapshot,
        epoch=epoch,
        evaluate=make_same_iterate_eval(data.labels, loss, reg, cfg.eta),
        backend=backend,
    )


# ---------------------------------------------------------------------------
# SynSVRG (Parameter Server, Appendix B Algorithms 3-4)
# ---------------------------------------------------------------------------


def run_syn_svrg(
    data: PaddedCSR,
    q: int,
    loss: losses_lib.MarginLoss,
    reg: losses_lib.Regularizer,
    cfg: SVRGConfig,
    cluster: ClusterModel | None = None,
    backend: Collectives | None = None,
    *,
    init_w: jax.Array | None = None,
) -> RunResult:
    backend = backend or SimBackend(q, cluster)
    n, d, nnz = data.num_instances, data.dim, data.nnz_max

    def snapshot(w):
        return full_gradient(data, w, loss)

    def epoch(t, rng, w, z_data, s0):
        fg = COSTS.ps_fullgrad(n=n, d=d, nnz=nnz, q=q)
        backend.p2p(fg.scalars, "ps_fullgrad", rounds=fg.rounds)
        backend.charge_cost(fg)

        # One sample per worker per synchronous step -> mini-batch of q.
        samples = draw_samples(rng, n, cfg.inner_steps, q)
        mask = option_mask(rng, cfg.inner_steps, cfg.option)
        w = _inner_epoch(
            (data.indices,), (data.values,), data.labels,
            w, z_data, s0,
            jnp.asarray(samples), cfg.eta, jnp.asarray(mask),
            loss.name, reg.name, reg.lam, (data.dim,), False,
            lam2=reg.lam2,
        )
        # per step: q workers pull dense w (q*d), push sparse VR grads
        # (2*u*nnz keys+values each) -- the <key,value> concession.
        st = COSTS.syn_inner_step(d=d, nnz=nnz, q=q, u=cfg.batch_size)
        backend.p2p(st.scalars * cfg.inner_steps, "ps_inner",
                    rounds=st.rounds * cfg.inner_steps)
        backend.charge_cost(st, steps=cfg.inner_steps)
        return w

    return run_outer_loop(
        outer_iters=cfg.outer_iters,
        seed=cfg.seed,
        init_w=resolve_init_w(init_w, d, data.values.dtype),
        snapshot=snapshot,
        epoch=epoch,
        evaluate=make_same_iterate_eval(data.labels, loss, reg, cfg.eta),
        backend=backend,
    )


# ---------------------------------------------------------------------------
# Asynchronous inner loops (AsySVRG and PS-Lite SGD share the machinery)
# ---------------------------------------------------------------------------


# lam stays traced (it only enters jnp arithmetic) so lambda sweeps reuse
# one compiled inner loop; lam2 is Python-branched in Regularizer.prox and
# must be static.
@functools.partial(
    jax.jit,
    static_argnames=(
        "loss_name", "reg_name", "delay_buf", "variance_reduced", "lam2"
    ),
)
def _async_epoch(
    indices, values, labels,
    w0, z_data, s0,
    samples,  # int32[M]
    delays,  # int32[M] in [0, delay_buf)
    eta, lam,
    loss_name: str, reg_name: str,
    delay_buf: int,
    variance_reduced: bool,
    lam2: float = 0.0,
):
    """Asynchronous PS inner loop with a bounded-staleness ring buffer.

    Step m computes its gradient at the iterate that was current ``delays[m]``
    server updates ago (Alg 5/6: workers pull, compute, push while the
    server keeps moving).  The server applies the proximal update — the
    prox acts on the fresh server iterate, the smooth gradient is
    evaluated at the stale pull — so the PS baselines run the same
    regularizer family as FD-Prox-SVRG for like-for-like comparisons.
    """
    loss = losses_lib.LOSSES[loss_name]
    reg = losses_lib.Regularizer(reg_name, lam, lam2)
    d = w0.shape[0]
    buf = jnp.broadcast_to(w0, (delay_buf, d))

    def step(carry, inp):
        buf, ptr = carry
        i_m, delay = inp
        w_now = buf[ptr % delay_buf]
        w_stale = buf[(ptr - delay) % delay_buf]
        idx = indices[i_m]
        val = values[i_m]
        y = labels[i_m]
        s_m = jnp.sum(w_stale[idx] * val)
        if variance_reduced:
            coef = loss.dvalue(s_m, y) - loss.dvalue(s0[i_m], y)
            g = coef * jnp.zeros((d,), values.dtype).at[idx].add(val) + z_data
        else:
            coef = loss.dvalue(s_m, y)
            g = coef * jnp.zeros((d,), values.dtype).at[idx].add(val)
        g = g + reg.smooth_grad(w_stale)
        w_next = reg.prox(w_now - eta * g, eta)
        buf = buf.at[(ptr + 1) % delay_buf].set(w_next)
        return (buf, ptr + 1), None

    (buf, ptr), _ = jax.lax.scan(step, (buf, jnp.zeros((), jnp.int32)), (samples, delays))
    return buf[ptr % delay_buf]


def _run_async(
    data: PaddedCSR,
    q: int,
    loss: losses_lib.MarginLoss,
    reg: losses_lib.Regularizer,
    cfg: SVRGConfig,
    backend: Collectives,
    variance_reduced: bool,
    kind: str,
    init_w: jax.Array | None = None,
) -> RunResult:
    n, d, nnz = data.num_instances, data.dim, data.nnz_max
    delay_buf = max(2, q)

    def snapshot(w):
        # Rotated into the epoch as the VR anchor; for the non-VR PS-Lite
        # path it is reporting-only (the epoch passes dead zeros instead).
        return full_gradient(data, w, loss)

    def epoch(t, rng, w, z_data, s0):
        if variance_reduced:
            fg = COSTS.ps_fullgrad(n=n, d=d, nnz=nnz, q=q)
            backend.p2p(fg.scalars, f"{kind}_fullgrad", rounds=fg.rounds)
            backend.charge_cost(fg)
        else:
            # No variance reduction: z is identically zero (in the data's
            # dtype, so float64 runs don't silently promote), and s0 is
            # dead in this jit specialization (_async_epoch reads it only
            # under variance_reduced=True) — zeros keep the call signature
            # without charging the algorithm for a gradient it never takes.
            z_data = jnp.zeros((d,), data.values.dtype)
            s0 = jnp.zeros((n,), data.values.dtype)

        samples = rng.integers(0, n, size=cfg.inner_steps).astype(np.int32)
        delays = rng.integers(0, q, size=cfg.inner_steps).astype(np.int32)
        w = _async_epoch(
            data.indices, data.values, data.labels,
            w, z_data, s0,
            jnp.asarray(samples), jnp.asarray(delays),
            cfg.eta, reg.lam, loss.name, reg.name, delay_buf, variance_reduced,
            lam2=reg.lam2,
        )
        # per async step: one worker pulls dense w (d) and pushes a sparse
        # (VR-)gradient (2*nnz) -- but the reg term makes pushes dense in
        # practice; we still grant sparsity to the baseline.  Async: q
        # workers overlap compute; the server serializes message handling,
        # so throughput is bounded by the server's bandwidth.
        per_step = COSTS.async_step_scalars(d=d, nnz=nnz)
        backend.p2p(per_step * cfg.inner_steps, f"{kind}_inner",
                    rounds=2 * cfg.inner_steps)
        backend.charge_seconds(
            cfg.inner_steps
            * COSTS.async_step_seconds(backend.cluster, d=d, nnz=nnz, q=q)
        )
        return w

    return run_outer_loop(
        outer_iters=cfg.outer_iters,
        seed=cfg.seed,
        init_w=resolve_init_w(init_w, d, data.values.dtype),
        snapshot=snapshot,
        epoch=epoch,
        evaluate=make_same_iterate_eval(data.labels, loss, reg, cfg.eta),
        backend=backend,
    )


def run_asy_svrg(data, q, loss, reg, cfg, cluster=None, backend=None, *,
                 init_w=None) -> RunResult:
    return _run_async(data, q, loss, reg, cfg, backend or SimBackend(q, cluster),
                      variance_reduced=True, kind="asysvrg", init_w=init_w)


def run_pslite_sgd(data, q, loss, reg, cfg, cluster=None, backend=None, *,
                   init_w=None) -> RunResult:
    return _run_async(data, q, loss, reg, cfg, backend or SimBackend(q, cluster),
                      variance_reduced=False, kind="pslite", init_w=init_w)
