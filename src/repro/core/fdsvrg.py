"""FD-SVRG (paper Algorithm 1) and serial SVRG (paper Algorithm 2).

Three implementations, one update rule:

* :func:`run_serial_svrg` — Algorithm 2 (Johnson & Zhang), options I/II,
  jitted ``lax.scan`` inner loop.  This is the reference the paper proves
  FD-SVRG equivalent to.
* :func:`run_fdsvrg` — Algorithm 1 at simulation level: numerics follow
  the feature-decomposed computation (margins as a sum of per-block
  partials), communication is metered with the paper's exact accounting
  (tree reduce+broadcast per inner product), wall-clock is modeled with
  :class:`~repro.core.comm.ClusterModel`.
* :func:`fdsvrg_worker_simulation` — an explicit q-worker object-level
  simulation (each worker only ever touches its own ``w^(l)`` and
  ``D^(l)``); slow, used by tests to certify exact equivalence.

All communication — executed or modeled — goes through a
:class:`repro.dist.Collectives` backend, so FD-SVRG and the baselines in
:mod:`repro.core.baselines` report bytes and modeled wall-clock through
the same meter.  The deployable TPU version (shard_map over the ``model``
mesh axis) lives in :mod:`repro.core.fdsvrg_shardmap`.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses as losses_lib
from repro.core.partition import FeaturePartition
from repro.dist import ClusterModel, Collectives, CommMeter, SimBackend, tree_order_sum
from repro.data.sparse import (
    PaddedCSR,
    margins,
    margins_block,
    scatter_grad,
    scatter_grad_block,
)


@dataclasses.dataclass(frozen=True)
class SVRGConfig:
    eta: float
    inner_steps: int  # M; paper sets M = #instances held per worker (= N for FD)
    outer_iters: int
    batch_size: int = 1  # u, the mini-batch trick of §4.4.1
    option: str = "I"  # paper proves Option I (Theorem 1) and uses it
    seed: int = 0

    def __post_init__(self) -> None:
        if self.option not in ("I", "II"):
            raise ValueError(f"option must be 'I' or 'II', got {self.option!r}")
        if self.batch_size < 1:
            raise ValueError("batch_size >= 1 required")


@dataclasses.dataclass
class OuterRecord:
    outer: int
    objective: float
    grad_norm: float
    comm_scalars: int
    comm_rounds: int
    modeled_time_s: float
    wall_time_s: float


@dataclasses.dataclass
class RunResult:
    w: jax.Array
    history: list[OuterRecord]
    meter: CommMeter

    def objectives(self) -> np.ndarray:
        return np.array([h.objective for h in self.history])

    def final_objective(self) -> float:
        return self.history[-1].objective


# ---------------------------------------------------------------------------
# Objective / full gradient
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("loss_name", "reg_name"))
def _objective_impl(indices, values, labels, w, lam, loss_name, reg_name):
    loss = losses_lib.LOSSES[loss_name]
    reg = losses_lib.Regularizer(reg_name, lam)
    s = jnp.sum(w[indices] * values, axis=1)
    return jnp.mean(loss.value(s, labels)) + reg.value(w)


def objective(
    data: PaddedCSR, w: jax.Array, loss: losses_lib.MarginLoss, reg: losses_lib.Regularizer
) -> float:
    return float(
        _objective_impl(
            data.indices, data.values, data.labels, w, reg.lam, loss.name, reg.name
        )
    )


@functools.partial(jax.jit, static_argnames=("loss_name",))
def _full_grad_impl(indices, values, labels, w, loss_name):
    """Data part of the full gradient plus the cached margins s0 = w^T x_i."""
    loss = losses_lib.LOSSES[loss_name]
    s0 = jnp.sum(w[indices] * values, axis=1)
    coeffs = loss.dvalue(s0, labels) / labels.shape[0]
    z_data = scatter_grad(indices, values, coeffs, w.shape[0])
    return z_data, s0


def full_gradient(
    data: PaddedCSR, w: jax.Array, loss: losses_lib.MarginLoss
) -> tuple[jax.Array, jax.Array]:
    return _full_grad_impl(data.indices, data.values, data.labels, w, loss.name)


# ---------------------------------------------------------------------------
# Inner epoch (shared by serial and simulated-FD paths)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("loss_name", "reg_name", "num_blocks", "bounds")
)
def _inner_epoch(
    indices,
    values,
    labels,
    w0,
    z_data,
    s0,
    samples,  # int32[M, u]
    eta,
    lam,
    step_mask,  # float32[M] (1 = apply update; Option II masks the tail)
    loss_name: str,
    reg_name: str,
    num_blocks: int,
    bounds: tuple[int, ...] | None,
):
    """M variance-reduced updates.

    When ``num_blocks > 1`` the margin of each sampled instance is computed
    the feature-distributed way: q per-block partial dots summed in block
    order (matching the tree reduce), certifying the decomposition the
    paper relies on.  ``num_blocks == 1`` is the serial path.
    """
    loss = losses_lib.LOSSES[loss_name]
    reg = losses_lib.Regularizer(reg_name, lam)
    u = samples.shape[1]
    n = labels.shape[0]

    def margin_of(w, idx, val):
        if num_blocks == 1:
            return jnp.sum(w[idx] * val, axis=-1)
        parts = []
        for l in range(num_blocks):
            lo, hi = bounds[l], bounds[l + 1]
            block = jax.lax.slice_in_dim(w, lo, hi)
            parts.append(margins_block(idx, val, block, lo))
        # Pairwise summation mirroring Figure 5 exactly (shared with the
        # simulation and interpret backends, so floating point matches).
        return tree_order_sum(parts)

    def step(w, inp):
        ids, mask = inp  # ids: int32[u]
        idx = indices[ids]  # [u, nnz]
        val = values[ids]
        y = labels[ids]
        s_m = margin_of(w, idx, val)
        s_anchor = s0[ids]
        coef = (loss.dvalue(s_m, y) - loss.dvalue(s_anchor, y)) / u
        data_grad = scatter_grad(idx, val, coef, w.shape[0])
        g = data_grad + z_data + reg.grad(w)
        return w - (eta * mask) * g, None

    w_final, _ = jax.lax.scan(step, w0, (samples, step_mask))
    return w_final


def _draw_samples(rng: np.random.Generator, n: int, m: int, u: int) -> np.ndarray:
    return rng.integers(0, n, size=(m, u), dtype=np.int64).astype(np.int32)


def _option_mask(rng: np.random.Generator, m: int, option: str) -> np.ndarray:
    if option == "I":
        return np.ones(m, dtype=np.float32)
    stop = int(rng.integers(1, m + 1))
    return (np.arange(m) < stop).astype(np.float32)


# ---------------------------------------------------------------------------
# Serial SVRG (Algorithm 2)
# ---------------------------------------------------------------------------


def run_serial_svrg(
    data: PaddedCSR,
    loss: losses_lib.MarginLoss,
    reg: losses_lib.Regularizer,
    cfg: SVRGConfig,
) -> RunResult:
    rng = np.random.default_rng(cfg.seed)
    w = jnp.zeros((data.dim,), dtype=data.values.dtype)
    meter = CommMeter()  # serial: stays empty
    history: list[OuterRecord] = []
    t_start = time.perf_counter()
    for t in range(cfg.outer_iters):
        z_data, s0 = full_gradient(data, w, loss)
        samples = _draw_samples(rng, data.num_instances, cfg.inner_steps, cfg.batch_size)
        mask = _option_mask(rng, cfg.inner_steps, cfg.option)
        w = _inner_epoch(
            data.indices,
            data.values,
            data.labels,
            w,
            z_data,
            s0,
            jnp.asarray(samples),
            cfg.eta,
            reg.lam,
            jnp.asarray(mask),
            loss.name,
            reg.name,
            1,
            None,
        )
        obj = objective(data, w, loss, reg)
        gnorm = float(jnp.linalg.norm(z_data + reg.grad(w)))
        history.append(
            OuterRecord(t, obj, gnorm, 0, 0, 0.0, time.perf_counter() - t_start)
        )
    return RunResult(w=w, history=history, meter=meter)


# ---------------------------------------------------------------------------
# FD-SVRG (Algorithm 1), metered simulation
# ---------------------------------------------------------------------------


def run_fdsvrg(
    data: PaddedCSR,
    partition: FeaturePartition,
    loss: losses_lib.MarginLoss,
    reg: losses_lib.Regularizer,
    cfg: SVRGConfig,
    cluster: ClusterModel | None = None,
    backend: Collectives | None = None,
) -> RunResult:
    """Algorithm 1 with q = partition.num_blocks feature-sharded workers.

    Numerics: identical update sequence to serial SVRG (Theorem: the
    decomposition w^T x = sum_l w^(l)T x^(l) is exact; summation follows
    the tree order).  Communication/time: the paper's accounting, metered
    through ``backend`` (default: a fresh ``SimBackend``) —

      outer t:  tree reduce+broadcast of the N-vector  w_t^T D  -> 2qN scalars
      inner m:  tree reduce+broadcast of u margins      -> 2qu scalars
    """
    q = partition.num_blocks
    if backend is None:
        backend = SimBackend(q, cluster)
    elif backend.q != q:
        raise ValueError(
            f"backend has q={backend.q} workers but the partition has "
            f"{q} blocks"
        )
    rng = np.random.default_rng(cfg.seed)
    w = jnp.zeros((data.dim,), dtype=data.values.dtype)
    history: list[OuterRecord] = []
    n = data.num_instances
    nnz = data.nnz_max
    log_rounds = backend.tree_rounds
    t_start = time.perf_counter()

    for t in range(cfg.outer_iters):
        # --- full-gradient phase (Alg 1 lines 3-5) ---
        z_data, s0 = full_gradient(data, w, loss)
        backend.meter_tree(payload=n)  # w_t^T D summed across blocks
        # per-worker compute: margins over the local block (N*nnz/q flops-ish)
        # + local scatter of the full gradient.
        backend.charge(
            flops=2.0 * n * nnz / q * 2,  # margins + scatter
            scalars=2 * q * n,
            rounds=log_rounds,
        )

        samples = _draw_samples(rng, n, cfg.inner_steps, cfg.batch_size)
        mask = _option_mask(rng, cfg.inner_steps, cfg.option)
        w = _inner_epoch(
            data.indices,
            data.values,
            data.labels,
            w,
            z_data,
            s0,
            jnp.asarray(samples),
            cfg.eta,
            reg.lam,
            jnp.asarray(mask),
            loss.name,
            reg.name,
            q,
            partition.bounds,
        )
        # --- inner-loop communication (Alg 1 lines 9-11): one tree round
        # per mini-batch of u margins; M steps total (metered in aggregate).
        backend.meter_tree(payload=cfg.batch_size, steps=cfg.inner_steps)
        # Dense-update compute per worker: O(d/q) per step for the z + reg
        # part plus O(nnz) for the sparse part.
        backend.charge_seconds(
            cfg.inner_steps
            * backend.cluster.time(
                critical_flops=2.0 * (data.dim / q + cfg.batch_size * nnz),
                critical_scalars=2 * q * cfg.batch_size,
                rounds=log_rounds,
            )
        )

        obj = objective(data, w, loss, reg)
        gnorm = float(jnp.linalg.norm(z_data + reg.grad(w)))
        history.append(
            OuterRecord(
                t,
                obj,
                gnorm,
                backend.meter.total_scalars,
                backend.meter.total_rounds,
                backend.modeled_time_s,
                time.perf_counter() - t_start,
            )
        )
    return RunResult(w=w, history=history, meter=backend.meter)


# ---------------------------------------------------------------------------
# Explicit q-worker simulation (tests): workers only see their own blocks
# ---------------------------------------------------------------------------


def fdsvrg_worker_simulation(
    data: PaddedCSR,
    partition: FeaturePartition,
    loss: losses_lib.MarginLoss,
    reg: losses_lib.Regularizer,
    cfg: SVRGConfig,
    backend: Collectives | None = None,
) -> tuple[jax.Array, CommMeter]:
    """Object-level Algorithm 1: a list of per-worker states, every
    cross-worker scalar passes through ``backend.all_reduce`` (default: a
    fresh ``SimBackend`` running the explicit Figure-5 schedule).

    Returns the concatenated final parameter and the backend's comm meter.
    Deliberately unjitted and slow — this is the executable spec, and the
    vehicle for the backend-equivalence tests.
    """
    q = partition.num_blocks
    backend = backend or SimBackend(q)
    rng = np.random.default_rng(cfg.seed)
    n = data.num_instances

    # Worker state: w^(l)
    blocks = [
        jnp.zeros((partition.bounds[l + 1] - partition.bounds[l],), dtype=data.values.dtype)
        for l in range(q)
    ]

    for t in range(cfg.outer_iters):
        # Lines 3-4: each worker computes w_t^(l)T D^(l); tree-sum the N-vector.
        partials = [
            margins_block(data.indices, data.values, blocks[l], partition.bounds[l])
            for l in range(q)
        ]
        s0 = backend.all_reduce(partials, payload=n)
        # Line 5: local full-gradient block from the shared margins.
        coeffs0 = loss.dvalue(s0, data.labels) / n
        z_blocks = [
            scatter_grad_block(
                data.indices,
                data.values,
                coeffs0,
                partition.bounds[l],
                blocks[l].shape[0],
            )
            for l in range(q)
        ]

        anchors = [b for b in blocks]  # w̃_0^(l) = w_t^(l)
        samples = _draw_samples(rng, n, cfg.inner_steps, cfg.batch_size)
        mask = _option_mask(rng, cfg.inner_steps, cfg.option)

        for m in range(cfg.inner_steps):
            ids = samples[m]
            idx = data.indices[ids]
            val = data.values[ids]
            y = data.labels[ids]
            # Lines 9-10: per-worker partial margins, tree-summed (u scalars).
            partial_m = [
                margins_block(idx, val, blocks[l], partition.bounds[l])
                for l in range(q)
            ]
            s_m = backend.all_reduce(partial_m, payload=cfg.batch_size)
            s_a = s0[ids]
            coef = (loss.dvalue(s_m, y) - loss.dvalue(s_a, y)) / cfg.batch_size
            # Line 11: purely local update on each block.
            for l in range(q):
                sparse_part = scatter_grad_block(
                    idx, val, coef, partition.bounds[l], blocks[l].shape[0]
                )
                g = sparse_part + z_blocks[l] + reg.grad(blocks[l])
                blocks[l] = blocks[l] - (cfg.eta * float(mask[m])) * g

    return jnp.concatenate(blocks), backend.meter
