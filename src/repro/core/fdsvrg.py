"""FD-SVRG (paper Algorithm 1) and serial SVRG (paper Algorithm 2).

Three implementations, one update rule:

* :func:`run_serial_svrg` — Algorithm 2 (Johnson & Zhang), options I/II,
  jitted ``lax.scan`` inner loop.  This is the reference the paper proves
  FD-SVRG equivalent to.
* :func:`run_fdsvrg` — Algorithm 1 at simulation level: numerics follow
  the feature-decomposed computation (margins as a sum of per-block
  partials), communication is metered with the paper's exact accounting
  and modeled time is charged from the shared closed forms
  (:data:`repro.dist.COSTS`).
* :func:`fdsvrg_worker_simulation` — an explicit q-worker object-level
  simulation (each worker only ever touches its own ``w^(l)`` and
  ``D^(l)``); slow, used by tests to certify exact equivalence.

All three drivers run on the ONE outer-loop engine
(:func:`repro.core.driver.run_outer_loop`): snapshot rotation, sample
drawing, same-iterate reporting, and history construction live there,
not here — each implementation supplies only its ``snapshot`` and
``epoch`` hooks.

All three run on the block-local layout
(:class:`repro.data.block_csr.BlockCSR`): each worker's rows carry only
its own block's entries with local ids, so per-worker gather/scatter work
is O(nnz_max/q) — no membership masks anywhere on the hot path.  Every
implementation takes ``use_kernels``: ``True`` routes the two hot paths
through the fused Pallas kernels (:func:`repro.kernels.ops.sparse_margins`
and :func:`repro.kernels.ops.fused_block_prox_update`, interpret-mode on
CPU), ``False`` is the pure-jnp numerics oracle.  The two paths are
bit-identical in interpret mode (asserted in tests), for every
regularizer: l2, l1, elastic_net, and none (the inner step is the
Prox-SVRG update, which specializes to classic SVRG when the prox is the
identity).

All communication — executed or modeled — goes through a
:class:`repro.dist.Collectives` backend, so FD-SVRG and the baselines in
:mod:`repro.core.baselines` report bytes and modeled wall-clock through
the same meter.  The deployable TPU version (shard_map over the ``model``
mesh axis) lives in :mod:`repro.core.fdsvrg_shardmap`.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import losses as losses_lib
from repro.core.driver import (
    CheckpointPolicy,
    OuterRecord,
    RecoveryPolicy,
    RunResult,
    draw_samples,
    make_same_iterate_eval,
    objective_from_margins,
    optimality_norm,
    option_mask,
    resolve_init_w,
    run_outer_loop,
)
from repro.core.partition import FeaturePartition, balanced
from repro.dist import COSTS, ClusterModel, Collectives, SimBackend, tree_order_sum
from repro.data.sparse import PaddedCSR, margins_rows, scatter_grad
from repro.data.block_csr import BlockCSR, local_margins, local_scatter
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class SVRGConfig:
    eta: float
    inner_steps: int  # M; paper sets M = #instances held per worker (= N for FD)
    outer_iters: int
    batch_size: int = 1  # u, the mini-batch trick of §4.4.1
    option: str = "I"  # paper proves Option I (Theorem 1) and uses it
    seed: int = 0

    def __post_init__(self) -> None:
        if self.option not in ("I", "II"):
            raise ValueError(f"option must be 'I' or 'II', got {self.option!r}")
        if self.batch_size < 1:
            raise ValueError("batch_size >= 1 required")


# ---------------------------------------------------------------------------
# Objective / full gradient
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("loss_name", "reg_name"))
def _objective_impl(indices, values, labels, w, lam, lam2, loss_name, reg_name):
    loss = losses_lib.LOSSES[loss_name]
    reg = losses_lib.Regularizer(reg_name, lam, lam2)
    s = margins_rows(indices, values, w)
    return jnp.mean(loss.value(s, labels)) + reg.value(w)


def objective(
    data: PaddedCSR, w: jax.Array, loss: losses_lib.MarginLoss, reg: losses_lib.Regularizer
) -> float:
    return float(
        _objective_impl(
            data.indices, data.values, data.labels, w, reg.lam, reg.lam2,
            loss.name, reg.name,
        )
    )


@functools.partial(jax.jit, static_argnames=("loss_name",))
def _full_grad_impl(indices, values, labels, w, loss_name):
    """Data part of the full gradient plus the cached margins s0 = w^T x_i."""
    loss = losses_lib.LOSSES[loss_name]
    s0 = margins_rows(indices, values, w)
    coeffs = loss.dvalue(s0, labels) / labels.shape[0]
    z_data = scatter_grad(indices, values, coeffs, w.shape[0])
    return z_data, s0


def full_gradient(
    data: PaddedCSR, w: jax.Array, loss: losses_lib.MarginLoss
) -> tuple[jax.Array, jax.Array]:
    return _full_grad_impl(data.indices, data.values, data.labels, w, loss.name)


# ---------------------------------------------------------------------------
# Block-local hot paths (shared by every implementation)
# ---------------------------------------------------------------------------


def _bounds(block_dims: tuple[int, ...]) -> tuple[int, ...]:
    b = [0]
    for d in block_dims:
        b.append(b[-1] + d)
    return tuple(b)


def _block_margins(idx, val, w_block, use_kernels: bool):
    """Per-block partial margins over block-LOCAL rows (gather, no mask)."""
    if use_kernels:
        return ops.sparse_margins(idx, val, w_block)
    return local_margins(idx, val, w_block)


@functools.partial(
    jax.jit, static_argnames=("loss_name", "block_dims", "use_kernels")
)
def _full_grad_blocks(
    block_indices, block_values, labels, w, loss_name, block_dims, use_kernels
):
    """Feature-decomposed full gradient: per-block partial margins summed
    in tree order (Alg 1 lines 3-4), then a purely block-local scatter
    (line 5).  Returns the concatenated z and the cached margins s0."""
    loss = losses_lib.LOSSES[loss_name]
    q = len(block_dims)
    bounds = _bounds(block_dims)
    parts = [
        _block_margins(
            block_indices[l],
            block_values[l],
            jax.lax.slice_in_dim(w, bounds[l], bounds[l + 1]),
            use_kernels,
        )
        for l in range(q)
    ]
    s0 = tree_order_sum(parts)
    coeffs = loss.dvalue(s0, labels) / labels.shape[0]
    z_blocks = [
        local_scatter(block_indices[l], block_values[l], coeffs, block_dims[l])
        for l in range(q)
    ]
    z_data = jnp.concatenate(z_blocks) if q > 1 else z_blocks[0]
    return z_data, s0


def _default_fd_abort(n: int, nnz: int, q: int):
    """The default ``RecoveryPolicy.on_abort`` for the FD drivers: an
    epoch abort re-establishes the snapshot on the restarted worker —
    one extra full-gradient phase, metered under its own ``"abort"``
    kind so honest-accounting tests can separate it from the schedule."""
    from repro.dist import tree_rounds

    def on_abort(backend):
        if backend.q > 1:
            backend.p2p(2 * backend.q * n, "abort", rounds=tree_rounds(backend.q))
        backend.charge_cost(COSTS.fd_fullgrad(n=n, nnz=nnz, q=q))

    return on_abort


def _with_default_abort(
    recovery: RecoveryPolicy | None, n: int, nnz: int, q: int
) -> RecoveryPolicy | None:
    if recovery is None or recovery.on_abort is not None:
        return recovery
    return dataclasses.replace(
        recovery, on_abort=_default_fd_abort(n, nnz, q)
    )


def _kernel_lams(
    reg: losses_lib.Regularizer, use_kernels: bool
) -> tuple[float, float, float] | None:
    """Static (smooth_lam, prox_l1, prox_l2) for the fused Pallas kernels
    (compile-time constants of the run), or None on the jnp path — where
    lam stays a traced operand so lambda sweeps reuse one compilation."""
    if not use_kernels:
        return None
    return (reg.smooth_lam, reg.prox_l1, reg.prox_l2)


# ---------------------------------------------------------------------------
# Inner epoch (shared by serial and simulated-FD paths)
# ---------------------------------------------------------------------------


# lam stays traced (it only enters jnp arithmetic) so lambda sweeps reuse
# one compiled scan — matching _async_epoch, which always traced it; lam2
# is Python-branched in Regularizer.prox and must stay static.  The fused
# Pallas kernels bake their lams in at compile time, so the kernel path
# receives them separately as the static `kernel_lams` triple.
@functools.partial(
    jax.jit,
    static_argnames=(
        "loss_name", "reg_name", "block_dims", "use_kernels", "lam2",
        "kernel_lams",
    ),
)
def _inner_epoch(
    block_indices,  # per-block int32[N, nnz_l], LOCAL ids
    block_values,  # per-block float[N, nnz_l]
    labels,
    w0,
    z_data,
    s0,
    samples,  # int32[M, u]
    eta,
    step_mask,  # float32[M] (1 = apply update; Option II masks the tail)
    loss_name: str,
    reg_name: str,
    lam,  # traced regularizer strength
    block_dims: tuple[int, ...],
    use_kernels: bool,
    lam2: float = 0.0,  # elastic-net L2 strength (trailing: legacy call sites)
    kernel_lams: tuple[float, float, float] | None = None,
):
    """M proximal variance-reduced updates on the block-local layout.

    The margin of each sampled instance is computed the
    feature-distributed way: q per-block partial dots (local gathers, no
    masks) summed in block order (matching the tree reduce), certifying
    the decomposition the paper relies on.  The update is the Prox-SVRG
    step ``w <- prox_{eta*g}(w - eta * (grad_vr + z + smooth_grad g))``;
    for the smooth family the prox is the identity and this is exactly
    the classic SVRG step, bit-for-bit.  The prox is elementwise (paper
    eq. 3: g decomposes over blocks), hence purely block-local — no extra
    communication relative to the L2 path.  ``len(block_dims) == 1`` is
    the serial path.  ``use_kernels`` swaps the gather-margin and the
    scatter+prox-update for the fused Pallas kernels and requires the
    static ``kernel_lams`` triple (see :func:`_kernel_lams`).
    """
    if use_kernels and kernel_lams is None:
        raise ValueError(
            "use_kernels=True requires kernel_lams=(smooth_lam, prox_l1, "
            "prox_l2) — the fused kernels bake them in at compile time"
        )
    loss = losses_lib.LOSSES[loss_name]
    reg = losses_lib.Regularizer(reg_name, lam, lam2)
    u = samples.shape[1]
    q = len(block_dims)
    bounds = _bounds(block_dims)

    def step(w, inp):
        ids, mask = inp  # ids: int32[u]
        y = labels[ids]
        rows = [(block_indices[l][ids], block_values[l][ids]) for l in range(q)]
        parts = [
            _block_margins(
                rows[l][0],
                rows[l][1],
                jax.lax.slice_in_dim(w, bounds[l], bounds[l + 1]),
                use_kernels,
            )
            for l in range(q)
        ]
        # Pairwise summation mirroring Figure 5 exactly (shared with the
        # simulation and interpret backends, so floating point matches).
        s_m = tree_order_sum(parts)
        s_anchor = s0[ids]
        coef = (loss.dvalue(s_m, y) - loss.dvalue(s_anchor, y)) / u
        eta_m = eta * mask
        new_blocks = []
        for l in range(q):
            idx, val = rows[l]
            w_blk = jax.lax.slice_in_dim(w, bounds[l], bounds[l + 1])
            z_blk = jax.lax.slice_in_dim(z_data, bounds[l], bounds[l + 1])
            if use_kernels:
                k_lam, k_l1, k_l2 = kernel_lams
                new_blocks.append(
                    ops.fused_block_prox_update(
                        w_blk, idx, val, coef, z_blk, eta_m,
                        lam=k_lam, lam1=k_l1, lam2=k_l2,
                    )
                )
            else:
                g = local_scatter(idx, val, coef, block_dims[l])
                g = g + z_blk + reg.smooth_grad(w_blk)
                new_blocks.append(reg.prox(w_blk - eta_m * g, eta_m))
        w_next = jnp.concatenate(new_blocks) if q > 1 else new_blocks[0]
        return w_next, None

    w_final, _ = jax.lax.scan(step, w0, (samples, step_mask))
    return w_final


# ---------------------------------------------------------------------------
# Lazy (delayed-decay) inner epoch — O(u * nnz_l) per step
# ---------------------------------------------------------------------------


def _check_lazy(lazy_updates: str | None) -> None:
    if lazy_updates not in (None, "exact", "proba"):
        raise ValueError(
            "lazy_updates must be None, 'exact', or 'proba', got "
            f"{lazy_updates!r}"
        )


def _lazy_lams(reg: losses_lib.Regularizer) -> tuple[float, float, float]:
    """Static (smooth_lam, prox_l1, prox_l2) for the lazy Pallas kernels
    and the object-level simulation helpers (whose dense counterpart,
    :func:`_sim_update`, also treats lam as static)."""
    return (reg.smooth_lam, reg.prox_l1, reg.prox_l2)


def _lazy_corrections(
    block_data: BlockCSR, n: int, u: int, lazy_updates: str | None
) -> jax.Array | None:
    """Concatenated per-feature step corrections (probabilistic variant)."""
    if lazy_updates != "proba":
        return None
    blocks = [
        ops.step_corrections(block_data.nnz_col_block(l), n, u)
        for l in range(block_data.num_blocks)
    ]
    return jnp.concatenate(blocks) if len(blocks) > 1 else blocks[0]


# Same scan skeleton as _inner_epoch, but per inner step each block does
# O(u * nnz_l) work instead of densifying all of w^(l):
#   exact —  catch up the touched features (replay their deferred steps),
#            read margins from the caught-up block, apply the dense update
#            at the touched lanes only, and reconcile every feature at
#            epoch end (lazy_flush) so the returned iterate is bit-equal
#            to _inner_epoch's;
#   proba —  touched features only, decay scaled by the per-feature
#            corrections; w is always materialized, so no counters and no
#            flush.
# Both variants read only block-local state — the all-reduced margins are
# byte-for-byte the eager schedule, so metering is unchanged by design.
#
# The smooth term is computed as ``smooth_lam * w`` with smooth_lam a
# RUNTIME scalar (lam for l2, a runtime +0.0 otherwise), never the
# compile-time ``zeros_like`` Regularizer.smooth_grad returns for the
# non-l2 modes.  With a constant-zero smooth term the replayed step's
# gradient is loop-invariant, XLA hoists the pre-rounded ``eta * g`` out
# of the replay loop, and the trajectory loses the in-loop
# ``w - eta*g`` FMA the dense scan's body gets from LLVM — a rare-input
# 1-ulp drift (see the comment block in repro/kernels/ref.py).  A runtime
# smooth_lam keeps g loop-varying; for the non-l2 modes ``smooth_lam * w``
# is ±0.0 and ``(0.0 + z) + ±0.0`` is bitwise ``0.0 + z`` (the left side
# is never -0.0), so the extra term is exact.  lam1/lam2 only enter
# through loop-invariant scalars (eta*lam1, 1 + eta*lam2) whose hoisting
# is value-preserving, so they may stay static on the kernel path.
@functools.partial(
    jax.jit,
    static_argnames=(
        "loss_name", "reg_name", "block_dims", "use_kernels", "variant",
        "lam2", "kernel_lams",
    ),
)
def _lazy_inner_epoch(
    block_indices,  # per-block int32[N, nnz_l], LOCAL ids
    block_values,  # per-block float[N, nnz_l]
    labels,
    w0,
    z_data,
    s0,
    samples,  # int32[M, u]
    eta,
    step_mask,  # float32[M]; must be a monotone prefix of ones (options I/II)
    corrections,  # [d] step corrections, or None (exact variant)
    loss_name: str,
    reg_name: str,
    lam,  # traced regularizer strength (as in _inner_epoch)
    block_dims: tuple[int, ...],
    use_kernels: bool,
    variant: str,  # "exact" | "proba"
    lam2: float = 0.0,
    kernel_lams: tuple[float, float, float] | None = None,
):
    if use_kernels and kernel_lams is None:
        raise ValueError(
            "use_kernels=True requires kernel_lams=(smooth_lam, prox_l1, "
            "prox_l2) — the lazy kernels bake them in at compile time"
        )
    loss = losses_lib.LOSSES[loss_name]
    reg = losses_lib.Regularizer(reg_name, lam, lam2)
    k_lam, k_l1, k_l2 = kernel_lams if kernel_lams else (0.0, 0.0, 0.0)
    # Runtime smooth strength: lam itself for l2, else lam * 0.0 — a traced
    # +0.0 XLA cannot fold away (see the comment above the decorator).
    smooth_lam = lam if reg_name == "l2" else lam * 0.0
    u = samples.shape[1]
    m_total = samples.shape[0]
    q = len(block_dims)
    bounds = _bounds(block_dims)
    exact = variant == "exact"
    # Number of active (unmasked) steps: option_mask yields 1s then 0s, so
    # the catch-up can decompose any gap as active replays + one masked one.
    stop = jnp.sum(step_mask).astype(jnp.int32)

    def split(vec):
        return [
            jax.lax.slice_in_dim(vec, bounds[l], bounds[l + 1])
            for l in range(q)
        ]

    def jnp_replay(wl, zl, k_active, has_masked, eta_v):
        # The untouched dense step — g is exactly the scatter's +0.0 base —
        # replayed k_active times plus at most one masked (eta_m = 0) step.
        # smooth_lam * cur (not reg.smooth_grad) keeps g loop-varying so
        # XLA can't hoist eta * g out of the loop; the value is identical.
        def one(cur, eta_i):
            g = 0.0 + zl + smooth_lam * cur
            return reg.prox(cur - eta_i * g, eta_i)

        def body(i, cur):
            return jnp.where(i < k_active, one(cur, eta_v), cur)

        wl = jax.lax.fori_loop(0, jnp.max(k_active, initial=0), body, wl)
        return jnp.where(has_masked, one(wl, eta_v * 0.0), wl)

    def jnp_catchup(w_blk, last_blk, z_blk, idx, m):
        flat = idx.reshape(-1)
        ll = last_blk[flat]
        k_active = jnp.maximum(jnp.minimum(stop, m) - ll, 0)
        has_masked = (m - ll) > k_active
        wl = jnp_replay(w_blk[flat], z_blk[flat], k_active, has_masked, eta)
        return w_blk.at[flat].set(wl), last_blk.at[flat].set(m + 1)

    def jnp_touch(w_blk, idx, val, coef, z_blk, eta_m):
        # The argmax-based first-occurrence dedup is a scalar reduce
        # XLA:CPU won't vectorize, but it is the only dedup that applies
        # the duplicate contributions in the dense scatter-add's exact
        # program order — the bit-identity contract pins it here.  The
        # proba path below, which has no bit contract, uses the fast
        # masked column-sum dedup instead.
        flat = idx.reshape(-1)
        contrib = (val * coef[..., None]).reshape(-1)
        first = ops.ref._first_occurrence(flat)
        g = jnp.zeros_like(contrib).at[first].add(contrib)
        wl = w_blk[flat]
        g = g + z_blk[flat] + smooth_lam * wl
        v = reg.prox(wl - eta_m * g, eta_m)
        return w_blk.at[flat].set(v[first])

    def jnp_flush(w_blk, last_blk, z_blk):
        total = jnp.asarray(m_total, dtype=jnp.int32)
        k_active = jnp.maximum(jnp.minimum(stop, total) - last_blk, 0)
        has_masked = (total - last_blk) > k_active
        return jnp_replay(w_blk, z_blk, k_active, has_masked, eta)

    def jnp_proba(w_blk, idx, val, coef, z_blk, corr_blk, eta_m):
        # Masked column-sum dedup: each lane of a duplicated id receives
        # the SAME summed contribution, so every duplicate computes an
        # identical v and the scatter-set below is order-independent — no
        # argmax, no first-occurrence scalar reduce.  The reduce may
        # reassociate the sum; fine here, the proba variant's contract is
        # unbiasedness, not bit order (the exact path keeps
        # _first_occurrence).
        flat = idx.reshape(-1)
        contrib = (val * coef[..., None]).reshape(-1)
        eq = flat[:, None] == flat[None, :]
        g = jnp.sum(jnp.where(eq, contrib[:, None], 0.0), axis=0)
        wl = w_blk[flat]
        cl = corr_blk[flat]
        v = wl - eta_m * (g + cl * (z_blk[flat] + smooth_lam * wl))
        if reg_name in ("l1", "elastic_net"):
            v = losses_lib.soft_threshold(v, eta_m * lam * cl)
            if lam2:
                v = v / (1.0 + eta_m * lam2 * cl)
        return w_blk.at[flat].set(v)

    z_blocks = split(z_data)
    corr_blocks = None if exact else split(corrections)

    def step(carry, inp):
        if exact:
            w, last = carry
            last_blocks = split(last)
        else:
            w = carry
        ids, mask, m = inp  # ids: int32[u]; m: int32 inner-step index
        y = labels[ids]
        rows = [(block_indices[l][ids], block_values[l][ids]) for l in range(q)]
        w_blocks = split(w)
        if exact:
            for l in range(q):
                if use_kernels:
                    w_blocks[l], last_blocks[l] = ops.lazy_block_catchup(
                        w_blocks[l], last_blocks[l], z_blocks[l], rows[l][0],
                        eta, m, stop, lam=smooth_lam, lam1=k_l1, lam2=k_l2,
                    )
                else:
                    w_blocks[l], last_blocks[l] = jnp_catchup(
                        w_blocks[l], last_blocks[l], z_blocks[l], rows[l][0],
                        m,
                    )
        # Margins gather only touched ids, which the catch-up just
        # materialized — so coef is bit-identical to the eager epoch's.
        parts = [
            _block_margins(rows[l][0], rows[l][1], w_blocks[l], use_kernels)
            for l in range(q)
        ]
        s_m = tree_order_sum(parts)
        coef = (loss.dvalue(s_m, y) - loss.dvalue(s0[ids], y)) / u
        eta_m = eta * mask
        for l in range(q):
            idx, val = rows[l]
            if exact:
                if use_kernels:
                    w_blocks[l] = ops.lazy_block_touch_update(
                        w_blocks[l], idx, val, coef, z_blocks[l], eta_m,
                        lam=k_lam, lam1=k_l1, lam2=k_l2,
                    )
                else:
                    w_blocks[l] = jnp_touch(
                        w_blocks[l], idx, val, coef, z_blocks[l], eta_m
                    )
            elif use_kernels:
                w_blocks[l] = ops.lazy_block_proba_update(
                    w_blocks[l], idx, val, coef, z_blocks[l], corr_blocks[l],
                    eta_m, lam=k_lam, lam1=k_l1, lam2=k_l2,
                )
            else:
                w_blocks[l] = jnp_proba(
                    w_blocks[l], idx, val, coef, z_blocks[l], corr_blocks[l],
                    eta_m,
                )
        w_next = jnp.concatenate(w_blocks) if q > 1 else w_blocks[0]
        if exact:
            last_next = (
                jnp.concatenate(last_blocks) if q > 1 else last_blocks[0]
            )
            return (w_next, last_next), None
        return w_next, None

    steps_idx = jnp.arange(m_total, dtype=jnp.int32)
    if not exact:
        w_final, _ = jax.lax.scan(
            step, w0, (samples, step_mask, steps_idx)
        )
        return w_final
    last0 = jnp.zeros(w0.shape, dtype=jnp.int32)
    (w_final, last_final), _ = jax.lax.scan(
        step, (w0, last0), (samples, step_mask, steps_idx)
    )
    # Epoch-end flush: snapshots, objectives, and meters downstream all see
    # the fully-materialized iterate.
    w_blocks = split(w_final)
    last_blocks = split(last_final)
    total = jnp.asarray(m_total, dtype=jnp.int32)
    for l in range(q):
        if use_kernels:
            w_blocks[l] = ops.lazy_block_flush(
                w_blocks[l], last_blocks[l], z_blocks[l], eta, total, stop,
                lam=smooth_lam, lam1=k_l1, lam2=k_l2,
            )
        else:
            w_blocks[l] = jnp_flush(w_blocks[l], last_blocks[l], z_blocks[l])
    return jnp.concatenate(w_blocks) if q > 1 else w_blocks[0]


# ---------------------------------------------------------------------------
# Serial SVRG (Algorithm 2)
# ---------------------------------------------------------------------------


def run_serial_svrg(
    data: PaddedCSR | None,
    loss: losses_lib.MarginLoss,
    reg: losses_lib.Regularizer,
    cfg: SVRGConfig,
    *,
    use_kernels: bool = False,
    block_data: BlockCSR | None = None,
    init_w: jax.Array | None = None,
    lazy_updates: str | None = None,
    recovery: RecoveryPolicy | None = None,
    checkpoint: CheckpointPolicy | None = None,
) -> RunResult:
    _check_lazy(lazy_updates)
    if block_data is None:
        if data is None:
            raise ValueError("pass data or a prebuilt block_data")
        # The q=1 BlockCSR shares the PaddedCSR arrays (local ids == global).
        block_data = BlockCSR.from_padded(data, balanced(data.dim, 1))
    elif block_data.num_blocks != 1:
        raise ValueError(
            f"serial SVRG runs on the q=1 layout; block_data has "
            f"{block_data.num_blocks} blocks"
        )
    # Everything below reads the block layout only — a streamed build
    # (repro.data.pipeline.stream_block_csr) runs without the global
    # PaddedCSR ever existing.  The SVRG inner step itself lives in the
    # update-rule layer now; lazy import keeps the graph acyclic
    # (repro.core.__init__ imports this module eagerly).
    from repro.optim.update_rules import SVRGRule, make_context, run_with_rule

    return run_with_rule(
        SVRGRule(use_kernels=use_kernels, lazy_updates=lazy_updates),
        make_context(block_data, loss, reg, cfg),
        init_w=init_w,
        recovery=recovery,
        checkpoint=checkpoint,
    )


# ---------------------------------------------------------------------------
# FD-SVRG (Algorithm 1), metered simulation
# ---------------------------------------------------------------------------


def run_fdsvrg(
    data: PaddedCSR | None,
    partition: FeaturePartition,
    loss: losses_lib.MarginLoss,
    reg: losses_lib.Regularizer,
    cfg: SVRGConfig,
    cluster: ClusterModel | None = None,
    backend: Collectives | None = None,
    *,
    use_kernels: bool = False,
    block_data: BlockCSR | None = None,
    init_w: jax.Array | None = None,
    lazy_updates: str | None = None,
    recovery: RecoveryPolicy | None = None,
    checkpoint: CheckpointPolicy | None = None,
) -> RunResult:
    """Algorithm 1 with q = partition.num_blocks feature-sharded workers.

    Numerics: identical update sequence to serial SVRG (Theorem: the
    decomposition w^T x = sum_l w^(l)T x^(l) is exact; summation follows
    the tree order), computed on the block-local
    :class:`~repro.data.block_csr.BlockCSR` layout (built once here, or
    passed in as ``block_data`` to amortize across runs — in which case
    ``data=None`` is allowed and nothing global is ever touched: the
    streamed ingestion path runs the driver from per-worker slabs alone).
    Communication/time: the paper's accounting, metered through
    ``backend`` (default: a fresh ``SimBackend``) with the shared §4.5
    closed forms (:data:`repro.dist.COSTS`) —

      outer t:  tree reduce+broadcast of the N-vector  w_t^T D  -> 2qN scalars
      inner m:  tree reduce+broadcast of u margins      -> 2qu scalars

    ``lazy_updates`` ("exact" | "proba") swaps the inner epoch for the
    delayed-decay O(u * nnz_l) path (:func:`_lazy_inner_epoch`); it is
    block-local, so the metered schedule above is unchanged bit-for-bit.
    """
    _check_lazy(lazy_updates)
    q = partition.num_blocks
    if backend is None:
        backend = SimBackend(q, cluster)
    elif backend.q != q:
        raise ValueError(
            f"backend has q={backend.q} workers but the partition has "
            f"{q} blocks"
        )
    if block_data is None:
        if data is None:
            raise ValueError("pass data or a prebuilt block_data")
        block_data = BlockCSR.from_padded(data, partition)
    elif block_data.partition.bounds != partition.bounds:
        raise ValueError("block_data was built for a different partition")
    # The SVRG inner step, its metering, and the default abort hook all
    # live in the update-rule layer now (lazy import: repro.core.__init__
    # imports this module eagerly, so a module-level import back into
    # repro.optim would see a partially-initialized module).
    from repro.optim.update_rules import SVRGRule, make_context, run_with_rule

    return run_with_rule(
        SVRGRule(use_kernels=use_kernels, lazy_updates=lazy_updates),
        make_context(block_data, loss, reg, cfg, backend=backend),
        init_w=init_w,
        recovery=recovery,
        checkpoint=checkpoint,
    )


# ---------------------------------------------------------------------------
# Explicit q-worker simulation (tests): workers only see their own blocks
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("use_kernels",))
def _sim_margins(idx, val, w_block, use_kernels):
    return _block_margins(idx, val, w_block, use_kernels)


@functools.partial(jax.jit, static_argnames=("block_dim",))
def _sim_scatter(idx, val, coeffs, block_dim):
    return local_scatter(idx, val, coeffs, block_dim)


@functools.partial(
    jax.jit, static_argnames=("reg_name", "lam", "use_kernels", "lam2")
)
def _sim_update(w_block, idx, val, coef, z_block, eta_m, reg_name, lam,
                use_kernels, lam2=0.0):
    reg = losses_lib.Regularizer(reg_name, lam, lam2)
    if use_kernels:
        return ops.fused_block_prox_update(
            w_block, idx, val, coef, z_block, eta_m,
            lam=reg.smooth_lam, lam1=reg.prox_l1, lam2=reg.prox_l2,
        )
    g = (
        local_scatter(idx, val, coef, w_block.shape[0])
        + z_block
        + reg.smooth_grad(w_block)
    )
    return reg.prox(w_block - eta_m * g, eta_m)


# Lazy per-step worker operations (object-level simulation).  ``m``/``stop``
# /``total`` arrive as traced int32 scalars so all M inner steps share one
# compilation.  The replaying pair (catchup/flush) takes the smooth
# strength ``lam`` as a traced operand — baked in, XLA hoists the replay
# loop's pre-rounded ``eta * g`` and the trajectory drifts an ulp from the
# eager per-step oracle (see repro/kernels/ref.py); the single-application
# helpers keep the full static triple like :func:`_sim_update`.
@functools.partial(jax.jit, static_argnames=("prox_lams", "use_kernels"))
def _sim_lazy_catchup(w_block, last_block, z_block, idx, eta, m, stop, lam,
                      prox_lams, use_kernels):
    lam1, lam2 = prox_lams
    fn = ops.lazy_block_catchup if use_kernels else ops.ref.lazy_catchup_ref
    return fn(w_block, last_block, z_block, idx, eta, m, stop,
              lam=lam, lam1=lam1, lam2=lam2)


@functools.partial(jax.jit, static_argnames=("lams", "use_kernels"))
def _sim_lazy_touch(w_block, idx, val, coef, z_block, eta_m, lams,
                    use_kernels):
    lam, lam1, lam2 = lams
    fn = (
        ops.lazy_block_touch_update
        if use_kernels
        else ops.ref.lazy_touch_update_ref
    )
    return fn(w_block, idx, val, coef, z_block, eta_m,
              lam=lam, lam1=lam1, lam2=lam2)


@functools.partial(jax.jit, static_argnames=("prox_lams", "use_kernels"))
def _sim_lazy_flush(w_block, last_block, z_block, eta, total, stop, lam,
                    prox_lams, use_kernels):
    lam1, lam2 = prox_lams
    fn = ops.lazy_block_flush if use_kernels else ops.ref.lazy_flush_ref
    return fn(w_block, last_block, z_block, eta, total, stop,
              lam=lam, lam1=lam1, lam2=lam2)


@functools.partial(jax.jit, static_argnames=("lams", "use_kernels"))
def _sim_lazy_proba(w_block, idx, val, coef, z_block, corr_block, eta_m,
                    lams, use_kernels):
    lam, lam1, lam2 = lams
    fn = (
        ops.lazy_block_proba_update
        if use_kernels
        else ops.ref.lazy_proba_update_ref
    )
    return fn(w_block, idx, val, coef, z_block, corr_block, eta_m,
              lam=lam, lam1=lam1, lam2=lam2)


def fdsvrg_worker_simulation(
    data: PaddedCSR | None,
    partition: FeaturePartition,
    loss: losses_lib.MarginLoss,
    reg: losses_lib.Regularizer,
    cfg: SVRGConfig,
    backend: Collectives | None = None,
    *,
    use_kernels: bool = False,
    block_data: BlockCSR | None = None,
    init_w: jax.Array | None = None,
    lazy_updates: str | None = None,
    recovery: RecoveryPolicy | None = None,
    checkpoint: CheckpointPolicy | None = None,
) -> RunResult:
    """Object-level Algorithm 1: a list of per-worker states; every
    inner-loop cross-worker scalar passes through ``backend.all_reduce``
    (default: a fresh ``SimBackend`` running the explicit Figure-5 message
    schedule), and the full-gradient tree is accounted in aggregate via
    ``meter_tree`` (its value comes from the harness snapshot — the same
    canonical tree-order sum, metered once per outer like every driver).
    Each worker holds only its block-local CSR shard and its ``w^(l)``.

    Returns a full :class:`~repro.core.driver.RunResult` (same history
    schema as every driver; the meter is the backend's).  Deliberately
    step-by-step and slow — this is the executable spec, and the vehicle
    for the backend-equivalence tests.

    ``lazy_updates`` ("exact" | "proba") runs the worker-local delayed-decay
    flow: catch up the touched features before the margin read (exact),
    update only the touched lanes, and flush each worker's block at epoch
    end — the all-reduce schedule is untouched.
    """
    _check_lazy(lazy_updates)
    q = partition.num_blocks
    backend = backend or SimBackend(q)
    if block_data is None:
        if data is None:
            raise ValueError("pass data or a prebuilt block_data")
        block_data = BlockCSR.from_padded(data, partition)
    elif block_data.partition.bounds != partition.bounds:
        raise ValueError("block_data was built for a different partition")
    labels = block_data.labels
    block_dims = block_data.block_dims
    bounds = _bounds(block_dims)
    n = block_data.num_instances

    def split(w):
        return [w[bounds[l]:bounds[l + 1]] for l in range(q)]

    def snapshot(w):
        # Lines 3-4 compute-side: per-worker partial margins, canonical
        # tree-order sum (bit-identical to every backend's all_reduce);
        # line 5: purely local scatter of the full-gradient block.
        blocks = split(w)
        partials = [
            _sim_margins(*block_data.block(l), blocks[l], use_kernels)
            for l in range(q)
        ]
        s0 = tree_order_sum(partials)
        coeffs0 = loss.dvalue(s0, labels) / n
        z_blocks = [
            _sim_scatter(*block_data.block(l), coeffs0, block_dims[l])
            for l in range(q)
        ]
        z_data = jnp.concatenate(z_blocks) if q > 1 else z_blocks[0]
        return z_data, s0

    lams = _lazy_lams(reg)
    smooth_lam = jnp.asarray(reg.smooth_lam, dtype=jnp.float32)
    prox_lams = (reg.prox_l1, reg.prox_l2)
    exact = lazy_updates == "exact"
    corr_blocks = (
        [
            ops.step_corrections(
                block_data.nnz_col_block(l), n, cfg.batch_size
            )
            for l in range(q)
        ]
        if lazy_updates == "proba"
        else None
    )

    def epoch(t, rng, w, z_data, s0, eta_scale=1.0):
        # Account the full-gradient tree this outer consumed (lines 3-4).
        backend.meter_tree(payload=n)
        eta_eff = cfg.eta * eta_scale  # bit-exact when eta_scale == 1
        blocks = split(w)
        z_blocks = split(z_data)
        samples = draw_samples(rng, n, cfg.inner_steps, cfg.batch_size)
        mask = option_mask(rng, cfg.inner_steps, cfg.option)
        eta_full = jnp.asarray(eta_eff, dtype=blocks[0].dtype)
        stop = jnp.asarray(int(jnp.asarray(mask).sum()), dtype=jnp.int32)
        lasts = [
            jnp.zeros((block_dims[l],), dtype=jnp.int32) for l in range(q)
        ]

        for m in range(cfg.inner_steps):
            ids = samples[m]
            rows = [
                (block_data.indices[l][ids], block_data.values[l][ids])
                for l in range(q)
            ]
            y = labels[ids]
            if exact:
                # Replay each touched feature's deferred steps so the
                # margin read below sees the materialized values.
                for l in range(q):
                    blocks[l], lasts[l] = _sim_lazy_catchup(
                        blocks[l], lasts[l], z_blocks[l], rows[l][0],
                        eta_full, jnp.asarray(m, dtype=jnp.int32), stop,
                        smooth_lam, prox_lams, use_kernels,
                    )
            # Lines 9-10: per-worker partial margins, tree-summed (u scalars).
            partial_m = [
                _sim_margins(rows[l][0], rows[l][1], blocks[l], use_kernels)
                for l in range(q)
            ]
            s_m = backend.all_reduce(partial_m, payload=cfg.batch_size)
            s_a = s0[ids]
            coef = (loss.dvalue(s_m, y) - loss.dvalue(s_a, y)) / cfg.batch_size
            eta_m = jnp.asarray(eta_eff * float(mask[m]), dtype=blocks[0].dtype)
            # Line 11: purely local prox update on each block (the prox is
            # elementwise — paper eq. 3 — so no worker needs its peers).
            for l in range(q):
                if lazy_updates is None:
                    blocks[l] = _sim_update(
                        blocks[l], rows[l][0], rows[l][1], coef, z_blocks[l],
                        eta_m, reg.name, reg.lam, use_kernels, lam2=reg.lam2,
                    )
                elif exact:
                    blocks[l] = _sim_lazy_touch(
                        blocks[l], rows[l][0], rows[l][1], coef, z_blocks[l],
                        eta_m, lams, use_kernels,
                    )
                else:
                    blocks[l] = _sim_lazy_proba(
                        blocks[l], rows[l][0], rows[l][1], coef, z_blocks[l],
                        corr_blocks[l], eta_m, lams, use_kernels,
                    )
        if exact:
            # Epoch-end reconciliation, worker-locally (zero communication).
            total = jnp.asarray(cfg.inner_steps, dtype=jnp.int32)
            for l in range(q):
                blocks[l] = _sim_lazy_flush(
                    blocks[l], lasts[l], z_blocks[l], eta_full, total, stop,
                    smooth_lam, prox_lams, use_kernels,
                )
        return jnp.concatenate(blocks) if q > 1 else blocks[0]

    return run_outer_loop(
        outer_iters=cfg.outer_iters,
        seed=cfg.seed,
        init_w=resolve_init_w(
            init_w, block_data.dim, block_data.values[0].dtype
        ),
        snapshot=snapshot,
        epoch=epoch,
        evaluate=make_same_iterate_eval(labels, loss, reg, cfg.eta),
        backend=backend,
        recovery=_with_default_abort(
            recovery, n, block_data.global_nnz_max(), q
        ),
        checkpoint=checkpoint,
    )
