"""Back-compat shim — the tree reduce moved to :mod:`repro.dist.tree`.

Schedules, the canonical tree-order summation, the simulated executable
spec, and the TPU-native mappings are all part of the unified distributed
substrate now (see ``docs/architecture.md``).  Import from ``repro.dist``
in new code.
"""

from repro.dist.tree import (  # noqa: F401
    broadcast_schedule,
    collective_permute_tree,
    psum_tree,
    simulate_tree_sum,
    tree_order_sum,
    tree_schedule,
)

__all__ = [
    "broadcast_schedule",
    "collective_permute_tree",
    "psum_tree",
    "simulate_tree_sum",
    "tree_order_sum",
    "tree_schedule",
]
