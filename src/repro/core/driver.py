"""The ONE outer-loop harness every optimizer driver runs on.

Before this module existed, the outer loop — snapshot rotation, sample
drawing, objective/optimality reporting, history construction — was
hand-copied into six drivers, and the copies drifted (PR 3 fixed the same
stale grad-norm bug six times; the shard_map driver then drifted again).
:func:`run_outer_loop` is the single engine; a driver supplies three
hooks and nothing else:

``snapshot(w) -> (z_data, s0)``
    The data part of the full gradient and the margins at ``w``,
    **compute only** — never meters.  The harness calls it once before
    the first epoch (the outer-0 snapshot) and once after every epoch:
    the post-epoch full gradient doubles as the next outer's snapshot
    AND as the same-iterate diagnostic pair for reporting, so the whole
    run pays exactly one extra full gradient.

``epoch(t, rng, w, z_data, s0) -> w``
    One outer iteration's inner work: draw samples (via
    :func:`draw_samples` / :func:`option_mask` so every driver consumes
    the rng stream the same way), run the inner loop, and meter/charge
    ALL the traffic and modeled compute this outer consumes — including
    the snapshot tree it consumed — through the backend, with the closed
    forms of :mod:`repro.dist.costs`.  Metering lives here, not in
    ``snapshot``, so the per-run meter reflects the algorithm (one
    full-gradient phase per outer), not the reporting overhead.

``evaluate(w, z_data, s0) -> (objective, optimality_norm)``
    Defaults to :func:`make_same_iterate_eval`: f(w) from the margins
    already in hand plus the optimality residual pairing z and w at the
    SAME iterate (gradient norm for smooth g, prox gradient-mapping norm
    otherwise).

The harness owns the rng construction, wall-clock timing, and the
:class:`RunResult`/:class:`OuterRecord` history schema, so every method —
serial, FD-SVRG (metered sim, worker simulation, shard_map), DSVRG, and
the parameter-server baselines — reports identically and a new scenario
is a one-place change.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses as losses_lib
from repro.dist import Collectives, CommMeter


@dataclasses.dataclass
class OuterRecord:
    outer: int
    objective: float
    grad_norm: float
    comm_scalars: int
    comm_rounds: int
    modeled_time_s: float
    wall_time_s: float


@dataclasses.dataclass
class RunResult:
    w: jax.Array
    history: list[OuterRecord]
    meter: CommMeter

    def objectives(self) -> np.ndarray:
        return np.array([h.objective for h in self.history])

    def final_objective(self) -> float:
        return self.history[-1].objective


# ---------------------------------------------------------------------------
# Same-iterate reporting (objective from cached margins, optimality residual)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("loss_name", "reg_name"))
def _objective_from_margins_impl(s, labels, w, lam, lam2, loss_name, reg_name):
    loss = losses_lib.LOSSES[loss_name]
    reg = losses_lib.Regularizer(reg_name, lam, lam2)
    return jnp.mean(loss.value(s, labels)) + reg.value(w)


def objective_from_margins(
    s: jax.Array,
    labels: jax.Array,
    w: jax.Array,
    loss: losses_lib.MarginLoss,
    reg: losses_lib.Regularizer,
) -> float:
    """Objective at ``w`` given the margins ``s = w^T x_i`` already in hand
    (the snapshot computes them anyway — no point paying a second
    O(N·nnz) sweep just to report f(w))."""
    return float(
        _objective_from_margins_impl(
            s, labels, w, reg.lam, reg.lam2, loss.name, reg.name
        )
    )


def optimality_norm(
    z_data: jax.Array,
    w: jax.Array,
    reg: losses_lib.Regularizer,
    eta: float,
) -> float:
    """First-order optimality residual at ``w``, given the data gradient
    ``z_data = (1/N) sum_i phi'(w^T x_i, y_i) x_i`` computed **at the same
    w** (not a stale snapshot).

    Smooth g: the plain gradient norm ``||z_data + grad g(w)||``.
    Nonsmooth g (l1 / elastic_net): the prox gradient-mapping norm
    ``||(w - prox_{eta*g}(w - eta * grad f(w))) / eta||`` — the standard
    composite-optimality measure, which specializes to the gradient norm
    when the prox is the identity.  Both vanish exactly at a minimizer.
    """
    if reg.is_smooth:
        return float(jnp.linalg.norm(z_data + reg.grad(w)))
    v = reg.prox(w - eta * (z_data + reg.smooth_grad(w)), eta)
    return float(jnp.linalg.norm((w - v) / eta))


def make_same_iterate_eval(
    labels: jax.Array,
    loss: losses_lib.MarginLoss,
    reg: losses_lib.Regularizer,
    eta: float,
) -> Callable:
    """The standard ``evaluate`` hook: objective from the snapshot margins,
    optimality residual from the snapshot gradient — z, s0, and w all at
    the post-epoch iterate."""

    def evaluate(w, z_data, s0):
        obj = objective_from_margins(s0, labels, w, loss, reg)
        return obj, optimality_norm(z_data, w, reg, eta)

    return evaluate


# ---------------------------------------------------------------------------
# Sample / option-mask drawing (one rng-stream convention for all drivers)
# ---------------------------------------------------------------------------


def resolve_init_w(
    init_w: jax.Array | None, dim: int, dtype
) -> jax.Array:
    """The starting iterate every driver shares: zeros unless the caller
    warm-starts (``repro.api`` threads ``FDSVRGClassifier.partial_fit``'s
    coefficients through here), always in the data's dtype so a warm
    start can't silently promote a float32 run to float64."""
    if init_w is None:
        return jnp.zeros((dim,), dtype=dtype)
    init_w = jnp.asarray(init_w, dtype=dtype)
    if init_w.shape != (dim,):
        raise ValueError(
            f"init_w has shape {init_w.shape}, expected ({dim},)"
        )
    return init_w


def draw_samples(rng: np.random.Generator, n: int, m: int, u: int) -> np.ndarray:
    """M mini-batches of u uniform instance ids (the paper's sampling)."""
    return rng.integers(0, n, size=(m, u), dtype=np.int64).astype(np.int32)


def option_mask(rng: np.random.Generator, m: int, option: str) -> np.ndarray:
    """Step mask: Option I runs all M steps (and draws nothing from the
    rng); Option II stops at a uniform random step."""
    if option == "I":
        return np.ones(m, dtype=np.float32)
    stop = int(rng.integers(1, m + 1))
    return (np.arange(m) < stop).astype(np.float32)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


def run_outer_loop(
    *,
    outer_iters: int,
    seed: int,
    init_w: jax.Array,
    snapshot: Callable,
    epoch: Callable,
    evaluate: Callable,
    backend: Collectives | None = None,
) -> RunResult:
    """Run ``outer_iters`` outer iterations with snapshot rotation.

    Sequence per outer t: ``epoch`` consumes the current snapshot
    (z, s0) — the full gradient at the iterate entering the epoch — then
    ``snapshot`` recomputes at the post-epoch iterate, which is both the
    next outer's snapshot and the same-iterate pair ``evaluate`` reports
    from.  ``backend=None`` means no communication (the serial path):
    the history records zero scalars/rounds/modeled time against a fresh
    empty meter.
    """
    rng = np.random.default_rng(seed)
    w = init_w
    meter = backend.meter if backend is not None else CommMeter()
    history: list[OuterRecord] = []
    t_start = time.perf_counter()
    z_data, s0 = snapshot(w)  # outer-0 snapshot
    for t in range(outer_iters):
        w = epoch(t, rng, w, z_data, s0)
        # Rotation: the post-epoch full gradient is next outer's snapshot
        # and this record's diagnostic pair (z and w at the SAME iterate).
        z_data, s0 = snapshot(w)
        obj, gnorm = evaluate(w, z_data, s0)
        history.append(
            OuterRecord(
                t,
                obj,
                gnorm,
                meter.total_scalars,
                meter.total_rounds,
                backend.modeled_time_s if backend is not None else 0.0,
                time.perf_counter() - t_start,
            )
        )
    return RunResult(w=w, history=history, meter=meter)
