"""The ONE outer-loop harness every optimizer driver runs on.

Before this module existed, the outer loop — snapshot rotation, sample
drawing, objective/optimality reporting, history construction — was
hand-copied into six drivers, and the copies drifted (PR 3 fixed the same
stale grad-norm bug six times; the shard_map driver then drifted again).
:func:`run_outer_loop` is the single engine; a driver supplies three
hooks and nothing else:

``snapshot(w) -> (z_data, s0)``
    The data part of the full gradient and the margins at ``w``,
    **compute only** — never meters.  The harness calls it once before
    the first epoch (the outer-0 snapshot) and once after every epoch:
    the post-epoch full gradient doubles as the next outer's snapshot
    AND as the same-iterate diagnostic pair for reporting, so the whole
    run pays exactly one extra full gradient.

``epoch(t, rng, w, z_data, s0) -> w``
    One outer iteration's inner work: draw samples (via
    :func:`draw_samples` / :func:`option_mask` so every driver consumes
    the rng stream the same way), run the inner loop, and meter/charge
    ALL the traffic and modeled compute this outer consumes — including
    the snapshot tree it consumed — through the backend, with the closed
    forms of :mod:`repro.dist.costs`.  Metering lives here, not in
    ``snapshot``, so the per-run meter reflects the algorithm (one
    full-gradient phase per outer), not the reporting overhead.

``evaluate(w, z_data, s0) -> (objective, optimality_norm)``
    Defaults to :func:`make_same_iterate_eval`: f(w) from the margins
    already in hand plus the optimality residual pairing z and w at the
    SAME iterate (gradient norm for smooth g, prox gradient-mapping norm
    otherwise).

The harness owns the rng construction, wall-clock timing, and the
:class:`RunResult`/:class:`OuterRecord` history schema, so every method —
serial, FD-SVRG (metered sim, worker simulation, shard_map), DSVRG, and
the parameter-server baselines — reports identically and a new scenario
is a one-place change.

It also owns the failure semantics, because SVRG hands them to us: the
replicated snapshot (w̃, z, s0) held at the top of each outer iteration
is a complete, consistent recovery point, so both recovery paths are
*epoch-abort-to-snapshot* — throw away the failed epoch and rerun it
from state every worker already holds:

* a **divergence guard** (:class:`RecoveryPolicy`): a non-finite or
  exploding objective after an epoch (e.g. a corrupted collective
  payload, or an eta too large for the spectrum) aborts the epoch,
  scales eta down by ``eta_backoff``, and reruns from the snapshot;
* **unrecoverable faults** (any :class:`repro.dist.FaultError`, e.g. a
  worker crash or retries exhausted) abort the epoch the same way, with
  the abort path's extra communication metered via the policy's
  ``on_abort`` hook (the FD drivers default it to one full-gradient
  redistribution).

and **checkpoint/resume** (:class:`CheckpointPolicy`): every k outers
the harness persists (w, snapshot, rng state, meter counters, modeled
time, history) through :mod:`repro.checkpoint.ckpt`; a resumed run is
bit-identical to the uninterrupted one — iterates, objectives, meter
counters, and modeled time exactly equal (pinned in
``tests/test_faults.py``).
"""

from __future__ import annotations

import dataclasses
import functools
import inspect
import os
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.core import losses as losses_lib
from repro.dist import Collectives, CommMeter, FaultError


class DivergenceError(FaultError):
    """The post-epoch iterate is numerically broken (NaN/inf objective or
    exploding optimality norm) — raised by the harness's divergence guard
    and recovered like any other fault: abort to snapshot (plus eta
    backoff, since divergence is usually a step-size problem)."""


@dataclasses.dataclass
class OuterRecord:
    outer: int
    objective: float
    grad_norm: float
    comm_scalars: int
    comm_rounds: int
    modeled_time_s: float
    wall_time_s: float


@dataclasses.dataclass
class RunResult:
    w: jax.Array
    history: list[OuterRecord]
    meter: CommMeter

    def objectives(self) -> np.ndarray:
        return np.array([h.objective for h in self.history])

    def final_objective(self) -> float:
        return self.history[-1].objective


# ---------------------------------------------------------------------------
# Same-iterate reporting (objective from cached margins, optimality residual)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("loss_name", "reg_name"))
def _objective_from_margins_impl(s, labels, w, lam, lam2, loss_name, reg_name):
    loss = losses_lib.LOSSES[loss_name]
    reg = losses_lib.Regularizer(reg_name, lam, lam2)
    return jnp.mean(loss.value(s, labels)) + reg.value(w)


def objective_from_margins(
    s: jax.Array,
    labels: jax.Array,
    w: jax.Array,
    loss: losses_lib.MarginLoss,
    reg: losses_lib.Regularizer,
) -> float:
    """Objective at ``w`` given the margins ``s = w^T x_i`` already in hand
    (the snapshot computes them anyway — no point paying a second
    O(N·nnz) sweep just to report f(w))."""
    return float(
        _objective_from_margins_impl(
            s, labels, w, reg.lam, reg.lam2, loss.name, reg.name
        )
    )


def optimality_norm(
    z_data: jax.Array,
    w: jax.Array,
    reg: losses_lib.Regularizer,
    eta: float,
) -> float:
    """First-order optimality residual at ``w``, given the data gradient
    ``z_data = (1/N) sum_i phi'(w^T x_i, y_i) x_i`` computed **at the same
    w** (not a stale snapshot).

    Smooth g: the plain gradient norm ``||z_data + grad g(w)||``.
    Nonsmooth g (l1 / elastic_net): the prox gradient-mapping norm
    ``||(w - prox_{eta*g}(w - eta * grad f(w))) / eta||`` — the standard
    composite-optimality measure, which specializes to the gradient norm
    when the prox is the identity.  Both vanish exactly at a minimizer.
    """
    if reg.is_smooth:
        return float(jnp.linalg.norm(z_data + reg.grad(w)))
    v = reg.prox(w - eta * (z_data + reg.smooth_grad(w)), eta)
    return float(jnp.linalg.norm((w - v) / eta))


def make_same_iterate_eval(
    labels: jax.Array,
    loss: losses_lib.MarginLoss,
    reg: losses_lib.Regularizer,
    eta: float,
) -> Callable:
    """The standard ``evaluate`` hook: objective from the snapshot margins,
    optimality residual from the snapshot gradient — z, s0, and w all at
    the post-epoch iterate."""

    def evaluate(w, z_data, s0):
        obj = objective_from_margins(s0, labels, w, loss, reg)
        return obj, optimality_norm(z_data, w, reg, eta)

    return evaluate


# ---------------------------------------------------------------------------
# Sample / option-mask drawing (one rng-stream convention for all drivers)
# ---------------------------------------------------------------------------


def resolve_init_w(
    init_w: jax.Array | None, dim: int, dtype, num_outputs: int = 1
) -> jax.Array:
    """The starting iterate every driver shares: zeros unless the caller
    warm-starts (``repro.api`` threads ``FDSVRGClassifier.partial_fit``'s
    coefficients through here), always in the data's dtype so a warm
    start can't silently promote a float32 run to float64.
    ``num_outputs > 1`` is the multi-output shape ``w ∈ R^{d×k}``
    (one-vs-rest / multivariate squared loss); ``1`` keeps the historical
    1-D iterate bit-for-bit."""
    shape = (dim,) if num_outputs == 1 else (dim, num_outputs)
    if init_w is None:
        return jnp.zeros(shape, dtype=dtype)
    init_w = jnp.asarray(init_w, dtype=dtype)
    if init_w.shape != shape:
        raise ValueError(
            f"init_w has shape {init_w.shape}, expected {shape}"
        )
    return init_w


def draw_samples(rng: np.random.Generator, n: int, m: int, u: int) -> np.ndarray:
    """M mini-batches of u uniform instance ids (the paper's sampling)."""
    return rng.integers(0, n, size=(m, u), dtype=np.int64).astype(np.int32)


def option_mask(rng: np.random.Generator, m: int, option: str) -> np.ndarray:
    """Step mask: Option I runs all M steps (and draws nothing from the
    rng); Option II stops at a uniform random step."""
    if option == "I":
        return np.ones(m, dtype=np.float32)
    stop = int(rng.integers(1, m + 1))
    return (np.arange(m) < stop).astype(np.float32)


# ---------------------------------------------------------------------------
# Failure semantics: recovery + checkpoint policies
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """Epoch-abort-to-snapshot recovery for the outer loop.

    On any :class:`~repro.dist.FaultError` raised during an epoch (worker
    crash, retries exhausted) or by the divergence guard, the harness
    discards the failed epoch and reruns outer t from the snapshot
    (w, z, s0) it already holds — SVRG's replicated outer state makes
    this correct with no ad-hoc repair.  ``on_abort(backend)`` meters
    whatever the abort path costs (the FD drivers default it to one
    full-gradient redistribution under the ``"abort"`` kind); after
    ``max_epoch_retries`` consecutive failed attempts of the same outer,
    the fault propagates to the caller.
    """

    max_epoch_retries: int = 2  # reruns allowed per outer iteration
    eta_backoff: float = 0.5  # eta scale multiplier on divergence
    divergence_factor: float = 1e3  # obj > factor * |prev obj| => diverged
    on_abort: Callable | None = None  # on_abort(backend): meter the abort

    def __post_init__(self) -> None:
        if self.max_epoch_retries < 0:
            raise ValueError("max_epoch_retries >= 0 required")
        if not 0.0 < self.eta_backoff <= 1.0:
            raise ValueError("eta_backoff must be in (0, 1]")
        if self.divergence_factor <= 1.0:
            raise ValueError("divergence_factor > 1 required")


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    """Persist outer-loop state every ``every`` outers (and at the end).

    One rolling checkpoint at ``<directory>/outer``: arrays (w, z, s0)
    in the npz, everything else — outer index, eta scale, numpy rng
    state, meter counters + event log, modeled time, history — in the
    json sidecar's ``extra`` dict.  ``resume=True`` restores all of it
    before the first epoch when the checkpoint exists (and starts fresh
    when it does not, so a first run and a restart share one flag); the
    resumed run is bit-identical to the uninterrupted one.
    """

    directory: str
    every: int = 1
    resume: bool = False

    def __post_init__(self) -> None:
        if not self.directory:
            raise ValueError("CheckpointPolicy.directory must be non-empty")
        if self.every < 1:
            raise ValueError("CheckpointPolicy.every >= 1 required")

    @property
    def path(self) -> str:
        return os.path.join(self.directory, "outer")

    def exists(self) -> bool:
        return os.path.exists(self.path + ".npz")


_CKPT_VERSION = 1


def _save_outer_state(
    policy: CheckpointPolicy,
    *,
    w,
    z_data,
    s0,
    outer_next: int,
    eta_scale: float,
    rng: np.random.Generator,
    meter: CommMeter,
    modeled_time_s: float,
    history: list[OuterRecord],
) -> None:
    ckpt.save(
        policy.path,
        {"w": w, "z": z_data, "s0": s0},
        extra={
            "version": _CKPT_VERSION,
            "outer_next": int(outer_next),
            "eta_scale": float(eta_scale),
            "rng_state": rng.bit_generator.state,
            "meter": meter.state_dict(),
            "modeled_time_s": float(modeled_time_s),
            "history": [dataclasses.asdict(h) for h in history],
        },
    )


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


def run_outer_loop(
    *,
    outer_iters: int,
    seed: int,
    init_w: jax.Array,
    snapshot: Callable,
    epoch: Callable,
    evaluate: Callable,
    backend: Collectives | None = None,
    recovery: RecoveryPolicy | None = None,
    checkpoint: CheckpointPolicy | None = None,
) -> RunResult:
    """Run ``outer_iters`` outer iterations with snapshot rotation.

    Sequence per outer t: ``epoch`` consumes the current snapshot
    (z, s0) — the full gradient at the iterate entering the epoch — then
    ``snapshot`` recomputes at the post-epoch iterate, which is both the
    next outer's snapshot and the same-iterate pair ``evaluate`` reports
    from.  ``backend=None`` means no communication (the serial path):
    the history records zero scalars/rounds/modeled time against a fresh
    empty meter.

    ``recovery`` arms epoch-abort-to-snapshot: the snapshot entering the
    epoch is only rotated *after* the epoch and its evaluation succeed,
    so a failed attempt retries from exactly the state it started with.
    If the epoch hook accepts an ``eta_scale`` keyword, divergence
    backoff is threaded through it (a retried epoch reruns with a
    smaller step); hooks that don't accept it still get abort/retry.
    ``checkpoint`` arms persistence/resume (see
    :class:`CheckpointPolicy`).
    """
    rng = np.random.default_rng(seed)
    w = init_w
    meter = backend.meter if backend is not None else CommMeter()
    history: list[OuterRecord] = []
    eta_scale = 1.0
    start_outer = 0
    accepts_scale = "eta_scale" in inspect.signature(epoch).parameters
    t_start = time.perf_counter()
    z_data, s0 = snapshot(w)  # outer-0 snapshot
    if checkpoint is not None and checkpoint.resume and checkpoint.exists():
        state = ckpt.restore(
            checkpoint.path, {"w": w, "z": z_data, "s0": s0}
        )
        extra = ckpt.load_meta(checkpoint.path)["extra"]
        w, z_data, s0 = state["w"], state["z"], state["s0"]
        rng.bit_generator.state = extra["rng_state"]
        meter.load_state(extra["meter"])
        if backend is not None:
            # 0.0 + x == x bitwise, and modeled time accumulates left to
            # right, so re-charging the saved prefix then continuing is
            # exactly the uninterrupted sum.
            backend.charge_seconds(extra["modeled_time_s"])
        eta_scale = float(extra["eta_scale"])
        start_outer = int(extra["outer_next"])
        history = [OuterRecord(**h) for h in extra["history"]]
        if history:
            t_start = time.perf_counter() - history[-1].wall_time_s
    prev_obj: float | None = None
    for t in range(start_outer, outer_iters):
        attempts = 0
        while True:
            begin_outer = getattr(backend, "begin_outer", None)
            if begin_outer is not None:
                begin_outer(t)
            try:
                if accepts_scale:
                    w_new = epoch(t, rng, w, z_data, s0, eta_scale=eta_scale)
                else:
                    w_new = epoch(t, rng, w, z_data, s0)
                # Rotation: the post-epoch full gradient is next outer's
                # snapshot and this record's diagnostic pair (z and w at
                # the SAME iterate).
                z_new, s0_new = snapshot(w_new)
                obj, gnorm = evaluate(w_new, z_new, s0_new)
                if recovery is not None:
                    floor = max(abs(prev_obj), 1.0) if prev_obj is not None \
                        else None
                    if not (np.isfinite(obj) and np.isfinite(gnorm)):
                        raise DivergenceError(
                            f"outer {t}: non-finite objective/optimality "
                            f"(obj={obj}, norm={gnorm})"
                        )
                    if floor is not None and \
                            obj > recovery.divergence_factor * floor:
                        raise DivergenceError(
                            f"outer {t}: objective exploded "
                            f"({obj:.3e} > {recovery.divergence_factor:g} * "
                            f"{floor:.3e})"
                        )
                break
            except FaultError as err:
                if recovery is None or attempts >= recovery.max_epoch_retries:
                    raise
                attempts += 1
                if isinstance(err, DivergenceError):
                    eta_scale *= recovery.eta_backoff
                if recovery.on_abort is not None and backend is not None:
                    recovery.on_abort(backend)
                # Retry from the snapshot: w/z_data/s0 were never rotated,
                # so the failed epoch leaves no trace in the trajectory —
                # only in the meter (retries, aborts) and modeled time.
        w, z_data, s0 = w_new, z_new, s0_new
        prev_obj = obj
        history.append(
            OuterRecord(
                t,
                obj,
                gnorm,
                meter.total_scalars,
                meter.total_rounds,
                backend.modeled_time_s if backend is not None else 0.0,
                time.perf_counter() - t_start,
            )
        )
        if checkpoint is not None and (
            (t + 1) % checkpoint.every == 0 or t == outer_iters - 1
        ):
            _save_outer_state(
                checkpoint,
                w=w,
                z_data=z_data,
                s0=s0,
                outer_next=t + 1,
                eta_scale=eta_scale,
                rng=rng,
                meter=meter,
                modeled_time_s=(
                    backend.modeled_time_s if backend is not None else 0.0
                ),
                history=history,
            )
    return RunResult(w=w, history=history, meter=meter)
