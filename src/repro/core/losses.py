"""Loss functions for linear classification (paper §2).

The paper's objective is

    min_w  f(w) = (1/N) sum_i phi(w^T x_i, y_i) + g(w)

with phi the logistic loss (LR) or hinge loss (linear SVM) and g an L2 or
L1 regularizer.  All functions here operate on the *margin* ``s = w^T x``
and the label ``y in {-1,+1}`` so that they compose with the
feature-distributed inner-product machinery: the only thing workers must
agree on is the scalar ``s``.

Every loss exposes ``value(s, y)`` and ``dvalue(s, y)`` (derivative w.r.t.
the margin), both elementwise, so a gradient w.r.t. ``w`` is
``dvalue(s, y) * x`` — computable per feature shard.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MarginLoss:
    """A loss phi(s, y) defined on the margin s = w^T x."""

    name: str
    value: Callable[[jax.Array, jax.Array], jax.Array]
    dvalue: Callable[[jax.Array, jax.Array], jax.Array]
    # Smoothness constant of phi as a function of s (used by step-size
    # heuristics and the Theorem-1 rate check in tests).
    smoothness: float


def _logistic_value(s: jax.Array, y: jax.Array) -> jax.Array:
    # log(1 + exp(-y s)) computed stably.
    z = -y * s
    return jnp.logaddexp(0.0, z)


def _logistic_dvalue(s: jax.Array, y: jax.Array) -> jax.Array:
    # d/ds log(1+exp(-ys)) = -y * sigmoid(-y s)
    z = -y * s
    return -y * jax.nn.sigmoid(z)


logistic = MarginLoss(
    name="logistic",
    value=_logistic_value,
    dvalue=_logistic_dvalue,
    smoothness=0.25,
)


def _squared_hinge_value(s: jax.Array, y: jax.Array) -> jax.Array:
    m = jnp.maximum(0.0, 1.0 - y * s)
    return 0.5 * m * m


def _squared_hinge_dvalue(s: jax.Array, y: jax.Array) -> jax.Array:
    m = jnp.maximum(0.0, 1.0 - y * s)
    return -y * m


# The paper's SVM uses the plain hinge; SVRG theory wants smooth phi, so we
# provide the standard squared hinge as the smooth SVM surrogate and the
# plain hinge (subgradient) for completeness.
squared_hinge = MarginLoss(
    name="squared_hinge",
    value=_squared_hinge_value,
    dvalue=_squared_hinge_dvalue,
    smoothness=1.0,
)


def _hinge_value(s: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.maximum(0.0, 1.0 - y * s)


def _hinge_dvalue(s: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.where(y * s < 1.0, -y, 0.0)


hinge = MarginLoss(
    name="hinge",
    value=_hinge_value,
    dvalue=_hinge_dvalue,
    smoothness=float("inf"),
)


LOSSES = {l.name: l for l in (logistic, squared_hinge, hinge)}


@dataclasses.dataclass(frozen=True)
class Regularizer:
    """g(w) applied per feature block (paper eq. (3): g decomposes over blocks)."""

    name: str
    lam: float

    def value(self, w: jax.Array) -> jax.Array:
        if self.name == "l2":
            return 0.5 * self.lam * jnp.sum(w * w)
        if self.name == "l1":
            return self.lam * jnp.sum(jnp.abs(w))
        if self.name == "none":
            return jnp.zeros((), dtype=w.dtype)
        raise ValueError(self.name)

    def grad(self, w: jax.Array) -> jax.Array:
        if self.name == "l2":
            return self.lam * w
        if self.name == "l1":
            return self.lam * jnp.sign(w)
        if self.name == "none":
            return jnp.zeros_like(w)
        raise ValueError(self.name)


def l2(lam: float) -> Regularizer:
    return Regularizer("l2", lam)


def l1(lam: float) -> Regularizer:
    return Regularizer("l1", lam)


def no_reg() -> Regularizer:
    return Regularizer("none", 0.0)
