"""Loss functions for linear classification (paper §2).

The paper's objective is

    min_w  f(w) = (1/N) sum_i phi(w^T x_i, y_i) + g(w)

with phi the logistic loss (LR) or hinge loss (linear SVM) and g an L2 or
L1 regularizer.  All functions here operate on the *margin* ``s = w^T x``
and the label ``y in {-1,+1}`` so that they compose with the
feature-distributed inner-product machinery: the only thing workers must
agree on is the scalar ``s``.

Every loss exposes ``value(s, y)`` and ``dvalue(s, y)`` (derivative w.r.t.
the margin), both elementwise, so a gradient w.r.t. ``w`` is
``dvalue(s, y) * x`` — computable per feature shard.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MarginLoss:
    """A loss phi(s, y) defined on the margin s = w^T x."""

    name: str
    value: Callable[[jax.Array, jax.Array], jax.Array]
    dvalue: Callable[[jax.Array, jax.Array], jax.Array]
    # Smoothness constant of phi as a function of s (used by step-size
    # heuristics and the Theorem-1 rate check in tests).
    smoothness: float


def _logistic_value(s: jax.Array, y: jax.Array) -> jax.Array:
    # log(1 + exp(-y s)) computed stably.
    z = -y * s
    return jnp.logaddexp(0.0, z)


def _logistic_dvalue(s: jax.Array, y: jax.Array) -> jax.Array:
    # d/ds log(1+exp(-ys)) = -y * sigmoid(-y s)
    z = -y * s
    return -y * jax.nn.sigmoid(z)


logistic = MarginLoss(
    name="logistic",
    value=_logistic_value,
    dvalue=_logistic_dvalue,
    smoothness=0.25,
)


def _squared_hinge_value(s: jax.Array, y: jax.Array) -> jax.Array:
    m = jnp.maximum(0.0, 1.0 - y * s)
    return 0.5 * m * m


def _squared_hinge_dvalue(s: jax.Array, y: jax.Array) -> jax.Array:
    m = jnp.maximum(0.0, 1.0 - y * s)
    return -y * m


# The paper's SVM uses the plain hinge; SVRG theory wants smooth phi, so we
# provide the standard squared hinge as the smooth SVM surrogate and the
# plain hinge (subgradient) for completeness.
squared_hinge = MarginLoss(
    name="squared_hinge",
    value=_squared_hinge_value,
    dvalue=_squared_hinge_dvalue,
    smoothness=1.0,
)


def _hinge_value(s: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.maximum(0.0, 1.0 - y * s)


def _hinge_dvalue(s: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.where(y * s < 1.0, -y, 0.0)


hinge = MarginLoss(
    name="hinge",
    value=_hinge_value,
    dvalue=_hinge_dvalue,
    smoothness=float("inf"),
)


def _squared_value(s: jax.Array, y: jax.Array) -> jax.Array:
    r = s - y
    return 0.5 * r * r


def _squared_dvalue(s: jax.Array, y: jax.Array) -> jax.Array:
    return s - y


# Squared loss on the margin: least-squares regression against the (not
# necessarily ±1) target y.  This is the multi-output workhorse — with
# w ∈ R^{d×k} and a [N, k] target matrix, each output column is an
# independent least-squares problem sharing one data matrix (and, in the
# FD drivers, one margin tree per sampled batch).  For y ∈ {-1, +1} it is
# the classic least-squares classifier, so it also drives one-vs-rest
# multiclass through the estimator.
squared = MarginLoss(
    name="squared",
    value=_squared_value,
    dvalue=_squared_dvalue,
    smoothness=1.0,
)


LOSSES = {l.name: l for l in (logistic, squared_hinge, hinge, squared)}


def soft_threshold(v: jax.Array, t: jax.Array | float) -> jax.Array:
    """prox of t*||.||_1: sign(v) * max(|v| - t, 0), elementwise.

    This exact expression is the numerics contract shared with the fused
    Pallas prox kernel (kernels/prox_update.py) and its oracle
    (kernels/ref.py) — same ops, same order, bit-identical results.
    """
    return jnp.sign(v) * jnp.maximum(jnp.abs(v) - t, 0.0)


@dataclasses.dataclass(frozen=True)
class Regularizer:
    """g(w) applied per feature block (paper eq. (3): g decomposes over blocks).

    Two ways to consume g in an optimizer:

    * **smooth path** (``l2`` / ``none``): add :meth:`grad` (or its folded
      coefficient :attr:`smooth_lam`) to the data gradient.
    * **proximal path** (``l1`` / ``elastic_net``): the nonsmooth part is
      handled by :meth:`prox` — the inner step becomes
      ``w <- prox_{eta*g}(w - eta * smooth_grad)``.  Because g decomposes
      over feature blocks (eq. 3), prox is elementwise and therefore
      purely block-local: FD-Prox-SVRG adds **zero** communication.

    ``lam`` is the L2 strength for ``l2``, the L1 strength for ``l1`` and
    ``elastic_net``; ``lam2`` is the elastic-net L2 strength (closed-form
    prox: soft-threshold then shrink by 1/(1 + eta*lam2)).
    """

    name: str
    lam: float
    lam2: float = 0.0

    def value(self, w: jax.Array) -> jax.Array:
        if self.name == "l2":
            return 0.5 * self.lam * jnp.sum(w * w)
        if self.name == "l1":
            return self.lam * jnp.sum(jnp.abs(w))
        if self.name == "elastic_net":
            return self.lam * jnp.sum(jnp.abs(w)) + 0.5 * self.lam2 * jnp.sum(w * w)
        if self.name == "none":
            return jnp.zeros((), dtype=w.dtype)
        raise ValueError(self.name)

    def grad(self, w: jax.Array) -> jax.Array:
        """(Sub)gradient of g — diagnostics and the historical subgradient
        path; the optimizers use smooth_grad + prox instead."""
        if self.name == "l2":
            return self.lam * w
        if self.name == "l1":
            return self.lam * jnp.sign(w)
        if self.name == "elastic_net":
            return self.lam * jnp.sign(w) + self.lam2 * w
        if self.name == "none":
            return jnp.zeros_like(w)
        raise ValueError(self.name)

    @property
    def is_smooth(self) -> bool:
        return self.name in ("l2", "none")

    @property
    def smooth_lam(self) -> float:
        """L2 coefficient folded into the smooth gradient (0 unless 'l2';
        the elastic-net L2 term goes through the closed-form prox)."""
        return float(self.lam) if self.name == "l2" else 0.0

    @property
    def prox_l1(self) -> float:
        """L1 strength handled by prox (0 for the smooth family)."""
        if self.name in ("l1", "elastic_net"):
            return float(self.lam)
        if self.name in ("l2", "none"):
            return 0.0
        raise ValueError(self.name)

    @property
    def prox_l2(self) -> float:
        """Elastic-net L2 strength handled by prox."""
        return float(self.lam2) if self.name == "elastic_net" else 0.0

    def smooth_grad(self, w: jax.Array) -> jax.Array:
        """Gradient of the smooth part of g only (what the inner step adds
        to the variance-reduced data gradient before prox)."""
        if self.name == "l2":
            return self.lam * w
        if self.name in ("l1", "elastic_net", "none"):
            return jnp.zeros_like(w)
        raise ValueError(self.name)

    def prox(self, v: jax.Array, eta: jax.Array | float) -> jax.Array:
        """prox_{eta*g_nonsmooth}(v); identity for the smooth family, so the
        proximal update specializes exactly to the classic SVRG step."""
        if self.name in ("l2", "none"):
            return v
        if self.name == "l1":
            return soft_threshold(v, eta * self.lam)
        if self.name == "elastic_net":
            out = soft_threshold(v, eta * self.lam)
            return out / (1.0 + eta * self.lam2) if self.lam2 else out
        raise ValueError(self.name)


def l2(lam: float) -> Regularizer:
    return Regularizer("l2", lam)


def l1(lam: float) -> Regularizer:
    return Regularizer("l1", lam)


def elastic_net(lam1: float, lam2: float) -> Regularizer:
    return Regularizer("elastic_net", lam1, lam2)


def no_reg() -> Regularizer:
    return Regularizer("none", 0.0)
