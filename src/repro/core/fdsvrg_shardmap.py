"""Deployable FD-SVRG: shard_map over the mesh's feature ("model") axes.

This is the TPU-native realization of Algorithm 1, built on
:class:`repro.dist.ShardMapBackend`.  The parameter vector ``w`` lives
feature-sharded across the given mesh axes (every chip is one of the
paper's Workers); the padded-CSR instance data is replicated (the paper
replicates instances across feature shards by construction — each worker
stores the feature *slice* of every instance; on TPU we keep the global
index/value rows and mask to the local block, which is the shape-static
equivalent).

Communication per inner step is exactly one all-reduce of ``u`` scalars
over the feature axes — the hardware tree standing in for Figure 5.  The
full-gradient phase all-reduces the N-vector of margins once per outer
iteration.  Everything else is chip-local.  The collective is selected by
the backend's ``tree_mode``:

  * ``"psum"``      — hardware all-reduce (default, fastest)
  * ``"butterfly"`` — explicit log-depth ppermute butterfly
    (:func:`repro.dist.tree.collective_permute_tree`) proving the
    paper's explicit topology lowers on TPU; used in §Perf comparisons.

On-device traffic cannot be observed from traced code, so
:func:`run_fdsvrg_sharded` meters the closed forms host-side through the
backend — the same accounting, the same meter, as the simulation paths.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import losses as losses_lib
from repro.dist import ClusterModel, ShardMapBackend


@dataclasses.dataclass(frozen=True)
class FDSVRGShardedConfig:
    dim: int
    num_instances: int
    nnz_max: int
    eta: float
    inner_steps: int
    batch_size: int = 16
    loss_name: str = "logistic"
    reg_name: str = "l2"
    lam: float = 1e-4
    tree_mode: str = "psum"  # or "butterfly"


def make_outer_iteration(
    mesh: Mesh,
    cfg: FDSVRGShardedConfig,
    feature_axes: Sequence[str] = ("data", "model"),
    backend: ShardMapBackend | None = None,
):
    """Build the jittable one-outer-iteration function.

    Signature of the returned fn:
      (w, indices, values, labels, samples) -> (w_next, full_grad_norm)
    with shardings:
      w:        P(feature_axes)           (feature-distributed, the paper)
      indices:  P(None, None)             (replicated padded-CSR rows)
      values:   P(None, None)
      labels:   P(None)
      samples:  P(None, None)             int32[M, u]
    """
    if backend is None:
        backend = ShardMapBackend(
            mesh=mesh, feature_axes=feature_axes, tree_mode=cfg.tree_mode
        )
    elif backend.mesh is not mesh or backend.feature_axes != tuple(feature_axes):
        raise ValueError(
            "backend was built on a different mesh/feature_axes than the ones "
            "passed to make_outer_iteration"
        )
    q = backend.q
    if cfg.dim % q != 0:
        raise ValueError(f"dim {cfg.dim} must divide by q={q} (pad features)")
    block = cfg.dim // q
    loss = losses_lib.LOSSES[cfg.loss_name]
    reg = losses_lib.Regularizer(cfg.reg_name, cfg.lam)
    axes = backend.feature_axes

    def worker(w_blk, indices, values, labels, samples):
        lo = backend.device_worker_id() * block

        def local_margins(w_b, idx, val):
            in_blk = (idx >= lo) & (idx < lo + block)
            loc = jnp.where(in_blk, idx - lo, 0)
            return jnp.sum(jnp.where(in_blk, w_b[loc], 0.0) * val, axis=-1)

        def local_scatter(idx, val, coeffs):
            in_blk = (idx >= lo) & (idx < lo + block)
            loc = jnp.where(in_blk, idx - lo, 0)
            contrib = jnp.where(in_blk, val, 0.0) * coeffs[..., None]
            return (
                jnp.zeros((block,), dtype=val.dtype)
                .at[loc.reshape(-1)]
                .add(contrib.reshape(-1))
            )

        # ---- full-gradient phase: one N-vector all-reduce ----
        partial_s0 = local_margins(w_blk, indices, values)  # [N]
        s0 = backend.device_all_reduce(partial_s0)
        coeffs0 = loss.dvalue(s0, labels) / labels.shape[0]
        z_blk = local_scatter(indices, values, coeffs0)
        gnorm_sq = jax.lax.psum(
            jnp.sum((z_blk + reg.grad(w_blk)) ** 2), axes
        )

        # ---- inner loop: one u-scalar all-reduce per step ----
        def step(w_b, ids):
            idx = indices[ids]
            val = values[ids]
            y = labels[ids]
            partial = local_margins(w_b, idx, val)
            s_m = backend.device_all_reduce(partial)
            coef = (loss.dvalue(s_m, y) - loss.dvalue(s0[ids], y)) / cfg.batch_size
            g = local_scatter(idx, val, coef) + z_blk + reg.grad(w_b)
            return w_b - cfg.eta * g, None

        w_blk, _ = jax.lax.scan(step, w_blk, samples)
        return w_blk, gnorm_sq

    spec_w = P(axes)
    mapped = backend.shard_map(
        worker,
        in_specs=(spec_w, P(None, None), P(None, None), P(None), P(None, None)),
        out_specs=(spec_w, P()),
    )

    @jax.jit
    def outer_iteration(w, indices, values, labels, samples):
        w_next, gnorm_sq = mapped(w, indices, values, labels, samples)
        return w_next, jnp.sqrt(gnorm_sq)

    return outer_iteration


def run_fdsvrg_sharded(
    data,
    mesh: Mesh,
    cfg: FDSVRGShardedConfig,
    feature_axes: Sequence[str] = ("data", "model"),
    outer_iters: int = 1,
    seed: int = 0,
    cluster: ClusterModel | None = None,
    backend: ShardMapBackend | None = None,
):
    """Metered driver for the deployable path.

    Runs ``outer_iters`` outer iterations of :func:`make_outer_iteration`
    on ``data`` (a PaddedCSR) and meters the closed-form traffic — one
    N-payload tree per outer plus one u-payload tree per inner step —
    through the backend, so the shard_map path reports bytes-on-the-wire
    from the same meter as every other method.  Modeled time stays a
    ``ClusterModel`` quantity (comm terms only — compute is real here);
    measured host wall-clock is reported per outer in the history, never
    mixed into the model.  Returns ``(w, history, backend)`` with history
    entries of ``(outer, grad_norm, comm_scalars, wall_time_s)``.
    """
    backend = backend or ShardMapBackend(
        mesh=mesh, feature_axes=feature_axes,
        tree_mode=cfg.tree_mode, cluster=cluster,
    )
    step = make_outer_iteration(mesh, cfg, feature_axes, backend=backend)
    rng = np.random.default_rng(seed)
    w = jnp.zeros((cfg.dim,), jnp.float32)
    history = []
    for t in range(outer_iters):
        samples = rng.integers(
            0, cfg.num_instances, size=(cfg.inner_steps, cfg.batch_size)
        ).astype(np.int32)
        t0 = time.perf_counter()
        w, gnorm = step(w, data.indices, data.values, data.labels,
                        jnp.asarray(samples))
        gnorm = float(gnorm)
        wall = time.perf_counter() - t0
        backend.meter_tree(payload=cfg.num_instances)
        backend.charge(scalars=2 * backend.q * cfg.num_instances,
                       rounds=backend.tree_rounds)
        backend.meter_tree(payload=cfg.batch_size, steps=cfg.inner_steps)
        backend.charge_seconds(
            cfg.inner_steps
            * backend.cluster.time(
                critical_flops=0.0,
                critical_scalars=2 * backend.q * cfg.batch_size,
                rounds=backend.tree_rounds,
            )
        )
        history.append((t, gnorm, backend.meter.total_scalars, wall))
    return w, history, backend


def input_shardings(mesh: Mesh, feature_axes: Sequence[str] = ("data", "model")):
    axes = tuple(feature_axes)
    return (
        NamedSharding(mesh, P(axes)),
        NamedSharding(mesh, P(None, None)),
        NamedSharding(mesh, P(None, None)),
        NamedSharding(mesh, P(None)),
        NamedSharding(mesh, P(None, None)),
    )
