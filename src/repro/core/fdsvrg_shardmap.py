"""Deployable FD-SVRG: shard_map over the mesh's feature ("model") axes.

This is the TPU-native realization of Algorithm 1, built on
:class:`repro.dist.ShardMapBackend`.  The parameter vector ``w`` lives
feature-sharded across the given mesh axes (every chip is one of the
paper's Workers); the instance data arrives in the block-local sharded
layout (:meth:`repro.data.block_csr.BlockCSR.stacked`): a ``[q, N, B]``
stack of per-block re-indexed padded rows, sharded on the leading axis,
so each worker holds only its own block's entries with LOCAL feature ids
and ``B ≈ nnz_max / q``.  That is the paper's construction verbatim —
worker l stores the feature *slice* of every instance — and it kills the
masked global-row fallback this module used to carry: no membership
compares, no id rebasing, O(nnz_max/q) gather/scatter work per chip.

Communication per inner step is exactly one all-reduce of ``u`` scalars
over the feature axes — the hardware tree standing in for Figure 5.  The
full-gradient phase all-reduces the N-vector of margins once per outer
iteration.  Everything else is chip-local.  The collective is selected by
the backend's ``tree_mode``:

  * ``"psum"``      — hardware all-reduce (default, fastest)
  * ``"butterfly"`` — explicit log-depth ppermute butterfly
    (:func:`repro.dist.tree.collective_permute_tree`) proving the
    paper's explicit topology lowers on TPU; used in §Perf comparisons.

``use_kernels=True`` routes the chip-local margin and scatter+update
through the fused Pallas kernels (:mod:`repro.kernels`), interpret-mode
off-TPU; ``False`` is the jnp numerics oracle — bit-identical in
interpret mode.

On-device traffic cannot be observed from traced code, so
:func:`run_fdsvrg_sharded` meters the closed forms host-side through the
backend — the same accounting, the same meter, and (since it also charges
the same compute terms) the same modeled time as the simulation paths.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import losses as losses_lib
from repro.core.partition import balanced
from repro.data.block_csr import BlockCSR, local_margins, local_scatter
from repro.dist import ClusterModel, ShardMapBackend
from repro.kernels import ops


def _opt_residual_blk(reg, eta, w_blk, z_blk):
    """Block-local optimality residual: the gradient for smooth g, the
    prox gradient mapping otherwise (see repro.core.fdsvrg.optimality_norm
    — this is its per-block body; callers psum the squares)."""
    if reg.is_smooth:
        return z_blk + reg.grad(w_blk)
    v_blk = reg.prox(w_blk - eta * (z_blk + reg.smooth_grad(w_blk)), eta)
    return (w_blk - v_blk) / eta


@dataclasses.dataclass(frozen=True)
class FDSVRGShardedConfig:
    dim: int
    num_instances: int
    nnz_max: int  # nnz budget of the GLOBAL rows (metering uses this)
    eta: float
    inner_steps: int
    batch_size: int = 16
    loss_name: str = "logistic"
    reg_name: str = "l2"  # "l2" | "l1" | "elastic_net" | "none"
    lam: float = 1e-4
    lam2: float = 0.0  # elastic-net L2 strength
    tree_mode: str = "psum"  # or "butterfly"
    use_kernels: bool = False


def make_outer_iteration(
    mesh: Mesh,
    cfg: FDSVRGShardedConfig,
    feature_axes: Sequence[str] = ("data", "model"),
    backend: ShardMapBackend | None = None,
):
    """Build the jittable one-outer-iteration function.

    Signature of the returned fn:
      (w, block_indices, block_values, labels, samples)
        -> (w_next, full_grad_norm)
    with shardings:
      w:             P(feature_axes)        (feature-distributed, the paper)
      block_indices: P(feature_axes, None, None)  int32[q, N, B] local ids
      block_values:  P(feature_axes, None, None)  float[q, N, B]
      labels:        P(None)
      samples:       P(None, None)          int32[M, u]

    Build the data stack once with
    ``BlockCSR.from_padded(data, balanced(dim, q)).stacked()`` (or let
    :func:`run_fdsvrg_sharded` do it).
    """
    if backend is None:
        backend = ShardMapBackend(
            mesh=mesh, feature_axes=feature_axes, tree_mode=cfg.tree_mode
        )
    elif backend.mesh is not mesh or backend.feature_axes != tuple(feature_axes):
        raise ValueError(
            "backend was built on a different mesh/feature_axes than the ones "
            "passed to make_outer_iteration"
        )
    q = backend.q
    if cfg.dim % q != 0:
        raise ValueError(f"dim {cfg.dim} must divide by q={q} (pad features)")
    block = cfg.dim // q
    loss = losses_lib.LOSSES[cfg.loss_name]
    reg = losses_lib.Regularizer(cfg.reg_name, cfg.lam, cfg.lam2)
    axes = backend.feature_axes

    def worker(w_blk, bidx, bval, labels, samples):
        bidx = bidx[0]  # [N, B]: the leading q-axis shards to size 1
        bval = bval[0]

        def margin_of(w_b, idx, val):
            if cfg.use_kernels:
                return ops.sparse_margins(idx, val, w_b)
            return local_margins(idx, val, w_b)

        # ---- full-gradient phase: one N-vector all-reduce ----
        partial_s0 = margin_of(w_blk, bidx, bval)  # [N]
        s0 = backend.device_all_reduce(partial_s0)
        coeffs0 = loss.dvalue(s0, labels) / labels.shape[0]
        z_blk = local_scatter(bidx, bval, coeffs0, block)
        # Optimality residual at the snapshot (z and w at the SAME
        # iterate — the driver reports the post-epoch value via
        # make_optimality_eval instead, matching the other drivers).
        gnorm_sq = jax.lax.psum(
            jnp.sum(_opt_residual_blk(reg, cfg.eta, w_blk, z_blk) ** 2), axes
        )

        # ---- inner loop: one u-scalar all-reduce per step; the prox is
        # elementwise on the local block, so the traffic is identical for
        # every regularizer ----
        def step(w_b, ids):
            idx = bidx[ids]
            val = bval[ids]
            y = labels[ids]
            partial = margin_of(w_b, idx, val)
            s_m = backend.device_all_reduce(partial)
            coef = (loss.dvalue(s_m, y) - loss.dvalue(s0[ids], y)) / cfg.batch_size
            if cfg.use_kernels:
                w_next = ops.fused_block_prox_update(
                    w_b, idx, val, coef, z_blk, cfg.eta,
                    lam=reg.smooth_lam, lam1=reg.prox_l1, lam2=reg.prox_l2,
                )
            else:
                g = local_scatter(idx, val, coef, block) + z_blk + reg.smooth_grad(w_b)
                w_next = reg.prox(w_b - cfg.eta * g, cfg.eta)
            return w_next, None

        w_blk, _ = jax.lax.scan(step, w_blk, samples)
        return w_blk, gnorm_sq

    spec_w = P(axes)
    spec_rows = P(axes, None, None)
    mapped = backend.shard_map(
        worker,
        in_specs=(spec_w, spec_rows, spec_rows, P(None), P(None, None)),
        out_specs=(spec_w, P()),
    )

    @jax.jit
    def outer_iteration(w, block_indices, block_values, labels, samples):
        w_next, gnorm_sq = mapped(w, block_indices, block_values, labels, samples)
        return w_next, jnp.sqrt(gnorm_sq)

    return outer_iteration


def make_optimality_eval(
    mesh: Mesh,
    cfg: FDSVRGShardedConfig,
    feature_axes: Sequence[str] = ("data", "model"),
    backend: ShardMapBackend | None = None,
):
    """Jittable ``(w, block_indices, block_values, labels) -> gnorm``: the
    full-gradient phase (one N-vector all-reduce, block-local scatter)
    without an inner epoch, reduced to the optimality-residual norm at
    ``w``.  The driver uses it to report ``grad_norm`` at the
    **post-epoch** iterate — z and w from the same point, like every
    other driver — for the final history record (earlier records reuse
    the next outer's snapshot residual), i.e. one extra full-gradient
    phase per run (a diagnostic; not metered as algorithm traffic)."""
    if backend is None:
        backend = ShardMapBackend(
            mesh=mesh, feature_axes=feature_axes, tree_mode=cfg.tree_mode
        )
    q = backend.q
    if cfg.dim % q != 0:
        raise ValueError(f"dim {cfg.dim} must divide by q={q} (pad features)")
    block = cfg.dim // q
    loss = losses_lib.LOSSES[cfg.loss_name]
    reg = losses_lib.Regularizer(cfg.reg_name, cfg.lam, cfg.lam2)
    axes = backend.feature_axes

    def worker(w_blk, bidx, bval, labels):
        bidx = bidx[0]
        bval = bval[0]
        if cfg.use_kernels:
            partial = ops.sparse_margins(bidx, bval, w_blk)
        else:
            partial = local_margins(bidx, bval, w_blk)
        s = backend.device_all_reduce(partial)
        coeffs = loss.dvalue(s, labels) / labels.shape[0]
        z_blk = local_scatter(bidx, bval, coeffs, block)
        return jax.lax.psum(
            jnp.sum(_opt_residual_blk(reg, cfg.eta, w_blk, z_blk) ** 2), axes
        )

    spec_rows = P(axes, None, None)
    mapped = backend.shard_map(
        worker,
        in_specs=(P(axes), spec_rows, spec_rows, P(None)),
        out_specs=P(),
    )

    @jax.jit
    def gnorm_at(w, block_indices, block_values, labels):
        return jnp.sqrt(mapped(w, block_indices, block_values, labels))

    return gnorm_at


def run_fdsvrg_sharded(
    data,
    mesh: Mesh,
    cfg: FDSVRGShardedConfig,
    feature_axes: Sequence[str] = ("data", "model"),
    outer_iters: int = 1,
    seed: int = 0,
    cluster: ClusterModel | None = None,
    backend: ShardMapBackend | None = None,
):
    """Metered driver for the deployable path.

    Re-indexes ``data`` (a PaddedCSR) into the block-local stacked layout
    for the mesh's q workers, runs ``outer_iters`` outer iterations of
    :func:`make_outer_iteration`, and meters the closed-form traffic —
    one N-payload tree per outer plus one u-payload tree per inner step —
    through the backend, so the shard_map path reports bytes-on-the-wire
    from the same meter as every other method.  Modeled time charges the
    same §4.5 closed forms as :func:`repro.core.fdsvrg.run_fdsvrg` —
    compute AND communication terms — so the two drivers' modeled-time
    accounting is directly comparable (asserted in tests); measured host
    wall-clock is reported per outer in the history, never mixed into the
    model.  Returns ``(w, history, backend)`` with history entries of
    ``(outer, grad_norm, comm_scalars, wall_time_s)``; ``grad_norm`` is
    the optimality residual at the **post-epoch** iterate (via
    :func:`make_optimality_eval`), matching every other driver.
    """
    backend = backend or ShardMapBackend(
        mesh=mesh, feature_axes=feature_axes,
        tree_mode=cfg.tree_mode, cluster=cluster,
    )
    step = make_outer_iteration(mesh, cfg, feature_axes, backend=backend)
    gnorm_at = make_optimality_eval(mesh, cfg, feature_axes, backend=backend)
    q = backend.q
    block_data = BlockCSR.from_padded(data, balanced(cfg.dim, q))
    bidx, bval = block_data.stacked()
    rng = np.random.default_rng(seed)
    w = jnp.zeros((cfg.dim,), jnp.float32)
    n, nnz, u = cfg.num_instances, cfg.nnz_max, cfg.batch_size
    history = []
    # Each record reports the residual at its POST-epoch iterate
    # (consistent z/w pair, same convention as run_fdsvrg and the
    # baselines).  The step fn already computes the snapshot residual in
    # its full-gradient phase, and outer t+1's snapshot IS outer t's
    # post-epoch iterate — so rotate it into the previous record and pay
    # the standalone eval only once, for the final record.
    pending = None  # (outer, scalars_after_outer, wall_s) awaiting its gnorm
    for t in range(outer_iters):
        samples = rng.integers(
            0, cfg.num_instances, size=(cfg.inner_steps, u)
        ).astype(np.int32)
        t0 = time.perf_counter()
        w, gnorm_snapshot = step(w, bidx, bval, data.labels, jnp.asarray(samples))
        wall = time.perf_counter() - t0
        if pending is not None:
            history.append((pending[0], float(gnorm_snapshot),
                            pending[1], pending[2]))
        # Same closed forms as run_fdsvrg: full-gradient phase ...
        backend.meter_tree(payload=n)
        backend.charge(
            flops=2.0 * n * nnz / q * 2,  # margins + scatter, per worker
            scalars=2 * q * n,
            rounds=backend.tree_rounds,
        )
        # ... and the M inner steps (dense O(d/q) + sparse O(u*nnz) work).
        backend.meter_tree(payload=u, steps=cfg.inner_steps)
        backend.charge_seconds(
            cfg.inner_steps
            * backend.cluster.time(
                critical_flops=2.0 * (cfg.dim / q + u * nnz),
                critical_scalars=2 * q * u,
                rounds=backend.tree_rounds,
            )
        )
        pending = (t, backend.meter.total_scalars, wall)
    if pending is not None:
        history.append((pending[0], float(gnorm_at(w, bidx, bval, data.labels)),
                        pending[1], pending[2]))
    return w, history, backend


def input_shardings(mesh: Mesh, feature_axes: Sequence[str] = ("data", "model")):
    axes = tuple(feature_axes)
    return (
        NamedSharding(mesh, P(axes)),
        NamedSharding(mesh, P(axes, None, None)),
        NamedSharding(mesh, P(axes, None, None)),
        NamedSharding(mesh, P(None)),
        NamedSharding(mesh, P(None, None)),
    )
