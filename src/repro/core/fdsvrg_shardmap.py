"""Deployable FD-SVRG: shard_map over the mesh's feature ("model") axes.

This is the TPU-native realization of Algorithm 1, built on
:class:`repro.dist.ShardMapBackend`.  The parameter vector ``w`` lives
feature-sharded across the given mesh axes (every chip is one of the
paper's Workers); the instance data arrives in the block-local sharded
layout (:meth:`repro.data.block_csr.BlockCSR.stacked`): a ``[q, N, B]``
stack of per-block re-indexed padded rows, sharded on the leading axis,
so each worker holds only its own block's entries with LOCAL feature ids
and ``B ≈ nnz_max / q``.  That is the paper's construction verbatim —
worker l stores the feature *slice* of every instance.

Communication per inner step is exactly one all-reduce of ``u`` scalars
over the feature axes — the hardware tree standing in for Figure 5.  The
full-gradient phase all-reduces the N-vector of margins once per outer
iteration.  Everything else is chip-local.  The collective is selected by
the backend's ``tree_mode``:

  * ``"psum"``      — hardware all-reduce (default, fastest)
  * ``"butterfly"`` — explicit log-depth ppermute butterfly
    (:func:`repro.dist.tree.collective_permute_tree`) proving the
    paper's explicit topology lowers on TPU; used in §Perf comparisons.

``use_kernels=True`` routes the chip-local margin and scatter+update
through the fused Pallas kernels (:mod:`repro.kernels`), interpret-mode
off-TPU; ``False`` is the jnp numerics oracle — bit-identical in
interpret mode.

Two granularities of compiled step:

* :func:`make_fullgrad` + :func:`make_inner_epoch` — the snapshot and
  epoch halves :func:`run_fdsvrg_sharded` plugs into the shared
  outer-loop harness (:func:`repro.core.driver.run_outer_loop`), so the
  deployable path reports the same :class:`~repro.core.driver.RunResult`
  schema — objective, same-iterate optimality residual, metered scalars,
  modeled time — as every other driver, in the data's dtype.
* :func:`make_outer_iteration` — both phases fused into one jittable
  call (the AOT/perf shape; ``launch/dryrun`` and ``launch/perf``
  compile this one).

On-device traffic cannot be observed from traced code, so
:func:`run_fdsvrg_sharded` meters host-side through the backend with the
shared §4.5 closed forms (:data:`repro.dist.COSTS`) — the same
accounting, the same meter, and therefore the same modeled time as the
simulation driver (asserted in tests).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import losses as losses_lib
from repro.core.driver import (
    draw_samples,
    make_same_iterate_eval,
    resolve_init_w,
    run_outer_loop,
)
from repro.core.partition import balanced
from repro.data.block_csr import BlockCSR, local_margins, local_scatter
from repro.dist import COSTS, ClusterModel, ShardMapBackend
from repro.kernels import ops


def _opt_residual_blk(reg, eta, w_blk, z_blk):
    """Block-local optimality residual: the gradient for smooth g, the
    prox gradient mapping otherwise (the per-block body of
    repro.core.driver.optimality_norm; callers psum the squares).  Only
    the fused AOT step reports it — the harness driver evaluates
    host-side like everyone else."""
    if reg.is_smooth:
        return z_blk + reg.grad(w_blk)
    v_blk = reg.prox(w_blk - eta * (z_blk + reg.smooth_grad(w_blk)), eta)
    return (w_blk - v_blk) / eta


@dataclasses.dataclass(frozen=True)
class FDSVRGShardedConfig:
    dim: int
    num_instances: int
    nnz_max: int  # nnz budget of the GLOBAL rows (metering uses this)
    eta: float
    inner_steps: int
    batch_size: int = 16
    loss_name: str = "logistic"
    reg_name: str = "l2"  # "l2" | "l1" | "elastic_net" | "none"
    lam: float = 1e-4
    lam2: float = 0.0  # elastic-net L2 strength
    tree_mode: str = "psum"  # or "butterfly"
    use_kernels: bool = False


def _resolve_backend(
    mesh: Mesh,
    cfg: FDSVRGShardedConfig,
    feature_axes: Sequence[str],
    backend: ShardMapBackend | None,
) -> tuple[ShardMapBackend, int]:
    """Shared builder plumbing: backend/mesh consistency + block size."""
    if backend is None:
        backend = ShardMapBackend(
            mesh=mesh, feature_axes=feature_axes, tree_mode=cfg.tree_mode
        )
    elif backend.mesh is not mesh or backend.feature_axes != tuple(feature_axes):
        raise ValueError(
            "backend was built on a different mesh/feature_axes than the ones "
            "passed to the step builder"
        )
    q = backend.q
    if cfg.dim % q != 0:
        raise ValueError(f"dim {cfg.dim} must divide by q={q} (pad features)")
    return backend, cfg.dim // q


def _margin_of(cfg: FDSVRGShardedConfig, w_b, idx, val):
    if cfg.use_kernels:
        return ops.sparse_margins(idx, val, w_b)
    return local_margins(idx, val, w_b)


def _fullgrad_blk(cfg, backend, loss, block, w_blk, bidx, bval, labels):
    """Full-gradient phase on one worker (Alg 1 lines 3-5): one N-vector
    all-reduce, then a purely block-local scatter."""
    partial = _margin_of(cfg, w_blk, bidx, bval)
    s0 = backend.device_all_reduce(partial)
    coeffs = loss.dvalue(s0, labels) / labels.shape[0]
    z_blk = local_scatter(bidx, bval, coeffs, block)
    return z_blk, s0


def _inner_scan_blk(cfg, backend, loss, reg, block,
                    w_blk, z_blk, s0, bidx, bval, labels, samples):
    """M inner steps on one worker: one u-scalar all-reduce per step; the
    prox is elementwise on the local block, so the traffic is identical
    for every regularizer."""

    def step(w_b, ids):
        idx = bidx[ids]
        val = bval[ids]
        y = labels[ids]
        partial = _margin_of(cfg, w_b, idx, val)
        s_m = backend.device_all_reduce(partial)
        coef = (loss.dvalue(s_m, y) - loss.dvalue(s0[ids], y)) / cfg.batch_size
        if cfg.use_kernels:
            w_next = ops.fused_block_prox_update(
                w_b, idx, val, coef, z_blk, cfg.eta,
                lam=reg.smooth_lam, lam1=reg.prox_l1, lam2=reg.prox_l2,
            )
        else:
            g = local_scatter(idx, val, coef, block) + z_blk + reg.smooth_grad(w_b)
            w_next = reg.prox(w_b - cfg.eta * g, cfg.eta)
        return w_next, None

    w_blk, _ = jax.lax.scan(step, w_blk, samples)
    return w_blk


def make_fullgrad(
    mesh: Mesh,
    cfg: FDSVRGShardedConfig,
    feature_axes: Sequence[str] = ("data", "model"),
    backend: ShardMapBackend | None = None,
):
    """Build the jittable snapshot half: ``(w, block_indices,
    block_values, labels) -> (z, s0)`` with ``z`` feature-sharded like
    ``w`` and ``s0`` (the margins at ``w``) replicated.  This is the
    harness ``snapshot`` hook — its output rotates into the next epoch
    AND carries the same-iterate reporting pair."""
    backend, block = _resolve_backend(mesh, cfg, feature_axes, backend)
    loss = losses_lib.LOSSES[cfg.loss_name]
    axes = backend.feature_axes

    def worker(w_blk, bidx, bval, labels):
        z_blk, s0 = _fullgrad_blk(
            cfg, backend, loss, block, w_blk, bidx[0], bval[0], labels
        )
        return z_blk, s0

    spec_rows = P(axes, None, None)
    mapped = backend.shard_map(
        worker,
        in_specs=(P(axes), spec_rows, spec_rows, P(None)),
        out_specs=(P(axes), P(None)),
    )
    return jax.jit(mapped)


def make_inner_epoch(
    mesh: Mesh,
    cfg: FDSVRGShardedConfig,
    feature_axes: Sequence[str] = ("data", "model"),
    backend: ShardMapBackend | None = None,
):
    """Build the jittable epoch half: ``(w, z, s0, block_indices,
    block_values, labels, samples) -> w_next`` — the M-step inner scan
    consuming a snapshot produced by :func:`make_fullgrad`."""
    backend, block = _resolve_backend(mesh, cfg, feature_axes, backend)
    loss = losses_lib.LOSSES[cfg.loss_name]
    reg = losses_lib.Regularizer(cfg.reg_name, cfg.lam, cfg.lam2)
    axes = backend.feature_axes

    def worker(w_blk, z_blk, s0, bidx, bval, labels, samples):
        return _inner_scan_blk(
            cfg, backend, loss, reg, block,
            w_blk, z_blk, s0, bidx[0], bval[0], labels, samples,
        )

    spec_rows = P(axes, None, None)
    mapped = backend.shard_map(
        worker,
        in_specs=(P(axes), P(axes), P(None), spec_rows, spec_rows,
                  P(None), P(None, None)),
        out_specs=P(axes),
    )
    return jax.jit(mapped)


def make_outer_iteration(
    mesh: Mesh,
    cfg: FDSVRGShardedConfig,
    feature_axes: Sequence[str] = ("data", "model"),
    backend: ShardMapBackend | None = None,
):
    """Build the fused one-outer-iteration function (the AOT/perf shape).

    Signature of the returned fn:
      (w, block_indices, block_values, labels, samples)
        -> (w_next, full_grad_norm)
    with shardings:
      w:             P(feature_axes)        (feature-distributed, the paper)
      block_indices: P(feature_axes, None, None)  int32[q, N, B] local ids
      block_values:  P(feature_axes, None, None)  float[q, N, B]
      labels:        P(None)
      samples:       P(None, None)          int32[M, u]

    ``full_grad_norm`` is the optimality residual at the *snapshot*
    iterate (the full-gradient phase computes it for free); the harness
    driver (:func:`run_fdsvrg_sharded`) reports post-epoch residuals
    instead, via the split :func:`make_fullgrad` / :func:`make_inner_epoch`
    pair.  Build the data stack once with
    ``BlockCSR.from_padded(data, balanced(dim, q)).stacked()``.
    """
    backend, block = _resolve_backend(mesh, cfg, feature_axes, backend)
    loss = losses_lib.LOSSES[cfg.loss_name]
    reg = losses_lib.Regularizer(cfg.reg_name, cfg.lam, cfg.lam2)
    axes = backend.feature_axes

    def worker(w_blk, bidx, bval, labels, samples):
        bidx = bidx[0]  # [N, B]: the leading q-axis shards to size 1
        bval = bval[0]
        z_blk, s0 = _fullgrad_blk(
            cfg, backend, loss, block, w_blk, bidx, bval, labels
        )
        gnorm_sq = jax.lax.psum(
            jnp.sum(_opt_residual_blk(reg, cfg.eta, w_blk, z_blk) ** 2), axes
        )
        w_blk = _inner_scan_blk(
            cfg, backend, loss, reg, block,
            w_blk, z_blk, s0, bidx, bval, labels, samples,
        )
        return w_blk, gnorm_sq

    spec_w = P(axes)
    spec_rows = P(axes, None, None)
    mapped = backend.shard_map(
        worker,
        in_specs=(spec_w, spec_rows, spec_rows, P(None), P(None, None)),
        out_specs=(spec_w, P()),
    )

    @jax.jit
    def outer_iteration(w, block_indices, block_values, labels, samples):
        w_next, gnorm_sq = mapped(w, block_indices, block_values, labels, samples)
        return w_next, jnp.sqrt(gnorm_sq)

    return outer_iteration


def run_fdsvrg_sharded(
    data,
    mesh: Mesh,
    cfg: FDSVRGShardedConfig,
    feature_axes: Sequence[str] = ("data", "model"),
    outer_iters: int = 1,
    seed: int = 0,
    cluster: ClusterModel | None = None,
    backend: ShardMapBackend | None = None,
    init_w: jax.Array | None = None,
):
    """Metered driver for the deployable path, on the shared harness.

    Re-indexes ``data`` (a PaddedCSR) into the block-local stacked layout
    for the mesh's q workers and runs ``outer_iters`` iterations of the
    split :func:`make_fullgrad` / :func:`make_inner_epoch` pair through
    :func:`repro.core.driver.run_outer_loop` — so snapshot rotation,
    sample drawing (same rng stream as :func:`repro.core.fdsvrg.run_fdsvrg`
    at the same seed), and same-iterate objective/optimality reporting
    are the engine's, not a local copy.  Traffic and modeled time are
    charged from the shared closed forms (:data:`repro.dist.COSTS`), so
    the meter is bit-consistent with the simulation driver's for the same
    shapes (asserted in tests).

    Returns a :class:`~repro.core.driver.RunResult` — same schema as
    every other driver, iterates in the data's dtype.
    """
    backend = backend or ShardMapBackend(
        mesh=mesh, feature_axes=feature_axes,
        tree_mode=cfg.tree_mode, cluster=cluster,
    )
    fullgrad = make_fullgrad(mesh, cfg, feature_axes, backend=backend)
    inner_epoch = make_inner_epoch(mesh, cfg, feature_axes, backend=backend)
    q = backend.q
    block_data = BlockCSR.from_padded(data, balanced(cfg.dim, q))
    bidx, bval = block_data.stacked()
    loss = losses_lib.LOSSES[cfg.loss_name]
    reg = losses_lib.Regularizer(cfg.reg_name, cfg.lam, cfg.lam2)
    n, nnz, u = cfg.num_instances, cfg.nnz_max, cfg.batch_size

    def snapshot(w):
        return fullgrad(w, bidx, bval, data.labels)

    def epoch(t, rng, w, z_data, s0):
        backend.meter_tree(payload=n)
        backend.charge_cost(COSTS.fd_fullgrad(n=n, nnz=nnz, q=q))
        samples = draw_samples(rng, n, cfg.inner_steps, u)
        w = inner_epoch(w, z_data, s0, bidx, bval, data.labels,
                        jnp.asarray(samples))
        backend.meter_tree(payload=u, steps=cfg.inner_steps)
        backend.charge_cost(
            COSTS.fd_inner_step(nnz=nnz, q=q, u=u), steps=cfg.inner_steps
        )
        return w

    return run_outer_loop(
        outer_iters=outer_iters,
        seed=seed,
        init_w=resolve_init_w(init_w, cfg.dim, data.values.dtype),
        snapshot=snapshot,
        epoch=epoch,
        evaluate=make_same_iterate_eval(data.labels, loss, reg, cfg.eta),
        backend=backend,
    )


def input_shardings(mesh: Mesh, feature_axes: Sequence[str] = ("data", "model")):
    axes = tuple(feature_axes)
    return (
        NamedSharding(mesh, P(axes)),
        NamedSharding(mesh, P(axes, None, None)),
        NamedSharding(mesh, P(axes, None, None)),
        NamedSharding(mesh, P(None)),
        NamedSharding(mesh, P(None, None)),
    )
