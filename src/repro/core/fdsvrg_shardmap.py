"""Deployable FD-SVRG: shard_map over the mesh's feature ("model") axes.

This is the TPU-native realization of Algorithm 1.  The parameter vector
``w`` lives feature-sharded across the given mesh axes (every chip is one
of the paper's Workers); the padded-CSR instance data is replicated (the
paper replicates instances across feature shards by construction — each
worker stores the feature *slice* of every instance; on TPU we keep the
global index/value rows and mask to the local block, which is the
shape-static equivalent).

Communication per inner step is exactly one psum of ``u`` scalars over the
feature axes — the hardware tree all-reduce standing in for Figure 5.
The full-gradient phase psums the N-vector of margins once per outer
iteration.  Everything else is chip-local.

``tree_mode``:
  * ``"psum"``      — hardware all-reduce (default, fastest)
  * ``"butterfly"`` — explicit log-depth ppermute butterfly
    (:func:`repro.core.tree_reduce.collective_permute_tree`) proving the
    paper's explicit topology lowers on TPU; used in §Perf comparisons.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax import shard_map

from repro.core import losses as losses_lib
from repro.core.tree_reduce import collective_permute_tree


@dataclasses.dataclass(frozen=True)
class FDSVRGShardedConfig:
    dim: int
    num_instances: int
    nnz_max: int
    eta: float
    inner_steps: int
    batch_size: int = 16
    loss_name: str = "logistic"
    reg_name: str = "l2"
    lam: float = 1e-4
    tree_mode: str = "psum"  # or "butterfly"


def _axis_size(mesh: Mesh, axes: Sequence[str]) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def _all_reduce(x: jax.Array, axes: Sequence[str], mode: str, mesh: Mesh) -> jax.Array:
    if mode == "psum":
        return jax.lax.psum(x, tuple(axes))
    if mode == "butterfly":
        out = x
        for a in axes:
            out = collective_permute_tree(out, a, mesh.shape[a])
        return out
    raise ValueError(mode)


def make_outer_iteration(
    mesh: Mesh,
    cfg: FDSVRGShardedConfig,
    feature_axes: Sequence[str] = ("data", "model"),
):
    """Build the jittable one-outer-iteration function.

    Signature of the returned fn:
      (w, indices, values, labels, samples) -> (w_next, full_grad_norm)
    with shardings:
      w:        P(feature_axes)           (feature-distributed, the paper)
      indices:  P(None, None)             (replicated padded-CSR rows)
      values:   P(None, None)
      labels:   P(None)
      samples:  P(None, None)             int32[M, u]
    """
    q = _axis_size(mesh, feature_axes)
    if cfg.dim % q != 0:
        raise ValueError(f"dim {cfg.dim} must divide by q={q} (pad features)")
    block = cfg.dim // q
    loss = losses_lib.LOSSES[cfg.loss_name]
    reg = losses_lib.Regularizer(cfg.reg_name, cfg.lam)
    axes = tuple(feature_axes)

    def worker(w_blk, indices, values, labels, samples):
        # Flatten the feature axes into a single linear worker id.
        wid = jnp.zeros((), dtype=jnp.int32)
        for a in axes:
            wid = wid * mesh.shape[a] + jax.lax.axis_index(a)
        lo = wid * block

        def local_margins(w_b, idx, val):
            in_blk = (idx >= lo) & (idx < lo + block)
            loc = jnp.where(in_blk, idx - lo, 0)
            return jnp.sum(jnp.where(in_blk, w_b[loc], 0.0) * val, axis=-1)

        def local_scatter(idx, val, coeffs):
            in_blk = (idx >= lo) & (idx < lo + block)
            loc = jnp.where(in_blk, idx - lo, 0)
            contrib = jnp.where(in_blk, val, 0.0) * coeffs[..., None]
            return (
                jnp.zeros((block,), dtype=val.dtype)
                .at[loc.reshape(-1)]
                .add(contrib.reshape(-1))
            )

        # ---- full-gradient phase: one N-vector all-reduce ----
        partial_s0 = local_margins(w_blk, indices, values)  # [N]
        s0 = _all_reduce(partial_s0, axes, cfg.tree_mode, mesh)
        coeffs0 = loss.dvalue(s0, labels) / labels.shape[0]
        z_blk = local_scatter(indices, values, coeffs0)
        gnorm_sq = _all_reduce(
            jnp.sum((z_blk + reg.grad(w_blk)) ** 2), axes, "psum", mesh
        )

        # ---- inner loop: one u-scalar all-reduce per step ----
        def step(w_b, ids):
            idx = indices[ids]
            val = values[ids]
            y = labels[ids]
            partial = local_margins(w_b, idx, val)
            s_m = _all_reduce(partial, axes, cfg.tree_mode, mesh)
            coef = (loss.dvalue(s_m, y) - loss.dvalue(s0[ids], y)) / cfg.batch_size
            g = local_scatter(idx, val, coef) + z_blk + reg.grad(w_b)
            return w_b - cfg.eta * g, None

        w_blk, _ = jax.lax.scan(step, w_blk, samples)
        return w_blk, gnorm_sq

    spec_w = P(axes)
    mapped = shard_map(
        worker,
        mesh=mesh,
        in_specs=(spec_w, P(None, None), P(None, None), P(None), P(None, None)),
        out_specs=(spec_w, P()),
        check_vma=False,
    )

    @jax.jit
    def outer_iteration(w, indices, values, labels, samples):
        w_next, gnorm_sq = mapped(w, indices, values, labels, samples)
        return w_next, jnp.sqrt(gnorm_sq)

    return outer_iteration


def input_shardings(mesh: Mesh, feature_axes: Sequence[str] = ("data", "model")):
    axes = tuple(feature_axes)
    return (
        NamedSharding(mesh, P(axes)),
        NamedSharding(mesh, P(None, None)),
        NamedSharding(mesh, P(None, None)),
        NamedSharding(mesh, P(None)),
        NamedSharding(mesh, P(None, None)),
    )
