"""Back-compat shim — the communication layer moved to :mod:`repro.dist`.

``CommMeter`` / ``ClusterModel`` / ``TpuV5eModel`` now live in
:mod:`repro.dist.meter` as part of the unified distributed substrate
(see ``docs/architecture.md``).  Import from ``repro.dist`` in new code.
"""

from repro.dist.meter import (  # noqa: F401
    ClusterModel,
    CommEvent,
    CommMeter,
    TpuV5eModel,
    tree_rounds,
)

__all__ = ["ClusterModel", "CommEvent", "CommMeter", "TpuV5eModel", "tree_rounds"]
