"""The paper's contribution: FD-SVRG and its comparison baselines."""

from repro.core import losses
from repro.core.comm import ClusterModel, CommMeter, TpuV5eModel
from repro.core.driver import (
    CheckpointPolicy,
    DivergenceError,
    OuterRecord,
    RecoveryPolicy,
    RunResult,
    make_same_iterate_eval,
    objective_from_margins,
    optimality_norm,
    run_outer_loop,
)
from repro.core.fdsvrg import (
    SVRGConfig,
    full_gradient,
    objective,
    run_fdsvrg,
    run_serial_svrg,
    fdsvrg_worker_simulation,
)
from repro.core.partition import FeaturePartition, balanced, by_nnz

__all__ = [
    "losses",
    "ClusterModel",
    "CommMeter",
    "TpuV5eModel",
    "CheckpointPolicy",
    "DivergenceError",
    "OuterRecord",
    "RecoveryPolicy",
    "RunResult",
    "SVRGConfig",
    "full_gradient",
    "make_same_iterate_eval",
    "objective",
    "objective_from_margins",
    "optimality_norm",
    "run_fdsvrg",
    "run_outer_loop",
    "run_serial_svrg",
    "fdsvrg_worker_simulation",
    "FeaturePartition",
    "balanced",
    "by_nnz",
]
