"""The paper's contribution: FD-SVRG and its comparison baselines."""

from repro.core import losses
from repro.core.comm import ClusterModel, CommMeter, TpuV5eModel
from repro.core.fdsvrg import (
    RunResult,
    SVRGConfig,
    full_gradient,
    objective,
    optimality_norm,
    run_fdsvrg,
    run_serial_svrg,
    fdsvrg_worker_simulation,
)
from repro.core.partition import FeaturePartition, balanced, by_nnz

__all__ = [
    "losses",
    "ClusterModel",
    "CommMeter",
    "TpuV5eModel",
    "RunResult",
    "SVRGConfig",
    "full_gradient",
    "objective",
    "optimality_norm",
    "run_fdsvrg",
    "run_serial_svrg",
    "fdsvrg_worker_simulation",
    "FeaturePartition",
    "balanced",
    "by_nnz",
]
