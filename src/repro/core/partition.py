"""Feature partitioning (paper §4.1: D split horizontally into q blocks).

A partition is a list of contiguous [lo, hi) feature ranges covering
[0, d) exactly once.  Contiguity matters on TPU: each worker's block is a
dense slice of w, so the shard_map/pjit mapping is a plain
``PartitionSpec("model")`` on the feature axis.

Two strategies:
  * ``balanced`` — equal feature counts (paper default: d_l = d/q).
  * ``by_nnz``   — equalize the number of nonzeros per block, which
    balances *compute* when feature popularity is skewed (text data).
    This is our TPU-era refinement; the synthetic generator scatters
    popular ids uniformly so both are close, but real text data is not.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class FeaturePartition:
    dim: int
    bounds: tuple[int, ...]  # length q+1, bounds[0]=0, bounds[-1]=dim

    @property
    def num_blocks(self) -> int:
        return len(self.bounds) - 1

    def block(self, l: int) -> tuple[int, int]:
        return self.bounds[l], self.bounds[l + 1]

    def block_sizes(self) -> list[int]:
        return [self.bounds[i + 1] - self.bounds[i] for i in range(self.num_blocks)]

    def owner_of(self, feature: int) -> int:
        return int(np.searchsorted(np.asarray(self.bounds), feature, side="right") - 1)


def balanced(dim: int, q: int) -> FeaturePartition:
    if not 1 <= q <= dim:
        raise ValueError(f"need 1 <= q <= dim, got q={q}, dim={dim}")
    base, rem = divmod(dim, q)
    bounds = [0]
    for l in range(q):
        bounds.append(bounds[-1] + base + (1 if l < rem else 0))
    return FeaturePartition(dim=dim, bounds=tuple(bounds))


def by_nnz(dim: int, q: int, feature_counts: np.ndarray) -> FeaturePartition:
    """Contiguous partition equalizing per-block nnz mass.

    feature_counts[j] = number of instances touching feature j (or any
    nonnegative weight).  Greedy prefix-sum cut at multiples of total/q.
    """
    if feature_counts.shape != (dim,):
        raise ValueError("feature_counts must have shape (dim,)")
    if q == 1:
        return FeaturePartition(dim=dim, bounds=(0, dim))
    # +1 smoothing so empty features still take space and bounds stay strictly
    # increasing even for pathological count vectors.
    weights = feature_counts.astype(np.float64) + 1.0
    csum = np.cumsum(weights)
    total = csum[-1]
    targets = total * np.arange(1, q) / q
    cuts = np.searchsorted(csum, targets, side="left") + 1
    # Enforce strict monotonicity and range validity.
    bounds = [0]
    for c in cuts:
        c = int(min(max(c, bounds[-1] + 1), dim - (q - len(bounds))))
        bounds.append(c)
    bounds.append(dim)
    return FeaturePartition(dim=dim, bounds=tuple(bounds))


def feature_counts(indices: np.ndarray, values: np.ndarray, dim: int) -> np.ndarray:
    """Per-feature nnz counts from padded-CSR arrays."""
    counts = np.zeros(dim, dtype=np.int64)
    mask = values != 0.0
    np.add.at(counts, indices[mask], 1)
    return counts
