"""``CommReport`` — the communication report type benchmarks consume.

A ``CommReport`` is the frozen, serializable summary of one method's run
through a :class:`repro.dist.Collectives` backend: scalars and bytes on
the wire, latency rounds, the per-kind breakdown, and modeled wall-clock.
Because every method meters through the same backend machinery, reports
are apples-to-apples across FD-SVRG and the instance-distributed
baselines — the property the paper's Figure 7 / Tables 2–3 comparisons
rest on.

``benchmarks/run.py`` serializes these into ``BENCH_*.json`` (schema
documented in ``docs/benchmarks.md``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

from repro.dist.meter import ClusterModel, CommMeter


@dataclasses.dataclass(frozen=True)
class CommReport:
    """One method's bytes-on-the-wire and modeled time, from one meter."""

    method: str
    q: int  # worker count
    scalars: int  # total scalars communicated
    rounds: int  # total latency-bearing message rounds
    bytes_on_wire: int  # scalars * bytes_per_scalar
    by_kind: dict[str, int]  # scalars per message kind
    modeled_time_s: float  # accumulated ClusterModel wall-clock

    @classmethod
    def from_meter(
        cls,
        *,
        method: str,
        q: int,
        meter: CommMeter,
        cluster: ClusterModel | None = None,
        modeled_time_s: float = 0.0,
    ) -> "CommReport":
        cluster = cluster or ClusterModel()
        return cls(
            method=method,
            q=q,
            scalars=meter.total_scalars,
            rounds=meter.total_rounds,
            bytes_on_wire=meter.total_scalars * cluster.bytes_per_scalar,
            by_kind=dict(meter.by_kind),
            modeled_time_s=modeled_time_s,
        )

    @classmethod
    def from_result(
        cls,
        method: str,
        q: int,
        result: Any,
        cluster: ClusterModel | None = None,
    ) -> "CommReport":
        """Summarize a ``RunResult``-shaped object (``.meter`` plus a
        ``.history`` whose last record carries ``modeled_time_s``)."""
        modeled = result.history[-1].modeled_time_s if result.history else 0.0
        return cls.from_meter(
            method=method, q=q, meter=result.meter,
            cluster=cluster, modeled_time_s=modeled,
        )

    def to_dict(self) -> dict[str, Any]:
        return {
            "method": self.method,
            "workers": self.q,
            "comm_scalars": self.scalars,
            "comm_rounds": self.rounds,
            "bytes_on_wire": self.bytes_on_wire,
            "by_kind": dict(sorted(self.by_kind.items())),
            "modeled_time_s": self.modeled_time_s,
        }


def reports_to_json(reports: Mapping[str, CommReport]) -> dict[str, Any]:
    """Keyed collection of reports in the BENCH_*.json layout."""
    return {name: r.to_dict() for name, r in sorted(reports.items())}
