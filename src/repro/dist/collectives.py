"""The ``Collectives`` protocol and its single-process backends.

A backend is the one object an optimization method talks to for anything
that crosses (or models crossing) a worker boundary.  It bundles:

* ``q``        — the worker count the method is (simulated as) running on,
* ``meter``    — a :class:`~repro.dist.meter.CommMeter` every message is
                 recorded against,
* ``cluster``  — the :class:`~repro.dist.meter.ClusterModel` used to
                 accumulate modeled wall-clock,

and exposes two kinds of primitives:

* **executing** collectives (``all_reduce``) that combine per-worker
  partials *and* meter the traffic, and
* **metering-only** primitives (``meter_tree``, ``p2p``, ``charge``) for
  jitted paths where the arithmetic is fused but the accounting must
  still happen — with the same closed forms, through the same meter.

Backends:

* :class:`LocalBackend`    — single-process reference.  Collectives are
  computed directly (in canonical tree order, so results are
  bit-comparable with the other backends) and metered with the §4.5
  closed forms.  The default for tests.
* :class:`SimBackend`      — the executable spec: ``all_reduce`` runs the
  explicit Figure-5 message schedule via
  :func:`~repro.dist.tree.simulate_tree_sum`.
* :class:`repro.dist.shardmap.ShardMapBackend` — the deployable path
  (real ``psum``/butterfly over a mesh axis), in its own module so this
  one stays importable without touching device state.
"""

from __future__ import annotations

from typing import Protocol, Sequence, runtime_checkable

import jax.numpy as jnp

from repro.dist.costs import PhaseCost
from repro.dist.meter import ClusterModel, CommMeter, tree_rounds
from repro.dist.metering import CommReport
from repro.dist.tree import simulate_tree_sum, tree_order_sum


@runtime_checkable
class Collectives(Protocol):
    """What an optimization method needs from the distributed substrate."""

    q: int
    meter: CommMeter
    cluster: ClusterModel

    def all_reduce(self, parts: Sequence, payload: int | None = None):
        """Combine per-worker partials into the replicated global sum,
        metering one tree reduce+broadcast of ``payload`` scalars."""
        ...

    def meter_tree(self, payload: int, steps: int = 1) -> None:
        """Meter ``steps`` tree reduce+broadcasts of ``payload`` scalars
        without executing them (for fused/jitted compute paths)."""
        ...

    def p2p(self, payload: int, kind: str, rounds: int = 1) -> None:
        """Meter a point-to-point (or aggregated) transfer of ``payload``
        scalars under the given kind label."""
        ...

    def charge(
        self, *, flops: float = 0.0, scalars: float = 0.0, rounds: float = 0.0
    ) -> None:
        """Accumulate modeled wall-clock for a critical-path segment."""
        ...

    def charge_seconds(self, seconds: float) -> None:
        """Accumulate pre-computed modeled wall-clock (method-specific
        formulas, e.g. async server-bound throughput)."""
        ...

    def charge_cost(self, cost: "PhaseCost", steps: int = 1) -> None:
        """Accumulate modeled wall-clock for ``steps`` repetitions of one
        :class:`~repro.dist.costs.PhaseCost` closed form."""
        ...

    @property
    def modeled_time_s(self) -> float: ...

    @property
    def tree_rounds(self) -> int: ...

    def report(self, method: str = "") -> CommReport: ...


class MeteredBackend:
    """Shared metering/cost machinery; subclasses supply ``all_reduce``."""

    def __init__(self, q: int, cluster: ClusterModel | None = None) -> None:
        if q < 1:
            raise ValueError(f"need q >= 1 workers, got {q}")
        self.q = int(q)
        self.cluster = cluster or ClusterModel()
        self.meter = CommMeter()
        self._modeled_time = 0.0

    # -- metering-only primitives (paper §4.5 closed forms) --------------

    def meter_tree(self, payload: int, steps: int = 1) -> None:
        self.meter.tree_reduce_broadcast(self.q, payload, steps)

    def p2p(self, payload: int, kind: str, rounds: int = 1) -> None:
        self.meter.record(kind, payload, rounds)

    # -- modeled wall-clock ----------------------------------------------

    def charge(
        self, *, flops: float = 0.0, scalars: float = 0.0, rounds: float = 0.0
    ) -> None:
        self._modeled_time += self.cluster.time(
            critical_flops=flops, critical_scalars=scalars, rounds=rounds
        )

    def charge_seconds(self, seconds: float) -> None:
        self._modeled_time += float(seconds)

    def charge_cost(self, cost: PhaseCost, steps: int = 1) -> None:
        self._modeled_time += steps * self.cluster.time(
            critical_flops=cost.flops,
            critical_scalars=cost.scalars,
            rounds=cost.rounds,
        )

    @property
    def modeled_time_s(self) -> float:
        return self._modeled_time

    @property
    def tree_rounds(self) -> int:
        """Latency rounds of one tree reduce+broadcast at this q."""
        return tree_rounds(self.q)

    def _host_all_reduce(self, parts: Sequence, payload: int | None):
        """Shared host-side reduction: validate one partial per worker,
        meter the closed form, sum in canonical tree order."""
        if len(parts) != self.q:
            raise ValueError(
                f"all_reduce needs one partial per worker: got {len(parts)} "
                f"parts for q={self.q}"
            )
        parts = [jnp.asarray(p) for p in parts]
        if payload is None:
            payload = int(parts[0].size)
        self.meter_tree(payload)
        return tree_order_sum(parts)

    def report(self, method: str = "") -> CommReport:
        return CommReport.from_meter(
            method=method,
            q=self.q,
            meter=self.meter,
            cluster=self.cluster,
            modeled_time_s=self._modeled_time,
        )


class LocalBackend(MeteredBackend):
    """Single-process reference backend.

    ``all_reduce`` sums the partials directly — no message schedule — but
    in canonical tree order and with the standard accounting, so iterates
    and meters match the other backends exactly.
    """

    def all_reduce(self, parts: Sequence, payload: int | None = None):
        return self._host_all_reduce(parts, payload)


class SimBackend(MeteredBackend):
    """The executable spec: runs the explicit Figure-5 message schedule."""

    def all_reduce(self, parts: Sequence, payload: int | None = None):
        if len(parts) != self.q:
            raise ValueError(
                f"all_reduce needs one partial per worker: got {len(parts)} "
                f"parts for q={self.q}"
            )
        return simulate_tree_sum(parts, meter=self.meter, payload=payload)
