"""Deterministic fault injection at the ``Collectives`` boundary.

The paper's headline claim — FD-SVRG wins on communication when d > N —
is a claim about a *cluster*, and clusters drop messages, stall, corrupt
payloads, and kill workers mid-epoch.  This module makes those failure
modes first-class and **seeded**, so a chaos run is exactly as
reproducible as a clean one:

* :class:`FaultPlan` — a frozen, seeded description of which faults fire
  (drop / straggler / corruption probabilities, worker crashes pinned to
  outer iterations).  Two backends built from the same plan draw the
  same fault sequence.
* :class:`RetryPolicy` — bounded retransmissions with exponential
  backoff + deterministic jitter and a per-collective timeout.
* :class:`FaultyBackend` — a wrapper conforming to the
  :class:`~repro.dist.collectives.Collectives` protocol that composes
  over ANY backend (Local/Sim/ShardMap).  Faults are injected at the
  collective boundary, so every driver gets them for free — no driver
  code knows faults exist.

**Honest accounting is the design invariant.**  A retried collective is
not free: every failed attempt's traffic is recorded in the shared
``CommMeter`` under the ``"retry"`` kind (same scalars and rounds as the
collective it retransmits), and its wall-clock cost — the timeout spent
waiting plus the backoff before retransmission — is charged to the
backend's modeled time.  The successful attempt is metered by the inner
backend exactly as in a fault-free run.  Consequently::

    meter.total_scalars == fault-free analytic schedule
                           + meter.by_kind["retry"]

holds *exactly* (scalar equality, pinned by the drift-guard test in
``tests/test_driver.py``), so comm-cost comparisons stay falsifiable
under failure instead of retries silently vanishing from the x-axis.

Fault taxonomy and what each does to a run:

=============  ============================================================
drop           The attempt's messages are lost.  The sender waits out the
               per-collective timeout, charges it, records the wasted
               traffic under ``"retry"``, backs off, retransmits.  Values
               are unchanged (the retransmission carries the same
               deterministic partials), so a drop-only run is
               **bit-identical** to the fault-free run — only bytes and
               modeled time grow.
straggler      One worker is slow: the collective completes but the
               drawn delay is charged to modeled time.  A delay that
               exceeds ``RetryPolicy.timeout_s`` is indistinguishable
               from a drop and takes the retry path.
corruption     The reduced payload arrives with a NaN (executing
               collectives only — ``all_reduce``).  Detection is
               downstream: the harness's divergence guard sees a
               non-finite objective and aborts the epoch back to the
               replicated snapshot.
crash          A worker dies at the start of outer iteration t (armed by
               ``begin_outer``, raised from the next collective call).
               Unrecoverable at the collective layer —
               :class:`WorkerCrashError` propagates to the harness,
               which epoch-aborts to the snapshot and meters the
               restarted worker's snapshot re-distribution.
=============  ============================================================

``q <= 1`` backends carry no wire traffic, so no faults fire on them.
A plan with all probabilities 0 and no crashes makes the wrapper a true
no-op: bit-identical iterates, scalar-identical meters (pinned by
``tests/test_dist_backends.py`` running the full 3-backend equivalence
suite through the wrapper).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.dist.costs import PhaseCost
from repro.dist.meter import ClusterModel, CommMeter, tree_rounds
from repro.dist.metering import CommReport


class FaultError(RuntimeError):
    """Base class for injected/derived run faults (see also
    :class:`repro.core.driver.DivergenceError`, which subclasses this so
    the harness's recovery path catches both with one handler)."""


class WorkerCrashError(FaultError):
    """A worker died; its in-epoch state is gone.  Recoverable only by
    epoch-abort to the replicated snapshot."""


class RetriesExhaustedError(FaultError):
    """A collective failed ``max_retries + 1`` consecutive attempts."""


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded description of the faults a run experiences.

    Deterministic by construction: the plan owns a PRNG seed, and the
    wrapper consumes one draw per fault decision in collective-call
    order.  The same plan over the same call sequence yields the same
    faults — replaying the metering schedule against a second wrapper
    reproduces the ``"retry"`` byte count exactly (the honest-accounting
    test does precisely this).
    """

    seed: int = 0
    drop_prob: float = 0.0  # P(an attempt's messages are lost)
    straggler_prob: float = 0.0  # P(one worker stalls this attempt)
    straggler_delay_s: float = 5e-3  # max stall; actual ~ U(0, max)
    corrupt_prob: float = 0.0  # P(reduced payload arrives NaN), all_reduce only
    crash_at_outer: tuple[int, ...] = ()  # worker crash at these outer iters

    def __post_init__(self) -> None:
        for name in ("drop_prob", "straggler_prob", "corrupt_prob"):
            p = getattr(self, name)
            if not 0.0 <= p < 1.0:
                raise ValueError(f"{name} must be in [0, 1), got {p!r}")
        if self.straggler_delay_s < 0:
            raise ValueError("straggler_delay_s >= 0 required")
        # normalize a stray int / list into the canonical tuple
        crash = self.crash_at_outer
        if isinstance(crash, int):
            crash = (crash,)
        object.__setattr__(self, "crash_at_outer", tuple(int(t) for t in crash))

    @property
    def is_noop(self) -> bool:
        return (
            self.drop_prob == 0.0
            and self.straggler_prob == 0.0
            and self.corrupt_prob == 0.0
            and not self.crash_at_outer
        )

    def rng(self) -> np.random.Generator:
        return np.random.default_rng(self.seed)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retransmission with exponential backoff + jitter.

    A failed attempt costs ``timeout_s`` (the wait that detected the
    loss) plus ``backoff_base_s * backoff_factor**attempt * (1 + j)``
    with ``j ~ U(0, jitter)`` drawn from the plan's PRNG — all charged to
    modeled time, never to the meter's byte count (bytes that were never
    re-sent aren't bytes; the retransmission itself is the ``"retry"``
    record).
    """

    max_retries: int = 3  # retransmissions allowed after the first attempt
    backoff_base_s: float = 1e-3
    backoff_factor: float = 2.0
    jitter: float = 0.1  # uniform multiplicative jitter on the backoff
    timeout_s: float = 0.1  # per-collective wait before declaring a drop

    def __post_init__(self) -> None:
        if self.max_retries < 0:
            raise ValueError("max_retries >= 0 required")
        if min(self.backoff_base_s, self.backoff_factor, self.jitter,
               self.timeout_s) < 0:
            raise ValueError("RetryPolicy time constants must be >= 0")

    def backoff_s(self, attempt: int, jitter_draw: float) -> float:
        return (
            self.backoff_base_s
            * self.backoff_factor ** attempt
            * (1.0 + self.jitter * jitter_draw)
        )


class FaultyBackend:
    """A ``Collectives`` backend that injects ``plan``'s faults into
    ``inner`` and meters the recovery honestly.

    Composes over any backend: the wrapper owns no meter, no cluster, and
    no modeled clock — everything delegates to ``inner``, so a wrapped
    run reports through the exact same accounting objects as a clean one
    and ``RunResult.meter is backend.meter`` keeps holding.
    """

    def __init__(
        self,
        inner,
        plan: FaultPlan,
        retry: RetryPolicy | None = None,
    ) -> None:
        self.inner = inner
        self.plan = plan
        self.retry = retry or RetryPolicy()
        self._rng = plan.rng()
        self._armed_outer: int | None = None  # crash pending for this outer
        self._crashed: set[int] = set()  # outers whose crash already fired

    # -- delegated protocol surface --------------------------------------

    @property
    def q(self) -> int:
        return self.inner.q

    @property
    def meter(self) -> CommMeter:
        return self.inner.meter

    @property
    def cluster(self) -> ClusterModel:
        return self.inner.cluster

    def charge(self, *, flops: float = 0.0, scalars: float = 0.0,
               rounds: float = 0.0) -> None:
        self.inner.charge(flops=flops, scalars=scalars, rounds=rounds)

    def charge_seconds(self, seconds: float) -> None:
        self.inner.charge_seconds(seconds)

    def charge_cost(self, cost: PhaseCost, steps: int = 1) -> None:
        self.inner.charge_cost(cost, steps)

    @property
    def modeled_time_s(self) -> float:
        return self.inner.modeled_time_s

    @property
    def tree_rounds(self) -> int:
        return self.inner.tree_rounds

    def report(self, method: str = "") -> CommReport:
        return self.inner.report(method)

    # -- crash arming (driven by the outer-loop harness) ------------------

    def begin_outer(self, t: int) -> None:
        """Arm the plan's crash for outer ``t``; it fires at the next
        collective call.  A crash fires once per outer — the restarted
        worker (post epoch-abort) does not re-crash."""
        if int(t) in self.plan.crash_at_outer and int(t) not in self._crashed:
            self._armed_outer = int(t)

    def _maybe_crash(self) -> None:
        if self._armed_outer is not None:
            t, self._armed_outer = self._armed_outer, None
            self._crashed.add(t)
            raise WorkerCrashError(
                f"worker crashed at outer iteration {t} (FaultPlan seed "
                f"{self.plan.seed})"
            )

    # -- the fault loop ----------------------------------------------------

    def _deliver(self, scalars: int, rounds: int, execute: Callable):
        """Run one collective under the plan: failed attempts meter their
        retransmitted traffic under ``"retry"`` and charge timeout +
        backoff; the successful attempt is ``execute()`` — the inner
        backend's own (metered) primitive, untouched."""
        self._maybe_crash()
        if self.q <= 1 or scalars <= 0:
            return execute()  # nothing on the wire -> nothing can fail
        for attempt in range(self.retry.max_retries + 1):
            r_drop, r_straggle = self._rng.random(2)
            delay = 0.0
            if r_straggle < self.plan.straggler_prob:
                delay = self.plan.straggler_delay_s * self._rng.random()
            if r_drop < self.plan.drop_prob or delay > self.retry.timeout_s:
                # Lost (or timed out): the attempt's traffic was spent for
                # nothing and must be retransmitted — that is the honest
                # overhead of the fault, metered under its own kind.
                self.inner.meter.record("retry", scalars, rounds)
                self.inner.charge_seconds(
                    self.retry.timeout_s
                    + self.retry.backoff_s(attempt, self._rng.random())
                )
                continue
            if delay > 0.0:
                self.inner.charge_seconds(delay)  # slow, but it arrived
            return execute()
        raise RetriesExhaustedError(
            f"collective failed {self.retry.max_retries + 1} consecutive "
            f"attempts (drop_prob={self.plan.drop_prob}, seed="
            f"{self.plan.seed}); raise RetryPolicy.max_retries or recover "
            "via epoch abort"
        )

    # -- Collectives primitives, faulted ----------------------------------

    def all_reduce(self, parts: Sequence, payload: int | None = None):
        p = int(payload) if payload is not None else int(
            np.asarray(parts[0]).size
        )
        scalars = 2 * self.q * p if self.q > 1 else 0
        out = self._deliver(
            scalars, tree_rounds(self.q),
            lambda: self.inner.all_reduce(parts, payload),
        )
        if self.q > 1 and self._rng.random() < self.plan.corrupt_prob:
            # The broadcast leg delivered a mangled payload: poison one
            # lane.  Detection is the harness's divergence guard.
            import jax.numpy as jnp

            out = jnp.asarray(out).at[0].set(jnp.nan)
        return out

    def meter_tree(self, payload: int, steps: int = 1) -> None:
        for _ in range(int(steps)):
            self._deliver(
                2 * self.q * payload if self.q > 1 else 0,
                tree_rounds(self.q),
                lambda: self.inner.meter_tree(payload, steps=1),
            )

    def p2p(self, payload: int, kind: str, rounds: int = 1) -> None:
        self._deliver(
            int(payload), int(rounds),
            lambda: self.inner.p2p(payload, kind, rounds),
        )
