"""Tree-structured global sum (paper §4.2, Figure 5).

Four views of the same reduction:

1. ``tree_schedule(q)`` — the explicit pairing schedule from Figure 5, as
   (round, src, dst) triples.  Used by the simulator and the comm meter;
   tests check it computes an exact sum for any q and any values.

2. ``tree_order_sum`` — the canonical pairwise summation in schedule
   order.  This is the ONE definition of "sum the per-worker partials the
   way Figure 5 does"; the jitted inner loop, the worker simulation, and
   the interpret-mode shard_map backend all call it, so their floating-
   point results are bit-comparable.

3. ``simulate_tree_sum`` — runs the schedule message-by-message on a list
   of per-worker values (the executable spec), returning the sum as the
   coordinator sees it and metering the traffic.

4. ``psum_tree`` / ``collective_permute_tree`` — the TPU-native mappings:
   ``jax.lax.psum`` over a mesh axis (the hardware all-reduce *is* a
   tree/ring), and an explicit log-depth butterfly built from
   ``lax.ppermute`` for when one wants the paper's exact topology on
   device (also demonstrates the pattern lowers; used by the dry-run).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.dist.meter import CommMeter


def tree_schedule(q: int) -> list[list[tuple[int, int]]]:
    """Rounds of (src -> dst) sends for a binary-tree reduce of q workers.

    Worker 0 doubles as the coordinator (paper's Figure 5 has a separate
    coordinator box; topologically it is the tree root).  Round r pairs
    workers at stride 2^r: src = k + 2^r sends to dst = k for
    k ≡ 0 (mod 2^(r+1)).
    """
    rounds: list[list[tuple[int, int]]] = []
    stride = 1
    while stride < q:
        sends = []
        k = 0
        while k + stride < q:
            sends.append((k + stride, k))
            k += 2 * stride
        rounds.append(sends)
        stride *= 2
    return rounds


def broadcast_schedule(q: int) -> list[list[tuple[int, int]]]:
    """Reverse-order tree broadcast (root 0 to everyone)."""
    return [
        [(dst, src) for (src, dst) in rnd] for rnd in reversed(tree_schedule(q))
    ]


def tree_order_sum(parts: Sequence):
    """Pairwise sum of per-worker partials in Figure-5 schedule order.

    Works on anything supporting ``+`` (jax arrays under jit included);
    every code path that claims equivalence with the tree reduce sums
    through this function so association order — and therefore floating
    point — matches exactly.
    """
    acc = list(parts)
    for rnd in tree_schedule(len(acc)):
        for src, dst in rnd:
            acc[dst] = acc[dst] + acc[src]
    return acc[0]


def simulate_tree_sum(
    values: Sequence[jax.Array] | Sequence[float],
    meter: CommMeter | None = None,
    payload: int | None = None,
) -> jax.Array:
    """Run the Figure-5 reduce+broadcast over per-worker values.

    Returns the global sum (identical on every worker after broadcast).
    Meters 2*q*payload scalars like the paper's accounting.
    """
    q = len(values)
    acc = [jnp.asarray(v) for v in values]
    if payload is None:
        payload = int(acc[0].size) if hasattr(acc[0], "size") else 1
    total = tree_order_sum(acc)
    # Broadcast back down the tree (reverse order).
    for rnd in broadcast_schedule(q):
        for src, dst in rnd:
            acc[dst] = total
    if meter is not None:
        meter.tree_reduce_broadcast(q, payload)
    return total


# ---------------------------------------------------------------------------
# TPU-native mappings
# ---------------------------------------------------------------------------


def psum_tree(x: jax.Array, axis_name: str) -> jax.Array:
    """The deployable form: hardware all-reduce over the model axis.

    On TPU this lowers to the ICI tree/ring all-reduce — the exact
    hardware realization of the paper's Figure 5 (reduce + broadcast in
    one collective, sum left replicated on every worker).
    """
    return jax.lax.psum(x, axis_name)


def collective_permute_tree(x: jax.Array, axis_name: str, axis_size: int) -> jax.Array:
    """Explicit log-depth all-reduce from ppermute rounds.

    A recursive-doubling butterfly: after round r every worker holds the
    sum over its 2^(r+1)-aligned group; after log2(q) rounds every worker
    holds the global sum.  Equivalent to reduce+broadcast in traffic
    (2q payloads total) but half the rounds; we use it in §Perf as a
    beyond-paper variant and to show the paper's topology lowers on TPU.

    Requires axis_size to be a power of two (the production meshes are).
    """
    if axis_size & (axis_size - 1):
        raise ValueError(f"axis_size must be a power of two, got {axis_size}")
    out = x
    stride = 1
    while stride < axis_size:
        perm = [(i, i ^ stride) for i in range(axis_size)]
        out = out + jax.lax.ppermute(out, axis_name, perm)
        stride *= 2
    return out
