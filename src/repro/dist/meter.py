"""Communication accounting and wall-clock cost models.

The paper's Figure 7 x-axis is "how many scalars have been communicated";
its complexity analysis (§4.5) counts, per N gradients:

    FD-SVRG : 2qN scalars        (tree reduce+broadcast of one scalar)
    DSVRG   : 2qd scalars        (full-gradient round + parameter handoff)
    PS SVRG : O((N + d) d / ...) — dense vectors every inner step.

``CommMeter`` records every message a simulated algorithm sends so tests
can check the closed forms *exactly*, and benchmarks can plot Figure 7.
Every backend of the :class:`repro.dist.Collectives` protocol owns one
meter, so all methods report through the same accounting.

``ClusterModel`` converts (flops, messages) into simulated wall-clock for
Figure 6 / Tables 2–3-style results: we are on one CPU, so time is modeled,
not measured — parameters mirror the paper's cluster (10GbE, Xeon E5-2620).
The model is deliberately simple and is validated qualitatively (ordering,
scaling trends), never used for correctness claims.
"""

from __future__ import annotations

import dataclasses
import math
from collections import defaultdict


def tree_rounds(q: int) -> int:
    """Latency-bearing rounds of one Figure-5 tree reduce+broadcast."""
    if q <= 1:
        return 0
    return 2 * max(1, math.ceil(math.log2(q)))


@dataclasses.dataclass
class CommEvent:
    kind: str  # e.g. "tree_reduce", "push", "pull", "ring"
    scalars: int
    rounds: int  # latency-bearing sequential rounds this event took


class CommMeter:
    """Counts scalars communicated, message rounds, and per-kind breakdown."""

    def __init__(self) -> None:
        self.total_scalars = 0
        self.total_rounds = 0
        self.by_kind: dict[str, int] = defaultdict(int)
        self.events: list[CommEvent] = []

    def record(self, kind: str, scalars: int, rounds: int = 1) -> None:
        scalars = int(scalars)
        rounds = int(rounds)
        self.total_scalars += scalars
        self.total_rounds += rounds
        self.by_kind[kind] += scalars
        self.events.append(CommEvent(kind, scalars, rounds))

    # -- canonical communication patterns -------------------------------

    def tree_reduce_broadcast(self, q: int, payload: int = 1, steps: int = 1) -> None:
        """Paper §4.5: tree reduce + reverse broadcast of `payload` scalars
        among q workers costs 2*q*payload scalars in ~2*ceil(log2 q) rounds
        (Figure 5: solid arrows = q per direction, counting the coordinator
        hop).  ``steps`` meters that many identical trees in one event.
        """
        if q <= 1 or steps <= 0:
            return
        self.record(
            "tree_reduce", 2 * q * payload * steps, tree_rounds(q) * steps
        )

    def point_to_point(self, payload: int, kind: str = "p2p") -> None:
        self.record(kind, payload, 1)

    def snapshot(self) -> dict[str, int]:
        return {
            "total_scalars": self.total_scalars,
            "total_rounds": self.total_rounds,
            **{f"kind:{k}": v for k, v in sorted(self.by_kind.items())},
        }

    # -- checkpoint support ----------------------------------------------

    def state_dict(self) -> dict:
        """JSON-serializable full state (counters AND the event log), so a
        resumed run's meter is indistinguishable from an uninterrupted one."""
        return {
            "total_scalars": self.total_scalars,
            "total_rounds": self.total_rounds,
            "by_kind": dict(self.by_kind),
            "events": [[e.kind, e.scalars, e.rounds] for e in self.events],
        }

    def load_state(self, state: dict) -> None:
        self.total_scalars = int(state["total_scalars"])
        self.total_rounds = int(state["total_rounds"])
        self.by_kind = defaultdict(int)
        for k, v in state["by_kind"].items():
            self.by_kind[k] = int(v)
        self.events = [
            CommEvent(str(k), int(s), int(r)) for k, s, r in state["events"]
        ]


@dataclasses.dataclass(frozen=True)
class ClusterModel:
    """Wall-clock simulator mirroring the paper's cluster.

    time = flops_on_critical_path / flops_per_s
         + scalars_on_critical_path * bytes_per_scalar / bandwidth
         + rounds * latency
    """

    flops_per_s: float = 2.0e9  # per-core effective sparse-ops throughput
    bandwidth_Bps: float = 1.25e9  # 10 GbE
    latency_s: float = 50e-6  # small-message RTT on Ethernet
    bytes_per_scalar: int = 8

    def time(
        self, *, critical_flops: float, critical_scalars: float, rounds: float
    ) -> float:
        return (
            critical_flops / self.flops_per_s
            + critical_scalars * self.bytes_per_scalar / self.bandwidth_Bps
            + rounds * self.latency_s
        )


# TPU-v5e model for the roofline layer (see launch/roofline.py). Kept here so
# the core cost models and the launch-time roofline share one set of numbers.
@dataclasses.dataclass(frozen=True)
class TpuV5eModel:
    peak_flops_bf16: float = 197e12  # per chip
    hbm_Bps: float = 819e9  # per chip
    ici_Bps_per_link: float = 50e9  # ~per link per direction

    def roofline_terms(
        self, *, flops: float, hbm_bytes: float, collective_bytes: float, chips: int
    ) -> dict[str, float]:
        compute = flops / (chips * self.peak_flops_bf16)
        memory = hbm_bytes / (chips * self.hbm_Bps)
        collective = collective_bytes / (chips * self.ici_Bps_per_link)
        dominant = max(
            ("compute", compute), ("memory", memory), ("collective", collective),
            key=lambda kv: kv[1],
        )[0]
        return {
            "compute_s": compute,
            "memory_s": memory,
            "collective_s": collective,
            "dominant": dominant,
        }
