"""The §4.5 cost closed forms — ONE place, every consumer.

Three things used to hold private copies of the per-outer cost
arithmetic: ``run_fdsvrg``, ``run_fdsvrg_sharded``, and
``benchmarks.common.analytic_outer`` — and they drifted (different
per-step compute terms, different PS pull conventions).  This module is
now the only implementation:

* the **measured-sim drivers** charge phase by phase
  (:meth:`CostModel.fd_fullgrad`, :meth:`CostModel.fd_inner_step`, …)
  through ``Collectives.charge_cost``;
* the **analytic benchmark schedules** aggregate the same phases into a
  per-outer total (:meth:`CostModel.outer_cost`) at the paper's full
  Table-1 sizes;
* the **drift-guard test** (``tests/test_driver.py``) runs every method
  and asserts the measured meter and the analytic schedule agree on
  scalars-per-outer (and modeled seconds) exactly.

Conventions, applied to every method alike:

* **Scalars** are the § 4.5 wire unit.  A Figure-5 tree reduce+broadcast
  of ``p`` scalars among q workers is ``2·q·p`` scalars in
  ``2⌈log₂q⌉`` rounds; ``q ≤ 1`` communicates nothing.  PS workers pull
  the dense ``w`` (d scalars) and push sparse <key,value> gradients
  (``2·u·nnz`` scalars) — the paper's concession to the baselines.
* **Compute** follows the lazy sparse-update trick for every method:
  one sampled (VR-)gradient costs O(nnz) — O(nnz/q) per worker under
  the feature partition, where each worker touches only its block's
  entries — and dense regularizer/z terms are folded lazily instead of
  being charged as O(d) per step.
* **Modeled seconds** for a linear phase are
  ``flops/flops_per_s + scalars·bytes_per_scalar/bandwidth +
  rounds·latency`` (:meth:`~repro.dist.meter.ClusterModel.time`); the
  asynchronous PS inner loop is the one nonlinear phase —
  ``max(compute/q, server bandwidth)`` per step — and has its own
  closed form here.
"""

from __future__ import annotations

import dataclasses

from repro.dist.meter import ClusterModel, tree_rounds


@dataclasses.dataclass(frozen=True)
class PhaseCost:
    """One critical-path segment: per-worker flops, wire scalars, and
    latency-bearing rounds.  Feed to ``Collectives.charge_cost`` (drivers)
    or :meth:`CostModel.seconds` (analytic schedules)."""

    flops: float = 0.0
    scalars: int = 0
    rounds: int = 0


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Paper §4.5 per-outer closed forms for all six optimizers."""

    # -- FD-SVRG (Algorithm 1; serial SVRG is the q = 1 specialization) --

    def fd_fullgrad(self, *, n: int, nnz: int, q: int, k: int = 1) -> PhaseCost:
        """Full-gradient phase (Alg 1 lines 3-5): per-worker margins +
        scatter over the local block, one N-payload tree.  ``k`` is the
        multi-output width (w ∈ R^{d×k}): per-nonzero work and the tree
        payload both scale by k, the round count does not (the k margin
        vectors ride one tree)."""
        return PhaseCost(
            flops=4.0 * n * nnz * k / q,
            scalars=2 * q * n * k if q > 1 else 0,
            rounds=tree_rounds(q),
        )

    def fd_inner_step(self, *, nnz: int, q: int, u: int, k: int = 1) -> PhaseCost:
        """One inner step (Alg 1 lines 9-11): per-worker sparse work on
        the sampled rows' local entries, one u-payload tree (u·k scalars
        for multi-output — see :meth:`fd_fullgrad`)."""
        return PhaseCost(
            flops=2.0 * u * nnz * k / q,
            scalars=2 * q * u * k if q > 1 else 0,
            rounds=tree_rounds(q),
        )

    # -- FD-SAGA (feature-distributed SAGA, replicated scalar table) -----

    def fd_saga_init(self, *, n: int, nnz: int, q: int) -> PhaseCost:
        """Table initialization (once per run, not per outer): one
        full-gradient-shaped pass sets the n-float margin-derivative
        table α and its running mean z — the table is *scalars per
        instance*, so replicating it costs one N-payload tree, same wire
        shape as the FD-SVRG full-gradient phase (never an O(d)
        gradient table per worker)."""
        return PhaseCost(
            flops=4.0 * n * nnz / q,
            scalars=2 * q * n if q > 1 else 0,
            rounds=tree_rounds(q),
        )

    def fd_saga_step(self, *, nnz: int, q: int, u: int) -> PhaseCost:
        """One FD-SAGA inner step: margins gather + direction scatter +
        table-mean scatter on the sampled rows' local entries (3 sparse
        passes vs FD-SVRG's 2 — SAGA folds its snapshot maintenance into
        every step), one u-payload tree exactly like the SVRG step."""
        return PhaseCost(
            flops=6.0 * u * nnz / q,
            scalars=2 * q * u if q > 1 else 0,
            rounds=tree_rounds(q),
        )

    # -- FD-BCD (distributed block coordinate descent, Mahajan et al.) ---

    def fd_bcd_step(self, *, n: int, nnz: int, q: int) -> PhaseCost:
        """One BCD block update: the active worker scatters the full data
        gradient restricted to its block (all N rows' local entries) and
        re-computes its block's margin delta, then the delta is
        tree-replicated so every worker's maintained margins stay
        consistent — an N-payload tree per step, the price BCD pays for
        updating whole blocks instead of sampled rows."""
        return PhaseCost(
            flops=4.0 * n * nnz / q,
            scalars=2 * q * n if q > 1 else 0,
            rounds=tree_rounds(q),
        )

    # -- DSVRG (Lee et al.: ring of instance shards) ---------------------

    def dsvrg_fullgrad(self, *, n: int, d: int, nnz: int, q: int) -> PhaseCost:
        """Parallel full gradient: center <-> q machines, dense d each way."""
        return PhaseCost(flops=4.0 * (n / q) * nnz, scalars=2 * q * d, rounds=2)

    def dsvrg_epoch(self, *, m: int, nnz: int, d: int, u: int) -> PhaseCost:
        """M serial inner steps on one machine + the dense parameter
        handoff (center -> J: full gradient; J -> center: parameter)."""
        return PhaseCost(flops=2.0 * m * u * nnz, scalars=2 * d, rounds=2)

    # -- Parameter-server family (Appendix B) ----------------------------

    def ps_fullgrad(self, *, n: int, d: int, nnz: int, q: int) -> PhaseCost:
        """Dense full-gradient round: q workers pull w and push grads."""
        return PhaseCost(flops=4.0 * (n / q) * nnz, scalars=2 * q * d, rounds=2)

    def syn_inner_step(self, *, d: int, nnz: int, q: int, u: int) -> PhaseCost:
        """One synchronous step: q workers each pull dense w (d) and push
        a sparse <key,value> VR gradient (2·u·nnz)."""
        return PhaseCost(
            flops=2.0 * u * nnz, scalars=q * (d + 2 * u * nnz), rounds=2
        )

    def async_step_scalars(self, *, d: int, nnz: int, u: int = 1) -> int:
        """One async step's traffic: one worker pulls dense w, pushes a
        sparse <key,value> (VR-)gradient."""
        return d + 2 * u * nnz

    def async_step_seconds(
        self, cluster: ClusterModel, *, d: int, nnz: int, q: int, u: int = 1
    ) -> float:
        """Async throughput: q workers overlap compute, the server
        serializes message handling — per-step time is the max of the
        overlapped compute and the server's wire time."""
        scalars = self.async_step_scalars(d=d, nnz=nnz, u=u)
        return max(
            2.0 * u * nnz / (cluster.flops_per_s * q),
            scalars * cluster.bytes_per_scalar / cluster.bandwidth_Bps,
        )

    # -- aggregation -----------------------------------------------------

    def seconds(self, cluster: ClusterModel, cost: PhaseCost) -> float:
        return cluster.time(
            critical_flops=cost.flops,
            critical_scalars=cost.scalars,
            rounds=cost.rounds,
        )

    def outer_cost(
        self,
        method: str,
        *,
        n: int,
        d: int,
        nnz: int,
        q: int,
        u: int = 1,
        inner_steps: int | None = None,
        cluster: ClusterModel | None = None,
    ) -> tuple[float, int]:
        """(modeled seconds, scalars) for ONE outer iteration of ``method``.

        ``inner_steps=None`` applies the paper's M conventions (FD: N/u;
        DSVRG/SynSVRG: N/q; AsySVRG/PS-Lite: N); pass the actual M to
        match a measured run exactly — the drift-guard test asserts that
        a driver's meter and this closed form agree per outer.
        """
        cl = cluster or ClusterModel()
        if method == "serial":
            method, q, u = "fdsvrg", 1, u
        if method == "fdsvrg":
            m = inner_steps if inner_steps is not None else max(1, n // u)
            fg = self.fd_fullgrad(n=n, nnz=nnz, q=q)
            st = self.fd_inner_step(nnz=nnz, q=q, u=u)
            return (
                self.seconds(cl, fg) + m * self.seconds(cl, st),
                fg.scalars + m * st.scalars,
            )
        if method == "fd_saga":
            m = inner_steps if inner_steps is not None else max(1, n // u)
            st = self.fd_saga_step(nnz=nnz, q=q, u=u)
            # Steady-state per-outer cost; the one-time table init is
            # :meth:`init_cost` (the drift guard pins meter == init +
            # outers * this).
            return m * self.seconds(cl, st), m * st.scalars
        if method == "fd_bcd":
            m = inner_steps if inner_steps is not None else max(1, q)
            st = self.fd_bcd_step(n=n, nnz=nnz, q=q)
            return m * self.seconds(cl, st), m * st.scalars
        if method == "dsvrg":
            m = inner_steps if inner_steps is not None else max(1, n // q)
            fg = self.dsvrg_fullgrad(n=n, d=d, nnz=nnz, q=q)
            ep = self.dsvrg_epoch(m=m, nnz=nnz, d=d, u=u)
            return (
                self.seconds(cl, fg) + self.seconds(cl, ep),
                fg.scalars + ep.scalars,
            )
        if method == "synsvrg":
            m = inner_steps if inner_steps is not None else max(1, n // q)
            fg = self.ps_fullgrad(n=n, d=d, nnz=nnz, q=q)
            st = self.syn_inner_step(d=d, nnz=nnz, q=q, u=u)
            return (
                self.seconds(cl, fg) + m * self.seconds(cl, st),
                fg.scalars + m * st.scalars,
            )
        if method in ("asysvrg", "pslite_sgd"):
            m = inner_steps if inner_steps is not None else n
            time_s = m * self.async_step_seconds(cl, d=d, nnz=nnz, q=q, u=u)
            scalars = m * self.async_step_scalars(d=d, nnz=nnz, u=u)
            if method == "asysvrg":
                fg = self.ps_fullgrad(n=n, d=d, nnz=nnz, q=q)
                time_s += self.seconds(cl, fg)
                scalars += fg.scalars
            return time_s, scalars
        raise ValueError(
            f"unknown method {method!r} in CostModel.outer_cost; methods "
            "with closed forms: serial, fdsvrg, fd_saga, fd_bcd, dsvrg, "
            "synsvrg, asysvrg, pslite_sgd"
        )

    def init_cost(
        self,
        method: str,
        *,
        n: int,
        nnz: int,
        q: int,
        cluster: ClusterModel | None = None,
    ) -> tuple[float, int]:
        """(modeled seconds, scalars) charged ONCE per run, before the
        per-outer schedule — zero for every method except ``fd_saga``,
        whose gradient-table initialization is a one-time full-gradient-
        shaped phase (:meth:`fd_saga_init`)."""
        if method == "fd_saga":
            cost = self.fd_saga_init(n=n, nnz=nnz, q=q)
            return self.seconds(cluster or ClusterModel(), cost), cost.scalars
        return 0.0, 0


#: The shared instance every driver and benchmark consumes.
COSTS = CostModel()
