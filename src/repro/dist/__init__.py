"""repro.dist — the unified distributed substrate.

One pluggable communication layer behind everything that crosses (or
models crossing) a worker boundary:

* :mod:`repro.dist.meter`        — ``CommMeter`` (scalars/rounds/per-kind
  accounting) and the ``ClusterModel`` / ``TpuV5eModel`` cost models.
* :mod:`repro.dist.tree`         — the paper's Figure-5 tree
  reduce+broadcast: schedules, the canonical tree-order summation, the
  simulated executable spec, and the TPU-native ``psum`` / ppermute
  butterfly mappings.
* :mod:`repro.dist.collectives`  — the ``Collectives`` protocol and the
  ``LocalBackend`` / ``SimBackend`` single-process backends.
* :mod:`repro.dist.costs`        — ``CostModel``, the single home of the
  §4.5 per-outer closed forms (drivers charge them, benchmark schedules
  aggregate them, the drift-guard test pins them together).
* :mod:`repro.dist.shardmap`     — ``ShardMapBackend``, the deployable
  shard_map realization over a mesh axis.
* :mod:`repro.dist.metering`     — ``CommReport``, the per-method
  communication report benchmarks consume.
* :mod:`repro.dist.compat`       — version-portable wrappers for the jax
  APIs (``shard_map``, ``make_mesh``) that moved between jax releases.
* :mod:`repro.dist.faults`       — seeded fault injection at the
  collective boundary: ``FaultPlan`` / ``RetryPolicy`` /
  ``FaultyBackend``, with retransmissions metered under the ``retry``
  kind so comm accounting stays honest under failure.

Every optimization method in :mod:`repro.core` (FD-SVRG, DSVRG, the
parameter-server baselines) takes a ``Collectives`` backend and routes
all communication accounting and modeled wall-clock through it, so
cross-method comparisons share one meter and one cost model.
"""

from repro.dist.collectives import (
    Collectives,
    LocalBackend,
    SimBackend,
)
from repro.dist.compat import make_mesh, shard_map
from repro.dist.costs import COSTS, CostModel, PhaseCost
from repro.dist.faults import (
    FaultError,
    FaultPlan,
    FaultyBackend,
    RetriesExhaustedError,
    RetryPolicy,
    WorkerCrashError,
)
from repro.dist.meter import (
    ClusterModel,
    CommEvent,
    CommMeter,
    TpuV5eModel,
    tree_rounds,
)
from repro.dist.metering import CommReport
from repro.dist.shardmap import ShardMapBackend
from repro.dist.tree import (
    broadcast_schedule,
    collective_permute_tree,
    psum_tree,
    simulate_tree_sum,
    tree_order_sum,
    tree_schedule,
)

__all__ = [
    "COSTS",
    "ClusterModel",
    "Collectives",
    "CommEvent",
    "CommMeter",
    "CommReport",
    "CostModel",
    "FaultError",
    "FaultPlan",
    "FaultyBackend",
    "PhaseCost",
    "LocalBackend",
    "RetriesExhaustedError",
    "RetryPolicy",
    "ShardMapBackend",
    "SimBackend",
    "TpuV5eModel",
    "WorkerCrashError",
    "broadcast_schedule",
    "collective_permute_tree",
    "make_mesh",
    "psum_tree",
    "shard_map",
    "simulate_tree_sum",
    "tree_order_sum",
    "tree_rounds",
    "tree_schedule",
]
