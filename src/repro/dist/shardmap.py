"""``ShardMapBackend`` — the deployable realization of the substrate.

On device, the paper's Figure-5 tree is the hardware all-reduce: inside a
``shard_map``-traced worker function, :meth:`ShardMapBackend.device_all_reduce`
lowers to ``jax.lax.psum`` over the feature ("model") mesh axes, or to the
explicit ppermute butterfly when ``tree_mode="butterfly"``.  Communication
cannot be observed from inside the traced computation, so the backend
meters *statically* on the host — with the same §4.5 closed forms the
simulation backends use, against the same :class:`~repro.dist.meter.CommMeter`.
That is the point of the substrate: measured-or-modeled, every method's
bytes flow through one meter.

``interpret=True`` gives a device-free stand-in for tests: ``all_reduce``
combines per-worker partials in canonical tree order (the deterministic
all-reduce semantics — every worker sees identical bits) without any mesh,
so the equivalence suite can run the "deployable" semantics on one CPU and
compare iterates and meters bit-for-bit against the other backends.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.dist import compat
from repro.dist.collectives import MeteredBackend
from repro.dist.meter import ClusterModel
from repro.dist.tree import collective_permute_tree

TREE_MODES = ("psum", "butterfly")


class ShardMapBackend(MeteredBackend):
    """Collectives over a jax mesh's feature axes (or their interpretation).

    Exactly one of ``mesh`` / ``q`` must be given:

    * ``mesh`` + ``feature_axes`` — the real thing; ``q`` is the product
      of the named axis sizes and ``device_all_reduce`` is usable inside
      ``shard_map``-traced code built via :meth:`shard_map`.
    * ``q`` with ``interpret=True`` — no devices; ``all_reduce`` runs the
      canonical tree-order reduction host-side.
    """

    def __init__(
        self,
        mesh=None,
        feature_axes: Sequence[str] = ("model",),
        tree_mode: str = "psum",
        cluster: ClusterModel | None = None,
        q: int | None = None,
        interpret: bool = False,
    ) -> None:
        if tree_mode not in TREE_MODES:
            raise ValueError(f"tree_mode must be one of {TREE_MODES}, got {tree_mode!r}")
        if (mesh is None) == (q is None):
            raise ValueError("pass exactly one of mesh= or q=")
        if mesh is not None:
            q = 1
            for a in feature_axes:
                q *= mesh.shape[a]
        super().__init__(q, cluster)
        self.mesh = mesh
        self.feature_axes = tuple(feature_axes)
        self.tree_mode = tree_mode
        self.interpret = bool(interpret or mesh is None)

    # -- device path (call inside shard_map-traced code) -----------------

    def device_all_reduce(self, x: jax.Array) -> jax.Array:
        """All-reduce over the feature axes; only valid under tracing by a
        ``shard_map`` built on this backend's mesh."""
        if self.mesh is None:
            raise ValueError("device_all_reduce requires a real mesh")
        if self.tree_mode == "psum":
            return jax.lax.psum(x, self.feature_axes)
        out = x
        for a in self.feature_axes:
            out = collective_permute_tree(out, a, self.mesh.shape[a])
        return out

    def shard_map(self, f, in_specs, out_specs):
        """Wrap ``f`` with ``shard_map`` over this backend's mesh."""
        if self.mesh is None:
            raise ValueError("shard_map requires a real mesh")
        return compat.shard_map(f, self.mesh, in_specs, out_specs)

    def device_worker_id(self) -> jax.Array:
        """Linear worker id across the feature axes (traced code only)."""
        wid = jnp.zeros((), dtype=jnp.int32)
        for a in self.feature_axes:
            wid = wid * self.mesh.shape[a] + jax.lax.axis_index(a)
        return wid

    # -- host path --------------------------------------------------------

    def all_reduce(self, parts: Sequence, payload: int | None = None):
        """Interpret-mode all-reduce of per-worker partials.

        Deterministic device all-reduce leaves identical bits on every
        worker; the canonical tree order is our interpretation of it.
        """
        if not self.interpret:
            raise ValueError(
                "host all_reduce is only available with interpret=True; "
                "use device_all_reduce inside shard_map-traced code"
            )
        return self._host_all_reduce(parts, payload)
