"""Version-portable wrappers for jax distributed APIs.

The repo targets the current jax while staying runnable on the 0.4.x
series baked into the container:

* ``shard_map`` graduated from ``jax.experimental.shard_map`` to
  ``jax.shard_map``, renaming ``check_rep`` to ``check_vma`` on the way.
* ``jax.make_mesh`` grew an ``axis_types`` kwarg (with
  ``jax.sharding.AxisType``) that older releases reject.

Everything in the repo goes through these two wrappers instead of the
raw APIs.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax

try:  # jax >= 0.6
    from jax import shard_map as _shard_map

    _CHECK_KWARG = "check_vma"
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KWARG = "check_rep"


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` with the replication/VMA check flag normalized."""
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        **{_CHECK_KWARG: check},
    )


def make_mesh(shape: Sequence[int], axes: Sequence[str], **kwargs: Any):
    """``jax.make_mesh`` requesting Auto axis types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None and "axis_types" not in kwargs:
        kwargs["axis_types"] = (axis_type.Auto,) * len(axes)
    return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)
