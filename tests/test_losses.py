"""Loss/regularizer derivatives vs jax.grad, and margin decomposition."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev-only dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core import losses
from repro.data.block_csr import BlockCSR, local_margins
from repro.data.sparse import margins, scatter_grad
from repro.data.synthetic import make_sparse_classification


@pytest.mark.parametrize("loss", [losses.logistic, losses.squared_hinge])
def test_dvalue_matches_autodiff(loss):
    s = jnp.linspace(-4.0, 4.0, 41)
    for y in (-1.0, 1.0):
        got = loss.dvalue(s, jnp.full_like(s, y))
        want = jax.vmap(jax.grad(lambda si: loss.value(si, y)))(s)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("name,lam", [("l2", 0.1), ("l1", 0.05), ("none", 0.0)])
def test_reg_grad_matches_autodiff(name, lam):
    reg = losses.Regularizer(name, lam)
    w = jnp.asarray(np.random.default_rng(0).normal(size=32).astype(np.float32))
    w = jnp.where(jnp.abs(w) < 1e-3, 0.1, w)  # avoid the |.| kink
    got = reg.grad(w)
    want = jax.grad(reg.value)(w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_logistic_value_stable_at_extremes():
    s = jnp.asarray([-1e4, 1e4])
    y = jnp.asarray([1.0, 1.0])
    v = losses.logistic.value(s, y)
    assert np.all(np.isfinite(np.asarray(v)))
    assert float(v[1]) == pytest.approx(0.0, abs=1e-6)


@given(st.integers(min_value=1, max_value=6), st.integers(min_value=0, max_value=1000))
@settings(max_examples=25, deadline=None)
def test_margin_block_decomposition(q, seed):
    """w^T x == sum_l w^(l)T x^(l) for any contiguous partition — the identity
    the whole paper rests on (§4.2)."""
    data = make_sparse_classification(
        dim=257, num_instances=17, nnz_per_instance=9, seed=seed
    )
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=data.dim).astype(np.float32))
    full = margins(data, w)
    from repro.core.partition import balanced

    part = balanced(data.dim, q)
    block_data = BlockCSR.from_padded(data, part)
    total = jnp.zeros_like(full)
    for l in range(q):
        lo, hi = part.block(l)
        total = total + local_margins(*block_data.block(l), w[lo:hi])
    np.testing.assert_allclose(np.asarray(total), np.asarray(full), rtol=2e-4, atol=1e-5)


def test_scatter_grad_matches_dense():
    data = make_sparse_classification(
        dim=300, num_instances=20, nnz_per_instance=7, seed=4
    )
    coeffs = jnp.asarray(
        np.random.default_rng(1).normal(size=data.num_instances).astype(np.float32)
    )
    got = scatter_grad(data.indices, data.values, coeffs, data.dim)
    dense = data.to_dense()  # [d, N]
    want = dense @ np.asarray(coeffs)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-5)
