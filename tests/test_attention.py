"""Attention: chunked-flash path vs materialized oracle; decode vs train;
sliding window; softcap; qk-norm."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    AttnConfig,
    attention_decode,
    attention_ref,
    attention_train,
    init_attention,
    init_kv_cache,
)
from repro.sharding.specs import unsharded_ctx

CTX = unsharded_ctx()


def _setup(cfg, b=2, s=64, d=96, seed=0):
    key = jax.random.key(seed)
    kp, kx = jax.random.split(key)
    params = init_attention(kp, d, cfg, jnp.float32)
    x = jax.random.normal(kx, (b, s, d), jnp.float32) * 0.3
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    return params, x, positions


@pytest.mark.parametrize(
    "cfg",
    [
        AttnConfig(num_heads=4, num_kv_heads=4, head_dim=16),  # MHA
        AttnConfig(num_heads=8, num_kv_heads=2, head_dim=16),  # GQA
        AttnConfig(num_heads=4, num_kv_heads=1, head_dim=16),  # MQA
        AttnConfig(num_heads=4, num_kv_heads=2, head_dim=16, qk_norm=True),
        AttnConfig(num_heads=4, num_kv_heads=2, head_dim=16, window=16),
        AttnConfig(num_heads=4, num_kv_heads=2, head_dim=16, attn_softcap=20.0),
    ],
    ids=["mha", "gqa", "mqa", "qknorm", "window", "softcap"],
)
@pytest.mark.parametrize("kv_chunk", [16, 32, 64])
def test_flash_matches_ref(cfg, kv_chunk):
    params, x, positions = _setup(cfg)
    y_flash, _ = attention_train(params, x, positions, cfg, CTX, kv_chunk=kv_chunk)
    y_ref = attention_ref(params, x, positions, cfg, CTX)
    np.testing.assert_allclose(
        np.asarray(y_flash), np.asarray(y_ref), rtol=2e-4, atol=2e-5
    )


@pytest.mark.parametrize(
    "cfg",
    [
        AttnConfig(num_heads=4, num_kv_heads=2, head_dim=16),
        AttnConfig(num_heads=4, num_kv_heads=2, head_dim=16, window=8),
        AttnConfig(num_heads=4, num_kv_heads=2, head_dim=16, qk_norm=True, attn_softcap=30.0),
    ],
    ids=["plain", "window", "qknorm-softcap"],
)
def test_decode_matches_train(cfg):
    """Decoding token-by-token must reproduce the train-mode forward rows."""
    b, s, d = 2, 24, 64
    params, x, positions = _setup(cfg, b=b, s=s, d=d, seed=3)
    y_train, (k_full, v_full) = attention_train(params, x, positions, cfg, CTX, kv_chunk=8)

    cache = init_kv_cache(b, s, cfg, jnp.float32, CTX)
    ys = []
    for t in range(s):
        y_t, cache = attention_decode(
            params, x[:, t : t + 1, :], cache, jnp.asarray(t, jnp.int32), cfg, CTX
        )
        ys.append(y_t)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_dec), np.asarray(y_train), rtol=3e-4, atol=3e-5
    )
    # cache contents written by decode match the train-path k/v
    np.testing.assert_allclose(
        np.asarray(cache["k"]), np.asarray(k_full), rtol=1e-5, atol=1e-6
    )


def test_window_masks_distant_tokens():
    """With window=1 each token attends only to itself -> output at position
    i is independent of earlier tokens."""
    cfg = AttnConfig(num_heads=2, num_kv_heads=2, head_dim=8, window=1)
    params, x, positions = _setup(cfg, b=1, s=8, d=16, seed=1)
    y1, _ = attention_train(params, x, positions, cfg, CTX, kv_chunk=8)
    x2 = x.at[:, 0, :].set(123.0)  # perturb token 0
    y2, _ = attention_train(params, x2, positions, cfg, CTX, kv_chunk=8)
    np.testing.assert_allclose(
        np.asarray(y1[:, 1:]), np.asarray(y2[:, 1:]), rtol=1e-5, atol=1e-6
    )


def test_causality():
    """Future tokens must not influence earlier outputs."""
    cfg = AttnConfig(num_heads=2, num_kv_heads=1, head_dim=8)
    params, x, positions = _setup(cfg, b=1, s=16, d=16, seed=2)
    y1, _ = attention_train(params, x, positions, cfg, CTX, kv_chunk=4)
    x2 = x.at[:, -1, :].set(55.0)
    y2, _ = attention_train(params, x2, positions, cfg, CTX, kv_chunk=4)
    np.testing.assert_allclose(
        np.asarray(y1[:, :-1]), np.asarray(y2[:, :-1]), rtol=1e-5, atol=1e-6
    )


def test_softcap_bounds_scores():
    from repro.models.layers import softcap

    x = jnp.linspace(-1e4, 1e4, 101)
    y = softcap(x, 50.0)
    assert float(jnp.max(jnp.abs(y))) <= 50.0
    np.testing.assert_allclose(float(softcap(jnp.asarray(0.1), 50.0)), 0.1, atol=1e-4)


@pytest.mark.parametrize("window", [None, 16])
@pytest.mark.parametrize("q_chunk", [16, 32])
def test_blockwise_matches_ref(window, q_chunk):
    """§Perf causal block-skipping path is numerically exact."""
    import dataclasses
    cfg = AttnConfig(num_heads=4, num_kv_heads=2, head_dim=16, window=window,
                     q_chunk=q_chunk, kv_chunk=16)
    params, x, positions = _setup(cfg, b=2, s=64, d=96, seed=5)
    y_block, _ = attention_train(params, x, positions, cfg, CTX)
    cfg_plain = dataclasses.replace(cfg, q_chunk=None)
    y_ref = attention_ref(params, x, positions, cfg_plain, CTX)
    np.testing.assert_allclose(
        np.asarray(y_block), np.asarray(y_ref), rtol=3e-4, atol=3e-5
    )
