"""The out-of-core data path: streaming LibSVM -> per-worker BlockCSR.

The HARD CONTRACT under test: for any chunk size, worker count q, and
padding budget, the streamed build is bit-identical to the one-shot
``PaddedCSR -> BlockCSR.from_padded`` path — indices, values, nnz_col,
budgets, labels, all of it — so solver trajectories cannot depend on how
the data arrived.  Sections:

  * LibSVM text round-trip (writer -> parser, format edge cases)
  * label canonicalization conventions
  * chunked == one-shot bitwise (parametrized + hypothesis property)
  * on-disk slab cache: warm-hit equality, invalidation, atomicity keys
  * solve(): source= vs data= bit-parity end to end
  * datasets memory guard, deprecation shim
"""

import os
import warnings

import numpy as np
import pytest

from repro.core.partition import balanced
from repro.data import datasets
from repro.data.block_csr import BlockCSR
from repro.data.ingest_cache import get_or_build, load_block_csr
from repro.data.libsvm import (
    LibSVMFormatError,
    canonical_label_map,
    load_libsvm,
    scan_libsvm,
    write_libsvm,
)
from repro.data.pipeline import (
    ArraySource,
    LibSVMSource,
    SyntheticSource,
    as_source,
    is_source,
    source_labels,
    stream_block_csr,
    stream_block_slab,
    streamed_margins,
)
from repro.data.sparse import PaddedCSR
from repro.data.synthetic import make_sparse_classification

try:
    import hypothesis  # noqa: F401  (dev-only dep; see requirements-dev.txt)

    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False


def _data(dim=211, n=37, nnz=9, seed=0):
    return make_sparse_classification(
        dim=dim, num_instances=n, nnz_per_instance=nnz, seed=seed
    )


def _assert_blocks_equal(a: BlockCSR, b: BlockCSR) -> None:
    """Bitwise equality of every field the solvers can observe."""
    assert a.partition.bounds == b.partition.bounds
    assert a.nnz_budgets == b.nnz_budgets
    assert a.global_nnz_max() == b.global_nnz_max()
    np.testing.assert_array_equal(np.asarray(a.labels), np.asarray(b.labels))
    for l in range(a.num_blocks):
        np.testing.assert_array_equal(
            np.asarray(a.indices[l]), np.asarray(b.indices[l])
        )
        np.testing.assert_array_equal(
            np.asarray(a.values[l]), np.asarray(b.values[l])
        )
        np.testing.assert_array_equal(
            np.asarray(a.nnz_col[l]), np.asarray(b.nnz_col[l])
        )
        assert a.nnz_col[l].dtype == b.nnz_col[l].dtype


# ---------------------------------------------------------------------------
# LibSVM text round-trip
# ---------------------------------------------------------------------------


def test_write_load_round_trip_exact(tmp_path):
    data = _data(seed=3)
    path = str(tmp_path / "rt.libsvm")
    write_libsvm(path, data)
    back = load_libsvm(path, dim=data.dim)
    assert back.dim == data.dim
    assert back.num_instances == data.num_instances
    np.testing.assert_array_equal(
        np.asarray(back.labels), np.asarray(data.labels)
    )
    # stored entries round-trip exactly (repr() float32 text contract);
    # compare as (id, value) sets per row — padding layout may differ
    src_idx, src_val = np.asarray(data.indices), np.asarray(data.values)
    got_idx, got_val = np.asarray(back.indices), np.asarray(back.values)
    for i in range(data.num_instances):
        want = sorted(
            (int(j), float(v))
            for j, v in zip(src_idx[i], src_val[i])
            if v != 0.0
        )
        got = sorted(
            (int(j), float(v))
            for j, v in zip(got_idx[i], got_val[i])
            if v != 0.0
        )
        assert got == want, f"row {i}"


def test_parser_comments_blanks_empty_rows_and_qid(tmp_path):
    path = str(tmp_path / "edge.libsvm")
    with open(path, "w") as f:
        f.write("# leading comment\n")
        f.write("+1 1:0.5 3:1.25 # trailing comment\n")
        f.write("\n")  # blank line skipped
        f.write("-1\n")  # empty row: label only, no features
        f.write("-1 qid:7 2:2.0\n")  # qid token skipped
    data = load_libsvm(path)
    assert data.num_instances == 3
    assert data.dim == 3  # 1-based "3:" is 0-based id 2, so dim = 3
    np.testing.assert_array_equal(
        np.asarray(data.labels), np.asarray([1.0, -1.0, -1.0], np.float32)
    )
    dense = np.asarray(data.to_dense())  # (dim, n)
    np.testing.assert_allclose(dense[:, 0], [0.5, 0.0, 1.25])
    np.testing.assert_allclose(dense[:, 1], [0.0, 0.0, 0.0])
    np.testing.assert_allclose(dense[:, 2], [0.0, 2.0, 0.0])


def test_parser_duplicate_ids_preserved_in_file_order(tmp_path):
    """Duplicate feature ids stay as separate stored entries in file
    order — the scatter program-order contract (last write wins for
    gather, sum for scatter) must see them exactly as written."""
    path = str(tmp_path / "dup.libsvm")
    with open(path, "w") as f:
        f.write("+1 2:1.0 2:3.0 1:0.5\n")
    data = load_libsvm(path)
    idx, val = np.asarray(data.indices[0]), np.asarray(data.values[0])
    stored = [(int(i), float(v)) for i, v in zip(idx, val) if v != 0.0]
    assert stored == [(1, 1.0), (1, 3.0), (0, 0.5)]


def test_parser_rejects_malformed(tmp_path):
    for bad in ("+1 0:1.0\n", "+1 3:not_a_float\n", "+1 3\n"):
        path = str(tmp_path / "bad.libsvm")
        with open(path, "w") as f:
            f.write(bad)
        with pytest.raises(LibSVMFormatError):
            load_libsvm(path)


def test_scan_matches_load(tmp_path):
    data = _data(seed=11)
    path = str(tmp_path / "scan.libsvm")
    write_libsvm(path, data)
    stats = scan_libsvm(path)
    loaded = load_libsvm(path)
    assert stats.num_instances == loaded.num_instances
    assert stats.max_index + 1 == loaded.dim
    assert stats.nnz_max == loaded.nnz_max


def test_writer_emits_one_based_indices(tmp_path):
    data = PaddedCSR(
        indices=np.asarray([[0, 2, 0]], np.int32),
        values=np.asarray([[1.5, 2.5, 0.0]], np.float32),
        labels=np.asarray([1.0], np.float32),
        dim=3,
    )
    path = str(tmp_path / "one.libsvm")
    write_libsvm(path, data)
    with open(path) as f:
        line = f.read().strip()
    assert line == "1 1:1.5 3:2.5"


# ---------------------------------------------------------------------------
# label conventions
# ---------------------------------------------------------------------------


def test_labels_plus_minus_one_pass_through():
    m = canonical_label_map((-1.0, 1.0))
    np.testing.assert_array_equal(
        m(np.asarray([1.0, -1.0, 1.0])), [1.0, -1.0, 1.0]
    )


def test_labels_zero_one_maps_zero_to_minus_one():
    m = canonical_label_map((0.0, 1.0))
    np.testing.assert_array_equal(m(np.asarray([0.0, 1.0])), [-1.0, 1.0])


def test_labels_arbitrary_pair_sorted_high_is_positive():
    m = canonical_label_map((3.0, 7.0))
    np.testing.assert_array_equal(
        m(np.asarray([7.0, 3.0, 7.0])), [1.0, -1.0, 1.0]
    )


def test_labels_reject_multiclass_and_odd_singleton():
    with pytest.raises(ValueError, match="binary"):
        canonical_label_map((1.0, 2.0, 3.0))
    with pytest.raises(ValueError, match="single label"):
        canonical_label_map((5.0,))


def test_labels_single_standard_value_ok():
    m = canonical_label_map((1.0,))
    np.testing.assert_array_equal(m(np.asarray([1.0, 1.0])), [1.0, 1.0])


def test_labels_zero_one_from_file(tmp_path):
    path = str(tmp_path / "zo.libsvm")
    with open(path, "w") as f:
        f.write("0 1:1.0\n1 2:1.0\n0 1:2.0\n")
    data = load_libsvm(path)
    np.testing.assert_array_equal(
        np.asarray(data.labels), np.asarray([-1.0, 1.0, -1.0], np.float32)
    )


# ---------------------------------------------------------------------------
# chunked == one-shot, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunk_rows", [1, 3, 7, 16, 1000])
@pytest.mark.parametrize("q", [1, 3, 8])
def test_array_source_streamed_equals_from_padded(q, chunk_rows):
    data = _data(seed=q)
    part = balanced(data.dim, q)
    want = BlockCSR.from_padded(data, part)
    got = stream_block_csr(
        ArraySource(data), part, chunk_rows=chunk_rows
    )
    _assert_blocks_equal(got, want)


@pytest.mark.parametrize("lane_multiple", [1, 8])
@pytest.mark.parametrize("q", [1, 4])
def test_lane_multiple_budgets_match(q, lane_multiple):
    data = _data(seed=2)
    part = balanced(data.dim, q)
    want = BlockCSR.from_padded(data, part, lane_multiple=lane_multiple)
    got = stream_block_csr(
        ArraySource(data), part, chunk_rows=5, lane_multiple=lane_multiple
    )
    _assert_blocks_equal(got, want)


@pytest.mark.parametrize("chunk_rows", [1, 13, 4096])
def test_libsvm_source_streamed_equals_oneshot(tmp_path, chunk_rows):
    data = _data(seed=5)
    path = str(tmp_path / "eq.libsvm")
    write_libsvm(path, data)
    src = LibSVMSource(path, dim=data.dim)
    part = balanced(data.dim, 4)
    want = BlockCSR.from_padded(load_libsvm(path, dim=data.dim), part)
    got = stream_block_csr(src, part, chunk_rows=chunk_rows)
    _assert_blocks_equal(got, want)


def test_explicit_zeros_streamed_like_oneshot():
    """from_padded drops value==0 stored entries for q>1 and keeps rows
    verbatim for q==1; the streamed build must mirror both behaviors."""
    idx = np.asarray([[0, 5, 9], [3, 3, 0]], np.int32)
    val = np.asarray([[1.0, 0.0, 2.0], [4.0, 5.0, 0.0]], np.float32)
    data = PaddedCSR(
        indices=idx, values=val,
        labels=np.asarray([1.0, -1.0], np.float32), dim=10,
    )
    for q in (1, 2, 3):
        part = balanced(10, q)
        _assert_blocks_equal(
            stream_block_csr(ArraySource(data), part, chunk_rows=1),
            BlockCSR.from_padded(data, part),
        )


def test_single_slab_matches_full_build():
    data = _data(seed=9)
    part = balanced(data.dim, 5)
    full = stream_block_csr(ArraySource(data), part, chunk_rows=7)
    for l in range(5):
        idx, val, nnz_col = stream_block_slab(
            ArraySource(data), part, l, chunk_rows=7
        )
        np.testing.assert_array_equal(idx, np.asarray(full.indices[l]))
        np.testing.assert_array_equal(val, np.asarray(full.values[l]))
        np.testing.assert_array_equal(nnz_col, np.asarray(full.nnz_col[l]))
        assert idx.shape[1] == full.nnz_budgets[l]


def test_synthetic_source_matches_datasets_load():
    src = SyntheticSource.from_dataset("news20", seed=0)
    data = datasets.load("news20", seed=0)
    part = balanced(data.dim, 4)
    _assert_blocks_equal(
        stream_block_csr(src, part, chunk_rows=999),
        BlockCSR.from_padded(data, part),
    )


def test_streamed_margins_match_dense_oracle():
    data = _data(seed=13)
    rng = np.random.default_rng(0)
    w = rng.normal(size=data.dim).astype(np.float32)
    got = streamed_margins(ArraySource(data), w, chunk_rows=5)
    want = np.asarray(data.to_dense()).T @ w
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_source_labels_and_as_source_coercion(tmp_path):
    data = _data(seed=1)
    np.testing.assert_array_equal(
        source_labels(ArraySource(data), chunk_rows=4),
        np.asarray(data.labels),
    )
    assert is_source(as_source(data))
    path = str(tmp_path / "c.libsvm")
    write_libsvm(path, data)
    src = as_source(path)
    assert isinstance(src, LibSVMSource)
    assert as_source(src) is src
    with pytest.raises(TypeError):
        as_source(42)


@pytest.mark.parametrize("chunk_rows", [1, 5, 12, 36, 100])
def test_streamed_helpers_chunk_boundaries(chunk_rows):
    """Chunk size must be invisible: chunk_rows=1, an exact divisor of N
    (empty tail), and chunk_rows > N all give the same answers."""
    data = _data(n=36, seed=21)  # 36 rows: 12 and 36 divide exactly
    rng = np.random.default_rng(2)
    w = rng.normal(size=data.dim).astype(np.float32)
    np.testing.assert_array_equal(
        streamed_margins(ArraySource(data), w, chunk_rows=chunk_rows),
        streamed_margins(ArraySource(data), w, chunk_rows=36),
    )
    np.testing.assert_array_equal(
        source_labels(ArraySource(data), chunk_rows=chunk_rows),
        np.asarray(data.labels),
    )


def test_streamed_margins_multioutput_matches_per_column():
    """[d, k] weights stream in ONE pass, each column bit-identical to
    the k = 1 call with that column."""
    data = _data(seed=23)
    rng = np.random.default_rng(3)
    w = rng.normal(size=(data.dim, 3)).astype(np.float32)
    got = streamed_margins(ArraySource(data), w, chunk_rows=7)
    assert got.shape == (37, 3)
    for j in range(3):
        np.testing.assert_array_equal(
            got[:, j],
            streamed_margins(ArraySource(data), w[:, j], chunk_rows=7),
        )
    with pytest.raises(ValueError, match=r"\[d\] or \[d, k\]"):
        streamed_margins(ArraySource(data), w[None], chunk_rows=7)


def test_streamed_margins_empty_source():
    empty = PaddedCSR(
        indices=np.zeros((0, 4), np.int32),
        values=np.zeros((0, 4), np.float32),
        labels=np.zeros((0,), np.float32),
        dim=11,
    )
    w = np.ones(11, np.float32)
    assert streamed_margins(ArraySource(empty), w).shape == (0,)
    w2 = np.ones((11, 2), np.float32)
    assert streamed_margins(ArraySource(empty), w2).shape == (0, 2)
    assert source_labels(ArraySource(empty)).shape == (0,)


def test_libsvm_dim_override_too_small_is_one_line_error(tmp_path):
    data = _data(seed=4)
    path = str(tmp_path / "d.libsvm")
    write_libsvm(path, data)
    max_id = int(np.asarray(data.indices).max())
    with pytest.raises(ValueError, match=f"feature id {max_id}") as exc:
        LibSVMSource(path, dim=max_id).stats()
    assert "\n" not in str(exc.value)
    # and the boundary value (max id + 1) is accepted
    assert LibSVMSource(path, dim=max_id + 1).stats().dim == max_id + 1


if HAS_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @given(
        st.integers(min_value=1, max_value=9),
        st.integers(min_value=1, max_value=50),
        st.sampled_from([1, 8]),
        st.integers(min_value=0, max_value=10_000),
    )
    @settings(max_examples=25, deadline=None)
    def test_property_chunked_equals_oneshot(q, chunk_rows, lane, seed):
        data = _data(dim=97, n=23, nnz=6, seed=seed % 17)
        part = balanced(data.dim, q)
        want = BlockCSR.from_padded(data, part, lane_multiple=lane)
        got = stream_block_csr(
            ArraySource(data), part,
            chunk_rows=chunk_rows, lane_multiple=lane,
        )
        _assert_blocks_equal(got, want)


# ---------------------------------------------------------------------------
# on-disk slab cache
# ---------------------------------------------------------------------------


def test_cache_cold_then_warm_bitwise(tmp_path):
    data = _data(seed=21)
    path = str(tmp_path / "c.libsvm")
    write_libsvm(path, data)
    cache = str(tmp_path / "cache")
    part = balanced(data.dim, 3)

    cold = get_or_build(
        LibSVMSource(path, dim=data.dim), part, cache_dir=cache
    )
    assert cold.status == "cold"
    warm = get_or_build(
        LibSVMSource(path, dim=data.dim), part, cache_dir=cache
    )
    assert warm.status == "warm"
    assert warm.path == cold.path
    _assert_blocks_equal(warm.data, cold.data)
    _assert_blocks_equal(
        cold.data, BlockCSR.from_padded(load_libsvm(path, dim=data.dim), part)
    )


def test_cache_off_without_dir():
    data = _data(seed=22)
    out = get_or_build(ArraySource(data), balanced(data.dim, 2),
                       cache_dir=None)
    assert out.status == "off"
    assert out.path is None


def test_cache_invalidates_when_file_changes(tmp_path):
    data = _data(seed=23)
    path = str(tmp_path / "c.libsvm")
    write_libsvm(path, data)
    cache = str(tmp_path / "cache")
    part = balanced(data.dim, 2)
    first = get_or_build(LibSVMSource(path, dim=data.dim), part,
                         cache_dir=cache)
    # rewrite with different contents (flip one label) -> digest moves
    flipped = PaddedCSR(
        indices=data.indices, values=data.values,
        labels=np.asarray(-np.asarray(data.labels)), dim=data.dim,
    )
    write_libsvm(path, flipped)
    os.utime(path, ns=(1, 1))  # defeat any mtime-based memoization
    second = get_or_build(LibSVMSource(path, dim=data.dim), part,
                          cache_dir=cache)
    assert second.status == "cold"
    assert second.path != first.path
    np.testing.assert_array_equal(
        np.asarray(second.data.labels), -np.asarray(first.data.labels)
    )


def test_cache_keyed_on_partition_and_lane(tmp_path):
    data = _data(seed=24)
    cache = str(tmp_path / "cache")
    src = ArraySource(data)
    a = get_or_build(src, balanced(data.dim, 2), cache_dir=cache)
    b = get_or_build(src, balanced(data.dim, 3), cache_dir=cache)
    c = get_or_build(src, balanced(data.dim, 2), cache_dir=cache,
                     lane_multiple=8)
    assert len({a.path, b.path, c.path}) == 3
    assert all(o.status == "cold" for o in (a, b, c))


def test_cache_same_bytes_for_any_chunking(tmp_path):
    """chunk_rows is NOT part of the cache key: the build is bit-identical
    for any chunking, so a cache written at one chunk size warm-hits a
    read at another."""
    data = _data(seed=25)
    cache = str(tmp_path / "cache")
    src = ArraySource(data)
    part = balanced(data.dim, 4)
    cold = get_or_build(src, part, cache_dir=cache, chunk_rows=3)
    warm = get_or_build(src, part, cache_dir=cache, chunk_rows=1000)
    assert cold.status == "cold" and warm.status == "warm"
    _assert_blocks_equal(cold.data, warm.data)


def test_cache_load_rejects_version_and_digest_mismatch(tmp_path):
    import json

    data = _data(seed=26)
    cache = str(tmp_path / "cache")
    src = ArraySource(data)
    part = balanced(data.dim, 2)
    out = get_or_build(src, part, cache_dir=cache)
    manifest = os.path.join(out.path, "manifest.json")
    with open(manifest) as f:
        m = json.load(f)
    m["digest"] = "tampered"
    with open(manifest, "w") as f:
        json.dump(m, f)
    assert load_block_csr(cache, src.digest(), part) is None


def test_cache_slabs_compressed_and_trimmed(tmp_path):
    """v2 format: slabs are deflated npz with trailing all-padding lanes
    dropped on disk, and the load re-pads to the exact in-memory layout."""
    import zipfile

    data = _data(seed=27)
    cache = str(tmp_path / "cache")
    part = balanced(data.dim, 2)
    # lane_multiple=8 rounds every slab's lane count up, guaranteeing
    # trailing pure-padding lanes for the trim to remove
    cold = get_or_build(ArraySource(data), part, cache_dir=cache,
                        lane_multiple=8)
    trimmed_any = False
    for l in range(2):
        slab_path = os.path.join(cold.path, f"slab_{l:04d}.npz")
        with zipfile.ZipFile(slab_path) as zf:
            assert all(i.compress_type == zipfile.ZIP_DEFLATED
                       for i in zf.infolist())
        with np.load(slab_path) as slab:
            lanes = int(slab["lanes"])
            assert lanes == np.asarray(cold.data.indices[l]).shape[1]
            assert slab["indices"].shape == slab["values"].shape
            assert slab["indices"].shape[1] <= lanes
            trimmed_any |= slab["indices"].shape[1] < lanes
    assert trimmed_any  # the rounded-up lanes really were dropped on disk
    warm = get_or_build(ArraySource(data), part, cache_dir=cache,
                        lane_multiple=8)
    assert warm.status == "warm"
    _assert_blocks_equal(warm.data, cold.data)


def test_cache_old_format_version_is_rebuilt(tmp_path, monkeypatch):
    """A v1-era entry (uncompressed, no lane trim) is never trusted: the
    format version is part of the key, so the current code cold-rebuilds
    beside it — and even a same-key manifest claiming an old version is
    refused by the load."""
    import json

    from repro.data import ingest_cache

    data = _data(seed=28)
    cache = str(tmp_path / "cache")
    src = ArraySource(data)
    part = balanced(data.dim, 2)
    monkeypatch.setattr(ingest_cache, "CACHE_VERSION", 1)
    old = get_or_build(src, part, cache_dir=cache)
    assert old.status == "cold"
    monkeypatch.undo()
    new = get_or_build(src, part, cache_dir=cache)
    assert new.status == "cold" and new.path != old.path
    _assert_blocks_equal(new.data, old.data)
    manifest = os.path.join(new.path, "manifest.json")
    with open(manifest) as f:
        m = json.load(f)
    m["version"] = 1
    with open(manifest, "w") as f:
        json.dump(m, f)
    assert load_block_csr(cache, src.digest(), part) is None


# ---------------------------------------------------------------------------
# solve(): source= vs data= bit-parity end to end
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["serial", "fdsvrg", "fdsvrg_sim"])
def test_solve_source_bitwise_matches_in_memory(tmp_path, method):
    from repro.api import ExperimentSpec, solve

    data = _data(dim=157, n=29, nnz=7, seed=31)
    path = str(tmp_path / "s.libsvm")
    write_libsvm(path, data)
    common = dict(
        method=method, outer_iters=2, inner_steps=40,
        q=3 if method != "serial" else None,
    )
    r_mem = solve(ExperimentSpec(data=load_libsvm(path), **common))
    r_src = solve(ExperimentSpec(
        source=path, ingest_chunk_rows=11,
        data_cache_dir=str(tmp_path / "cache"), **common,
    ))
    np.testing.assert_array_equal(
        np.asarray(r_mem.w), np.asarray(r_src.w)
    )
    for a, b in zip(r_mem.history, r_src.history):
        assert a.objective == b.objective
        assert a.grad_norm == b.grad_norm
        assert a.comm_scalars == b.comm_scalars
        assert a.modeled_time_s == b.modeled_time_s


def test_solve_rejects_source_for_non_streaming_method(tmp_path):
    from repro.api import ExperimentSpec, solve

    data = _data(seed=32)
    path = str(tmp_path / "s.libsvm")
    write_libsvm(path, data)
    with pytest.raises(ValueError, match="stream"):
        solve(ExperimentSpec(source=path, method="dsvrg", outer_iters=1))


def test_spec_requires_exactly_one_input(tmp_path):
    from repro.api import ExperimentSpec

    data = _data(seed=33)
    with pytest.raises(ValueError):
        ExperimentSpec(method="fdsvrg")  # none of dataset/data/source
    with pytest.raises(ValueError):
        ExperimentSpec(method="fdsvrg", dataset="news20", source="x.libsvm")
    with pytest.raises(ValueError):
        ExperimentSpec(method="fdsvrg", data=data,
                       data_cache_dir="c")  # cache needs a source


def test_estimator_fits_from_path(tmp_path):
    from repro.api import FDSVRGClassifier

    data = _data(dim=157, n=40, nnz=7, seed=34)
    path = str(tmp_path / "e.libsvm")
    write_libsvm(path, data)
    clf = FDSVRGClassifier(
        method="fdsvrg", workers=3, outer_iters=2, inner_steps=40,
        data_cache_dir=str(tmp_path / "cache"),
    )
    clf.fit(path)
    assert clf.n_features_in_ == load_libsvm(path).dim
    margins = clf.decision_function(path)
    assert margins.shape == (data.num_instances,)
    assert 0.0 <= clf.score(path) <= 1.0
    with pytest.raises(ValueError, match="y"):
        clf.fit(path, y=np.asarray(data.labels))


# ---------------------------------------------------------------------------
# datasets memory guard + deprecation shim
# ---------------------------------------------------------------------------


def test_datasets_guard_blocks_oversized_materialize():
    with pytest.raises(MemoryError, match="SyntheticSource"):
        datasets.load("webspam", scaled=False)


def test_datasets_guard_respects_budget_override():
    spec = datasets.spec("webspam", scaled=False)
    assert datasets.materialize_bytes(spec) > (1 << 30)
    # scaled presets stay well under the default budget
    assert datasets.materialize_bytes(datasets.spec("webspam")) < (1 << 30)


def test_token_stream_shim_warns():
    import repro.data.pipeline as pipeline_mod

    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        cfg = pipeline_mod.PipelineConfig
    assert any(issubclass(w.category, DeprecationWarning) for w in rec)
    from repro.data.token_stream import PipelineConfig

    assert cfg is PipelineConfig
    with pytest.raises(AttributeError):
        pipeline_mod.does_not_exist
