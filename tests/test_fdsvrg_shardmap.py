"""Tests for the deployable shard_map FD-SVRG (core/fdsvrg_shardmap.py).

Single-device mesh in-process; an 8-device feature-sharded run executes in
a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8 (the
main test process must keep seeing exactly 1 device).

The shard_map path consumes the block-local stacked layout
(BlockCSR.stacked): [q, N, B] re-indexed rows sharded over the feature
axes, so workers never see global ids.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses
from repro.core.fdsvrg import SVRGConfig, run_fdsvrg, run_serial_svrg
from repro.core.fdsvrg_shardmap import (
    FDSVRGShardedConfig,
    input_shardings,
    make_outer_iteration,
    run_fdsvrg_sharded,
)
from repro.core.partition import balanced
from repro.data.block_csr import BlockCSR
from repro.data.synthetic import make_sparse_classification
from repro.dist import SimBackend


def _stacked(data, q):
    return BlockCSR.from_padded(data, balanced(data.dim, q)).stacked()


def test_shardmap_single_device_matches_serial():
    data = make_sparse_classification(
        dim=512, num_instances=64, nnz_per_instance=8, seed=0
    )
    eta, inner, outers, u, lam = 0.2, 16, 3, 2, 1e-3
    mesh = jax.make_mesh((1,), ("model",))
    cfg = FDSVRGShardedConfig(
        dim=data.dim, num_instances=data.num_instances, nnz_max=data.nnz_max,
        eta=eta, inner_steps=inner, batch_size=u, lam=lam,
    )
    step = make_outer_iteration(mesh, cfg, feature_axes=("model",))
    bidx, bval = _stacked(data, 1)

    rng = np.random.default_rng(7)
    w = jnp.zeros((data.dim,), jnp.float32)
    for t in range(outers):
        samples = rng.integers(0, data.num_instances, size=(inner, u)).astype(np.int32)
        w, gnorm = step(w, bidx, bval, data.labels, jnp.asarray(samples))
    assert np.all(np.isfinite(np.asarray(w)))
    assert float(gnorm) >= 0.0

    # same sample stream through the serial reference
    rng = np.random.default_rng(7)
    cfg_ref = SVRGConfig(eta=eta, inner_steps=inner, outer_iters=outers,
                         batch_size=u, seed=0)
    from repro.core.fdsvrg import _full_grad_blocks, _inner_epoch

    block = BlockCSR.from_padded(data, balanced(data.dim, 1))
    w_ref = jnp.zeros((data.dim,), jnp.float32)
    for t in range(outers):
        z, s0 = _full_grad_blocks(
            block.indices, block.values, data.labels, w_ref,
            "logistic", block.block_dims, False,
        )
        samples = rng.integers(0, data.num_instances, size=(inner, u)).astype(np.int32)
        w_ref = _inner_epoch(
            block.indices, block.values, data.labels, w_ref, z, s0,
            jnp.asarray(samples), eta, jnp.ones(inner, jnp.float32),
            "logistic", "l2", lam, block.block_dims, False,
        )
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref), rtol=2e-4, atol=1e-6)


def test_shardmap_butterfly_mode_single_device():
    data = make_sparse_classification(
        dim=256, num_instances=32, nnz_per_instance=8, seed=1
    )
    mesh = jax.make_mesh((1,), ("model",))
    cfg = FDSVRGShardedConfig(
        dim=data.dim, num_instances=data.num_instances, nnz_max=data.nnz_max,
        eta=0.1, inner_steps=8, batch_size=1, tree_mode="butterfly",
    )
    step = make_outer_iteration(mesh, cfg, feature_axes=("model",))
    bidx, bval = _stacked(data, 1)
    samples = np.zeros((8, 1), dtype=np.int32)
    w, gnorm = step(
        jnp.zeros((data.dim,), jnp.float32),
        bidx, bval, data.labels, jnp.asarray(samples),
    )
    assert np.all(np.isfinite(np.asarray(w)))


def test_shardmap_use_kernels_bit_identical_single_device():
    """The fused-kernel worker (interpret mode) must produce bit-identical
    iterates to the jnp reference worker — same mesh, same samples."""
    data = make_sparse_classification(
        dim=384, num_instances=48, nnz_per_instance=8, seed=3
    )
    mesh = jax.make_mesh((1,), ("model",))
    samples = np.random.default_rng(5).integers(
        0, data.num_instances, size=(12, 2)
    ).astype(np.int32)
    bidx, bval = _stacked(data, 1)
    results = {}
    for use_kernels in (False, True):
        cfg = FDSVRGShardedConfig(
            dim=data.dim, num_instances=data.num_instances, nnz_max=data.nnz_max,
            eta=0.2, inner_steps=12, batch_size=2, lam=1e-3,
            use_kernels=use_kernels,
        )
        step = make_outer_iteration(mesh, cfg, feature_axes=("model",))
        w = jnp.zeros((data.dim,), jnp.float32)
        for _ in range(2):
            w, gnorm = step(w, bidx, bval, data.labels, jnp.asarray(samples))
        results[use_kernels] = np.asarray(w)
    np.testing.assert_array_equal(results[True], results[False])


def test_sharded_driver_metering_matches_simulation_driver():
    """run_fdsvrg_sharded must charge the same §4.5 closed forms —
    compute terms included — as run_fdsvrg (both consume repro.dist.COSTS
    now), so the two drivers' meters and modeled times are bit-consistent
    for identical shapes, record by record."""
    from repro.dist import ShardMapBackend

    data = make_sparse_classification(
        dim=512, num_instances=64, nnz_per_instance=8, seed=0
    )
    inner, u, outers = 8, 4, 2
    mesh = jax.make_mesh((1,), ("model",))
    cfg = FDSVRGShardedConfig(
        dim=data.dim, num_instances=data.num_instances, nnz_max=data.nnz_max,
        eta=0.1, inner_steps=inner, batch_size=u, lam=1e-3,
    )
    backend = ShardMapBackend(mesh=mesh, feature_axes=("model",))
    res = run_fdsvrg_sharded(
        data, mesh, cfg, feature_axes=("model",), outer_iters=outers, seed=0,
        backend=backend,
    )
    assert res.meter is backend.meter
    assert backend.modeled_time_s > 0.0

    sim_backend = SimBackend(backend.q)
    sim_cfg = SVRGConfig(eta=0.1, inner_steps=inner, outer_iters=outers,
                         batch_size=u, seed=0)
    sim = run_fdsvrg(data, balanced(data.dim, backend.q), losses.logistic,
                     losses.l2(1e-3), sim_cfg, backend=sim_backend)
    assert backend.meter.total_scalars == sim_backend.meter.total_scalars
    np.testing.assert_allclose(
        backend.modeled_time_s, sim_backend.modeled_time_s, rtol=1e-12
    )
    # the two drivers run the same harness: record-by-record schema parity
    for h_sh, h_sim in zip(res.history, sim.history):
        assert h_sh.outer == h_sim.outer
        assert h_sh.comm_scalars == h_sim.comm_scalars
        assert h_sh.comm_rounds == h_sim.comm_rounds
        np.testing.assert_allclose(h_sh.modeled_time_s, h_sim.modeled_time_s,
                                   rtol=1e-12)


def test_sharded_driver_matches_sim_driver_iterates_and_objective():
    """Same seed => same sample stream through the shared harness: the
    q=1 shard_map driver and run_fdsvrg produce matching iterates and
    per-outer objectives (the sharded path finally reports a real
    RunResult with objectives, like everyone else)."""
    data = make_sparse_classification(
        dim=384, num_instances=48, nnz_per_instance=8, seed=1
    )
    inner, u, outers = 10, 2, 2
    mesh = jax.make_mesh((1,), ("model",))
    cfg = FDSVRGShardedConfig(
        dim=data.dim, num_instances=data.num_instances, nnz_max=data.nnz_max,
        eta=0.2, inner_steps=inner, batch_size=u, lam=1e-3,
    )
    res = run_fdsvrg_sharded(
        data, mesh, cfg, feature_axes=("model",), outer_iters=outers, seed=7
    )
    sim_cfg = SVRGConfig(eta=0.2, inner_steps=inner, outer_iters=outers,
                         batch_size=u, seed=7)
    sim = run_fdsvrg(data, balanced(data.dim, 1), losses.logistic,
                     losses.l2(1e-3), sim_cfg)
    np.testing.assert_allclose(
        np.asarray(res.w), np.asarray(sim.w), rtol=2e-4, atol=2e-6
    )
    for h_sh, h_sim in zip(res.history, sim.history):
        np.testing.assert_allclose(h_sh.objective, h_sim.objective, rtol=1e-5)
        np.testing.assert_allclose(h_sh.grad_norm, h_sim.grad_norm, rtol=1e-3,
                                   atol=1e-6)


def test_sharded_driver_gnorm_is_post_epoch_residual():
    """Every record's grad_norm must be the optimality residual at that
    outer's post-epoch iterate (the fused step fn's own gnorm output is
    the snapshot residual — one epoch stale for reporting purposes)."""
    from repro.core.fdsvrg import full_gradient, optimality_norm

    data = make_sparse_classification(
        dim=256, num_instances=32, nnz_per_instance=8, seed=2
    )
    mesh = jax.make_mesh((1,), ("model",))
    for reg_name, lam, lam2 in (("l2", 1e-3, 0.0), ("l1", 2e-3, 0.0)):
        cfg = FDSVRGShardedConfig(
            dim=data.dim, num_instances=data.num_instances, nnz_max=data.nnz_max,
            eta=0.2, inner_steps=8, batch_size=2,
            reg_name=reg_name, lam=lam, lam2=lam2,
        )
        res = run_fdsvrg_sharded(
            data, mesh, cfg, feature_axes=("model",), outer_iters=2, seed=0
        )
        gd, _ = full_gradient(data, res.w, losses.logistic)
        want = optimality_norm(
            gd, res.w, losses.Regularizer(reg_name, lam, lam2), cfg.eta
        )
        np.testing.assert_allclose(res.history[-1].grad_norm, want, rtol=1e-4)


def test_sharded_driver_preserves_float64():
    """Satellite regression: the sharded driver used to hardcode
    jnp.float32 for the initial iterate, silently demoting float64 runs —
    it must initialize from the data's dtype (same bug class PR 3 fixed
    in _run_async)."""
    from repro.data.sparse import PaddedCSR

    data32 = make_sparse_classification(
        dim=128, num_instances=16, nnz_per_instance=4, seed=0
    )
    enable_x64 = getattr(jax, "enable_x64", None) or jax.experimental.enable_x64
    with enable_x64(True):
        data = PaddedCSR(
            indices=jnp.asarray(np.asarray(data32.indices)),
            values=jnp.asarray(np.asarray(data32.values), dtype=jnp.float64),
            labels=jnp.asarray(np.asarray(data32.labels), dtype=jnp.float64),
            dim=data32.dim,
        )
        mesh = jax.make_mesh((1,), ("model",))
        cfg = FDSVRGShardedConfig(
            dim=data.dim, num_instances=data.num_instances,
            nnz_max=data.nnz_max, eta=0.2, inner_steps=4, batch_size=2,
            lam=1e-3,
        )
        res = run_fdsvrg_sharded(
            data, mesh, cfg, feature_axes=("model",), outer_iters=1, seed=0
        )
        assert res.w.dtype == jnp.float64
        assert np.all(np.isfinite(np.asarray(res.w)))
        assert np.isfinite(res.history[-1].objective)


def test_input_shardings_match_step_arity():
    mesh = jax.make_mesh((1,), ("model",))
    shardings = input_shardings(mesh, feature_axes=("model",))
    assert len(shardings) == 5  # w, block_indices, block_values, labels, samples


_SUBPROCESS_PROG = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np, jax.numpy as jnp
    from repro.core import losses
    from repro.core.fdsvrg import SVRGConfig, run_serial_svrg
    from repro.core.fdsvrg_shardmap import FDSVRGShardedConfig, make_outer_iteration
    from repro.core.partition import balanced
    from repro.data.block_csr import BlockCSR
    from repro.data.synthetic import make_sparse_classification

    assert jax.device_count() == 8
    data = make_sparse_classification(dim=512, num_instances=48, nnz_per_instance=8, seed=0)
    eta, inner, outers, u, lam = 0.2, 12, 2, 2, 1e-3
    mesh = jax.make_mesh((8,), ("model",))
    cfg = FDSVRGShardedConfig(dim=data.dim, num_instances=data.num_instances,
                              nnz_max=data.nnz_max, eta=eta, inner_steps=inner,
                              batch_size=u, lam=lam, tree_mode="{mode}")
    step = make_outer_iteration(mesh, cfg, feature_axes=("model",))
    bidx, bval = BlockCSR.from_padded(data, balanced(data.dim, 8)).stacked()
    rng = np.random.default_rng(3)
    w = jnp.zeros((data.dim,), jnp.float32)
    all_samples = []
    for t in range(outers):
        s = rng.integers(0, data.num_instances, size=(inner, u)).astype(np.int32)
        all_samples.append(s)
        w, gnorm = step(w, bidx, bval, data.labels, jnp.asarray(s))

    # serial reference with the same sample stream
    from repro.core.fdsvrg import _full_grad_blocks, _inner_epoch
    block = BlockCSR.from_padded(data, balanced(data.dim, 1))
    w_ref = jnp.zeros((data.dim,), jnp.float32)
    for t in range(outers):
        z, s0 = _full_grad_blocks(block.indices, block.values, data.labels, w_ref,
                                  "logistic", block.block_dims, False)
        w_ref = _inner_epoch(block.indices, block.values, data.labels, w_ref, z, s0,
                             jnp.asarray(all_samples[t]), eta,
                             jnp.ones(inner, jnp.float32),
                             "logistic", "l2", lam, block.block_dims, False)
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref), rtol=3e-4, atol=3e-6)
    print("OK-8DEV")
    """
)


@pytest.mark.parametrize("mode", ["psum", "butterfly"])
def test_shardmap_eight_devices_subprocess(mode):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_PROG.replace("{mode}", mode)],
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OK-8DEV" in proc.stdout
