"""Tests for the deployable shard_map FD-SVRG (core/fdsvrg_shardmap.py).

Single-device mesh in-process; an 8-device feature-sharded run executes in
a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8 (the
main test process must keep seeing exactly 1 device).
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses
from repro.core.fdsvrg import SVRGConfig, run_serial_svrg
from repro.core.fdsvrg_shardmap import (
    FDSVRGShardedConfig,
    input_shardings,
    make_outer_iteration,
)
from repro.data.synthetic import make_sparse_classification


def _reference_run(data, eta, inner, outers, u, lam, seed):
    cfg = SVRGConfig(eta=eta, inner_steps=inner, outer_iters=outers,
                     batch_size=u, seed=seed)
    return run_serial_svrg(data, losses.logistic, losses.l2(lam), cfg)


def test_shardmap_single_device_matches_serial():
    data = make_sparse_classification(
        dim=512, num_instances=64, nnz_per_instance=8, seed=0
    )
    eta, inner, outers, u, lam = 0.2, 16, 3, 2, 1e-3
    mesh = jax.make_mesh((1,), ("model",))
    cfg = FDSVRGShardedConfig(
        dim=data.dim, num_instances=data.num_instances, nnz_max=data.nnz_max,
        eta=eta, inner_steps=inner, batch_size=u, lam=lam,
    )
    step = make_outer_iteration(mesh, cfg, feature_axes=("model",))

    rng = np.random.default_rng(7)
    w = jnp.zeros((data.dim,), jnp.float32)
    for t in range(outers):
        samples = rng.integers(0, data.num_instances, size=(inner, u)).astype(np.int32)
        w, gnorm = step(w, data.indices, data.values, data.labels,
                        jnp.asarray(samples))
    assert np.all(np.isfinite(np.asarray(w)))
    assert float(gnorm) >= 0.0

    # same sample stream through the serial reference
    rng = np.random.default_rng(7)
    w_ref = jnp.zeros((data.dim,), jnp.float32)
    from repro.core.fdsvrg import _inner_epoch, full_gradient

    for t in range(outers):
        z, s0 = full_gradient(data, w_ref, losses.logistic)
        samples = rng.integers(0, data.num_instances, size=(inner, u)).astype(np.int32)
        w_ref = _inner_epoch(
            data.indices, data.values, data.labels, w_ref, z, s0,
            jnp.asarray(samples), eta, lam,
            jnp.ones(inner, jnp.float32), "logistic", "l2", 1, None,
        )
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref), rtol=2e-4, atol=1e-6)


def test_shardmap_butterfly_mode_single_device():
    data = make_sparse_classification(
        dim=256, num_instances=32, nnz_per_instance=8, seed=1
    )
    mesh = jax.make_mesh((1,), ("model",))
    cfg = FDSVRGShardedConfig(
        dim=data.dim, num_instances=data.num_instances, nnz_max=data.nnz_max,
        eta=0.1, inner_steps=8, batch_size=1, tree_mode="butterfly",
    )
    step = make_outer_iteration(mesh, cfg, feature_axes=("model",))
    samples = np.zeros((8, 1), dtype=np.int32)
    w, gnorm = step(
        jnp.zeros((data.dim,), jnp.float32),
        data.indices, data.values, data.labels, jnp.asarray(samples),
    )
    assert np.all(np.isfinite(np.asarray(w)))


_SUBPROCESS_PROG = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np, jax.numpy as jnp
    from repro.core import losses
    from repro.core.fdsvrg import SVRGConfig, run_serial_svrg
    from repro.core.fdsvrg_shardmap import FDSVRGShardedConfig, make_outer_iteration
    from repro.data.synthetic import make_sparse_classification

    assert jax.device_count() == 8
    data = make_sparse_classification(dim=512, num_instances=48, nnz_per_instance=8, seed=0)
    eta, inner, outers, u, lam = 0.2, 12, 2, 2, 1e-3
    mesh = jax.make_mesh((8,), ("model",))
    cfg = FDSVRGShardedConfig(dim=data.dim, num_instances=data.num_instances,
                              nnz_max=data.nnz_max, eta=eta, inner_steps=inner,
                              batch_size=u, lam=lam, tree_mode="{mode}")
    step = make_outer_iteration(mesh, cfg, feature_axes=("model",))
    rng = np.random.default_rng(3)
    w = jnp.zeros((data.dim,), jnp.float32)
    all_samples = []
    for t in range(outers):
        s = rng.integers(0, data.num_instances, size=(inner, u)).astype(np.int32)
        all_samples.append(s)
        w, gnorm = step(w, data.indices, data.values, data.labels, jnp.asarray(s))

    # serial reference with the same sample stream
    from repro.core.fdsvrg import _inner_epoch, full_gradient
    w_ref = jnp.zeros((data.dim,), jnp.float32)
    for t in range(outers):
        z, s0 = full_gradient(data, w_ref, losses.logistic)
        w_ref = _inner_epoch(data.indices, data.values, data.labels, w_ref, z, s0,
                             jnp.asarray(all_samples[t]), eta, lam,
                             jnp.ones(inner, jnp.float32), "logistic", "l2", 1, None)
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref), rtol=3e-4, atol=3e-6)
    print("OK-8DEV")
    """
)


@pytest.mark.parametrize("mode", ["psum", "butterfly"])
def test_shardmap_eight_devices_subprocess(mode):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_PROG.replace("{mode}", mode)],
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OK-8DEV" in proc.stdout
