"""The public API (repro.api): registry parity, capability validation,
the shared BlockCSR cache, and the estimator.

Load-bearing properties:

1. **Registry parity** — for every registered method, ``solve(spec)`` is
   bit-identical (iterates, objective history, comm scalars, modeled
   time) to the direct driver call it wraps.  The front door adds
   dispatch, never numerics.
2. **Shim parity** — ``benchmarks.common.run_method`` (now a thin shim
   over ``solve``) reproduces the pre-redesign dispatcher bit-for-bit at
   the benchmark defaults, including the per-method ``"paper"`` rules
   (ETA table, trajectory mini-batch, ``m = N/u`` and its cap) that
   moved into the registry.
3. **Loud capability mismatches** — ``use_kernels`` on a driver without
   a kernel path, Option II on a driver that ignores it, a mesh on a
   non-shard_map method: all raise instead of silently running something
   other than what the caller asked for.
4. The bounded BlockCSR cache semantics (per-sweep scope + LRU), ported
   here from the benchmarks module along with the cache itself.
5. ``FDSVRGClassifier`` fit/partial_fit(warm start)/predict/score.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    BLOCK_CACHE,
    BlockCache,
    ExperimentSpec,
    FDSVRGClassifier,
    METHODS,
    PAPER_MAX_INNER,
    as_padded_csr,
    method_info,
    solve,
)
from repro.api.registry import _resolve
from repro.core import baselines, losses
from repro.core.driver import resolve_init_w
from repro.core.fdsvrg import (
    SVRGConfig,
    fdsvrg_worker_simulation,
    run_fdsvrg,
    run_serial_svrg,
)
from repro.core.fdsvrg_shardmap import FDSVRGShardedConfig, run_fdsvrg_sharded
from repro.core.partition import balanced
from repro.data.sparse import PaddedCSR
from repro.data.synthetic import make_sparse_classification

LOSS = losses.logistic
REG = losses.l2(1e-3)


@pytest.fixture(scope="module")
def data():
    # n divisible by the q and u used below so the paper-M rules are exact.
    return make_sparse_classification(
        dim=512, num_instances=48, nnz_per_instance=8, seed=2
    )


def _assert_same_run(a, b):
    """Bit-identity across the full RunResult surface."""
    np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))
    assert [h.objective for h in a.history] == [h.objective for h in b.history]
    assert [h.grad_norm for h in a.history] == [h.grad_norm for h in b.history]
    assert a.meter.total_scalars == b.meter.total_scalars
    assert a.meter.total_rounds == b.meter.total_rounds
    assert [h.modeled_time_s for h in a.history] == [
        h.modeled_time_s for h in b.history
    ]


# ---------------------------------------------------------------------------
# 1. registry parity: solve(spec) == the direct driver call, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", sorted(METHODS))
def test_solve_matches_direct_driver(data, method):
    q = 1 if method == "fdsvrg_sharded" else 2
    eta, inner, u, outers = 0.3, 8, 2, 2
    cfg = SVRGConfig(eta=eta, inner_steps=inner, outer_iters=outers,
                     batch_size=u, seed=0)
    mesh = None
    if method == "serial":
        direct = run_serial_svrg(data, LOSS, REG, cfg)
    elif method == "fdsvrg":
        direct = run_fdsvrg(data, balanced(data.dim, q), LOSS, REG, cfg)
    elif method == "fdsvrg_sim":
        direct = fdsvrg_worker_simulation(
            data, balanced(data.dim, q), LOSS, REG, cfg
        )
    elif method == "fdsvrg_sharded":
        mesh = jax.make_mesh((1,), ("model",))
        shcfg = FDSVRGShardedConfig(
            dim=data.dim, num_instances=data.num_instances,
            nnz_max=data.nnz_max, eta=eta, inner_steps=inner, batch_size=u,
            lam=REG.lam,
        )
        direct = run_fdsvrg_sharded(
            data, mesh, shcfg, feature_axes=("model",), outer_iters=outers,
            seed=0,
        )
    elif method in ("fd_saga", "fd_bcd"):
        from repro.data.block_csr import BlockCSR
        from repro.dist import SimBackend
        from repro.optim.update_rules import (
            BCDRule, SAGARule, make_context, run_with_rule,
        )

        rule = SAGARule() if method == "fd_saga" else BCDRule()
        direct = run_with_rule(rule, make_context(
            BlockCSR.from_padded(data, balanced(data.dim, q)),
            LOSS, REG, cfg, backend=SimBackend(q, None),
        ))
    else:
        runner = {
            "dsvrg": baselines.run_dsvrg,
            "synsvrg": baselines.run_syn_svrg,
            "asysvrg": baselines.run_asy_svrg,
            "pslite_sgd": baselines.run_pslite_sgd,
        }[method]
        direct = runner(data, q, LOSS, REG, cfg)

    via_api = solve(ExperimentSpec(
        method=method, data=data, reg=REG,
        q=None if method == "fdsvrg_sharded" else q,
        eta=eta, batch_size=u, inner_steps=inner, outer_iters=outers,
        mesh=mesh,
    ))
    _assert_same_run(via_api, direct)


# ---------------------------------------------------------------------------
# 2. shim parity: run_method == the pre-redesign dispatcher, bit for bit
# ---------------------------------------------------------------------------


def _legacy_run_method(method, data, q, lam, *, reg=None, eta=None,
                       outer_iters=6, batch_size=None, seed=0,
                       use_kernels=False):
    """The dispatcher exactly as benchmarks/common.py shipped it before
    the registry existed (PR 4 state), minus the lam/reg mismatch error.
    The constants are intentionally inlined, NOT imported from the
    registry — this is the independent oracle the shim is pinned to."""
    from repro.data.block_csr import BlockCSR
    from benchmarks.common import CLUSTER

    ETA = {"fdsvrg": 2.0, "serial": 2.0, "dsvrg": 1.0,
           "synsvrg": 2.0, "asysvrg": 0.5, "pslite_sgd": 0.3}
    U_TRAJ, MAX_INNER = 8, 12_000

    if reg is None:
        reg = losses.l2(lam)
    n = data.num_instances
    eta = ETA[method] if eta is None else eta
    if method == "fdsvrg":
        u = U_TRAJ if batch_size is None else batch_size
        m = min(max(1, n // u), MAX_INNER)
        cfg = SVRGConfig(eta=eta, inner_steps=m,
                         outer_iters=outer_iters, batch_size=u, seed=seed)
        return run_fdsvrg(data, balanced(data.dim, q), LOSS, reg, cfg,
                          CLUSTER, use_kernels=use_kernels,
                          block_data=BlockCSR.from_padded(
                              data, balanced(data.dim, q)))
    if method == "serial":
        cfg = SVRGConfig(eta=eta, inner_steps=min(n, MAX_INNER),
                         outer_iters=outer_iters, seed=seed)
        return run_serial_svrg(data, LOSS, reg, cfg, use_kernels=use_kernels)
    if method in ("dsvrg", "synsvrg"):
        cfg = SVRGConfig(eta=eta, inner_steps=min(max(1, n // q), MAX_INNER),
                         outer_iters=outer_iters, seed=seed)
        runner = {"dsvrg": baselines.run_dsvrg,
                  "synsvrg": baselines.run_syn_svrg}[method]
        return runner(data, q, LOSS, reg, cfg, CLUSTER)
    cfg = SVRGConfig(eta=eta, inner_steps=min(n, MAX_INNER),
                     outer_iters=outer_iters, seed=seed)
    runner = {"asysvrg": baselines.run_asy_svrg,
              "pslite_sgd": baselines.run_pslite_sgd}[method]
    return runner(data, q, LOSS, reg, cfg, CLUSTER)


@pytest.mark.parametrize(
    "method", ["fdsvrg", "serial", "dsvrg", "synsvrg", "asysvrg", "pslite_sgd"]
)
def test_run_method_shim_matches_pre_redesign(data, method):
    from benchmarks.common import run_method

    legacy = _legacy_run_method(method, data, 4, 1e-3, outer_iters=2)
    shim = run_method(method, data, 4, 1e-3, outer_iters=2)
    _assert_same_run(shim, legacy)


def test_run_method_shim_honors_explicit_eta_and_batch(data):
    from benchmarks.common import run_method

    legacy = _legacy_run_method("fdsvrg", data, 2, 1e-3, eta=0.7,
                                batch_size=4, outer_iters=2)
    shim = run_method("fdsvrg", data, 2, 1e-3, eta=0.7, batch_size=4,
                      outer_iters=2)
    _assert_same_run(shim, legacy)


def test_run_method_shim_honors_batch_for_fd_family(data):
    """fdsvrg_sim is newly reachable through the shim; an explicit
    batch_size must reach it (not silently fall back to the paper u)."""
    from benchmarks.common import CLUSTER, run_method

    shim = run_method("fdsvrg_sim", data, 2, 1e-3, batch_size=4,
                      outer_iters=2)
    via_api = solve(ExperimentSpec(
        method="fdsvrg_sim", data=data, q=2, reg=losses.l2(1e-3),
        batch_size=4, outer_iters=2, cluster=CLUSTER,
    ))
    # if the shim dropped batch_size to the paper u, the sample stream
    # (and therefore the iterates) could not match the explicit-u spec
    _assert_same_run(shim, via_api)
    paper = run_method("fdsvrg_sim", data, 2, 1e-3, outer_iters=2)
    assert not np.array_equal(np.asarray(shim.w), np.asarray(paper.w))


def test_run_method_reg_override_no_mismatch_error(data):
    """The lam/reg dual-argument footgun is dead: an override regularizer
    IS the regularizer, the headline lambda derives from it, and a
    (previously fatal) disagreeing lam is simply not consulted."""
    from benchmarks.common import run_method

    reg = losses.l1(5e-4)
    res = run_method("fdsvrg", data, 2, 1e-3, reg=reg, outer_iters=2)
    legacy = _legacy_run_method("fdsvrg", data, 2, None, reg=reg,
                                outer_iters=2)
    _assert_same_run(res, legacy)


# ---------------------------------------------------------------------------
# 3. validation: capability mismatches fail loudly
# ---------------------------------------------------------------------------


def test_use_kernels_rejected_for_non_kernel_methods(data):
    for method in ("dsvrg", "synsvrg", "asysvrg", "pslite_sgd",
                   "fdsvrg_sharded"):
        assert not method_info(method).supports_kernels
        with pytest.raises(ValueError, match="use_kernels"):
            solve(ExperimentSpec(method=method, data=data, use_kernels=True))


def test_option_ii_rejected_where_ignored(data):
    for method in ("asysvrg", "pslite_sgd", "fdsvrg_sharded"):
        with pytest.raises(ValueError, match="Option I/II"):
            solve(ExperimentSpec(method=method, data=data, option="II"))


def test_mesh_rejected_for_non_shardmap_methods(data):
    mesh = jax.make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="mesh"):
        solve(ExperimentSpec(method="serial", data=data, mesh=mesh))


def test_tree_mode_rejected_for_non_shardmap_methods(data):
    with pytest.raises(ValueError, match="tree_mode"):
        solve(ExperimentSpec(method="dsvrg", data=data,
                             tree_mode="butterfly"))


def test_mesh_q_mismatch_rejected(data):
    with pytest.raises(ValueError, match="mesh"):
        solve(ExperimentSpec(method="fdsvrg_sharded", data=data, q=8))


def test_unknown_method_lists_registry(data):
    with pytest.raises(ValueError, match="registered methods"):
        solve(ExperimentSpec(method="sgd", data=data))


def test_spec_structural_validation(data):
    with pytest.raises(ValueError, match="exactly one"):
        ExperimentSpec(method="serial")
    with pytest.raises(ValueError, match="exactly one"):
        ExperimentSpec(method="serial", dataset="news20", data=data)
    with pytest.raises(TypeError, match="ONE regularizer"):
        ExperimentSpec(method="serial", data=data, reg=1e-4)
    with pytest.raises(ValueError, match="option"):
        ExperimentSpec(method="serial", data=data, option="III")
    with pytest.raises(ValueError, match="eta"):
        ExperimentSpec(method="serial", data=data, eta="auto")
    with pytest.raises(ValueError, match="batch_size"):
        ExperimentSpec(method="serial", data=data, batch_size=0)
    with pytest.raises(ValueError, match="inner_steps"):
        ExperimentSpec(method="serial", data=data, inner_steps=0)
    with pytest.raises(ValueError, match="outer_iters"):
        ExperimentSpec(method="serial", data=data, outer_iters=0)
    with pytest.raises(ValueError, match="eta"):
        ExperimentSpec(method="serial", data=data, eta=0.0)


def test_paper_rules_resolve_per_method():
    """The m = N/u and m = N/q rules (and the inner cap) live in the
    registry, per method, exactly as the benchmarks ran them."""
    n = 100
    r = _resolve(ExperimentSpec(method="fdsvrg", dataset="news20"),
                 method_info("fdsvrg"), n, q=4)
    assert (r.eta, r.batch_size, r.inner_steps) == (2.0, 8, 100 // 8)
    r = _resolve(ExperimentSpec(method="serial", dataset="news20"),
                 method_info("serial"), n, q=4)
    assert (r.eta, r.batch_size, r.inner_steps) == (2.0, 1, 100)
    r = _resolve(ExperimentSpec(method="dsvrg", dataset="news20"),
                 method_info("dsvrg"), n, q=4)
    assert (r.eta, r.batch_size, r.inner_steps) == (1.0, 1, 25)
    r = _resolve(ExperimentSpec(method="pslite_sgd", dataset="news20"),
                 method_info("pslite_sgd"), 10**6, q=4)
    assert r.inner_steps == PAPER_MAX_INNER  # the cap


def test_capability_matrix_covers_every_method():
    from repro.api import capability_matrix

    rows = {r["method"] for r in capability_matrix()}
    assert rows == set(METHODS)


# ---------------------------------------------------------------------------
# 4. the shared BlockCSR cache (ported from the benchmarks module)
# ---------------------------------------------------------------------------


def test_block_cache_bounded_and_per_sweep():
    """A second data set evicts the first (per-sweep scope), and the
    entry count stays bounded even for many q values."""
    a = make_sparse_classification(dim=64, num_instances=8,
                                   nnz_per_instance=4, seed=0)
    b = make_sparse_classification(dim=64, num_instances=8,
                                   nnz_per_instance=4, seed=1)
    cache = BlockCache(max_entries=4)
    blk_a2 = cache.get(a, 2)
    assert cache.get(a, 2) is blk_a2  # hit
    cache.get(a, 4)
    assert len(cache) == 2
    cache.get(b, 2)
    # every surviving entry belongs to b: a's blocks were evicted
    assert all(obj is b for obj, _ in cache.values())
    # LRU bound holds for many q values of one data set
    for q in (1, 2, 4, 8, 16, 32):
        cache.get(b, q)
    assert len(cache) <= cache.max_entries


def test_solve_reuses_the_shared_cache(data):
    BLOCK_CACHE.clear()
    spec = ExperimentSpec(method="fdsvrg", data=data, reg=REG, eta=0.3,
                          batch_size=2, inner_steps=4, outer_iters=1, q=2)
    solve(spec)
    blk = BLOCK_CACHE.get(data, 2)  # hit: solve built it
    assert len(BLOCK_CACHE) == 1
    solve(spec)
    assert BLOCK_CACHE.get(data, 2) is blk  # still the same entry
    # the whole FD family goes through the cache, not just fdsvrg
    solve(spec.replace(method="fdsvrg_sim"))
    assert BLOCK_CACHE.get(data, 2) is blk
    assert len(BLOCK_CACHE) == 1
    BLOCK_CACHE.clear()


def test_dataset_name_specs_hit_the_cache_across_solves():
    """solve() memoizes datasets.load, so dataset-NAME sweeps (the
    to_spec()/CLI path) reuse one data object and the id()-keyed
    BlockCSR cache hits instead of being evicted every call."""
    from repro.api.registry import _load_dataset

    assert _load_dataset("news20") is _load_dataset("news20")
    BLOCK_CACHE.clear()
    spec = ExperimentSpec(method="fdsvrg", dataset="news20", reg=REG,
                          eta=0.5, batch_size=2, inner_steps=2,
                          outer_iters=1, q=2)
    solve(spec)
    blk = BLOCK_CACHE.get(_load_dataset("news20"), 2)
    solve(spec.replace(reg=losses.l1(1e-4)))
    assert BLOCK_CACHE.get(_load_dataset("news20"), 2) is blk
    BLOCK_CACHE.clear()


def test_estimator_partial_fit_reuses_encoded_data():
    """Warm-start calls on the same (X, y) reuse ONE encoded data set —
    the label re-encode must not mint a fresh PaddedCSR per call (that
    would evict the BlockCSR cache on every partial_fit)."""
    raw = make_sparse_classification(dim=64, num_instances=16,
                                     nnz_per_instance=4, seed=3)
    y01 = (np.asarray(raw.labels) > 0).astype(int)  # {0,1}: forces re-wrap
    clf = FDSVRGClassifier(method="fdsvrg", workers=2, eta=0.3, lam=1e-3,
                           batch_size=2, inner_steps=4, outer_iters=1)
    clf.fit(raw, y01)
    encoded = clf._encoded[2]
    assert set(np.unique(np.asarray(encoded.labels))) == {-1.0, 1.0}
    clf.partial_fit(raw, y01)
    assert clf._encoded[2] is encoded  # same object, cache stays warm
    # re-encoded labels follow the data's values dtype (no mixed precision)
    assert encoded.labels.dtype == raw.values.dtype


def test_as_padded_csr_dense_length_mismatch():
    with pytest.raises(ValueError, match="labels but X holds"):
        as_padded_csr(np.ones((3, 2)), np.array([1.0, -1.0]))


def test_estimator_score_decodes_stored_labels():
    """score(X) with y=None must agree with score(X, y) when the model
    was fitted on classes other than the PaddedCSR's ±1 coding."""
    raw = make_sparse_classification(dim=64, num_instances=16,
                                     nnz_per_instance=4, seed=5)
    y01 = (np.asarray(raw.labels) > 0).astype(int)
    clf = FDSVRGClassifier(method="serial", eta=0.3, lam=1e-3,
                           inner_steps=8, outer_iters=2)
    clf.fit(raw, y01)
    assert clf.score(raw) == clf.score(raw, y01)


def test_register_method_summary_fallbacks():
    """A third-party adapter with neither summary= nor a docstring must
    register cleanly (empty summary), not die on an IndexError."""
    from repro.api import METHODS, register_method

    @register_method("_tmp_nodoc", backend="sim", supports_kernels=False,
                     paper_eta=1.0, inner_rule="n")
    def _adapter(spec, data, p, mesh):
        return None

    try:
        assert METHODS["_tmp_nodoc"].summary == ""
    finally:
        del METHODS["_tmp_nodoc"]


def test_estimator_string_labels_dense_input():
    """Labels 'may be any two values' includes non-numeric ones on the
    dense path: encoding happens before the sparse conversion."""
    rng = np.random.default_rng(1)
    X = rng.normal(size=(20, 8))
    y = np.where(rng.random(20) < 0.5, "ham", "spam")
    y[:2] = ["ham", "spam"]  # both classes present
    clf = FDSVRGClassifier(method="serial", eta=0.5, lam=1e-3,
                           inner_steps=20, outer_iters=2)
    clf.fit(X, y)
    assert set(np.unique(clf.predict(X))) <= {"ham", "spam"}
    assert 0.0 <= clf.score(X, y) <= 1.0


# ---------------------------------------------------------------------------
# 5. warm start (init_w) through the harness
# ---------------------------------------------------------------------------


def test_init_w_resolves_and_validates(data):
    w = resolve_init_w(None, 8, jnp.float32)
    assert w.shape == (8,) and not w.any()
    w = resolve_init_w(np.ones(8, np.float64), 8, jnp.float32)
    assert w.dtype == jnp.float32  # no silent promotion of the run
    with pytest.raises(ValueError, match="init_w"):
        resolve_init_w(np.ones(4), 8, jnp.float32)


@pytest.mark.parametrize("method", ["serial", "fdsvrg", "dsvrg"])
def test_warm_start_continues_from_given_iterate(data, method):
    """The outer-0 snapshot is taken at init_w, so a warm-started run
    reports its first outer from the trained iterate, not from zeros."""
    base = ExperimentSpec(method=method, data=data, reg=REG, q=2, eta=0.3,
                          batch_size=2, inner_steps=8, outer_iters=2)
    cold = solve(base)
    warm = solve(base.replace(init_w=cold.w, seed=1, outer_iters=1))
    assert warm.history[0].objective < cold.history[0].objective


# ---------------------------------------------------------------------------
# 6. the estimator
# ---------------------------------------------------------------------------


def test_estimator_fit_predict_score(data):
    clf = FDSVRGClassifier(method="fdsvrg", workers=2, eta=0.3, lam=1e-3,
                           batch_size=2, inner_steps=16, outer_iters=3)
    clf.fit(data)
    assert clf.n_features_in_ == data.dim
    assert clf.coef_.shape == (data.dim,)
    margins = clf.decision_function(data)
    assert margins.shape == (data.num_instances,)
    preds = clf.predict(data)
    assert set(np.unique(preds)) <= set(clf.classes_)
    assert clf.score(data) > 0.7  # planted separator: well above chance
    assert len(clf.history_) == 3


def test_estimator_partial_fit_warm_starts(data):
    clf = FDSVRGClassifier(method="serial", eta=0.3, lam=1e-3,
                           inner_steps=16, outer_iters=2)
    clf.fit(data)
    obj_after_fit = clf.final_objective()
    first_fit_obj = clf.history_[0].objective
    clf.partial_fit(data, outer_iters=2)
    assert len(clf.history_) == 4
    assert [h.outer for h in clf.history_] == [0, 1, 2, 3]
    # warm start: the continued run's FIRST outer already beats the cold
    # run's first outer (it starts from the fitted iterate)
    assert clf.history_[2].objective < first_fit_obj
    assert clf.final_objective() <= obj_after_fit + 1e-9
    # the cumulative fields read as ONE continuous run: no counter steps
    # backwards at the warm-start boundary
    for prev, cur in zip(clf.history_, clf.history_[1:]):
        assert cur.comm_scalars >= prev.comm_scalars
        assert cur.modeled_time_s >= prev.modeled_time_s
        assert cur.wall_time_s >= prev.wall_time_s
    # serving: the training-set memo is releasable
    assert clf.free_training_cache() is clf and clf._encoded is None
    assert clf.score(data) >= 0.0  # predict still works from coef_


def test_estimator_dense_input_and_label_mapping():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(24, 12)) * (rng.random((24, 12)) < 0.5)
    w_true = rng.normal(size=12)
    y = (X @ w_true > 0).astype(int)  # labels in {0, 1}
    if len(np.unique(y)) < 2:  # pragma: no cover - rng guard
        y[0] = 1 - y[0]
    clf = FDSVRGClassifier(method="serial", eta=0.5, lam=1e-4,
                           inner_steps=24, outer_iters=4)
    clf.fit(X, y)
    assert np.array_equal(clf.classes_, np.unique(y))
    preds = clf.predict(X)
    assert set(np.unique(preds)) <= set(clf.classes_)
    assert clf.score(X, y) > 0.7


def test_estimator_unfitted_raises():
    clf = FDSVRGClassifier()
    with pytest.raises(ValueError, match="not fitted"):
        clf.predict(np.zeros((2, 3)))


def test_as_padded_csr_roundtrip():
    X = np.array([[0.0, 1.5, 0.0, -2.0],
                  [3.0, 0.0, 0.0, 0.0],
                  [0.0, 0.0, 0.0, 0.0]])
    y = np.array([1.0, -1.0, 1.0])
    data = as_padded_csr(X, y)
    assert isinstance(data, PaddedCSR)
    assert data.dim == 4 and data.num_instances == 3
    np.testing.assert_array_equal(data.to_dense().T, X)


def test_as_padded_csr_roundtrip_random():
    """The vectorized pack (one np.nonzero, offset arithmetic) agrees
    with the dense oracle on ragged random sparsity."""
    rng = np.random.default_rng(7)
    X = rng.normal(size=(37, 23)).astype(np.float32)
    X[rng.random(X.shape) < 0.8] = 0.0
    data = as_padded_csr(X, np.where(rng.random(37) < 0.5, 1.0, -1.0))
    np.testing.assert_array_equal(data.to_dense().T, X)


def test_estimator_news20_end_to_end():
    """Acceptance: FDSVRGClassifier.fit(...).score(...) on news20."""
    from repro.data import datasets

    data = datasets.load("news20")
    clf = FDSVRGClassifier(method="fdsvrg", eta=2.0,
                           lam=2.0 / data.num_instances, outer_iters=2)
    clf.fit(data)
    assert clf.coef_.shape == (data.dim,)
    assert clf.score(data) > 0.6  # heavily regularized: above chance
    assert np.isfinite(clf.final_objective())


def _three_blobs(seed=0, per_class=30, dim=8):
    """Three well-separated Gaussian blobs; returns (X, y_int)."""
    rng = np.random.default_rng(seed)
    centers = np.eye(3, dim) * 6.0
    X = np.concatenate(
        [rng.normal(size=(per_class, dim)) + centers[c] for c in range(3)]
    )
    y = np.repeat(np.arange(3), per_class)
    return X, y


def test_estimator_multiclass_ovr_round_trip():
    """>2 classes: one-vs-rest through the multi-output driver path —
    string labels round-trip, coef_ is sklearn-shaped [k, d], and the
    blobs are easy enough that OvR must score near-perfectly."""
    X, y_int = _three_blobs()
    y = np.array(["ant", "bee", "cat"])[y_int]
    clf = FDSVRGClassifier(method="serial", eta=0.5, lam=1e-4,
                           inner_steps=64, outer_iters=6)
    clf.fit(X, y)
    np.testing.assert_array_equal(clf.classes_, ["ant", "bee", "cat"])
    assert clf.coef_.shape == (3, X.shape[1])
    df = clf.decision_function(X)
    assert df.shape == (X.shape[0], 3)
    preds = clf.predict(X)
    assert set(np.unique(preds)) <= {"ant", "bee", "cat"}
    assert clf.score(X, y) > 0.9


def test_estimator_ovr_column_bitwise_matches_binary_fit():
    """OvR column j == an independent binary fit of (class j vs rest),
    BITWISE: the multi-output driver vmaps one shared sample stream, so
    each column replays exactly the solve the binary path runs."""
    X, y = _three_blobs(seed=3)
    kw = dict(method="serial", eta=0.5, lam=1e-4,
              inner_steps=32, outer_iters=3)
    multi = FDSVRGClassifier(**kw).fit(X, y)
    for j, cls in enumerate(multi.classes_):
        binary = FDSVRGClassifier(**kw).fit(X, (y == cls).astype(int))
        # binary classes_ are [0, 1] -> +1 encodes class j, same as the
        # OvR column's +1
        np.testing.assert_array_equal(multi.coef_[j], binary.coef_)


def test_estimator_multiclass_partial_fit_warm_starts():
    X, y = _three_blobs(seed=5)
    clf = FDSVRGClassifier(method="serial", eta=0.5, lam=1e-4,
                           inner_steps=32, outer_iters=2)
    clf.fit(X, y)
    first = clf.history_[0].objective
    clf.partial_fit(X, y, outer_iters=2)
    assert clf.coef_.shape == (3, X.shape[1])
    # warm start: the continued run's first outer beats the cold first
    assert clf.history_[2].objective < first


def test_estimator_single_class_raises():
    X = np.ones((4, 3))
    clf = FDSVRGClassifier(method="serial")
    with pytest.raises(ValueError, match="at least 2 classes"):
        clf.fit(X, np.zeros(4))


# ---------------------------------------------------------------------------
# 7. LinearConfig.to_spec and the CLI entry point
# ---------------------------------------------------------------------------


def test_linear_config_to_spec():
    from repro.configs.fdsvrg_linear import CONFIGS

    lc = CONFIGS["fdsvrg-news20"]
    spec = lc.to_spec()
    assert spec.method == "fdsvrg"
    assert spec.dataset == "news20"
    assert spec.q == lc.workers == 8
    assert spec.reg == lc.regularizer()
    assert spec.eta == lc.eta
    spec2 = lc.to_spec(method="dsvrg", outer_iters=2, inner_steps=5)
    assert (spec2.method, spec2.outer_iters, spec2.inner_steps) == (
        "dsvrg", 2, 5)
    lc_l1 = CONFIGS["fdsvrg-webspam-l1"]
    assert lc_l1.to_spec().reg.name == "l1"


def test_cli_list_and_smoke(capsys, data):
    from repro.api import cli

    assert cli.main(["--list"]) == 0
    out = capsys.readouterr().out
    for name in METHODS:
        assert name in out
    assert "multi_output" in out  # the capability matrix grows columns too
    assert cli.main([]) == 2  # --config required
    # capability/validation errors follow the same one-line convention
    assert cli.main(["--config", "fdsvrg-news20", "--method", "dsvrg",
                     "--use-kernels", "--quick"]) == 2
    assert "use_kernels" in capsys.readouterr().err
    assert cli.main(["--config", "fdsvrg-news20", "--method", "sgd"]) == 2


def test_run_method_shim_warns_deprecation(data):
    from benchmarks.common import run_method

    with pytest.warns(DeprecationWarning, match="repro.api.solve"):
        run_method("serial", data, 1, 1e-3, outer_iters=1)
