"""SSD/Mamba2: chunked scan vs naive recurrence; decode vs train;
prefill-state handoff."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import (
    SSMConfig,
    init_ssm,
    init_ssm_cache,
    ssd_chunked,
    ssm_decode,
    ssm_train,
)
from repro.sharding.specs import unsharded_ctx

CTX = unsharded_ctx()


def _naive_recurrence(x, dt, a, bmat, cmat):
    """Reference: step-by-step linear recurrence (fp64-ish via f32 loops)."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    state = np.zeros((b, h, p, n), np.float64)
    x = np.asarray(x, np.float64)
    dt = np.asarray(dt, np.float64)
    a = np.asarray(a, np.float64)
    bm = np.asarray(bmat, np.float64)
    cm = np.asarray(cmat, np.float64)
    ys = np.zeros((b, s, h, p), np.float64)
    for t in range(s):
        decay = np.exp(dt[:, t, :] * a[None, :])  # [B, H]
        xd = x[:, t] * dt[:, t][..., None]  # [B, H, P]
        state = state * decay[..., None, None] + np.einsum(
            "bhp,bn->bhpn", xd, bm[:, t]
        )
        ys[:, t] = np.einsum("bhpn,bn->bhp", state, cm[:, t])
    return ys


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
@pytest.mark.parametrize("s", [32, 64])
def test_chunked_ssd_matches_recurrence(chunk, s):
    rng = np.random.default_rng(0)
    b, h, p, n = 2, 3, 4, 8
    x = jnp.asarray(rng.normal(size=(b, s, h, p)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, h)).astype(np.float32))
    a = jnp.asarray(-rng.uniform(0.5, 2.0, size=(h,)).astype(np.float32))
    bm = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
    cm = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
    got = ssd_chunked(x, dt, a, bm, cm, chunk)
    want = _naive_recurrence(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-4)


def test_ssm_decode_matches_train():
    """Token-by-token recurrent decode == chunked train forward."""
    cfg = SSMConfig(d_model=32, d_state=8, expand=2, head_dim=16, chunk=8)
    kp, kx = jax.random.split(jax.random.key(0))
    params = init_ssm(kp, cfg, jnp.float32)
    b, s = 2, 24
    x = jax.random.normal(kx, (b, s, cfg.d_model), jnp.float32) * 0.3
    # train path needs s % chunk == 0
    y_train = ssm_train(params, x, cfg, CTX)

    cache = init_ssm_cache(b, cfg, jnp.float32, CTX)
    ys = []
    for t in range(s):
        y_t, cache = ssm_decode(params, x[:, t : t + 1, :], cache, cfg, CTX)
        ys.append(y_t)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_dec), np.asarray(y_train), rtol=3e-3, atol=3e-4
    )


def test_chunked_ssd_pads_non_multiple_seq():
    """Sequences that don't divide the chunk are padded internally; result
    must still match the naive recurrence."""
    rng = np.random.default_rng(7)
    b, s, h, p, n, chunk = 2, 13, 3, 4, 8, 8
    x = jnp.asarray(rng.normal(size=(b, s, h, p)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, h)).astype(np.float32))
    a = jnp.asarray(-rng.uniform(0.5, 2.0, size=(h,)).astype(np.float32))
    bm = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
    cm = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
    got = ssd_chunked(x, dt, a, bm, cm, chunk)
    assert got.shape == (b, s, h, p)
    want = _naive_recurrence(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-4)


def test_prefill_state_matches_decode_rollout():
    """transformer.ssm_prefill_cache must equal the state after decoding the
    same prefix token-by-token."""
    from repro.models.transformer import ssm_prefill_cache
    from repro.configs.base import ModelConfig, LayerTemplate

    mcfg = ModelConfig(
        name="t", arch_type="ssm", source="", num_layers=2, d_model=32, d_ff=0,
        vocab_size=64, pattern=(LayerTemplate("ssm", "none"),),
        ssm_state=8, ssm_expand=2, ssm_head_dim=16, ssm_chunk=8, dtype="float32",
    )
    cfg = SSMConfig(d_model=32, d_state=8, expand=2, head_dim=16, chunk=8)
    kp, kx = jax.random.split(jax.random.key(5))
    params = init_ssm(kp, cfg, jnp.float32)
    b, s = 2, 16
    h = jax.random.normal(kx, (b, s, 32), jnp.float32) * 0.3

    pre = ssm_prefill_cache(params, h, mcfg, CTX)

    cache = init_ssm_cache(b, cfg, jnp.float32, CTX)
    for t in range(s):
        _, cache = ssm_decode(params, h[:, t : t + 1, :], cache, cfg, CTX)

    np.testing.assert_allclose(
        np.asarray(pre["state"]), np.asarray(cache["state"]), rtol=2e-3, atol=2e-4
    )
    np.testing.assert_allclose(
        np.asarray(pre["conv"]), np.asarray(cache["conv"]), rtol=1e-4, atol=1e-5
    )


def test_ssd_bf16_compute_close_to_f32():
    """§Perf lever: bf16 SSD operands with f32 accumulation stay within
    bf16 tolerance of the f32 path."""
    rng = np.random.default_rng(3)
    b, s, h, p, n, chunk = 2, 64, 4, 8, 16, 16
    x = jnp.asarray(rng.normal(size=(b, s, h, p)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, h)).astype(np.float32))
    a = jnp.asarray(-rng.uniform(0.5, 2.0, size=(h,)).astype(np.float32))
    bm = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
    cm = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
    y32 = ssd_chunked(x, dt, a, bm, cm, chunk, compute_dtype="float32")
    y16 = ssd_chunked(x, dt, a, bm, cm, chunk, compute_dtype="bfloat16")
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y32), rtol=5e-2, atol=5e-2)
