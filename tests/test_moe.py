"""MoE routing/dispatch: sort-dispatch vs dense oracle, mass conservation,
capacity overflow behaviour, load-balance loss properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import MoEConfig, capacity, init_moe, moe_ffn, moe_ffn_dense_ref
from repro.sharding.specs import unsharded_ctx

CTX = unsharded_ctx()


def _setup(cfg, b=2, s=16, seed=0):
    kp, kx = jax.random.split(jax.random.key(seed))
    params = init_moe(kp, cfg, jnp.float32)
    x = jax.random.normal(kx, (b, s, cfg.d_model), jnp.float32) * 0.5
    return params, x


@pytest.mark.parametrize(
    "e,k", [(4, 1), (4, 2), (8, 2), (8, 8)], ids=["e4k1", "e4k2", "e8k2", "e8k8"]
)
def test_dispatch_matches_dense_oracle(e, k):
    """With capacity >= all assignments, sorted dispatch == dense compute."""
    cfg = MoEConfig(d_model=32, d_ff=64, num_experts=e, top_k=k, capacity_factor=float(e))
    params, x = _setup(cfg)
    y, aux = moe_ffn(params, x, cfg, CTX)
    assert float(aux["overflow_frac"]) == 0.0
    y_ref = moe_ffn_dense_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=5e-4, atol=5e-5)


def test_capacity_overflow_drops_not_corrupts():
    cfg = MoEConfig(d_model=16, d_ff=32, num_experts=4, top_k=2, capacity_factor=0.25)
    params, x = _setup(cfg, b=2, s=32, seed=1)
    y, aux = moe_ffn(params, x, cfg, CTX)
    assert np.all(np.isfinite(np.asarray(y)))
    assert 0.0 < float(aux["overflow_frac"]) < 1.0


def test_combine_weights_sum_to_one():
    """Renormalized top-k weights: with identity experts the MoE output
    equals the input (weights sum to 1 per token)."""
    cfg = MoEConfig(d_model=8, d_ff=8, num_experts=4, top_k=2, capacity_factor=4.0)
    params, x = _setup(cfg, b=1, s=8, seed=2)
    # make every expert the identity: w_gate s.t. silu(g)*u == x requires
    # engineering; instead check mass conservation through linear experts:
    # zero the gate (silu(0)=0) -> output 0 regardless of weights
    params = dict(params)
    params["w_gate"] = jnp.zeros_like(params["w_gate"])
    y, _ = moe_ffn(params, x, cfg, CTX)
    np.testing.assert_allclose(np.asarray(y), 0.0, atol=1e-6)


def test_lb_loss_uniform_router_is_minimal():
    """Perfectly uniform routing gives lb_loss == 1 (its minimum is ~1)."""
    cfg = MoEConfig(d_model=16, d_ff=16, num_experts=4, top_k=4, capacity_factor=4.0)
    params, x = _setup(cfg, b=2, s=64, seed=3)
    params = dict(params)
    params["router"] = jnp.zeros_like(params["router"])  # uniform probs
    _, aux = moe_ffn(params, x, cfg, CTX)
    # top_k = E and uniform: every expert sees every token (frac_tokens = 1)
    # and frac_probs = 1/E, so lb = E * sum_e (1 * 1/E) = E * 1 ... here the
    # Switch normalization makes the uniform-top_k=E value exactly E.
    np.testing.assert_allclose(float(aux["lb_loss"]), float(cfg.num_experts), rtol=1e-5)


def test_capacity_formula():
    cfg = MoEConfig(d_model=8, d_ff=8, num_experts=64, top_k=8, capacity_factor=1.25)
    assert capacity(65536, cfg) == int(65536 * 8 * 1.25 / 64)
    assert capacity(4, cfg) >= cfg.top_k  # floor


def test_moe_gradients_flow():
    cfg = MoEConfig(d_model=16, d_ff=32, num_experts=4, top_k=2, capacity_factor=2.0)
    params, x = _setup(cfg, b=2, s=8, seed=4)

    def loss(p):
        y, aux = moe_ffn(p, x, cfg, CTX)
        return jnp.sum(y ** 2) + 0.01 * aux["lb_loss"]

    g = jax.grad(loss)(params)
    for name in ("router", "w_gate", "w_up", "w_down"):
        assert float(jnp.max(jnp.abs(g[name]))) > 0.0, f"no grad for {name}"
        assert np.all(np.isfinite(np.asarray(g[name])))
