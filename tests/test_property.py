"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev-only dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core.comm import CommMeter, TpuV5eModel
from repro.models.layers import apply_rope, rms_norm, softcap
from repro.sharding.specs import RULES, ShardingCtx
from repro.train.loop import cross_entropy


# ---------------------------------------------------------------------------
# CommMeter
# ---------------------------------------------------------------------------


@given(st.lists(st.tuples(st.integers(1, 10**6), st.integers(1, 100)), min_size=1, max_size=30))
@settings(max_examples=30, deadline=None)
def test_comm_meter_additivity(events):
    m = CommMeter()
    for scalars, rounds in events:
        m.record("x", scalars, rounds)
    assert m.total_scalars == sum(e[0] for e in events)
    assert m.total_rounds == sum(e[1] for e in events)
    assert m.by_kind["x"] == m.total_scalars


@given(st.integers(2, 512), st.integers(1, 10**6))
@settings(max_examples=40, deadline=None)
def test_tree_reduce_cost_formula(q, payload):
    m = CommMeter()
    m.tree_reduce_broadcast(q, payload)
    assert m.total_scalars == 2 * q * payload  # paper §4.5
    assert m.total_rounds == 2 * int(np.ceil(np.log2(q)))


# ---------------------------------------------------------------------------
# Numerics helpers
# ---------------------------------------------------------------------------


@given(st.floats(1.0, 100.0), st.floats(-1e6, 1e6))
@settings(max_examples=50, deadline=None)
def test_softcap_bounded_and_monotone_through_zero(cap, x):
    y = float(softcap(jnp.asarray(x, jnp.float32), cap))
    assert abs(y) <= cap + 1e-3
    assert y * x >= 0.0  # sign preserved (both may be ±0)


def test_rms_norm_scale_invariance():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 8, 16)), jnp.float32)
    s = jnp.zeros((16,), jnp.float32)
    y1 = rms_norm(x, s)
    y2 = rms_norm(x * 7.3, s)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-5)


def test_rope_preserves_norm_and_relative_phase():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1, 6, 2, 8)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(6), (1, 6))
    y = apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-4, atol=1e-5,
    )
    # relative property: <rope(q,m), rope(k,n)> depends only on m-n
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 8)), jnp.float32)

    def dot_at(m, n):
        qm = apply_rope(q, jnp.full((1, 1), m), 10_000.0)
        kn = apply_rope(k, jnp.full((1, 1), n), 10_000.0)
        return float(jnp.sum(qm * kn))

    assert dot_at(3, 1) == pytest.approx(dot_at(7, 5), rel=1e-4)
    assert dot_at(2, 2) == pytest.approx(dot_at(9, 9), rel=1e-4)


@given(st.integers(2, 6), st.integers(2, 10), st.integers(3, 50))
@settings(max_examples=20, deadline=None)
def test_cross_entropy_uniform_logits(b, s, v):
    logits = jnp.zeros((b, s, v), jnp.float32)
    labels = jnp.zeros((b, s), jnp.int32)
    mask = jnp.ones((b, s))
    ce = float(cross_entropy(logits, labels, mask, v))
    assert ce == pytest.approx(np.log(v), rel=1e-5)


def test_cross_entropy_masks_positions():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(1, 4, 8)), jnp.float32)
    labels = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    full = float(cross_entropy(logits, labels, jnp.ones((1, 4)), 8))
    # masking position 0 == CE over the remaining three
    part = float(cross_entropy(logits, labels, jnp.asarray([[0.0, 1, 1, 1]]), 8))
    manual = float(cross_entropy(logits[:, 1:], labels[:, 1:], jnp.ones((1, 3)), 8))
    assert part == pytest.approx(manual, rel=1e-6)
    assert part != pytest.approx(full, rel=1e-6)


# ---------------------------------------------------------------------------
# Sharding specs
# ---------------------------------------------------------------------------


def test_ctx_without_mesh_is_identity():
    ctx = ShardingCtx(mesh=None)
    x = jnp.ones((4, 4))
    assert ctx.constrain(x, "batch", "embed") is x
    assert ctx.spec("batch", "embed") == jax.sharding.PartitionSpec()


def test_spec_div_drops_indivisible_axes():
    from repro.dist.compat import make_mesh

    mesh = make_mesh((1,), ("model",))
    # fake a 16-wide axis via rules resolution against a real mesh is hard
    # on 1 device; test the arithmetic directly instead
    ctx = ShardingCtx(mesh=mesh)
    spec = ctx.spec_div((15, 64), "heads", None)
    # model axis size 1 divides everything -> keeps the mapping
    assert spec == jax.sharding.PartitionSpec("model", None)


def test_rules_cover_all_logical_axes_used_by_models():
    used = {
        "batch", "seq", "seq_kv", "embed", "heads", "kv_heads", "mlp",
        "experts", "expert_mlp", "vocab", "ssm_heads", "zero1",
    }
    assert used <= set(RULES)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_tpu_model_dominant_is_max(seed):
    rng = np.random.default_rng(seed)
    f, b, c = rng.uniform(1, 1e18, 3)
    terms = TpuV5eModel().roofline_terms(
        flops=f, hbm_bytes=b, collective_bytes=c, chips=256
    )
    vals = {k: terms[f"{k}_s"] for k in ("compute", "memory", "collective")}
    assert terms["dominant"] == max(vals, key=vals.get)


# ---------------------------------------------------------------------------
# Benchmark cost model
# ---------------------------------------------------------------------------


def test_analytic_outer_paper_orderings():
    from benchmarks.common import analytic_outer
    from repro.data import datasets

    for name in ("news20", "webspam", "kdd2010"):
        spec = datasets.spec(name, scaled=False)
        q = spec.default_workers
        t_fd, c_fd = analytic_outer("fdsvrg", spec, q)
        t_ds, c_ds = analytic_outer("dsvrg", spec, q)
        t_ps, c_ps = analytic_outer("pslite_sgd", spec, q)
        # paper §4.5 compares per-GRADIENT: FD does 2N gradients per outer
        # (fullgrad + M=N inner), DSVRG does N(1+1/q)
        per_grad_fd = c_fd / (2 * spec.num_instances)
        per_grad_ds = c_ds / (spec.num_instances * (1 + 1 / q))
        if spec.dim > spec.num_instances:
            assert per_grad_fd < per_grad_ds, name
        if spec.dim > 10 * spec.num_instances:  # d >> N: strict per-outer win
            assert t_fd < t_ds, name
            assert t_ps > t_fd, name  # PS-Lite slowest (paper Table 3)


def test_analytic_scaling_near_ideal_at_small_q():
    from benchmarks.common import analytic_outer
    from repro.data import datasets

    spec = datasets.spec("webspam", scaled=False)
    t1, _ = analytic_outer("fdsvrg", spec, 1)
    t4, _ = analytic_outer("fdsvrg", spec, 4)
    t16, _ = analytic_outer("fdsvrg", spec, 16)
    assert t1 / t4 > 3.0  # >75% efficiency at q=4 (paper Fig 9)
    assert t1 / t16 > 8.0  # >50% efficiency at q=16
