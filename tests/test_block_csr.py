"""BlockCSR: the block-local sharded layout must match the masked
global-CSR computation exactly, for any partition.

The masked path — keep global ids, select ids in [lo, hi) with
``(idx >= lo) & (idx < hi)`` on every access — is re-implemented inline
here as the oracle; it no longer exists in the library because BlockCSR
replaced it on every hot path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.partition import balanced, by_nnz, feature_counts
from repro.data.block_csr import BlockCSR, local_margins, local_scatter
from repro.data.sparse import PaddedCSR, margins, scatter_grad
from repro.data.synthetic import make_sparse_classification

try:
    import hypothesis  # noqa: F401  (dev-only dep; see requirements-dev.txt)

    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False


RNG = np.random.default_rng(0)


def _data(dim=517, n=41, nnz=11, seed=0):
    return make_sparse_classification(
        dim=dim, num_instances=n, nnz_per_instance=nnz, seed=seed
    )


# ---------------------------------------------------------------------------
# masked global-CSR oracle (the pattern BlockCSR killed)
# ---------------------------------------------------------------------------


def masked_margins(indices, values, w_block, lo):
    hi = lo + w_block.shape[0]
    in_block = (indices >= lo) & (indices < hi)
    local = jnp.where(in_block, indices - lo, 0)
    gathered = jnp.where(in_block, w_block[local], 0.0)
    return jnp.sum(gathered * values, axis=-1)


def masked_scatter(indices, values, coeffs, lo, block_dim):
    hi = lo + block_dim
    in_block = (indices >= lo) & (indices < hi)
    local = jnp.where(in_block, indices - lo, 0)
    contrib = jnp.where(in_block, values, 0.0) * coeffs[..., None]
    return (
        jnp.zeros((block_dim,), dtype=values.dtype)
        .at[local.reshape(-1)]
        .add(contrib.reshape(-1))
    )


def _random_partition(rng, dim, q):
    cuts = np.sort(rng.choice(np.arange(1, dim), size=q - 1, replace=False))
    from repro.core.partition import FeaturePartition

    return FeaturePartition(dim=dim, bounds=(0, *map(int, cuts), dim))


# ---------------------------------------------------------------------------
# layout construction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("q", [1, 2, 3, 5, 8])
def test_from_padded_budgets_and_coverage(q):
    data = _data()
    part = balanced(data.dim, q)
    b = BlockCSR.from_padded(data, part)
    assert b.num_blocks == q
    assert b.num_instances == data.num_instances
    assert sum(b.block_dims) == data.dim
    # every stored nonzero is local to its block
    for l in range(q):
        idx, val = b.block(l)
        assert int(jnp.max(idx)) < b.block_dims[l] or b.block_dims[l] == 0
        assert int(jnp.min(idx)) >= 0
    # no nonzero lost: total mass matches
    assert b.nnz_total() == int(jnp.sum(data.values != 0.0))
    # per-worker rows shrink with q (the point of the layout)
    assert max(b.nnz_budgets) <= data.nnz_max
    if q >= 4:
        assert max(b.nnz_budgets) < data.nnz_max


def test_from_padded_single_block_shares_arrays():
    data = _data()
    b = BlockCSR.from_padded(data, balanced(data.dim, 1))
    assert b.indices[0] is data.indices
    assert b.values[0] is data.values


def test_from_padded_rejects_wrong_dim():
    data = _data(dim=100)
    with pytest.raises(ValueError, match="dim"):
        BlockCSR.from_padded(data, balanced(99, 4))


def test_lane_multiple_rounds_budgets():
    data = _data()
    b = BlockCSR.from_padded(data, balanced(data.dim, 4), lane_multiple=8)
    assert all(budget % 8 == 0 for budget in b.nnz_budgets)


def test_stacked_uniform_budget_and_equivalence():
    data = _data()
    q = 4
    part = balanced(data.dim, q)
    b = BlockCSR.from_padded(data, part)
    sidx, sval = b.stacked()
    assert sidx.shape == sval.shape == (q, data.num_instances, max(b.nnz_budgets))
    w = jnp.asarray(RNG.normal(size=data.dim).astype(np.float32))
    total = jnp.zeros((data.num_instances,), jnp.float32)
    for l in range(q):
        lo, hi = part.block(l)
        total = total + local_margins(sidx[l], sval[l], w[lo:hi])
    np.testing.assert_allclose(
        np.asarray(total), np.asarray(margins(data, w)), rtol=2e-4, atol=1e-5
    )
    with pytest.raises(ValueError, match="budget"):
        b.stacked(budget=1)


# ---------------------------------------------------------------------------
# equivalence with the masked global-CSR path (parametrized; always runs)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("q", [1, 2, 4, 7])
@pytest.mark.parametrize("strategy", ["balanced", "by_nnz"])
def test_margins_match_masked_path(q, strategy):
    data = _data(seed=q)
    if strategy == "balanced":
        part = balanced(data.dim, q)
    else:
        counts = feature_counts(
            np.asarray(data.indices), np.asarray(data.values), data.dim
        )
        part = by_nnz(data.dim, q, counts)
    b = BlockCSR.from_padded(data, part)
    w = jnp.asarray(RNG.normal(size=data.dim).astype(np.float32))
    for l in range(q):
        lo, hi = part.block(l)
        got = jax.jit(local_margins)(*b.block(l), w[lo:hi])
        want = jax.jit(masked_margins, static_argnames=("lo",))(
            data.indices, data.values, w[lo:hi], lo
        )
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-7
        )


@pytest.mark.parametrize("q", [1, 2, 4, 7])
def test_scatter_matches_masked_path_and_global(q):
    data = _data(seed=10 + q)
    part = balanced(data.dim, q)
    b = BlockCSR.from_padded(data, part)
    coeffs = jnp.asarray(
        RNG.normal(size=data.num_instances).astype(np.float32)
    )
    pieces = []
    for l in range(q):
        lo, hi = part.block(l)
        got = local_scatter(*b.block(l), coeffs, b.block_dims[l])
        want = masked_scatter(data.indices, data.values, coeffs, lo, hi - lo)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
        )
        pieces.append(got)
    full = scatter_grad(data.indices, data.values, coeffs, data.dim)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(pieces)), np.asarray(full),
        rtol=1e-5, atol=1e-6,
    )


# ---------------------------------------------------------------------------
# hypothesis property: random partitions, sampled rows (CI; dev-only dep)
# ---------------------------------------------------------------------------


if HAS_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @given(
        st.integers(min_value=1, max_value=9),
        st.integers(min_value=0, max_value=10_000),
    )
    @settings(max_examples=25, deadline=None)
    def test_property_margins_and_scatter_match_masked(q, seed):
        rng = np.random.default_rng(seed)
        data = _data(dim=211, n=13, nnz=7, seed=seed % 17)
        part = (
            balanced(data.dim, q)
            if seed % 2
            else _random_partition(rng, data.dim, max(q, 2))
        )
        b = BlockCSR.from_padded(data, part)
        w = jnp.asarray(rng.normal(size=data.dim).astype(np.float32))
        ids = jnp.asarray(
            rng.integers(0, data.num_instances, size=5).astype(np.int32)
        )
        coeffs = jnp.asarray(rng.normal(size=5).astype(np.float32))
        for l in range(part.num_blocks):
            lo, hi = part.block(l)
            idx_l, val_l = b.block(l)
            # margins over sampled rows (the inner-loop access pattern)
            got = local_margins(idx_l[ids], val_l[ids], w[lo:hi])
            want = masked_margins(
                data.indices[ids], data.values[ids], w[lo:hi], lo
            )
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
            )
            got_s = local_scatter(idx_l[ids], val_l[ids], coeffs, hi - lo)
            want_s = masked_scatter(
                data.indices[ids], data.values[ids], coeffs, lo, hi - lo
            )
            np.testing.assert_allclose(
                np.asarray(got_s), np.asarray(want_s), rtol=1e-5, atol=1e-6
            )


# ---------------------------------------------------------------------------
# explicit-zero entries (the from_padded `val != 0.0` filter invariant)
# ---------------------------------------------------------------------------


def _data_with_explicit_zeros(dim=120, n=9, nnz=6, seed=5, block_lo=None):
    """Padded rows where some stored entries have value exactly 0.0 —
    including, when ``block_lo`` is given, an explicit zero AT a block's
    lower bound, whose re-indexed form (local id 0, value 0.0) collides
    exactly with the padding pattern."""
    rng = np.random.default_rng(seed)
    base = make_sparse_classification(
        dim=dim, num_instances=n, nnz_per_instance=nnz, seed=seed
    )
    val = np.asarray(base.values).copy()
    idx = np.asarray(base.indices).copy()
    # zero out one genuine entry per even row (index kept: explicit zero)
    for i in range(0, n, 2):
        val[i, rng.integers(0, nnz)] = 0.0
    if block_lo is not None:
        # a stored (id == block lower bound, value 0.0) entry
        idx[1, 0] = block_lo
        val[1, 0] = 0.0
    return PaddedCSR(
        indices=jnp.asarray(idx), values=jnp.asarray(val),
        labels=base.labels, dim=dim,
    )


@pytest.mark.parametrize("q", [2, 3, 4])
def test_explicit_zeros_margins_and_scatter_match_masked(q):
    """Explicit zeros are dropped by from_padded — and that is safe:
    margins and scatter match the masked oracle (which keeps them) bit
    for contribution, because a zero value contributes nothing."""
    part = balanced(120, q)
    lo1 = part.block(1)[0]  # put a colliding (id lo, 0.0) in block 1
    data = _data_with_explicit_zeros(block_lo=lo1)
    b = BlockCSR.from_padded(data, part)
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.normal(size=data.dim).astype(np.float32))
    coeffs = jnp.asarray(
        rng.normal(size=data.num_instances).astype(np.float32)
    )
    for l in range(q):
        lo, hi = part.block(l)
        got = local_margins(*b.block(l), w[lo:hi])
        want = masked_margins(data.indices, data.values, w[lo:hi], lo)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
        )
        got_s = local_scatter(*b.block(l), coeffs, hi - lo)
        want_s = masked_scatter(data.indices, data.values, coeffs, lo, hi - lo)
        np.testing.assert_allclose(
            np.asarray(got_s), np.asarray(want_s), rtol=1e-5, atol=1e-6
        )


def test_explicit_zeros_dropped_from_budgets_and_counts():
    """from_padded counts only value != 0 entries: nnz_total excludes the
    explicit zeros, and per-block budgets never grow because of them."""
    data = _data_with_explicit_zeros()
    b = BlockCSR.from_padded(data, balanced(data.dim, 3))
    assert b.nnz_total() == int(jnp.sum(data.values != 0.0))
    dense_rows = (np.asarray(data.values) != 0.0).sum(axis=1)
    assert max(b.nnz_budgets) <= int(dense_rows.max())


if HAS_HYPOTHESIS:

    @given(
        st.integers(min_value=2, max_value=6),
        st.integers(min_value=0, max_value=10_000),
    )
    @settings(max_examples=20, deadline=None)
    def test_property_explicit_zeros_preserve_margins(q, seed):
        rng = np.random.default_rng(seed)
        data = _data_with_explicit_zeros(dim=97, n=7, nnz=5, seed=seed % 13)
        part = balanced(data.dim, q)
        b = BlockCSR.from_padded(data, part)
        w = jnp.asarray(rng.normal(size=data.dim).astype(np.float32))
        ids = jnp.asarray(
            rng.integers(0, data.num_instances, size=4).astype(np.int32)
        )
        coeffs = jnp.asarray(rng.normal(size=4).astype(np.float32))
        for l in range(part.num_blocks):
            lo, hi = part.block(l)
            idx_l, val_l = b.block(l)
            got = local_margins(idx_l[ids], val_l[ids], w[lo:hi])
            want = masked_margins(
                data.indices[ids], data.values[ids], w[lo:hi], lo
            )
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
            )
            got_s = local_scatter(idx_l[ids], val_l[ids], coeffs, hi - lo)
            want_s = masked_scatter(
                data.indices[ids], data.values[ids], coeffs, lo, hi - lo
            )
            np.testing.assert_allclose(
                np.asarray(got_s), np.asarray(want_s), rtol=1e-5, atol=1e-6
            )


# ---------------------------------------------------------------------------
# vectorized to_dense (satellite regression)
# ---------------------------------------------------------------------------


def test_to_dense_shape_dtype_and_values():
    data = _data(dim=300, n=20, nnz=7, seed=4)
    dense = data.to_dense()
    assert dense.shape == (data.dim, data.num_instances)
    assert dense.dtype == np.float32
    # oracle: the original per-instance np.add.at loop
    idx = np.asarray(data.indices)
    val = np.asarray(data.values)
    want = np.zeros_like(dense)
    for i in range(data.num_instances):
        np.add.at(want[:, i], idx[i], val[i])
    np.testing.assert_array_equal(dense, want)


def test_to_dense_accumulates_repeated_indices():
    data = PaddedCSR(
        indices=jnp.asarray([[1, 1, 0], [2, 0, 0]], jnp.int32),
        values=jnp.asarray([[1.0, 2.0, 0.0], [4.0, 0.0, 0.0]], jnp.float32),
        labels=jnp.asarray([1.0, -1.0]),
        dim=4,
    )
    dense = data.to_dense()
    assert dense[1, 0] == pytest.approx(3.0)  # repeated index summed
    assert dense[2, 1] == pytest.approx(4.0)
    assert dense[0, 0] == pytest.approx(0.0)  # zero-value padding ignored
