"""The pluggable update-rule layer (repro.optim.update_rules).

Load-bearing claims:
  1. The SVRG rule IS the extraction of the pre-refactor drivers: running
     it through :func:`run_with_rule` is bit-identical to
     ``run_serial_svrg`` / ``run_fdsvrg`` / ``fdsvrg_worker_simulation``
     (the executable spec keeps its inline epoch precisely so this test
     has an unrefactored reference), across use_kernels x lazy_updates.
  2. The new rules (FD-SAGA, FD-BCD) converge through the public
     ``solve()`` surface and enforce their capability flags (no
     recovery/checkpoint/Option-II — their carried state advances inside
     the epoch).
  3. Multi-output w in R^{d x k}: a [N, k] label matrix solves k
     independent problems BITWISE (shared sample stream under vmap);
     [N, 1] is squeezed and stays bitwise identical to the 1-D path;
     kernels/lazy are rejected for k > 1.

The meter-vs-closed-form drift guard for fd_saga/fd_bcd lives with the
other analytic-schedule rows in tests/test_driver.py.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentSpec, solve
from repro.core import losses
from repro.core.driver import CheckpointPolicy, RecoveryPolicy
from repro.core.fdsvrg import (
    SVRGConfig,
    fdsvrg_worker_simulation,
    run_fdsvrg,
    run_serial_svrg,
)
from repro.core.partition import balanced
from repro.data.block_csr import BlockCSR
from repro.data.synthetic import make_sparse_classification
from repro.dist import ClusterModel, SimBackend
from repro.optim.update_rules import (
    RULES,
    BCDRule,
    SAGARule,
    SVRGRule,
    make_context,
    run_with_rule,
)

LOSS = losses.logistic
REG = losses.l2(1e-3)


@pytest.fixture(scope="module")
def data():
    return make_sparse_classification(
        dim=512, num_instances=96, nnz_per_instance=12, seed=3
    )


def _block(data, q):
    return BlockCSR.from_padded(data, balanced(data.dim, q))


# ---------------------------------------------------------------------------
# 1. SVRG-via-rule == the drivers, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("lazy_updates", [None, "exact", "proba"])
def test_svrg_rule_bit_identical_to_serial_driver(data, lazy_updates):
    cfg = SVRGConfig(eta=0.2, inner_steps=24, outer_iters=3, seed=5)
    rule = SVRGRule(lazy_updates=lazy_updates)
    res = run_with_rule(rule, make_context(_block(data, 1), LOSS, REG, cfg))
    ref = run_serial_svrg(data, LOSS, REG, cfg, lazy_updates=lazy_updates)
    np.testing.assert_array_equal(np.asarray(res.w), np.asarray(ref.w))
    assert [h.objective for h in res.history] == [
        h.objective for h in ref.history
    ]


@pytest.mark.parametrize("q", [2, 4])
def test_svrg_rule_bit_identical_to_fd_driver_and_worker_sim(data, q):
    """run_with_rule(SVRGRule) == run_fdsvrg bitwise, and matches the
    object-level worker simulation at its historical tolerance with an
    EXACTLY equal meter.  fdsvrg_worker_simulation kept its pre-refactor
    inline epoch, so this pins the extraction against unrefactored code,
    not against itself.  (The sim's per-worker partial dots were never
    bitwise to the batched scan — rtol 2e-4 is the bar the pre-refactor
    equivalence suite always used; the communication accounting, by
    contrast, must agree scalar for scalar.)"""
    cfg = SVRGConfig(eta=0.2, inner_steps=24, outer_iters=3, seed=5)
    part = balanced(data.dim, q)
    cluster = ClusterModel()
    res = run_with_rule(
        SVRGRule(),
        make_context(
            _block(data, q), LOSS, REG, cfg,
            backend=SimBackend(q, cluster),
        ),
    )
    ref = run_fdsvrg(
        data, part, LOSS, REG, cfg, backend=SimBackend(q, cluster)
    )
    sim = fdsvrg_worker_simulation(
        data, part, LOSS, REG, cfg, backend=SimBackend(q, cluster)
    )
    np.testing.assert_array_equal(np.asarray(res.w), np.asarray(ref.w))
    np.testing.assert_allclose(
        np.asarray(res.w), np.asarray(sim.w), rtol=2e-4, atol=2e-6
    )
    for other in (ref, sim):
        assert res.meter.total_scalars == other.meter.total_scalars
    # modeled time: the sim meters traffic but has never charged the cost
    # model, so only the real driver is held to exact time equality
    assert res.history[-1].modeled_time_s == ref.history[-1].modeled_time_s


# ---------------------------------------------------------------------------
# 2. FD-SAGA / FD-BCD: convergence through solve(), capability flags
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["fd_saga", "fd_bcd"])
def test_new_methods_converge_via_solve(data, method):
    res = solve(
        ExperimentSpec(
            method=method, data=data, q=4, reg=REG, outer_iters=6
        )
    )
    objs = res.objectives()
    # Strict decrease from the first outer and well below the w=0
    # objective (log 2 for logistic).
    assert objs[-1] < objs[0] < float(np.log(2.0))
    assert res.meter.total_scalars > 0


def test_saga_first_epoch_matches_svrg_first_epoch(data):
    """With the table initialized from the snapshot, alpha[i] == the
    snapshot margin derivative for every untouched i — so as long as no
    sample repeats, FD-SAGA's directions equal FD-SVRG's.  One short
    u=1 epoch with distinct draws must therefore match bitwise."""
    q = 2
    cfg = SVRGConfig(eta=0.1, inner_steps=1, outer_iters=1, seed=9)
    saga = run_with_rule(
        SAGARule(), make_context(_block(data, q), LOSS, REG, cfg)
    )
    svrg = run_with_rule(
        SVRGRule(), make_context(_block(data, q), LOSS, REG, cfg)
    )
    np.testing.assert_array_equal(np.asarray(saga.w), np.asarray(svrg.w))


def test_bcd_is_deterministic_and_seed_free(data):
    q = 4
    runs = [
        run_with_rule(
            BCDRule(),
            make_context(
                _block(data, q), LOSS, losses.l1(1e-4),
                SVRGConfig(eta=0.5, inner_steps=q, outer_iters=3, seed=s),
            ),
        )
        for s in (0, 123)
    ]
    np.testing.assert_array_equal(np.asarray(runs[0].w), np.asarray(runs[1].w))


@pytest.mark.parametrize("rule_cls", [SAGARule, BCDRule])
def test_rules_reject_recovery_and_checkpoint(data, rule_cls, tmp_path):
    ctx = make_context(
        _block(data, 2), LOSS, REG,
        SVRGConfig(eta=0.2, inner_steps=4, outer_iters=1),
    )
    with pytest.raises(ValueError, match="recovery"):
        run_with_rule(rule_cls(), ctx, recovery=RecoveryPolicy())
    with pytest.raises(ValueError, match="checkpoint"):
        run_with_rule(
            rule_cls(), ctx, checkpoint=CheckpointPolicy(str(tmp_path))
        )


@pytest.mark.parametrize("rule_cls", [SAGARule, BCDRule])
def test_rules_reject_option_ii(data, rule_cls):
    ctx = make_context(
        _block(data, 2), LOSS, REG,
        SVRGConfig(eta=0.2, inner_steps=4, outer_iters=1, option="II"),
    )
    with pytest.raises(ValueError, match="Option I"):
        run_with_rule(rule_cls(), ctx)


def test_rules_registry_names():
    assert set(RULES) == {"svrg", "fd_saga", "fd_bcd"}
    for name, cls in RULES.items():
        assert cls.name == name


# ---------------------------------------------------------------------------
# 3. Multi-output w in R^{d x k}
# ---------------------------------------------------------------------------


def _multi_labels(data, k, seed=7):
    rng = np.random.default_rng(seed)
    y = rng.choice([-1.0, 1.0], size=(data.num_instances, k))
    y[:, 0] = np.asarray(data.labels)  # one real column among the k
    return jnp.asarray(y.astype(np.float32))


@pytest.mark.parametrize("loss_name", ["squared", "logistic"])
def test_multi_output_matches_independent_solves(data, loss_name):
    k, q = 3, 2
    loss = losses.LOSSES[loss_name]
    cfg = SVRGConfig(eta=0.2, inner_steps=16, outer_iters=3, seed=2)
    y = _multi_labels(data, k)
    block = _block(data, q)
    res = run_with_rule(
        SVRGRule(),
        make_context(
            dataclasses.replace(block, labels=y), loss, REG, cfg
        ),
    )
    assert res.w.shape == (data.dim, k)
    for j in range(k):
        ref = run_with_rule(
            SVRGRule(),
            make_context(
                dataclasses.replace(block, labels=y[:, j]), loss, REG, cfg
            ),
        )
        np.testing.assert_array_equal(
            np.asarray(res.w[:, j]), np.asarray(ref.w)
        )


def test_multi_output_k1_bitwise_equals_scalar_path(data):
    q = 2
    cfg = SVRGConfig(eta=0.2, inner_steps=16, outer_iters=2, seed=2)
    block = _block(data, q)
    wide = dataclasses.replace(block, labels=block.labels[:, None])
    res = run_with_rule(SVRGRule(), make_context(wide, LOSS, REG, cfg))
    ref = run_with_rule(SVRGRule(), make_context(block, LOSS, REG, cfg))
    assert res.w.ndim == 1  # [N, 1] labels are squeezed onto the 1-D path
    np.testing.assert_array_equal(np.asarray(res.w), np.asarray(ref.w))
    assert res.final_objective() == ref.final_objective()


def test_multi_output_meter_scales_by_k(data):
    q, k = 2, 3
    cfg = SVRGConfig(eta=0.2, inner_steps=8, outer_iters=2, seed=2)
    block = _block(data, q)
    cluster = ClusterModel()

    def run(labels):
        return run_with_rule(
            SVRGRule(),
            make_context(
                dataclasses.replace(block, labels=labels),
                losses.LOSSES["squared"], REG, cfg,
                backend=SimBackend(q, cluster),
            ),
        )

    wide = run(_multi_labels(data, k))
    scalar = run(block.labels)
    assert wide.meter.total_scalars == k * scalar.meter.total_scalars


def test_multi_output_rejects_kernels_and_lazy(data):
    cfg = SVRGConfig(eta=0.2, inner_steps=4, outer_iters=1)
    ctx = make_context(
        dataclasses.replace(
            _block(data, 2), labels=_multi_labels(data, 2)
        ),
        LOSS, REG, cfg,
    )
    for rule in (SVRGRule(use_kernels=True), SVRGRule(lazy_updates="exact")):
        with pytest.raises(ValueError, match="multi-output"):
            run_with_rule(rule, ctx)


@pytest.mark.parametrize("rule_cls", [SAGARule, BCDRule])
def test_non_multi_rules_reject_wide_labels(data, rule_cls):
    cfg = SVRGConfig(eta=0.2, inner_steps=4, outer_iters=1)
    ctx = make_context(
        dataclasses.replace(
            _block(data, 2), labels=_multi_labels(data, 2)
        ),
        LOSS, REG, cfg,
    )
    with pytest.raises(ValueError, match="multi-output"):
        run_with_rule(rule_cls(), ctx)


def test_registry_gates_multi_output_methods(data):
    y = _multi_labels(data, 3)
    wide = dataclasses.replace(data, labels=y)
    spec = ExperimentSpec(
        method="dsvrg", data=wide, q=2, reg=REG, outer_iters=1
    )
    with pytest.raises(ValueError, match="multi-output"):
        solve(spec)


def test_solve_multi_output_end_to_end(data):
    y = _multi_labels(data, 3)
    wide = dataclasses.replace(data, labels=y)
    res = solve(
        ExperimentSpec(
            method="fdsvrg", data=wide, q=2, reg=REG,
            loss="squared", outer_iters=2,
        )
    )
    assert res.w.shape == (data.dim, 3)
    assert np.isfinite(res.final_objective())
