"""Fault injection, recovery semantics, and checkpoint/resume.

Three layers under test:

1. **The wrapper** (`repro.dist.faults`): seeded fault draws, the retry
   loop's honest metering (failed attempts under the ``"retry"`` kind,
   timeout+backoff on the modeled clock), crash arming, q<=1 immunity.
2. **The harness** (`repro.core.driver`): epoch-abort-to-snapshot on any
   FaultError, the divergence guard's eta backoff, abort metering via
   ``RecoveryPolicy.on_abort``, retry exhaustion.
3. **Checkpoint/resume**: a run interrupted at any checkpoint boundary
   and resumed is BIT-identical to the uninterrupted run — iterates,
   objectives, meter counters, and modeled time all exactly equal —
   across the serial, jitted-FD, and worker-simulation drivers, with and
   without the pallas kernels, and through the ``repro.api`` front door.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import losses
from repro.core.driver import (
    CheckpointPolicy,
    DivergenceError,
    RecoveryPolicy,
    run_outer_loop,
)
from repro.core.fdsvrg import (
    SVRGConfig,
    fdsvrg_worker_simulation,
    run_fdsvrg,
    run_serial_svrg,
)
from repro.core.partition import balanced
from repro.data.synthetic import make_sparse_classification
from repro.dist import (
    FaultPlan,
    FaultyBackend,
    RetriesExhaustedError,
    RetryPolicy,
    SimBackend,
    WorkerCrashError,
)

LOSS = losses.logistic
REG = losses.l2(1e-3)
Q = 4


@pytest.fixture(scope="module")
def data():
    return make_sparse_classification(
        dim=256, num_instances=48, nnz_per_instance=8, seed=2
    )


def _cfg(**kw) -> SVRGConfig:
    base = dict(eta=0.3, inner_steps=8, outer_iters=3, seed=13, batch_size=2)
    base.update(kw)
    return SVRGConfig(**base)


# ---------------------------------------------------------------------------
# 1. the wrapper: plans, retries, crashes
# ---------------------------------------------------------------------------


def test_fault_plan_validates_and_normalizes():
    with pytest.raises(ValueError, match="drop_prob"):
        FaultPlan(drop_prob=1.0)
    with pytest.raises(ValueError, match="corrupt_prob"):
        FaultPlan(corrupt_prob=-0.1)
    with pytest.raises(ValueError, match="straggler_delay_s"):
        FaultPlan(straggler_delay_s=-1.0)
    assert FaultPlan().is_noop
    plan = FaultPlan(crash_at_outer=1)  # stray int normalized
    assert plan.crash_at_outer == (1,)
    assert not plan.is_noop


def test_retry_policy_backoff_and_validation():
    rp = RetryPolicy(backoff_base_s=1e-3, backoff_factor=2.0, jitter=0.0)
    assert rp.backoff_s(0, 0.7) == pytest.approx(1e-3)
    assert rp.backoff_s(2, 0.7) == pytest.approx(4e-3)
    jittered = RetryPolicy(backoff_base_s=1e-3, jitter=0.5)
    assert jittered.backoff_s(0, 1.0) == pytest.approx(1.5e-3)
    with pytest.raises(ValueError, match="max_retries"):
        RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError, match=">= 0"):
        RetryPolicy(timeout_s=-0.1)


def test_drop_meters_retry_kind_and_charges_time():
    b = FaultyBackend(
        SimBackend(Q), FaultPlan(seed=0, drop_prob=0.9),
        RetryPolicy(max_retries=64, timeout_s=0.01),
    )
    clean = SimBackend(Q)
    clean.meter_tree(payload=5)
    b.meter_tree(payload=5)
    m = b.meter
    # the delivered collective is metered exactly as the clean one...
    assert m.by_kind["tree_reduce"] == clean.meter.by_kind["tree_reduce"]
    # ...each failed attempt retransmits the SAME 2qp scalars under
    # "retry" (drop_prob=.9 over this seed fires at least once)...
    retry = m.by_kind["retry"]
    assert retry > 0 and retry % (2 * Q * 5) == 0
    assert m.total_scalars == clean.meter.total_scalars + retry
    # ...and every failed attempt's timeout+backoff hit the modeled clock
    assert b.modeled_time_s > clean.modeled_time_s


def test_retries_exhausted_raises_fault():
    b = FaultyBackend(
        SimBackend(Q), FaultPlan(seed=0, drop_prob=0.99),
        RetryPolicy(max_retries=0),
    )
    with pytest.raises(RetriesExhaustedError, match="consecutive"):
        b.meter_tree(payload=3)


def test_straggler_below_timeout_charges_delay_only():
    b = FaultyBackend(
        SimBackend(Q),
        FaultPlan(seed=1, straggler_prob=0.99, straggler_delay_s=1e-3),
        RetryPolicy(timeout_s=0.1),
    )
    clean = SimBackend(Q)
    clean.meter_tree(payload=5)
    b.meter_tree(payload=5)
    # slow but delivered: no retransmission, just a slower clock
    assert "retry" not in b.meter.by_kind
    assert b.meter.total_scalars == clean.meter.total_scalars
    assert b.modeled_time_s > clean.modeled_time_s


def test_straggler_beyond_timeout_is_a_drop():
    # a 10s stall against a 1ms timeout: every attempt times out
    b = FaultyBackend(
        SimBackend(Q),
        FaultPlan(seed=1, straggler_prob=0.99, straggler_delay_s=10.0),
        RetryPolicy(max_retries=1, timeout_s=1e-3),
    )
    with pytest.raises(RetriesExhaustedError):
        b.meter_tree(payload=3)
    assert b.meter.by_kind["retry"] == 2 * (2 * Q * 3)  # both attempts


def test_q1_faults_never_fire():
    b = FaultyBackend(
        SimBackend(1), FaultPlan(seed=0, drop_prob=0.9),
        RetryPolicy(max_retries=0),
    )
    b.meter_tree(payload=5)  # would exhaust retries if the fault path ran
    out = b.all_reduce([jnp.ones(3)])
    np.testing.assert_array_equal(np.asarray(out), np.ones(3))
    assert b.meter.total_scalars == 0


def test_corruption_poisons_the_reduced_payload():
    b = FaultyBackend(SimBackend(Q), FaultPlan(seed=3, corrupt_prob=0.99))
    out = np.asarray(b.all_reduce([jnp.ones(4) for _ in range(Q)]))
    assert np.isnan(out[0])
    assert np.isfinite(out[1:]).all()
    # metered like a clean collective: corruption is silent on the wire
    assert b.meter.by_kind == {"tree_reduce": 2 * Q * 4}


def test_crash_arms_per_outer_and_fires_once():
    b = FaultyBackend(SimBackend(Q), FaultPlan(crash_at_outer=(1,)))
    b.begin_outer(0)
    b.meter_tree(payload=2)  # outer 0: no crash armed
    b.begin_outer(1)
    with pytest.raises(WorkerCrashError, match="outer iteration 1"):
        b.meter_tree(payload=2)
    b.begin_outer(1)  # the restarted attempt must not re-crash
    b.meter_tree(payload=2)


# ---------------------------------------------------------------------------
# 2. the harness: abort-to-snapshot, divergence guard, eta backoff
# ---------------------------------------------------------------------------


def test_crash_without_recovery_propagates(data):
    b = FaultyBackend(SimBackend(Q), FaultPlan(crash_at_outer=(1,)))
    with pytest.raises(WorkerCrashError):
        run_fdsvrg(data, balanced(data.dim, Q), LOSS, REG, _cfg(), backend=b)


def test_crash_recovery_matches_the_clean_run(data):
    """The crash fires at the epoch's first collective — before the
    epoch's sample draw — so the retried epoch replays the same samples
    and the recovered trajectory is bitwise the clean one; the recovery's
    only trace is the metered abort re-distribution and its time."""
    part = balanced(data.dim, Q)
    clean = run_fdsvrg(data, part, LOSS, REG, _cfg(), backend=SimBackend(Q))
    b = FaultyBackend(SimBackend(Q), FaultPlan(crash_at_outer=(1,)))
    res = run_fdsvrg(data, part, LOSS, REG, _cfg(), backend=b,
                     recovery=RecoveryPolicy())
    np.testing.assert_array_equal(np.asarray(res.w), np.asarray(clean.w))
    assert [h.objective for h in res.history] == \
        [h.objective for h in clean.history]
    # one abort: one full-gradient re-broadcast (2*q*N scalars)
    assert res.meter.by_kind["abort"] == 2 * Q * data.num_instances
    assert res.meter.total_scalars == \
        clean.meter.total_scalars + res.meter.by_kind["abort"]


@pytest.mark.chaos
def test_corruption_recovers_via_epoch_abort(data):
    plan = FaultPlan(seed=23, corrupt_prob=0.05)
    b = FaultyBackend(SimBackend(Q), plan, RetryPolicy())
    res = fdsvrg_worker_simulation(
        data, balanced(data.dim, Q), LOSS, REG, _cfg(), backend=b,
        recovery=RecoveryPolicy(max_epoch_retries=4, eta_backoff=1.0),
    )
    assert np.isfinite(res.final_objective())
    assert np.isfinite(np.asarray(res.w)).all()
    # this seed does poison a payload: the divergence guard aborted
    assert res.meter.by_kind["abort"] > 0


def test_divergence_guard_backs_off_eta_and_restores_snapshot():
    seen = []

    def epoch(t, rng, w, z, s0, eta_scale=1.0):
        seen.append(eta_scale)
        return w + eta_scale

    def snapshot(w):
        return w, w

    def evaluate(w, z, s0):
        # the first attempt of outer 0 "diverges"; every retry is finite
        obj = float("nan") if len(seen) == 1 else float(np.asarray(w)[0])
        return obj, 1.0

    res = run_outer_loop(
        outer_iters=2, seed=0, init_w=jnp.zeros(2),
        snapshot=snapshot, epoch=epoch, evaluate=evaluate,
        recovery=RecoveryPolicy(max_epoch_retries=1, eta_backoff=0.5),
    )
    # retry at halved eta; the smaller step persists into outer 1
    assert seen == [1.0, 0.5, 0.5]
    # the failed attempt left no trace: w restarted from the snapshot
    np.testing.assert_array_equal(np.asarray(res.w), np.full(2, 1.0))


def test_recovery_exhaustion_reraises_and_meters_each_abort():
    aborts = []

    def epoch(t, rng, w, z, s0):
        return w

    def snapshot(w):
        return w, w

    def evaluate(w, z, s0):
        return float("nan"), 1.0  # never recovers

    with pytest.raises(DivergenceError, match="non-finite"):
        run_outer_loop(
            outer_iters=1, seed=0, init_w=jnp.zeros(2),
            snapshot=snapshot, epoch=epoch, evaluate=evaluate,
            backend=SimBackend(Q),
            recovery=RecoveryPolicy(
                max_epoch_retries=2, on_abort=lambda b: aborts.append(b.q)
            ),
        )
    assert aborts == [Q, Q]  # one abort per retried attempt


def test_objective_explosion_trips_the_guard():
    def epoch(t, rng, w, z, s0):
        return w + 1.0

    def snapshot(w):
        return w, w

    def evaluate(w, z, s0):
        # finite but exploding: 1.0 then 1e9
        return float(np.asarray(w)[0]) ** 9 + 1.0, 1.0

    with pytest.raises(DivergenceError, match="exploded"):
        run_outer_loop(
            outer_iters=3, seed=0, init_w=jnp.ones(1),
            snapshot=snapshot, epoch=epoch, evaluate=evaluate,
            recovery=RecoveryPolicy(max_epoch_retries=0,
                                    divergence_factor=10.0),
        )


# ---------------------------------------------------------------------------
# 3. checkpoint/resume bit-identity
# ---------------------------------------------------------------------------


def _run_driver(method, data, cfg, use_kernels, checkpoint=None):
    if method == "serial":
        return run_serial_svrg(data, LOSS, REG, cfg,
                               use_kernels=use_kernels, checkpoint=checkpoint)
    part = balanced(data.dim, Q)
    if method == "fdsvrg":
        return run_fdsvrg(data, part, LOSS, REG, cfg, backend=SimBackend(Q),
                          use_kernels=use_kernels, checkpoint=checkpoint)
    return fdsvrg_worker_simulation(
        data, part, LOSS, REG, cfg, backend=SimBackend(Q),
        use_kernels=use_kernels, checkpoint=checkpoint,
    )


def _assert_identical_runs(res, ref):
    np.testing.assert_array_equal(np.asarray(res.w), np.asarray(ref.w))
    for a, b in zip(res.history, ref.history):
        assert a.outer == b.outer
        assert a.objective == b.objective  # exact, not approx
        assert a.grad_norm == b.grad_norm
        assert a.comm_scalars == b.comm_scalars
        assert a.comm_rounds == b.comm_rounds
        assert a.modeled_time_s == b.modeled_time_s
    assert res.meter.state_dict() == ref.meter.state_dict()


@pytest.mark.parametrize("use_kernels", [False, True])
@pytest.mark.parametrize("method", ["serial", "fdsvrg", "fdsvrg_sim"])
def test_resume_is_bit_identical(tmp_path, data, method, use_kernels):
    """Interrupt at outer 2 of 4, resume to completion: iterates,
    objectives, meter counters, and modeled time exactly equal the
    uninterrupted run's — every driver, both kernel settings."""
    full, half = _cfg(outer_iters=4), _cfg(outer_iters=2)
    ref = _run_driver(method, data, full, use_kernels)
    ckdir = str(tmp_path / method)
    _run_driver(method, data, half, use_kernels,
                checkpoint=CheckpointPolicy(directory=ckdir, every=2))
    res = _run_driver(method, data, full, use_kernels,
                      checkpoint=CheckpointPolicy(directory=ckdir, every=2,
                                                  resume=True))
    assert res.history[0].outer == 0  # resumed history includes the prefix
    _assert_identical_runs(res, ref)


def test_resume_flag_with_no_checkpoint_starts_fresh(tmp_path, data):
    """resume=True against an empty directory is a first run, not an
    error — one flag serves both the first launch and every restart."""
    policy = CheckpointPolicy(directory=str(tmp_path / "empty"), resume=True)
    ref = _run_driver("fdsvrg", data, _cfg(), False)
    res = _run_driver("fdsvrg", data, _cfg(), False, checkpoint=policy)
    _assert_identical_runs(res, ref)


def test_resume_after_faulty_run_replays_recovery_state(tmp_path, data):
    """A checkpoint taken AFTER a recovered crash carries the recovery's
    meter (abort + schedule) and clock; resuming reproduces the faulty
    run's final state exactly."""
    part = balanced(data.dim, Q)
    plan = FaultPlan(crash_at_outer=(1,))

    def faulty_run(cfg, checkpoint=None):
        b = FaultyBackend(SimBackend(Q), plan)
        return run_fdsvrg(data, part, LOSS, REG, cfg, backend=b,
                          recovery=RecoveryPolicy(), checkpoint=checkpoint)

    ref = faulty_run(_cfg(outer_iters=4))
    ckdir = str(tmp_path / "faulty")
    faulty_run(_cfg(outer_iters=2),
               checkpoint=CheckpointPolicy(directory=ckdir))
    # the resumed run is past outer 1: its wrapper's crash never fires
    res = faulty_run(_cfg(outer_iters=4),
                     checkpoint=CheckpointPolicy(directory=ckdir,
                                                 resume=True))
    _assert_identical_runs(res, ref)
    assert res.meter.by_kind["abort"] == 2 * Q * data.num_instances


# ---------------------------------------------------------------------------
# the front door: spec / registry / estimator threading
# ---------------------------------------------------------------------------


def test_solve_checkpoint_resume_bit_identity(tmp_path, data):
    from repro.api import ExperimentSpec, solve

    base = dict(method="fdsvrg", data=data, q=Q, reg=REG, eta=0.3,
                batch_size=2, inner_steps=8, seed=5)
    ref = solve(ExperimentSpec(**base, outer_iters=4))
    ckdir = str(tmp_path / "api")
    solve(ExperimentSpec(**base, outer_iters=2, checkpoint_dir=ckdir))
    res = solve(ExperimentSpec(**base, outer_iters=4, checkpoint_dir=ckdir,
                               resume=True))
    _assert_identical_runs(res, ref)


def test_spec_and_registry_validate_checkpointing(tmp_path, data):
    from repro.api import ExperimentSpec, solve

    with pytest.raises(ValueError, match="resume"):
        ExperimentSpec(method="fdsvrg", data=data, reg=REG, resume=True)
    with pytest.raises(ValueError, match="checkpoint_every"):
        ExperimentSpec(method="fdsvrg", data=data, reg=REG,
                       checkpoint_dir=str(tmp_path), checkpoint_every=0)
    with pytest.raises(ValueError, match="checkpoint"):
        solve(ExperimentSpec(method="dsvrg", data=data, reg=REG, q=Q,
                             eta=0.1, inner_steps=8, outer_iters=1,
                             checkpoint_dir=str(tmp_path)))
