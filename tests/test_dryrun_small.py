"""Dry-run machinery at test scale: an 8-device (2 data x 4 model) mesh in a
subprocess, lowering + compiling train/prefill/decode for reduced variants
of three families, plus the roofline HLO parser on real compiled text.

(The full 512-device x 10-arch matrix runs via `python -m
repro.launch.dryrun --both-meshes`; its results are in results/dryrun/.)
"""

import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch.roofline import Roofline, collective_bytes, _shape_bytes


def test_shape_bytes_parser():
    assert _shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert _shape_bytes("bf16[2,4,8]") == 64 * 2
    assert _shape_bytes("(f32[16], s32[4])") == 16 * 4 + 4 * 4
    assert _shape_bytes("pred[]") == 1
    assert _shape_bytes("u8[10]") == 10


def test_collective_parser_on_synthetic_hlo():
    hlo = textwrap.dedent("""
      %ag = f32[64,128] all-gather(f32[4,128] %x), replica_groups={}
      %ar = bf16[256] all-reduce(bf16[256] %y), to_apply=%sum
      %rs = f32[8] reduce-scatter(f32[128] %z), dimensions={0}
      %cp = f32[32] collective-permute(f32[32] %w), source_target_pairs={{0,1}}
      %a2a = f32[16,16] all-to-all(f32[16,16] %v), dimensions={0}
      %notacoll = f32[99] add(f32[99] %a, f32[99] %b)
    """)
    got = collective_bytes(hlo)
    assert got["all-gather"] == 64 * 128 * 4
    assert got["all-reduce"] == 256 * 2
    assert got["reduce-scatter"] == 8 * 4
    assert got["collective-permute"] == 32 * 4
    assert got["all-to-all"] == 16 * 16 * 4
    assert "add" not in got


def test_roofline_dominant_term():
    r = Roofline(flops_total=1e18, hbm_bytes_total=1e12, collective_bytes_per_chip=1e9, chips=256)
    assert r.dominant == "compute"
    r2 = Roofline(flops_total=1e12, hbm_bytes_total=1e15, collective_bytes_per_chip=1e9, chips=256)
    assert r2.dominant == "memory"
    r3 = Roofline(flops_total=1e12, hbm_bytes_total=1e9, collective_bytes_per_chip=1e13, chips=256)
    assert r3.dominant == "collective"


_SUB = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config, reduced_config, INPUT_SHAPES
    from repro.configs.base import InputShape
    from repro.launch.roofline import collective_bytes
    from repro.launch.dryrun import _lower_combo, _rules_overrides
    from repro.models import transformer

    from repro.dist.compat import make_mesh

    mesh = make_mesh((2, 4), ("data", "model"))

    shapes = {
        "train": InputShape("t", 64, 8, "train"),
        "prefill": InputShape("p", 64, 4, "prefill"),
        "decode": InputShape("d", 64, 8, "decode"),
    }
    for arch in ("smollm-360m", "granite-moe-1b-a400m", "jamba-v0.1-52b"):
        cfg = reduced_config(get_config(arch))
        cfg = dataclasses.replace(cfg, ssm_chunk=16)
        for kind, shape in shapes.items():
            ctx = transformer.make_ctx(mesh, cfg, overrides=_rules_overrides(shape))
            lowered = _lower_combo(cfg, shape, mesh, ctx, 2 if kind == "train" else 1)
            compiled = lowered.compile()
            cost = compiled.cost_analysis()
            if isinstance(cost, list):
                cost = cost[0]
            coll = collective_bytes(compiled.as_text())
            assert float(cost.get("flops", 0)) > 0, (arch, kind)
            assert compiled.memory_analysis() is not None
            # sharded models must actually communicate
            assert sum(coll.values()) > 0, (arch, kind, coll)
            print(f"OK {arch} {kind} coll={sorted(coll)}")
    print("SMALL-DRYRUN-OK")
""")


def test_small_mesh_dryrun_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    proc = subprocess.run(
        [sys.executable, "-c", _SUB], capture_output=True, text=True,
        env=env, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "SMALL-DRYRUN-OK" in proc.stdout


@pytest.mark.slow
def test_roofline_auto_populates_and_measures_at_least_one_combo(tmp_path, monkeypatch):
    """Regression guard for the bench that measured nothing: on a fresh
    checkout (empty results/dryrun/) `benchmarks.roofline.run` must
    auto-invoke the dryrun --smoke combo (in a subprocess, so XLA_FLAGS
    land before jax initializes) and come back with >= 1 OK row instead
    of silently rendering an empty table."""
    import benchmarks.roofline as roofline

    monkeypatch.setattr(roofline, "DRYRUN_DIR", str(tmp_path / "dryrun"))
    _, rows = roofline.run()
    ok = sum(1 for r in rows if r and r[3] != "FAIL")
    assert ok >= 1, rows
