"""Data pipeline and dry-run input-spec contracts."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, INPUT_SHAPES, get_config, reduced_config
from repro.data.token_stream import PipelineConfig, batches
from repro.launch.inputs import (
    decode_token_specs,
    prefill_batch_specs,
    train_batch_specs,
)


@pytest.mark.parametrize("arch", ["smollm-360m", "musicgen-large", "paligemma-3b"])
def test_pipeline_matches_input_specs(arch):
    """The pipeline must emit exactly the batch dict input_specs promises."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES["train_4k"]
    specs = train_batch_specs(cfg, shape, grad_accum=1)
    pcfg = PipelineConfig(shape.global_batch, shape.seq_len)
    # generating a full 256x4096 batch is fine on CPU (ints)
    batch = next(batches(cfg, pcfg))
    assert set(batch) == set(specs)
    for k in specs:
        assert batch[k].shape == specs[k].shape, k
        assert jnp.asarray(batch[k]).dtype == specs[k].dtype, k


def test_pipeline_tokens_in_range_and_learnable():
    cfg = reduced_config(get_config("smollm-360m"))
    batch = next(batches(cfg, PipelineConfig(2, 256, seed=1)))
    toks = batch["tokens"]
    assert toks.min() >= 0 and toks.max() < cfg.vocab_size
    # copy motifs present: position 64..72 repeats 56..64
    np.testing.assert_array_equal(toks[0, 64:72], toks[0, 56:64])


def test_grad_accum_reshape():
    cfg = reduced_config(get_config("smollm-360m"))
    batch = next(batches(cfg, PipelineConfig(8, 16, grad_accum=4)))
    assert batch["tokens"].shape == (4, 2, 16)


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("shape_name", ["train_4k", "prefill_32k", "decode_32k"])
def test_input_specs_cover_every_combo(arch, shape_name):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "train":
        specs = train_batch_specs(cfg, shape, grad_accum=4)
        assert all(v.shape[0] == 4 for v in specs.values())
    elif shape.kind == "prefill":
        specs = prefill_batch_specs(cfg, shape)
        assert "tokens" in specs
        if cfg.modality == "vision":
            assert specs["patch_embeds"].shape == (
                shape.global_batch, cfg.num_patches, cfg.frontend_dim
            )
    else:
        tok = decode_token_specs(cfg, shape)
        assert tok.shape[0] == shape.global_batch and tok.shape[1] == 1
        if cfg.modality == "audio-codec":
            assert tok.shape[2] == cfg.num_codebooks


def test_vlm_train_spec_seq_budget():
    """VLM text+patches must sum to the assigned seq_len."""
    cfg = get_config("paligemma-3b")
    shape = INPUT_SHAPES["train_4k"]
    specs = train_batch_specs(cfg, shape, 1)
    assert specs["tokens"].shape[1] + cfg.num_patches == shape.seq_len
    assert specs["labels"].shape[1] == shape.seq_len
