"""Per-architecture smoke tests: REDUCED variant of each assigned family
(<=2-ish layers... exactly one pattern repeat, d_model<=256, <=4 experts),
one forward + one train step on CPU; asserts shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced_config
from repro.configs.base import ModelConfig
from repro.data.token_stream import PipelineConfig, batches
from repro.models import transformer
from repro.optim.optimizers import adamw
from repro.sharding.specs import unsharded_ctx
from repro.train.loop import TrainSettings, init_state, make_train_step


ALL_ARCHS = sorted(ARCHS)


def _smoke_setup(arch: str, batch_size=2, seq=32):
    cfg = reduced_config(get_config(arch))
    ctx = unsharded_ctx()
    pcfg = PipelineConfig(batch_size=batch_size, seq_len=seq, seed=0)
    batch = {k: jnp.asarray(v) for k, v in next(batches(cfg, pcfg)).items()}
    return cfg, ctx, batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg, ctx, batch = _smoke_setup(arch)
    params = transformer.init_params(cfg, jax.random.key(0), tp=1)
    logits, aux = transformer.forward(params, cfg, batch, ctx)
    b = batch["tokens"].shape[0]
    s = 32
    vpad = transformer.padded_vocab(cfg, 1)
    if cfg.modality == "audio-codec":
        assert logits.shape == (b, s, cfg.num_codebooks, vpad)
    else:
        assert logits.shape == (b, s, vpad)
    assert np.all(np.isfinite(np.asarray(logits))), f"{arch}: non-finite logits"
    assert np.all(np.isfinite(np.asarray(aux["lb_loss"])))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step_decreases_nothing_nan(arch):
    cfg, ctx, batch = _smoke_setup(arch)
    opt = adamw(1e-3)
    settings = TrainSettings(grad_accum=1, max_grad_norm=1.0)
    state = init_state(cfg, jax.random.key(1), opt, tp=1)
    step = jax.jit(make_train_step(cfg, ctx, opt, settings))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: NaN loss"
    assert int(state2["step"]) == 1
    # params actually moved
    moved = jax.tree.map(
        lambda a, b_: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b_.astype(jnp.float32)))),
        state["params"], state2["params"],
    )
    assert max(jax.tree.leaves(moved)) > 0.0
    # and a second step keeps everything finite
    state3, metrics3 = step(state2, batch)
    assert np.isfinite(float(metrics3["loss"]))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_loss_decreases_over_few_steps(arch):
    """20 steps on repeated data must reduce the loss (learnability)."""
    cfg, ctx, batch = _smoke_setup(arch, batch_size=2, seq=32)
    opt = adamw(3e-3)
    settings = TrainSettings(max_grad_norm=1.0)
    state = init_state(cfg, jax.random.key(2), opt, tp=1)
    step = jax.jit(make_train_step(cfg, ctx, opt, settings))
    first = None
    for i in range(20):
        state, metrics = step(state, batch)
        if first is None:
            first = float(metrics["ce"])
    last = float(metrics["ce"])
    assert np.isfinite(last)
    assert last < first, f"{arch}: ce {first} -> {last} did not decrease"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_grad_accum_matches_single_batch(arch):
    """grad_accum=2 over a split batch == one step over the full batch."""
    cfg, ctx, _ = _smoke_setup(arch)
    pcfg = PipelineConfig(batch_size=4, seq_len=16, seed=3)
    full = {k: jnp.asarray(v) for k, v in next(batches(cfg, pcfg)).items()}
    split = {k: v.reshape((2, 2) + v.shape[1:]) for k, v in full.items()}

    opt = adamw(1e-3)
    state = init_state(cfg, jax.random.key(4), opt, tp=1)
    step1 = jax.jit(make_train_step(cfg, ctx, opt, TrainSettings(grad_accum=1, max_grad_norm=None)))
    step2 = jax.jit(make_train_step(cfg, ctx, opt, TrainSettings(grad_accum=2, max_grad_norm=None)))
    s1, m1 = step1(state, full)
    s2, m2 = step2(state, split)
    np.testing.assert_allclose(
        float(m1["ce"]), float(m2["ce"]), rtol=2e-3,
    )
    d = jax.tree.map(
        lambda a, b_: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b_.astype(jnp.float32)))),
        s1["params"], s2["params"],
    )
    assert max(jax.tree.leaves(d)) < 5e-2  # same direction, small numeric drift


def test_configs_match_assignment():
    """The full configs carry exactly the assigned hyperparameters."""
    spec = {
        "paligemma-3b": dict(num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1, d_ff=16384, vocab_size=257216),
        "smollm-360m": dict(num_layers=32, d_model=960, num_heads=15, num_kv_heads=5, d_ff=2560, vocab_size=49152),
        "qwen3-14b": dict(num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8, d_ff=17408, vocab_size=151936, qk_norm=True),
        "olmoe-1b-7b": dict(num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16, vocab_size=50304, num_experts=64, top_k=8, moe_d_ff=1024),
        "musicgen-large": dict(num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32, d_ff=8192, vocab_size=2048, num_codebooks=4),
        "jamba-v0.1-52b": dict(num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=65536, num_experts=16, top_k=2),
        "minitron-4b": dict(num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8, d_ff=9216, vocab_size=256000),
        "mamba2-2.7b": dict(num_layers=64, d_model=2560, num_heads=0, vocab_size=50280, ssm_state=128),
        "gemma2-9b": dict(num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8, d_ff=14336, vocab_size=256000, sliding_window=4096, logit_softcap=30.0),
        "granite-moe-1b-a400m": dict(num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8, vocab_size=49155, num_experts=32, top_k=8, moe_d_ff=512),
    }
    assert set(spec) == set(ARCHS)
    for name, fields in spec.items():
        cfg = get_config(name)
        for f, v in fields.items():
            assert getattr(cfg, f) == v, f"{name}.{f}: {getattr(cfg, f)} != {v}"


def test_jamba_pattern_ratio():
    cfg = get_config("jamba-v0.1-52b")
    mixers = [t.mixer for t in cfg.pattern] * cfg.num_repeats
    assert mixers.count("global") == 4  # 1:7 attn:mamba over 32 layers
    assert mixers.count("ssm") == 28
    ffns = [t.ffn for t in cfg.pattern] * cfg.num_repeats
    assert ffns.count("moe") == 16  # MoE every other layer


def test_gemma2_pattern_alternates():
    cfg = get_config("gemma2-9b")
    assert [t.mixer for t in cfg.pattern] == ["local", "global"]
    assert cfg.num_repeats == 21


def test_param_counts_plausible():
    """Sanity-check the 6ND calculators against the nominal model sizes."""
    expected = {
        "qwen3-14b": (12e9, 16e9),
        "gemma2-9b": (8e9, 11e9),
        "mamba2-2.7b": (2.2e9, 3.2e9),
        "smollm-360m": (0.3e9, 0.45e9),
        "olmoe-1b-7b": (6e9, 8e9),
        "minitron-4b": (3.5e9, 5e9),
        "jamba-v0.1-52b": (45e9, 60e9),
        "musicgen-large": (2.5e9, 4e9),
        "granite-moe-1b-a400m": (1e9, 1.7e9),
        "paligemma-3b": (2.2e9, 3.5e9),
    }
    for name, (lo, hi) in expected.items():
        n = get_config(name).param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
    # MoE active < total
    for name in ("olmoe-1b-7b", "granite-moe-1b-a400m", "jamba-v0.1-52b"):
        cfg = get_config(name)
        assert cfg.active_param_count() < cfg.param_count()
