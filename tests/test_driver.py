"""The outer-loop harness and the single §4.5 cost model.

Load-bearing properties after the driver-drift refactor:

1. **Drift guard** — for every method, the measured-sim meter (what the
   driver actually records per outer) and the analytic schedule
   (``benchmarks.common.analytic_outer`` → ``repro.dist.COSTS``) agree on
   scalars-per-outer exactly, and on modeled seconds to float precision.
   A new driver or a edited closed form that drifts breaks this test, not
   a benchmark three PRs later.
2. **Harness semantics** — snapshot rotation (one extra full gradient per
   run, post-epoch z/w pairs), same-iterate reporting for every driver
   including PS-Lite, and the shared rng-stream conventions.
3. Satellites: the `_inner_epoch` recompile fix (lam traced) and
   `use_kernels` plumbed through run_method.  (The BlockCSR cache tests
   moved to tests/test_api.py with the cache itself — it now lives in
   repro.api.cache.)
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, losses
from repro.core.driver import (
    OuterRecord,
    RunResult,
    optimality_norm,
    run_outer_loop,
)
from repro.core.fdsvrg import (
    SVRGConfig,
    _inner_epoch,
    full_gradient,
    fdsvrg_worker_simulation,
    run_fdsvrg,
    run_serial_svrg,
)
from repro.core.partition import balanced
from repro.data.synthetic import make_sparse_classification
from repro.dist import COSTS, ClusterModel

LOSS = losses.logistic
REG = losses.l2(1e-3)


@pytest.fixture(scope="module")
def data():
    # n divisible by q and u so the paper-M conventions are exact integers.
    return make_sparse_classification(
        dim=512, num_instances=48, nnz_per_instance=8, seed=2
    )


def _spec_of(data):
    """A DatasetSpec-shaped view of a synthetic set, for analytic_outer."""
    from repro.data.datasets import DatasetSpec

    return DatasetSpec("synthetic", data.dim, data.num_instances,
                       int(data.nnz_max), 4)


# ---------------------------------------------------------------------------
# 1. the drift guard: measured meter == analytic schedule, per outer
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("q", [2, 4])
@pytest.mark.parametrize(
    "method", ["fdsvrg", "serial", "dsvrg", "synsvrg", "asysvrg", "pslite_sgd"]
)
def test_measured_meter_matches_analytic_schedule(data, method, q):
    """Run each driver at the paper's M convention and assert its meter
    and modeled time equal ``analytic_outer``'s closed form exactly —
    the same CostModel on both sides, by construction AND by measurement."""
    from benchmarks.common import analytic_outer

    n = data.num_instances
    outers, u = 2, 2
    cluster = ClusterModel()
    spec = _spec_of(data)

    if method == "fdsvrg":
        cfg = SVRGConfig(eta=0.2, inner_steps=n // u, outer_iters=outers,
                         batch_size=u)
        res = run_fdsvrg(data, balanced(data.dim, q), LOSS, REG, cfg, cluster)
        t1, c1 = analytic_outer(method, spec, q, u=u, cluster=cluster)
    elif method == "serial":
        cfg = SVRGConfig(eta=0.2, inner_steps=n, outer_iters=outers)
        res = run_serial_svrg(data, LOSS, REG, cfg)
        t1, c1 = analytic_outer(method, spec, q, u=1, cluster=cluster)
    else:
        m = n // q if method in ("dsvrg", "synsvrg") else n
        cfg = SVRGConfig(eta=0.1, inner_steps=m, outer_iters=outers)
        runner = {
            "dsvrg": baselines.run_dsvrg,
            "synsvrg": baselines.run_syn_svrg,
            "asysvrg": baselines.run_asy_svrg,
            "pslite_sgd": baselines.run_pslite_sgd,
        }[method]
        res = runner(data, q, LOSS, REG, cfg, cluster)
        t1, c1 = analytic_outer(method, spec, q, cluster=cluster)

    assert res.meter.total_scalars == outers * c1
    if method == "serial":
        assert res.history[-1].modeled_time_s == 0.0  # serial: no backend
    else:
        np.testing.assert_allclose(
            res.history[-1].modeled_time_s, outers * t1, rtol=1e-12
        )
    # and per-record: the meter is cumulative outer by outer
    for h in res.history:
        assert h.comm_scalars == (h.outer + 1) * c1


@pytest.mark.parametrize("q", [2, 4])
@pytest.mark.parametrize("method", ["fd_saga", "fd_bcd"])
def test_update_rule_meter_matches_analytic_schedule(data, method, q):
    """The update-rule methods meter against the same closed forms: every
    FD-SAGA/FD-BCD scalar the backend records equals the analytic
    schedule (including fd_saga's one-time table-init phase, which the
    schedule carries as an offset — ``CostModel.init_cost``)."""
    from benchmarks.common import analytic_outer
    from repro.data.block_csr import BlockCSR
    from repro.dist import SimBackend
    from repro.optim.update_rules import (
        BCDRule,
        SAGARule,
        make_context,
        run_with_rule,
    )

    n = data.num_instances
    outers, u = 2, 2
    cluster = ClusterModel()
    spec = _spec_of(data)
    block = BlockCSR.from_padded(data, balanced(data.dim, q))
    if method == "fd_saga":
        cfg = SVRGConfig(eta=0.2, inner_steps=n // u, outer_iters=outers,
                         batch_size=u)
        rule = SAGARule()
    else:
        # One cycle over the q blocks per outer (the paper-M convention
        # registered as inner_rule="q").
        cfg = SVRGConfig(eta=0.2, inner_steps=q, outer_iters=outers)
        rule = BCDRule()
    ctx = make_context(block, LOSS, REG, cfg, backend=SimBackend(q, cluster))
    res = run_with_rule(rule, ctx)

    t1, c1 = analytic_outer(method, spec, q, u=u, cluster=cluster)
    t0, c0 = COSTS.init_cost(
        method, n=n, nnz=int(data.nnz_max), q=q, cluster=cluster
    )
    assert res.meter.total_scalars == c0 + outers * c1
    np.testing.assert_allclose(
        res.history[-1].modeled_time_s, t0 + outers * t1, rtol=1e-12
    )
    for h in res.history:
        assert h.comm_scalars == c0 + (h.outer + 1) * c1


@pytest.mark.parametrize("lazy", ["exact", "proba"])
def test_lazy_updates_comm_parity_with_eager_and_analytic(data, lazy):
    """Lazy inner steps change WHERE the decay is applied, never WHAT is
    communicated: per inner step each worker still all-reduces exactly one
    u-vector of partial margins.  Guard against drift — the lazy run's
    meter must equal the eager run's (and the analytic schedule) exactly,
    scalar for scalar, round for round, and the modeled-time history must
    be identical record by record."""
    from benchmarks.common import analytic_outer

    n = data.num_instances
    outers, u, q = 2, 2, 4
    cluster = ClusterModel()
    cfg = SVRGConfig(eta=0.2, inner_steps=n // u, outer_iters=outers,
                     batch_size=u, seed=3)
    part = balanced(data.dim, q)
    eager = run_fdsvrg(data, part, LOSS, REG, cfg, cluster)
    lazy_res = run_fdsvrg(data, part, LOSS, REG, cfg, cluster,
                          lazy_updates=lazy)
    assert lazy_res.meter.total_scalars == eager.meter.total_scalars
    assert lazy_res.meter.total_rounds == eager.meter.total_rounds
    _, c1 = analytic_outer("fdsvrg", _spec_of(data), q, u=u, cluster=cluster)
    assert lazy_res.meter.total_scalars == outers * c1
    for he, hl in zip(eager.history, lazy_res.history):
        assert hl.comm_scalars == he.comm_scalars
        assert hl.modeled_time_s == he.modeled_time_s


def test_worker_simulation_meters_like_the_jitted_driver(data):
    """The message-level executable spec lands on the same closed form."""
    q, outers, m = 4, 2, 10
    cfg = SVRGConfig(eta=0.2, inner_steps=m, outer_iters=outers, seed=3)
    sim = fdsvrg_worker_simulation(data, balanced(data.dim, q), LOSS, REG, cfg)
    _, c1 = COSTS.outer_cost(
        "fdsvrg", n=data.num_instances, d=data.dim, nnz=int(data.nnz_max),
        q=q, u=1, inner_steps=m,
    )
    assert sim.meter.total_scalars == outers * c1


def test_sharded_driver_modeled_time_matches_cost_model(data):
    """run_fdsvrg_sharded charges COSTS too (q=1 mesh: zero scalars, pure
    compute closed form)."""
    import jax

    from repro.core.fdsvrg_shardmap import FDSVRGShardedConfig, run_fdsvrg_sharded

    outers, m, u = 2, 8, 2
    mesh = jax.make_mesh((1,), ("model",))
    cfg = FDSVRGShardedConfig(
        dim=data.dim, num_instances=data.num_instances, nnz_max=data.nnz_max,
        eta=0.2, inner_steps=m, batch_size=u, lam=1e-3,
    )
    res = run_fdsvrg_sharded(data, mesh, cfg, feature_axes=("model",),
                             outer_iters=outers, seed=0)
    t1, c1 = COSTS.outer_cost(
        "fdsvrg", n=data.num_instances, d=data.dim, nnz=int(data.nnz_max),
        q=1, u=u, inner_steps=m,
    )
    assert c1 == 0 and res.meter.total_scalars == 0
    np.testing.assert_allclose(
        res.history[-1].modeled_time_s, outers * t1, rtol=1e-12
    )


def test_cost_model_basic_shapes():
    """Pin the §4.5 closed forms themselves (scalars side)."""
    _, c = COSTS.outer_cost("fdsvrg", n=100, d=1000, nnz=10, q=8, u=4,
                            inner_steps=25)
    assert c == 2 * 8 * 100 + 25 * 2 * 8 * 4
    _, c = COSTS.outer_cost("dsvrg", n=100, d=1000, nnz=10, q=8)
    assert c == 2 * 8 * 1000 + 2 * 1000
    _, c = COSTS.outer_cost("synsvrg", n=96, d=1000, nnz=10, q=8, u=1)
    assert c == 2 * 8 * 1000 + 12 * 8 * (1000 + 20)
    _, c = COSTS.outer_cost("pslite_sgd", n=96, d=1000, nnz=10, q=8)
    assert c == 96 * (1000 + 20)
    _, c = COSTS.outer_cost("asysvrg", n=96, d=1000, nnz=10, q=8)
    assert c == 2 * 8 * 1000 + 96 * (1000 + 20)
    # q = 1 communicates nothing on the tree path
    _, c = COSTS.outer_cost("fdsvrg", n=100, d=1000, nnz=10, q=1, u=1)
    assert c == 0
    with pytest.raises(ValueError):
        COSTS.outer_cost("nope", n=1, d=1, nnz=1, q=1)


# ---------------------------------------------------------------------------
# 2. harness semantics
# ---------------------------------------------------------------------------


def test_harness_rotates_snapshot_one_extra_full_gradient():
    """snapshot runs outer_iters + 1 times (initial + one per epoch) and
    the epoch at outer t consumes the snapshot taken at the iterate
    entering it."""
    calls = {"snapshot": [], "epoch": []}

    def snapshot(w):
        calls["snapshot"].append(float(w[0]))
        return w * 0.0, jnp.zeros((1,))

    def epoch(t, rng, w, z, s0):
        calls["epoch"].append((t, float(w[0])))
        return w + 1.0

    res = run_outer_loop(
        outer_iters=3, seed=0, init_w=jnp.zeros((2,)),
        snapshot=snapshot, epoch=epoch,
        evaluate=lambda w, z, s0: (float(w[0]), 0.0),
    )
    assert calls["snapshot"] == [0.0, 1.0, 2.0, 3.0]  # outers + 1
    assert calls["epoch"] == [(0, 0.0), (1, 1.0), (2, 2.0)]
    assert [h.objective for h in res.history] == [1.0, 2.0, 3.0]
    assert isinstance(res, RunResult)
    assert res.meter.total_scalars == 0  # backend=None: fresh empty meter


@pytest.mark.parametrize(
    "runner",
    [
        lambda d, cfg: baselines.run_pslite_sgd(d, 4, LOSS, REG, cfg),
        lambda d, cfg: baselines.run_asy_svrg(d, 4, LOSS, REG, cfg),
    ],
    ids=["pslite", "asysvrg"],
)
def test_async_grad_norm_recorded_at_post_epoch_iterate(data, runner):
    """The async pair reports the same-iterate residual like everyone
    else (PS-Lite included — its snapshot is reporting-only)."""
    cfg = SVRGConfig(eta=0.1, inner_steps=16, outer_iters=2, seed=13)
    res = runner(data, cfg)
    gd, _ = full_gradient(data, res.w, LOSS)
    want = optimality_norm(gd, res.w, REG, cfg.eta)
    np.testing.assert_allclose(res.history[-1].grad_norm, want, rtol=1e-4,
                               atol=1e-7)


def test_history_schema_uniform_across_all_drivers(data):
    """Every driver emits the same OuterRecord schema with finite
    objectives — the shard_map driver included (no more bare tuples)."""
    import jax

    from repro.core.fdsvrg_shardmap import FDSVRGShardedConfig, run_fdsvrg_sharded

    cfg = SVRGConfig(eta=0.1, inner_steps=8, outer_iters=2, seed=1)
    mesh = jax.make_mesh((1,), ("model",))
    sh_cfg = FDSVRGShardedConfig(
        dim=data.dim, num_instances=data.num_instances, nnz_max=data.nnz_max,
        eta=0.1, inner_steps=8, batch_size=1, lam=1e-3,
    )
    results = [
        run_serial_svrg(data, LOSS, REG, cfg),
        run_fdsvrg(data, balanced(data.dim, 4), LOSS, REG, cfg),
        fdsvrg_worker_simulation(data, balanced(data.dim, 4), LOSS, REG, cfg),
        baselines.run_dsvrg(data, 4, LOSS, REG, cfg),
        baselines.run_syn_svrg(data, 4, LOSS, REG, cfg),
        baselines.run_asy_svrg(data, 4, LOSS, REG, cfg),
        baselines.run_pslite_sgd(data, 4, LOSS, REG, cfg),
        run_fdsvrg_sharded(data, mesh, sh_cfg, feature_axes=("model",),
                           outer_iters=2, seed=1),
    ]
    for res in results:
        assert isinstance(res, RunResult)
        assert len(res.history) == 2
        for h in res.history:
            assert isinstance(h, OuterRecord)
            assert np.isfinite(h.objective)
            assert np.isfinite(h.grad_norm)
            assert h.wall_time_s >= 0.0
        assert res.history[0].wall_time_s <= res.history[-1].wall_time_s


# ---------------------------------------------------------------------------
# 3. satellites
# ---------------------------------------------------------------------------


def test_inner_epoch_compiles_once_across_lambda_sweep(data):
    """lam is traced (like _async_epoch): a 3-lambda sweep reuses ONE
    compiled scan instead of recompiling per point (the
    lambda_sensitivity regression)."""
    cfg = SVRGConfig(eta=0.2, inner_steps=4, outer_iters=1)
    before = _inner_epoch._cache_size()
    for lam in (1e-3, 2e-3, 5e-3):
        run_fdsvrg(data, balanced(data.dim, 4), LOSS, losses.l2(lam), cfg)
    assert _inner_epoch._cache_size() - before <= 1
    # and the traced path matches a fresh static-value run numerically
    a = run_fdsvrg(data, balanced(data.dim, 4), LOSS, losses.l2(2e-3), cfg)
    b = run_serial_svrg(data, LOSS, losses.l2(2e-3), cfg)
    np.testing.assert_allclose(np.asarray(a.w), np.asarray(b.w),
                               rtol=2e-4, atol=2e-6)


def test_inner_epoch_kernels_require_static_lams(data):
    """The fused kernels bake lambda in at compile time — calling the
    kernel path without the static triple fails loudly, not silently."""
    from repro.data.block_csr import BlockCSR

    block = BlockCSR.from_padded(data, balanced(data.dim, 1))
    with pytest.raises(ValueError, match="kernel_lams"):
        _inner_epoch(
            block.indices, block.values, data.labels,
            jnp.zeros((data.dim,)), jnp.zeros((data.dim,)),
            jnp.zeros((data.num_instances,)),
            jnp.zeros((2, 1), jnp.int32), 0.1, jnp.ones(2, jnp.float32),
            "logistic", "l2", 1e-3, block.block_dims, True,
        )


@pytest.mark.parametrize("method", ["serial", "fdsvrg"])
def test_run_method_plumbs_use_kernels(data, method):
    """BENCH_* trajectories can exercise the Pallas hot path: run_method's
    use_kernels flag reaches the drivers and stays bit-identical."""
    import benchmarks.common as common

    ref = common.run_method(method, data, 4, 1e-3, outer_iters=2)
    ker = common.run_method(method, data, 4, 1e-3, outer_iters=2,
                            use_kernels=True)
    np.testing.assert_array_equal(np.asarray(ref.w), np.asarray(ker.w))
    assert ref.meter.total_scalars == ker.meter.total_scalars


# ---------------------------------------------------------------------------
# 4. honest accounting under faults: the drift guard, faulted
# ---------------------------------------------------------------------------


def test_faulty_meter_is_analytic_schedule_plus_exact_retries(data):
    """Under a drop-fault plan the meter stays falsifiable: the delivered
    traffic equals the fault-free analytic schedule EXACTLY (same closed
    form as the clean drift guard), and the total exceeds it by exactly
    the retransmitted bytes recorded under the ``"retry"`` kind — which
    an independent replay of the same seeded plan over the driver's
    metering call sequence reproduces scalar-for-scalar."""
    from benchmarks.common import analytic_outer
    from repro.dist import FaultPlan, FaultyBackend, RetryPolicy, SimBackend

    q, u, outers = 4, 2, 2
    n = data.num_instances
    cluster = ClusterModel()
    cfg = SVRGConfig(eta=0.2, inner_steps=n // u, outer_iters=outers,
                     batch_size=u)
    plan = FaultPlan(seed=5, drop_prob=0.2)
    retry = RetryPolicy(max_retries=8)
    backend = FaultyBackend(SimBackend(q, cluster), plan, retry)
    res = run_fdsvrg(data, balanced(data.dim, q), LOSS, REG, cfg,
                     backend=backend)

    _, c1 = analytic_outer("fdsvrg", _spec_of(data), q, u=u, cluster=cluster)
    m = res.meter
    # delivered collectives: the fault-free schedule, untouched
    assert m.by_kind["tree_reduce"] == outers * c1
    # retransmissions: present, and the only thing added to the total
    retries = m.by_kind["retry"]
    assert retries > 0
    assert m.total_scalars == outers * c1 + retries

    # independent replay: same plan + policy over the jitted driver's
    # metering sequence (per outer: one N-payload tree, then M u-trees)
    replay = FaultyBackend(SimBackend(q, cluster), plan, retry)
    for _ in range(outers):
        replay.meter_tree(payload=n)
        replay.meter_tree(payload=u, steps=cfg.inner_steps)
    assert replay.meter.by_kind["retry"] == retries

    # drops retransmit deterministic partials: the trajectory cannot move
    clean = run_fdsvrg(data, balanced(data.dim, q), LOSS, REG, cfg, cluster)
    np.testing.assert_array_equal(np.asarray(res.w), np.asarray(clean.w))
    assert [h.objective for h in res.history] == \
        [h.objective for h in clean.history]
