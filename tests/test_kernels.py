"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
swept over shapes (including non-tile-multiples, exercising the padding
paths) and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev-only dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


RNG = np.random.default_rng(0)


def _rand(shape, dtype):
    x = RNG.normal(0.0, 1.0, size=shape)
    return jnp.asarray(x, dtype=dtype)


# ---------------------------------------------------------------------------
# fd_matvec
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d,n", [(512, 256), (1024, 512), (777, 130), (512, 1), (1, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fd_matvec_matches_ref(d, n, dtype):
    w = _rand((d,), dtype)
    data = _rand((d, n), dtype)
    got = ops.margins_dense(w, data, interpret=True)
    want = ref.fd_matvec_ref(w[:, None], data)[0]
    tol = 2e-4 if dtype == jnp.float32 else 3e-2  # f32 sums over d terms
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)
    assert got.dtype == jnp.float32  # f32 accumulation regardless of input


@pytest.mark.parametrize("block_k,block_n", [(128, 128), (256, 512), (512, 256)])
def test_fd_matvec_block_shape_sweep(block_k, block_n):
    w = _rand((1200,), jnp.float32)
    data = _rand((1200, 300), jnp.float32)
    got = ops.margins_dense(w, data, block_k=block_k, block_n=block_n, interpret=True)
    want = ref.fd_matvec_ref(w[:, None], data)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# logistic_grad
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [8, 1000, 1024, 4097])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_logistic_grad_matches_ref(n, dtype):
    s = _rand((n,), dtype) * 3
    y = jnp.sign(_rand((n,), jnp.float32)) + (jnp.sign(_rand((n,), jnp.float32)) == 0)
    loss, dloss = ops.loss_and_grad(s, y.astype(dtype), interpret=True)
    loss_w, dloss_w = ref.logistic_grad_ref(s, y)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(loss), np.asarray(loss_w), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(dloss), np.asarray(dloss_w), rtol=tol, atol=tol)


def test_logistic_grad_extreme_margins_stable():
    s = jnp.asarray([-1e4, -50.0, 0.0, 50.0, 1e4])
    y = jnp.ones(5)
    loss, dloss = ops.loss_and_grad(s, y, interpret=True)
    assert np.all(np.isfinite(np.asarray(loss)))
    assert np.all(np.isfinite(np.asarray(dloss)))
    assert float(loss[4]) == pytest.approx(0.0, abs=1e-6)
    assert float(dloss[0]) == pytest.approx(-1.0, abs=1e-6)


# ---------------------------------------------------------------------------
# svrg_update
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d", [2048, 2049, 100, 65536])
@pytest.mark.parametrize("eta,lam", [(0.1, 1e-4), (0.5, 0.0), (0.01, 1e-2)])
def test_svrg_update_matches_ref(d, eta, lam):
    w = _rand((d,), jnp.float32)
    g = _rand((d,), jnp.float32)
    z = _rand((d,), jnp.float32)
    got = ops.svrg_dense_update(w, g, z, eta=eta, lam=lam, interpret=True)
    want = ref.svrg_update_ref(w, g, z, eta=eta, lam=lam)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


@given(
    st.integers(min_value=1, max_value=300),
    st.floats(min_value=1e-4, max_value=1.0),
    st.floats(min_value=0.0, max_value=0.1),
)
@settings(max_examples=20, deadline=None)
def test_svrg_update_property(d, eta, lam):
    rng = np.random.default_rng(d)
    w = jnp.asarray(rng.normal(size=d).astype(np.float32))
    g = jnp.asarray(rng.normal(size=d).astype(np.float32))
    z = jnp.asarray(rng.normal(size=d).astype(np.float32))
    got = ops.svrg_dense_update(w, g, z, eta=float(eta), lam=float(lam), interpret=True)
    want = ref.svrg_update_ref(w, g, z, eta=float(eta), lam=float(lam))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash_decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "h,hkv,dh,s,length",
    [
        (8, 8, 64, 1024, 1024),   # MHA, full cache
        (8, 2, 64, 1024, 700),    # GQA, partial cache
        (16, 4, 128, 2048, 1),    # single valid position
        (4, 1, 32, 300, 257),     # MQA, non-multiple S (padding path)
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_matches_ref(h, hkv, dh, s, length, dtype):
    q = _rand((h, dh), dtype)
    k = _rand((s, hkv, dh), dtype)
    v = _rand((s, hkv, dh), dtype)
    got = ops.decode_attention(q, k, v, length=length, interpret=True, block_s=256)
    want = ref.flash_decode_ref(q, k, v, length=length)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_flash_decode_block_sweep():
    q = _rand((8, 64), jnp.float32)
    k = _rand((1024, 4, 64), jnp.float32)
    v = _rand((1024, 4, 64), jnp.float32)
    want = ref.flash_decode_ref(q, k, v, length=900)
    for bs in (128, 256, 512, 1024):
        got = ops.decode_attention(q, k, v, length=900, block_s=bs, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_decode_matches_ref_long_cache():
    """32k-token cache (the decode_32k shape, one batch element)."""
    q = _rand((8, 64), jnp.bfloat16)
    k = _rand((32768, 8, 64), jnp.bfloat16)
    v = _rand((32768, 8, 64), jnp.bfloat16)
    got = ops.decode_attention(q, k, v, length=31000, interpret=True)
    want = ref.flash_decode_ref(q, k, v, length=31000)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# kernels against the *algorithm* (integration): one SVRG step via kernels
# equals one step of the reference implementation
# ---------------------------------------------------------------------------


def test_kernel_composed_svrg_step_matches_core():
    from repro.core import losses
    from repro.data.synthetic import make_dense_classification

    d, n = 640, 32
    D, y = make_dense_classification(dim=d, num_instances=n, seed=0)
    D = jnp.asarray(D)
    y = jnp.asarray(y)
    w = jnp.asarray(RNG.normal(size=d).astype(np.float32)) * 0.1
    eta, lam = 0.2, 1e-3

    # full-gradient phase via kernels
    s0 = ops.margins_dense(w, D, interpret=True)
    _, dl0 = ops.loss_and_grad(s0, y, interpret=True)
    z = D @ (dl0 / n)

    # one inner step on instance 3 via kernels
    x3 = D[:, 3]
    s_m = ops.margins_dense(w, D[:, 3:4], interpret=True)[0]
    _, dl_m = ops.loss_and_grad(s_m[None], y[3:4], interpret=True)
    g_sparse = (dl_m[0] - dl0[3]) * x3
    w_next = ops.svrg_dense_update(w, g_sparse, z, eta=eta, lam=lam, interpret=True)

    # reference: plain jnp
    s0_ref = D.T @ w
    dl0_ref = losses.logistic.dvalue(s0_ref, y)
    z_ref = D @ (dl0_ref / n)
    s_m_ref = x3 @ w
    coef = losses.logistic.dvalue(s_m_ref, y[3]) - dl0_ref[3]
    w_next_ref = w - eta * (coef * x3 + z_ref + lam * w)

    np.testing.assert_allclose(
        np.asarray(w_next), np.asarray(w_next_ref), rtol=2e-5, atol=2e-5
    )
