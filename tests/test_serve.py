"""Serving-path integration: prefill + token-by-token decode reproduces the
full-forward logits for every architecture family (the contract the
decode_32k / long_500k dry-run shapes rely on)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models import transformer
from repro.sharding.specs import unsharded_ctx
from repro.train.serve import make_serve_step

CTX = unsharded_ctx()

# one representative per family (full 10 covered by smoke tests; serving
# consistency is family-level behaviour)
FAMILY_ARCHS = [
    "smollm-360m",      # dense
    "gemma2-9b",        # dense local/global + softcaps + post-norm
    "olmoe-1b-7b",      # moe
    "mamba2-2.7b",      # ssm
    "jamba-v0.1-52b",   # hybrid
    "musicgen-large",   # audio
    "paligemma-3b",     # vlm
]


def _inputs(cfg, b, s, rng):
    if cfg.modality == "audio-codec":
        toks = rng.integers(0, cfg.vocab_size, size=(b, s, cfg.num_codebooks))
        return {"tokens": jnp.asarray(toks, jnp.int32)}
    if cfg.modality == "vision":
        toks = rng.integers(0, cfg.vocab_size, size=(b, s - cfg.num_patches))
        patches = rng.normal(0, 1, size=(b, cfg.num_patches, cfg.frontend_dim))
        return {
            "tokens": jnp.asarray(toks, jnp.int32),
            "patch_embeds": jnp.asarray(patches, jnp.float32),
        }
    toks = rng.integers(0, cfg.vocab_size, size=(b, s))
    return {"tokens": jnp.asarray(toks, jnp.int32)}


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    cfg = reduced_config(get_config(arch))
    rng = np.random.default_rng(0)
    b, s_total, s_prefix = 2, 16, 12
    batch_full = _inputs(cfg, b, s_total, rng)
    params = transformer.init_params(cfg, jax.random.key(0), tp=1)

    # ground truth: full forward logits
    logits_full, _ = transformer.forward(params, cfg, batch_full, CTX)

    # serving: prefill the prefix, decode the rest token by token
    if cfg.modality == "vision":
        text = batch_full["tokens"]
        prefix_batch = {
            "tokens": text[:, : s_prefix - cfg.num_patches],
            "patch_embeds": batch_full["patch_embeds"],
        }
        stream = text[:, s_prefix - cfg.num_patches :]
    elif cfg.modality == "audio-codec":
        prefix_batch = {"tokens": batch_full["tokens"][:, :s_prefix]}
        stream = batch_full["tokens"][:, s_prefix:]
    else:
        prefix_batch = {"tokens": batch_full["tokens"][:, :s_prefix]}
        stream = batch_full["tokens"][:, s_prefix:]

    last_logits, cache = transformer.prefill(params, cfg, prefix_batch, s_total, CTX)

    # prefill's last-position logits == forward at position s_prefix-1
    np.testing.assert_allclose(
        np.asarray(last_logits[:, 0]),
        np.asarray(logits_full[:, s_prefix - 1]),
        rtol=2e-3, atol=2e-3,
    )

    logits_dec = []
    for i in range(s_total - s_prefix):
        tok = stream[:, i : i + 1]
        pos = jnp.asarray(s_prefix + i, jnp.int32)
        lg, cache = transformer.decode_step(params, cfg, cache, tok, pos, CTX)
        logits_dec.append(lg)
    logits_dec = jnp.concatenate(logits_dec, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec),
        np.asarray(logits_full[:, s_prefix:]),
        rtol=2e-3, atol=2e-3,
    )


def test_serve_step_masks_padded_vocab():
    cfg = reduced_config(get_config("granite-moe-1b-a400m"))  # vocab 512 (reduced)
    import dataclasses
    cfg = dataclasses.replace(cfg, vocab_size=509)  # force padding
    params = transformer.init_params(cfg, jax.random.key(0), tp=4)
    ctx = CTX
    cache = transformer.init_cache(cfg, 2, 8, ctx, tp=4)
    step = make_serve_step(cfg, ctx)
    toks = jnp.zeros((2, 1), jnp.int32)
    nxt, logits, cache = step(params, cache, toks, jnp.asarray(0, jnp.int32))
    assert int(jnp.max(nxt)) < 509  # never samples a padded id
    assert np.all(np.isfinite(np.asarray(logits[..., :509])))


def test_greedy_generate_runs():
    from repro.train.serve import greedy_generate

    cfg = reduced_config(get_config("smollm-360m"))
    params = transformer.init_params(cfg, jax.random.key(0), tp=1)
    prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    out = greedy_generate(params, cfg, CTX, prompt, steps=4, max_len=16)
    assert out.shape == (1, 4)
    assert np.all(np.asarray(out) >= 0)
