"""`repro.serve` — engine/batcher/loop contracts.

The load-bearing assertions:

* engine margins are BIT-identical to ``FDSVRGClassifier.
  decision_function`` on the same rows — across snapshot forms
  (dense / per-worker blocks), ``use_kernels`` on/off, and ``k > 1``;
* the batcher maps arbitrary-nnz requests onto the bounded power-of-two
  shape universe and its padding is bit-inert (round-trip through a
  flushed batch serves the same bits as scoring the row alone);
* the serve loop's snapshot/version/staleness contract: publishes are
  monotone and atomic, batches pin the snapshot they flushed against,
  and every served margin is reproducible from the version it reports.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import FDSVRGClassifier
from repro.core.partition import balanced
from repro.data.sparse import PaddedCSR
from repro.serve import (
    MicroBatcher,
    PredictionEngine,
    WeightSnapshot,
    bucket_width,
    run_serve_loop,
    synthetic_request_source,
)
from repro.serve.engine import batched_margins

pytestmark = pytest.mark.serve


def _fit_binary(data, *, use_kernels=False, **kw):
    kw.setdefault("method", "serial")
    kw.setdefault("eta", 0.3)
    kw.setdefault("lam", 1e-3)
    kw.setdefault("inner_steps", 16)
    kw.setdefault("outer_iters", 2)
    clf = FDSVRGClassifier(use_kernels=use_kernels, **kw)
    clf.fit(data)
    return clf


@pytest.fixture(scope="module")
def stream():
    return synthetic_request_source(
        dim=256, num_requests=300, nnz_lo=2, nnz_hi=16, seed=0
    )


@pytest.fixture(scope="module")
def data(stream):
    return stream.materialize()


# ---------------------------------------------------------------------------
# engine == decision_function (the tentpole bit contract)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_kernels", [False, True])
def test_engine_bitwise_matches_decision_function(data, use_kernels):
    clf = _fit_binary(data, use_kernels=use_kernels)
    engine = PredictionEngine.from_estimator(clf, use_kernels=use_kernels)
    got = engine.margins(data.indices, data.values)
    want = clf.decision_function(data)
    np.testing.assert_array_equal(got, want)


def test_engine_kernel_and_ref_paths_agree_bitwise(data):
    clf = _fit_binary(data)
    ref = PredictionEngine.from_estimator(clf, use_kernels=False)
    krn = PredictionEngine.from_estimator(clf, use_kernels=True)
    np.testing.assert_array_equal(
        ref.margins(data.indices, data.values),
        krn.margins(data.indices, data.values),
    )


@pytest.mark.parametrize("q", [2, 4, 7])
def test_block_snapshot_serves_identically_to_dense(data, q):
    clf = _fit_binary(data)
    dense = PredictionEngine.from_estimator(clf)
    w = np.asarray(clf.coef_)
    part = balanced(data.dim, q)
    blocks = [w[lo:hi] for lo, hi in (part.block(l) for l in range(q))]
    blocked = PredictionEngine(WeightSnapshot.from_blocks(blocks, 0))
    np.testing.assert_array_equal(
        dense.margins(data.indices, data.values),
        blocked.margins(data.indices, data.values),
    )


@pytest.mark.parametrize("use_kernels", [False, True])
def test_multioutput_engine_bitwise_matches_decision_function(use_kernels):
    rng = np.random.default_rng(3)
    X = rng.normal(size=(90, 40)) * (rng.random((90, 40)) < 0.3)
    y = rng.integers(0, 3, size=90)
    # multi-output TRAINING is jnp-only; kernels are an inference-side
    # choice, so fit without and flip the flag for serving
    clf = FDSVRGClassifier(method="serial", eta=0.4, lam=1e-4,
                           inner_steps=32, outer_iters=3)
    clf.fit(X, y)
    clf.use_kernels = use_kernels
    assert clf.coef_.shape == (3, 40)
    engine = PredictionEngine.from_estimator(clf, use_kernels=use_kernels)
    Xp = clf._inference_data(X)
    got = engine.margins(Xp.indices, Xp.values)
    want = clf.decision_function(X)
    assert got.shape == (90, 3)
    np.testing.assert_array_equal(got, want)
    # block-published multi-output snapshot serves the same bits
    w = np.asarray(clf.coef_).T  # [d, k]
    part = balanced(40, 3)
    blocks = [w[lo:hi] for lo, hi in (part.block(l) for l in range(3))]
    blocked = PredictionEngine(
        WeightSnapshot.from_blocks(blocks, 0), use_kernels=use_kernels
    )
    np.testing.assert_array_equal(
        blocked.margins(Xp.indices, Xp.values), want
    )


def test_empty_batch_margins(data):
    clf = _fit_binary(data)
    engine = PredictionEngine.from_estimator(clf)
    out = engine.margins(
        np.zeros((0, 8), np.int32), np.zeros((0, 8), np.float32)
    )
    assert out.shape == (0,)


def test_batched_margins_validates_shapes():
    w = np.ones(8, np.float32)
    with pytest.raises(ValueError, match="matching"):
        batched_margins(np.zeros((2, 3), np.int32),
                        np.zeros((2, 4), np.float32), w)
    with pytest.raises(ValueError, match=r"\[d\] or \[d, k\]"):
        batched_margins(np.zeros((2, 3), np.int32),
                        np.zeros((2, 3), np.float32),
                        np.ones((2, 2, 2), np.float32))


# ---------------------------------------------------------------------------
# snapshots: versioning, publish semantics
# ---------------------------------------------------------------------------


def test_snapshot_publish_is_monotone(data):
    clf = _fit_binary(data)
    engine = PredictionEngine.from_estimator(clf)  # version 0
    w = engine.snapshot.w
    prev = engine.publish(WeightSnapshot(w=w * 2, version=3))
    assert prev.version == 0 and engine.version == 3
    with pytest.raises(ValueError, match="not newer"):
        engine.publish(WeightSnapshot(w=w, version=3))
    with pytest.raises(ValueError, match="not newer"):
        engine.publish(WeightSnapshot(w=w, version=1))
    with pytest.raises(ValueError, match="dim"):
        engine.publish(WeightSnapshot(w=w[:-1], version=9))
    assert engine.version == 3  # failed publishes change nothing


def test_engine_without_snapshot_raises():
    engine = PredictionEngine()
    with pytest.raises(ValueError, match="no snapshot"):
        engine.margins(np.zeros((1, 4), np.int32), np.zeros((1, 4), np.float32))


def test_snapshot_constructors_validate():
    with pytest.raises(ValueError, match=r"\[d\] or \[d, k\]"):
        WeightSnapshot(w=jnp.ones((2, 2, 2)), version=0)
    with pytest.raises(ValueError, match="at least one"):
        WeightSnapshot.from_blocks([], version=0)
    with pytest.raises(ValueError, match="ndims"):
        WeightSnapshot.from_blocks([jnp.ones(3), jnp.ones((3, 2))], version=0)
    snap = WeightSnapshot.from_blocks([jnp.ones((3, 2)), jnp.ones((5, 2))], 1)
    assert snap.dim == 8 and snap.num_outputs == 2 and snap.version == 1


def test_snapshot_from_estimator_orientation(data):
    clf = _fit_binary(data)
    snap = WeightSnapshot.from_estimator(clf, 7)
    assert snap.w.ndim == 1 and snap.dim == data.dim and snap.version == 7


# ---------------------------------------------------------------------------
# batcher: buckets, deadlines, padding
# ---------------------------------------------------------------------------


def test_bucket_width_powers_of_two():
    assert [bucket_width(n) for n in (0, 1, 8, 9, 16, 17, 100)] == \
        [8, 8, 8, 16, 16, 32, 128]
    assert bucket_width(3, min_width=1) == 4
    with pytest.raises(ValueError):
        bucket_width(-1)


def test_batcher_full_flush_and_row_padding():
    clock = [0.0]
    b = MicroBatcher(max_batch=4, max_delay_s=10.0, min_width=4,
                     clock=lambda: clock[0])
    for i in range(4):
        b.submit([1, 2], [1.0, float(i)])
    batches = b.ready()
    assert len(batches) == 1 and batches[0].cause == "full"
    assert batches[0].shape == (4, 4) and batches[0].n_valid == 4
    # three requests deadline-flush into a pow2 row bucket of 4
    for i in range(3):
        b.submit([5], [2.0])
    assert b.ready() == []  # not full, deadline not reached
    clock[0] = 11.0
    (batch,) = b.ready()
    assert batch.cause == "deadline" and batch.shape == (4, 4)
    assert batch.n_valid == 3
    np.testing.assert_array_equal(batch.values[3], np.zeros(4))
    assert b.pending == 0


def test_batcher_routes_by_width_bucket():
    b = MicroBatcher(max_batch=8, max_delay_s=0.0, min_width=4)
    b.submit(np.arange(3), np.ones(3))     # width 4
    b.submit(np.arange(6), np.ones(6))     # width 8
    b.submit(np.arange(4), np.ones(4))     # width 4
    batches = b.ready()
    assert sorted(bb.shape for bb in batches) == [(1, 8), (2, 4)]
    assert {bb.cause for bb in batches} == {"deadline"}


def test_batcher_drain_and_shape_universe():
    b = MicroBatcher(max_batch=16, max_delay_s=1e9, min_width=4)
    rng = np.random.default_rng(0)
    for _ in range(200):
        nnz = int(rng.integers(1, 40))
        b.submit(rng.integers(0, 99, nnz), rng.normal(size=nnz))
    batches = b.drain()
    assert b.pending == 0
    assert all(bb.cause == "drain" for bb in batches)
    # every shape is (pow2 rows <= max_batch, pow2 width >= min_width)
    for rows, width in b.bucket_counts:
        assert rows & (rows - 1) == 0 and rows <= 16
        assert width & (width - 1) == 0 and width >= 4
    assert sum(bb.n_valid for bb in batches) == 200


def test_batcher_padding_round_trips_bits(data):
    """A row scored through a flushed (row- and width-padded) batch
    serves the same bits as the row scored alone at the bucket width —
    padding is representation, not data."""
    clf = _fit_binary(data)
    engine = PredictionEngine.from_estimator(clf)
    b = MicroBatcher(max_batch=8, max_delay_s=0.0, min_width=4)
    idx = np.asarray(data.indices)
    val = np.asarray(data.values)
    reqs = []
    for r in range(20):
        m = val[r] != 0.0
        reqs.append((idx[r, m], val[r, m]))
        b.submit(idx[r, m], val[r, m])
    served = {}
    for batch in b.ready() + b.drain():
        out = engine.margins(batch.indices, batch.values)
        for i, req in enumerate(batch.requests):
            served[req.req_id] = out[i]
    for rid, (ri, rv) in enumerate(reqs):
        width = bucket_width(len(ri), min_width=4)
        pi = np.zeros((1, width), np.int32)
        pv = np.zeros((1, width), np.float32)
        pi[0, : len(ri)] = ri
        pv[0, : len(rv)] = rv
        alone = engine.margins(pi, pv)[0]
        np.testing.assert_array_equal(served[rid], alone)


def test_batcher_validation():
    with pytest.raises(ValueError, match="power of two"):
        MicroBatcher(max_batch=6)
    with pytest.raises(ValueError, match="power of two"):
        MicroBatcher(min_width=3)
    b = MicroBatcher()
    with pytest.raises(ValueError, match="mismatch"):
        b.submit([1, 2], [1.0])


def test_engine_compiled_shape_metering(data):
    clf = _fit_binary(data)
    engine = PredictionEngine.from_estimator(clf)
    i8 = np.zeros((4, 8), np.int32)
    v8 = np.zeros((4, 8), np.float32)
    engine.margins(i8, v8)
    engine.margins(i8, v8)  # same shape: no new compile
    assert len(engine.compiled_shapes) == 1
    engine.margins(np.zeros((4, 16), np.int32), np.zeros((4, 16), np.float32))
    engine.margins(np.zeros((8, 8), np.int32), np.zeros((8, 8), np.float32))
    assert len(engine.compiled_shapes) == 3
    assert engine.batches_served == 4 and engine.rows_served == 20


# ---------------------------------------------------------------------------
# the serve loop: interleaved partial_fit, version swaps, staleness
# ---------------------------------------------------------------------------


def _warmup(stream, n=128, **kw):
    data = stream.materialize()
    warm = PaddedCSR(
        indices=data.indices[:n], values=data.values[:n],
        labels=data.labels[:n], dim=data.dim,
    )
    return _fit_binary(warm, **kw)


def test_serve_loop_interleaves_updates(stream):
    clf = _warmup(stream, inner_steps=8, outer_iters=1)
    engine = PredictionEngine.from_estimator(clf)
    # record every published weight vector so each served margin can be
    # replayed against the exact version it reports
    published = {0: np.asarray(engine.snapshot.w)}
    orig_publish = engine.publish

    def recording_publish(snap):
        published[snap.version] = np.asarray(snap.w)
        return orig_publish(snap)

    engine.publish = recording_publish
    batcher = MicroBatcher(max_batch=32, max_delay_s=0.0, min_width=4)
    report = run_serve_loop(
        stream, engine, batcher,
        classifier=clf, update_every_chunks=2, chunk_rows=50,
    )
    # every request served exactly once
    assert report.num_requests == 300
    assert sorted(r.req_id for r in report.served) == list(range(300))
    # the version counter advanced mid-stream (not just at the end):
    # requests were served at more than one version
    assert report.versions_published >= 2
    versions_used = {r.version_used for r in report.served}
    assert len(versions_used) >= 2
    # staleness: batches flushed before an update and served after it
    # report staleness 1; others 0.  Both must occur.
    hist = report.staleness_histogram()
    assert set(hist) == {0, 1} and hist[0] > 0 and hist[1] > 0
    assert report.num_batches == sum(report.bucket_counts.values())
    assert report.compiled_shapes >= 1
    lat = report.latency_percentiles()
    assert 0 <= lat["p50_ms"] <= lat["p99_ms"]


def test_serve_loop_served_margins_reflect_the_swap(stream):
    """Each served margin is bit-reproducible from the weight version its
    record claims — old-version batches really used the old snapshot,
    post-swap batches really used the new one."""
    clf = _warmup(stream, inner_steps=8, outer_iters=1)
    engine = PredictionEngine.from_estimator(clf)
    published = {0: np.asarray(engine.snapshot.w)}
    orig_publish = engine.publish

    def recording_publish(snap):
        published[snap.version] = np.asarray(snap.w)
        return orig_publish(snap)

    engine.publish = recording_publish
    batcher = MicroBatcher(max_batch=32, max_delay_s=0.0, min_width=4)
    report = run_serve_loop(
        stream, engine, batcher,
        classifier=clf, update_every_chunks=2, chunk_rows=50,
    )
    # the model really changed across versions
    assert not np.array_equal(published[0], published[max(published)])
    data = stream.materialize()
    idx = np.asarray(data.indices)
    val = np.asarray(data.values)
    checked_versions = set()
    for r in report.served:
        m = val[r.req_id] != 0.0
        ri, rv = idx[r.req_id, m], val[r.req_id, m]
        width = bucket_width(len(ri), min_width=4)
        pi = np.zeros((1, width), np.int32)
        pv = np.zeros((1, width), np.float32)
        pi[0, : len(ri)] = ri
        pv[0, : len(rv)] = rv
        want = batched_margins(pi, pv, jnp.asarray(published[r.version_used]))
        np.testing.assert_array_equal(np.asarray(r.margin), want[0])
        checked_versions.add(r.version_used)
    assert len(checked_versions) >= 2


def test_serve_loop_pure_inference(stream):
    clf = _warmup(stream)
    engine = PredictionEngine.from_estimator(clf)
    batcher = MicroBatcher(max_batch=64, max_delay_s=0.0, min_width=4)
    report = run_serve_loop(stream, engine, batcher, chunk_rows=64)
    assert report.versions_published == 0
    assert report.staleness_histogram() == {0: 300}
    assert {r.version_used for r in report.served} == {0}
    # margins() reassembles request order == decision_function row order
    # up to bucket re-padding (exact here: nnz <= 16 stays in the exact-
    # reassociation regime — see the engine docstring)
    np.testing.assert_array_equal(
        report.margins(), clf.decision_function(stream.materialize())
    )


def test_serve_loop_guards(stream):
    unfitted = FDSVRGClassifier()
    clf = _warmup(stream)
    engine = PredictionEngine.from_estimator(clf)
    with pytest.raises(ValueError, match="fitted"):
        run_serve_loop(stream, engine, MicroBatcher(), classifier=unfitted)
    small = PredictionEngine(WeightSnapshot.from_dense(np.ones(7), 0))
    with pytest.raises(ValueError, match="dim"):
        run_serve_loop(stream, small, MicroBatcher())


def test_synthetic_request_source_validates():
    with pytest.raises(ValueError, match="nnz_lo"):
        synthetic_request_source(dim=8, num_requests=4, nnz_lo=5, nnz_hi=3)


# ---------------------------------------------------------------------------
# estimator inference memo (the repeated-conversion fix)
# ---------------------------------------------------------------------------


def test_dense_inference_converts_once_and_matches_sparse_path():
    rng = np.random.default_rng(5)
    X = rng.normal(size=(40, 30)) * (rng.random((40, 30)) < 0.4)
    y = (X @ rng.normal(size=30) > 0).astype(int)
    clf = FDSVRGClassifier(method="serial", eta=0.4, lam=1e-4,
                           inner_steps=16, outer_iters=2)
    clf.fit(X, y)
    df = clf.decision_function(X)
    converted = clf._infer_encoded[1]
    clf.predict(X)
    clf.score(X, y)
    # predict -> score reused ONE conversion
    assert clf._infer_encoded[1] is converted
    # and the dense path is the PaddedCSR path (bitwise)
    np.testing.assert_array_equal(df, clf.decision_function(converted))
    # a different matrix re-converts
    X2 = X.copy()
    clf.decision_function(X2)
    assert clf._infer_encoded[0] is X2
    # free_training_cache releases the inference memo too
    clf.free_training_cache()
    assert clf._infer_encoded is None
