"""FD-Prox-SVRG correctness (paper eq. 3: g decomposes over feature blocks,
so the prox step is purely block-local and communication-free).

Covers:
  * prox operators: soft-threshold analytic identity + hypothesis
    properties, elastic-net closed form via its optimality condition;
  * the four implementations (serial, metered FD, worker simulation,
    shard_map) agree on L1 / elastic-net problems, jnp and kernel paths
    bit-identical;
  * L1 runs produce genuinely sparse iterates while the comm-scalar
    meter equals the L2 path exactly (the prox adds zero traffic);
  * recorded grad_norm is the prox gradient-mapping norm at the recorded
    iterate.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses
from repro.core.fdsvrg import (
    SVRGConfig,
    fdsvrg_worker_simulation,
    full_gradient,
    optimality_norm,
    run_fdsvrg,
    run_serial_svrg,
)
from repro.core import baselines
from repro.core.partition import balanced
from repro.data.synthetic import make_sparse_classification

try:
    import hypothesis  # noqa: F401  (dev-only dep; see requirements-dev.txt)

    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False


LOSS = losses.logistic

L1 = losses.l1(2e-3)
EN = losses.elastic_net(2e-3, 1e-3)
REGS = pytest.mark.parametrize("reg", [L1, EN], ids=["l1", "elastic_net"])


@pytest.fixture(scope="module")
def tiny_data():
    return make_sparse_classification(
        dim=512, num_instances=96, nnz_per_instance=12, seed=3
    )


# ---------------------------------------------------------------------------
# prox operators
# ---------------------------------------------------------------------------


def test_soft_threshold_matches_analytic():
    v = jnp.asarray(np.linspace(-2.0, 2.0, 41).astype(np.float32))
    t = 0.3
    got = np.asarray(losses.soft_threshold(v, t))
    vn = np.asarray(v)
    want = np.where(vn > t, vn - t, np.where(vn < -t, vn + t, 0.0))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def test_prox_l1_is_soft_threshold():
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.normal(size=64).astype(np.float32))
    eta = 0.25
    got = L1.prox(v, eta)
    want = losses.soft_threshold(v, eta * L1.lam)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_prox_identity_for_smooth_family():
    rng = np.random.default_rng(1)
    v = jnp.asarray(rng.normal(size=32).astype(np.float32))
    for reg in (losses.l2(0.1), losses.no_reg()):
        np.testing.assert_array_equal(np.asarray(reg.prox(v, 0.5)), np.asarray(v))


def test_elastic_net_prox_optimality_condition():
    """x = prox_{eta g}(v) iff 0 in lam1*d|x| + lam2*x + (x - v)/eta."""
    rng = np.random.default_rng(2)
    v = jnp.asarray(rng.normal(size=256).astype(np.float32))
    eta, lam1, lam2 = 0.4, 0.3, 0.2
    reg = losses.elastic_net(lam1, lam2)
    x = np.asarray(reg.prox(v, eta))
    vn = np.asarray(v)
    nz = x != 0.0
    # nonzero coords: lam1*sign(x) + lam2*x + (x - v)/eta == 0
    resid = lam1 * np.sign(x[nz]) + lam2 * x[nz] + (x[nz] - vn[nz]) / eta
    np.testing.assert_allclose(resid, 0.0, atol=1e-5)
    # zero coords: |v|/eta <= lam1  (subdifferential of |.| is [-1, 1])
    assert np.all(np.abs(vn[~nz]) <= eta * lam1 + 1e-6)
    # and the prox genuinely thresholds: some coordinates hit zero
    assert np.any(~nz) and np.any(nz)


def test_elastic_net_value_and_grad():
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(size=32).astype(np.float32))
    w = jnp.where(jnp.abs(w) < 1e-3, 0.1, w)  # avoid the |.| kink
    reg = losses.elastic_net(0.05, 0.1)
    want = 0.05 * jnp.sum(jnp.abs(w)) + 0.5 * 0.1 * jnp.sum(w * w)
    np.testing.assert_allclose(float(reg.value(w)), float(want), rtol=1e-6)
    g = jax.grad(reg.value)(w)
    np.testing.assert_allclose(
        np.asarray(reg.grad(w)), np.asarray(g), rtol=1e-5, atol=1e-6
    )


if HAS_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @given(
        st.integers(min_value=1, max_value=128),
        st.floats(min_value=0.0, max_value=2.0),
        st.integers(min_value=0, max_value=10_000),
    )
    @settings(max_examples=30, deadline=None)
    def test_property_soft_threshold_analytic(n, t, seed):
        rng = np.random.default_rng(seed)
        v = rng.normal(scale=2.0, size=n).astype(np.float32)
        got = np.asarray(losses.soft_threshold(jnp.asarray(v), t))
        want = np.sign(v) * np.maximum(np.abs(v) - t, 0.0)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)
        # shrinkage properties
        assert np.all(np.abs(got) <= np.abs(v))  # never grows a coordinate
        assert np.all(got[np.abs(v) <= t] == 0.0)  # dead zone
        assert np.all(got * v >= 0.0)  # never flips sign

    @given(
        st.floats(min_value=1e-3, max_value=1.0),
        st.floats(min_value=0.0, max_value=1.0),
        st.floats(min_value=0.0, max_value=1.0),
        st.integers(min_value=0, max_value=10_000),
    )
    @settings(max_examples=30, deadline=None)
    def test_property_prox_is_nonexpansive(eta, lam1, lam2, seed):
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.normal(size=64).astype(np.float32))
        b = jnp.asarray(rng.normal(size=64).astype(np.float32))
        reg = losses.elastic_net(lam1, lam2)
        pa, pb = np.asarray(reg.prox(a, eta)), np.asarray(reg.prox(b, eta))
        assert np.linalg.norm(pa - pb) <= np.linalg.norm(
            np.asarray(a) - np.asarray(b)
        ) * (1 + 1e-6)


# ---------------------------------------------------------------------------
# the four implementations agree (FD-Prox-SVRG == serial Prox-SVRG)
# ---------------------------------------------------------------------------


@REGS
@pytest.mark.parametrize("q", [2, 4, 7])
def test_fd_prox_svrg_equals_serial(tiny_data, reg, q):
    cfg = SVRGConfig(eta=0.2, inner_steps=24, outer_iters=3, seed=11)
    serial = run_serial_svrg(tiny_data, LOSS, reg, cfg)
    fd = run_fdsvrg(tiny_data, balanced(tiny_data.dim, q), LOSS, reg, cfg)
    np.testing.assert_allclose(
        np.asarray(fd.w), np.asarray(serial.w), rtol=2e-4, atol=2e-6
    )


@REGS
@pytest.mark.parametrize("q", [2, 5])
def test_prox_worker_simulation_equals_serial(tiny_data, reg, q):
    cfg = SVRGConfig(eta=0.2, inner_steps=12, outer_iters=2, seed=7)
    serial = run_serial_svrg(tiny_data, LOSS, reg, cfg)
    sim = fdsvrg_worker_simulation(
        tiny_data, balanced(tiny_data.dim, q), LOSS, reg, cfg
    )
    np.testing.assert_allclose(
        np.asarray(sim.w), np.asarray(serial.w), rtol=2e-4, atol=2e-6
    )
    assert sim.meter.total_scalars > 0


@REGS
@pytest.mark.parametrize("q", [2, 4])
def test_prox_use_kernels_bit_identical(tiny_data, reg, q):
    cfg = SVRGConfig(eta=0.2, inner_steps=16, outer_iters=2, batch_size=2, seed=5)
    part = balanced(tiny_data.dim, q)
    a = run_fdsvrg(tiny_data, part, LOSS, reg, cfg, use_kernels=False)
    b = run_fdsvrg(tiny_data, part, LOSS, reg, cfg, use_kernels=True)
    np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))
    assert a.meter.total_scalars == b.meter.total_scalars
    sa = fdsvrg_worker_simulation(tiny_data, part, LOSS, reg, cfg,
                                  use_kernels=False)
    sb = fdsvrg_worker_simulation(tiny_data, part, LOSS, reg, cfg,
                                  use_kernels=True)
    np.testing.assert_array_equal(np.asarray(sa.w), np.asarray(sb.w))


@REGS
def test_prox_option_II_and_minibatch(tiny_data, reg):
    """Option II's masked tail steps (eta_m = 0 => threshold 0 => identity)
    and u > 1 must survive the prox path, jnp and kernel alike."""
    cfg = SVRGConfig(eta=0.2, inner_steps=16, outer_iters=2, batch_size=4,
                     option="II", seed=3)
    a = run_fdsvrg(tiny_data, balanced(tiny_data.dim, 4), LOSS, reg, cfg,
                   use_kernels=False)
    b = run_fdsvrg(tiny_data, balanced(tiny_data.dim, 4), LOSS, reg, cfg,
                   use_kernels=True)
    np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))


@pytest.mark.parametrize("reg_name,lam,lam2", [
    ("l1", 2e-3, 0.0), ("elastic_net", 2e-3, 1e-3),
])
@pytest.mark.parametrize("use_kernels", [False, True])
def test_prox_shardmap_matches_serial_reference(reg_name, lam, lam2, use_kernels):
    """The deployable shard_map worker runs the same prox update: identical
    iterates to the serial reference under a shared sample stream."""
    from repro.core.fdsvrg import _full_grad_blocks, _inner_epoch
    from repro.core.fdsvrg_shardmap import FDSVRGShardedConfig, make_outer_iteration
    from repro.data.block_csr import BlockCSR

    data = make_sparse_classification(
        dim=384, num_instances=48, nnz_per_instance=8, seed=3
    )
    eta, inner, outers, u = 0.2, 12, 2, 2
    mesh = jax.make_mesh((1,), ("model",))
    cfg = FDSVRGShardedConfig(
        dim=data.dim, num_instances=data.num_instances, nnz_max=data.nnz_max,
        eta=eta, inner_steps=inner, batch_size=u,
        reg_name=reg_name, lam=lam, lam2=lam2, use_kernels=use_kernels,
    )
    step = make_outer_iteration(mesh, cfg, feature_axes=("model",))
    block = BlockCSR.from_padded(data, balanced(data.dim, 1))
    bidx, bval = block.stacked()

    rng = np.random.default_rng(5)
    all_samples = [
        rng.integers(0, data.num_instances, size=(inner, u)).astype(np.int32)
        for _ in range(outers)
    ]
    w = jnp.zeros((data.dim,), jnp.float32)
    for t in range(outers):
        w, gnorm = step(w, bidx, bval, data.labels, jnp.asarray(all_samples[t]))
    assert float(gnorm) >= 0.0

    w_ref = jnp.zeros((data.dim,), jnp.float32)
    for t in range(outers):
        z, s0 = _full_grad_blocks(
            block.indices, block.values, data.labels, w_ref,
            "logistic", block.block_dims, False,
        )
        w_ref = _inner_epoch(
            block.indices, block.values, data.labels, w_ref, z, s0,
            jnp.asarray(all_samples[t]), eta, jnp.ones(inner, jnp.float32),
            "logistic", reg_name, lam, block.block_dims, False, lam2=lam2,
        )
    np.testing.assert_allclose(
        np.asarray(w), np.asarray(w_ref), rtol=2e-4, atol=2e-6
    )


# ---------------------------------------------------------------------------
# sparsity + communication: the paper's point — prox is free
# ---------------------------------------------------------------------------


def test_l1_run_produces_sparse_iterates_and_same_comm(tiny_data):
    """L1 ends with genuinely sparse w (nnz(w) < d, unlike the historical
    sign-subgradient path) while the comm-scalar meter equals the L2 run
    exactly: the prox is block-local, zero extra traffic."""
    cfg = SVRGConfig(eta=0.25, inner_steps=96, outer_iters=4, seed=1)
    part = balanced(tiny_data.dim, 4)
    l1 = run_fdsvrg(tiny_data, part, LOSS, losses.l1(2e-3), cfg)
    l2 = run_fdsvrg(tiny_data, part, LOSS, losses.l2(2e-3), cfg)

    w1 = np.asarray(l1.w)
    nnz = int(np.count_nonzero(w1))
    assert 0 < nnz < tiny_data.dim  # sparse, but not trivially zero
    # the subgradient path could only ever produce exact zeros by accident;
    # the prox zeroes entire dead-zone coordinates
    assert nnz < int(np.count_nonzero(np.asarray(l2.w)))

    assert l1.meter.total_scalars == l2.meter.total_scalars
    assert l1.meter.total_rounds == l2.meter.total_rounds
    assert np.isfinite(l1.final_objective())
    assert l1.history[-1].objective < l1.history[0].objective


def test_elastic_net_sparser_with_larger_l1(tiny_data):
    cfg = SVRGConfig(eta=0.25, inner_steps=96, outer_iters=3, seed=1)
    part = balanced(tiny_data.dim, 2)
    small = run_fdsvrg(tiny_data, part, LOSS, losses.elastic_net(5e-4, 1e-3), cfg)
    big = run_fdsvrg(tiny_data, part, LOSS, losses.elastic_net(8e-3, 1e-3), cfg)
    assert int(np.count_nonzero(np.asarray(big.w))) < int(
        np.count_nonzero(np.asarray(small.w))
    )


def test_prox_baselines_run_l1(tiny_data):
    """The PS baselines accept the prox family too (like-for-like Fig 6/7
    comparisons)."""
    cfg = SVRGConfig(eta=0.1, inner_steps=32, outer_iters=3, seed=0)
    for runner in (baselines.run_dsvrg, baselines.run_syn_svrg,
                   baselines.run_asy_svrg):
        res = runner(tiny_data, 4, LOSS, L1, cfg)
        assert np.isfinite(res.history[-1].objective)
        assert res.history[-1].objective < res.history[0].objective
        assert int(np.count_nonzero(np.asarray(res.w))) < tiny_data.dim


# ---------------------------------------------------------------------------
# reporting: gradient-mapping norm at the recorded iterate
# ---------------------------------------------------------------------------


def test_prox_grad_norm_is_gradient_mapping_at_recorded_iterate(tiny_data):
    cfg = SVRGConfig(eta=0.2, inner_steps=24, outer_iters=2, seed=9)
    res = run_fdsvrg(tiny_data, balanced(tiny_data.dim, 4), LOSS, L1, cfg)
    gd, _ = full_gradient(tiny_data, res.w, LOSS)
    want = optimality_norm(gd, res.w, L1, cfg.eta)
    np.testing.assert_allclose(res.history[-1].grad_norm, want, rtol=1e-4)


def test_optimality_norm_reduces_to_grad_norm_when_smooth(tiny_data):
    rng = np.random.default_rng(4)
    w = jnp.asarray(rng.normal(size=tiny_data.dim).astype(np.float32))
    gd, _ = full_gradient(tiny_data, w, LOSS)
    reg = losses.l2(1e-3)
    want = float(jnp.linalg.norm(gd + reg.grad(w)))
    assert optimality_norm(gd, w, reg, 0.2) == want


def test_optimality_norm_vanishes_near_prox_fixed_point(tiny_data):
    """Run long enough that the gradient mapping is far below its initial
    value — the measure actually tracks composite optimality."""
    cfg = SVRGConfig(eta=0.25, inner_steps=96, outer_iters=12, seed=0)
    res = run_serial_svrg(tiny_data, LOSS, L1, cfg)
    norms = [h.grad_norm for h in res.history]
    assert norms[-1] < 0.35 * norms[0]
