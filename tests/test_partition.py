"""Property tests for the feature partitioner (paper §4.1)."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev-only dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core.partition import balanced, by_nnz, feature_counts


@given(
    st.integers(min_value=1, max_value=5000),
    st.integers(min_value=1, max_value=64),
)
@settings(max_examples=80, deadline=None)
def test_balanced_partition_invariants(dim, q):
    if q > dim:
        q = dim
    part = balanced(dim, q)
    sizes = part.block_sizes()
    # covers [0, dim) exactly, contiguously
    assert part.bounds[0] == 0 and part.bounds[-1] == dim
    assert all(b > a for a, b in zip(part.bounds, part.bounds[1:]))
    assert sum(sizes) == dim
    # balanced to within one feature (paper: d_l = d/q)
    assert max(sizes) - min(sizes) <= 1


@given(
    st.integers(min_value=8, max_value=2000),
    st.integers(min_value=2, max_value=16),
    st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=50, deadline=None)
def test_by_nnz_partition_invariants(dim, q, seed):
    if q > dim:
        q = dim
    rng = np.random.default_rng(seed)
    counts = rng.integers(0, 100, size=dim)
    part = by_nnz(dim, q, counts)
    assert part.bounds[0] == 0 and part.bounds[-1] == dim
    assert all(b > a for a, b in zip(part.bounds, part.bounds[1:]))
    assert part.num_blocks == q


def test_by_nnz_balances_skewed_mass():
    dim, q = 1000, 4
    counts = np.zeros(dim, dtype=np.int64)
    counts[:10] = 10_000  # ten hot features carry almost all mass
    part = by_nnz(dim, q, counts)
    masses = [
        counts[part.bounds[i]:part.bounds[i + 1]].sum() for i in range(q)
    ]
    # hot features spread across blocks far better than `balanced` would
    bal = balanced(dim, q)
    masses_bal = [
        counts[bal.bounds[i]:bal.bounds[i + 1]].sum() for i in range(q)
    ]
    assert max(masses) < max(masses_bal)


def test_owner_of():
    part = balanced(100, 7)
    for f in [0, 13, 50, 99]:
        l = part.owner_of(f)
        lo, hi = part.block(l)
        assert lo <= f < hi


def test_feature_counts():
    indices = np.array([[0, 1, 1], [2, 0, 0]])
    values = np.array([[1.0, 2.0, 0.0], [3.0, 0.0, 4.0]])
    counts = feature_counts(indices, values, 4)
    # (0,0)=1.0 and (1,2)=4.0 both hit feature 0; padding zeros don't count
    assert counts.tolist() == [2, 1, 1, 0]


def test_invalid_q_raises():
    with pytest.raises(ValueError):
        balanced(4, 0)
    with pytest.raises(ValueError):
        balanced(4, 5)
