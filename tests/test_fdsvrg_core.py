"""Faithfulness tests for the paper's algorithm.

The load-bearing claims:
  1. FD-SVRG's update sequence == serial SVRG's (paper §4.3: "exactly
     equivalent"), for any feature partition.
  2. Communication accounting matches the closed forms of §4.5
     (2qN-per-N-gradients for FD-SVRG, 2qd+2d per outer for DSVRG, ...).
  3. Theorem 1: linear convergence of Option I on a strongly convex
     problem, with empirical rate within the theorem's bound.
  4. FD-SVRG communicates less than DSVRG iff roughly d > N (the paper's
     headline claim).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses
from repro.core.comm import ClusterModel, CommMeter
from repro.core.fdsvrg import (
    SVRGConfig,
    fdsvrg_worker_simulation,
    full_gradient,
    objective,
    run_fdsvrg,
    run_serial_svrg,
)
from repro.core.partition import balanced, by_nnz, feature_counts
from repro.core import baselines
from repro.data.synthetic import make_sparse_classification


@pytest.fixture(scope="module")
def tiny_data():
    return make_sparse_classification(
        dim=512, num_instances=96, nnz_per_instance=12, seed=3
    )


@pytest.fixture(scope="module")
def small_data():
    return make_sparse_classification(
        dim=4096, num_instances=256, nnz_per_instance=24, seed=0
    )


LOSS = losses.logistic
REG = losses.l2(1e-3)


# ---------------------------------------------------------------------------
# 1. Exact equivalence with serial SVRG
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("q", [1, 2, 4, 7, 8])
def test_fdsvrg_equals_serial_svrg(tiny_data, q):
    cfg = SVRGConfig(eta=0.2, inner_steps=32, outer_iters=3, seed=11)
    serial = run_serial_svrg(tiny_data, LOSS, REG, cfg)
    part = balanced(tiny_data.dim, q)
    fd = run_fdsvrg(tiny_data, part, LOSS, REG, cfg)
    np.testing.assert_allclose(
        np.asarray(fd.w), np.asarray(serial.w), rtol=2e-4, atol=2e-6
    )


@pytest.mark.parametrize("q", [2, 4, 5])
def test_worker_simulation_equals_serial(tiny_data, q):
    """The object-level simulation — workers only touch their own blocks —
    reproduces the serial iterates."""
    cfg = SVRGConfig(eta=0.2, inner_steps=12, outer_iters=2, seed=7)
    serial = run_serial_svrg(tiny_data, LOSS, REG, cfg)
    part = balanced(tiny_data.dim, q)
    sim = fdsvrg_worker_simulation(tiny_data, part, LOSS, REG, cfg)
    np.testing.assert_allclose(
        np.asarray(sim.w), np.asarray(serial.w), rtol=2e-4, atol=2e-6
    )
    assert sim.meter.total_scalars > 0
    # the sim is a full harness citizen now: same-iterate reporting too
    np.testing.assert_allclose(
        sim.history[-1].objective, serial.history[-1].objective, rtol=1e-5
    )


def test_fdsvrg_nnz_partition_equals_serial(tiny_data):
    cfg = SVRGConfig(eta=0.2, inner_steps=16, outer_iters=2, seed=5)
    counts = feature_counts(
        np.asarray(tiny_data.indices), np.asarray(tiny_data.values), tiny_data.dim
    )
    part = by_nnz(tiny_data.dim, 4, counts)
    serial = run_serial_svrg(tiny_data, LOSS, REG, cfg)
    fd = run_fdsvrg(tiny_data, part, LOSS, REG, cfg)
    np.testing.assert_allclose(
        np.asarray(fd.w), np.asarray(serial.w), rtol=2e-4, atol=2e-6
    )


def test_minibatch_variant_consistent(tiny_data):
    """u>1 (paper §4.4.1) must agree between FD and serial paths too."""
    cfg = SVRGConfig(eta=0.2, inner_steps=16, outer_iters=2, batch_size=4, seed=9)
    serial = run_serial_svrg(tiny_data, LOSS, REG, cfg)
    fd = run_fdsvrg(tiny_data, balanced(tiny_data.dim, 4), LOSS, REG, cfg)
    np.testing.assert_allclose(
        np.asarray(fd.w), np.asarray(serial.w), rtol=2e-4, atol=2e-6
    )


def test_option_II_runs_and_converges(tiny_data):
    cfg = SVRGConfig(eta=0.2, inner_steps=32, outer_iters=4, option="II", seed=1)
    res = run_serial_svrg(tiny_data, LOSS, REG, cfg)
    assert res.history[-1].objective < res.history[0].objective


# ---------------------------------------------------------------------------
# 1b. Fused-kernel path ≡ reference path (bit-identical, interpret mode)
# ---------------------------------------------------------------------------


def test_serial_use_kernels_bit_identical(tiny_data):
    cfg = SVRGConfig(eta=0.2, inner_steps=24, outer_iters=2, batch_size=2, seed=11)
    a = run_serial_svrg(tiny_data, LOSS, REG, cfg, use_kernels=False)
    b = run_serial_svrg(tiny_data, LOSS, REG, cfg, use_kernels=True)
    np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))


@pytest.mark.parametrize("q", [2, 4, 7])
def test_fdsvrg_use_kernels_bit_identical(tiny_data, q):
    cfg = SVRGConfig(eta=0.2, inner_steps=16, outer_iters=2, batch_size=2, seed=5)
    part = balanced(tiny_data.dim, q)
    a = run_fdsvrg(tiny_data, part, LOSS, REG, cfg, use_kernels=False)
    b = run_fdsvrg(tiny_data, part, LOSS, REG, cfg, use_kernels=True)
    np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))
    # metering must be layout- and kernel-independent
    assert a.meter.total_scalars == b.meter.total_scalars
    # and the kernel path still matches serial within tolerance
    serial = run_serial_svrg(tiny_data, LOSS, REG, cfg)
    np.testing.assert_allclose(
        np.asarray(b.w), np.asarray(serial.w), rtol=2e-4, atol=2e-6
    )


@pytest.mark.parametrize("q", [2, 5])
def test_worker_simulation_use_kernels_bit_identical(tiny_data, q):
    cfg = SVRGConfig(eta=0.2, inner_steps=8, outer_iters=2, seed=7)
    part = balanced(tiny_data.dim, q)
    a = fdsvrg_worker_simulation(tiny_data, part, LOSS, REG, cfg,
                                 use_kernels=False)
    b = fdsvrg_worker_simulation(tiny_data, part, LOSS, REG, cfg,
                                 use_kernels=True)
    np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))


def test_use_kernels_option_II_and_minibatch(tiny_data):
    """Option II's masked tail steps and u>1 must survive the fused path."""
    cfg = SVRGConfig(eta=0.2, inner_steps=16, outer_iters=2, batch_size=4,
                     option="II", seed=3)
    a = run_fdsvrg(tiny_data, balanced(tiny_data.dim, 4), LOSS, REG, cfg,
                   use_kernels=False)
    b = run_fdsvrg(tiny_data, balanced(tiny_data.dim, 4), LOSS, REG, cfg,
                   use_kernels=True)
    np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))


@pytest.mark.parametrize(
    "reg",
    [losses.l1(1e-3), losses.elastic_net(1e-3, 1e-4), losses.no_reg()],
    ids=["l1", "elastic_net", "none"],
)
def test_use_kernels_accepts_whole_regularizer_family(reg):
    """The historical `_kernel_lam` L2-only ValueError is gone: the fused
    prox kernel covers l1 / elastic-net / none, bit-identical to jnp."""
    data = make_sparse_classification(
        dim=128, num_instances=16, nnz_per_instance=4, seed=0
    )
    cfg = SVRGConfig(eta=0.1, inner_steps=6, outer_iters=2)
    a = run_serial_svrg(data, LOSS, reg, cfg, use_kernels=False)
    b = run_serial_svrg(data, LOSS, reg, cfg, use_kernels=True)
    np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))


def test_fdsvrg_accepts_prebuilt_block_data(tiny_data):
    from repro.data.block_csr import BlockCSR

    part = balanced(tiny_data.dim, 4)
    block_data = BlockCSR.from_padded(tiny_data, part)
    cfg = SVRGConfig(eta=0.2, inner_steps=8, outer_iters=1, seed=1)
    a = run_fdsvrg(tiny_data, part, LOSS, REG, cfg, block_data=block_data)
    b = run_fdsvrg(tiny_data, part, LOSS, REG, cfg)
    np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))
    with pytest.raises(ValueError, match="partition"):
        run_fdsvrg(tiny_data, balanced(tiny_data.dim, 2), LOSS, REG, cfg,
                   block_data=block_data)


# ---------------------------------------------------------------------------
# 1c. grad_norm regression: recorded norm is the gradient AT the recorded
# iterate, not the stale snapshot pair the drivers used to report
# ---------------------------------------------------------------------------


def _expected_grad_norm(data, w, reg):
    gd, _ = full_gradient(data, w, losses.logistic)
    return float(jnp.linalg.norm(gd + reg.grad(w)))


@pytest.mark.parametrize("outers", [1, 2])
@pytest.mark.parametrize(
    "runner",
    [
        lambda d, cfg: run_serial_svrg(d, LOSS, REG, cfg),
        lambda d, cfg: run_fdsvrg(d, balanced(d.dim, 4), LOSS, REG, cfg),
        lambda d, cfg: baselines.run_dsvrg(d, 4, LOSS, REG, cfg),
        lambda d, cfg: baselines.run_syn_svrg(d, 4, LOSS, REG, cfg),
        lambda d, cfg: baselines.run_asy_svrg(d, 4, LOSS, REG, cfg),
    ],
    ids=["serial", "fdsvrg", "dsvrg", "synsvrg", "asysvrg"],
)
def test_grad_norm_recorded_at_post_epoch_iterate(tiny_data, runner, outers):
    """history[-1].grad_norm must equal an independently computed
    ||grad f(w_history)|| at the returned iterate (the historical code mixed
    the snapshot z with the post-epoch w — the norm of nothing)."""
    cfg = SVRGConfig(eta=0.2, inner_steps=16, outer_iters=outers, seed=13)
    res = runner(tiny_data, cfg)
    want = _expected_grad_norm(tiny_data, res.w, REG)
    got = res.history[-1].grad_norm
    # blockwise (tree-order) vs global float summation differ in the last
    # bits only
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-7)


def test_grad_norm_every_record_consistent(tiny_data):
    """Each record's grad_norm corresponds to that outer's post-epoch w:
    truncated reruns (same seed => same iterate prefix) agree record-for-
    record with the longer run."""
    cfg3 = SVRGConfig(eta=0.2, inner_steps=16, outer_iters=3, seed=2)
    full = run_fdsvrg(tiny_data, balanced(tiny_data.dim, 4), LOSS, REG, cfg3)
    for outers in (1, 2):
        cfg = SVRGConfig(eta=0.2, inner_steps=16, outer_iters=outers, seed=2)
        part = run_fdsvrg(tiny_data, balanced(tiny_data.dim, 4), LOSS, REG, cfg)
        assert part.history[-1].grad_norm == full.history[outers - 1].grad_norm
        np.testing.assert_allclose(
            part.history[-1].grad_norm,
            _expected_grad_norm(tiny_data, part.w, REG),
            rtol=1e-4,
        )


# ---------------------------------------------------------------------------
# 2. Communication accounting (paper §4.5)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("q", [2, 4, 8, 16])
def test_fdsvrg_comm_closed_form(tiny_data, q):
    m, outers, u = 20, 2, 1
    cfg = SVRGConfig(eta=0.1, inner_steps=m, outer_iters=outers, batch_size=u)
    fd = run_fdsvrg(tiny_data, balanced(tiny_data.dim, q), LOSS, REG, cfg)
    n = tiny_data.num_instances
    # per outer: full-grad tree on the N-vector (2qN) + M trees on u scalars.
    expected = outers * (2 * q * n + 2 * q * u * m)
    assert fd.meter.total_scalars == expected


def test_dsvrg_comm_closed_form(tiny_data):
    q, outers = 4, 3
    cfg = SVRGConfig(eta=0.1, inner_steps=tiny_data.num_instances // q, outer_iters=outers)
    res = baselines.run_dsvrg(tiny_data, q, LOSS, REG, cfg)
    d = tiny_data.dim
    expected = outers * (2 * q * d + 2 * d)  # paper §4.5
    assert res.meter.total_scalars == expected


def test_comm_crossover_d_vs_n():
    """FD-SVRG wins on scalars iff d > N (the paper's headline claim),
    comparing per-outer totals with the paper's M settings."""
    q = 8
    highdim = make_sparse_classification(
        dim=8192, num_instances=128, nnz_per_instance=8, seed=0
    )
    lowdim = make_sparse_classification(
        dim=128, num_instances=4096, nnz_per_instance=8, seed=0
    )
    for data, fd_should_win in ((highdim, True), (lowdim, False)):
        n = data.num_instances
        cfg_fd = SVRGConfig(eta=0.05, inner_steps=n, outer_iters=1)
        cfg_ds = SVRGConfig(eta=0.05, inner_steps=n // q, outer_iters=1)
        fd = run_fdsvrg(data, balanced(data.dim, q), LOSS, REG, cfg_fd)
        ds = baselines.run_dsvrg(data, q, LOSS, REG, cfg_ds)
        if fd_should_win:
            assert fd.meter.total_scalars < ds.meter.total_scalars
        else:
            assert fd.meter.total_scalars > ds.meter.total_scalars


def test_ps_svrg_comm_dominates(tiny_data):
    """Parameter-server SVRG traffic is O(M·(qd + q·nnz)) per outer — far
    above both FD-SVRG and DSVRG on high-dim data (paper §4.5)."""
    q = 4
    cfg = SVRGConfig(eta=0.1, inner_steps=16, outer_iters=1)
    fd = run_fdsvrg(tiny_data, balanced(tiny_data.dim, q), LOSS, REG, cfg)
    syn = baselines.run_syn_svrg(tiny_data, q, LOSS, REG, cfg)
    asy = baselines.run_asy_svrg(tiny_data, q, LOSS, REG, cfg)
    assert syn.meter.total_scalars > fd.meter.total_scalars
    assert asy.meter.total_scalars > fd.meter.total_scalars


# ---------------------------------------------------------------------------
# 3. Convergence (Theorem 1)
# ---------------------------------------------------------------------------


def test_linear_convergence_rate(small_data):
    """Empirical per-outer contraction of the objective gap should be <= the
    Theorem-1 factor (a^M + b/(1-a)) once within the quadratic basin.
    Run in float64 so the gap doesn't hit the fp32 objective floor."""
    import dataclasses as _dc

    from repro.data.sparse import PaddedCSR

    lam = 0.1
    reg = losses.l2(lam)
    # Smoothness of f_i: phi'' <= 1/4 times ||x||^2 (rows are L2-normalized
    # so ||x||=1) plus lam from the regularizer; strong convexity >= lam.
    L = 0.25 + lam
    mu = lam
    # b/(1-a) = 2L^2 eta / (mu - 2L^2 eta) < 1 requires eta < mu/(4L^2);
    # take eta = mu/(8L^2) so b/(1-a) = 1/3 and a^M shrinks geometrically.
    eta = mu / (8 * L * L)
    M = small_data.num_instances
    a = 1 - mu * eta + 2 * L * L * eta * eta
    b = 2 * L * L * eta * eta
    bound = a**M + b / (1 - a)
    assert bound < 1.0

    # jax.enable_x64 graduated from jax.experimental after the 0.4 series
    enable_x64 = getattr(jax, "enable_x64", None) or jax.experimental.enable_x64
    with enable_x64(True):
        data64 = PaddedCSR(
            indices=jnp.asarray(np.asarray(small_data.indices)),
            values=jnp.asarray(np.asarray(small_data.values), dtype=jnp.float64),
            labels=jnp.asarray(np.asarray(small_data.labels), dtype=jnp.float64),
            dim=small_data.dim,
        )
        cfg = SVRGConfig(eta=eta, inner_steps=M, outer_iters=25, seed=0)
        res = run_serial_svrg(data64, LOSS, reg, cfg)
        objs = res.objectives()
        # approximate f(w*) by running longer
        cfg_star = SVRGConfig(eta=eta, inner_steps=M, outer_iters=120, seed=1)
        star = run_serial_svrg(data64, LOSS, reg, cfg_star).final_objective()
    gaps = np.maximum(objs - star, 1e-16)
    # geometric decrease while the gap is informative (above f64 noise)
    informative = gaps > 5e-15
    ratios = np.array(
        [gaps[i + 1] / gaps[i] for i in range(len(gaps) - 1)
         if informative[i] and informative[i + 1]]
    )
    assert len(ratios) >= 3, f"gaps collapsed too fast: {gaps[:8]}"
    assert np.median(ratios) < 1.0  # strictly contracting
    # and the contraction is at least as good as the theorem's bound
    assert np.median(ratios) <= bound + 0.05


def test_fdsvrg_decreases_objective(small_data):
    cfg = SVRGConfig(eta=0.25, inner_steps=small_data.num_instances, outer_iters=5)
    res = run_fdsvrg(small_data, balanced(small_data.dim, 8), LOSS, REG, cfg)
    objs = res.objectives()
    assert objs[-1] <= objs[0]
    assert objs[-1] < 0.693 * 0.55  # far below the w=0 objective log(2)
    assert np.all(np.isfinite(objs))


# ---------------------------------------------------------------------------
# 4. Baselines converge (sanity for the benchmark suite)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "runner,kwargs",
    [
        (baselines.run_dsvrg, {}),
        (baselines.run_syn_svrg, {}),
        (baselines.run_asy_svrg, {}),
    ],
)
def test_baselines_converge(tiny_data, runner, kwargs):
    cfg = SVRGConfig(eta=0.1, inner_steps=48, outer_iters=4)
    res = runner(tiny_data, 4, LOSS, REG, cfg, **kwargs)
    assert res.history[-1].objective < res.history[0].objective
    assert np.isfinite(res.history[-1].objective)


def test_pslite_sgd_converges_slowly(tiny_data):
    """Fixed-step async SGD stalls at its noise floor while AsySVRG keeps
    contracting — the reason the paper builds on SVRG (Tables 2-3)."""
    cfg = SVRGConfig(eta=0.1, inner_steps=256, outer_iters=6)
    sgd = baselines.run_pslite_sgd(tiny_data, 4, LOSS, REG, cfg)
    svrg = baselines.run_asy_svrg(tiny_data, 4, LOSS, REG, cfg)
    assert np.isfinite(sgd.history[-1].objective)
    assert sgd.history[-1].objective < sgd.history[0].objective + 1e-6  # moves
    # and VR beats plain SGD at equal gradient budget
    assert svrg.history[-1].objective <= sgd.history[-1].objective + 1e-4


def test_modeled_time_ordering():
    """Figure 6's qualitative ordering under the cluster model: in the
    paper's regime (d >> N, mini-batched inner loop per §4.4.1), FD-SVRG
    reaches the same gradient budget in less modeled time than DSVRG."""
    data = make_sparse_classification(
        dim=65536, num_instances=256, nnz_per_instance=24, seed=2
    )
    q, u = 8, 32
    n = data.num_instances
    # equal gradient budgets: FD does n grads/outer via n/u batched steps;
    # DSVRG does n/q grads/outer on one machine (paper M = N/q).
    cfg_fd = SVRGConfig(eta=0.25, inner_steps=n // u, outer_iters=3, batch_size=u)
    cfg_ds = SVRGConfig(eta=0.25, inner_steps=n // q, outer_iters=3)
    fd = run_fdsvrg(data, balanced(data.dim, q), LOSS, REG, cfg_fd)
    ds = baselines.run_dsvrg(data, q, LOSS, REG, cfg_ds)
    assert fd.history[-1].modeled_time_s < ds.history[-1].modeled_time_s
    # and DSVRG in turn beats the parameter-server SVRG (paper Figure 6)
    cfg_ps = SVRGConfig(eta=0.25, inner_steps=n // q, outer_iters=3)
    ps = baselines.run_syn_svrg(data, q, LOSS, REG, cfg_ps)
    assert ds.history[-1].modeled_time_s < ps.history[-1].modeled_time_s
