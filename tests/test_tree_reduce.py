"""Tree-reduce schedule and TPU-mapping tests (paper Figure 5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev-only dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core.comm import CommMeter
from repro.core.tree_reduce import (
    broadcast_schedule,
    collective_permute_tree,
    psum_tree,
    simulate_tree_sum,
    tree_schedule,
)


@given(st.integers(min_value=1, max_value=64))
@settings(max_examples=40, deadline=None)
def test_schedule_covers_all_workers(q):
    """Every non-root worker sends exactly once; root receives everything."""
    senders = [src for rnd in tree_schedule(q) for (src, dst) in rnd]
    assert sorted(senders) == list(range(1, q))
    # log depth
    assert len(tree_schedule(q)) == (0 if q == 1 else int(np.ceil(np.log2(q))))


@given(
    st.integers(min_value=1, max_value=33),
    st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_tree_sum_equals_sum(q, seed):
    rng = np.random.default_rng(seed)
    vals = rng.normal(size=(q, 3)).astype(np.float32)
    got = simulate_tree_sum([jnp.asarray(v) for v in vals])
    np.testing.assert_allclose(
        np.asarray(got), vals.astype(np.float64).sum(axis=0), rtol=1e-4, atol=1e-5
    )


@given(st.integers(min_value=2, max_value=64))
@settings(max_examples=30, deadline=None)
def test_meter_matches_paper_accounting(q):
    """Paper §4.5: tree reduce+broadcast of one scalar costs 2q scalars."""
    meter = CommMeter()
    simulate_tree_sum([jnp.ones(()) for _ in range(q)], meter=meter, payload=1)
    assert meter.total_scalars == 2 * q
    assert meter.total_rounds == 2 * int(np.ceil(np.log2(q)))


def test_broadcast_is_reverse_tree():
    q = 8
    fwd = tree_schedule(q)
    bwd = broadcast_schedule(q)
    assert len(fwd) == len(bwd)
    flipped = [[(dst, src) for (src, dst) in rnd] for rnd in reversed(bwd)]
    assert flipped == fwd


def test_psum_tree_single_device():
    from jax.sharding import PartitionSpec as P

    from repro.dist.compat import make_mesh, shard_map

    mesh = make_mesh((1,), ("model",))
    f = shard_map(
        lambda x: psum_tree(x, "model"),
        mesh,
        in_specs=P("model"),
        out_specs=P("model"),
    )
    x = jnp.arange(4.0)
    np.testing.assert_allclose(np.asarray(f(x)), np.asarray(x))


def test_collective_permute_tree_rejects_non_pow2():
    with pytest.raises(ValueError):
        # trace-time check: axis_size validation fires before any collective
        collective_permute_tree(jnp.ones(()), "model", 3)


def test_butterfly_matches_psum_in_vmapped_sim():
    """Simulate the butterfly with explicit per-worker lanes (no devices):
    run the same arithmetic the ppermute tree does and check it all-reduces."""
    q = 8
    rng = np.random.default_rng(0)
    vals = rng.normal(size=(q,)).astype(np.float64)
    lanes = vals.copy()
    stride = 1
    while stride < q:
        permuted = np.empty_like(lanes)
        for i in range(q):
            permuted[i ^ stride] = lanes[i]
        lanes = lanes + permuted
        stride *= 2
    np.testing.assert_allclose(lanes, np.full(q, vals.sum()), rtol=1e-12)
