"""Equivalence tests for the repro.dist substrate.

The load-bearing property: the three Collectives backends are
interchangeable — same iterates (bit-for-bit, thanks to the shared
canonical tree-order summation), same metered traffic (the §4.5 closed
forms live in ONE place) — so any method ported onto the substrate can be
compared across backends and against any other method on the same meter.

The ``faulty-*`` kinds run the SAME equivalence suite through a
:class:`repro.dist.FaultyBackend` wrapping each backend with a no-fault
:class:`repro.dist.FaultPlan`: with no faults armed the wrapper must be
a true no-op — bit-identical iterates and scalar-identical meters.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses
from repro.core.fdsvrg import SVRGConfig, fdsvrg_worker_simulation, run_fdsvrg
from repro.core.partition import balanced
from repro.dist import (
    ClusterModel,
    Collectives,
    CommReport,
    FaultPlan,
    FaultyBackend,
    LocalBackend,
    ShardMapBackend,
    SimBackend,
    tree_rounds,
)
from repro.data.synthetic import make_sparse_classification

LOSS = losses.logistic
REG = losses.l2(1e-3)
Q = 4


def make_backend(kind: str, q: int = Q) -> Collectives:
    if kind.startswith("faulty-"):
        # the wrapper with nothing armed: must behave as its inner backend
        return FaultyBackend(make_backend(kind[len("faulty-"):], q),
                             FaultPlan())
    if kind == "local":
        return LocalBackend(q)
    if kind == "sim":
        return SimBackend(q)
    if kind == "shardmap-interpret":
        return ShardMapBackend(q=q, interpret=True)
    raise ValueError(kind)


BACKENDS = ["local", "sim", "shardmap-interpret"]
BACKENDS += [f"faulty-{k}" for k in BACKENDS]


@pytest.fixture(scope="module")
def data():
    return make_sparse_classification(
        dim=256, num_instances=48, nnz_per_instance=8, seed=2
    )


# ---------------------------------------------------------------------------
# Primitive-level equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", BACKENDS)
def test_all_reduce_value_and_meter(kind):
    rng = np.random.default_rng(0)
    parts = [jnp.asarray(rng.normal(size=(5,)).astype(np.float32)) for _ in range(Q)]
    b = make_backend(kind)
    got = b.all_reduce(parts, payload=5)
    np.testing.assert_allclose(
        np.asarray(got), np.sum([np.asarray(p) for p in parts], axis=0),
        rtol=1e-5, atol=1e-6,
    )
    # paper §4.5: one tree reduce+broadcast of 5 scalars among Q workers
    assert b.meter.total_scalars == 2 * Q * 5
    assert b.meter.total_rounds == tree_rounds(Q)
    assert b.meter.by_kind == {"tree_reduce": 2 * Q * 5}


def test_backends_all_reduce_bitwise_identical():
    """All backends sum in the canonical Figure-5 order, so the floats
    match exactly, not just approximately."""
    rng = np.random.default_rng(1)
    parts = [jnp.asarray(rng.normal(size=(7,)).astype(np.float32)) for _ in range(Q)]
    results = [np.asarray(make_backend(k).all_reduce(parts)) for k in BACKENDS]
    for r in results[1:]:
        np.testing.assert_array_equal(results[0], r)


def test_protocol_conformance():
    for kind in BACKENDS:
        b = make_backend(kind)
        assert isinstance(b, Collectives)
        assert b.q == Q
        b.meter_tree(payload=3, steps=2)
        b.p2p(10, "push")
        b.charge(flops=1e6, scalars=100, rounds=2)
        b.charge_seconds(0.5)
        assert b.modeled_time_s > 0.5
        rep = b.report("m")
        assert rep.scalars == b.meter.total_scalars
        assert rep.bytes_on_wire == rep.scalars * b.cluster.bytes_per_scalar


@pytest.mark.parametrize("kind", BACKENDS)
def test_all_reduce_rejects_wrong_partial_count(kind):
    b = make_backend(kind)
    with pytest.raises(ValueError, match="one partial per worker"):
        b.all_reduce([jnp.ones(2)] * (Q - 1))


def test_q1_backends_meter_nothing():
    for kind in BACKENDS:
        b = make_backend(kind, q=1)
        out = b.all_reduce([jnp.ones(3)])
        np.testing.assert_array_equal(np.asarray(out), np.ones(3))
        assert b.meter.total_scalars == 0


def test_shardmap_backend_guards():
    b = ShardMapBackend(q=2, interpret=True)
    with pytest.raises(ValueError):
        b.device_all_reduce(jnp.ones(()))
    with pytest.raises(ValueError):
        ShardMapBackend(q=2, tree_mode="ring")
    with pytest.raises(ValueError):
        ShardMapBackend()  # neither mesh nor q
    # a real-mesh backend refuses the host path
    from repro.dist.compat import make_mesh

    real = ShardMapBackend(mesh=make_mesh((1,), ("model",)))
    with pytest.raises(ValueError):
        real.all_reduce([jnp.ones(2)])
    # a backend built on one mesh/axes cannot drive an iteration on another
    # (note jax interns equal meshes, so differ by axis name)
    from repro.core.fdsvrg_shardmap import FDSVRGShardedConfig, make_outer_iteration

    other = make_mesh((1,), ("data",))
    cfg = FDSVRGShardedConfig(dim=8, num_instances=4, nnz_max=2,
                              eta=0.1, inner_steps=2)
    with pytest.raises(ValueError, match="different mesh"):
        make_outer_iteration(other, cfg, feature_axes=("data",), backend=real)


# ---------------------------------------------------------------------------
# FD-SVRG-level equivalence (the satellite acceptance test)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", BACKENDS)
def test_fdsvrg_run_identical_across_backends(data, kind):
    """SimBackend, LocalBackend, and interpret-mode ShardMapBackend drive
    the worker simulation to identical iterates AND identical metered
    byte counts for a small FD-SVRG run."""
    cfg = SVRGConfig(eta=0.2, inner_steps=10, outer_iters=2, seed=13)
    part = balanced(data.dim, Q)
    ref = fdsvrg_worker_simulation(
        data, part, LOSS, REG, cfg, backend=SimBackend(Q)
    )
    res = fdsvrg_worker_simulation(
        data, part, LOSS, REG, cfg, backend=make_backend(kind)
    )
    w, meter, ref_meter = res.w, res.meter, ref.meter
    np.testing.assert_array_equal(np.asarray(w), np.asarray(ref.w))
    assert meter.total_scalars == ref_meter.total_scalars
    assert meter.total_rounds == ref_meter.total_rounds
    assert dict(meter.by_kind) == dict(ref_meter.by_kind)
    # and the closed form itself: per outer, one N-payload tree + M u-trees
    n, m, u = data.num_instances, cfg.inner_steps, cfg.batch_size
    assert meter.total_scalars == cfg.outer_iters * (2 * Q * n + 2 * Q * u * m)


def test_run_fdsvrg_accepts_backend(data):
    """The jitted driver meters through an injected backend and the
    result's meter IS the backend's meter."""
    cfg = SVRGConfig(eta=0.2, inner_steps=8, outer_iters=2, seed=3)
    backend = SimBackend(Q, ClusterModel(flops_per_s=1e8))
    res = run_fdsvrg(data, balanced(data.dim, Q), LOSS, REG, cfg, backend=backend)
    assert res.meter is backend.meter
    assert res.history[-1].comm_scalars == backend.meter.total_scalars
    assert res.history[-1].modeled_time_s == pytest.approx(backend.modeled_time_s)
    # jitted driver and worker simulation agree on the accounting
    sim = fdsvrg_worker_simulation(
        data, balanced(data.dim, Q), LOSS, REG, cfg, backend=SimBackend(Q)
    )
    assert backend.meter.total_scalars == sim.meter.total_scalars


def test_run_fdsvrg_rejects_mismatched_backend_q(data):
    cfg = SVRGConfig(eta=0.2, inner_steps=4, outer_iters=1)
    with pytest.raises(ValueError, match="q=8 workers but the partition"):
        run_fdsvrg(data, balanced(data.dim, Q), LOSS, REG, cfg,
                   backend=SimBackend(8))


def test_baselines_share_the_substrate(data):
    """Two baselines run through injected Collectives backends and report
    through the same meter machinery as FD-SVRG."""
    from repro.core import baselines

    cfg = SVRGConfig(eta=0.1, inner_steps=12, outer_iters=2)
    b_ds = LocalBackend(Q)
    ds = baselines.run_dsvrg(data, Q, LOSS, REG, cfg, backend=b_ds)
    assert ds.meter is b_ds.meter
    assert ds.meter.total_scalars == cfg.outer_iters * (2 * Q * data.dim + 2 * data.dim)

    b_ps = LocalBackend(Q)
    ps = baselines.run_syn_svrg(data, Q, LOSS, REG, cfg, backend=b_ps)
    assert ps.meter is b_ps.meter
    assert set(ps.meter.by_kind) == {"ps_fullgrad", "ps_inner"}

    # apples-to-apples reports from the shared report type
    rep_ds = CommReport.from_result("dsvrg", Q, ds)
    rep_ps = CommReport.from_result("synsvrg", Q, ps)
    assert rep_ps.bytes_on_wire > rep_ds.bytes_on_wire > 0


def test_meter_tree_aggregate_matches_loop():
    """meter_tree(payload, steps) must equal `steps` separate trees in
    scalars and rounds (the jitted path's aggregate accounting)."""
    agg, loop = SimBackend(8), SimBackend(8)
    agg.meter_tree(payload=3, steps=5)
    for _ in range(5):
        loop.meter_tree(payload=3)
    assert agg.meter.total_scalars == loop.meter.total_scalars
    assert agg.meter.total_rounds == loop.meter.total_rounds
