"""The two fused FD-SVRG hot-path kernels (interpret=True on CPU) vs the
pure-jnp oracles in kernels/ref.py, swept over shapes and tilings.

Bit-identity is part of the contract: inside a jit, the interpret-mode
kernels must reproduce the reference expression tree exactly — that is
what makes ``use_kernels=True`` produce bit-identical iterates (asserted
end-to-end in test_fdsvrg_core.py / test_fdsvrg_shardmap.py).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

try:
    import hypothesis  # noqa: F401  (dev-only dep; see requirements-dev.txt)

    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False


RNG = np.random.default_rng(0)


def _case(d, n, nnz, seed=0):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=d).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, d, size=(n, nnz)).astype(np.int32))
    val = jnp.asarray(rng.normal(size=(n, nnz)).astype(np.float32))
    return w, idx, val


# ---------------------------------------------------------------------------
# sparse_margin
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "d,n,nnz", [(64, 8, 4), (300, 37, 9), (1024, 128, 16), (50, 1, 1)]
)
def test_sparse_margin_matches_ref_bitwise(d, n, nnz):
    w, idx, val = _case(d, n, nnz, seed=d)
    got = ops.sparse_margins(idx, val, w, interpret=True)
    want = jax.jit(ref.sparse_margin_ref)(w, idx, val)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.dtype == jnp.float32


@pytest.mark.parametrize("block_rows", [1, 4, 8, 16])
def test_sparse_margin_row_tiling_sweep(block_rows):
    w, idx, val = _case(200, 23, 7, seed=1)  # 23 rows: exercises padding
    got = ops.sparse_margins(idx, val, w, block_rows=block_rows, interpret=True)
    want = jax.jit(ref.sparse_margin_ref)(w, idx, val)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-7
    )


def test_sparse_margin_zero_value_padding_is_inert():
    """(idx 0, val 0) padding — BlockCSR's convention — contributes 0."""
    w = jnp.asarray(RNG.normal(size=10).astype(np.float32))
    idx = jnp.asarray([[3, 0, 0], [7, 2, 0]], jnp.int32)
    val = jnp.asarray([[2.0, 0.0, 0.0], [1.0, 1.0, 0.0]], jnp.float32)
    got = ops.sparse_margins(idx, val, w, interpret=True)
    want = jnp.asarray([2.0 * w[3], w[7] + w[2]])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# ---------------------------------------------------------------------------
# fused_update
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d,u,nnz", [(64, 1, 4), (300, 5, 9), (1024, 16, 8)])
@pytest.mark.parametrize("eta,lam", [(0.1, 1e-4), (0.5, 0.0), (0.01, 1e-2)])
def test_fused_update_matches_ref_bitwise(d, u, nnz, eta, lam):
    w, idx, val = _case(d, u, nnz, seed=d + u)
    coef = jnp.asarray(RNG.normal(size=u).astype(np.float32))
    z = jnp.asarray(RNG.normal(size=d).astype(np.float32))
    eta_arr = jnp.float32(eta)
    got = ops.fused_block_update(
        w, idx, val, coef, z, eta_arr, lam=lam, interpret=True
    )
    want = jax.jit(ref.fused_update_ref, static_argnames=("lam",))(
        w, idx, val, coef, z, eta_arr, lam=lam
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_update_masked_step_is_identity():
    """eta * mask = 0 (Option II tail) must return w unchanged."""
    w, idx, val = _case(100, 3, 5, seed=9)
    coef = jnp.asarray(RNG.normal(size=3).astype(np.float32))
    z = jnp.asarray(RNG.normal(size=100).astype(np.float32))
    got = ops.fused_block_update(
        w, idx, val, coef, z, jnp.float32(0.0), lam=1e-3, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(w))


def test_fused_update_collapses_three_passes():
    """The fusion target: scatter pass + add pass + axpy pass == kernel."""
    w, idx, val = _case(256, 4, 6, seed=2)
    coef = jnp.asarray(RNG.normal(size=4).astype(np.float32))
    z = jnp.asarray(RNG.normal(size=256).astype(np.float32))
    eta, lam = 0.2, 1e-3

    @jax.jit
    def three_pass(w, idx, val, coef, z):
        from repro.data.block_csr import local_scatter

        g = local_scatter(idx, val, coef, w.shape[0])  # pass 1: densify
        g = g + z + lam * w  # pass 2: combine
        return w - eta * g  # pass 3: axpy

    got = ops.fused_block_update(
        w, idx, val, coef, z, jnp.float32(eta), lam=lam, interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(three_pass(w, idx, val, coef, z)),
        rtol=1e-6, atol=1e-7,
    )


# ---------------------------------------------------------------------------
# prox_update (the whole-regularizer-family fused kernel)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d,u,nnz", [(64, 1, 4), (300, 5, 9), (1024, 16, 8)])
@pytest.mark.parametrize(
    "lam,lam1,lam2",
    [
        (1e-4, 0.0, 0.0),  # l2: the prox stages elide at trace time
        (0.0, 1e-2, 0.0),  # l1: soft-threshold
        (0.0, 1e-2, 1e-3),  # elastic net: threshold + shrink
        (0.0, 0.0, 0.0),  # none
    ],
)
def test_prox_update_matches_ref_bitwise(d, u, nnz, lam, lam1, lam2):
    w, idx, val = _case(d, u, nnz, seed=d + u)
    coef = jnp.asarray(RNG.normal(size=u).astype(np.float32))
    z = jnp.asarray(RNG.normal(size=d).astype(np.float32))
    eta = jnp.float32(0.2)
    got = ops.fused_block_prox_update(
        w, idx, val, coef, z, eta, lam=lam, lam1=lam1, lam2=lam2, interpret=True
    )
    want = jax.jit(
        ref.prox_update_ref, static_argnames=("lam", "lam1", "lam2")
    )(w, idx, val, coef, z, eta, lam=lam, lam1=lam1, lam2=lam2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_prox_update_l2_path_reproduces_fused_update():
    """lam1 = lam2 = 0 must leave exactly the fused_update expression tree —
    the L2 family keeps its historical bit-identity."""
    w, idx, val = _case(256, 4, 6, seed=2)
    coef = jnp.asarray(RNG.normal(size=4).astype(np.float32))
    z = jnp.asarray(RNG.normal(size=256).astype(np.float32))
    eta = jnp.float32(0.1)
    a = ops.fused_block_update(w, idx, val, coef, z, eta, lam=1e-3, interpret=True)
    b = ops.fused_block_prox_update(
        w, idx, val, coef, z, eta, lam=1e-3, lam1=0.0, lam2=0.0, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_prox_update_masked_step_is_identity():
    """eta * mask = 0 (Option II tail): threshold 0, shrink 1 — w unchanged
    (up to the sign of zero, which compares equal)."""
    w, idx, val = _case(100, 3, 5, seed=9)
    coef = jnp.asarray(RNG.normal(size=3).astype(np.float32))
    z = jnp.asarray(RNG.normal(size=100).astype(np.float32))
    got = ops.fused_block_prox_update(
        w, idx, val, coef, z, jnp.float32(0.0), lam=0.0, lam1=1e-2, lam2=1e-3,
        interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(w))


def test_prox_update_thresholds_small_coordinates():
    """Coordinates whose post-step magnitude falls below eta*lam1 come out
    exactly zero — the sparsity mechanism itself."""
    d = 32
    w = jnp.full((d,), 1e-4, jnp.float32)
    idx = jnp.zeros((1, 1), jnp.int32)
    val = jnp.zeros((1, 1), jnp.float32)
    coef = jnp.zeros((1,), jnp.float32)
    z = jnp.zeros((d,), jnp.float32)
    out = ops.fused_block_prox_update(
        w, idx, val, coef, z, jnp.float32(0.1), lam=0.0, lam1=1.0, lam2=0.0,
        interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(out), np.zeros(d, np.float32))


# ---------------------------------------------------------------------------
# hypothesis properties (CI; dev-only dep)
# ---------------------------------------------------------------------------


if HAS_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @given(
        st.integers(min_value=1, max_value=200),
        st.integers(min_value=1, max_value=12),
        st.integers(min_value=1, max_value=10),
    )
    @settings(max_examples=20, deadline=None)
    def test_property_sparse_margin_interpret_equivalence(d, n, nnz):
        w, idx, val = _case(d, n, nnz, seed=d * 31 + n)
        got = ops.sparse_margins(idx, val, w, interpret=True)
        want = jax.jit(ref.sparse_margin_ref)(w, idx, val)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @given(
        st.integers(min_value=1, max_value=200),
        st.integers(min_value=1, max_value=8),
        st.floats(min_value=1e-4, max_value=1.0),
        st.floats(min_value=0.0, max_value=0.1),
    )
    @settings(max_examples=20, deadline=None)
    def test_property_fused_update_interpret_equivalence(d, u, eta, lam):
        rng = np.random.default_rng(d * 7 + u)
        w, idx, val = _case(d, u, 5, seed=d + u)
        coef = jnp.asarray(rng.normal(size=u).astype(np.float32))
        z = jnp.asarray(rng.normal(size=d).astype(np.float32))
        got = ops.fused_block_update(
            w, idx, val, coef, z, jnp.float32(eta), lam=float(lam),
            interpret=True,
        )
        want = jax.jit(ref.fused_update_ref, static_argnames=("lam",))(
            w, idx, val, coef, z, jnp.float32(eta), lam=float(lam)
        )
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @given(
        st.integers(min_value=1, max_value=200),
        st.integers(min_value=1, max_value=8),
        st.floats(min_value=1e-4, max_value=1.0),
        st.floats(min_value=0.0, max_value=0.1),
        st.floats(min_value=0.0, max_value=0.1),
    )
    @settings(max_examples=20, deadline=None)
    def test_property_prox_update_interpret_equivalence(d, u, eta, lam1, lam2):
        rng = np.random.default_rng(d * 13 + u)
        w, idx, val = _case(d, u, 5, seed=d + 2 * u)
        coef = jnp.asarray(rng.normal(size=u).astype(np.float32))
        z = jnp.asarray(rng.normal(size=d).astype(np.float32))
        got = ops.fused_block_prox_update(
            w, idx, val, coef, z, jnp.float32(eta), lam=0.0,
            lam1=float(lam1), lam2=float(lam2), interpret=True,
        )
        want = jax.jit(
            ref.prox_update_ref, static_argnames=("lam", "lam1", "lam2")
        )(w, idx, val, coef, z, jnp.float32(eta), lam=0.0,
          lam1=float(lam1), lam2=float(lam2))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
