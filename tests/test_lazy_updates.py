"""Lazy O(nnz) delayed-decay inner steps vs the dense oracle.

The equivalence contract, in three layers:

1. **Per-step oracle, bitwise.**  The exact-lazy epoch must be
   bit-identical to the *per-step dense oracle* — :func:`_sim_update`
   (the dense fused update / prox update) iterated step by step — across
   every regularizer family, worker count, kernel mode, and step-mask
   option.  The per-step oracle is the q-independent reference; the
   fused ``_inner_epoch`` scan itself is NOT q-stable for the prox
   family (see layer 3).
2. **Kernel vs reference, bitwise.**  Each of the four lazy Pallas
   kernels (interpret mode on CPU) reproduces its jnp reference oracle
   exactly.
3. **Drivers.**  ``lazy_updates="exact"`` is bit-identical to the eager
   run for the serial driver, the object-level simulation (any q), and
   ``run_fdsvrg`` at q=1 — and ulp-bounded at q>1 for l1/elastic-net,
   where the *dense* scan's own bits move: XLA contracts the soft
   threshold ``|v| - eta*lam`` into an FMA at some q and pre-rounds
   ``fl(eta*lam)`` at others (verified coordinate-by-coordinate against
   both emulations), so no single lazy implementation can bit-match the
   fused scan at every q.  The probabilistic variant is checked for
   unbiasedness (per-feature expected update == dense, over many draws)
   and end-to-end convergence.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import fdsvrg, losses
from repro.core.fdsvrg import (
    SVRGConfig,
    _check_lazy,
    fdsvrg_worker_simulation,
    run_fdsvrg,
    run_serial_svrg,
)
from repro.core.partition import balanced
from repro.data.block_csr import BlockCSR
from repro.data.sparse import PaddedCSR
from repro.data.synthetic import make_sparse_classification
from repro.kernels import ops, ref

REGS = {
    "none": losses.no_reg(),
    "l2": losses.l2(1e-3),
    "l1": losses.l1(1e-3),
    "elastic_net": losses.elastic_net(1e-3, 1e-3),
}

#: (lam, lam1, lam2) triples the four lazy kernels are exercised with.
LAM_TRIPLES = {
    "none": (0.0, 0.0, 0.0),
    "l2": (1e-3, 0.0, 0.0),
    "l1": (0.0, 1e-3, 0.0),
    "elastic_net": (0.0, 1e-3, 1e-3),
}


def _bits(a) -> np.ndarray:
    return np.asarray(a).view(np.uint32)


def _ulp_diff(a, b) -> int:
    """Max distance in float32 ulps, via the lexicographic int mapping."""
    ia = _bits(a).astype(np.int64)
    ib = _bits(b).astype(np.int64)
    ia = np.where(ia >= 0x80000000, 0x80000000 - ia, ia)
    ib = np.where(ib >= 0x80000000, 0x80000000 - ib, ib)
    return int(np.abs(ia - ib).max()) if ia.size else 0


def oracle_epoch(bd, labels, w, z, s0, samples, eta, mask, reg, use_kernels):
    """The per-step dense oracle: one _sim_update per block per inner step,
    margins summed in the shared tree order — the q-independent reference
    the exact-lazy epoch must reproduce bit-for-bit."""
    q = bd.num_blocks
    bounds = [0]
    for d_ in bd.block_dims:
        bounds.append(bounds[-1] + d_)
    blocks = [w[bounds[l]:bounds[l + 1]] for l in range(q)]
    z_blocks = [z[bounds[l]:bounds[l + 1]] for l in range(q)]
    loss = losses.logistic
    u = samples.shape[1]
    for m in range(samples.shape[0]):
        ids = samples[m]
        rows = [(bd.indices[l][ids], bd.values[l][ids]) for l in range(q)]
        parts = [
            fdsvrg._sim_margins(rows[l][0], rows[l][1], blocks[l], use_kernels)
            for l in range(q)
        ]
        s_m = fdsvrg.tree_order_sum(parts)
        y = labels[ids]
        coef = (loss.dvalue(s_m, y) - loss.dvalue(s0[ids], y)) / u
        eta_m = jnp.asarray(eta * float(mask[m]), dtype=jnp.float32)
        for l in range(q):
            blocks[l] = fdsvrg._sim_update(
                blocks[l], rows[l][0], rows[l][1], coef, z_blocks[l], eta_m,
                reg.name, reg.lam, use_kernels, lam2=reg.lam2,
            )
    return jnp.concatenate(blocks) if q > 1 else blocks[0]


def _lazy_epoch(bd, labels, w, z, s0, samples, eta, mask, reg, use_kernels):
    klams = fdsvrg._kernel_lams(reg, use_kernels)
    return fdsvrg._lazy_inner_epoch(
        bd.indices, bd.values, labels, w, z, s0, jnp.asarray(samples), eta,
        jnp.asarray(mask), None, "logistic", reg.name, reg.lam,
        bd.block_dims, use_kernels, "exact", lam2=reg.lam2,
        kernel_lams=klams,
    )


def _epoch_case(seed=7, d=256, n=48, nnz=6, m_steps=12, u=2):
    data = make_sparse_classification(
        dim=d, num_instances=n, nnz_per_instance=nnz, seed=seed
    )
    rng = np.random.default_rng(0)
    w0 = jnp.asarray(rng.normal(size=data.dim).astype(np.float32) * 0.01)
    samples = rng.integers(0, n, size=(m_steps, u)).astype(np.int32)
    return data, w0, samples


# ---------------------------------------------------------------------------
# 1. exact-lazy epoch == per-step dense oracle, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("option", ["I", "II"])
@pytest.mark.parametrize("use_kernels", [False, True])
@pytest.mark.parametrize("reg_name", sorted(REGS))
@pytest.mark.parametrize("q", [1, 3])
def test_exact_epoch_matches_per_step_oracle(q, reg_name, use_kernels, option):
    data, w0, samples = _epoch_case()
    bd = BlockCSR.from_padded(data, balanced(data.dim, q))
    reg = REGS[reg_name]
    m_steps = samples.shape[0]
    mask = (
        np.ones(m_steps, np.float32)
        if option == "I"
        else (np.arange(m_steps) < m_steps - 4).astype(np.float32)
    )
    z, s0 = fdsvrg._full_grad_blocks(
        bd.indices, bd.values, data.labels, w0, "logistic", bd.block_dims,
        use_kernels,
    )
    want = oracle_epoch(
        bd, data.labels, w0, z, s0, samples, 0.1, mask, reg, use_kernels
    )
    got = _lazy_epoch(
        bd, data.labels, w0, z, s0, samples, 0.1, mask, reg, use_kernels
    )
    np.testing.assert_array_equal(_bits(got), _bits(want))


def test_never_touched_features_match_oracle():
    """Features no sampled row ever touches must still follow the dense
    decay trajectory exactly — they only ever see the epoch-end flush."""
    rng = np.random.default_rng(3)
    d, n, nnz, m_steps = 64, 16, 3, 10
    # every row's ids live in [0, 8): features 8.. are never touched
    idx = rng.integers(0, 8, size=(n, nnz)).astype(np.int32)
    val = rng.normal(size=(n, nnz)).astype(np.float32)
    labels = np.sign(rng.normal(size=n)).astype(np.float32)
    data = PaddedCSR(
        indices=jnp.asarray(idx), values=jnp.asarray(val),
        labels=jnp.asarray(labels), dim=d,
    )
    bd = BlockCSR.from_padded(data, balanced(d, 1))
    w0 = jnp.asarray(rng.normal(size=d).astype(np.float32))
    samples = rng.integers(0, n, size=(m_steps, 2)).astype(np.int32)
    mask = np.ones(m_steps, np.float32)
    for reg in REGS.values():
        z, s0 = fdsvrg._full_grad_blocks(
            bd.indices, bd.values, data.labels, w0, "logistic",
            bd.block_dims, False,
        )
        want = oracle_epoch(
            bd, data.labels, w0, z, s0, samples, 0.1, mask, reg, False
        )
        got = _lazy_epoch(
            bd, data.labels, w0, z, s0, samples, 0.1, mask, reg, False
        )
        np.testing.assert_array_equal(_bits(got), _bits(want), err_msg=reg.name)
        # and for the decaying regularizers the untouched tail really is
        # nontrivial: it moved (for "none" it rightly stays put — z = 0
        # there and there is no smooth/prox term to apply)
        if reg.name != "none":
            assert not np.array_equal(np.asarray(got)[8:], np.asarray(w0)[8:])


def test_first_and_last_step_only_touches():
    """A feature touched ONLY at step 0 must replay all later decay at the
    flush; one touched ONLY at step M-1 must catch up the whole prefix
    first.  Both bit-equal to the oracle."""
    rng = np.random.default_rng(5)
    d, m_steps = 32, 8
    # row r touches feature r+1 (plus a shared feature 0)
    n = m_steps
    idx = np.stack([np.zeros(n), np.arange(1, n + 1)], axis=1).astype(np.int32)
    val = rng.normal(size=(n, 2)).astype(np.float32)
    labels = np.sign(rng.normal(size=n)).astype(np.float32)
    data = PaddedCSR(
        indices=jnp.asarray(idx), values=jnp.asarray(val),
        labels=jnp.asarray(labels), dim=d,
    )
    bd = BlockCSR.from_padded(data, balanced(d, 1))
    w0 = jnp.asarray(rng.normal(size=d).astype(np.float32))
    samples = np.arange(m_steps, dtype=np.int32)[:, None]  # step m draws row m
    mask = np.ones(m_steps, np.float32)
    for reg in REGS.values():
        z, s0 = fdsvrg._full_grad_blocks(
            bd.indices, bd.values, data.labels, w0, "logistic",
            bd.block_dims, False,
        )
        want = oracle_epoch(
            bd, data.labels, w0, z, s0, samples, 0.1, mask, reg, False
        )
        got = _lazy_epoch(
            bd, data.labels, w0, z, s0, samples, 0.1, mask, reg, False
        )
        np.testing.assert_array_equal(_bits(got), _bits(want), err_msg=reg.name)


def test_padding_collision_id_zero_value_zero():
    """CSR padding lanes carry (id == block lo, value 0.0).  A row that
    ALSO genuinely touches local id 0 forces the dedup to merge real and
    padding contributions at the same id — the classic collision — and
    the catch-up must not replay id 0 twice."""
    rng = np.random.default_rng(11)
    d, n, m_steps = 16, 6, 6
    idx = np.zeros((n, 4), dtype=np.int32)
    val = np.zeros((n, 4), dtype=np.float32)
    for r in range(n):
        idx[r, 0] = 0  # every row genuinely touches id 0...
        val[r, 0] = float(rng.normal())
        idx[r, 1] = int(rng.integers(1, d))
        val[r, 1] = float(rng.normal())
        # ...lanes 2-3 stay (0, 0.0) padding, colliding with lane 0
    labels = np.sign(rng.normal(size=n)).astype(np.float32)
    data = PaddedCSR(
        indices=jnp.asarray(idx), values=jnp.asarray(val),
        labels=jnp.asarray(labels), dim=d,
    )
    bd = BlockCSR.from_padded(data, balanced(d, 1))
    w0 = jnp.asarray(rng.normal(size=d).astype(np.float32))
    samples = rng.integers(0, n, size=(m_steps, 2)).astype(np.int32)
    mask = np.ones(m_steps, np.float32)
    for reg in REGS.values():
        for use_kernels in (False, True):
            z, s0 = fdsvrg._full_grad_blocks(
                bd.indices, bd.values, data.labels, w0, "logistic",
                bd.block_dims, use_kernels,
            )
            want = oracle_epoch(
                bd, data.labels, w0, z, s0, samples, 0.1, mask, reg,
                use_kernels,
            )
            got = _lazy_epoch(
                bd, data.labels, w0, z, s0, samples, 0.1, mask, reg,
                use_kernels,
            )
            np.testing.assert_array_equal(
                _bits(got), _bits(want),
                err_msg=f"{reg.name} kernels={use_kernels}",
            )


# ---------------------------------------------------------------------------
# 2. the four lazy kernels vs their jnp reference oracles, bitwise
# ---------------------------------------------------------------------------


def _kernel_case(seed, d=64, u=3, nnz=4, m_steps=9):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=d).astype(np.float32))
    last = jnp.asarray(rng.integers(0, m_steps, size=d).astype(np.int32))
    z = jnp.asarray(rng.normal(size=d).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, d, size=(u, nnz)).astype(np.int32))
    val = jnp.asarray(rng.normal(size=(u, nnz)).astype(np.float32))
    coef = jnp.asarray(rng.normal(size=u).astype(np.float32))
    corr = jnp.asarray(rng.uniform(1.0, 20.0, size=d).astype(np.float32))
    return w, last, z, idx, val, coef, corr


@pytest.mark.parametrize("lams", sorted(LAM_TRIPLES))
def test_lazy_catchup_kernel_matches_ref_bitwise(lams):
    lam, lam1, lam2 = LAM_TRIPLES[lams]
    w, last, z, idx, _, _, _ = _kernel_case(1)
    eta = jnp.float32(0.1)
    m = jnp.asarray(6, jnp.int32)
    stop = jnp.asarray(7, jnp.int32)
    # jit the ref (the fused-kernel test idiom): eager op-by-op rounding
    # differs from the compiled kernel by FMA contraction
    want_w, want_last = jax.jit(
        ref.lazy_catchup_ref, static_argnames=("lam1", "lam2")
    )(w, last, z, idx, eta, m, stop, lam=jnp.float32(lam), lam1=lam1,
      lam2=lam2)
    got_w, got_last = ops.lazy_block_catchup(
        w, last, z, idx, eta, m, stop, lam=jnp.float32(lam), lam1=lam1,
        lam2=lam2, interpret=True,
    )
    np.testing.assert_array_equal(_bits(got_w), _bits(want_w))
    np.testing.assert_array_equal(np.asarray(got_last), np.asarray(want_last))


@pytest.mark.parametrize("lams", sorted(LAM_TRIPLES))
@pytest.mark.parametrize("eta_m", [0.1, 0.0])
def test_lazy_touch_kernel_matches_ref_bitwise(lams, eta_m):
    lam, lam1, lam2 = LAM_TRIPLES[lams]
    w, _, z, idx, val, coef, _ = _kernel_case(2)
    want = jax.jit(
        ref.lazy_touch_update_ref, static_argnames=("lam", "lam1", "lam2")
    )(w, idx, val, coef, z, jnp.float32(eta_m), lam=lam, lam1=lam1,
      lam2=lam2)
    got = ops.lazy_block_touch_update(
        w, idx, val, coef, z, jnp.float32(eta_m), lam=lam, lam1=lam1,
        lam2=lam2, interpret=True,
    )
    np.testing.assert_array_equal(_bits(got), _bits(want))


@pytest.mark.parametrize("lams", sorted(LAM_TRIPLES))
def test_lazy_flush_kernel_matches_ref_bitwise(lams):
    lam, lam1, lam2 = LAM_TRIPLES[lams]
    w, last, z, _, _, _, _ = _kernel_case(3)
    eta = jnp.float32(0.1)
    total = jnp.asarray(9, jnp.int32)
    stop = jnp.asarray(5, jnp.int32)  # Option II: masked tail to replay
    want = jax.jit(
        ref.lazy_flush_ref, static_argnames=("lam1", "lam2")
    )(w, last, z, eta, total, stop, lam=jnp.float32(lam), lam1=lam1,
      lam2=lam2)
    got = ops.lazy_block_flush(
        w, last, z, eta, total, stop, lam=jnp.float32(lam), lam1=lam1,
        lam2=lam2, interpret=True,
    )
    np.testing.assert_array_equal(_bits(got), _bits(want))


@pytest.mark.parametrize("lams", sorted(LAM_TRIPLES))
def test_lazy_proba_kernel_matches_ref_bitwise(lams):
    lam, lam1, lam2 = LAM_TRIPLES[lams]
    w, _, z, idx, val, coef, corr = _kernel_case(4)
    want = jax.jit(
        ref.lazy_proba_update_ref, static_argnames=("lam", "lam1", "lam2")
    )(w, idx, val, coef, z, corr, jnp.float32(0.1), lam=lam, lam1=lam1,
      lam2=lam2)
    got = ops.lazy_block_proba_update(
        w, idx, val, coef, z, corr, jnp.float32(0.1), lam=lam, lam1=lam1,
        lam2=lam2, interpret=True,
    )
    np.testing.assert_array_equal(_bits(got), _bits(want))


def test_step_corrections_values():
    """corr_j = 1 / (1 - (1 - nnz_col_j/n)^u); untouchable features (zero
    column count) are pinned to 1 so they contribute no NaN/inf."""
    nnz_col = jnp.asarray([0, 1, 4, 8], jnp.int32)
    n, u = 8, 2
    corr = np.asarray(ops.step_corrections(nnz_col, n, u))
    assert corr[0] == 1.0
    for j, c in ((1, 1), (2, 4), (3, 8)):
        p = 1.0 - (1.0 - c / n) ** u
        np.testing.assert_allclose(corr[j], 1.0 / p, rtol=1e-6)
    assert np.isfinite(corr).all()


# ---------------------------------------------------------------------------
# 3. drivers
# ---------------------------------------------------------------------------


def _driver_data(seed=7):
    return make_sparse_classification(
        dim=256, num_instances=48, nnz_per_instance=6, seed=seed
    )


@pytest.mark.parametrize("use_kernels", [False, True])
@pytest.mark.parametrize("reg_name", sorted(REGS))
def test_serial_lazy_exact_bitwise(reg_name, use_kernels):
    data = _driver_data()
    cfg = SVRGConfig(eta=0.1, inner_steps=10, outer_iters=2, seed=5,
                     option="II")
    reg = REGS[reg_name]
    a = run_serial_svrg(data, losses.logistic, reg, cfg,
                        use_kernels=use_kernels)
    b = run_serial_svrg(data, losses.logistic, reg, cfg,
                        use_kernels=use_kernels, lazy_updates="exact")
    np.testing.assert_array_equal(_bits(a.w), _bits(b.w))
    for ha, hb in zip(a.history, b.history):
        assert ha.objective == hb.objective


@pytest.mark.parametrize("reg_name", sorted(REGS))
def test_fdsvrg_q1_lazy_exact_bitwise(reg_name):
    data = _driver_data()
    cfg = SVRGConfig(eta=0.1, inner_steps=10, outer_iters=2, seed=5)
    part = balanced(data.dim, 1)
    reg = REGS[reg_name]
    a = run_fdsvrg(data, part, losses.logistic, reg, cfg)
    b = run_fdsvrg(data, part, losses.logistic, reg, cfg,
                   lazy_updates="exact")
    np.testing.assert_array_equal(_bits(a.w), _bits(b.w))


@pytest.mark.parametrize("reg_name", ["none", "l2"])
def test_fdsvrg_multiblock_smooth_bitwise(reg_name):
    data = _driver_data()
    cfg = SVRGConfig(eta=0.1, inner_steps=10, outer_iters=2, seed=5)
    part = balanced(data.dim, 3)
    reg = REGS[reg_name]
    a = run_fdsvrg(data, part, losses.logistic, reg, cfg)
    b = run_fdsvrg(data, part, losses.logistic, reg, cfg,
                   lazy_updates="exact")
    np.testing.assert_array_equal(_bits(a.w), _bits(b.w))


@pytest.mark.parametrize("reg_name", ["l1", "elastic_net"])
def test_fdsvrg_multiblock_prox_ulp_bounded(reg_name):
    """At q>1 the prox family is ulp-bounded, not bitwise, against the
    fused dense scan — and the slack is in the DENSE side, not the lazy
    side.  Verified coordinate-by-coordinate with double-precision FMA
    emulation: the dense ``_inner_epoch`` soft threshold evaluates
    ``|v| - eta*lam`` as a single-rounding FMA at q=3 but against the
    pre-rounded ``fl(eta*lam)`` at q=1, so its own bits are q-dependent.
    The lazy epoch is pinned bitwise to the q-independent per-step oracle
    (the tests above); here we only require it to stay within a small ulp
    envelope of the fused scan — per inner step the two threshold
    evaluations differ by 1-2 ulp, and the divergence compounds across
    outer iterations because the full gradient is recomputed from the
    (slightly different) iterate."""
    data = _driver_data()
    cfg = SVRGConfig(eta=0.1, inner_steps=10, outer_iters=2, seed=5)
    part = balanced(data.dim, 3)
    reg = REGS[reg_name]
    a = run_fdsvrg(data, part, losses.logistic, reg, cfg)
    b = run_fdsvrg(data, part, losses.logistic, reg, cfg,
                   lazy_updates="exact")
    assert _ulp_diff(a.w, b.w) <= 32
    np.testing.assert_allclose(np.asarray(a.w), np.asarray(b.w), rtol=1e-5,
                               atol=1e-7)


@pytest.mark.parametrize("q", [1, 3])
@pytest.mark.parametrize("reg_name", sorted(REGS))
def test_sim_driver_lazy_exact_bitwise(reg_name, q):
    data = _driver_data()
    cfg = SVRGConfig(eta=0.1, inner_steps=10, outer_iters=2, seed=5,
                     option="II")
    part = balanced(data.dim, q)
    reg = REGS[reg_name]
    a = fdsvrg_worker_simulation(data, part, losses.logistic, reg, cfg)
    b = fdsvrg_worker_simulation(data, part, losses.logistic, reg, cfg,
                                 lazy_updates="exact")
    np.testing.assert_array_equal(_bits(a.w), _bits(b.w))


# ---------------------------------------------------------------------------
# probabilistic variant: unbiasedness + convergence
# ---------------------------------------------------------------------------


def test_proba_expected_update_matches_dense():
    """Over many independent single-step draws, the per-feature mean
    update of the probabilistic variant must match the dense oracle's:
    the decay is applied with probability p_j but scaled by 1/p_j."""
    rng = np.random.default_rng(0)
    d, n, nnz, u, draws = 64, 32, 4, 2, 512
    data = make_sparse_classification(
        dim=d, num_instances=n, nnz_per_instance=nnz, seed=9
    )
    bd = BlockCSR.from_padded(data, balanced(d, 1))
    reg = losses.l2(1e-2)
    w0 = jnp.asarray(rng.normal(size=d).astype(np.float32) * 0.1)
    eta = 0.05
    z, s0 = fdsvrg._full_grad_blocks(
        bd.indices, bd.values, data.labels, w0, "logistic", bd.block_dims,
        False,
    )
    corr = fdsvrg._lazy_corrections(bd, n, u, "proba")
    mask = jnp.ones(1, dtype=jnp.float32)
    d_sum = np.zeros(d, np.float64)
    p_sum = np.zeros(d, np.float64)
    for k in range(draws):
        samples = jnp.asarray(
            rng.integers(0, n, size=(1, u)).astype(np.int32)
        )
        dense = fdsvrg._inner_epoch(
            bd.indices, bd.values, data.labels, w0, z, s0, samples, eta,
            mask, "logistic", reg.name, reg.lam, bd.block_dims, False,
        )
        proba = fdsvrg._lazy_inner_epoch(
            bd.indices, bd.values, data.labels, w0, z, s0, samples, eta,
            mask, corr, "logistic", reg.name, reg.lam, bd.block_dims,
            False, "proba",
        )
        d_sum += np.asarray(dense, np.float64) - np.asarray(w0, np.float64)
        p_sum += np.asarray(proba, np.float64) - np.asarray(w0, np.float64)
    mean_dense = d_sum / draws
    mean_proba = p_sum / draws
    # CLT tolerance: the proba update per draw is O(corr * eta * decay);
    # 512 draws shrink the sampling noise ~23x below that scale.
    scale = float(np.abs(mean_dense).max())
    np.testing.assert_allclose(
        mean_proba, mean_dense, atol=max(scale, 1e-4) * 0.35
    )
    # and the bias really is small relative to the mean update magnitude
    err = np.abs(mean_proba - mean_dense).mean()
    assert err <= max(np.abs(mean_dense).mean(), 1e-6)


@pytest.mark.slow
def test_proba_end_to_end_news20_converges():
    """The unbiased variant must actually optimize on the real preset: a
    quick news20 run through the front door, final objective within a
    loose rtol of the eager path.  The rtol is honest about the price of
    the estimator: news20's columns are stored by ~1 row each, so the
    corrections are ~N and the per-touch decay variance is large — the
    proba run tracks the eager objective to ~7-9 % here (measured across
    seeds 1/5/11/23), while genuinely descending.  It is a different
    stochastic estimator, not a bit-identical one; bit-level claims
    belong to the exact variant only."""
    from repro.api import ExperimentSpec, solve

    base = dict(method="serial", dataset="news20", reg=losses.l2(1e-4),
                eta=0.05, inner_steps=998, outer_iters=4, seed=5)
    a = solve(ExperimentSpec(**base))
    b = solve(ExperimentSpec(lazy_updates="proba", **base))
    fa, fb = a.final_objective(), b.final_objective()
    assert np.isfinite(fb)
    assert abs(fa - fb) <= 0.15 * abs(fa)
    # and it descended from the start
    assert fb < a.history[0].objective


# ---------------------------------------------------------------------------
# validation surfaces
# ---------------------------------------------------------------------------


def test_check_lazy_rejects_unknown_variant():
    with pytest.raises(ValueError, match="lazy_updates"):
        _check_lazy("bogus")
    data = _driver_data()
    cfg = SVRGConfig(eta=0.1, inner_steps=4, outer_iters=1)
    with pytest.raises(ValueError, match="lazy_updates"):
        run_serial_svrg(data, losses.logistic, losses.no_reg(), cfg,
                        lazy_updates="bogus")


def test_spec_and_registry_validation():
    from repro.api import ExperimentSpec, method_info, solve

    data = _driver_data()
    with pytest.raises(ValueError, match="lazy_updates"):
        ExperimentSpec(method="serial", data=data, lazy_updates="nope")
    # capability mismatch fails loudly in solve(), not silently
    with pytest.raises(ValueError, match="does not support lazy_updates"):
        solve(ExperimentSpec(method="dsvrg", data=data, lazy_updates="exact",
                             outer_iters=1, inner_steps=4))
    for name in ("serial", "fdsvrg", "fdsvrg_sim"):
        assert method_info(name).supports_lazy
    for name in ("dsvrg", "synsvrg", "asysvrg", "pslite_sgd",
                 "fdsvrg_sharded"):
        assert not method_info(name).supports_lazy


def test_solve_lazy_exact_bitwise_through_front_door():
    from repro.api import ExperimentSpec, solve

    data = _driver_data()
    base = dict(data=data, reg=losses.l1(1e-3), outer_iters=2,
                inner_steps=10, eta=0.1, q=1)
    for method in ("serial", "fdsvrg", "fdsvrg_sim"):
        a = solve(ExperimentSpec(method=method, **base))
        b = solve(ExperimentSpec(method=method, lazy_updates="exact", **base))
        np.testing.assert_array_equal(_bits(a.w), _bits(b.w), err_msg=method)
