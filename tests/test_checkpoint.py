"""Checkpoint round-trip: full train state, dtype preservation, specs meta."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import get_config, reduced_config
from repro.models import transformer
from repro.optim.optimizers import adamw
from repro.sharding.specs import unsharded_ctx
from repro.train.loop import init_state


def test_roundtrip_train_state(tmp_path):
    cfg = reduced_config(get_config("smollm-360m"))
    opt = adamw(1e-3)
    state = init_state(cfg, jax.random.key(0), opt, tp=1)
    path = os.path.join(tmp_path, "ck")
    specs = transformer.param_specs(state["params"], cfg, unsharded_ctx())
    ckpt.save(path, state, specs={"params": specs})

    # perturb, then restore into the same structure
    zeroed = jax.tree.map(lambda a: jnp.zeros_like(a), state)
    restored = ckpt.restore(path, zeroed)

    orig_leaves = jax.tree.leaves(state)
    rest_leaves = jax.tree.leaves(restored)
    assert len(orig_leaves) == len(rest_leaves)
    for a, b in zip(orig_leaves, rest_leaves):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    meta = ckpt.load_meta(path)
    assert len(meta["keys"]) == len(orig_leaves)
    assert meta["specs"]  # sharding metadata recorded


def test_restore_shape_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "ck2")
    ckpt.save(path, {"a": jnp.ones(3)})
    try:
        ckpt.restore(path, {"a": jnp.ones(3), "b": jnp.ones(2)})
        assert False, "expected ValueError"
    except ValueError:
        pass


def test_restore_validates_leaf_shape(tmp_path):
    import pytest

    path = os.path.join(tmp_path, "ck_shape")
    ckpt.save(path, {"a": jnp.ones((3, 2))})
    with pytest.raises(ValueError, match=r"shape .*template wants"):
        ckpt.restore(path, {"a": jnp.ones((2, 3))})


def test_restore_validates_leaf_dtype(tmp_path):
    import pytest

    path = os.path.join(tmp_path, "ck_dtype")
    ckpt.save(path, {"a": jnp.ones(4, dtype=jnp.float32)})
    with pytest.raises(ValueError, match=r"dtype .*template wants"):
        ckpt.restore(path, {"a": jnp.ones(4, dtype=jnp.int32)})


def test_restore_validates_tree_keys(tmp_path):
    import pytest

    path = os.path.join(tmp_path, "ck_keys")
    ckpt.save(path, {"a": jnp.ones(2), "b": jnp.zeros(2)})
    with pytest.raises(ValueError, match="tree structure mismatch"):
        ckpt.restore(path, {"a": jnp.ones(2), "c": jnp.zeros(2)})


def test_roundtrip_non_float_dtypes(tmp_path):
    """The outer-loop checkpoint state carries int counters and bool
    masks; they must round-trip without a float detour."""
    state = {
        "counters": jnp.asarray([3, 0, 7], dtype=jnp.int32),
        "mask": jnp.asarray([True, False, True]),
        "step": np.int64(41),
    }
    path = os.path.join(tmp_path, "ck_nf")
    ckpt.save(path, state)
    out = ckpt.restore(
        path,
        {
            "counters": jnp.zeros(3, dtype=jnp.int32),
            "mask": jnp.zeros(3, dtype=bool),
            "step": np.int64(0),
        },
    )
    assert out["counters"].dtype == jnp.int32
    assert out["mask"].dtype == jnp.bool_
    np.testing.assert_array_equal(np.asarray(out["counters"]), [3, 0, 7])
    np.testing.assert_array_equal(np.asarray(out["mask"]), [True, False, True])
    assert int(out["step"]) == 41


def test_save_extra_meta_roundtrip(tmp_path):
    """The json sidecar carries non-array state (rng state, meter
    counters) exactly — including ints wider than 64 bits (PCG64)."""
    big = 2**127 + 11
    extra = {"outer_next": 5, "rng_state": {"state": big}, "time_s": 0.1 + 0.2}
    path = os.path.join(tmp_path, "ck_extra")
    ckpt.save(path, {"w": jnp.ones(2)}, extra=extra)
    meta = ckpt.load_meta(path)
    assert meta["extra"]["outer_next"] == 5
    assert meta["extra"]["rng_state"]["state"] == big
    assert meta["extra"]["time_s"] == 0.1 + 0.2  # float round-trip is exact


def test_training_resumes_bitwise(tmp_path):
    """step -> save -> restore -> step  ==  step -> step."""
    from repro.data.token_stream import PipelineConfig, batches
    from repro.train.loop import TrainSettings, make_train_step

    cfg = reduced_config(get_config("granite-moe-1b-a400m"))
    opt = adamw(1e-3)
    state = init_state(cfg, jax.random.key(1), opt, tp=1)
    step = jax.jit(make_train_step(cfg, unsharded_ctx(), opt, TrainSettings()))
    batch = {
        k: jnp.asarray(v)
        for k, v in next(batches(cfg, PipelineConfig(2, 16, seed=0))).items()
    }

    s1, _ = step(state, batch)
    path = os.path.join(tmp_path, "ck3")
    ckpt.save(path, s1)
    s1r = ckpt.restore(path, jax.tree.map(jnp.zeros_like, s1))
    s2a, m2a = step(s1, batch)
    s2b, m2b = step(s1r, batch)
    np.testing.assert_array_equal(
        np.asarray(m2a["loss"]), np.asarray(m2b["loss"])
    )
    for a, b in zip(jax.tree.leaves(s2a), jax.tree.leaves(s2b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
