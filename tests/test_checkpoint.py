"""Checkpoint round-trip: full train state, dtype preservation, specs meta."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import get_config, reduced_config
from repro.models import transformer
from repro.optim.optimizers import adamw
from repro.sharding.specs import unsharded_ctx
from repro.train.loop import init_state


def test_roundtrip_train_state(tmp_path):
    cfg = reduced_config(get_config("smollm-360m"))
    opt = adamw(1e-3)
    state = init_state(cfg, jax.random.key(0), opt, tp=1)
    path = os.path.join(tmp_path, "ck")
    specs = transformer.param_specs(state["params"], cfg, unsharded_ctx())
    ckpt.save(path, state, specs={"params": specs})

    # perturb, then restore into the same structure
    zeroed = jax.tree.map(lambda a: jnp.zeros_like(a), state)
    restored = ckpt.restore(path, zeroed)

    orig_leaves = jax.tree.leaves(state)
    rest_leaves = jax.tree.leaves(restored)
    assert len(orig_leaves) == len(rest_leaves)
    for a, b in zip(orig_leaves, rest_leaves):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    meta = ckpt.load_meta(path)
    assert len(meta["keys"]) == len(orig_leaves)
    assert meta["specs"]  # sharding metadata recorded


def test_restore_shape_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "ck2")
    ckpt.save(path, {"a": jnp.ones(3)})
    try:
        ckpt.restore(path, {"a": jnp.ones(3), "b": jnp.ones(2)})
        assert False, "expected ValueError"
    except ValueError:
        pass


def test_training_resumes_bitwise(tmp_path):
    """step -> save -> restore -> step  ==  step -> step."""
    from repro.data.pipeline import PipelineConfig, batches
    from repro.train.loop import TrainSettings, make_train_step

    cfg = reduced_config(get_config("granite-moe-1b-a400m"))
    opt = adamw(1e-3)
    state = init_state(cfg, jax.random.key(1), opt, tp=1)
    step = jax.jit(make_train_step(cfg, unsharded_ctx(), opt, TrainSettings()))
    batch = {
        k: jnp.asarray(v)
        for k, v in next(batches(cfg, PipelineConfig(2, 16, seed=0))).items()
    }

    s1, _ = step(state, batch)
    path = os.path.join(tmp_path, "ck3")
    ckpt.save(path, s1)
    s1r = ckpt.restore(path, jax.tree.map(jnp.zeros_like, s1))
    s2a, m2a = step(s1, batch)
    s2b, m2b = step(s1r, batch)
    np.testing.assert_array_equal(
        np.asarray(m2a["loss"]), np.asarray(m2b["loss"])
    )
    for a, b in zip(jax.tree.leaves(s2a), jax.tree.leaves(s2b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
