"""Quickstart: the public API end to end on a news20-shaped problem.

    PYTHONPATH=src python examples/quickstart.py

Three things, each a few lines of user code:

1. ``solve(ExperimentSpec(...))`` — FD-SVRG and serial SVRG through the
   ONE front door, demonstrating the paper's §4.3 equivalence (identical
   update sequence, so identical objectives) plus the communication
   meter.
2. Method dispatch — the same spec re-targeted at a baseline
   (``spec.replace(method="dsvrg")``) for a like-for-like comparison.
3. ``FDSVRGClassifier`` — fit / predict / score, the serving scenario.
"""

from repro.api import ExperimentSpec, FDSVRGClassifier, solve
from repro.configs.fdsvrg_linear import get_config
from repro.core import losses
from repro.data import datasets


def main():
    # get_config follows the registry's one-line error convention: a
    # misspelled preset (or method= below) lists the valid names instead
    # of surfacing a raw KeyError.
    lc = get_config("fdsvrg-news20")
    data = datasets.load(lc.dataset)
    print(f"dataset {lc.dataset}: d={data.dim:,} N={data.num_instances:,} "
          f"(d/N={data.dim/data.num_instances:.0f} — the paper's regime)")

    # --- 1. one spec, two methods, one meter -----------------------------
    # conditioning-preserving lambda at container scale (see EXPERIMENTS.md)
    spec = ExperimentSpec(
        method="fdsvrg",
        data=data,
        reg=losses.l2(2.0 / data.num_instances),
        q=lc.workers,
        eta=2.0,
        batch_size=8,
        inner_steps=data.num_instances // 8,
        outer_iters=8,
    )
    fd = solve(spec)
    serial = solve(spec.replace(method="serial"))

    print(f"\n{'outer':>5} {'FD-SVRG obj':>12} {'serial obj':>12} "
          f"{'comm scalars':>14}")
    for h_fd, h_s in zip(fd.history, serial.history):
        print(f"{h_fd.outer:>5} {h_fd.objective:>12.6f} {h_s.objective:>12.6f} "
              f"{h_fd.comm_scalars:>14,}")
    drift = abs(fd.final_objective() - serial.final_objective())
    print(f"\nFD-SVRG == serial SVRG (paper §4.3): |Δobj| = {drift:.2e}")

    # --- 2. the same problem through a baseline driver -------------------
    ds = solve(spec.replace(method="dsvrg", eta=1.0))
    print(f"DSVRG at the same spec: obj {ds.final_objective():.6f}, "
          f"{ds.meter.total_scalars:,} scalars vs FD-SVRG's "
          f"{fd.meter.total_scalars:,} "
          f"(the paper's 2qd-vs-2qN communication gap)")

    # --- 3. the estimator: fit / score, then two warm-started outers -----
    clf = FDSVRGClassifier(
        method="fdsvrg", workers=lc.workers, eta=2.0,
        lam=2.0 / data.num_instances, batch_size=8,
        inner_steps=data.num_instances // 8, outer_iters=4,
    )
    clf.fit(data)
    acc = clf.score(data)
    print(f"\nFDSVRGClassifier: train accuracy {acc:.3f} after "
          f"{len(clf.history_)} outers (objective "
          f"{clf.final_objective():.6f})")
    clf.partial_fit(data, outer_iters=2)
    print(f"after 2 warm-started outers: accuracy {clf.score(data):.3f}, "
          f"objective {clf.final_objective():.6f}")
    # d/N ~ 68 with conditioning-preserving lambda: the model is heavily
    # regularized, so "clearly above chance" is the right sanity bar.
    assert acc > 0.65, "quickstart sanity: training accuracy above chance"


if __name__ == "__main__":
    main()
