"""Quickstart: FD-SVRG on a news20-shaped sparse problem (the paper, end
to end, in ~20 lines of user code).

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.configs.fdsvrg_linear import CONFIGS
from repro.core import losses
from repro.core.fdsvrg import SVRGConfig, objective, run_fdsvrg, run_serial_svrg
from repro.core.partition import balanced
from repro.data import datasets
from repro.dist import ClusterModel, SimBackend


def main():
    lc = CONFIGS["fdsvrg-news20"]
    data = datasets.load(lc.dataset)
    print(f"dataset {lc.dataset}: d={data.dim:,} N={data.num_instances:,} "
          f"(d/N={data.dim/data.num_instances:.0f} — the paper's regime)")

    loss = losses.LOSSES[lc.loss]
    # conditioning-preserving lambda at container scale (see EXPERIMENTS.md)
    reg = losses.l2(2.0 / data.num_instances)
    cfg = SVRGConfig(eta=2.0, inner_steps=data.num_instances // 8,
                     outer_iters=8, batch_size=8)

    part = balanced(data.dim, lc.workers)
    backend = SimBackend(lc.workers, ClusterModel(flops_per_s=2e8))
    fd = run_fdsvrg(data, part, loss, reg, cfg, backend=backend)
    serial = run_serial_svrg(data, loss, reg, cfg)

    print(f"\n{'outer':>5} {'FD-SVRG obj':>12} {'serial obj':>12} "
          f"{'comm scalars':>14}")
    for h_fd, h_s in zip(fd.history, serial.history):
        print(f"{h_fd.outer:>5} {h_fd.objective:>12.6f} {h_s.objective:>12.6f} "
              f"{h_fd.comm_scalars:>14,}")
    drift = abs(fd.final_objective() - serial.final_objective())
    print(f"\nFD-SVRG == serial SVRG (paper §4.3): |Δobj| = {drift:.2e}")
    rep = backend.report("fdsvrg")
    print(f"total communication: {rep.scalars:,} scalars "
          f"({rep.bytes_on_wire:,} bytes) across {rep.q} workers "
          f"(DSVRG would need ~{2*lc.workers*data.dim:,} scalars per outer iteration)")


if __name__ == "__main__":
    main()
