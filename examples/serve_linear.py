"""Serving quickstart: batched sparse inference + online updates.

    PYTHONPATH=src python examples/serve_linear.py

The ``repro.serve`` path in four steps, each a few lines of user code:

1. fit an ``FDSVRGClassifier`` on a warmup slice — its ``coef_`` becomes
   the engine's version-0 :class:`~repro.serve.engine.WeightSnapshot`;
2. score a padded batch through the :class:`~repro.serve.engine.
   PredictionEngine` — bit-identical to ``clf.decision_function`` on
   the same rows (the hard contract ``tests/test_serve_engine.py`` pins);
3. micro-batch ragged requests with :class:`~repro.serve.batching.
   MicroBatcher` — power-of-two nnz/row buckets keep the compiled-shape
   universe bounded no matter what the traffic looks like;
4. run the full serve loop: inference interleaved with ``partial_fit``,
   atomic snapshot swaps, per-request staleness.
"""

import numpy as np

from repro.data.sparse import PaddedCSR
from repro.serve import (
    MicroBatcher,
    PredictionEngine,
    run_serve_loop,
    synthetic_request_source,
)


def main():
    # a planted-separator request stream: 1000 sparse rows, nnz varies
    # per row (2..32 stored entries), labels from a hidden w*
    stream = synthetic_request_source(
        dim=4096, num_requests=1000, nnz_lo=2, nnz_hi=32, seed=0
    )
    data = stream.materialize()

    # --- 1. warm start: fit on the first 200 rows ------------------------
    from repro.api import FDSVRGClassifier

    warm = PaddedCSR(
        indices=data.indices[:200], values=data.values[:200],
        labels=data.labels[:200], dim=data.dim,
    )
    clf = FDSVRGClassifier(method="serial", eta=0.3, lam=1e-3,
                           inner_steps=32, outer_iters=2)
    clf.fit(warm)
    print(f"warm model: dim={data.dim}, train acc on warmup "
          f"{clf.score(warm, np.asarray(warm.labels)):.3f}")

    # --- 2. the engine serves the estimator's exact numbers --------------
    engine = PredictionEngine.from_estimator(clf)
    margins = engine.margins(data.indices, data.values)
    assert np.array_equal(margins, clf.decision_function(data))
    print(f"engine v{engine.version}: {margins.shape[0]} margins, "
          f"bit-identical to decision_function")

    # --- 3. ragged requests -> bounded compiled shapes -------------------
    batcher = MicroBatcher(max_batch=64, max_delay_s=0.001, min_width=8)

    # --- 4. serve while training: updates every 2 chunks ------------------
    report = run_serve_loop(
        stream, engine, batcher,
        classifier=clf, update_every_chunks=2, chunk_rows=100,
    )
    lat = report.latency_percentiles()
    print(f"served {report.num_requests} requests in "
          f"{report.num_batches} batches: "
          f"{report.predictions_per_s:.0f} pred/s, "
          f"p50 {lat['p50_ms']:.2f}ms / p99 {lat['p99_ms']:.2f}ms")
    print(f"compiled shapes: {report.compiled_shapes} "
          f"(buckets {sorted(report.bucket_counts)})")
    print(f"versions published mid-stream: {report.versions_published}, "
          f"staleness histogram {report.staleness_histogram()}")
    assert report.versions_published >= 1
    print("OK")


if __name__ == "__main__":
    main()
