"""Batched serving example: prefill + greedy decode across architecture
families, including the SSM/hybrid caches and the audio codebook heads.

    PYTHONPATH=src python examples/serve_decode.py

This exercises the LM decode path.  For serving the paper's sparse
linear classifiers (micro-batched margins + online updates via
``repro.serve``), see ``examples/serve_linear.py``.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import transformer
from repro.sharding.specs import unsharded_ctx
from repro.train.serve import make_serve_step

ARCHS = ["smollm-360m", "mamba2-2.7b", "jamba-v0.1-52b", "musicgen-large"]


def main():
    ctx = unsharded_ctx()
    rng = np.random.default_rng(0)
    b, s0, gen = 4, 16, 12
    for arch in ARCHS:
        cfg = reduced_config(get_config(arch))
        params = transformer.init_params(cfg, jax.random.key(1), tp=1)
        max_len = s0 + gen
        if cfg.modality == "audio-codec":
            prompt = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (b, s0, cfg.num_codebooks)), jnp.int32
            )
        else:
            prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s0)), jnp.int32)
        t0 = time.perf_counter()
        _, cache = transformer.prefill(params, cfg, {"tokens": prompt}, max_len, ctx)
        serve = jax.jit(make_serve_step(cfg, ctx))
        tok = prompt[:, -1:]
        ids = []
        for i in range(gen):
            tok, _, cache = serve(params, cache, tok, jnp.asarray(s0 + i - 1, jnp.int32))
            ids.append(np.asarray(tok))
        dt = time.perf_counter() - t0
        flat = np.concatenate(ids, axis=1)[0].flatten()
        print(f"{arch:>18} [{cfg.arch_type:>6}]  {gen} tokens x {b} reqs "
              f"in {dt:.2f}s -> {flat[:10].tolist()}")


if __name__ == "__main__":
    main()
