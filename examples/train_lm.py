"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps with the framework's full stack (pipeline -> model -> train loop ->
checkpoint), on CPU.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--tiny]

``--tiny`` drops to the smoke-scale model for CI-speed runs; the default
builds a ~100M-parameter llama-style model (smollm-360m geometry, shortened
stack) which is the "train a ~100M model for a few hundred steps" example
from the deliverables.
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs import get_config, reduced_config
from repro.data.token_stream import PipelineConfig, batches
from repro.optim import optimizers
from repro.sharding.specs import unsharded_ctx
from repro.train.loop import TrainSettings, init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ck")
    args = ap.parse_args()

    base = get_config("smollm-360m")
    if args.tiny:
        cfg = reduced_config(base)
    else:
        # ~100M params: smollm-360m geometry at 8 layers, fp32 for CPU speed
        cfg = dataclasses.replace(
            base, name="smollm-100m", num_layers=8, dtype="float32",
        )
    ctx = unsharded_ctx()
    opt = optimizers.adamw(1e-3, weight_decay=0.01)
    state = init_state(cfg, jax.random.key(0), opt, tp=1)
    n_params = sum(p.size for p in jax.tree.leaves(state["params"]))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{cfg.num_layers} layers, d_model={cfg.d_model}")

    step = jax.jit(make_train_step(cfg, ctx, opt, TrainSettings()))
    it = batches(cfg, PipelineConfig(args.batch, args.seq, seed=0))

    losses_seen = []
    t0 = time.perf_counter()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, metrics = step(state, batch)
        losses_seen.append(float(metrics["ce"]))
        if (i + 1) % 25 == 0:
            dt = (time.perf_counter() - t0) / (i + 1)
            print(f"step {i+1:4d}  ce={losses_seen[-1]:.4f}  ({dt:.2f}s/step)",
                  flush=True)

    ckpt.save(args.ckpt, state)
    print(f"\nfirst-25 mean ce: {sum(losses_seen[:25])/25:.4f}")
    print(f"last-25  mean ce: {sum(losses_seen[-25:])/25:.4f}")
    assert sum(losses_seen[-25:]) < sum(losses_seen[:25]), "did not learn!"
    print(f"checkpoint: {args.ckpt}.npz — done.")


if __name__ == "__main__":
    main()
