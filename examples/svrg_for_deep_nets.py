"""Beyond-paper: the paper's optimizer (SVRG) applied to a deep LM.

The paper notes (§1) that the feature-distributed framework "can also be
applied to SGD and other variants ... and other linear models"; this
example goes one step further and runs variance-reduced training on a
transformer, using the framework's optim.svrg wrapper: an anchor snapshot
plus a periodically refreshed large-batch gradient, with the inner steps
using the control variate g(w) - g(w̃) + z.

    PYTHONPATH=src python examples/svrg_for_deep_nets.py
"""

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.data.token_stream import PipelineConfig, batches
from repro.models import transformer
from repro.optim import optimizers
from repro.sharding.specs import unsharded_ctx
from repro.train.loop import TrainSettings, loss_fn

ANCHOR_EVERY = 20
STEPS = 100


def main():
    cfg = reduced_config(get_config("smollm-360m"))
    ctx = unsharded_ctx()
    settings = TrainSettings()
    base = optimizers.sgd(0.05)
    opt = optimizers.svrg(base)

    params = transformer.init_params(cfg, jax.random.key(0), tp=1)
    params = jax.tree.map(
        lambda p: p.astype(jnp.float32) if p.dtype == jnp.bfloat16 else p, params
    )
    state = opt.init(params)

    grad_of = jax.jit(
        jax.grad(lambda p, b: loss_fn(p, cfg, b, ctx, settings)[0])
    )
    loss_of = jax.jit(lambda p, b: loss_fn(p, cfg, b, ctx, settings)[0])

    it = batches(cfg, PipelineConfig(4, 32, seed=0))
    anchor_batch = {k: jnp.asarray(v) for k, v in next(batches(cfg, PipelineConfig(16, 32, seed=99))).items()}

    losses = []
    for i in range(STEPS):
        if i % ANCHOR_EVERY == 0:
            z = grad_of(params, anchor_batch)  # large-batch anchor gradient
            state = optimizers.svrg_refresh(state, params, z)
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        g_cur = grad_of(params, batch)
        g_anc = grad_of(state.anchor_params, batch)
        updates, state = opt.update((g_cur, g_anc), state, params)
        params = optimizers.apply_updates(params, updates)
        losses.append(float(loss_of(params, batch)))
        if (i + 1) % 20 == 0:
            print(f"step {i+1:3d}  loss={losses[-1]:.4f}", flush=True)
    assert losses[-1] < losses[0], "SVRG-on-LM did not learn"
    print(f"\nloss {losses[0]:.4f} -> {losses[-1]:.4f} with variance-reduced steps")


if __name__ == "__main__":
    main()
