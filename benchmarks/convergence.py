"""Figures 6 & 7: objective gap vs modeled wall-clock and vs communicated
scalars, for FD-SVRG and all baselines on the four (scaled) data sets."""

from __future__ import annotations

import time

from benchmarks.common import (
    analytic_schedule,
    best_objective,
    run_method,
    write_csv,
)
from repro.data import datasets

METHODS = ["fdsvrg", "dsvrg", "synsvrg", "asysvrg", "pslite_sgd"]


def run(lam: float = 1e-4, outer_iters: int = 6, quick: bool = False):
    names = ["news20", "webspam"] if quick else ["news20", "url", "webspam", "kdd2010"]
    rows = []
    for name in names:
        spec_full = datasets.spec(name, scaled=False)
        data = datasets.load(name)
        q = spec_full.default_workers
        results = {}
        for m in METHODS:
            results[m] = run_method(m, data, q, lam, outer_iters=outer_iters)
        star = best_objective(list(results.values()))
        for m, res in results.items():
            sched = analytic_schedule(m, spec_full, q, outer_iters)
            for h in res.history:
                t, c = sched[h.outer]
                rows.append([
                    name, m, q, h.outer,
                    f"{h.objective - star:.6e}",
                    f"{t:.6f}",
                    c,
                ])
    path = write_csv(
        "fig6_fig7_convergence.csv",
        ["dataset", "method", "workers", "outer", "objective_gap",
         "modeled_time_s", "comm_scalars"],
        rows,
    )
    return path, rows


def main():
    path, rows = run()
    print(f"convergence: wrote {len(rows)} rows to {path}")


if __name__ == "__main__":
    main()
