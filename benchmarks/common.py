"""Shared benchmark harness utilities.

Methodology (see EXPERIMENTS.md): convergence *trajectories* (objective gap
per outer iteration) come from the container-scale data sets, which
preserve d/N and sparsity; wall-clock and communication per outer are
computed ANALYTICALLY from the paper's full-size Table-1 dimensions via
:func:`analytic_outer` — so the Figure-6/7 axes reflect the cluster the
paper ran on, not the shrunken simulation.  The compute rate models lazy
sparse updates (all methods get the standard O(nnz)-per-step trick) at the
effective sparse throughput of an E5-2620-class core.
"""

from __future__ import annotations

import csv
import os
import time
from collections import OrderedDict

import numpy as np

from repro.core import losses
from repro.core.fdsvrg import RunResult, SVRGConfig, run_fdsvrg, run_serial_svrg
from repro.core.partition import balanced
from repro.core import baselines
from repro.data import datasets
from repro.data.block_csr import BlockCSR
from repro.dist import COSTS, ClusterModel, CommReport

# Re-indexing a data set into BlockCSR is host-side numpy work; sweeps call
# run_method repeatedly with the same (data, q), so amortize it — but with
# per-sweep scope: a new data object evicts every entry built for other
# data sets (the unbounded id()-keyed dict used to pin whole data sets
# alive across sweeps), and an LRU bound caps the per-data entries too.
_BLOCK_CACHE: "OrderedDict[tuple[int, int], tuple[object, BlockCSR]]" = OrderedDict()
_BLOCK_CACHE_MAX = 4  # distinct q values cached for the current data set


def _block_data(data, q: int) -> BlockCSR:
    key = (id(data), q)
    hit = _BLOCK_CACHE.get(key)
    if hit is not None and hit[0] is data:
        _BLOCK_CACHE.move_to_end(key)
        return hit[1]
    # New data object: this sweep moved on — drop other data sets' entries
    # (and any stale entry whose id() was recycled).
    for k in [k for k, v in _BLOCK_CACHE.items() if v[0] is not data]:
        del _BLOCK_CACHE[k]
    block = BlockCSR.from_padded(data, balanced(data.dim, q))
    _BLOCK_CACHE[key] = (data, block)
    while len(_BLOCK_CACHE) > _BLOCK_CACHE_MAX:
        _BLOCK_CACHE.popitem(last=False)
    return block

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "benchmarks")

LOSS = losses.logistic
# sparse-gradient effective throughput (random-access bound), 10GbE, ~50us RTT
CLUSTER = ClusterModel(flops_per_s=2.0e8)

# FD-SVRG inner-loop mini-batch (paper §4.4.1; latency amortization)
FD_BATCH = 1024

# per-method step sizes tuned on the scaled sets (fixed, like the paper)
ETA = {
    "fdsvrg": 2.0, "serial": 2.0, "dsvrg": 1.0,
    "synsvrg": 2.0, "asysvrg": 0.5, "pslite_sgd": 0.3,
}
# scaled-trajectory minibatch for FD-SVRG (keeps big-set scans tractable)
U_TRAJ = 8
# cap on inner steps per outer for the scaled trajectories of the
# largest sets (url/kdd) — subsampled epochs, noted in EXPERIMENTS.md
MAX_INNER = 12_000


def lam_equiv(name: str, factor: float = 1.0) -> float:
    """Conditioning-preserving regularization: the paper's lambda=1e-4 at
    N=20k..19M gives N/kappa >= 8 (kappa = L/mu = 0.25/lambda); the scaled
    sets shrink N, so lambda scales up to keep N/kappa — and therefore the
    per-epoch SVRG contraction — in the paper's regime.  ``factor``
    reproduces Figure 8's lambda x10 / lambda/10 variants."""
    n = datasets.spec(name).num_instances
    return factor * 2.0 / n


def analytic_outer(method: str, spec, q: int, u: int = FD_BATCH,
                   cluster: ClusterModel = CLUSTER) -> tuple[float, int]:
    """(modeled seconds, scalars communicated) for ONE outer iteration of
    ``method`` at the full-size dataset ``spec``, q workers.

    Thin wrapper over the ONE cost model (:data:`repro.dist.COSTS`) — the
    same closed forms the measured-sim drivers charge, at the paper's M
    conventions (FD: M=N/u; DSVRG/Syn: M=N/q; Asy/PS: M=N).  ``u`` is the
    FD mini-batch (§4.4.1); the baselines run the paper's per-worker
    batch of 1, matching :func:`run_method`'s configs — which is what the
    drift-guard test pins meter-for-meter against this function.
    """
    return COSTS.outer_cost(
        method,
        n=spec.num_instances,
        d=spec.dim,
        nnz=spec.nnz_per_instance,
        q=q,
        u=u if method in ("fdsvrg", "serial") else 1,
        cluster=cluster,
    )


def analytic_schedule(method: str, spec, q: int, outers: int, u: int = FD_BATCH):
    """Cumulative (time, comm) after each outer iteration."""
    t1, c1 = analytic_outer(method, spec, q, u)
    return [((i + 1) * t1, (i + 1) * c1) for i in range(outers)]


def ensure_dir() -> str:
    d = os.path.abspath(RESULTS_DIR)
    os.makedirs(d, exist_ok=True)
    return d


def write_bench_json(name: str, payload: dict) -> str:
    """Serialize one suite's report as results/benchmarks/BENCH_<name>.json."""
    import json

    path = os.path.join(ensure_dir(), f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return path


def write_csv(name: str, header: list[str], rows: list[list]) -> str:
    path = os.path.join(ensure_dir(), name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


def run_method(
    method: str,
    data,
    q: int,
    lam: float,
    *,
    reg: losses.Regularizer | None = None,
    eta: float | None = None,
    outer_iters: int = 6,
    batch_size: int | None = None,
    seed: int = 0,
    use_kernels: bool = False,
) -> RunResult:
    """One named method on one data set with the paper's M conventions.

    ``reg`` overrides the default L2(lam) regularizer — pass
    ``losses.l1(...)`` / ``losses.elastic_net(...)`` for the proximal
    variants (every method runs the same prox update family, so Fig-6/7
    comparisons stay like-for-like).  ``lam`` stays the headline strength
    either way, so a mismatched override fails loudly instead of silently
    running at a different lambda than the caller reports.

    ``use_kernels=True`` routes the ``serial``/``fdsvrg`` hot paths
    through the fused Pallas kernels (interpret mode off-TPU) —
    bit-identical iterates and meters to the jnp path, so BENCH_*
    trajectories can exercise the kernels directly.  Note the fused
    kernels bake lambda in at compile time, so kernel-path sweeps pay one
    compile per lambda point (the jnp path traces lambda and compiles
    once per sweep)."""
    if reg is None:
        reg = losses.l2(lam)
    elif reg.lam != lam:
        raise ValueError(
            f"reg.lam={reg.lam!r} disagrees with lam={lam!r}; pass the same "
            "strength in both (lam is what sweeps record/report)"
        )
    n = data.num_instances
    eta = ETA[method] if eta is None else eta
    if method == "fdsvrg":
        u = U_TRAJ if batch_size is None else batch_size
        m = min(max(1, n // u), MAX_INNER)
        cfg = SVRGConfig(eta=eta, inner_steps=m,
                         outer_iters=outer_iters, batch_size=u, seed=seed)
        return run_fdsvrg(data, balanced(data.dim, q), LOSS, reg, cfg, CLUSTER,
                          use_kernels=use_kernels,
                          block_data=_block_data(data, q))
    if method == "serial":
        cfg = SVRGConfig(eta=eta, inner_steps=min(n, MAX_INNER),
                         outer_iters=outer_iters, seed=seed)
        return run_serial_svrg(data, LOSS, reg, cfg, use_kernels=use_kernels)
    if method == "dsvrg":
        cfg = SVRGConfig(eta=eta, inner_steps=min(max(1, n // q), MAX_INNER),
                         outer_iters=outer_iters, seed=seed)
        return baselines.run_dsvrg(data, q, LOSS, reg, cfg, CLUSTER)
    if method == "synsvrg":
        cfg = SVRGConfig(eta=eta, inner_steps=min(max(1, n // q), MAX_INNER),
                         outer_iters=outer_iters, seed=seed)
        return baselines.run_syn_svrg(data, q, LOSS, reg, cfg, CLUSTER)
    if method == "asysvrg":
        cfg = SVRGConfig(eta=eta, inner_steps=min(n, MAX_INNER),
                         outer_iters=outer_iters, seed=seed)
        return baselines.run_asy_svrg(data, q, LOSS, reg, cfg, CLUSTER)
    if method == "pslite_sgd":
        cfg = SVRGConfig(eta=eta, inner_steps=min(n, MAX_INNER),
                         outer_iters=outer_iters, seed=seed)
        return baselines.run_pslite_sgd(data, q, LOSS, reg, cfg, CLUSTER)
    raise ValueError(method)


def comm_report(method: str, result: RunResult, q: int) -> CommReport:
    """Bytes-on-the-wire summary of a measured run.  Every method's backend
    meters with the same machinery and closed forms (one meter per run),
    so reports are directly comparable across methods."""
    return CommReport.from_result(method, q, result, cluster=CLUSTER)


def time_to_gap(result: RunResult, target_obj: float, schedule, tol: float = 1e-4):
    """(modeled_time, comm_scalars, outer) at the first outer whose gap <= tol,
    with time/comm read from the full-size analytic ``schedule``."""
    for h in result.history:
        if h.objective - target_obj <= tol:
            t, c = schedule[h.outer]
            return t, c, h.outer
    return None, None, None


def best_objective(results: list[RunResult]) -> float:
    return min(r.final_objective() for r in results)
