"""Shared benchmark harness utilities.

Methodology (see EXPERIMENTS.md): convergence *trajectories* (objective gap
per outer iteration) come from the container-scale data sets, which
preserve d/N and sparsity; wall-clock and communication per outer are
computed ANALYTICALLY from the paper's full-size Table-1 dimensions via
:func:`analytic_outer` — so the Figure-6/7 axes reflect the cluster the
paper ran on, not the shrunken simulation.  The compute rate models lazy
sparse updates (all methods get the standard O(nnz)-per-step trick) at the
effective sparse throughput of an E5-2620-class core.

Method dispatch, per-method paper defaults, and the BlockCSR cache used
to live here; they are now owned by :mod:`repro.api` (the solver
registry and the shared bounded :data:`repro.api.BLOCK_CACHE`).  What
remains here is benchmark *reporting*: the analytic full-size schedules,
CSV/JSON writers, and a deprecated :func:`run_method` shim kept so the
sweep modules (and any external notebook) don't all churn at once.
"""

from __future__ import annotations

import csv
import os

from repro.api import ExperimentSpec, solve
from repro.core import losses
from repro.core.driver import RunResult
from repro.data import datasets
from repro.dist import COSTS, ClusterModel, CommReport

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "benchmarks")

LOSS = losses.logistic
# sparse-gradient effective throughput (random-access bound), 10GbE, ~50us RTT
CLUSTER = ClusterModel(flops_per_s=2.0e8)

# FD-SVRG inner-loop mini-batch (paper §4.4.1; latency amortization) —
# the *analytic* full-size operating point of the Figure-6/7 schedules
# (the measured trajectories run the registry's scaled paper defaults).
FD_BATCH = 1024


def lam_equiv(name: str, factor: float = 1.0) -> float:
    """Conditioning-preserving regularization: the paper's lambda=1e-4 at
    N=20k..19M gives N/kappa >= 8 (kappa = L/mu = 0.25/lambda); the scaled
    sets shrink N, so lambda scales up to keep N/kappa — and therefore the
    per-epoch SVRG contraction — in the paper's regime.  ``factor``
    reproduces Figure 8's lambda x10 / lambda/10 variants."""
    n = datasets.spec(name).num_instances
    return factor * 2.0 / n


def analytic_outer(method: str, spec, q: int, u: int = FD_BATCH,
                   cluster: ClusterModel = CLUSTER) -> tuple[float, int]:
    """(modeled seconds, scalars communicated) for ONE outer iteration of
    ``method`` at the full-size dataset ``spec``, q workers.

    Thin wrapper over the ONE cost model (:data:`repro.dist.COSTS`) — the
    same closed forms the measured-sim drivers charge, at the paper's M
    conventions (FD: M=N/u; DSVRG/Syn: M=N/q; Asy/PS: M=N).  ``u`` is the
    FD mini-batch (§4.4.1); the baselines run the paper's per-worker
    batch of 1, matching :func:`run_method`'s configs — which is what the
    drift-guard test pins meter-for-meter against this function.
    """
    return COSTS.outer_cost(
        method,
        n=spec.num_instances,
        d=spec.dim,
        nnz=spec.nnz_per_instance,
        q=q,
        # The FD mini-batch applies to the sampled-step methods that take
        # it (fd_bcd steps are whole blocks, the baselines run u=1).
        u=u if method in ("fdsvrg", "serial", "fd_saga") else 1,
        cluster=cluster,
    )


def analytic_schedule(method: str, spec, q: int, outers: int, u: int = FD_BATCH,
                      cluster: ClusterModel = CLUSTER):
    """Cumulative (time, comm) after each outer iteration, including any
    one-time setup phase (fd_saga's gradient-table init; zero for every
    other method)."""
    t1, c1 = analytic_outer(method, spec, q, u, cluster)
    t0, c0 = COSTS.init_cost(
        method,
        n=spec.num_instances,
        nnz=spec.nnz_per_instance,
        q=q,
        cluster=cluster,
    )
    return [(t0 + (i + 1) * t1, c0 + (i + 1) * c1) for i in range(outers)]


def measure_us(fn, repeats: int = 7) -> dict:
    """Median-over-repeats wall time of ``fn()`` in microseconds.

    Epoch timings on a shared box show ~50% run-to-run swings (CHANGES
    PR 6), so a single number is not honest: BENCH payloads report the
    **median** (robust central estimate) together with a ``spread``
    field — (max - min) / median over the timed repeats — so a reader
    can tell a stable 2x from a noisy one.  ``fn`` is called once,
    untimed, to absorb compilation before the timed repeats; callers are
    responsible for blocking on async results inside ``fn`` (e.g.
    ``jax.block_until_ready``).
    """
    import statistics
    import time as _time

    fn()  # warm / compile
    samples = []
    for _ in range(max(1, repeats)):
        t0 = _time.perf_counter()
        fn()
        samples.append((_time.perf_counter() - t0) * 1e6)
    med = statistics.median(samples)
    return {
        "us": med,
        "spread": (max(samples) - min(samples)) / med if med > 0 else 0.0,
        "repeats": len(samples),
    }


def ensure_dir() -> str:
    d = os.path.abspath(RESULTS_DIR)
    os.makedirs(d, exist_ok=True)
    return d


def write_bench_json(name: str, payload: dict) -> str:
    """Serialize one suite's report as results/benchmarks/BENCH_<name>.json."""
    import json

    path = os.path.join(ensure_dir(), f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return path


def write_csv(name: str, header: list[str], rows: list[list]) -> str:
    path = os.path.join(ensure_dir(), name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


def run_method(
    method: str,
    data,
    q: int,
    lam: float | None = None,
    *,
    reg: losses.Regularizer | None = None,
    eta: float | None = None,
    outer_iters: int = 6,
    batch_size: int | None = None,
    seed: int = 0,
    use_kernels: bool = False,
) -> RunResult:
    """DEPRECATED shim over :func:`repro.api.solve` — behavior-identical
    to the pre-registry dispatcher at the benchmark defaults (asserted by
    the parity tests in tests/test_api.py).  New code should build an
    :class:`repro.api.ExperimentSpec` and call ``solve`` directly.

    The old dual-argument footgun is gone: the spec takes ONE
    regularizer.  ``reg=None`` means L2 at strength ``lam``; when ``reg``
    is given it IS the regularizer and the headline lambda is derived
    from it (``reg.lam``) — there is no second strength to mismatch and
    no mismatch error to hit.

    Per-method defaults (step size, trajectory mini-batch, the ``m = N/u``
    inner rule and its cap) resolve through the registry's ``"paper"``
    sentinels.  ``batch_size`` is honored for the FD family; for the
    legacy baseline methods it is ignored exactly as the pre-registry
    dispatcher ignored it (bit parity) — pass a spec to ``solve`` if you
    want a baseline at a non-default batch.
    """
    import warnings

    warnings.warn(
        "benchmarks.common.run_method is a deprecated shim; build an "
        "ExperimentSpec and call repro.api.solve instead",
        DeprecationWarning, stacklevel=2,
    )
    if reg is None:
        if lam is None:
            raise TypeError("run_method needs lam (or an explicit reg)")
        reg = losses.l2(lam)
    fd_family = ("fdsvrg", "fdsvrg_sim", "fdsvrg_sharded")
    spec = ExperimentSpec(
        method=method,
        data=data,
        q=q,
        reg=reg,
        eta="paper" if eta is None else eta,
        batch_size=(
            batch_size
            if batch_size is not None and method in fd_family
            else "paper"
        ),
        outer_iters=outer_iters,
        seed=seed,
        use_kernels=use_kernels,
        cluster=CLUSTER,
    )
    return solve(spec)


def comm_report(method: str, result: RunResult, q: int) -> CommReport:
    """Bytes-on-the-wire summary of a measured run.  Every method's backend
    meters with the same machinery and closed forms (one meter per run),
    so reports are directly comparable across methods."""
    return CommReport.from_result(method, q, result, cluster=CLUSTER)


def time_to_gap(result: RunResult, target_obj: float, schedule, tol: float = 1e-4):
    """(modeled_time, comm_scalars, outer) at the first outer whose gap <= tol,
    with time/comm read from the full-size analytic ``schedule``."""
    for h in result.history:
        if h.objective - target_obj <= tol:
            t, c = schedule[h.outer]
            return t, c, h.outer
    return None, None, None


def best_objective(results: list[RunResult]) -> float:
    return min(r.final_objective() for r in results)
