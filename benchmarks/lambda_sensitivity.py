"""Figure 8: webspam convergence for lambda in {1e-3, 1e-5} — FD-SVRG must
stay fastest under both regularization strengths."""

from __future__ import annotations

from benchmarks.common import (
    analytic_schedule,
    best_objective,
    run_method,
    write_csv,
)
from repro.data import datasets


def run(outer_iters: int = 6):
    data = datasets.load("webspam")
    spec_full = datasets.spec("webspam", scaled=False)
    q = spec_full.default_workers
    rows = []
    for lam in (1e-3, 1e-5):
        res = {
            m: run_method(m, data, q, lam, outer_iters=outer_iters)
            for m in ("fdsvrg", "dsvrg", "synsvrg", "asysvrg")
        }
        star = best_objective(list(res.values()))
        for m, r in res.items():
            sched = analytic_schedule(m, spec_full, q, outer_iters)
            for h in r.history:
                t, c = sched[h.outer]
                rows.append([
                    f"{lam:g}", m, h.outer,
                    f"{h.objective - star:.6e}",
                    f"{t:.6f}",
                    c,
                ])
    path = write_csv(
        "fig8_lambda.csv",
        ["lambda", "method", "outer", "objective_gap", "modeled_time_s",
         "comm_scalars"],
        rows,
    )
    return path, rows


def main():
    path, rows = run()
    print(f"lambda_sensitivity: wrote {len(rows)} rows to {path}")


if __name__ == "__main__":
    main()
