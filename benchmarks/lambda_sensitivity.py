"""Figure 8 + the FD-Prox-SVRG sparsity sweep.

* :func:`run` — Figure 8: webspam convergence for lambda in {1e-3, 1e-5};
  FD-SVRG must stay fastest under both regularization strengths.
* :func:`run_prox` — sparsity-vs-lambda for the proximal family (paper
  eq. 3: L1 / elastic-net decompose over feature blocks, so the prox step
  is block-local and communication-free): for each lambda, run
  FD-Prox-SVRG and record nnz(w)/d and the objective, plus the L2 run at
  the same lambda to certify comm-scalar parity.  Emits
  ``results/benchmarks/BENCH_prox.json``.

Standalone entry point with a ``--quick`` smoke mode for CI:

    PYTHONPATH=src python -m benchmarks.lambda_sensitivity [--quick]

``--quick`` runs only the prox sweep on the scaled news20 preset.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import (
    analytic_schedule,
    best_objective,
    lam_equiv,
    run_method,
    write_bench_json,
    write_csv,
)
from repro.api import method_info
from repro.core import losses
from repro.data import datasets


def run(outer_iters: int = 6):
    data = datasets.load("webspam")
    spec_full = datasets.spec("webspam", scaled=False)
    q = spec_full.default_workers
    rows = []
    for lam in (1e-3, 1e-5):
        res = {
            m: run_method(m, data, q, lam, outer_iters=outer_iters)
            for m in ("fdsvrg", "dsvrg", "synsvrg", "asysvrg")
        }
        star = best_objective(list(res.values()))
        for m, r in res.items():
            sched = analytic_schedule(m, spec_full, q, outer_iters)
            for h in r.history:
                t, c = sched[h.outer]
                rows.append([
                    f"{lam:g}", m, h.outer,
                    f"{h.objective - star:.6e}",
                    f"{t:.6f}",
                    c,
                ])
    path = write_csv(
        "fig8_lambda.csv",
        ["lambda", "method", "outer", "objective_gap", "modeled_time_s",
         "comm_scalars"],
        rows,
    )
    return path, rows


def run_prox(quick: bool = False):
    """Sparsity-vs-lambda sweep; returns (csv_path, rows, payload)."""
    name = "news20" if quick else "webspam"
    data = datasets.load(name)
    q = datasets.spec(name).default_workers
    outer_iters = 3 if quick else 6
    base = lam_equiv(name)
    factors = (0.05, 0.5, 5.0) if quick else (0.01, 0.05, 0.5, 5.0, 50.0)

    rows: list[list] = []
    report: list[dict] = []
    parity = True
    # One L2 control for the whole sweep: the meter is charged from shapes
    # (n, d, q, M, outers) only, so its totals are independent of reg and
    # lambda — a single run certifies comm parity for every sweep point.
    l2 = run_method("fdsvrg", data, q, base, outer_iters=outer_iters)
    for factor in factors:
        lam = base * factor
        runs = {
            "l1": run_method(
                "fdsvrg", data, q, lam,
                reg=losses.l1(lam), outer_iters=outer_iters,
            ),
            "elastic_net": run_method(
                "fdsvrg", data, q, lam,
                reg=losses.elastic_net(lam, base), outer_iters=outer_iters,
            ),
        }
        for reg_name, res in runs.items():
            w = np.asarray(res.w)
            nnz = int(np.count_nonzero(w))
            parity &= res.meter.total_scalars == l2.meter.total_scalars
            entry = {
                "reg": reg_name,
                "lambda": lam,
                "lambda2": base if reg_name == "elastic_net" else 0.0,
                "objective": res.final_objective(),
                "grad_mapping_norm": res.history[-1].grad_norm,
                "nnz": nnz,
                "nnz_frac": nnz / data.dim,
                "comm_scalars": res.meter.total_scalars,
                "comm_scalars_l2": l2.meter.total_scalars,
            }
            report.append(entry)
            rows.append([
                reg_name, f"{lam:g}", f"{entry['objective']:.6e}",
                nnz, f"{entry['nnz_frac']:.4f}", entry["comm_scalars"],
            ])
    payload = {
        "quick": quick,
        "dataset": name,
        "dim": data.dim,
        "workers": q,
        "eta": method_info("fdsvrg").paper_eta,
        "outer_iters": outer_iters,
        "comm_parity_with_l2": parity,
        "sweep": report,
    }
    path = write_csv(
        "prox_sparsity.csv",
        ["reg", "lambda", "objective", "nnz", "nnz_frac", "comm_scalars"],
        rows,
    )
    return path, rows, payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="prox sweep only, small preset (CI smoke mode)")
    args = ap.parse_args()
    if not args.quick:
        path, rows = run()
        print(f"lambda_sensitivity: wrote {len(rows)} rows to {path}")
    t0 = time.perf_counter()
    path, rows, payload = run_prox(quick=args.quick)
    payload["wall_us"] = (time.perf_counter() - t0) * 1e6
    write_bench_json("prox", payload)
    print(f"prox_sparsity: wrote {len(rows)} rows to {path} "
          f"(comm parity with L2: {payload['comm_parity_with_l2']})")
    for r in rows:
        print("  ", ",".join(map(str, r)))


if __name__ == "__main__":
    main()
