"""Chaos benchmark: FD-SVRG under seeded fault plans.

Runs the fdsvrg driver (and the object-level worker simulation for the
corruption plan, whose fault needs an *executing* collective) under a
set of sampled :class:`repro.dist.FaultPlan` s and reports, per plan:

* **convergence to the fault-free optimum**: the faulty run's final
  objective gap to the clean run's final objective, normalized by the
  clean run's total objective decrease — ``converged`` means the faulty
  run recovered at least 90% of the clean run's progress;
* **honest retry overhead**: the exact extra scalars metered under the
  ``retry`` (and, for recovered plans, ``abort``) kinds, and the check
  that ``total == fault-free schedule + retry + abort`` held;
* modeled-time overhead (timeouts, backoff, straggler delays, abort
  recompute are all charged to the shared clock).

The fault/accounting numbers are exactly reproducible from the plan
seeds; the one wall timing (a fault-free driver run) follows the shared
median+spread convention of :func:`benchmarks.common.measure_us`.

Standalone entry point with a ``--quick`` smoke mode for CI:

    PYTHONPATH=src python -m benchmarks.chaos_bench [--quick]

writes results/benchmarks/chaos.csv and BENCH_chaos.json.
"""

from __future__ import annotations

import argparse
import time

from benchmarks.common import measure_us, write_bench_json, write_csv
from repro.core import losses
from repro.core.driver import RecoveryPolicy
from repro.core.fdsvrg import (
    SVRGConfig,
    fdsvrg_worker_simulation,
    run_fdsvrg,
)
from repro.core.partition import balanced
from repro.data.synthetic import make_sparse_classification
from repro.dist import FaultPlan, FaultyBackend, RetryPolicy, SimBackend

def _plans(cfg) -> list[tuple[str, dict]]:
    """The sampled fault plans (>= 3 per the acceptance criteria).  Drop
    and straggler plans retransmit deterministic partials, so they must
    land bitwise on the clean optimum; crash and corruption alter the
    trajectory and recover through epoch-abort-to-snapshot.  The
    corruption probability is per-collective, so it scales with the run
    shape to an expected ~1.5 poisoned payloads per run — enough to
    force recovery without drowning every epoch."""
    corrupt_p = 1.5 / (cfg.outer_iters * cfg.inner_steps)
    return [
        ("drop_light", dict(seed=11, drop_prob=0.05)),
        ("drop_heavy", dict(seed=13, drop_prob=0.25)),
        ("drop_straggle", dict(seed=17, drop_prob=0.10, straggler_prob=0.20,
                               straggler_delay_s=2e-3)),
        ("crash_mid", dict(seed=19, crash_at_outer=(2,))),
        ("corrupt", dict(seed=23, corrupt_prob=corrupt_p)),
    ]

RETRY = RetryPolicy(max_retries=10, timeout_s=0.05)
RECOVERY = RecoveryPolicy(max_epoch_retries=4)
#: Corruption is transient (the retried epoch draws fresh randomness and
#: a fresh fault stream), so the right recovery re-runs at FULL step
#: size: backing eta off — the medicine for a genuinely divergent step
#: size — would only slow the healthy retries down.
RECOVERY_TRANSIENT = RecoveryPolicy(max_epoch_retries=4, eta_backoff=1.0)

#: Recovered fraction of the clean run's objective decrease required to
#: call a faulty run converged.
CONVERGENCE_FRACTION = 0.9


def _problem(quick: bool):
    d, n, nnz, m, outers = (
        (512, 64, 8, 16, 4) if quick else (4096, 512, 16, 64, 6)
    )
    data = make_sparse_classification(
        dim=d, num_instances=n, nnz_per_instance=nnz, seed=4
    )
    cfg = SVRGConfig(eta=0.5, inner_steps=m, outer_iters=outers, seed=9)
    return data, balanced(d, 4), losses.logistic, losses.l2(1e-3), cfg


def _run_plan(name, plan_kwargs, data, part, loss, reg, cfg, clean):
    plan = FaultPlan(**plan_kwargs)
    q = part.num_blocks
    backend = FaultyBackend(SimBackend(q), plan, RETRY)
    # The jitted fdsvrg driver meters without executing collectives, so a
    # corruption fault (which poisons an executed payload) needs the
    # object-level worker simulation; every other plan runs the fast
    # driver.  Both sit on the same outer-loop harness and meter.
    runner = fdsvrg_worker_simulation if plan.corrupt_prob > 0 else run_fdsvrg
    recovery = RECOVERY_TRANSIENT if plan.corrupt_prob > 0 else RECOVERY
    kwargs = dict(backend=backend, recovery=recovery)
    if runner is run_fdsvrg:
        res = run_fdsvrg(data, part, loss, reg, cfg, **kwargs)
    else:
        res = fdsvrg_worker_simulation(data, part, loss, reg, cfg, **kwargs)

    f_init = clean.history[0].objective
    f_star = clean.final_objective()
    decrease = max(f_init - f_star, 1e-12)
    gap = max(0.0, res.final_objective() - f_star)
    m = res.meter
    retry = int(m.by_kind.get("retry", 0))
    abort = int(m.by_kind.get("abort", 0))
    schedule = clean.meter.total_scalars
    # Aborted attempts: each abort charges one 2*q*N gradient re-broadcast.
    # In the object-level sim a corrupted epoch runs to completion before
    # the divergence guard fires, so the aborted attempt has *already*
    # metered one outer's worth of collectives — that traffic happened and
    # the honest total carries it.  The jitted driver's crash fires before
    # any epoch metering, so its aborted attempts replay nothing.
    n_aborts = abort // (2 * q * data.num_instances) if abort else 0
    per_outer = schedule // cfg.outer_iters
    replay = n_aborts * per_outer if plan.corrupt_prob > 0 else 0
    return {
        "plan": name,
        "fault_plan": {k: list(v) if isinstance(v, tuple) else v
                       for k, v in plan_kwargs.items()},
        "driver": "fdsvrg_sim" if runner is fdsvrg_worker_simulation
        else "fdsvrg",
        "final_objective": res.final_objective(),
        "fault_free_objective": f_star,
        "objective_gap": gap,
        "gap_over_decrease": gap / decrease,
        "converged": bool(gap <= (1.0 - CONVERGENCE_FRACTION) * decrease),
        "schedule_scalars": schedule,
        "retry_scalars": retry,
        "abort_scalars": abort,
        "replay_scalars": replay,
        "epoch_aborts": n_aborts,
        "retry_overhead": retry / schedule,
        "accounting_exact": bool(
            m.total_scalars == schedule + retry + abort + replay
        ),
        "modeled_time_s": res.history[-1].modeled_time_s,
        "modeled_overhead_s": (
            res.history[-1].modeled_time_s
            - clean.history[-1].modeled_time_s
        ),
    }


def run(quick: bool = False):
    data, part, loss, reg, cfg = _problem(quick)
    clean = run_fdsvrg(data, part, loss, reg, cfg)
    # The fault/accounting numbers above are seeded and exact; the one
    # *timing* this suite reports (wall time of a fault-free driver run)
    # follows the shared median+spread convention.
    clean_timing = measure_us(
        lambda: run_fdsvrg(data, part, loss, reg, cfg), repeats=3
    )
    results = [
        _run_plan(name, kw, data, part, loss, reg, cfg, clean)
        for name, kw in _plans(cfg)
    ]
    rows = [
        [r["plan"], r["driver"], f"{r['objective_gap']:.3e}",
         str(r["converged"]), str(r["retry_scalars"]),
         str(r["abort_scalars"]), f"{r['retry_overhead']:.3f}",
         str(r["accounting_exact"])]
        for r in results
    ]
    path = write_csv(
        "chaos.csv",
        ["plan", "driver", "objective_gap", "converged", "retry_scalars",
         "abort_scalars", "retry_overhead", "accounting_exact"],
        rows,
    )
    summary = {
        "clean_final_objective": clean.final_objective(),
        "clean_total_scalars": clean.meter.total_scalars,
        "clean_run_us": clean_timing["us"],
        "clean_run_spread": clean_timing["spread"],
        "timing_repeats": clean_timing["repeats"],
        "plans": results,
        "all_converged": all(r["converged"] for r in results),
        "all_accounting_exact": all(r["accounting_exact"] for r in results),
    }
    return path, rows, summary


def report_payload(summary: dict, wall_us: float, quick: bool) -> dict:
    """The BENCH_chaos.json schema — one builder for the standalone and
    the aggregate (benchmarks.run) entry points.  wall_us is the suite's
    wall time (single timing; the per-plan numbers are metered/modeled,
    hence exactly reproducible — no repeats needed)."""
    return {
        "wall_us": wall_us,
        "quick": quick,
        "timing": {"estimator": "median", "spread": "(max-min)/median"},
        "clean_run_us": summary["clean_run_us"],
        "spread": summary["clean_run_spread"],
        "num_plans": len(summary["plans"]),
        "all_converged": summary["all_converged"],
        "all_accounting_exact": summary["all_accounting_exact"],
        "max_retry_overhead": max(
            r["retry_overhead"] for r in summary["plans"]
        ),
        "convergence_fraction": CONVERGENCE_FRACTION,
        "detail": summary,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shapes (CI smoke mode)")
    args = ap.parse_args()
    t0 = time.perf_counter()
    path, rows, summary = run(quick=args.quick)
    payload = report_payload(
        summary, (time.perf_counter() - t0) * 1e6, args.quick)
    write_bench_json("chaos", payload)
    print(f"chaos: wrote {len(rows)} rows to {path}")
    for r in rows:
        print("  ", ",".join(map(str, r)))
    print(
        f"  {payload['num_plans']} fault plans: "
        f"converged={payload['all_converged']}, "
        f"accounting exact={payload['all_accounting_exact']}, "
        f"max retry overhead "
        f"{payload['max_retry_overhead'] * 100:.1f}% of schedule"
    )
    if not (payload["all_converged"] and payload["all_accounting_exact"]):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
