"""Pallas kernel micro-benchmarks (interpret mode on CPU measures the
*reference semantics*; us_per_call here tracks wrapper/oracle overhead and
regression, not TPU latency — TPU numbers come from the roofline model)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import write_csv
from repro.kernels import ops, ref


def _timeit(fn, *args, iters=5) -> float:
    fn(*args)  # warm / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run():
    rng = np.random.default_rng(0)
    rows = []

    d, n = 8192, 2048
    w = jnp.asarray(rng.normal(size=d).astype(np.float32))
    dmat = jnp.asarray(rng.normal(size=(d, n)).astype(np.float32))
    rows.append([
        "fd_matvec_ref_8192x2048",
        f"{_timeit(jax.jit(lambda a, b: ref.fd_matvec_ref(a[:, None], b)), w, dmat):.1f}",
        "jnp oracle",
    ])

    s = jnp.asarray(rng.normal(size=65536).astype(np.float32))
    y = jnp.sign(s) + (jnp.sign(s) == 0)
    rows.append([
        "logistic_grad_ref_65536",
        f"{_timeit(jax.jit(ref.logistic_grad_ref), s, y):.1f}",
        "jnp oracle",
    ])

    wv = jnp.asarray(rng.normal(size=262144).astype(np.float32))
    g = jnp.asarray(rng.normal(size=262144).astype(np.float32))
    z = jnp.asarray(rng.normal(size=262144).astype(np.float32))
    rows.append([
        "svrg_update_ref_262144",
        f"{_timeit(jax.jit(lambda a, b, c: ref.svrg_update_ref(a, b, c, eta=0.1, lam=1e-4)), wv, g, z):.1f}",
        "jnp oracle",
    ])

    q = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(4096, 2, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(4096, 2, 64)).astype(np.float32))
    rows.append([
        "flash_decode_ref_4096",
        f"{_timeit(jax.jit(lambda a, b, c: ref.flash_decode_ref(a, b, c, length=4000)), q, k, v):.1f}",
        "jnp oracle",
    ])
    # interpret-mode kernel sanity timing (NOT a TPU number)
    rows.append([
        "flash_decode_pallas_interp_4096",
        f"{_timeit(lambda a, b, c: ops.decode_attention(a, b, c, length=4000, interpret=True), q, k, v):.1f}",
        "pallas interpret=True",
    ])

    path = write_csv("kernels_micro.csv", ["name", "us_per_call", "derived"], rows)
    return path, rows


def main():
    path, rows = run()
    print(f"kernels: wrote {len(rows)} rows to {path}")
    for r in rows:
        print("  ", ",".join(map(str, r)))


if __name__ == "__main__":
    main()
