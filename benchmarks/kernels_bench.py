"""Pallas kernel micro-benchmarks (interpret mode on CPU measures the
*reference semantics*; us_per_call here tracks wrapper/oracle overhead and
regression, not TPU latency — TPU numbers come from the roofline model).

The ``blockcsr`` section is the PR-2 hot-path comparison: the historical
masked global-CSR per-worker computation (O(nnz_max) compare/where work
per row, re-implemented inline here as the baseline since the library no
longer carries it) against the block-local BlockCSR layout (O(nnz_max/q)
rows, no masks) — as plain jnp and through the fused Pallas kernels.
Standalone entry point with a ``--quick`` smoke mode for CI:

    PYTHONPATH=src python -m benchmarks.kernels_bench [--quick]

writes results/benchmarks/kernels_micro.csv and BENCH_kernels.json.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import write_bench_json, write_csv
from repro.core.partition import balanced
from repro.data.block_csr import BlockCSR, local_margins, local_scatter
from repro.data.synthetic import make_sparse_classification
from repro.kernels import ops, ref


def _timeit(fn, *args, iters=5) -> float:
    fn(*args)  # warm / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


# ---------------------------------------------------------------------------
# masked global-CSR baseline (the pattern BlockCSR replaced)
# ---------------------------------------------------------------------------


def _masked_margins(indices, values, w_block, lo):
    hi = lo + w_block.shape[0]
    in_block = (indices >= lo) & (indices < hi)
    local = jnp.where(in_block, indices - lo, 0)
    return jnp.sum(jnp.where(in_block, w_block[local], 0.0) * values, axis=-1)


def _masked_update_3pass(indices, values, coef, w_block, z_block, lo, eta, lam):
    hi = lo + w_block.shape[0]
    in_block = (indices >= lo) & (indices < hi)
    local = jnp.where(in_block, indices - lo, 0)
    contrib = jnp.where(in_block, values, 0.0) * coef[..., None]
    g = (  # pass 1: densify the sparse gradient
        jnp.zeros_like(w_block).at[local.reshape(-1)].add(contrib.reshape(-1))
    )
    g = g + z_block + lam * w_block  # pass 2: combine
    return w_block - eta * g  # pass 3: axpy


def _blockcsr_update_fused_jnp(indices, values, coef, w_block, z_block, eta, lam):
    g = local_scatter(indices, values, coef, w_block.shape[0])
    return w_block - eta * (g + z_block + lam * w_block)


def _blockcsr_prox_update_jnp(indices, values, coef, w_block, z_block, eta,
                              lam1, lam2):
    """Unfused reference for the prox path: scatter, combine, axpy, then
    the two prox sweeps (threshold + shrink) — five passes over d/q."""
    g = local_scatter(indices, values, coef, w_block.shape[0])
    v = w_block - eta * (g + z_block)
    v = jnp.sign(v) * jnp.maximum(jnp.abs(v) - eta * lam1, 0.0)
    return v / (1.0 + eta * lam2)


def bench_blockcsr(quick: bool) -> tuple[list[list], dict]:
    """Per-worker hot-path timings: masked global rows vs block-local rows.

    Sizes mimic a text shard: q workers over [N, nnz_max] global rows;
    the BlockCSR budget lands near nnz_max/q (Zipf ids are scattered
    uniformly by the generator).  Timed per single worker, which is the
    quantity that sets cluster wall-clock.
    """
    if quick:
        d, n, nnz, q, u = 8192, 512, 64, 8, 64
    else:
        d, n, nnz, q, u = 65536, 2048, 128, 8, 256
    iters = 50  # rows here are 30-2000us; average out scheduler noise
    rng = np.random.default_rng(0)
    data = make_sparse_classification(
        dim=d, num_instances=n, nnz_per_instance=nnz, seed=0
    )
    part = balanced(d, q)
    block_data = BlockCSR.from_padded(data, part)
    lo, hi = part.block(0)
    block_dim = hi - lo
    w_blk = jnp.asarray(rng.normal(size=block_dim).astype(np.float32))
    z_blk = jnp.asarray(rng.normal(size=block_dim).astype(np.float32))
    bidx, bval = block_data.block(0)
    ids = jnp.asarray(rng.integers(0, n, size=u).astype(np.int32))
    coef = jnp.asarray(rng.normal(size=u).astype(np.float32))
    eta, lam = 0.1, 1e-4
    gidx_u, gval_u = data.indices[ids], data.values[ids]
    bidx_u, bval_u = bidx[ids], bval[ids]

    rows: list[list] = []
    summary: dict = {
        "shape": {"d": d, "N": n, "nnz_max": nnz, "q": q, "u": u,
                  "blockcsr_budget": max(block_data.nnz_budgets)},
    }

    # --- full-data margins (the outer full-gradient phase) ---
    t_masked = _timeit(
        jax.jit(lambda i, v, w: _masked_margins(i, v, w, lo)),
        data.indices, data.values, w_blk, iters=iters,
    )
    t_local = _timeit(jax.jit(local_margins), bidx, bval, w_blk, iters=iters)
    t_kernel = _timeit(
        lambda i, v, w: ops.sparse_margins(i, v, w, interpret=True),
        bidx, bval, w_blk, iters=iters,
    )
    rows += [
        [f"margin_fullgrad_masked_global_q{q}", f"{t_masked:.1f}",
         f"[N={n},nnz={nnz}]"],
        [f"margin_fullgrad_blockcsr_jnp_q{q}", f"{t_local:.1f}",
         f"[N={n},nnz={max(block_data.nnz_budgets)}]"],
        [f"margin_fullgrad_blockcsr_kernel_q{q}", f"{t_kernel:.1f}",
         "pallas interpret=True"],
    ]
    summary["margin_fullgrad"] = {
        "masked_us": t_masked,
        "blockcsr_us": t_local,
        "blockcsr_kernel_interpret_us": t_kernel,
        "hot_path_speedup_vs_masked": t_masked / t_local,
        "kernel_interpret_overhead_x": t_kernel / t_local,
    }

    # --- sampled-row margins (the inner loop) ---
    t_masked = _timeit(
        jax.jit(lambda i, v, w: _masked_margins(i, v, w, lo)),
        gidx_u, gval_u, w_blk, iters=iters,
    )
    t_local = _timeit(jax.jit(local_margins), bidx_u, bval_u, w_blk, iters=iters)
    t_kernel = _timeit(
        lambda i, v, w: ops.sparse_margins(i, v, w, interpret=True),
        bidx_u, bval_u, w_blk, iters=iters,
    )
    rows += [
        [f"margin_inner_masked_global_q{q}", f"{t_masked:.1f}", f"[u={u}]"],
        [f"margin_inner_blockcsr_jnp_q{q}", f"{t_local:.1f}", f"[u={u}]"],
        [f"margin_inner_blockcsr_kernel_q{q}", f"{t_kernel:.1f}",
         "pallas interpret=True"],
    ]
    summary["margin_inner"] = {
        "masked_us": t_masked,
        "blockcsr_us": t_local,
        "blockcsr_kernel_interpret_us": t_kernel,
        "hot_path_speedup_vs_masked": t_masked / t_local,
        "kernel_interpret_overhead_x": t_kernel / t_local,
    }

    # --- scatter-grad + VR update (three sweeps -> one fused pass) ---
    t_masked = _timeit(
        jax.jit(lambda i, v, c, w, z: _masked_update_3pass(
            i, v, c, w, z, lo, eta, lam)),
        gidx_u, gval_u, coef, w_blk, z_blk, iters=iters,
    )
    t_local = _timeit(
        jax.jit(lambda i, v, c, w, z: _blockcsr_update_fused_jnp(
            i, v, c, w, z, eta, lam)),
        bidx_u, bval_u, coef, w_blk, z_blk, iters=iters,
    )
    t_kernel = _timeit(
        lambda i, v, c, w, z: ops.fused_block_update(
            w, i, v, c, z, jnp.float32(eta), lam=lam, interpret=True),
        bidx_u, bval_u, coef, w_blk, z_blk, iters=iters,
    )
    rows += [
        [f"scatter_update_masked_3pass_q{q}", f"{t_masked:.1f}",
         f"[u={u},d/q={block_dim}]"],
        [f"scatter_update_blockcsr_jnp_q{q}", f"{t_local:.1f}",
         f"[u={u},d/q={block_dim}]"],
        [f"scatter_update_blockcsr_kernel_q{q}", f"{t_kernel:.1f}",
         "pallas interpret=True"],
    ]
    summary["scatter_update"] = {
        "masked_us": t_masked,
        "blockcsr_us": t_local,
        "blockcsr_kernel_interpret_us": t_kernel,
        "hot_path_speedup_vs_masked": t_masked / t_local,
        "kernel_interpret_overhead_x": t_kernel / t_local,
    }

    # --- prox-fused update (FD-Prox-SVRG inner step: scatter + VR update
    # + soft-threshold + elastic-net shrink in ONE pass) ---
    lam1, lam2 = 1e-3, 1e-4
    t_unfused = _timeit(
        jax.jit(lambda i, v, c, w, z: _blockcsr_prox_update_jnp(
            i, v, c, w, z, eta, lam1, lam2)),
        bidx_u, bval_u, coef, w_blk, z_blk, iters=iters,
    )
    t_kernel = _timeit(
        lambda i, v, c, w, z: ops.fused_block_prox_update(
            w, i, v, c, z, jnp.float32(eta), lam=0.0, lam1=lam1, lam2=lam2,
            interpret=True),
        bidx_u, bval_u, coef, w_blk, z_blk, iters=iters,
    )
    rows += [
        [f"prox_update_blockcsr_jnp_q{q}", f"{t_unfused:.1f}",
         f"[u={u},d/q={block_dim},elastic_net]"],
        [f"prox_update_blockcsr_kernel_q{q}", f"{t_kernel:.1f}",
         "pallas interpret=True"],
    ]
    summary["prox_update"] = {
        "blockcsr_us": t_unfused,
        "blockcsr_kernel_interpret_us": t_kernel,
        "kernel_interpret_overhead_x": t_kernel / t_unfused,
    }
    return rows, summary


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    rows = []

    d, n = (2048, 512) if quick else (8192, 2048)
    w = jnp.asarray(rng.normal(size=d).astype(np.float32))
    dmat = jnp.asarray(rng.normal(size=(d, n)).astype(np.float32))
    rows.append([
        f"fd_matvec_ref_{d}x{n}",
        f"{_timeit(jax.jit(lambda a, b: ref.fd_matvec_ref(a[:, None], b)), w, dmat):.1f}",
        "jnp oracle",
    ])

    s = jnp.asarray(rng.normal(size=65536).astype(np.float32))
    y = jnp.sign(s) + (jnp.sign(s) == 0)
    rows.append([
        "logistic_grad_ref_65536",
        f"{_timeit(jax.jit(ref.logistic_grad_ref), s, y):.1f}",
        "jnp oracle",
    ])

    wv = jnp.asarray(rng.normal(size=262144).astype(np.float32))
    g = jnp.asarray(rng.normal(size=262144).astype(np.float32))
    z = jnp.asarray(rng.normal(size=262144).astype(np.float32))
    rows.append([
        "svrg_update_ref_262144",
        f"{_timeit(jax.jit(lambda a, b, c: ref.svrg_update_ref(a, b, c, eta=0.1, lam=1e-4)), wv, g, z):.1f}",
        "jnp oracle",
    ])

    q = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(4096, 2, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(4096, 2, 64)).astype(np.float32))
    rows.append([
        "flash_decode_ref_4096",
        f"{_timeit(jax.jit(lambda a, b, c: ref.flash_decode_ref(a, b, c, length=4000)), q, k, v):.1f}",
        "jnp oracle",
    ])
    # interpret-mode kernel sanity timing (NOT a TPU number)
    rows.append([
        "flash_decode_pallas_interp_4096",
        f"{_timeit(lambda a, b, c: ops.decode_attention(a, b, c, length=4000, interpret=True), q, k, v):.1f}",
        "pallas interpret=True",
    ])

    blockcsr_rows, blockcsr_summary = bench_blockcsr(quick)
    rows += blockcsr_rows

    path = write_csv("kernels_micro.csv", ["name", "us_per_call", "derived"], rows)
    return path, rows, blockcsr_summary


def report_payload(rows, blockcsr, wall_us: float, quick: bool) -> dict:
    """The BENCH_kernels.json schema — one builder for the standalone and
    the aggregate (benchmarks.run) entry points."""
    return {
        "wall_us": wall_us,
        "quick": quick,
        "kernels": {str(r[0]): {"us_per_call": r[1], "derived": r[2]}
                    for r in rows if len(r) >= 3},
        "blockcsr": blockcsr,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shapes (CI smoke mode)")
    args = ap.parse_args()
    t0 = time.perf_counter()
    path, rows, blockcsr = run(quick=args.quick)
    write_bench_json("kernels", report_payload(
        rows, blockcsr, (time.perf_counter() - t0) * 1e6, args.quick))
    print(f"kernels: wrote {len(rows)} rows to {path}")
    for r in rows:
        print("  ", ",".join(map(str, r)))
    for section in ("margin_fullgrad", "margin_inner", "scatter_update"):
        s = blockcsr[section]
        print(
            f"  {section}: blockcsr hot path {s['hot_path_speedup_vs_masked']:.2f}x "
            f"vs masked global-CSR (kernel interpret-mode semantics check "
            f"{s['kernel_interpret_overhead_x']:.1f}x the jnp time; TPU numbers "
            f"come from the roofline model)"
        )


if __name__ == "__main__":
    main()
