"""Serving benchmark: batched sparse inference + online partial_fit.

What it measures and certifies (the numbers land in BENCH_serve.json):

* **throughput** — predictions/s through the `MicroBatcher ->
  PredictionEngine` path (engine compute only, and end-to-end with the
  interleaved training included);
* **latency** — p50/p99 request latency, enqueue to served (so it
  includes the batching delay the deadline policy bounds);
* **bounded shapes** — the flushed-bucket histogram and the engine's
  compiled-shape meter: the shape universe must stay within the
  ``log2(max_batch) * log2(max_width)`` bound the batcher constructs;
* **staleness** — versions published mid-stream and the per-request
  staleness histogram (batches pinned pre-publish serve with the old
  snapshot and report staleness 1);
* **bitwise serving** — engine margins equal ``FDSVRGClassifier.
  decision_function`` on the same rows, jnp path AND Pallas kernel
  path, re-proven on the benchmark's own traffic.

Standalone entry point with a ``--quick`` smoke mode for CI:

    PYTHONPATH=src python -m benchmarks.serve_bench [--quick]

writes results/benchmarks/serve.csv and BENCH_serve.json, and exits
non-zero if a certified contract (bitwise equality, bounded shapes,
interleaving actually happened) fails — CI treats a regression here as
a build break.
"""

from __future__ import annotations

import argparse
import math
import time

import numpy as np

from benchmarks.common import ensure_dir, write_bench_json, write_csv
from repro.api import FDSVRGClassifier
from repro.data.sparse import PaddedCSR
from repro.serve import (
    MicroBatcher,
    PredictionEngine,
    run_serve_loop,
    synthetic_request_source,
)


def _traffic(quick: bool):
    if quick:
        return dict(dim=2_048, num_requests=2_000, nnz_lo=2, nnz_hi=32)
    return dict(dim=65_536, num_requests=20_000, nnz_lo=2, nnz_hi=64)


def _warm_classifier(stream, n_warm: int) -> FDSVRGClassifier:
    data = stream.materialize()
    warm = PaddedCSR(
        indices=data.indices[:n_warm],
        values=data.values[:n_warm],
        labels=data.labels[:n_warm],
        dim=data.dim,
    )
    clf = FDSVRGClassifier(
        method="serial", eta=0.3, lam=1e-3, inner_steps=32, outer_iters=1
    )
    clf.fit(warm)
    return clf


def _bitwise_gate(stream, clf) -> dict:
    """Engine == decision_function on this benchmark's rows, both paths."""
    data = stream.materialize()
    out = {}
    for use_kernels in (False, True):
        clf.use_kernels = use_kernels
        engine = PredictionEngine.from_estimator(clf, use_kernels=use_kernels)
        got = engine.margins(data.indices, data.values)
        want = clf.decision_function(data)
        key = "kernel" if use_kernels else "jnp"
        out[f"engine_equals_decision_function_{key}"] = bool(
            np.array_equal(got, want)
        )
    clf.use_kernels = False
    return out


def run(quick: bool = False):
    cfg = _traffic(quick)
    max_batch = 128 if quick else 256
    min_width = 8
    chunk_rows = 200 if quick else 500
    update_every = 2

    stream = synthetic_request_source(seed=11, **cfg)
    clf = _warm_classifier(stream, n_warm=chunk_rows)
    rows: list[list] = []

    # bitwise gates first (cheap, and everything else is meaningless if
    # the engine doesn't serve the estimator's numbers)
    t = time.perf_counter()
    gates = _bitwise_gate(stream, clf)
    t_gate = time.perf_counter() - t
    rows.append(["serve_bitwise_gate", f"{t_gate * 1e6:.0f}",
                 ";".join(f"{k.rsplit('_', 1)[-1]}={v}"
                          for k, v in gates.items())])

    # the serve loop: inference interleaved with partial_fit
    engine = PredictionEngine.from_estimator(clf)
    batcher = MicroBatcher(
        max_batch=max_batch, max_delay_s=0.001, min_width=min_width
    )
    report = run_serve_loop(
        stream, engine, batcher,
        classifier=clf, update_every_chunks=update_every,
        chunk_rows=chunk_rows,
    )
    lat = report.latency_percentiles()
    hist = report.staleness_histogram()
    # the constructed bound on the compiled-shape universe
    width_hi = max(w for _, w in report.bucket_counts)
    shape_bound = (int(math.log2(max_batch)) + 1) * (
        int(math.log2(width_hi // min_width)) + 1
    )
    shapes_bounded = report.compiled_shapes <= shape_bound
    interleaved = (
        report.versions_published >= 2
        and len({r.version_used for r in report.served}) >= 2
        and hist.get(1, 0) > 0
    )
    rows.append([
        "serve_loop_total", f"{report.total_wall_s * 1e6:.0f}",
        f"{report.predictions_per_s:.0f}pred/s "
        f"p50={lat['p50_ms']:.2f}ms p99={lat['p99_ms']:.2f}ms "
        f"batches={report.num_batches} shapes={report.compiled_shapes} "
        f"versions={report.versions_published} "
        f"staleness1={hist.get(1, 0)}",
    ])
    rows.append([
        "serve_engine_compute", f"{report.serve_wall_s * 1e6:.0f}",
        f"{report.num_requests}req/{report.num_batches}batches "
        f"causes={report.flush_causes}",
    ])

    summary = {
        "traffic": {**cfg, "max_batch": max_batch, "min_width": min_width,
                    "chunk_rows": chunk_rows,
                    "update_every_chunks": update_every},
        "throughput": {
            "predictions_per_s": report.predictions_per_s,
            "requests": report.num_requests,
            "batches": report.num_batches,
            "serve_wall_s": report.serve_wall_s,
            "total_wall_s": report.total_wall_s,
        },
        "latency_ms": lat,
        "shapes": {
            "bucket_counts": {
                f"{r}x{w}": c for (r, w), c in
                sorted(report.bucket_counts.items())
            },
            "flush_causes": report.flush_causes,
            "compiled_shapes": report.compiled_shapes,
            "shape_bound": shape_bound,
            "shapes_bounded": bool(shapes_bounded),
        },
        "staleness": {
            "versions_published": report.versions_published,
            "updates_skipped": report.updates_skipped,
            "histogram": {str(k): v for k, v in sorted(hist.items())},
            "interleaved": bool(interleaved),
        },
        "bitwise": gates,
    }

    ensure_dir()
    path = write_csv("serve.csv", ["name", "us_per_call", "derived"], rows)
    return path, rows, summary


def contracts_hold(summary: dict) -> bool:
    """The certified invariants a CI run gates on."""
    return (
        all(summary["bitwise"].values())
        and summary["shapes"]["shapes_bounded"]
        and summary["staleness"]["interleaved"]
    )


def report_payload(summary: dict, wall_us: float, quick: bool) -> dict:
    """The BENCH_serve.json schema — one builder for the standalone and
    the aggregate (benchmarks.run) entry points."""
    return {
        "wall_us": wall_us,
        "quick": quick,
        "predictions_per_s": summary["throughput"]["predictions_per_s"],
        "p50_ms": summary["latency_ms"]["p50_ms"],
        "p99_ms": summary["latency_ms"]["p99_ms"],
        "compiled_shapes": summary["shapes"]["compiled_shapes"],
        "shapes_bounded": summary["shapes"]["shapes_bounded"],
        "versions_published": summary["staleness"]["versions_published"],
        "interleaved": summary["staleness"]["interleaved"],
        "bitwise": summary["bitwise"],
        "detail": summary,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small traffic (CI smoke mode)")
    args = ap.parse_args()
    t0 = time.perf_counter()
    path, rows, summary = run(quick=args.quick)
    payload = report_payload(
        summary, (time.perf_counter() - t0) * 1e6, args.quick)
    write_bench_json("serve", payload)
    print(f"serve: wrote {len(rows)} rows to {path}")
    for r in rows:
        print("  ", ",".join(map(str, r)))
    print(
        f"  {payload['predictions_per_s']:.0f} pred/s, "
        f"p50 {payload['p50_ms']:.2f}ms / p99 {payload['p99_ms']:.2f}ms, "
        f"{payload['compiled_shapes']} compiled shapes "
        f"(bound {summary['shapes']['shape_bound']}), "
        f"{payload['versions_published']} versions published"
    )
    if not contracts_hold(summary):
        raise SystemExit(
            "serve contracts FAILED: "
            f"bitwise={summary['bitwise']} "
            f"shapes_bounded={summary['shapes']['shapes_bounded']} "
            f"interleaved={summary['staleness']['interleaved']}"
        )


if __name__ == "__main__":
    main()
