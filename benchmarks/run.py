"""Benchmark entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV lines per the harness contract;
full tables land in results/benchmarks/*.csv, and per-suite JSON reports
(including the per-method ``repro.dist`` communication reports) land in
results/benchmarks/BENCH_<name>.json — schema in docs/benchmarks.md.
"""

from __future__ import annotations

import argparse
import time

from benchmarks.common import write_bench_json


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="small datasets only")
    args = ap.parse_args()

    from benchmarks import (
        chaos_bench,
        convergence,
        ingest_bench,
        serve_bench,
        kernels_bench,
        lambda_sensitivity,
        lazy_bench,
        roofline,
        scalability,
        speedup,
    )
    from repro.dist.metering import reports_to_json

    print("name,us_per_call,derived")
    t0 = time.perf_counter()

    def stamp(name, t_start, derived):
        us = (time.perf_counter() - t_start) * 1e6
        print(f"{name},{us:.0f},{derived}", flush=True)
        return us

    t = time.perf_counter()
    _, rows = convergence.run(quick=args.quick)
    us = stamp("fig6_fig7_convergence", t, f"{len(rows)} rows")
    write_bench_json("convergence", {"wall_us": us, "rows": len(rows)})

    t = time.perf_counter()
    _, rows, summary, reports = speedup.run(quick=args.quick)
    fd_vs_ds = [r for r in rows if r[1] == "speedup_vs_dsvrg"]
    us = stamp("tab2_speedup_vs_dsvrg", t,
               ";".join(f"{r[0]}={r[3]}" for r in fd_vs_ds))
    fd_vs_ps = [r for r in rows if r[1] == "speedup_vs_pslite_sgd"]
    print(f"tab3_speedup_vs_pslite,0," + ";".join(f"{r[0]}={r[3]}" for r in fd_vs_ps))
    write_bench_json("speedup", {
        "wall_us": us,
        "modeled_time_to_gap_s": {
            name: {m: t_gap for m, t_gap in times.items()}
            for name, times in summary.items()
        },
        "comm": reports_to_json(reports),
    })

    t = time.perf_counter()
    _, rows = lambda_sensitivity.run()
    us = stamp("fig8_lambda_sensitivity", t, f"{len(rows)} rows")
    write_bench_json("lambda_sensitivity", {"wall_us": us, "rows": len(rows)})

    t = time.perf_counter()
    _, rows, payload = lambda_sensitivity.run_prox(quick=args.quick)
    us = stamp("prox_sparsity_sweep", t,
               f"{len(rows)} rows;comm_parity={payload['comm_parity_with_l2']}")
    payload["wall_us"] = us
    write_bench_json("prox", payload)

    t = time.perf_counter()
    _, rows, times, measured = scalability.run()
    us = stamp("fig9_scalability", t,
               ";".join(f"q{q}={times[1]/times[q]:.2f}x" for q in (1, 4, 8, 16)))
    write_bench_json("scalability", {
        "wall_us": us,
        "modeled_time_s": {str(q): times[q] for q in times},
        "speedup": {str(q): times[1] / times[q] for q in times},
        "comm": reports_to_json({"webspam/fdsvrg": measured}),
    })

    t = time.perf_counter()
    _, rows, blockcsr = kernels_bench.run(quick=args.quick)
    for r in rows:
        print(",".join(map(str, r)))
    us = stamp("kernels_micro_total", t, f"{len(rows)} kernels")
    write_bench_json(
        "kernels", kernels_bench.report_payload(rows, blockcsr, us, args.quick)
    )

    t = time.perf_counter()
    _, rows, lazy_summary = lazy_bench.run(quick=args.quick)
    for r in rows:
        print(",".join(map(str, r)))
    us = stamp(
        "lazy_inner_total", t,
        f"proba {lazy_summary['inner_epoch']['speedup_proba']:.2f}x;"
        f"bitwise={lazy_summary['inner_epoch']['exact_bitwise_equal']};"
        f"comm_parity={lazy_summary['comm']['comm_parity']}",
    )
    write_bench_json(
        "lazy", lazy_bench.report_payload(lazy_summary, us, args.quick)
    )

    t = time.perf_counter()
    _, rows, chaos_summary = chaos_bench.run(quick=args.quick)
    for r in rows:
        print(",".join(map(str, r)))
    us = stamp(
        "chaos_total", t,
        f"{len(chaos_summary['plans'])} plans;"
        f"converged={chaos_summary['all_converged']};"
        f"accounting={chaos_summary['all_accounting_exact']}",
    )
    write_bench_json(
        "chaos", chaos_bench.report_payload(chaos_summary, us, args.quick)
    )

    t = time.perf_counter()
    _, rows, ingest_summary = ingest_bench.run(quick=args.quick)
    for r in rows:
        print(",".join(map(str, r)))
    us = stamp(
        "ingest_total", t,
        f"{ingest_summary['throughput']['streamed_rows_per_s']:.0f}rows/s;"
        f"equal={ingest_summary['streamed_equals_oneshot']};"
        f"warm={ingest_summary['cache']['warm_speedup']:.1f}x",
    )
    write_bench_json(
        "ingest", ingest_bench.report_payload(ingest_summary, us, args.quick)
    )

    t = time.perf_counter()
    _, rows, serve_summary = serve_bench.run(quick=args.quick)
    for r in rows:
        print(",".join(map(str, r)))
    us = stamp(
        "serve_total", t,
        f"{serve_summary['throughput']['predictions_per_s']:.0f}pred/s;"
        f"p99={serve_summary['latency_ms']['p99_ms']:.2f}ms;"
        f"shapes={serve_summary['shapes']['compiled_shapes']};"
        f"bitwise={all(serve_summary['bitwise'].values())}",
    )
    write_bench_json(
        "serve", serve_bench.report_payload(serve_summary, us, args.quick)
    )

    t = time.perf_counter()
    _, rows = roofline.run()
    ok = sum(1 for r in rows if r and r[3] != "FAIL")
    us = stamp("roofline_table", t, f"{ok}/{len(rows)} dryrun combos OK")
    write_bench_json("roofline", {"wall_us": us, "ok": ok, "total": len(rows)})

    print(f"total_benchmark_wall,{(time.perf_counter()-t0)*1e6:.0f},seconds="
          f"{time.perf_counter()-t0:.1f}")


if __name__ == "__main__":
    main()
