"""Benchmark entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV lines per the harness contract;
full tables land in results/benchmarks/*.csv.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="small datasets only")
    args = ap.parse_args()

    from benchmarks import (
        convergence,
        kernels_bench,
        lambda_sensitivity,
        roofline,
        scalability,
        speedup,
    )

    print("name,us_per_call,derived")
    t0 = time.perf_counter()

    def stamp(name, t_start, derived):
        us = (time.perf_counter() - t_start) * 1e6
        print(f"{name},{us:.0f},{derived}", flush=True)

    t = time.perf_counter()
    _, rows = convergence.run(quick=args.quick)
    stamp("fig6_fig7_convergence", t, f"{len(rows)} rows")

    t = time.perf_counter()
    _, rows, summary = speedup.run(quick=args.quick)
    fd_vs_ds = [r for r in rows if r[1] == "speedup_vs_dsvrg"]
    stamp("tab2_speedup_vs_dsvrg", t,
          ";".join(f"{r[0]}={r[3]}" for r in fd_vs_ds))
    fd_vs_ps = [r for r in rows if r[1] == "speedup_vs_pslite_sgd"]
    print(f"tab3_speedup_vs_pslite,0," + ";".join(f"{r[0]}={r[3]}" for r in fd_vs_ps))

    t = time.perf_counter()
    _, rows = lambda_sensitivity.run()
    stamp("fig8_lambda_sensitivity", t, f"{len(rows)} rows")

    t = time.perf_counter()
    _, rows, times = scalability.run()
    stamp("fig9_scalability", t,
          ";".join(f"q{q}={times[1]/times[q]:.2f}x" for q in (1, 4, 8, 16)))

    t = time.perf_counter()
    _, rows = kernels_bench.run()
    for r in rows:
        print(",".join(map(str, r)))
    stamp("kernels_micro_total", t, f"{len(rows)} kernels")

    t = time.perf_counter()
    _, rows = roofline.run()
    ok = sum(1 for r in rows if r and r[3] != "FAIL")
    stamp("roofline_table", t, f"{ok}/{len(rows)} dryrun combos OK")

    print(f"total_benchmark_wall,{(time.perf_counter()-t0)*1e6:.0f},seconds="
          f"{time.perf_counter()-t0:.1f}")


if __name__ == "__main__":
    main()
