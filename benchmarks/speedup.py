"""Tables 2 & 3: modeled time to reach a 1e-4 objective gap; speedups of
FD-SVRG over DSVRG and over PS-Lite (SGD).

Every method runs through the ``repro.dist`` substrate, so the
``measured_*`` columns are bytes-on-the-wire read from each run's meter —
same metering machinery and closed forms for every method, hence
apples-to-apples by construction."""

from __future__ import annotations

from benchmarks.common import (
    analytic_schedule,
    best_objective,
    comm_report,
    run_method,
    time_to_gap,
    write_csv,
)
from repro.data import datasets

TOL = 1e-4


def run(lam: float = 1e-4, outer_iters: int = 8, quick: bool = False):
    names = ["news20", "webspam"] if quick else ["news20", "url", "webspam", "kdd2010"]
    rows = []
    summary = {}
    reports = {}
    for name in names:
        spec_full = datasets.spec(name, scaled=False)
        data = datasets.load(name)
        q = spec_full.default_workers
        res = {
            m: run_method(m, data, q, lam, outer_iters=outer_iters)
            for m in ("fdsvrg", "fd_saga", "fd_bcd", "dsvrg", "pslite_sgd")
        }
        star = best_objective(list(res.values()))
        times = {}
        last_time = {}
        for m, r in res.items():
            rep = comm_report(m, r, q)
            reports[f"{name}/{m}"] = rep
            sched = analytic_schedule(m, spec_full, q, outer_iters)
            t, comm, outer = time_to_gap(r, star, sched, TOL)
            times[m] = t
            last_time[m] = sched[-1][0]
            rows.append([
                name, m, q,
                f"{t:.6f}" if t is not None else f">{sched[-1][0]:.4f}",
                comm if comm is not None else f">{sched[-1][1]}",
                outer if outer is not None else "n/a",
                rep.scalars,
                rep.bytes_on_wire,
            ])
        summary[name] = times
        # speedups (paper Table 2/3 layout)
        fd = times["fdsvrg"]
        for base in ("dsvrg", "pslite_sgd"):
            tb = times[base]
            if fd:
                if tb is not None:
                    sp = tb / fd
                    rows.append([name, f"speedup_vs_{base}", q, f"{sp:.2f}", "", "", "", ""])
                else:
                    lower = last_time[base] / fd
                    rows.append([name, f"speedup_vs_{base}", q, f">{lower:.1f}", "", "", "", ""])
    path = write_csv(
        "tab2_tab3_speedup.csv",
        ["dataset", "method", "workers", "modeled_time_to_gap_s",
         "comm_scalars_to_gap", "outer_iters_to_gap",
         "measured_comm_scalars", "measured_bytes_on_wire"],
        rows,
    )
    return path, rows, summary, reports


def main():
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--quick", action="store_true",
        help="smoke subset (news20 + webspam only) — the CI configuration",
    )
    args = ap.parse_args()
    path, rows, summary, reports = run(quick=args.quick)
    print(f"speedup: wrote {len(rows)} rows to {path}")
    for name, times in summary.items():
        print(" ", name, {k: (round(v, 5) if v else None) for k, v in times.items()})
    for key, rep in sorted(reports.items()):
        print(f"  {key}: {rep.bytes_on_wire:,} bytes on the wire "
              f"({rep.scalars:,} scalars, {rep.rounds:,} rounds)")


if __name__ == "__main__":
    main()
