"""Out-of-core ingestion benchmark: streaming LibSVM -> per-worker
BlockCSR slabs with the on-disk cache.

What it measures and certifies (the numbers land in BENCH_ingest.json):

* **throughput** — rows/s for the chunked parse+build
  (:func:`repro.data.pipeline.stream_block_csr` over a
  :class:`~repro.data.pipeline.LibSVMSource`) vs the one-shot
  ``load_libsvm -> BlockCSR.from_padded`` path;
* **bounded ingestion memory** — the tracemalloc python-heap peak during
  the chunked build stays under an analytic budget of
  ``output slabs + compacted strips + K chunks + slack``, i.e. transient
  parse state is a constant number of chunks, never the padded file
  (numpy data allocations are tracemalloc-visible; jax buffers are not,
  so the build keeps everything numpy until the final device put);
* **cache** — a cold ``get_or_build`` parses and writes slabs, the warm
  re-run loads them back bitwise-equal without touching the parser;
* **equality** — streamed-vs-oneshot bitwise equality, the pipeline's
  hard contract, re-proven on the benchmark's own skewed-width data.

Standalone entry point with a ``--quick`` smoke mode for CI:

    PYTHONPATH=src python -m benchmarks.ingest_bench [--quick]

writes results/benchmarks/ingest.csv and BENCH_ingest.json, and exits
non-zero if any certified contract (equality, warm hit, memory budget)
fails — CI treats a regression here as a build break.
"""

from __future__ import annotations

import argparse
import os
import shutil
import tempfile
import time
import tracemalloc

import numpy as np

from benchmarks.common import ensure_dir, write_bench_json, write_csv
from repro.core.partition import balanced
from repro.data.block_csr import BlockCSR
from repro.data.libsvm import load_libsvm, write_libsvm
from repro.data.ingest_cache import get_or_build
from repro.data.pipeline import LibSVMSource, stream_block_csr
from repro.data.sparse import PaddedCSR


def _skewed_data(quick: bool) -> PaddedCSR:
    """Text-shaped rows: mostly narrow, a few very wide — the regime
    where chunked parsing matters (the global padded width is set by
    rare outlier rows, so whole-file materialization is mostly padding).
    """
    if quick:
        n, dim, nnz_common, nnz_wide, every = 2_000, 8_192, 4, 64, 250
    else:
        n, dim, nnz_common, nnz_wide, every = 30_000, 65_536, 6, 256, 500
    rng = np.random.default_rng(7)
    indices = np.zeros((n, nnz_wide), dtype=np.int32)
    values = np.zeros((n, nnz_wide), dtype=np.float32)
    for i in range(n):
        k = nnz_wide if i % every == 0 else nnz_common
        cols = rng.choice(dim, size=k, replace=False).astype(np.int32)
        indices[i, :k] = cols
        values[i, :k] = rng.normal(size=k).astype(np.float32)
    labels = rng.choice([-1.0, 1.0], size=n).astype(np.float32)
    return PaddedCSR(
        indices=indices, values=values, labels=labels, dim=dim
    )


def _blocks_equal(a: BlockCSR, b: BlockCSR) -> bool:
    if a.partition.bounds != b.partition.bounds:
        return False
    if a.nnz_budgets != b.nnz_budgets:
        return False
    if not np.array_equal(np.asarray(a.labels), np.asarray(b.labels)):
        return False
    for l in range(a.num_blocks):
        for fa, fb in (
            (a.indices[l], b.indices[l]),
            (a.values[l], b.values[l]),
            (a.nnz_col[l], b.nnz_col[l]),
        ):
            if not np.array_equal(np.asarray(fa), np.asarray(fb)):
                return False
    return True


def _slab_bytes(block: BlockCSR) -> int:
    """Bytes the finished slabs occupy (indices + values + nnz_col)."""
    total = 0
    for l in range(block.num_blocks):
        total += np.asarray(block.indices[l]).nbytes
        total += np.asarray(block.values[l]).nbytes
        total += np.asarray(block.nnz_col[l]).nbytes
    return total + np.asarray(block.labels).nbytes


def _memory_budget(block: BlockCSR, chunk_rows: int, nnz_wide: int) -> int:
    """The analytic peak-heap bound the streamed build must respect.

    * the output slabs themselves (padded, O(n));
    * the compacted per-chunk strips the accumulators hold until
      ``finalize`` — at most the slabs again;
    * a constant number of in-flight chunk buffers: the packed numpy
      chunk plus the row-of-python-lists parse state (~100 bytes per
      stored entry is generous for boxed floats + list slots);
    * fixed slack for interpreter noise.
    """
    slabs = _slab_bytes(block)
    chunk_numpy = chunk_rows * nnz_wide * (4 + 4 + 8)
    chunk_python = chunk_rows * nnz_wide * 100
    return 2 * slabs + 4 * (chunk_numpy + chunk_python) + (16 << 20)


def run(quick: bool = False):
    q = 4
    chunk_rows = 256 if quick else 1024
    data = _skewed_data(quick)
    nnz_wide = data.nnz_max
    n = data.num_instances

    workdir = tempfile.mkdtemp(prefix="ingest_bench_")
    rows: list[list] = []
    try:
        path = os.path.join(workdir, "bench.libsvm")
        t = time.perf_counter()
        write_libsvm(path, data)
        t_write = time.perf_counter() - t
        file_mb = os.path.getsize(path) / 2**20
        rows.append(["ingest_write_libsvm", f"{t_write * 1e6:.0f}",
                     f"{file_mb:.1f}MB"])

        # one-shot reference: whole file -> padded matrix -> slabs
        t = time.perf_counter()
        tracemalloc.start()
        loaded = load_libsvm(path)
        part = balanced(loaded.dim, q)
        oneshot = BlockCSR.from_padded(loaded, part)
        _, peak_oneshot = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        t_oneshot = time.perf_counter() - t
        rows.append(["ingest_oneshot_build", f"{t_oneshot * 1e6:.0f}",
                     f"{n / t_oneshot:.0f}rows/s "
                     f"peak={peak_oneshot / 2**20:.1f}MB"])

        # streamed build: bounded chunks, same bits out
        source = LibSVMSource(path)
        t = time.perf_counter()
        tracemalloc.start()
        streamed = stream_block_csr(source, part, chunk_rows=chunk_rows)
        _, peak_streamed = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        t_streamed = time.perf_counter() - t
        equal = _blocks_equal(streamed, oneshot)
        budget = _memory_budget(streamed, chunk_rows, nnz_wide)
        within = peak_streamed <= budget
        rows.append(["ingest_streamed_build", f"{t_streamed * 1e6:.0f}",
                     f"{n / t_streamed:.0f}rows/s chunk={chunk_rows} "
                     f"peak={peak_streamed / 2**20:.1f}MB "
                     f"budget={budget / 2**20:.1f}MB "
                     f"equal={equal} within_budget={within}"])

        # cache: cold writes slabs, warm skips the parser entirely
        cache_dir = os.path.join(workdir, "cache")
        t = time.perf_counter()
        cold = get_or_build(LibSVMSource(path), part, cache_dir=cache_dir,
                            chunk_rows=chunk_rows)
        t_cold = time.perf_counter() - t
        t = time.perf_counter()
        warm = get_or_build(LibSVMSource(path), part, cache_dir=cache_dir,
                            chunk_rows=chunk_rows)
        t_warm = time.perf_counter() - t
        warm_hit = (
            cold.status == "cold"
            and warm.status == "warm"
            and _blocks_equal(cold.data, warm.data)
        )
        rows.append(["ingest_cache_cold", f"{t_cold * 1e6:.0f}",
                     f"status={cold.status}"])
        rows.append(["ingest_cache_warm", f"{t_warm * 1e6:.0f}",
                     f"status={warm.status} hit={warm_hit} "
                     f"speedup={t_cold / t_warm:.1f}x"])

        summary = {
            "shape": {
                "n": n, "dim": data.dim, "nnz_max": int(nnz_wide),
                "q": q, "chunk_rows": chunk_rows,
                "file_mb": file_mb,
            },
            "throughput": {
                "streamed_rows_per_s": n / t_streamed,
                "oneshot_rows_per_s": n / t_oneshot,
                "write_s": t_write,
            },
            "memory": {
                "streamed_peak_bytes": int(peak_streamed),
                "oneshot_peak_bytes": int(peak_oneshot),
                "budget_bytes": int(budget),
                "slab_bytes": int(_slab_bytes(streamed)),
                "peak_within_budget": bool(within),
            },
            "cache": {
                "cold_s": t_cold,
                "warm_s": t_warm,
                "warm_speedup": t_cold / t_warm,
                "warm_hit": bool(warm_hit),
            },
            "streamed_equals_oneshot": bool(equal),
        }
    finally:
        shutil.rmtree(workdir, ignore_errors=True)

    ensure_dir()
    path = write_csv("ingest.csv", ["name", "us_per_call", "derived"], rows)
    return path, rows, summary


def contracts_hold(summary: dict) -> bool:
    """The certified invariants a CI run gates on."""
    return (
        summary["streamed_equals_oneshot"]
        and summary["cache"]["warm_hit"]
        and summary["memory"]["peak_within_budget"]
    )


def report_payload(summary: dict, wall_us: float, quick: bool) -> dict:
    """The BENCH_ingest.json schema — one builder for the standalone and
    the aggregate (benchmarks.run) entry points."""
    return {
        "wall_us": wall_us,
        "quick": quick,
        "streamed_rows_per_s": summary["throughput"]["streamed_rows_per_s"],
        "streamed_equals_oneshot": summary["streamed_equals_oneshot"],
        "peak_within_budget": summary["memory"]["peak_within_budget"],
        "warm_hit": summary["cache"]["warm_hit"],
        "warm_speedup": summary["cache"]["warm_speedup"],
        "detail": summary,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small file (CI smoke mode)")
    args = ap.parse_args()
    t0 = time.perf_counter()
    path, rows, summary = run(quick=args.quick)
    payload = report_payload(
        summary, (time.perf_counter() - t0) * 1e6, args.quick)
    write_bench_json("ingest", payload)
    print(f"ingest: wrote {len(rows)} rows to {path}")
    for r in rows:
        print("  ", ",".join(map(str, r)))
    print(
        f"  streamed {payload['streamed_rows_per_s']:.0f} rows/s at "
        f"chunk={summary['shape']['chunk_rows']}; peak "
        f"{summary['memory']['streamed_peak_bytes'] / 2**20:.1f}MB vs "
        f"budget {summary['memory']['budget_bytes'] / 2**20:.1f}MB; warm "
        f"cache {payload['warm_speedup']:.1f}x; "
        f"equal={payload['streamed_equals_oneshot']}"
    )
    if not contracts_hold(summary):
        raise SystemExit(
            "ingest contracts FAILED: "
            f"equal={summary['streamed_equals_oneshot']} "
            f"warm_hit={summary['cache']['warm_hit']} "
            f"within_budget={summary['memory']['peak_within_budget']}"
        )


if __name__ == "__main__":
    main()
