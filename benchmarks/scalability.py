"""Figure 9: FD-SVRG speedup vs worker count on webspam.

speedup(q) = modeled_time(1 worker) / modeled_time(q workers) at equal
work (same outer iterations / gradient budget)."""

from __future__ import annotations

from benchmarks.common import analytic_outer, comm_report, run_method, write_csv
from repro.data import datasets


def run(outer_iters: int = 4):
    """Correctness trajectory from the scaled data (the algorithm is
    identical for any q — verified by the equivalence tests), time from the
    full-size analytic model at each worker count."""
    data = datasets.load("webspam")
    spec_full = datasets.spec("webspam", scaled=False)
    # one scaled run proves convergence; per-q time is the analytic model
    res = run_method("fdsvrg", data, 16, 1e-4, outer_iters=outer_iters)
    assert res.history[-1].objective < res.history[0].objective
    measured = comm_report("fdsvrg", res, 16)

    rows = []
    times = {}
    for q in (1, 4, 8, 16):
        t1, _ = analytic_outer("fdsvrg", spec_full, q)
        times[q] = t1 * outer_iters
    for q in (1, 4, 8, 16):
        rows.append([q, f"{times[q]:.6f}", f"{times[1] / times[q]:.3f}", q])
    path = write_csv(
        "fig9_scalability.csv",
        ["workers", "modeled_time_s", "speedup", "ideal"],
        rows,
    )
    return path, rows, times, measured


def main():
    path, rows, times, measured = run()
    print(f"scalability: wrote {len(rows)} rows to {path}")
    for q in (1, 4, 8, 16):
        print(f"  q={q}: time={times[q]:.5f}s speedup={times[1]/times[q]:.2f}x")
    print(f"  measured (scaled, q=16): {measured.bytes_on_wire:,} bytes on the wire")


if __name__ == "__main__":
    main()
